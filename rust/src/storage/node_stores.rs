//! The node-local storage data plane: a capacity-managed RAM tier
//! whose eviction **demotes** whole replicas to a per-node SSD tier
//! (when the machine models one) instead of destroying them.
//!
//! Residency semantics of a real tiered node store:
//!
//! - Replicas are stored once per *node range* (the staging hook
//!   writes the same blob to every node), so memory is O(files), not
//!   O(files x nodes). Replicas of one path are node-disjoint within a
//!   tier: a write replaces the overlapped portion of any older
//!   same-path replica in that tier.
//! - An optional uniform per-node **capacity** per tier is enforced on
//!   every write: least-recently-used unpinned replicas of other paths
//!   covering a still-over-budget node of the write range are
//!   displaced (whole replicas, LRU order, ties broken by insertion
//!   sequence then path/lo order) until the write fits on every node
//!   of its range. An infeasible write — pinned residents alone exceed
//!   the budget — is rejected with the store untouched.
//! - **Displacement from RAM demotes**: when the SSD tier is enabled,
//!   each RAM victim is re-inserted whole into the SSD tier (which may
//!   in turn discard its own LRU victims — the cascade is reported in
//!   the same eviction list, tagged [`StorageTier::Ssd`]). A victim
//!   the SSD cannot admit (over its budget even after discarding every
//!   unpinned SSD resident) is discarded, exactly the single-tier
//!   behaviour. With the SSD tier absent (`ssd_capacity() == None`)
//!   the store is byte-for-byte the pre-tiering single-tier RAM disk.
//! - **Pinned** paths are never displaced from either tier (the
//!   dataset a campaign is actively computing on, or an SSD replica a
//!   submitted promotion plan is about to consume).
//! - [`NodeStores::promote_range`] moves a replica SSD -> RAM (the
//!   cheap local re-stage path), with the same capacity-checked
//!   admission — RAM victims it displaces demote as usual.
//!
//! Fleet-scale layout: paths are interned to dense `u32` ids
//! ([`super::intern::PathInterner`]) and each tier's per-path state
//! lives in a `Vec<Option<PathEntry>>` indexed by id, so the
//! scheduler's placement loop ([`NodeStores::coverage_of_id`]) and the
//! cache-hit test are array indexes, not string-keyed BTree walks. The
//! string surface remains: it resolves through the interner once and
//! answers identically (the differential suite in
//! `tests/property_sched_scale.rs` holds the two surfaces equal).
//!
//! Enumeration is deterministic: `paths_on`/`dump` resolve ids and
//! sort by path, reproducing the BTreeMap-era ordering exactly. LRU
//! victim order never depended on enumeration order — the
//! `(last_use, seq)` key is unique across paths (ties only arise
//! between residuals of one split replica, which stay lo-sorted within
//! their entry) — so victim choice is bit-identical to the string
//! era. Per-path coverage is memoized beside the replica list, so the
//! scheduler's placement loop is a borrow, not a rescan.

use std::collections::BTreeMap;
use std::mem::size_of;

use crate::pfs::Blob;

use super::intern::PathInterner;
use super::residency_table::Eviction;
use super::tier::StorageTier;

/// Outcome of a capacity-checked node-local write.
#[derive(Clone, Debug)]
pub enum StoreWrite {
    /// Replica stored on every node of the range; `evicted` lists the
    /// displaced victims in displacement order: each RAM victim (LRU
    /// first, `demoted` telling whether it survived on SSD) followed
    /// immediately by the SSD discards its demotion caused.
    Stored { evicted: Vec<Eviction> },
    /// Write refused and the store left untouched: even after evicting
    /// every unpinned replica, some node of the range would still be
    /// `short_bytes` over capacity.
    Rejected { short_bytes: u64 },
}

/// Outcome of [`NodeStores::promote_range`].
#[derive(Clone, Debug)]
pub enum PromoteOutcome {
    /// The SSD replica now lives in RAM (`bytes` per node); `evicted`
    /// lists the RAM victims its admission displaced (plus their
    /// demotion cascade), as in [`StoreWrite::Stored`].
    Promoted { bytes: u64, evicted: Vec<Eviction> },
    /// Nothing to promote: the SSD tier does not hold `path` with
    /// uniform content across the whole node range.
    Missing,
    /// RAM admission was rejected (pinned residents alone exceed the
    /// budget); the SSD copy is left intact.
    Rejected { short_bytes: u64 },
}

/// One path's replicas in a [`NodeStores::dump`] snapshot:
/// (lo, hi, per-node bytes) per replica.
pub type ReplicaSnapshot = Vec<(u32, u32, u64)>;

/// One resident replica: `blob` present on every node in `lo..=hi`.
#[derive(Clone, Debug)]
struct Replica {
    lo: u32,
    hi: u32,
    blob: Blob,
    /// LRU clock value of the last write or touch.
    last_use: u64,
    /// Monotone insertion sequence (deterministic LRU tie-break;
    /// residuals of a split replica keep their original seq).
    seq: u64,
}

impl Replica {
    fn covers(&self, node: u32) -> bool {
        (self.lo..=self.hi).contains(&node)
    }

    fn overlaps(&self, lo: u32, hi: u32) -> bool {
        self.lo <= hi && self.hi >= lo
    }
}

/// One path's state in a tier: the node-disjoint replica list plus the
/// memoized coverage it implies. `coverage` is rebuilt on every
/// structural mutation, so reads are a slice borrow.
#[derive(Debug, Default)]
struct PathEntry {
    /// Node-disjoint replicas, sorted by `lo`.
    reps: Vec<Replica>,
    /// Memoized `(lo, hi)` per replica — sorted, disjoint.
    coverage: Vec<(u32, u32)>,
}

impl PathEntry {
    fn refresh_coverage(&mut self) {
        self.coverage.clear();
        self.coverage.extend(self.reps.iter().map(|r| (r.lo, r.hi)));
    }

    /// Binary search the memoized coverage for the replica covering
    /// `node` (coverage is sorted and disjoint).
    fn covering_idx(&self, node: u32) -> Option<usize> {
        let i = self.coverage.partition_point(|&(lo, _)| lo <= node);
        if i > 0 && self.coverage[i - 1].1 >= node {
            Some(i - 1)
        } else {
            None
        }
    }
}

/// Pin refcounts, keyed by interned path id.
type Pins = BTreeMap<u32, u32>;

/// Victims a tier displaced for one write, with their replicas (blobs
/// intact so the caller can demote them).
enum TierWrite {
    Stored { victims: Vec<(u32, Replica)> },
    Rejected { short_bytes: u64 },
}

/// One tier's replica store: capacity accounting, LRU displacement,
/// deterministic enumeration. Per-path state is a dense `Vec` indexed
/// by interned path id (`None` = path not resident in this tier). The
/// LRU clock and insertion sequence are shared across tiers (owned by
/// [`NodeStores`]) so demotions order correctly against ordinary
/// writes.
#[derive(Debug, Default)]
struct TierStore {
    /// path id -> replicas + memoized coverage.
    entries: Vec<Option<PathEntry>>,
    /// Number of `Some` slots (== distinct resident paths).
    occupied: usize,
    /// Uniform per-node byte budget; None = unbounded (RAM) or tier
    /// absent (SSD).
    capacity: Option<u64>,
    /// Resident bytes per node (only nodes holding data appear).
    used: BTreeMap<u32, u64>,
}

impl TierStore {
    fn entry(&self, id: u32) -> Option<&PathEntry> {
        self.entries.get(id as usize).and_then(Option::as_ref)
    }

    fn entry_mut(&mut self, id: u32) -> Option<&mut PathEntry> {
        self.entries.get_mut(id as usize).and_then(Option::as_mut)
    }

    /// Remove and return the entry of `id`, if resident.
    fn take_entry(&mut self, id: u32) -> Option<PathEntry> {
        let e = self.entries.get_mut(id as usize).and_then(Option::take);
        if e.is_some() {
            self.occupied -= 1;
        }
        e
    }

    /// Install `e` at `id` (the slot must be vacant).
    fn put_entry(&mut self, id: u32, e: PathEntry) {
        if id as usize >= self.entries.len() {
            self.entries.resize_with(id as usize + 1, || None);
        }
        debug_assert!(self.entries[id as usize].is_none());
        self.entries[id as usize] = Some(e);
        self.occupied += 1;
    }

    /// All resident entries in id order.
    fn iter_entries(&self) -> impl Iterator<Item = (u32, &PathEntry)> {
        self.entries
            .iter()
            .enumerate()
            .filter_map(|(i, e)| e.as_ref().map(|e| (i as u32, e)))
    }

    /// Capacity-checked write. On success returns the displaced
    /// victims (whole replicas, LRU order) so the caller can demote
    /// them; rejection leaves the tier byte-for-byte untouched.
    /// `clock`/`seq` are the shared LRU counters, bumped once on
    /// success.
    #[allow(clippy::too_many_arguments)]
    fn write_range_evicting(
        &mut self,
        lo: u32,
        hi: u32,
        id: u32,
        data: Blob,
        pinned: &Pins,
        clock: &mut u64,
        seq: &mut u64,
    ) -> TierWrite {
        assert!(lo <= hi, "bad node range");
        let need = data.len();
        let mut victims = Vec::new();
        if let Some(cap) = self.capacity {
            if need > cap {
                return TierWrite::Rejected { short_bytes: need - cap };
            }
            // Feasibility first, so rejection is a no-op: with every
            // eligible victim gone, only pinned other-path replicas
            // remain on the range's nodes. (Nothing pinned -> always
            // feasible, since `need <= cap` held above.)
            if !pinned.is_empty() {
                for n in lo..=hi {
                    let kept: u64 = self
                        .iter_entries()
                        .filter(|&(p, _)| p != id && pinned.contains_key(&p))
                        .flat_map(|(_, e)| e.reps.iter())
                        .filter(|r| r.covers(n))
                        .map(|r| r.blob.len())
                        .sum();
                    if kept + need > cap {
                        return TierWrite::Rejected { short_bytes: kept + need - cap };
                    }
                }
            }
            // Evict LRU victims until every node of the range fits.
            // Victims must cover at least one currently-over-budget
            // node: a merely range-overlapping replica on a node that
            // already fits would be displaced without freeing anything
            // where it matters.
            loop {
                let over: Vec<u32> = (lo..=hi)
                    .filter(|&n| self.used_after_overwrite(n, id) + need > cap)
                    .collect();
                if over.is_empty() {
                    break;
                }
                let victim = self
                    .iter_entries()
                    .filter(|&(p, _)| p != id && !pinned.contains_key(&p))
                    .flat_map(|(p, e)| e.reps.iter().map(move |r| (p, r)))
                    .filter(|(_, r)| over.iter().any(|&n| r.covers(n)))
                    .min_by_key(|&(_, r)| (r.last_use, r.seq))
                    .map(|(p, r)| (p, r.lo));
                let (vid, vlo) =
                    victim.expect("feasibility check guaranteed an evictable victim");
                let rep = self.remove_replica(vid, vlo);
                victims.push((vid, rep));
            }
        }
        // Replace the overlapped portion of older same-path replicas
        // and store the new one.
        *clock += 1;
        *seq += 1;
        let (now, sq) = (*clock, *seq);
        let mut entry = self.take_entry(id).unwrap_or_default();
        let mut out: Vec<Replica> = Vec::with_capacity(entry.reps.len() + 1);
        for r in entry.reps.drain(..) {
            if !r.overlaps(lo, hi) {
                out.push(r);
                continue;
            }
            let (olo, ohi) = (r.lo.max(lo), r.hi.min(hi));
            let b = r.blob.len();
            if b > 0 {
                for n in olo..=ohi {
                    self.sub_used(n, b);
                }
            }
            if r.lo < lo {
                out.push(Replica { lo: r.lo, hi: lo - 1, ..r.clone() });
            }
            if r.hi > hi {
                out.push(Replica { lo: hi + 1, hi: r.hi, ..r });
            }
        }
        if need > 0 {
            for n in lo..=hi {
                *self.used.entry(n).or_insert(0) += need;
            }
        }
        out.push(Replica { lo, hi, blob: data, last_use: now, seq: sq });
        out.sort_by_key(|r| r.lo);
        entry.reps = out;
        entry.refresh_coverage();
        self.put_entry(id, entry);
        TierWrite::Stored { victims }
    }

    /// Remove every replica of `id` (forced purge). Returns the
    /// removed replicas sorted by `lo`.
    fn purge_path(&mut self, id: u32) -> Vec<Replica> {
        let Some(entry) = self.take_entry(id) else {
            return Vec::new();
        };
        for r in &entry.reps {
            let b = r.blob.len();
            if b > 0 {
                for n in r.lo..=r.hi {
                    self.sub_used(n, b);
                }
            }
        }
        entry.reps
    }

    /// Remove the portions of `id`'s replicas inside `lo..=hi`,
    /// splitting stragglers (promotion consumed that range).
    fn remove_range(&mut self, lo: u32, hi: u32, id: u32) {
        let Some(mut entry) = self.take_entry(id) else {
            return;
        };
        let mut out: Vec<Replica> = Vec::with_capacity(entry.reps.len() + 1);
        for r in entry.reps.drain(..) {
            if !r.overlaps(lo, hi) {
                out.push(r);
                continue;
            }
            let (olo, ohi) = (r.lo.max(lo), r.hi.min(hi));
            let b = r.blob.len();
            if b > 0 {
                for n in olo..=ohi {
                    self.sub_used(n, b);
                }
            }
            if r.lo < lo {
                out.push(Replica { lo: r.lo, hi: lo - 1, ..r.clone() });
            }
            if r.hi > hi {
                out.push(Replica { lo: hi + 1, hi: r.hi, ..r });
            }
        }
        if !out.is_empty() {
            entry.reps = out;
            entry.refresh_coverage();
            self.put_entry(id, entry);
        }
    }

    /// Remove the single-node slice covering `node` from every replica
    /// of every path (the node's memory vanished), splitting
    /// stragglers. Pins are intentionally **not** consulted — hardware
    /// failure does not honour them. Returns the removed slices as
    /// (path id, replica restricted to `node`) in id order.
    fn drop_node(&mut self, node: u32) -> Vec<(u32, Replica)> {
        let ids: Vec<u32> = self
            .iter_entries()
            .filter(|(_, e)| e.covering_idx(node).is_some())
            .map(|(id, _)| id)
            .collect();
        let mut out = Vec::with_capacity(ids.len());
        for id in ids {
            let mut entry = self.take_entry(id).expect("id listed as resident");
            let mut kept: Vec<Replica> = Vec::with_capacity(entry.reps.len() + 1);
            for r in entry.reps.drain(..) {
                if !r.covers(node) {
                    kept.push(r);
                    continue;
                }
                let b = r.blob.len();
                if b > 0 {
                    self.sub_used(node, b);
                }
                if r.lo < node {
                    kept.push(Replica { lo: r.lo, hi: node - 1, ..r.clone() });
                }
                if r.hi > node {
                    kept.push(Replica { lo: node + 1, hi: r.hi, ..r.clone() });
                }
                out.push((id, Replica { lo: node, hi: node, ..r }));
            }
            if !kept.is_empty() {
                entry.reps = kept;
                entry.refresh_coverage();
                self.put_entry(id, entry);
            }
        }
        out
    }

    /// Usage of `n` once the same-path replica covering it (if any) is
    /// replaced by the pending write.
    fn used_after_overwrite(&self, n: u32, id: u32) -> u64 {
        let mut u = self.used.get(&n).copied().unwrap_or(0);
        if let Some(e) = self.entry(id) {
            if let Some(i) = e.covering_idx(n) {
                u -= e.reps[i].blob.len();
            }
        }
        u
    }

    /// Remove the replica of `id` starting at node `lo` (unique:
    /// replicas of one path are node-disjoint).
    fn remove_replica(&mut self, id: u32, lo: u32) -> Replica {
        let e = self.entry_mut(id).expect("victim path present");
        let idx = e.reps.iter().position(|r| r.lo == lo).expect("victim replica present");
        let r = e.reps.remove(idx);
        e.refresh_coverage();
        let now_empty = e.reps.is_empty();
        if now_empty {
            self.take_entry(id);
        }
        let b = r.blob.len();
        if b > 0 {
            for n in r.lo..=r.hi {
                self.sub_used(n, b);
            }
        }
        r
    }

    fn sub_used(&mut self, n: u32, b: u64) {
        let e = self.used.get_mut(&n).expect("usage accounting out of sync");
        *e -= b;
        if *e == 0 {
            self.used.remove(&n);
        }
    }

    fn read(&self, node: u32, id: u32) -> Option<&Blob> {
        let e = self.entry(id)?;
        e.covering_idx(node).map(|i| &e.reps[i].blob)
    }

    fn bytes_on(&self, node: u32) -> u64 {
        self.used.get(&node).copied().unwrap_or(0)
    }

    fn coverage_of(&self, id: u32) -> &[(u32, u32)] {
        self.entry(id).map(|e| e.coverage.as_slice()).unwrap_or(&[])
    }

    /// True when every node of `lo..=hi` holds `id` with content
    /// identical to `want`.
    fn resident_matches(&self, lo: u32, hi: u32, id: u32, want: &Blob) -> bool {
        let Some(e) = self.entry(id) else {
            return false;
        };
        let mut covered = 0u64;
        for r in &e.reps {
            if !r.overlaps(lo, hi) {
                continue;
            }
            if !r.blob.same_content(want) {
                return false;
            }
            covered += (r.hi.min(hi) - r.lo.max(lo) + 1) as u64;
        }
        covered == (hi - lo + 1) as u64
    }

    /// The single blob covering all of `lo..=hi` when every
    /// overlapping replica agrees on content; None otherwise.
    fn uniform_content(&self, lo: u32, hi: u32, id: u32) -> Option<Blob> {
        let e = self.entry(id)?;
        let first = e.covering_idx(lo).map(|i| e.reps[i].blob.clone())?;
        self.resident_matches(lo, hi, id, &first).then_some(first)
    }

    /// Ids of paths visible to `node`, in id order (the caller
    /// resolves and sorts by path for the deterministic surface).
    fn ids_on(&self, node: u32) -> Vec<u32> {
        // Memoized coverage + binary search: O(paths x log replicas)
        // per query, never a replica rescan.
        self.iter_entries()
            .filter(|(_, e)| e.covering_idx(node).is_some())
            .map(|(id, _)| id)
            .collect()
    }

    fn dump(&self) -> Vec<(u32, ReplicaSnapshot)> {
        self.iter_entries()
            .map(|(id, e)| (id, e.reps.iter().map(|r| (r.lo, r.hi, r.blob.len())).collect()))
            .collect()
    }

    /// Resident bytes of this tier's bookkeeping (slot table, replica
    /// lists, memoized coverage, usage map) — simulated blob payload
    /// excluded, it is what the store *models*, not what it costs.
    fn state_bytes(&self) -> u64 {
        let mut b = self.entries.capacity() as u64 * size_of::<Option<PathEntry>>() as u64;
        for e in self.entries.iter().flatten() {
            b += e.reps.capacity() as u64 * size_of::<Replica>() as u64;
            b += e.coverage.capacity() as u64 * size_of::<(u32, u32)>() as u64;
        }
        b + self.used.len() as u64 * (size_of::<(u32, u64)>() + 16) as u64
    }
}

/// The tiered node-local storage data plane: a RAM tier ("/tmp" on
/// every node) whose eviction demotes to a per-node SSD tier, backed
/// by the shared parallel filesystem. See the module docs for the full
/// semantics; the un-suffixed query surface reads the RAM tier, and
/// the `_id` surface answers the same questions for pre-interned paths
/// without touching a string.
#[derive(Debug, Default)]
pub struct NodeStores {
    /// Path ↔ dense id bijection shared by both tiers and the pin set.
    interner: PathInterner,
    ram: TierStore,
    ssd: TierStore,
    /// Paths exempt from displacement in **both** tiers, refcounted:
    /// several owners (e.g. two datasets delivering the same
    /// node-local path) may hold a pin independently and the path
    /// stays protected until every one releases it.
    pinned: Pins,
    /// LRU clock, bumped by writes and touches (shared across tiers).
    clock: u64,
    /// Insertion sequence counter (shared across tiers).
    seq: u64,
}

impl NodeStores {
    pub fn new() -> Self {
        Self::default()
    }

    fn tier(&self, tier: StorageTier) -> &TierStore {
        match tier {
            StorageTier::Ram => &self.ram,
            StorageTier::Ssd => &self.ssd,
            StorageTier::Gpfs => panic!("GPFS is backed by ParallelFs, not NodeStores"),
        }
    }

    fn tier_mut(&mut self, tier: StorageTier) -> &mut TierStore {
        match tier {
            StorageTier::Ram => &mut self.ram,
            StorageTier::Ssd => &mut self.ssd,
            StorageTier::Gpfs => panic!("GPFS is backed by ParallelFs, not NodeStores"),
        }
    }

    /// Intern `path`, returning its dense id for the `_id` fast paths.
    /// Idempotent; ids are stable for the life of the store.
    pub fn intern_path(&mut self, path: &str) -> u32 {
        self.interner.intern(path)
    }

    /// Id of `path` if it has ever been interned (written, pinned, or
    /// explicitly interned).
    pub fn path_id(&self, path: &str) -> Option<u32> {
        self.interner.get(path)
    }

    /// The path behind an id issued by [`NodeStores::intern_path`].
    pub fn resolve_path(&self, id: u32) -> &str {
        self.interner.resolve(id)
    }

    /// Number of paths ever interned (resident or not).
    pub fn interned_paths(&self) -> usize {
        self.interner.len()
    }

    /// Set or clear the uniform per-node RAM capacity. Enforced on
    /// subsequent writes; existing contents are left as they are.
    pub fn set_capacity(&mut self, cap: Option<u64>) {
        self.ram.capacity = cap;
    }

    pub fn capacity(&self) -> Option<u64> {
        self.ram.capacity
    }

    /// Set or clear the per-node SSD tier capacity. `None` disables
    /// the tier: eviction discards, exactly the single-tier store.
    pub fn set_ssd_capacity(&mut self, cap: Option<u64>) {
        self.ssd.capacity = cap;
    }

    pub fn ssd_capacity(&self) -> Option<u64> {
        self.ssd.capacity
    }

    /// Exempt `path` from displacement (both tiers) until a matching
    /// [`NodeStores::unpin`]. Refcounted: pin twice, unpin twice.
    pub fn pin(&mut self, path: impl Into<String>) {
        let path = path.into();
        let id = self.interner.intern(&path);
        *self.pinned.entry(id).or_insert(0) += 1;
    }

    /// Release one pin of `path` (no-op when not pinned).
    pub fn unpin(&mut self, path: &str) {
        let Some(id) = self.interner.get(path) else {
            return;
        };
        if let Some(n) = self.pinned.get_mut(&id) {
            *n -= 1;
            if *n == 0 {
                self.pinned.remove(&id);
            }
        }
    }

    pub fn is_pinned(&self, path: &str) -> bool {
        self.interner.get(path).is_some_and(|id| self.pinned.contains_key(&id))
    }

    /// Refresh the LRU clock of the RAM replica covering
    /// (`node`, `path`). No-op when nothing covers it (the clock still
    /// advances).
    pub fn touch(&mut self, node: u32, path: &str) {
        self.touch_tier(StorageTier::Ram, node, path);
    }

    /// [`NodeStores::touch`] against an arbitrary managed tier — an
    /// in-place SSD stream must refresh its replica's recency, or
    /// actively-read demoted data becomes the next discard victim.
    pub fn touch_tier(&mut self, tier: StorageTier, node: u32, path: &str) {
        self.clock += 1;
        let now = self.clock;
        let Some(id) = self.interner.get(path) else {
            return;
        };
        if let Some(e) = self.tier_mut(tier).entry_mut(id) {
            if let Some(i) = e.covering_idx(node) {
                e.reps[i].last_use = now;
            }
        }
    }

    /// [`NodeStores::touch`] by pre-interned id (RAM tier).
    pub fn touch_id(&mut self, node: u32, id: u32) {
        self.touch_tier_id(StorageTier::Ram, node, id);
    }

    /// [`NodeStores::touch_tier`] by pre-interned id.
    pub fn touch_tier_id(&mut self, tier: StorageTier, node: u32, id: u32) {
        self.clock += 1;
        let now = self.clock;
        if let Some(e) = self.tier_mut(tier).entry_mut(id) {
            if let Some(i) = e.covering_idx(node) {
                e.reps[i].last_use = now;
            }
        }
    }

    /// Refresh the LRU clock of *every* RAM replica of `path`
    /// overlapping `lo..=hi` (one clock bump shared by all). A
    /// range-wide hit must not leave split replicas of the reused path
    /// LRU-stale.
    pub fn touch_range(&mut self, lo: u32, hi: u32, path: &str) {
        self.clock += 1;
        let now = self.clock;
        let Some(id) = self.interner.get(path) else {
            return;
        };
        if let Some(e) = self.ram.entry_mut(id) {
            for r in e.reps.iter_mut().filter(|r| r.overlaps(lo, hi)) {
                r.last_use = now;
            }
        }
    }

    /// RAM-resident node ranges of `path`: disjoint, sorted by `lo`.
    /// A borrow of the memoized coverage — O(1), no replica scan — so
    /// the scheduler's placement inner loop can call it per task
    /// without allocation.
    pub fn coverage_of(&self, path: &str) -> &[(u32, u32)] {
        match self.interner.get(path) {
            Some(id) => self.ram.coverage_of(id),
            None => &[],
        }
    }

    /// [`NodeStores::coverage_of`] for an arbitrary managed tier.
    pub fn coverage_of_tier(&self, tier: StorageTier, path: &str) -> &[(u32, u32)] {
        match self.interner.get(path) {
            Some(id) => self.tier(tier).coverage_of(id),
            None => &[],
        }
    }

    /// [`NodeStores::coverage_of`] by pre-interned id: a direct array
    /// index, the scheduler's fleet-scale placement path.
    pub fn coverage_of_id(&self, id: u32) -> &[(u32, u32)] {
        self.ram.coverage_of(id)
    }

    /// [`NodeStores::coverage_of_tier`] by pre-interned id.
    pub fn coverage_of_tier_id(&self, tier: StorageTier, id: u32) -> &[(u32, u32)] {
        self.tier(tier).coverage_of(id)
    }

    /// Write `data` at `path` on every node in `lo..=hi`, panicking if
    /// the capacity-checked write is rejected (legacy entry point for
    /// unbounded stores; capacity-aware callers use
    /// [`NodeStores::write_range_evicting`] or route through
    /// `SimCore::node_write_range` to keep metrics and the residency
    /// mirror in sync).
    pub fn write_range(&mut self, lo: u32, hi: u32, path: impl Into<String>, data: Blob) {
        let path = path.into();
        match self.write_range_evicting(lo, hi, &path, data) {
            StoreWrite::Stored { .. } => {}
            StoreWrite::Rejected { short_bytes } => panic!(
                "node store write of {path} on {lo}..={hi} exceeds capacity by {short_bytes} B"
            ),
        }
    }

    /// Write on a single node.
    pub fn write(&mut self, node: u32, path: impl Into<String>, data: Blob) {
        self.write_range(node, node, path, data);
    }

    /// Capacity-checked RAM write of `data` at `path` on every node in
    /// `lo..=hi`. Displaces LRU unpinned replicas of *other* paths
    /// covering a still-over-budget node of the range until the write
    /// fits on every node (the overlapped portion of an older
    /// same-path replica is replaced, never counted); each victim is
    /// demoted whole into the SSD tier when it can admit it (see
    /// module docs). Rejection leaves the store byte-for-byte
    /// untouched.
    pub fn write_range_evicting(
        &mut self,
        lo: u32,
        hi: u32,
        path: &str,
        data: Blob,
    ) -> StoreWrite {
        let id = self.interner.intern(path);
        self.write_range_evicting_id(lo, hi, id, data)
    }

    /// [`NodeStores::write_range_evicting`] by pre-interned id.
    pub fn write_range_evicting_id(&mut self, lo: u32, hi: u32, id: u32, data: Blob) -> StoreWrite {
        match self.ram.write_range_evicting(
            lo,
            hi,
            id,
            data,
            &self.pinned,
            &mut self.clock,
            &mut self.seq,
        ) {
            TierWrite::Rejected { short_bytes } => StoreWrite::Rejected { short_bytes },
            TierWrite::Stored { victims } => {
                StoreWrite::Stored { evicted: self.demote_victims(victims) }
            }
        }
    }

    /// Capacity-checked **direct SSD** write of `data` at `path` on
    /// every node in `lo..=hi` — the ingest backpressure path: a frame
    /// that cannot be admitted to RAM lands on the SSD tier without
    /// displacing anything from RAM. Displacement within the SSD tier
    /// is the ordinary LRU discard (victims are *not* re-demoted —
    /// there is no tier below). Rejected when the tier is absent
    /// (`ssd_capacity() == None`) or pinned SSD residents leave no
    /// room; rejection leaves the store byte-for-byte untouched.
    pub fn write_range_ssd_evicting(
        &mut self,
        lo: u32,
        hi: u32,
        path: &str,
        data: Blob,
    ) -> StoreWrite {
        if self.ssd.capacity.is_none() {
            return StoreWrite::Rejected { short_bytes: data.len() };
        }
        let id = self.interner.intern(path);
        match self.ssd.write_range_evicting(
            lo,
            hi,
            id,
            data,
            &self.pinned,
            &mut self.clock,
            &mut self.seq,
        ) {
            TierWrite::Rejected { short_bytes } => StoreWrite::Rejected { short_bytes },
            TierWrite::Stored { victims } => StoreWrite::Stored {
                evicted: victims
                    .into_iter()
                    .map(|(vid, r)| Eviction {
                        path: self.interner.resolve(vid).to_string(),
                        lo: r.lo,
                        hi: r.hi,
                        bytes: r.blob.len(),
                        tier: StorageTier::Ssd,
                        demoted: false,
                    })
                    .collect(),
            },
        }
    }

    /// Demote RAM victims into the SSD tier (where enabled and
    /// admissible), producing the eviction records: each RAM victim
    /// followed by the SSD discards its demotion caused.
    fn demote_victims(&mut self, victims: Vec<(u32, Replica)>) -> Vec<Eviction> {
        let mut out = Vec::with_capacity(victims.len());
        for (vid, rep) in victims {
            let bytes = rep.blob.len();
            let (lo, hi) = (rep.lo, rep.hi);
            let mut cascade = Vec::new();
            let mut demoted = false;
            if self.ssd.capacity.is_some() {
                match self.ssd.write_range_evicting(
                    lo,
                    hi,
                    vid,
                    rep.blob,
                    &self.pinned,
                    &mut self.clock,
                    &mut self.seq,
                ) {
                    TierWrite::Stored { victims } => {
                        demoted = true;
                        cascade = victims;
                    }
                    TierWrite::Rejected { .. } => {}
                }
            }
            out.push(Eviction {
                path: self.interner.resolve(vid).to_string(),
                lo,
                hi,
                bytes,
                tier: StorageTier::Ram,
                demoted,
            });
            for (cid, crep) in cascade {
                out.push(Eviction {
                    path: self.interner.resolve(cid).to_string(),
                    lo: crep.lo,
                    hi: crep.hi,
                    bytes: crep.blob.len(),
                    tier: StorageTier::Ssd,
                    demoted: false,
                });
            }
        }
        out
    }

    /// Promote `path` from the SSD tier into RAM across `lo..=hi`: the
    /// cheap, node-local re-stage path. Requires full SSD coverage of
    /// the range with uniform content; RAM admission is the ordinary
    /// capacity-checked write (its victims demote as usual), and on
    /// success the promoted portion leaves the SSD tier.
    pub fn promote_range(&mut self, lo: u32, hi: u32, path: &str) -> PromoteOutcome {
        let Some(id) = self.interner.get(path) else {
            return PromoteOutcome::Missing;
        };
        self.promote_range_id(lo, hi, id)
    }

    /// [`NodeStores::promote_range`] by pre-interned id.
    pub fn promote_range_id(&mut self, lo: u32, hi: u32, id: u32) -> PromoteOutcome {
        let Some(blob) = self.ssd.uniform_content(lo, hi, id) else {
            return PromoteOutcome::Missing;
        };
        let bytes = blob.len();
        match self.write_range_evicting_id(lo, hi, id, blob) {
            StoreWrite::Rejected { short_bytes } => PromoteOutcome::Rejected { short_bytes },
            StoreWrite::Stored { evicted } => {
                self.ssd.remove_range(lo, hi, id);
                PromoteOutcome::Promoted { bytes, evicted }
            }
        }
    }

    /// Forcibly purge every replica of `path` from **both** tiers
    /// (the path is being destroyed — deleted upstream, torn down by a
    /// test — so nothing demotes). No-op when pinned.
    pub fn evict_path(&mut self, path: &str) -> Vec<Eviction> {
        let Some(id) = self.interner.get(path) else {
            return Vec::new();
        };
        if self.pinned.contains_key(&id) {
            return Vec::new();
        }
        let mut out = Vec::new();
        for (tier, store) in [
            (StorageTier::Ram, &mut self.ram),
            (StorageTier::Ssd, &mut self.ssd),
        ] {
            for r in store.purge_path(id) {
                out.push(Eviction {
                    path: path.to_string(),
                    lo: r.lo,
                    hi: r.hi,
                    bytes: r.blob.len(),
                    tier,
                    demoted: false,
                });
            }
        }
        out
    }

    /// Crash `node`: every replica slice it held — RAM and SSD, pinned
    /// or not — is destroyed (hardware failure does not honour pins,
    /// and nothing demotes: the memory is simply gone). Pin refcounts
    /// themselves survive — they belong to the dataset owners, who
    /// will re-stage and re-deliver under the same pins. Returns the
    /// losses as eviction records (`demoted == false`) so the caller
    /// can keep the residency mirror in sync.
    pub fn fail_node(&mut self, node: u32) -> Vec<Eviction> {
        let mut out = Vec::new();
        for (tier, store) in [
            (StorageTier::Ram, &mut self.ram),
            (StorageTier::Ssd, &mut self.ssd),
        ] {
            for (id, r) in store.drop_node(node) {
                out.push(Eviction {
                    path: self.interner.resolve(id).to_string(),
                    lo: r.lo,
                    hi: r.hi,
                    bytes: r.blob.len(),
                    tier,
                    demoted: false,
                });
            }
        }
        out
    }

    /// Read `path` as seen by `node` (RAM tier).
    pub fn read(&self, node: u32, path: &str) -> Option<&Blob> {
        let id = self.interner.get(path)?;
        self.ram.read(node, id)
    }

    /// Read `path` as seen by `node` in an arbitrary managed tier.
    pub fn read_tier(&self, tier: StorageTier, node: u32, path: &str) -> Option<&Blob> {
        let id = self.interner.get(path)?;
        self.tier(tier).read(node, id)
    }

    /// [`NodeStores::read`] by pre-interned id.
    pub fn read_id(&self, node: u32, id: u32) -> Option<&Blob> {
        self.ram.read(node, id)
    }

    /// [`NodeStores::read_tier`] by pre-interned id.
    pub fn read_tier_id(&self, tier: StorageTier, node: u32, id: u32) -> Option<&Blob> {
        self.tier(tier).read(node, id)
    }

    pub fn exists_on(&self, node: u32, path: &str) -> bool {
        self.read(node, path).is_some()
    }

    /// Bytes RAM-resident on one node (O(1): incrementally accounted).
    pub fn bytes_on(&self, node: u32) -> u64 {
        self.ram.bytes_on(node)
    }

    /// Bytes resident on one node in an arbitrary managed tier.
    pub fn bytes_on_tier(&self, tier: StorageTier, node: u32) -> u64 {
        self.tier(tier).bytes_on(node)
    }

    /// True when every node of `lo..=hi` holds `path` in RAM with
    /// content identical to `want` — the incremental re-stage hit test
    /// (a stale replica, updated on the shared FS since staging, fails
    /// the checksum and is restaged).
    pub fn resident_matches(&self, lo: u32, hi: u32, path: &str, want: &Blob) -> bool {
        self.interner
            .get(path)
            .is_some_and(|id| self.ram.resident_matches(lo, hi, id, want))
    }

    /// [`NodeStores::resident_matches`] against an arbitrary managed
    /// tier — the promotion planner's SSD hit test.
    pub fn resident_matches_tier(
        &self,
        tier: StorageTier,
        lo: u32,
        hi: u32,
        path: &str,
        want: &Blob,
    ) -> bool {
        self.interner
            .get(path)
            .is_some_and(|id| self.tier(tier).resident_matches(lo, hi, id, want))
    }

    /// Number of distinct paths RAM-resident anywhere.
    pub fn path_count(&self) -> usize {
        self.ram.occupied
    }

    /// Number of distinct paths resident in a managed tier.
    pub fn path_count_tier(&self, tier: StorageTier) -> usize {
        self.tier(tier).occupied
    }

    /// Paths RAM-visible to `node`, sorted (deterministic enumeration
    /// for the gather collective's local directory listing and the
    /// hook's transfer lists).
    pub fn paths_on(&self, node: u32) -> Vec<String> {
        let mut v: Vec<String> = self
            .ram
            .ids_on(node)
            .into_iter()
            .map(|id| self.interner.resolve(id).to_string())
            .collect();
        v.sort();
        v
    }

    /// Deterministic RAM snapshot: (path, [(lo, hi, per-node bytes)]),
    /// paths sorted, replicas sorted by `lo`. Test/mirror support.
    pub fn dump(&self) -> Vec<(String, ReplicaSnapshot)> {
        self.dump_tier(StorageTier::Ram)
    }

    /// [`NodeStores::dump`] for an arbitrary managed tier.
    pub fn dump_tier(&self, tier: StorageTier) -> Vec<(String, ReplicaSnapshot)> {
        let mut v: Vec<(String, ReplicaSnapshot)> = self
            .tier(tier)
            .dump()
            .into_iter()
            .map(|(id, snap)| (self.interner.resolve(id).to_string(), snap))
            .collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Resident bytes of the store's own bookkeeping: interner, both
    /// tier tables, and the pin set. Simulated blob payload is
    /// excluded — it is what the store models, not what it costs. The
    /// `scale` bench divides this by interned paths to report
    /// bytes-of-state per path.
    pub fn state_bytes(&self) -> u64 {
        self.interner.state_bytes()
            + self.ram.state_bytes()
            + self.ssd.state_bytes()
            + self.pinned.len() as u64 * (size_of::<(u32, u32)>() + 16) as u64
    }

    /// Wipe all replicas (both tiers), usage accounting, and pins
    /// (capacities, the LRU clock, and the path interner survive — ids
    /// stay stable across a clear).
    pub fn clear(&mut self) {
        for store in [&mut self.ram, &mut self.ssd] {
            store.entries.clear();
            store.occupied = 0;
            store.used.clear();
        }
        self.pinned.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::units::MB;

    #[test]
    fn node_store_replicas() {
        let mut ns = NodeStores::new();
        let blob = Blob::real(vec![9; 64]);
        ns.write_range(0, 511, "/tmp/param.txt", blob.clone());
        assert!(ns.exists_on(0, "/tmp/param.txt"));
        assert!(ns.exists_on(511, "/tmp/param.txt"));
        assert!(!ns.exists_on(512, "/tmp/param.txt"));
        assert!(ns.read(100, "/tmp/param.txt").unwrap().same_content(&blob));
        assert_eq!(ns.bytes_on(77), 64);
        assert_eq!(ns.bytes_on(1000), 0);
        assert_eq!(ns.path_count(), 1);
    }

    #[test]
    fn node_store_newest_wins() {
        let mut ns = NodeStores::new();
        ns.write_range(0, 10, "/tmp/x", Blob::real(vec![1]));
        ns.write(5, "/tmp/x", Blob::real(vec![2, 2]));
        assert_eq!(ns.read(5, "/tmp/x").unwrap().len(), 2);
        assert_eq!(ns.read(4, "/tmp/x").unwrap().len(), 1);
        // The overwrite replaced (not shadowed) the middle node.
        assert_eq!(ns.bytes_on(5), 2);
        assert_eq!(ns.bytes_on(4), 1);
    }

    #[test]
    fn capacity_evicts_lru_first() {
        let mut ns = NodeStores::new();
        ns.set_capacity(Some(100));
        ns.write_range(0, 3, "/tmp/a", Blob::real(vec![1; 40]));
        ns.write_range(0, 3, "/tmp/b", Blob::real(vec![2; 40]));
        // Refresh a: b becomes the LRU victim.
        ns.touch(1, "/tmp/a");
        let out = ns.write_range_evicting(0, 3, "/tmp/c", Blob::real(vec![3; 40]));
        match out {
            StoreWrite::Stored { evicted } => {
                assert_eq!(evicted.len(), 1);
                assert_eq!(evicted[0].path, "/tmp/b");
                assert_eq!(evicted[0].bytes, 40);
                assert_eq!((evicted[0].lo, evicted[0].hi), (0, 3));
                // No SSD tier: the displacement is a discard.
                assert!(!evicted[0].demoted);
                assert_eq!(evicted[0].tier, StorageTier::Ram);
            }
            other => panic!("expected Stored, got {other:?}"),
        }
        assert!(ns.exists_on(2, "/tmp/a"));
        assert!(!ns.exists_on(2, "/tmp/b"));
        assert!(ns.exists_on(2, "/tmp/c"));
        assert_eq!(ns.bytes_on(2), 80);
    }

    #[test]
    fn pinned_replicas_survive_pressure() {
        let mut ns = NodeStores::new();
        ns.set_capacity(Some(100));
        ns.write_range(0, 1, "/tmp/keep", Blob::real(vec![1; 60]));
        ns.pin("/tmp/keep");
        ns.write_range(0, 1, "/tmp/x", Blob::real(vec![2; 30]));
        // 60 pinned + 30 + 30 > 100: x is evicted, keep survives.
        let out = ns.write_range_evicting(0, 1, "/tmp/y", Blob::real(vec![3; 30]));
        assert!(matches!(out, StoreWrite::Stored { ref evicted } if evicted.len() == 1
            && evicted[0].path == "/tmp/x"));
        assert!(ns.exists_on(0, "/tmp/keep"));
        // A write that cannot fit beside the pinned resident is
        // rejected with the store untouched.
        let before = ns.dump();
        let out = ns.write_range_evicting(0, 1, "/tmp/z", Blob::real(vec![4; 50]));
        assert!(matches!(out, StoreWrite::Rejected { short_bytes: 10 }));
        assert_eq!(ns.dump(), before);
        // Unpinning makes the same write admissible again.
        ns.unpin("/tmp/keep");
        assert!(matches!(
            ns.write_range_evicting(0, 1, "/tmp/z", Blob::real(vec![4; 50])),
            StoreWrite::Stored { .. }
        ));
        assert!(ns.bytes_on(0) <= 100 && ns.bytes_on(1) <= 100);
    }

    #[test]
    fn oversized_blob_rejected_outright() {
        let mut ns = NodeStores::new();
        ns.set_capacity(Some(10));
        let out = ns.write_range_evicting(0, 0, "/tmp/big", Blob::real(vec![0; 25]));
        assert!(matches!(out, StoreWrite::Rejected { short_bytes: 15 }));
        assert_eq!(ns.path_count(), 0);
    }

    #[test]
    fn eviction_scoped_to_overlapping_ranges() {
        let mut ns = NodeStores::new();
        ns.set_capacity(Some(100));
        ns.write_range(0, 1, "/tmp/left", Blob::real(vec![1; 80]));
        ns.write_range(4, 5, "/tmp/right", Blob::real(vec![2; 80]));
        // Pressure on nodes 4-5 must not evict the disjoint left range.
        let out = ns.write_range_evicting(4, 5, "/tmp/new", Blob::real(vec![3; 60]));
        assert!(matches!(out, StoreWrite::Stored { ref evicted } if evicted.len() == 1
            && evicted[0].path == "/tmp/right"));
        assert!(ns.exists_on(0, "/tmp/left"));
        assert!(!ns.exists_on(4, "/tmp/right"));
    }

    #[test]
    fn touch_range_refreshes_split_replicas() {
        let mut ns = NodeStores::new();
        ns.set_capacity(Some(100));
        // Split /tmp/hot into three replicas via a same-content patch.
        ns.write_range(0, 5, "/tmp/hot", Blob::real(vec![1; 30]));
        ns.write_range(2, 3, "/tmp/hot", Blob::real(vec![1; 30]));
        ns.write_range(0, 5, "/tmp/cold", Blob::real(vec![2; 30]));
        assert_eq!(ns.coverage_of("/tmp/hot"), vec![(0, 1), (2, 3), (4, 5)]);
        assert!(ns.coverage_of("/tmp/none").is_empty());
        // A range-wide hit refreshes ALL hot replicas (not just the
        // one covering the probe node); cold is then the LRU victim.
        ns.touch_range(0, 5, "/tmp/hot");
        let out = ns.write_range_evicting(0, 5, "/tmp/new", Blob::real(vec![3; 60]));
        match out {
            StoreWrite::Stored { evicted } => {
                assert!(!evicted.is_empty());
                assert!(
                    evicted.iter().all(|e| e.path == "/tmp/cold"),
                    "hot replicas evicted despite the range-wide hit: {evicted:?}"
                );
            }
            other => panic!("expected Stored, got {other:?}"),
        }
        for n in 0..6u32 {
            assert!(ns.exists_on(n, "/tmp/hot"));
        }
    }

    #[test]
    fn victims_must_cover_an_over_budget_node() {
        // /tmp/old (LRU-oldest) lives only on node 0, which still fits
        // the incoming write; /tmp/busy fills node 5. The eviction must
        // take /tmp/busy (covering the over-budget node), not destroy
        // /tmp/old needlessly.
        let mut ns = NodeStores::new();
        ns.set_capacity(Some(100));
        ns.write_range(0, 0, "/tmp/old", Blob::real(vec![1; 40]));
        ns.write_range(5, 5, "/tmp/busy", Blob::real(vec![2; 80]));
        let out = ns.write_range_evicting(0, 5, "/tmp/new", Blob::real(vec![3; 60]));
        match out {
            StoreWrite::Stored { evicted } => {
                assert_eq!(evicted.len(), 1);
                assert_eq!(evicted[0].path, "/tmp/busy");
            }
            other => panic!("expected Stored, got {other:?}"),
        }
        assert!(ns.exists_on(0, "/tmp/old"), "node-0 replica destroyed needlessly");
        assert!(ns.exists_on(3, "/tmp/new"));
        assert_eq!(ns.bytes_on(0), 100);
        assert_eq!(ns.bytes_on(5), 60);
    }

    #[test]
    fn overwrite_splits_replicas_and_keeps_accounting() {
        let mut ns = NodeStores::new();
        ns.write_range(0, 9, "/tmp/x", Blob::real(vec![1; 10]));
        ns.write_range(3, 6, "/tmp/x", Blob::real(vec![2; 20]));
        assert_eq!(ns.dump(), vec![(
            "/tmp/x".to_string(),
            vec![(0, 2, 10), (3, 6, 20), (7, 9, 10)],
        )]);
        for n in 0..10u32 {
            let want = if (3..=6).contains(&n) { 20 } else { 10 };
            assert_eq!(ns.bytes_on(n), want, "node {n}");
        }
        assert_eq!(ns.bytes_on(10), 0);
    }

    #[test]
    fn paths_on_is_sorted_and_deterministic() {
        let build = || {
            let mut ns = NodeStores::new();
            for name in ["/tmp/z.bin", "/tmp/a.bin", "/tmp/m.bin", "/tmp/k.bin"] {
                ns.write_range(0, 7, name, Blob::real(vec![0; 4]));
            }
            ns.write_range(2, 3, "/tmp/partial.bin", Blob::real(vec![0; 4]));
            ns
        };
        let a = build();
        let b = build();
        let paths = a.paths_on(2);
        let mut sorted = paths.clone();
        sorted.sort();
        assert_eq!(paths, sorted, "paths_on must return sorted order");
        assert_eq!(paths.len(), 5);
        assert_eq!(a.paths_on(5).len(), 4);
        // Identical construction -> identical enumeration (no
        // HashMap iteration-order dependence).
        assert_eq!(a.paths_on(2), b.paths_on(2));
        assert_eq!(a.dump(), b.dump());
    }

    #[test]
    fn resident_matches_checks_coverage_and_content() {
        let mut ns = NodeStores::new();
        let blob = Blob::synthetic(1000, 7);
        ns.write_range(0, 3, "/tmp/d", blob.clone());
        assert!(ns.resident_matches(0, 3, "/tmp/d", &blob));
        assert!(ns.resident_matches(1, 2, "/tmp/d", &blob));
        // Partial coverage fails.
        assert!(!ns.resident_matches(0, 4, "/tmp/d", &blob));
        // Stale content fails.
        assert!(!ns.resident_matches(0, 3, "/tmp/d", &Blob::synthetic(1000, 8)));
        // A same-content patch over a sub-range still matches.
        ns.write_range(1, 2, "/tmp/d", blob.clone());
        assert!(ns.resident_matches(0, 3, "/tmp/d", &blob));
    }

    #[test]
    fn pins_are_refcounted_across_owners() {
        let mut ns = NodeStores::new();
        ns.write_range(0, 1, "/tmp/shared", Blob::real(vec![1; 8]));
        ns.pin("/tmp/shared"); // owner X
        ns.pin("/tmp/shared"); // owner Y
        ns.unpin("/tmp/shared"); // Y releases; X still holds it
        assert!(ns.is_pinned("/tmp/shared"));
        assert!(ns.evict_path("/tmp/shared").is_empty());
        ns.unpin("/tmp/shared");
        assert!(!ns.is_pinned("/tmp/shared"));
        // Unbalanced extra unpins are harmless no-ops.
        ns.unpin("/tmp/shared");
        assert_eq!(ns.evict_path("/tmp/shared").len(), 1);
    }

    #[test]
    fn forced_evict_path_respects_pins() {
        let mut ns = NodeStores::new();
        ns.write_range(0, 3, "/tmp/a", Blob::real(vec![1; 8]));
        ns.pin("/tmp/a");
        assert!(ns.evict_path("/tmp/a").is_empty());
        assert!(ns.exists_on(0, "/tmp/a"));
        ns.unpin("/tmp/a");
        let ev = ns.evict_path("/tmp/a");
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].bytes, 8);
        assert!(!ns.exists_on(0, "/tmp/a"));
        assert_eq!(ns.bytes_on(0), 0);
    }

    // ------------------------------------------------------------------
    // tiered semantics
    // ------------------------------------------------------------------

    #[test]
    fn eviction_demotes_to_ssd_preserving_content() {
        let mut ns = NodeStores::new();
        ns.set_capacity(Some(100));
        ns.set_ssd_capacity(Some(200));
        let a = Blob::synthetic(60, 11);
        ns.write_range(0, 3, "/tmp/a", a.clone());
        let out = ns.write_range_evicting(0, 3, "/tmp/b", Blob::synthetic(60, 12));
        match out {
            StoreWrite::Stored { evicted } => {
                assert_eq!(evicted.len(), 1);
                assert!(evicted[0].demoted, "SSD tier enabled: eviction must demote");
                assert_eq!(evicted[0].tier, StorageTier::Ram);
            }
            other => panic!("expected Stored, got {other:?}"),
        }
        // The replica left RAM but survives bit-identical on SSD.
        assert!(!ns.exists_on(1, "/tmp/a"));
        assert!(ns.read_tier(StorageTier::Ssd, 1, "/tmp/a").unwrap().same_content(&a));
        assert_eq!(ns.bytes_on_tier(StorageTier::Ssd, 2), 60);
        assert_eq!(ns.bytes_on(2), 60);
    }

    #[test]
    fn ssd_overflow_cascades_to_discard() {
        let mut ns = NodeStores::new();
        ns.set_capacity(Some(100));
        ns.set_ssd_capacity(Some(100));
        // Three 60 B datasets through a 100 B RAM + 100 B SSD stack:
        // staging c demotes a to SSD; staging d demotes b, which
        // discards a from SSD to make room (cascade).
        ns.write_range(0, 1, "/tmp/a", Blob::synthetic(60, 1));
        ns.write_range(0, 1, "/tmp/b", Blob::synthetic(60, 2));
        let out = ns.write_range_evicting(0, 1, "/tmp/c", Blob::synthetic(60, 3));
        match out {
            StoreWrite::Stored { evicted } => {
                // b was written second (a demoted already when b
                // landed): the victim here is b, whose demotion
                // discards a from the SSD.
                assert_eq!(evicted.len(), 2, "{evicted:?}");
                assert_eq!(evicted[0].tier, StorageTier::Ram);
                assert!(evicted[0].demoted);
                assert_eq!(evicted[1].tier, StorageTier::Ssd);
                assert!(!evicted[1].demoted);
            }
            other => panic!("expected Stored, got {other:?}"),
        }
        // Per-tier budgets held throughout.
        for n in 0..2 {
            assert!(ns.bytes_on(n) <= 100);
            assert!(ns.bytes_on_tier(StorageTier::Ssd, n) <= 100);
        }
    }

    #[test]
    fn promote_restores_ram_residency() {
        let mut ns = NodeStores::new();
        ns.set_capacity(Some(100));
        ns.set_ssd_capacity(Some(200));
        let a = Blob::synthetic(60, 5);
        ns.write_range(0, 3, "/tmp/a", a.clone());
        ns.write_range(0, 3, "/tmp/b", Blob::synthetic(60, 6)); // a -> SSD
        assert!(!ns.exists_on(0, "/tmp/a"));
        match ns.promote_range(0, 3, "/tmp/a") {
            PromoteOutcome::Promoted { bytes, evicted } => {
                assert_eq!(bytes, 60);
                // b displaced in turn — and demoted, not lost.
                assert!(evicted.iter().any(|e| e.path == "/tmp/b" && e.demoted));
            }
            other => panic!("expected promotion, got {other:?}"),
        }
        assert!(ns.read(2, "/tmp/a").unwrap().same_content(&a));
        // The promoted copy left the SSD tier.
        assert!(ns.read_tier(StorageTier::Ssd, 2, "/tmp/a").is_none());
        assert!(ns.read_tier(StorageTier::Ssd, 2, "/tmp/b").is_some());
    }

    #[test]
    fn promote_missing_and_rejected() {
        let mut ns = NodeStores::new();
        ns.set_capacity(Some(100));
        ns.set_ssd_capacity(Some(200));
        assert!(matches!(ns.promote_range(0, 1, "/tmp/none"), PromoteOutcome::Missing));
        // Partial SSD coverage does not promote.
        ns.write_range(0, 0, "/tmp/p", Blob::synthetic(40, 1));
        ns.write_range(0, 0, "/tmp/q", Blob::synthetic(80, 2)); // p -> SSD on node 0 only
        assert!(matches!(ns.promote_range(0, 1, "/tmp/p"), PromoteOutcome::Missing));
        // A pinned wall in RAM rejects promotion, leaving SSD intact.
        ns.pin("/tmp/q");
        assert!(matches!(
            ns.promote_range(0, 0, "/tmp/p"),
            PromoteOutcome::Rejected { short_bytes: 20 }
        ));
        assert!(ns.read_tier(StorageTier::Ssd, 0, "/tmp/p").is_some());
    }

    #[test]
    fn pins_never_demote_because_they_never_evict() {
        let mut ns = NodeStores::new();
        ns.set_capacity(Some(100));
        ns.set_ssd_capacity(Some(100));
        ns.write_range(0, 1, "/tmp/pinned", Blob::synthetic(50, 1));
        ns.pin("/tmp/pinned");
        ns.write_range(0, 1, "/tmp/x", Blob::synthetic(40, 2));
        let out = ns.write_range_evicting(0, 1, "/tmp/y", Blob::synthetic(40, 3));
        match out {
            StoreWrite::Stored { evicted } => {
                assert!(evicted.iter().all(|e| e.path != "/tmp/pinned"));
            }
            other => panic!("expected Stored, got {other:?}"),
        }
        assert!(ns.exists_on(0, "/tmp/pinned"));
        assert!(ns.read_tier(StorageTier::Ssd, 0, "/tmp/pinned").is_none());
    }

    #[test]
    fn pinned_ssd_replicas_survive_demotion_pressure() {
        let mut ns = NodeStores::new();
        ns.set_capacity(Some(100));
        ns.set_ssd_capacity(Some(100));
        // a demotes to SSD, then gets pinned there (a promotion plan
        // in flight). Later demotions must not discard it.
        ns.write_range(0, 1, "/tmp/a", Blob::synthetic(70, 1));
        ns.write_range(0, 1, "/tmp/b", Blob::synthetic(70, 2)); // a -> SSD
        ns.pin("/tmp/a");
        let out = ns.write_range_evicting(0, 1, "/tmp/c", Blob::synthetic(70, 3));
        match out {
            StoreWrite::Stored { evicted } => {
                // b displaced from RAM, but a's pinned SSD copy blocks
                // its demotion (70 pinned + 70 > 100): b is discarded.
                let b = evicted.iter().find(|e| e.path == "/tmp/b").unwrap();
                assert!(!b.demoted, "SSD pin must block the demotion");
            }
            other => panic!("expected Stored, got {other:?}"),
        }
        assert!(ns.read_tier(StorageTier::Ssd, 0, "/tmp/a").is_some());
    }

    #[test]
    fn direct_ssd_writes_bypass_ram() {
        let mut ns = NodeStores::new();
        ns.set_capacity(Some(100));
        ns.set_ssd_capacity(Some(100));
        ns.write_range(0, 1, "/tmp/ram", Blob::synthetic(90, 1));
        let a = Blob::synthetic(60, 2);
        // Lands on SSD without touching the RAM resident.
        let out = ns.write_range_ssd_evicting(0, 1, "/tmp/frame0", a.clone());
        assert!(matches!(out, StoreWrite::Stored { ref evicted } if evicted.is_empty()));
        assert!(ns.read_tier(StorageTier::Ssd, 0, "/tmp/frame0").unwrap().same_content(&a));
        assert!(ns.read(0, "/tmp/frame0").is_none());
        assert!(ns.exists_on(0, "/tmp/ram"));
        // SSD pressure displaces the LRU SSD resident, never RAM.
        let out = ns.write_range_ssd_evicting(0, 1, "/tmp/frame1", Blob::synthetic(60, 3));
        match out {
            StoreWrite::Stored { evicted } => {
                assert_eq!(evicted.len(), 1);
                assert_eq!(evicted[0].path, "/tmp/frame0");
                assert_eq!(evicted[0].tier, StorageTier::Ssd);
                assert!(!evicted[0].demoted);
            }
            other => panic!("expected Stored, got {other:?}"),
        }
        assert!(ns.exists_on(1, "/tmp/ram"));
        // Pinned SSD residents reject the write, store untouched.
        ns.pin("/tmp/frame1");
        let before = ns.dump_tier(StorageTier::Ssd);
        let out = ns.write_range_ssd_evicting(0, 1, "/tmp/frame2", Blob::synthetic(60, 4));
        assert!(matches!(out, StoreWrite::Rejected { short_bytes: 20 }));
        assert_eq!(ns.dump_tier(StorageTier::Ssd), before);
        // Tier absent: rejected outright.
        let mut no_ssd = NodeStores::new();
        let out = no_ssd.write_range_ssd_evicting(0, 0, "/tmp/f", Blob::synthetic(8, 1));
        assert!(matches!(out, StoreWrite::Rejected { short_bytes: 8 }));
    }

    #[test]
    fn forced_evict_purges_both_tiers() {
        let mut ns = NodeStores::new();
        ns.set_capacity(Some(100));
        ns.set_ssd_capacity(Some(200));
        ns.write_range(0, 1, "/tmp/a", Blob::synthetic(60, 1));
        ns.write_range(0, 1, "/tmp/b", Blob::synthetic(60, 2)); // a -> SSD
        ns.write_range(2, 3, "/tmp/a", Blob::synthetic(60, 1)); // a also in RAM elsewhere
        let ev = ns.evict_path("/tmp/a");
        assert_eq!(ev.len(), 2, "{ev:?}");
        assert!(ev.iter().any(|e| e.tier == StorageTier::Ram));
        assert!(ev.iter().any(|e| e.tier == StorageTier::Ssd));
        assert!(ev.iter().all(|e| !e.demoted));
        assert_eq!(ns.path_count_tier(StorageTier::Ssd), 0);
        assert!(!ns.exists_on(3, "/tmp/a"));
    }

    #[test]
    fn fail_node_drops_both_tiers_ignoring_pins() {
        let mut ns = NodeStores::new();
        ns.set_capacity(Some(100));
        ns.set_ssd_capacity(Some(200));
        ns.write_range(0, 3, "/tmp/a", Blob::synthetic(60, 1));
        ns.write_range(0, 3, "/tmp/b", Blob::synthetic(60, 2)); // a -> SSD
        ns.pin("/tmp/a");
        ns.pin("/tmp/b");
        let ev = ns.fail_node(2);
        // One RAM slice (b) and one SSD slice (a), node 2 only.
        assert_eq!(ev.len(), 2, "{ev:?}");
        assert!(ev.iter().all(|e| e.lo == 2 && e.hi == 2 && !e.demoted));
        assert!(ev.iter().any(|e| e.path == "/tmp/b" && e.tier == StorageTier::Ram));
        assert!(ev.iter().any(|e| e.path == "/tmp/a" && e.tier == StorageTier::Ssd));
        // Survivors keep their slices; the dead node lost both tiers.
        assert!(ns.exists_on(1, "/tmp/b"));
        assert!(!ns.exists_on(2, "/tmp/b"));
        assert!(ns.read_tier(StorageTier::Ssd, 2, "/tmp/a").is_none());
        assert!(ns.read_tier(StorageTier::Ssd, 3, "/tmp/a").is_some());
        assert_eq!(ns.bytes_on(2), 0);
        assert_eq!(ns.bytes_on_tier(StorageTier::Ssd, 2), 0);
        assert_eq!(ns.coverage_of("/tmp/b"), vec![(0, 1), (3, 3)]);
        // Pins survive the crash: the owners still hold them.
        assert!(ns.is_pinned("/tmp/a"));
        // A node holding nothing reports no losses.
        assert!(ns.fail_node(7).is_empty());
    }

    #[test]
    fn coverage_is_memoized_not_rescanned() {
        let mut ns = NodeStores::new();
        ns.write_range(0, 3, "/tmp/a", Blob::synthetic(MB, 1));
        ns.write_range(6, 9, "/tmp/a", Blob::synthetic(MB, 1));
        let first = ns.coverage_of("/tmp/a");
        assert_eq!(first, vec![(0, 3), (6, 9)]);
        // Same borrow on every call — a slice of memoized state, not a
        // fresh allocation per query (the scheduler hot-path property;
        // also asserted in benches/hotpath.rs).
        assert_eq!(ns.coverage_of("/tmp/a").as_ptr(), ns.coverage_of("/tmp/a").as_ptr());
        // Mutation refreshes it.
        ns.write_range(4, 5, "/tmp/a", Blob::synthetic(MB, 1));
        assert_eq!(ns.coverage_of("/tmp/a"), vec![(0, 3), (4, 5), (6, 9)]);
    }

    // ------------------------------------------------------------------
    // interned-id surface
    // ------------------------------------------------------------------

    #[test]
    fn id_surface_answers_identically_to_strings() {
        let mut ns = NodeStores::new();
        ns.set_capacity(Some(200));
        ns.set_ssd_capacity(Some(200));
        ns.write_range(0, 7, "/tmp/a", Blob::synthetic(60, 1));
        ns.write_range(2, 5, "/tmp/b", Blob::synthetic(60, 2));
        let a = ns.path_id("/tmp/a").unwrap();
        let b = ns.path_id("/tmp/b").unwrap();
        assert_eq!(ns.resolve_path(a), "/tmp/a");
        assert_eq!(ns.coverage_of_id(a), ns.coverage_of("/tmp/a"));
        assert_eq!(ns.coverage_of_id(b), ns.coverage_of("/tmp/b"));
        for n in 0..9u32 {
            assert_eq!(ns.read_id(n, a).is_some(), ns.exists_on(n, "/tmp/a"));
            assert_eq!(
                ns.read_tier_id(StorageTier::Ram, n, b).map(Blob::len),
                ns.read_tier(StorageTier::Ram, n, "/tmp/b").map(Blob::len)
            );
        }
        // An interned-but-never-written path answers empty, like an
        // unknown string.
        let ghost = ns.intern_path("/tmp/ghost");
        assert!(ns.coverage_of_id(ghost).is_empty());
        assert!(ns.read_id(0, ghost).is_none());
    }

    #[test]
    fn id_writes_and_touches_match_string_behavior() {
        let via_str = {
            let mut ns = NodeStores::new();
            ns.set_capacity(Some(100));
            ns.write_range(0, 3, "/tmp/a", Blob::synthetic(40, 1));
            ns.write_range(0, 3, "/tmp/b", Blob::synthetic(40, 2));
            ns.touch(1, "/tmp/a");
            ns.write_range_evicting(0, 3, "/tmp/c", Blob::synthetic(40, 3));
            ns.dump()
        };
        let via_id = {
            let mut ns = NodeStores::new();
            ns.set_capacity(Some(100));
            let a = ns.intern_path("/tmp/a");
            let b = ns.intern_path("/tmp/b");
            let c = ns.intern_path("/tmp/c");
            ns.write_range_evicting_id(0, 3, a, Blob::synthetic(40, 1));
            ns.write_range_evicting_id(0, 3, b, Blob::synthetic(40, 2));
            ns.touch_id(1, a);
            ns.write_range_evicting_id(0, 3, c, Blob::synthetic(40, 3));
            ns.dump()
        };
        assert_eq!(via_str, via_id);
    }

    #[test]
    fn clear_keeps_ids_stable() {
        let mut ns = NodeStores::new();
        ns.write_range(0, 1, "/tmp/a", Blob::synthetic(8, 1));
        let a = ns.path_id("/tmp/a").unwrap();
        ns.clear();
        assert_eq!(ns.path_count(), 0);
        assert_eq!(ns.path_id("/tmp/a"), Some(a), "interner must survive clear");
        ns.write_range(0, 1, "/tmp/a", Blob::synthetic(8, 1));
        assert_eq!(ns.path_id("/tmp/a"), Some(a));
        assert!(ns.exists_on(0, "/tmp/a"));
    }

    #[test]
    fn state_bytes_tracks_bookkeeping_not_payload() {
        let mut ns = NodeStores::new();
        let empty = ns.state_bytes();
        // A large simulated blob must not dominate state_bytes: the
        // payload is modelled, not held per node.
        ns.write_range(0, 4095, "/tmp/big", Blob::synthetic(512 * MB, 1));
        let one = ns.state_bytes();
        assert!(one > empty);
        assert!(one < 512 * MB, "payload leaked into state accounting: {one}");
        for i in 0..64 {
            ns.write_range(0, 63, format!("/tmp/f{i:02}"), Blob::synthetic(1024, 2));
        }
        assert!(ns.state_bytes() > one);
    }
}
