//! The multi-tier node-local storage subsystem.
//!
//! Extracted from `cluster.rs` when the single RAM-disk staging tier
//! grew an SSD demotion tier underneath it. Four layers:
//!
//! - [`tier`] — [`StorageTier`]: the levels of the staging hierarchy
//!   (node RAM, node SSD, the shared GPFS backing store) and the
//!   per-node [`TierBudgets`] a machine grants them.
//! - [`intern`] — [`PathInterner`]: dense path ↔ `u32` id interning.
//!   Both the data plane and the mirror key their per-path state on
//!   dense ids so fleet-scale hot paths (coverage queries, cache-hit
//!   tests, residency probes) are array indexes, not string-keyed
//!   BTree walks.
//! - [`node_stores`] — [`NodeStores`]: the data plane. A
//!   capacity-managed RAM tier whose LRU eviction **demotes** whole
//!   replicas to the per-node SSD tier (when the machine models one)
//!   instead of destroying them, plus the [`NodeStores::promote_range`]
//!   path that moves them back at local-device cost. Pinning, LRU
//!   upkeep, deterministic enumeration, and memoized coverage for the
//!   scheduler's placement loop all live here.
//! - [`residency_table`] — [`ResidencyTable`]: the per-tier
//!   bookkeeping mirror `engine::SimCore` keeps exactly in sync with
//!   every engine-applied write, demotion, promotion, and eviction,
//!   plus displacement telemetry ([`Eviction`]).
//!
//! The *timing* of tier traffic is not modelled here: demotions and
//! promotions are timed flows over the machine's SSD link class
//! (`cluster::Topology::path_ssd`), scheduled by the engine
//! (`SimCore::node_write_range` / `Effect::NodePromote`).
//!
//! `cluster` re-exports this module's surface, so pre-extraction
//! imports (`crate::cluster::NodeStores`, ...) keep compiling.

pub mod intern;
pub mod node_stores;
pub mod residency_table;
pub mod tier;

pub use intern::PathInterner;
pub use node_stores::{NodeStores, PromoteOutcome, ReplicaSnapshot, StoreWrite};
pub use residency_table::{Eviction, ResidencyTable};
pub use tier::{StorageTier, TierBudgets};
