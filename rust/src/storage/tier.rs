//! Storage tiers: the levels of the staging hierarchy a replica can
//! occupy.
//!
//! The paper's machine has exactly one staging tier — the node-local
//! RAM disk ("/tmp") — backed by the shared GPFS. Modern deployments
//! interpose a node-local flash/burst-buffer tier between the two
//! (cf. the Perlmutter direct-streaming work in PAPERS.md), which
//! turns eviction from *destruction* into *demotion*: a replica
//! displaced from RAM survives on the node's SSD and can later be
//! promoted back at local-device bandwidth instead of being re-staged
//! through the contended parallel filesystem.
//!
//! [`StorageTier`] names the tiers; [`crate::storage::NodeStores`]
//! manages the two node-local ones (RAM + SSD) while
//! [`crate::pfs::ParallelFs`] *is* the GPFS backing tier — it holds
//! the originals and is never capacity-managed here.

/// One level of the staging hierarchy.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub enum StorageTier {
    /// Node-local RAM disk: the paper's "/tmp". Fastest reads
    /// (per-process stream at `ramdisk_proc_read_bw`); the only tier
    /// analysis tasks read from.
    Ram,
    /// Node-local SSD / burst buffer: the demotion target. Larger and
    /// slower than RAM; replicas here are promoted back before use.
    Ssd,
    /// The shared parallel filesystem: the backing tier holding every
    /// original. Not managed by `NodeStores` — re-staging from here is
    /// the expensive path the tiers above exist to avoid.
    Gpfs,
}

impl StorageTier {
    /// Short lower-case name for metrics keys and reports.
    pub fn name(self) -> &'static str {
        match self {
            StorageTier::Ram => "ram",
            StorageTier::Ssd => "ssd",
            StorageTier::Gpfs => "gpfs",
        }
    }
}

/// Per-node byte budgets of the two managed tiers. `ram: None` means
/// the RAM tier is unbounded; `ssd: None` means the SSD tier is
/// **absent** (a diskless machine — zero capacity, not infinite).
/// Produced by [`crate::cluster::MachineSpec`] accessors and applied
/// by [`crate::cluster::Topology::apply_storage_budgets`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TierBudgets {
    pub ram: Option<u64>,
    pub ssd: Option<u64>,
}

impl TierBudgets {
    /// Total node-local staging bytes across both managed tiers: RAM
    /// plus the SSD budget (an absent SSD tier contributes zero).
    /// None only when the RAM tier is unbounded.
    pub fn total(&self) -> Option<u64> {
        self.ram.map(|r| r + self.ssd.unwrap_or(0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_names_are_stable_metric_keys() {
        assert_eq!(StorageTier::Ram.name(), "ram");
        assert_eq!(StorageTier::Ssd.name(), "ssd");
        assert_eq!(StorageTier::Gpfs.name(), "gpfs");
    }

    #[test]
    fn budgets_total() {
        assert_eq!(TierBudgets { ram: Some(10), ssd: Some(32) }.total(), Some(42));
        // An absent SSD tier is zero capacity, not unbounded: the
        // diskless machine's total is its RAM budget.
        assert_eq!(TierBudgets { ram: Some(10), ssd: None }.total(), Some(10));
        // An unbounded RAM tier makes the total unbounded.
        assert_eq!(TierBudgets { ram: None, ssd: Some(32) }.total(), None);
        assert_eq!(TierBudgets::default().total(), None);
    }
}
