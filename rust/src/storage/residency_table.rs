//! The residency mirror: which paths are resident on which node
//! ranges, per storage tier, plus displacement telemetry.
//!
//! `engine::SimCore` owns one [`ResidencyTable`] and keeps it exactly
//! in sync with every engine-applied node write
//! (`SimCore::node_write_range`), promotion (`SimCore::promote_range`)
//! and eviction (`SimCore::evict_path`), so experiments can report hit
//! rates, demoted bytes, and evicted bytes without rescanning the data
//! plane.

use std::collections::BTreeMap;

use super::node_stores::NodeStores;
use super::tier::StorageTier;

/// A replica displaced from a tier — to make room for a write, by a
/// forced [`NodeStores::evict_path`], or as demotion cascade fallout.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Eviction {
    pub path: String,
    pub lo: u32,
    pub hi: u32,
    /// Per-node bytes the displacement freed in `tier`.
    pub bytes: u64,
    /// Tier the replica was displaced from.
    pub tier: StorageTier,
    /// True when the replica survived: it was demoted whole into the
    /// SSD tier rather than destroyed. Only `tier == Ram` evictions
    /// can demote; an SSD displacement is always a discard (the GPFS
    /// original remains the backing copy).
    pub demoted: bool,
}

impl Eviction {
    /// Bytes across the whole node span (per-node bytes x span).
    pub fn span_bytes(&self) -> u64 {
        self.bytes * (self.hi - self.lo + 1) as u64
    }
}

type RangeMap = BTreeMap<String, Vec<(u32, u32)>>;

/// Bookkeeping mirror of [`NodeStores`]: path -> disjoint, sorted,
/// coalesced node ranges, kept **per tier**, plus displacement
/// telemetry. The legacy (un-suffixed) query surface reads the RAM
/// tier — the tier analysis tasks consume.
#[derive(Clone, Debug, Default)]
pub struct ResidencyTable {
    /// RAM tier: path -> resident node ranges.
    ram: RangeMap,
    /// SSD tier: path -> resident node ranges.
    ssd: RangeMap,
    /// Replicas displaced from RAM under capacity pressure or by
    /// forced eviction (count; includes demotions).
    pub evictions: u64,
    /// Total bytes displaced from RAM (per-node bytes x node span).
    pub evicted_bytes: u64,
    /// RAM displacements that survived as SSD demotions (count).
    pub demotions: u64,
    /// Total bytes demoted RAM -> SSD (per-node bytes x node span).
    pub demoted_bytes: u64,
    /// Replicas discarded from the SSD tier (count).
    pub ssd_evictions: u64,
    /// Total bytes discarded from SSD (per-node bytes x node span).
    pub ssd_evicted_bytes: u64,
    /// Replicas promoted SSD -> RAM (count).
    pub promotions: u64,
    /// Total bytes promoted SSD -> RAM (per-node bytes x node span).
    pub promoted_bytes: u64,
}

impl ResidencyTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a stored RAM write of `path` on `lo..=hi` that displaced
    /// `evicted` first.
    pub fn on_stored(&mut self, lo: u32, hi: u32, path: &str, evicted: &[Eviction]) {
        self.on_evicted(evicted);
        add_range(self.ram.entry(path.to_string()).or_default(), lo, hi);
    }

    /// Record displacements (capacity pressure, demotion cascade, or
    /// forced eviction), tier by tier.
    pub fn on_evicted(&mut self, evicted: &[Eviction]) {
        for ev in evicted {
            match ev.tier {
                StorageTier::Ram => {
                    self.evictions += 1;
                    self.evicted_bytes += ev.span_bytes();
                    remove_from(&mut self.ram, &ev.path, ev.lo, ev.hi);
                    if ev.demoted {
                        self.demotions += 1;
                        self.demoted_bytes += ev.span_bytes();
                        add_range(self.ssd.entry(ev.path.clone()).or_default(), ev.lo, ev.hi);
                    }
                }
                StorageTier::Ssd => {
                    self.ssd_evictions += 1;
                    self.ssd_evicted_bytes += ev.span_bytes();
                    remove_from(&mut self.ssd, &ev.path, ev.lo, ev.hi);
                }
                StorageTier::Gpfs => unreachable!("GPFS is not capacity-managed"),
            }
        }
    }

    /// Record a promotion of `path` on `lo..=hi` (`bytes` per node)
    /// whose RAM admission displaced `evicted` first.
    pub fn on_promoted(&mut self, lo: u32, hi: u32, path: &str, bytes: u64, evicted: &[Eviction]) {
        self.on_evicted(evicted);
        self.promotions += 1;
        self.promoted_bytes += bytes * (hi - lo + 1) as u64;
        remove_from(&mut self.ssd, path, lo, hi);
        add_range(self.ram.entry(path.to_string()).or_default(), lo, hi);
    }

    /// True when `path` is RAM-resident on `node`.
    pub fn resident(&self, node: u32, path: &str) -> bool {
        self.resident_tier(StorageTier::Ram, node, path)
    }

    /// True when `path` is resident on `node` in `tier`.
    pub fn resident_tier(&self, tier: StorageTier, node: u32, path: &str) -> bool {
        self.map_of(tier)
            .get(path)
            .is_some_and(|rs| rs.iter().any(|&(a, b)| (a..=b).contains(&node)))
    }

    /// RAM-resident node ranges of `path` (sorted, coalesced).
    pub fn coverage(&self, path: &str) -> &[(u32, u32)] {
        self.coverage_tier(StorageTier::Ram, path)
    }

    /// Resident node ranges of `path` in `tier` (sorted, coalesced).
    pub fn coverage_tier(&self, tier: StorageTier, path: &str) -> &[(u32, u32)] {
        self.map_of(tier).get(path).map(Vec::as_slice).unwrap_or(&[])
    }

    /// All RAM-resident paths, sorted.
    pub fn resident_paths(&self) -> impl Iterator<Item = &String> {
        self.ram.keys()
    }

    fn map_of(&self, tier: StorageTier) -> &RangeMap {
        match tier {
            StorageTier::Ram => &self.ram,
            StorageTier::Ssd => &self.ssd,
            StorageTier::Gpfs => panic!("GPFS residency lives in ParallelFs"),
        }
    }

    /// Exact-mirror check against the data plane: the table and the
    /// store must agree on every path's resident node set, in both
    /// managed tiers.
    pub fn mirrors(&self, stores: &NodeStores) -> bool {
        let want = |tier| {
            let mut m: RangeMap = BTreeMap::new();
            for (path, reps) in stores.dump_tier(tier) {
                let ranges = m.entry(path).or_default();
                for (lo, hi, _) in reps {
                    add_range(ranges, lo, hi);
                }
            }
            m
        };
        want(StorageTier::Ram) == self.ram && want(StorageTier::Ssd) == self.ssd
    }
}

fn remove_from(map: &mut RangeMap, path: &str, lo: u32, hi: u32) {
    if let Some(ranges) = map.get_mut(path) {
        sub_range(ranges, lo, hi);
        if ranges.is_empty() {
            map.remove(path);
        }
    }
}

/// Merge `[lo, hi]` into a sorted, disjoint, coalesced range set.
pub(crate) fn add_range(ranges: &mut Vec<(u32, u32)>, lo: u32, hi: u32) {
    ranges.push((lo, hi));
    ranges.sort_unstable();
    let mut out: Vec<(u32, u32)> = Vec::with_capacity(ranges.len());
    for &(a, b) in ranges.iter() {
        match out.last_mut() {
            Some((_, pb)) if a <= pb.saturating_add(1) => *pb = (*pb).max(b),
            _ => out.push((a, b)),
        }
    }
    *ranges = out;
}

/// Remove `[lo, hi]` from a sorted, disjoint range set.
pub(crate) fn sub_range(ranges: &mut Vec<(u32, u32)>, lo: u32, hi: u32) {
    let mut out: Vec<(u32, u32)> = Vec::with_capacity(ranges.len() + 1);
    for &(a, b) in ranges.iter() {
        if b < lo || a > hi {
            out.push((a, b));
            continue;
        }
        if a < lo {
            out.push((a, lo - 1));
        }
        if b > hi {
            out.push((hi + 1, b));
        }
    }
    *ranges = out;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pfs::Blob;
    use crate::storage::StoreWrite;

    #[test]
    fn residency_range_set_algebra() {
        let mut rs = Vec::new();
        add_range(&mut rs, 4, 7);
        add_range(&mut rs, 0, 1);
        assert_eq!(rs, vec![(0, 1), (4, 7)]);
        add_range(&mut rs, 2, 3); // bridges and coalesces
        assert_eq!(rs, vec![(0, 7)]);
        sub_range(&mut rs, 3, 5);
        assert_eq!(rs, vec![(0, 2), (6, 7)]);
        sub_range(&mut rs, 0, 7);
        assert!(rs.is_empty());
    }

    #[test]
    fn residency_table_mirrors_store() {
        let mut ns = NodeStores::new();
        let mut table = ResidencyTable::new();
        let w = |ns: &mut NodeStores, t: &mut ResidencyTable, lo, hi, p: &str| {
            match ns.write_range_evicting(lo, hi, p, Blob::real(vec![0; 4])) {
                StoreWrite::Stored { evicted } => t.on_stored(lo, hi, p, &evicted),
                StoreWrite::Rejected { .. } => {}
            }
        };
        w(&mut ns, &mut table, 0, 3, "/tmp/a");
        w(&mut ns, &mut table, 4, 7, "/tmp/a"); // coalesces to (0,7)
        w(&mut ns, &mut table, 2, 5, "/tmp/b");
        assert!(table.mirrors(&ns));
        assert!(table.resident(5, "/tmp/a"));
        assert_eq!(table.coverage("/tmp/a"), &[(0, 7)]);
        assert_eq!(table.resident_paths().count(), 2);
        table.on_evicted(&ns.evict_path("/tmp/b"));
        assert!(table.mirrors(&ns));
        assert!(!table.resident(3, "/tmp/b"));
        assert_eq!(table.evictions, 1);
        assert_eq!(table.evicted_bytes, 4 * 4);
    }

    #[test]
    fn mirror_tracks_demotion_and_promotion() {
        let mut ns = NodeStores::new();
        let mut table = ResidencyTable::new();
        ns.set_capacity(Some(100));
        ns.set_ssd_capacity(Some(100));
        let mut w = |ns: &mut NodeStores, t: &mut ResidencyTable, lo, hi, p: &str, b: u64| {
            match ns.write_range_evicting(lo, hi, p, Blob::synthetic(b, 7)) {
                StoreWrite::Stored { evicted } => t.on_stored(lo, hi, p, &evicted),
                StoreWrite::Rejected { .. } => panic!("unexpected rejection"),
            }
        };
        w(&mut ns, &mut table, 0, 3, "/tmp/a", 60);
        w(&mut ns, &mut table, 0, 3, "/tmp/b", 60); // a demotes to SSD
        assert!(table.mirrors(&ns));
        assert_eq!(table.demotions, 1);
        assert_eq!(table.demoted_bytes, 60 * 4);
        assert!(table.resident_tier(StorageTier::Ssd, 2, "/tmp/a"));
        assert!(!table.resident(2, "/tmp/a"));
        // Promote a back: b demotes in turn.
        match ns.promote_range(0, 3, "/tmp/a") {
            crate::storage::PromoteOutcome::Promoted { bytes, evicted } => {
                table.on_promoted(0, 3, "/tmp/a", bytes, &evicted);
            }
            other => panic!("expected promotion, got {other:?}"),
        }
        assert!(table.mirrors(&ns));
        assert_eq!(table.promotions, 1);
        assert_eq!(table.promoted_bytes, 60 * 4);
        assert!(table.resident(1, "/tmp/a"));
        assert!(table.resident_tier(StorageTier::Ssd, 1, "/tmp/b"));
    }
}
