//! The residency mirror: which paths are resident on which node
//! ranges, per storage tier, plus displacement telemetry.
//!
//! `engine::SimCore` owns one [`ResidencyTable`] and keeps it exactly
//! in sync with every engine-applied node write
//! (`SimCore::node_write_range`), promotion (`SimCore::promote_range`)
//! and eviction (`SimCore::evict_path`), so experiments can report hit
//! rates, demoted bytes, and evicted bytes without rescanning the data
//! plane.
//!
//! Fleet-scale layout: like [`NodeStores`], the table interns paths to
//! dense `u32` ids and keeps each tier's ranges in a `Vec` indexed by
//! id, so the per-query cost is an array index. The string surface
//! resolves through the interner and answers identically; enumeration
//! (`resident_paths`) stays path-sorted via the interner's sorted
//! side.

use std::collections::BTreeMap;
use std::mem::size_of;

use super::intern::PathInterner;
use super::node_stores::NodeStores;
use super::tier::StorageTier;

/// A replica displaced from a tier — to make room for a write, by a
/// forced [`NodeStores::evict_path`], or as demotion cascade fallout.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Eviction {
    pub path: String,
    pub lo: u32,
    pub hi: u32,
    /// Per-node bytes the displacement freed in `tier`.
    pub bytes: u64,
    /// Tier the replica was displaced from.
    pub tier: StorageTier,
    /// True when the replica survived: it was demoted whole into the
    /// SSD tier rather than destroyed. Only `tier == Ram` evictions
    /// can demote; an SSD displacement is always a discard (the GPFS
    /// original remains the backing copy).
    pub demoted: bool,
}

impl Eviction {
    /// Bytes across the whole node span (per-node bytes x span).
    pub fn span_bytes(&self) -> u64 {
        self.bytes * (self.hi - self.lo + 1) as u64
    }
}

/// Per-tier residency: range set per interned path id (empty = not
/// resident in this tier).
type RangeVec = Vec<Vec<(u32, u32)>>;

/// Bookkeeping mirror of [`NodeStores`]: path -> disjoint, sorted,
/// coalesced node ranges, kept **per tier**, plus displacement
/// telemetry. The legacy (un-suffixed) query surface reads the RAM
/// tier — the tier analysis tasks consume.
#[derive(Clone, Debug, Default)]
pub struct ResidencyTable {
    /// Path ↔ dense id bijection (the table's own — independent of the
    /// data plane's, since either side may learn a path first).
    interner: PathInterner,
    /// RAM tier: path id -> resident node ranges.
    ram: RangeVec,
    /// SSD tier: path id -> resident node ranges.
    ssd: RangeVec,
    /// Replicas displaced from RAM under capacity pressure or by
    /// forced eviction (count; includes demotions).
    pub evictions: u64,
    /// Total bytes displaced from RAM (per-node bytes x node span).
    pub evicted_bytes: u64,
    /// RAM displacements that survived as SSD demotions (count).
    pub demotions: u64,
    /// Total bytes demoted RAM -> SSD (per-node bytes x node span).
    pub demoted_bytes: u64,
    /// Replicas discarded from the SSD tier (count).
    pub ssd_evictions: u64,
    /// Total bytes discarded from SSD (per-node bytes x node span).
    pub ssd_evicted_bytes: u64,
    /// Replicas promoted SSD -> RAM (count).
    pub promotions: u64,
    /// Total bytes promoted SSD -> RAM (per-node bytes x node span).
    pub promoted_bytes: u64,
}

/// The ranges slot of `id`, growing the dense table as needed.
fn slot_mut(v: &mut RangeVec, id: u32) -> &mut Vec<(u32, u32)> {
    if id as usize >= v.len() {
        v.resize_with(id as usize + 1, Vec::new);
    }
    &mut v[id as usize]
}

impl ResidencyTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a stored RAM write of `path` on `lo..=hi` that displaced
    /// `evicted` first.
    pub fn on_stored(&mut self, lo: u32, hi: u32, path: &str, evicted: &[Eviction]) {
        self.on_evicted(evicted);
        let id = self.interner.intern(path);
        add_range(slot_mut(&mut self.ram, id), lo, hi);
    }

    /// Record displacements (capacity pressure, demotion cascade, or
    /// forced eviction), tier by tier.
    pub fn on_evicted(&mut self, evicted: &[Eviction]) {
        for ev in evicted {
            let id = self.interner.intern(&ev.path);
            match ev.tier {
                StorageTier::Ram => {
                    self.evictions += 1;
                    self.evicted_bytes += ev.span_bytes();
                    sub_range(slot_mut(&mut self.ram, id), ev.lo, ev.hi);
                    if ev.demoted {
                        self.demotions += 1;
                        self.demoted_bytes += ev.span_bytes();
                        add_range(slot_mut(&mut self.ssd, id), ev.lo, ev.hi);
                    }
                }
                StorageTier::Ssd => {
                    self.ssd_evictions += 1;
                    self.ssd_evicted_bytes += ev.span_bytes();
                    sub_range(slot_mut(&mut self.ssd, id), ev.lo, ev.hi);
                }
                StorageTier::Gpfs => unreachable!("GPFS is not capacity-managed"),
            }
        }
    }

    /// Record a stored **direct SSD** write of `path` on `lo..=hi`
    /// (the ingest backpressure path) that displaced `evicted` SSD
    /// residents first.
    pub fn on_ssd_stored(&mut self, lo: u32, hi: u32, path: &str, evicted: &[Eviction]) {
        self.on_evicted(evicted);
        let id = self.interner.intern(path);
        add_range(slot_mut(&mut self.ssd, id), lo, hi);
    }

    /// Record a promotion of `path` on `lo..=hi` (`bytes` per node)
    /// whose RAM admission displaced `evicted` first.
    pub fn on_promoted(&mut self, lo: u32, hi: u32, path: &str, bytes: u64, evicted: &[Eviction]) {
        self.on_evicted(evicted);
        self.promotions += 1;
        self.promoted_bytes += bytes * (hi - lo + 1) as u64;
        let id = self.interner.intern(path);
        sub_range(slot_mut(&mut self.ssd, id), lo, hi);
        add_range(slot_mut(&mut self.ram, id), lo, hi);
    }

    /// Id of `path` in the table's interner, if it has ever appeared.
    pub fn path_id(&self, path: &str) -> Option<u32> {
        self.interner.get(path)
    }

    /// True when `path` is RAM-resident on `node`.
    pub fn resident(&self, node: u32, path: &str) -> bool {
        self.resident_tier(StorageTier::Ram, node, path)
    }

    /// True when `path` is resident on `node` in `tier`.
    pub fn resident_tier(&self, tier: StorageTier, node: u32, path: &str) -> bool {
        self.coverage_tier(tier, path).iter().any(|&(a, b)| (a..=b).contains(&node))
    }

    /// [`ResidencyTable::resident`] by pre-interned id (RAM tier).
    pub fn resident_id(&self, node: u32, id: u32) -> bool {
        self.coverage_tier_id(StorageTier::Ram, id)
            .iter()
            .any(|&(a, b)| (a..=b).contains(&node))
    }

    /// RAM-resident node ranges of `path` (sorted, coalesced).
    pub fn coverage(&self, path: &str) -> &[(u32, u32)] {
        self.coverage_tier(StorageTier::Ram, path)
    }

    /// Resident node ranges of `path` in `tier` (sorted, coalesced).
    pub fn coverage_tier(&self, tier: StorageTier, path: &str) -> &[(u32, u32)] {
        match self.interner.get(path) {
            Some(id) => self.coverage_tier_id(tier, id),
            None => &[],
        }
    }

    /// [`ResidencyTable::coverage`] by pre-interned id (RAM tier).
    pub fn coverage_id(&self, id: u32) -> &[(u32, u32)] {
        self.coverage_tier_id(StorageTier::Ram, id)
    }

    /// [`ResidencyTable::coverage_tier`] by pre-interned id: a direct
    /// array index.
    pub fn coverage_tier_id(&self, tier: StorageTier, id: u32) -> &[(u32, u32)] {
        self.vec_of(tier).get(id as usize).map(Vec::as_slice).unwrap_or(&[])
    }

    /// All RAM-resident paths, sorted.
    pub fn resident_paths(&self) -> impl Iterator<Item = &String> {
        self.interner
            .iter()
            .filter(|&(_, id)| self.ram.get(id as usize).is_some_and(|rs| !rs.is_empty()))
            .map(|(p, _)| p)
    }

    fn vec_of(&self, tier: StorageTier) -> &RangeVec {
        match tier {
            StorageTier::Ram => &self.ram,
            StorageTier::Ssd => &self.ssd,
            StorageTier::Gpfs => panic!("GPFS residency lives in ParallelFs"),
        }
    }

    /// Resident bytes of the mirror's own bookkeeping (interner plus
    /// both dense range tables). The `scale` bench divides by path
    /// count to report bytes-of-state per mirrored path.
    pub fn state_bytes(&self) -> u64 {
        let side = |v: &RangeVec| -> u64 {
            v.capacity() as u64 * size_of::<Vec<(u32, u32)>>() as u64
                + v.iter().map(|rs| rs.capacity() as u64 * 8).sum::<u64>()
        };
        self.interner.state_bytes() + side(&self.ram) + side(&self.ssd)
    }

    /// Exact-mirror check against the data plane: the table and the
    /// store must agree on every path's resident node set, in both
    /// managed tiers.
    pub fn mirrors(&self, stores: &NodeStores) -> bool {
        let want = |tier| {
            let mut m: BTreeMap<String, Vec<(u32, u32)>> = BTreeMap::new();
            for (path, reps) in stores.dump_tier(tier) {
                let ranges = m.entry(path).or_default();
                for (lo, hi, _) in reps {
                    add_range(ranges, lo, hi);
                }
            }
            m
        };
        let have = |v: &RangeVec| {
            let mut m: BTreeMap<String, Vec<(u32, u32)>> = BTreeMap::new();
            for (path, id) in self.interner.iter() {
                if let Some(rs) = v.get(id as usize) {
                    if !rs.is_empty() {
                        m.insert(path.clone(), rs.clone());
                    }
                }
            }
            m
        };
        want(StorageTier::Ram) == have(&self.ram) && want(StorageTier::Ssd) == have(&self.ssd)
    }
}

/// Merge `[lo, hi]` into a sorted, disjoint, coalesced range set.
pub(crate) fn add_range(ranges: &mut Vec<(u32, u32)>, lo: u32, hi: u32) {
    ranges.push((lo, hi));
    ranges.sort_unstable();
    let mut out: Vec<(u32, u32)> = Vec::with_capacity(ranges.len());
    for &(a, b) in ranges.iter() {
        match out.last_mut() {
            Some((_, pb)) if a <= pb.saturating_add(1) => *pb = (*pb).max(b),
            _ => out.push((a, b)),
        }
    }
    *ranges = out;
}

/// Remove `[lo, hi]` from a sorted, disjoint range set.
pub(crate) fn sub_range(ranges: &mut Vec<(u32, u32)>, lo: u32, hi: u32) {
    let mut out: Vec<(u32, u32)> = Vec::with_capacity(ranges.len() + 1);
    for &(a, b) in ranges.iter() {
        if b < lo || a > hi {
            out.push((a, b));
            continue;
        }
        if a < lo {
            out.push((a, lo - 1));
        }
        if b > hi {
            out.push((hi + 1, b));
        }
    }
    *ranges = out;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pfs::Blob;
    use crate::storage::StoreWrite;

    #[test]
    fn residency_range_set_algebra() {
        let mut rs = Vec::new();
        add_range(&mut rs, 4, 7);
        add_range(&mut rs, 0, 1);
        assert_eq!(rs, vec![(0, 1), (4, 7)]);
        add_range(&mut rs, 2, 3); // bridges and coalesces
        assert_eq!(rs, vec![(0, 7)]);
        sub_range(&mut rs, 3, 5);
        assert_eq!(rs, vec![(0, 2), (6, 7)]);
        sub_range(&mut rs, 0, 7);
        assert!(rs.is_empty());
    }

    #[test]
    fn residency_table_mirrors_store() {
        let mut ns = NodeStores::new();
        let mut table = ResidencyTable::new();
        let w = |ns: &mut NodeStores, t: &mut ResidencyTable, lo, hi, p: &str| {
            match ns.write_range_evicting(lo, hi, p, Blob::real(vec![0; 4])) {
                StoreWrite::Stored { evicted } => t.on_stored(lo, hi, p, &evicted),
                StoreWrite::Rejected { .. } => {}
            }
        };
        w(&mut ns, &mut table, 0, 3, "/tmp/a");
        w(&mut ns, &mut table, 4, 7, "/tmp/a"); // coalesces to (0,7)
        w(&mut ns, &mut table, 2, 5, "/tmp/b");
        assert!(table.mirrors(&ns));
        assert!(table.resident(5, "/tmp/a"));
        assert_eq!(table.coverage("/tmp/a"), &[(0, 7)]);
        assert_eq!(table.resident_paths().count(), 2);
        table.on_evicted(&ns.evict_path("/tmp/b"));
        assert!(table.mirrors(&ns));
        assert!(!table.resident(3, "/tmp/b"));
        assert_eq!(table.evictions, 1);
        assert_eq!(table.evicted_bytes, 4 * 4);
    }

    #[test]
    fn mirror_tracks_demotion_and_promotion() {
        let mut ns = NodeStores::new();
        let mut table = ResidencyTable::new();
        ns.set_capacity(Some(100));
        ns.set_ssd_capacity(Some(100));
        let mut w = |ns: &mut NodeStores, t: &mut ResidencyTable, lo, hi, p: &str, b: u64| {
            match ns.write_range_evicting(lo, hi, p, Blob::synthetic(b, 7)) {
                StoreWrite::Stored { evicted } => t.on_stored(lo, hi, p, &evicted),
                StoreWrite::Rejected { .. } => panic!("unexpected rejection"),
            }
        };
        w(&mut ns, &mut table, 0, 3, "/tmp/a", 60);
        w(&mut ns, &mut table, 0, 3, "/tmp/b", 60); // a demotes to SSD
        assert!(table.mirrors(&ns));
        assert_eq!(table.demotions, 1);
        assert_eq!(table.demoted_bytes, 60 * 4);
        assert!(table.resident_tier(StorageTier::Ssd, 2, "/tmp/a"));
        assert!(!table.resident(2, "/tmp/a"));
        // Promote a back: b demotes in turn.
        match ns.promote_range(0, 3, "/tmp/a") {
            crate::storage::PromoteOutcome::Promoted { bytes, evicted } => {
                table.on_promoted(0, 3, "/tmp/a", bytes, &evicted);
            }
            other => panic!("expected promotion, got {other:?}"),
        }
        assert!(table.mirrors(&ns));
        assert_eq!(table.promotions, 1);
        assert_eq!(table.promoted_bytes, 60 * 4);
        assert!(table.resident(1, "/tmp/a"));
        assert!(table.resident_tier(StorageTier::Ssd, 1, "/tmp/b"));
    }

    #[test]
    fn mirror_tracks_direct_ssd_writes() {
        let mut ns = NodeStores::new();
        let mut table = ResidencyTable::new();
        ns.set_capacity(Some(100));
        ns.set_ssd_capacity(Some(100));
        for (i, p) in ["/tmp/f0", "/tmp/f1"].iter().enumerate() {
            match ns.write_range_ssd_evicting(0, 1, p, Blob::synthetic(60, i as u64)) {
                StoreWrite::Stored { evicted } => table.on_ssd_stored(0, 1, p, &evicted),
                StoreWrite::Rejected { .. } => panic!("unexpected rejection"),
            }
        }
        // f1 displaced f0 (100 B budget): the mirror tracked both the
        // landing and the discard, and RAM stayed empty.
        assert!(table.mirrors(&ns));
        assert!(table.resident_tier(StorageTier::Ssd, 0, "/tmp/f1"));
        assert!(!table.resident_tier(StorageTier::Ssd, 0, "/tmp/f0"));
        assert!(!table.resident(0, "/tmp/f1"));
        assert_eq!(table.ssd_evictions, 1);
        assert_eq!(table.ssd_evicted_bytes, 60 * 2);
        assert_eq!(table.evictions, 0);
    }

    #[test]
    fn id_surface_matches_string_surface() {
        let mut table = ResidencyTable::new();
        table.on_stored(0, 7, "/tmp/a", &[]);
        table.on_stored(2, 5, "/tmp/b", &[]);
        let a = table.path_id("/tmp/a").unwrap();
        let b = table.path_id("/tmp/b").unwrap();
        assert_eq!(table.coverage_id(a), table.coverage("/tmp/a"));
        assert_eq!(table.coverage_id(b), table.coverage("/tmp/b"));
        for n in 0..9u32 {
            assert_eq!(table.resident_id(n, a), table.resident(n, "/tmp/a"));
            assert_eq!(table.resident_id(n, b), table.resident(n, "/tmp/b"));
        }
        assert!(table.path_id("/tmp/nope").is_none());
        assert!(table.coverage("/tmp/nope").is_empty());
        // Paths enumerate sorted regardless of interning order.
        table.on_stored(0, 1, "/tmp/0-first", &[]);
        let order: Vec<&str> = table.resident_paths().map(String::as_str).collect();
        assert_eq!(order, vec!["/tmp/0-first", "/tmp/a", "/tmp/b"]);
    }

    #[test]
    fn state_bytes_scales_with_paths() {
        let mut table = ResidencyTable::new();
        let empty = table.state_bytes();
        for i in 0..100 {
            table.on_stored(0, 63, &format!("/tmp/f{i:03}"), &[]);
        }
        let full = table.state_bytes();
        assert!(full > empty);
        // Bounded per-path state: well under 1 KiB each for short
        // paths with one range.
        assert!(full / 100 < 1024, "bytes per path: {}", full / 100);
    }
}
