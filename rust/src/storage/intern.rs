//! Dense path interning: the fleet-scale storage layers key their hot
//! paths on `u32` ids instead of `String`s.
//!
//! At 8K nodes and 10⁴+ sessions the data plane answers millions of
//! per-path queries (coverage lookups, residency probes, cache-hit
//! tests) per simulated second. String-keyed BTree walks pay a pointer
//! chase plus a byte-compare per level; a dense id indexes a `Vec`
//! directly. The interner is the bridge: paths intern once (on first
//! write or first schedule), and every subsequent hot-path query rides
//! the id.
//!
//! Ids are dense (`0..len`), never reused, and stable for the life of
//! the interner — a `Vec<T>` indexed by id is a perfect shard table.
//! Enumeration (`iter`) is path-sorted, preserving the deterministic
//! output order the string-keyed stores had by construction.

use std::collections::BTreeMap;
use std::mem::size_of;

/// Path ↔ dense-id bijection. Interning is get-or-insert; resolution
/// is an index. See the module docs for the design rationale.
#[derive(Clone, Debug, Default)]
pub struct PathInterner {
    /// path -> id (sorted: gives deterministic enumeration).
    by_path: BTreeMap<String, u32>,
    /// id -> path (dense).
    paths: Vec<String>,
}

impl PathInterner {
    pub fn new() -> Self {
        Self::default()
    }

    /// Id of `path`, allocating the next dense id on first sight.
    pub fn intern(&mut self, path: &str) -> u32 {
        if let Some(&id) = self.by_path.get(path) {
            return id;
        }
        let id = u32::try_from(self.paths.len()).expect("interner overflow");
        self.by_path.insert(path.to_string(), id);
        self.paths.push(path.to_string());
        id
    }

    /// Id of `path` if it has been interned.
    pub fn get(&self, path: &str) -> Option<u32> {
        self.by_path.get(path).copied()
    }

    /// The path behind `id`. Panics on an id this interner never
    /// issued.
    pub fn resolve(&self, id: u32) -> &str {
        &self.paths[id as usize]
    }

    /// Number of interned paths (== the exclusive id upper bound).
    pub fn len(&self) -> usize {
        self.paths.len()
    }

    pub fn is_empty(&self) -> bool {
        self.paths.is_empty()
    }

    /// All interned paths with their ids, sorted by path.
    pub fn iter(&self) -> impl Iterator<Item = (&String, u32)> {
        self.by_path.iter().map(|(p, &id)| (p, id))
    }

    /// Approximate resident bytes of the interner's own bookkeeping
    /// (both sides of the bijection; excludes allocator slack in the
    /// BTree beyond a per-entry node estimate).
    pub fn state_bytes(&self) -> u64 {
        let vec_side = self.paths.capacity() as u64 * size_of::<String>() as u64;
        let strings: u64 = self.paths.iter().map(|p| 2 * p.capacity() as u64).sum();
        // BTreeMap node payload: key String header + u32 value, plus a
        // rough 16 B/entry structural overhead.
        let map_side = self.by_path.len() as u64 * (size_of::<String>() + 4 + 16) as u64;
        vec_side + strings + map_side
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_are_dense_and_stable() {
        let mut it = PathInterner::new();
        let a = it.intern("/tmp/a");
        let b = it.intern("/tmp/b");
        let c = it.intern("/tmp/c");
        assert_eq!((a, b, c), (0, 1, 2));
        // Idempotent: re-interning returns the same id.
        assert_eq!(it.intern("/tmp/b"), 1);
        assert_eq!(it.len(), 3);
        assert_eq!(it.get("/tmp/c"), Some(2));
        assert_eq!(it.get("/tmp/zzz"), None);
    }

    #[test]
    fn resolve_round_trips() {
        let mut it = PathInterner::new();
        for p in ["/d/x.bin", "/d/y.bin", "/a/z.bin"] {
            let id = it.intern(p);
            assert_eq!(it.resolve(id), p);
        }
    }

    #[test]
    fn iter_is_path_sorted() {
        let mut it = PathInterner::new();
        it.intern("/z");
        it.intern("/a");
        it.intern("/m");
        let order: Vec<&str> = it.iter().map(|(p, _)| p.as_str()).collect();
        assert_eq!(order, vec!["/a", "/m", "/z"]);
        // Ids still reflect interning order, not sort order.
        assert_eq!(it.get("/z"), Some(0));
    }

    #[test]
    fn state_bytes_grows_with_content() {
        let mut it = PathInterner::new();
        let empty = it.state_bytes();
        it.intern("/tmp/some/longish/path/segment.bin");
        assert!(it.state_bytes() > empty);
    }
}
