//! The simulation core: executes [`Plan`] DAGs over the flow network
//! under a deterministic virtual clock.
//!
//! The engine is a reactor. Subsystems that need to make decisions
//! *during* the run (the dataflow scheduler launching tasks as cores
//! free up, the staging hook chaining phases) implement [`Director`]
//! and receive [`Notice`]s — plan completions, step notifications,
//! timers — through which they submit more plans. All state mutation
//! happens on the single thread that owns [`SimCore`]; runs are
//! bit-reproducible.

use std::collections::{HashMap, VecDeque};

use crate::metrics::Metrics;
use crate::pfs::ParallelFs;
use crate::simtime::flownet::{CompId, FlowId, FlowNet, LinkId, ThroughputMode};
use crate::simtime::heap::{EventHeap, HeapKind, HeapStats};
use crate::simtime::plan::{Effect, Plan, PlanId, Step};
use crate::storage::{Eviction, NodeStores, PromoteOutcome, ResidencyTable, StoreWrite};
use crate::units::{Duration, SimTime};

/// Tag of the engine's internal demotion plans (RAM -> SSD transfers
/// spun off evictions). Below every director-owned tag namespace
/// (`dataflow::sched::TASK_TAG_BASE` = 1<<48,
/// `staging::service::STAGE_TAG_BASE` = 1<<47), so directors ignore
/// their completions. (`chaos::CHAOS_TAG_BASE` = 1<<45 sits below
/// this too, but is a **timer** namespace — chaos never tags plans —
/// so the two cannot collide.)
pub const DEMOTE_TAG: u64 = 1 << 46;

/// How engine-applied demotions reach the SSD tier: the flownet path
/// (the machine's aggregated SSD layer) and the per-node rate cap.
/// Installed by `cluster::Topology::apply_storage_budgets`.
#[derive(Clone, Debug)]
pub struct DemoteRoute {
    pub path: Vec<LinkId>,
    pub cap_each: f64,
}

/// Notification delivered to the [`Director`].
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Notice {
    /// All steps of the plan finished. Carries the plan's `tag`.
    PlanDone { plan: PlanId, tag: u64 },
    /// An `Effect::Notify(tag)` step fired.
    Step { tag: u64 },
    /// A timer scheduled with [`SimCore::timer`] fired.
    Timer { tag: u64 },
}

/// The decision-making layer driven by the engine.
pub trait Director {
    fn on_notice(&mut self, core: &mut SimCore, notice: Notice);
}

/// A director for static workloads: everything submitted up front.
pub struct NullDirector;

impl Director for NullDirector {
    fn on_notice(&mut self, _core: &mut SimCore, _notice: Notice) {}
}

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
enum Ev {
    /// Look for drained flows in one network component. Component ids
    /// are never reused, so a check whose component has since been
    /// invalidated is stale and ignored — unrelated components' checks
    /// stay valid in the heap (no global epoch).
    FlowCheck { comp: CompId },
    /// A `Step::Delay` finished.
    StepDone { plan: u32, step: u32 },
    /// Director timer.
    Timer { tag: u64 },
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum StepState {
    Blocked,
    Running,
    Done,
}

struct PlanRun {
    plan: Plan,
    missing: Vec<u32>,
    dependents: Vec<Vec<u32>>,
    state: Vec<StepState>,
    remaining: usize,
}

/// The simulation core. Owns the clock, the flow network, the shared
/// filesystem, the node-local stores, and all in-flight plans.
pub struct SimCore {
    pub now: SimTime,
    pub net: FlowNet,
    pub pfs: ParallelFs,
    pub nodes: NodeStores,
    /// Residency mirror of `nodes`, kept in sync by every
    /// engine-applied node write ([`SimCore::node_write_range`]),
    /// promotion ([`SimCore::promote_range`]) and eviction
    /// ([`SimCore::evict_path`]).
    pub residency: ResidencyTable,
    pub metrics: Metrics,
    /// Route demotion transfers take through the flow network (None =
    /// demotions, if any, are untimed data-plane moves).
    demote_route: Option<DemoteRoute>,
    heap: EventHeap<Ev>,
    plans: Vec<PlanRun>,
    flow_owner: HashMap<FlowId, (u32, u32)>,
    pending: VecDeque<Notice>,
    last_net_update: SimTime,
    /// The live `FlowCheck` per component: `comp id -> (time, seq)`
    /// heap coordinates. Maintained only on the wheel kernel (the seed
    /// kernel keeps the original fire-as-stale-no-op behaviour as the
    /// differential baseline). Invariant **K2**: at most one entry per
    /// component — a component's check is scheduled once at the settle
    /// that built it, and rescheduling happens only after the old
    /// check popped (the rounding-residue re-dirty path).
    pending_checks: HashMap<u64, (SimTime, u64)>,
    /// Scratch for draining retired component ids (allocation reuse).
    retired_scratch: Vec<u64>,
    /// `FlowCheck` pops whose component had been invalidated — each is
    /// a wasted heap round-trip the wheel kernel avoids by reclaiming.
    stale_check_pops: u64,
    /// Pending checks cancelled eagerly at the settle that retired
    /// their component (wheel kernel only).
    stale_checks_reclaimed: u64,
    /// Total events processed (perf telemetry).
    pub events_processed: u64,
    /// Incomplete submitted plans (kept O(1) for serving loops).
    live_plan_count: usize,
    /// Step descriptors still held across submitted plans.
    retained_step_count: usize,
}

impl SimCore {
    pub fn new() -> Self {
        SimCore::with_mode(ThroughputMode::Fast)
    }

    /// A core whose flow network runs the given throughput model
    /// (`Slow` is the reference oracle for differential tests).
    pub fn with_mode(mode: ThroughputMode) -> Self {
        SimCore::with_parts(mode, HeapKind::default())
    }

    /// A core with both the throughput model and the event-heap
    /// backend chosen explicitly (`HeapKind::Seed` is the differential
    /// baseline for `benches/kernel.rs` / `tests/property_kernel.rs`).
    pub fn with_parts(mode: ThroughputMode, kind: HeapKind) -> Self {
        SimCore {
            now: SimTime::ZERO,
            net: FlowNet::with_mode(mode),
            pfs: ParallelFs::new(),
            nodes: NodeStores::new(),
            residency: ResidencyTable::new(),
            metrics: Metrics::new(),
            demote_route: None,
            heap: EventHeap::with_kind(kind),
            plans: Vec::new(),
            flow_owner: HashMap::new(),
            pending: VecDeque::new(),
            last_net_update: SimTime::ZERO,
            pending_checks: HashMap::new(),
            retired_scratch: Vec::new(),
            stale_check_pops: 0,
            stale_checks_reclaimed: 0,
            events_processed: 0,
            live_plan_count: 0,
            retained_step_count: 0,
        }
    }

    /// Submit a plan; its ready steps start at the current time.
    pub fn submit(&mut self, plan: Plan) -> PlanId {
        assert!(!plan.is_empty(), "empty plan");
        let id = PlanId(self.plans.len());
        let n = plan.len();
        let mut missing = vec![0u32; n];
        let mut dependents: Vec<Vec<u32>> = vec![Vec::new(); n];
        for (i, s) in plan.steps.iter().enumerate() {
            missing[i] = s.deps.len() as u32;
            for d in &s.deps {
                dependents[d.0].push(i as u32);
            }
        }
        self.plans.push(PlanRun {
            plan,
            missing,
            dependents,
            state: vec![StepState::Blocked; n],
            remaining: n,
        });
        self.live_plan_count += 1;
        self.retained_step_count += n;
        for i in 0..n {
            // An earlier instantaneous step may have already cascaded
            // into this one via complete_step; only start steps still
            // Blocked with no outstanding deps. A fully-instantaneous
            // plan can even finish mid-scan — whereupon its step
            // storage was released — so stop once nothing remains.
            let run = &self.plans[id.0];
            if run.remaining == 0 {
                break;
            }
            if run.missing[i] == 0 && run.state[i] == StepState::Blocked {
                self.start_step(id.0 as u32, i as u32);
            }
        }
        id
    }

    /// Deliver `Notice::Timer { tag }` to the director at time `at`.
    pub fn timer(&mut self, at: SimTime, tag: u64) {
        assert!(at >= self.now, "timer in the past");
        self.heap.push(at, Ev::Timer { tag });
    }

    /// Install (or clear) the route demotion transfers take through
    /// the flow network. With a route set, every engine-applied
    /// eviction that demotes RAM -> SSD also submits a timed transfer
    /// over it (tagged [`DEMOTE_TAG`]), so tier traffic contends like
    /// any other machine layer.
    pub fn set_demote_route(&mut self, route: Option<DemoteRoute>) {
        self.demote_route = route;
    }

    pub fn demote_route(&self) -> Option<&DemoteRoute> {
        self.demote_route.as_ref()
    }

    /// Capacity-checked node-local write keeping metrics and the
    /// residency mirror in sync. All engine-applied
    /// [`Effect::NodeWrite`]s route through here; direct data-plane
    /// writes should use it too whenever residency accounting matters.
    /// A rejected write (pinned residents alone exceed the node
    /// budget) leaves the store untouched and counts under
    /// `node.write.rejected`.
    pub fn node_write_range(
        &mut self,
        lo: u32,
        hi: u32,
        path: &str,
        data: crate::pfs::Blob,
    ) -> StoreWrite {
        let per_node = data.len();
        let outcome = self.nodes.write_range_evicting(lo, hi, path, data);
        match &outcome {
            StoreWrite::Stored { evicted } => {
                self.metrics.add_bytes("node.write", per_node * (hi - lo + 1) as u64);
                self.residency.on_stored(lo, hi, path, evicted);
                self.book_evictions(evicted);
            }
            StoreWrite::Rejected { .. } => {
                self.metrics.incr("node.write.rejected");
            }
        }
        outcome
    }

    /// Capacity-checked **direct SSD** write keeping metrics and the
    /// residency mirror in sync — the ingest backpressure path: a
    /// detector frame that cannot be admitted to RAM lands on the SSD
    /// tier without displacing anything from RAM. Books under its own
    /// labels (`node.write.ssd` / `node.write.ssd.rejected`), distinct
    /// from the RAM-write telemetry, so harnesses asserting
    /// [`SimCore::node_write_rejections`]` == 0` are unaffected by
    /// expected SSD backpressure.
    pub fn node_write_range_ssd(
        &mut self,
        lo: u32,
        hi: u32,
        path: &str,
        data: crate::pfs::Blob,
    ) -> StoreWrite {
        let per_node = data.len();
        let outcome = self.nodes.write_range_ssd_evicting(lo, hi, path, data);
        match &outcome {
            StoreWrite::Stored { evicted } => {
                self.metrics.add_bytes("node.write.ssd", per_node * (hi - lo + 1) as u64);
                self.residency.on_ssd_stored(lo, hi, path, evicted);
                self.book_evictions(evicted);
            }
            StoreWrite::Rejected { .. } => {
                self.metrics.incr("node.write.ssd.rejected");
            }
        }
        outcome
    }

    /// Account displacement telemetry with tier provenance and submit
    /// the timed demotion transfers. `node.evict`/`node.evictions`
    /// keep their original meaning — replicas displaced from RAM —
    /// whether or not the replica survived by demotion.
    fn book_evictions(&mut self, evicted: &[Eviction]) {
        let mut demote = self
            .demote_route
            .as_ref()
            .map(|route| (route.clone(), Plan::new(DEMOTE_TAG)));
        for ev in evicted {
            match ev.tier {
                crate::storage::StorageTier::Ram => {
                    self.metrics.add_bytes("node.evict", ev.span_bytes());
                    self.metrics.incr("node.evictions");
                    if ev.demoted {
                        self.metrics.add_bytes("node.demote", ev.span_bytes());
                        self.metrics.incr("node.demotions");
                        if let Some((route, plan)) = demote.as_mut() {
                            plan.flow_capped(
                                route.path.clone(),
                                (ev.hi - ev.lo + 1) as u64,
                                ev.bytes,
                                route.cap_each,
                                vec![],
                                "demote",
                            );
                        }
                    }
                }
                crate::storage::StorageTier::Ssd => {
                    self.metrics.add_bytes("node.evict.ssd", ev.span_bytes());
                    self.metrics.incr("node.evictions.ssd");
                }
                crate::storage::StorageTier::Gpfs => unreachable!(),
            }
        }
        if let Some((_, plan)) = demote {
            if !plan.is_empty() {
                self.submit(plan);
            }
        }
    }

    /// Promote `path` from the SSD tier into RAM across `lo..=hi`,
    /// keeping metrics and the residency mirror in sync. All
    /// engine-applied [`Effect::NodePromote`]s route through here. A
    /// miss (`node.promote.missed`: the SSD copy vanished between plan
    /// and effect — impossible while the planner pins it) or rejection
    /// (`node.promote.rejected`) leaves both tiers untouched.
    pub fn promote_range(&mut self, lo: u32, hi: u32, path: &str) -> PromoteOutcome {
        let outcome = self.nodes.promote_range(lo, hi, path);
        match &outcome {
            PromoteOutcome::Promoted { bytes, evicted } => {
                self.metrics.add_bytes("node.promote", bytes * (hi - lo + 1) as u64);
                self.metrics.incr("node.promotions");
                self.residency.on_promoted(lo, hi, path, *bytes, evicted);
                self.book_evictions(evicted);
            }
            PromoteOutcome::Missing => {
                self.metrics.incr("node.promote.missed");
            }
            PromoteOutcome::Rejected { .. } => {
                self.metrics.incr("node.promote.rejected");
            }
        }
        outcome
    }

    /// Node-local writes rejected under memory pressure so far. A
    /// plain `staged_plan` keeps running after a rejected
    /// [`Effect::NodeWrite`] — only this counter records that its
    /// manifest over-promises. Harnesses that stage while paths are
    /// pinned should either go through `staging::Residency` (which
    /// verifies delivery and returns `Err`) or assert this stays zero.
    pub fn node_write_rejections(&self) -> u64 {
        self.metrics.count("node.write.rejected")
    }

    /// Forcibly evict `path` from every node and **both tiers** — a
    /// purge, nothing demotes (no-op when pinned) — keeping metrics
    /// and the residency mirror in sync.
    pub fn evict_path(&mut self, path: &str) -> Vec<Eviction> {
        let evicted = self.nodes.evict_path(path);
        self.residency.on_evicted(&evicted);
        self.book_evictions(&evicted);
        evicted
    }

    /// Crash-restart failure injection: `node`'s memory vanishes.
    /// Every RAM and SSD replica slice the node held is dropped — pins
    /// are not honoured, hardware failure outranks them — and the
    /// residency mirror follows (the losses book as non-demoting
    /// displacements). A warm spare rejoins instantly under the same
    /// node id: the cluster's shape, slot pool, and network are
    /// unchanged, so recovery is purely a data-and-tasks concern — the
    /// owner aborts plans that were computing on the node
    /// ([`SimCore::abort_plan`]) and re-stages lost replicas from the
    /// cheapest surviving source. Returns the lost slices;
    /// `chaos.node.failed` / `chaos.bytes.lost` account the event.
    pub fn fail_node(&mut self, node: u32) -> Vec<Eviction> {
        let lost = self.nodes.fail_node(node);
        self.residency.on_evicted(&lost);
        self.metrics.incr("chaos.node.failed");
        for ev in &lost {
            self.metrics.add_bytes("chaos.bytes.lost", ev.span_bytes());
        }
        lost
    }

    /// Abort an in-flight plan (its work died with a failed node):
    /// cancel the flows it owns — the freed capacity redistributes at
    /// the next settle — discard its unfinished steps without applying
    /// their effects, and release its step storage. **No `PlanDone` is
    /// emitted**, so the owner can resubmit the work under the same
    /// tag and observe exactly one completion. Delay timers and flow
    /// checks already in the heap become stale and are ignored when
    /// they fire. Returns false (and does nothing) when the plan had
    /// already completed: the abort raced a completion notice still in
    /// the pending queue, and exactly-once then belongs to that
    /// notice.
    pub fn abort_plan(&mut self, id: PlanId) -> bool {
        if self.plans[id.0].remaining == 0 {
            return false;
        }
        // Cancel owned flows in FlowId order: the flow-owner map is
        // hash-ordered, and slot free-list order must stay
        // deterministic for bit-reproducible runs.
        let mut owned: Vec<FlowId> = self
            .flow_owner
            .iter()
            .filter(|&(_, &(p, _))| p as usize == id.0)
            .map(|(&f, _)| f)
            .collect();
        owned.sort();
        for f in owned {
            self.flow_owner.remove(&f);
            self.net.cancel(f);
        }
        let run = &mut self.plans[id.0];
        // Close the metrics phases of steps caught mid-run.
        let open: Vec<&'static str> = run
            .state
            .iter()
            .enumerate()
            .filter(|&(_, &st)| st == StepState::Running)
            .map(|(i, _)| run.plan.steps[i].label)
            .collect();
        let released = run.plan.steps.len();
        run.plan.steps = Vec::new();
        run.state = Vec::new();
        run.missing = Vec::new();
        run.dependents = Vec::new();
        run.remaining = 0;
        self.live_plan_count -= 1;
        self.retained_step_count -= released;
        for label in open {
            self.metrics.phase_end(label, self.now);
        }
        self.metrics.incr("chaos.plans.aborted");
        true
    }

    /// Run until the event queue drains. The director receives every
    /// notice and may keep submitting work.
    pub fn run(&mut self, director: &mut impl Director) {
        loop {
            self.settle_network();
            while let Some(n) = self.pending.pop_front() {
                director.on_notice(self, n);
                self.settle_network();
            }
            let Some((t, ev)) = self.heap.pop() else { break };
            debug_assert!(t >= self.now, "time went backwards");
            self.now = t;
            self.events_processed += 1;
            self.handle(ev);
        }
        assert!(
            self.plans.iter().all(|p| p.remaining == 0),
            "deadlock: {} plans incomplete at drain",
            self.plans.iter().filter(|p| p.remaining > 0).count()
        );
        self.record_kernel_gauges();
    }

    /// Convenience: run with no director.
    pub fn run_to_completion(&mut self) {
        self.run(&mut NullDirector);
    }

    // ------------------------------------------------------------------
    // internals
    // ------------------------------------------------------------------

    fn handle(&mut self, ev: Ev) {
        match ev {
            Ev::FlowCheck { comp } => {
                // This check is no longer pending (K2 frees the slot
                // for the component's next schedule); a pop whose
                // component has died is the waste the wheel kernel's
                // eager reclamation exists to avoid — count it.
                self.pending_checks.remove(&comp.0);
                if !self.net.comp_live(comp) {
                    self.stale_check_pops += 1;
                }
                self.advance_net();
                // Drained flows of this component only (sorted; ties
                // complete together at this timestamp). A stale check —
                // the component was invalidated after scheduling —
                // returns nothing and costs O(1). Eager completion here
                // (including instantaneous infinite-rate flows) keeps
                // every check bounded: nothing is ever re-reported.
                for f in self.net.check(comp) {
                    self.net.complete(f);
                    let (p, s) = self.flow_owner.remove(&f).expect("unowned flow");
                    self.complete_step(p, s);
                }
            }
            Ev::StepDone { plan, step } => {
                // A Delay timer may outlive its plan: the plan was
                // aborted (node failure) and its storage released.
                // Such stale timers are no-ops — a *live* plan can
                // never see a StepDone for an already-Done step, so
                // remaining == 0 precisely identifies the aborted (or
                // finished-by-abort-race) case.
                if self.plans[plan as usize].remaining == 0 {
                    return;
                }
                self.complete_step(plan, step);
            }
            Ev::Timer { tag } => {
                self.pending.push_back(Notice::Timer { tag });
            }
        }
    }

    /// Advance flow progress to `self.now`.
    fn advance_net(&mut self) {
        let dt = self.now - self.last_net_update;
        if dt > Duration::ZERO {
            self.net.advance(dt);
        }
        self.last_net_update = self.now;
    }

    /// If the active flow set changed, recompute fair shares for the
    /// dirty components and schedule their completion checks.
    /// Untouched components keep their already-scheduled checks.
    fn settle_network(&mut self) {
        // Reclaim before the dirty check: a settle can retire
        // components without leaving the network dirty afterwards
        // (flood-fill absorption, singleton completion), and the seed
        // kernel still needs the retired record drained so it stays
        // bounded.
        self.reclaim_retired_checks();
        if !self.net.is_dirty() {
            return;
        }
        self.advance_net();
        let reclaiming = self.heap.kind() == HeapKind::Wheel;
        for check in self.net.settle_checks() {
            debug_assert!(check.at >= self.now, "check scheduled in the past");
            let seq = self.heap.push(check.at, Ev::FlowCheck { comp: check.comp });
            if reclaiming {
                // K2: the component was just built by this settle, so
                // no earlier check can still be pending under its
                // (never-reused) id.
                let prev = self.pending_checks.insert(check.comp.0, (check.at, seq));
                debug_assert!(prev.is_none(), "two live checks for one component");
            }
        }
    }

    /// Cancel the pending checks of every component retired since the
    /// last drain (**K3**: a retired component's check never fires on
    /// the wheel kernel — it leaves the heap at the settle that killed
    /// it). On the seed kernel the retired record is drained and
    /// dropped: stale checks stay in the heap and fire as no-ops,
    /// preserving the seed's exact event count and final clock.
    fn reclaim_retired_checks(&mut self) {
        let mut retired = std::mem::take(&mut self.retired_scratch);
        self.net.drain_retired(&mut retired);
        if self.heap.kind() == HeapKind::Wheel {
            for comp in retired.drain(..) {
                if let Some((at, seq)) = self.pending_checks.remove(&comp) {
                    let hit = self.heap.cancel(at, seq);
                    debug_assert!(hit, "pending check vanished before its cancel");
                    self.stale_checks_reclaimed += u64::from(hit);
                }
            }
        } else {
            retired.clear();
        }
        self.retired_scratch = retired;
    }

    /// Fold the kernel's lifetime occupancy peaks and stale-check
    /// counters into `metrics` (run on every drain; `record_max` keeps
    /// the figures monotone across repeated [`SimCore::run`] calls).
    fn record_kernel_gauges(&mut self) {
        let st = self.heap.stats();
        self.metrics.record_max("kernel.heap.peak_depth", st.peak_depth as f64);
        self.metrics.record_max("kernel.heap.peak_wheel", st.peak_wheel as f64);
        self.metrics.record_max("kernel.heap.peak_overflow", st.peak_overflow as f64);
        self.metrics
            .record_max("kernel.checks.stale_pops", self.stale_check_pops as f64);
        self.metrics
            .record_max("kernel.checks.reclaimed", self.stale_checks_reclaimed as f64);
    }

    fn start_step(&mut self, plan: u32, step: u32) {
        let run = &mut self.plans[plan as usize];
        debug_assert_eq!(run.state[step as usize], StepState::Blocked);
        run.state[step as usize] = StepState::Running;
        let label = run.plan.steps[step as usize].label;
        self.metrics.phase_start(label, self.now);
        // Clone the step descriptor (cheap: blobs are Arc/descriptor).
        let s = run.plan.steps[step as usize].step.clone();
        match s {
            Step::Flow { path, members, bytes_each, cap_each } => {
                if bytes_each == 0 {
                    self.complete_step(plan, step);
                } else {
                    self.advance_net();
                    let f = self.net.start_capped(path, members, bytes_each, cap_each);
                    self.flow_owner.insert(f, (plan, step));
                }
            }
            Step::Delay(d) => {
                if d == Duration::ZERO {
                    self.complete_step(plan, step);
                } else {
                    self.heap.push(self.now + d, Ev::StepDone { plan, step });
                }
            }
            Step::Effect(e) => {
                self.apply_effect(e);
                self.complete_step(plan, step);
            }
        }
    }

    fn apply_effect(&mut self, e: Effect) {
        match e {
            Effect::PfsWrite { path, data } => {
                self.metrics.add_bytes("pfs.write", data.len());
                self.pfs.write(path, data);
            }
            Effect::NodeWrite { nodes: (lo, hi), path, data } => {
                self.node_write_range(lo, hi, &path, data);
            }
            Effect::NodePromote { nodes: (lo, hi), path } => {
                self.promote_range(lo, hi, &path);
            }
            Effect::Notify(tag) => {
                self.pending.push_back(Notice::Step { tag });
            }
        }
    }

    fn complete_step(&mut self, plan: u32, step: u32) {
        let run = &mut self.plans[plan as usize];
        debug_assert_ne!(run.state[step as usize], StepState::Done, "double completion");
        run.state[step as usize] = StepState::Done;
        run.remaining -= 1;
        // Decide completion NOW: dependent steps started below may
        // cascade (zero-length steps complete recursively) and push the
        // plan's remaining to 0 inside the recursion — only the call
        // whose decrement reached 0 may emit PlanDone.
        let finished = run.remaining == 0;
        let label = run.plan.steps[step as usize].label;
        self.metrics.phase_end(label, self.now);
        let deps = std::mem::take(&mut self.plans[plan as usize].dependents[step as usize]);
        for d in deps {
            let run = &mut self.plans[plan as usize];
            run.missing[d as usize] -= 1;
            if run.missing[d as usize] == 0 {
                self.start_step(plan, d);
            }
        }
        if finished {
            // Release the finished plan's step storage: a long-lived
            // serving core submits one plan per task across thousands
            // of sessions, and memory must track *live* work, not the
            // total submitted history. The slot itself stays (PlanId
            // is an index and `plan_done` still answers), but steps,
            // dependency arrays, and state shrink to nothing.
            let run = &mut self.plans[plan as usize];
            let released = run.plan.steps.len();
            run.plan.steps = Vec::new();
            run.state = Vec::new();
            run.missing = Vec::new();
            run.dependents = Vec::new();
            self.live_plan_count -= 1;
            self.retained_step_count -= released;
            self.pending.push_back(Notice::PlanDone {
                plan: PlanId(plan as usize),
                tag: self.plans[plan as usize].plan.tag,
            });
        }
    }

    /// True when a submitted plan has fully completed.
    pub fn plan_done(&self, id: PlanId) -> bool {
        self.plans[id.0].remaining == 0
    }

    /// Submitted plans still incomplete. O(1): maintained at submit
    /// and completion, so serving loops can poll it freely.
    pub fn live_plans(&self) -> usize {
        self.live_plan_count
    }

    /// Step descriptors still held across all submitted plans. Only
    /// live plans retain steps — completed plans release theirs — so a
    /// multi-session serving run's footprint is bounded by concurrent
    /// work, not by session count. O(1) like [`SimCore::live_plans`].
    pub fn retained_steps(&self) -> usize {
        self.retained_step_count
    }

    /// Which event-heap backend this core runs on.
    pub fn heap_kind(&self) -> HeapKind {
        self.heap.kind()
    }

    /// Kernel observability snapshot: heap occupancy peaks plus the
    /// stale-check economy. `events_processed - stale_check_pops` is
    /// the *useful* event count — the quantity that is identical
    /// across heap backends (the wheel kernel reclaims checks before
    /// they pop, so its raw event count can be lower, never higher).
    pub fn kernel_stats(&self) -> KernelStats {
        KernelStats {
            heap: self.heap.stats(),
            stale_check_pops: self.stale_check_pops,
            stale_checks_reclaimed: self.stale_checks_reclaimed,
        }
    }
}

/// Kernel observability counters surfaced by [`SimCore::kernel_stats`]
/// (see `DESIGN.md` "Event core" for the K1–K3 invariants they
/// witness).
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub struct KernelStats {
    /// Event-heap occupancy peaks (wheel/overflow split is zero on
    /// the seed backend).
    pub heap: HeapStats,
    /// `FlowCheck` pops whose component had already been invalidated
    /// (zero-ish on the wheel kernel; the seed kernel's churn waste).
    pub stale_check_pops: u64,
    /// Pending checks cancelled eagerly when their component retired
    /// (always zero on the seed kernel).
    pub stale_checks_reclaimed: u64,
}

impl Default for SimCore {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pfs::Blob;
    use crate::simtime::flownet::Capacity;
    use crate::units::GB;

    #[test]
    fn delay_chain_accumulates_time() {
        let mut core = SimCore::new();
        let mut p = Plan::new(1);
        let a = p.delay(Duration::from_secs(2), vec![], "a");
        p.delay(Duration::from_secs(3), vec![a], "b");
        let id = core.submit(p);
        core.run_to_completion();
        assert!(core.plan_done(id));
        assert_eq!(core.now.secs_f64(), 5.0);
    }

    #[test]
    fn parallel_delays_overlap() {
        let mut core = SimCore::new();
        let mut p = Plan::new(0);
        p.delay(Duration::from_secs(2), vec![], "a");
        p.delay(Duration::from_secs(3), vec![], "b");
        p.barrier("join");
        core.submit(p);
        core.run_to_completion();
        assert_eq!(core.now.secs_f64(), 3.0);
    }

    #[test]
    fn flow_transfer_takes_bandwidth_time() {
        let mut core = SimCore::new();
        let l = core.net.add_link("l", Capacity::Fixed(GB as f64));
        let mut p = Plan::new(0);
        p.flow(vec![l], 1, 2 * GB, vec![], "xfer");
        core.submit(p);
        core.run_to_completion();
        assert!((core.now.secs_f64() - 2.0).abs() < 1e-6);
    }

    #[test]
    fn two_flows_share_then_speed_up() {
        // 1 GB and 3 GB on a 2 GB/s link: share 1 GB/s each; the small
        // one finishes at t=1, the big one then runs at 2 GB/s and
        // finishes at t = 1 + 2/2 = 2.
        let mut core = SimCore::new();
        let l = core.net.add_link("l", Capacity::Fixed(2.0 * GB as f64));
        let mut p = Plan::new(0);
        p.flow(vec![l], 1, GB, vec![], "small");
        p.flow(vec![l], 1, 3 * GB, vec![], "big");
        core.submit(p);
        core.run_to_completion();
        assert!((core.now.secs_f64() - 2.0).abs() < 1e-6, "{}", core.now);
    }

    #[test]
    fn dependent_flow_starts_after_dep() {
        let mut core = SimCore::new();
        let l = core.net.add_link("l", Capacity::Fixed(GB as f64));
        let mut p = Plan::new(0);
        let a = p.flow(vec![l], 1, GB, vec![], "a");
        p.flow(vec![l], 1, GB, vec![a], "b");
        core.submit(p);
        core.run_to_completion();
        // Sequential: 1 + 1 = 2 s (no sharing since never concurrent).
        assert!((core.now.secs_f64() - 2.0).abs() < 1e-6);
    }

    #[test]
    fn effects_apply_to_data_plane() {
        let mut core = SimCore::new();
        let blob = Blob::real(vec![5; 32]);
        let mut p = Plan::new(0);
        let w = p.effect(
            Effect::PfsWrite { path: "/d/x".into(), data: blob.clone() },
            vec![],
            "w",
        );
        p.effect(
            Effect::NodeWrite { nodes: (0, 7), path: "/tmp/x".into(), data: blob.clone() },
            vec![w],
            "n",
        );
        core.submit(p);
        core.run_to_completion();
        assert!(core.pfs.read("/d/x").unwrap().same_content(&blob));
        assert!(core.nodes.read(3, "/tmp/x").unwrap().same_content(&blob));
        assert!(core.nodes.read(8, "/tmp/x").is_none());
    }

    #[test]
    fn node_writes_keep_residency_mirror_and_evict_metrics() {
        let mut core = SimCore::new();
        core.nodes.set_capacity(Some(50));
        let mut p = Plan::new(0);
        let write = |path: &str, fill: u8| Effect::NodeWrite {
            nodes: (0, 3),
            path: path.into(),
            data: Blob::real(vec![fill; 30]),
        };
        let a = p.effect(write("/tmp/a", 1), vec![], "w");
        p.effect(write("/tmp/b", 2), vec![a], "w");
        core.submit(p);
        core.run_to_completion();
        // `a` was the LRU victim admitting `b`.
        assert!(!core.nodes.exists_on(1, "/tmp/a"));
        assert!(core.nodes.exists_on(1, "/tmp/b"));
        assert_eq!(core.metrics.bytes("node.evict"), 30 * 4);
        assert_eq!(core.metrics.count("node.evictions"), 1);
        assert!(core.residency.mirrors(&core.nodes));
        assert!(core.residency.resident(2, "/tmp/b"));
        assert!(!core.residency.resident(2, "/tmp/a"));
        // Forced eviction keeps the mirror in sync too.
        core.evict_path("/tmp/b");
        assert!(core.residency.mirrors(&core.nodes));
        assert_eq!(core.residency.evicted_bytes, 30 * 4 * 2);
        assert_eq!(core.nodes.path_count(), 0);
    }

    #[test]
    fn demotions_ride_the_demote_route_and_mirror_stays_synced() {
        use crate::storage::StorageTier;
        let mut core = SimCore::new();
        let l = core.net.add_link("ssd", Capacity::Fixed(GB as f64));
        core.set_demote_route(Some(DemoteRoute { path: vec![l], cap_each: GB as f64 }));
        core.nodes.set_capacity(Some(50));
        core.nodes.set_ssd_capacity(Some(200));
        core.node_write_range(0, 3, "/tmp/a", Blob::real(vec![1; 30]));
        let out = core.node_write_range(0, 3, "/tmp/b", Blob::real(vec![2; 30]));
        match out {
            StoreWrite::Stored { evicted } => {
                assert_eq!(evicted.len(), 1);
                assert!(evicted[0].demoted, "SSD tier armed: eviction must demote");
            }
            other => panic!("expected Stored, got {other:?}"),
        }
        // The replica moved tiers in the data plane and the mirror...
        assert!(core.residency.mirrors(&core.nodes));
        assert!(core.residency.resident_tier(StorageTier::Ssd, 1, "/tmp/a"));
        assert!(!core.residency.resident(1, "/tmp/a"));
        assert_eq!(core.metrics.bytes("node.demote"), 30 * 4);
        assert_eq!(core.metrics.count("node.demotions"), 1);
        // `node.evict` keeps meaning "displaced from RAM".
        assert_eq!(core.metrics.bytes("node.evict"), 30 * 4);
        // ...and the timed transfer is a live plan over the SSD link.
        assert_eq!(core.live_plans(), 1);
        core.run_to_completion();
        assert!(core.now.secs_f64() > 0.0, "demotion must cost virtual time");
        assert_eq!(core.live_plans(), 0);
    }

    #[test]
    fn promote_effect_restores_ram_and_times_the_transfer() {
        use crate::storage::StorageTier;
        let mut core = SimCore::new();
        core.nodes.set_capacity(Some(50));
        core.nodes.set_ssd_capacity(Some(100));
        core.node_write_range(0, 1, "/tmp/a", Blob::real(vec![1; 30]));
        core.node_write_range(0, 1, "/tmp/b", Blob::real(vec![2; 30])); // a -> SSD
        assert!(!core.nodes.exists_on(0, "/tmp/a"));
        let l = core.net.add_link("ssd", Capacity::Fixed(GB as f64));
        let mut p = Plan::new(0);
        let f = p.flow(vec![l], 2, 30, vec![], "promote");
        p.effect(
            Effect::NodePromote { nodes: (0, 1), path: "/tmp/a".into() },
            vec![f],
            "promote",
        );
        core.submit(p);
        core.run_to_completion();
        assert!(core.nodes.exists_on(0, "/tmp/a"));
        assert!(core.residency.mirrors(&core.nodes));
        assert_eq!(core.metrics.bytes("node.promote"), 30 * 2);
        assert_eq!(core.metrics.count("node.promotions"), 1);
        // b was displaced in turn — demoted, not destroyed.
        assert!(core.residency.resident_tier(StorageTier::Ssd, 0, "/tmp/b"));
        assert!(core.now.secs_f64() > 0.0);
        // Promoting a path with no SSD copy is a recorded miss.
        core.promote_range(0, 1, "/tmp/nothing");
        assert_eq!(core.metrics.count("node.promote.missed"), 1);
    }

    struct Chainer {
        launched: bool,
        done_tags: Vec<u64>,
    }

    impl Director for Chainer {
        fn on_notice(&mut self, core: &mut SimCore, n: Notice) {
            match n {
                Notice::PlanDone { tag, .. } => {
                    self.done_tags.push(tag);
                    if !self.launched {
                        self.launched = true;
                        let mut p = Plan::new(99);
                        p.delay(Duration::from_secs(1), vec![], "chained");
                        core.submit(p);
                    }
                }
                Notice::Timer { tag } => self.done_tags.push(1000 + tag),
                _ => {}
            }
        }
    }

    #[test]
    fn director_chains_plans_and_timers() {
        let mut core = SimCore::new();
        let mut p = Plan::new(7);
        p.delay(Duration::from_secs(2), vec![], "first");
        core.submit(p);
        core.timer(SimTime::ZERO + Duration::from_secs(1), 42);
        let mut d = Chainer { launched: false, done_tags: vec![] };
        core.run(&mut d);
        assert_eq!(d.done_tags, vec![1042, 7, 99]);
        assert_eq!(core.now.secs_f64(), 3.0);
    }

    #[test]
    fn notify_effect_reaches_director() {
        struct Catcher(Vec<u64>);
        impl Director for Catcher {
            fn on_notice(&mut self, _c: &mut SimCore, n: Notice) {
                if let Notice::Step { tag } = n {
                    self.0.push(tag);
                }
            }
        }
        let mut core = SimCore::new();
        let mut p = Plan::new(0);
        let d = p.delay(Duration::from_secs(1), vec![], "work");
        p.effect(Effect::Notify(5), vec![d], "note");
        core.submit(p);
        let mut c = Catcher(vec![]);
        core.run(&mut c);
        assert_eq!(c.0, vec![5]);
    }

    #[test]
    fn finished_plans_release_step_storage() {
        let mut core = SimCore::new();
        for tag in 0..10 {
            let mut p = Plan::new(tag);
            let a = p.delay(Duration::from_secs(1), vec![], "a");
            p.delay(Duration::from_secs(1), vec![a], "b");
            core.submit(p);
        }
        assert_eq!(core.live_plans(), 10);
        assert_eq!(core.retained_steps(), 20);
        core.run_to_completion();
        assert_eq!(core.live_plans(), 0);
        assert_eq!(core.retained_steps(), 0);
        // Completion queries still answer after reclamation.
        assert!(core.plan_done(PlanId(3)));
    }

    #[test]
    fn fully_instantaneous_plan_completes_inside_submit() {
        // A plan of zero-duration steps cascades to completion while
        // submit() is still scanning for ready steps; the scan must
        // stop at the released storage instead of indexing it.
        let mut core = SimCore::new();
        let mut p = Plan::new(5);
        let a = p.delay(Duration::ZERO, vec![], "a");
        let b = p.delay(Duration::ZERO, vec![a], "b");
        p.delay(Duration::ZERO, vec![b], "c");
        let id = core.submit(p);
        assert!(core.plan_done(id));
        assert_eq!(core.retained_steps(), 0);
        core.run_to_completion();
        assert_eq!(core.now.secs_f64(), 0.0);
    }

    #[test]
    fn abort_plan_cancels_flows_and_stays_silent() {
        let mut core = SimCore::new();
        let l = core.net.add_link("l", Capacity::Fixed(GB as f64));
        let mut p = Plan::new(11);
        p.flow(vec![l], 1, 4 * GB, vec![], "doomed");
        let mut q = Plan::new(22);
        q.flow(vec![l], 1, GB, vec![], "survivor");
        let doomed = core.submit(p);
        core.submit(q);
        assert!(core.abort_plan(doomed));
        assert!(!core.abort_plan(doomed), "second abort must be a no-op");
        struct Tags(Vec<u64>);
        impl Director for Tags {
            fn on_notice(&mut self, _c: &mut SimCore, n: Notice) {
                if let Notice::PlanDone { tag, .. } = n {
                    self.0.push(tag);
                }
            }
        }
        let mut d = Tags(vec![]);
        core.run(&mut d);
        // Only the survivor completes — no PlanDone for the abort —
        // and with the doomed flow cancelled it gets the whole link.
        assert_eq!(d.0, vec![22]);
        assert!(core.plan_done(doomed), "aborted plan reads as settled");
        assert!((core.now.secs_f64() - 1.0).abs() < 1e-6, "{}", core.now);
        assert_eq!(core.live_plans(), 0);
        assert_eq!(core.retained_steps(), 0);
        assert_eq!(core.metrics.count("chaos.plans.aborted"), 1);
    }

    #[test]
    fn aborted_plans_stale_delay_timers_are_ignored() {
        let mut core = SimCore::new();
        let mut p = Plan::new(1);
        p.delay(Duration::from_secs(5), vec![], "work");
        let id = core.submit(p);
        let mut q = Plan::new(2);
        q.delay(Duration::from_secs(7), vec![], "other");
        core.submit(q);
        assert!(core.abort_plan(id));
        // The 5 s StepDone for the aborted plan fires mid-run and must
        // be ignored rather than indexing the released state vector.
        core.run_to_completion();
        assert_eq!(core.now.secs_f64(), 7.0);
    }

    #[test]
    fn fail_node_drops_replicas_and_mirror_follows() {
        let mut core = SimCore::new();
        core.nodes.set_capacity(Some(100));
        core.nodes.set_ssd_capacity(Some(100));
        core.node_write_range(0, 3, "/tmp/a", Blob::real(vec![1; 60]));
        core.node_write_range(0, 3, "/tmp/b", Blob::real(vec![2; 60])); // a -> SSD
        let lost = core.fail_node(2);
        // One RAM slice (b) and one SSD slice (a) died with the node.
        assert_eq!(lost.len(), 2, "{lost:?}");
        assert!(core.residency.mirrors(&core.nodes));
        assert!(!core.residency.resident(2, "/tmp/b"));
        assert!(core.residency.resident(1, "/tmp/b"));
        assert_eq!(core.metrics.count("chaos.node.failed"), 1);
        assert_eq!(core.metrics.bytes("chaos.bytes.lost"), 120);
    }

    #[test]
    fn phase_metrics_span_wall_time() {
        let mut core = SimCore::new();
        let mut p = Plan::new(0);
        p.delay(Duration::from_secs(2), vec![], "stage");
        p.delay(Duration::from_secs(3), vec![], "stage");
        core.submit(p);
        core.run_to_completion();
        assert_eq!(core.metrics.phase_span("stage").unwrap().secs_f64(), 3.0);
    }

    #[test]
    fn rate_change_mid_flight_is_honored() {
        // Flow A alone for 1 s (2 GB/s), then B joins via a timer-driven
        // director; A's completion reflects the reduced share.
        struct Joiner {
            link: crate::simtime::flownet::LinkId,
        }
        impl Director for Joiner {
            fn on_notice(&mut self, core: &mut SimCore, n: Notice) {
                if let Notice::Timer { .. } = n {
                    let mut p = Plan::new(2);
                    p.flow(vec![self.link], 1, 2 * GB, vec![], "b");
                    core.submit(p);
                }
            }
        }
        let mut core = SimCore::new();
        let l = core.net.add_link("l", Capacity::Fixed(2.0 * GB as f64));
        let mut p = Plan::new(1);
        p.flow(vec![l], 1, 4 * GB, vec![], "a");
        core.submit(p);
        core.timer(SimTime::ZERO + Duration::from_secs(1), 0);
        core.run(&mut Joiner { link: l });
        // A: 1 s at 2 GB/s (2 GB left), then shares at 1 GB/s -> 2 more
        // seconds -> A done at t=3. B: 2 GB at 1 GB/s from t=1, but after
        // A finishes at t=3 B has 0 GB left... both end at t=3.
        assert!((core.now.secs_f64() - 3.0).abs() < 1e-6, "{}", core.now);
    }
}
