//! Metadata catalog: step 4 of the Fig 7 workflow.
//!
//! The paper records transferred datasets in a metadata catalog
//! (Malik et al. [9]) so downstream HPC jobs and humans can find them.
//! This is the minimal production shape of that service: datasets with
//! typed attributes and provenance edges, queryable by attribute, with
//! deterministic iteration for reproducible reports.

use std::collections::BTreeMap;

/// Identifies a dataset record.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct DatasetId(pub u64);

/// One catalogued dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub id: DatasetId,
    pub name: String,
    /// Glob root of the files on the shared filesystem.
    pub location: String,
    pub files: u64,
    pub bytes: u64,
    /// Free-form typed attributes ("sample" -> "gold-wire", ...).
    pub attrs: BTreeMap<String, String>,
    /// Datasets this one was derived from (provenance).
    pub parents: Vec<DatasetId>,
}

/// The catalog.
#[derive(Clone, Debug, Default)]
pub struct Catalog {
    next: u64,
    datasets: BTreeMap<DatasetId, Dataset>,
}

impl Catalog {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a dataset; returns its id.
    pub fn register(
        &mut self,
        name: impl Into<String>,
        location: impl Into<String>,
        files: u64,
        bytes: u64,
    ) -> DatasetId {
        let id = DatasetId(self.next);
        self.next += 1;
        self.datasets.insert(
            id,
            Dataset {
                id,
                name: name.into(),
                location: location.into(),
                files,
                bytes,
                attrs: BTreeMap::new(),
                parents: Vec::new(),
            },
        );
        id
    }

    /// Grow a dataset that is still being written: incremental
    /// visibility for streaming ingest, where each landed frame bumps
    /// `files`/`bytes` so a session can open the dataset mid-stream
    /// and see exactly how much has arrived.
    pub fn record_growth(&mut self, id: DatasetId, files: u64, bytes: u64) {
        let d = self.datasets.get_mut(&id).expect("growth on unregistered dataset");
        d.files += files;
        d.bytes += bytes;
    }

    pub fn set_attr(&mut self, id: DatasetId, key: impl Into<String>, val: impl Into<String>) {
        if let Some(d) = self.datasets.get_mut(&id) {
            d.attrs.insert(key.into(), val.into());
        }
    }

    pub fn add_parent(&mut self, id: DatasetId, parent: DatasetId) {
        assert!(self.datasets.contains_key(&parent), "unknown parent {parent:?}");
        if let Some(d) = self.datasets.get_mut(&id) {
            d.parents.push(parent);
        }
    }

    pub fn get(&self, id: DatasetId) -> Option<&Dataset> {
        self.datasets.get(&id)
    }

    pub fn len(&self) -> usize {
        self.datasets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.datasets.is_empty()
    }

    /// All datasets with `key == val`.
    pub fn find_by_attr(&self, key: &str, val: &str) -> Vec<&Dataset> {
        self.datasets
            .values()
            .filter(|d| d.attrs.get(key).map(String::as_str) == Some(val))
            .collect()
    }

    /// Transitive provenance chain (parents-first, deduped).
    pub fn lineage(&self, id: DatasetId) -> Vec<DatasetId> {
        let mut out = Vec::new();
        let mut stack = vec![id];
        while let Some(cur) = stack.pop() {
            if out.contains(&cur) {
                continue;
            }
            out.push(cur);
            if let Some(d) = self.datasets.get(&cur) {
                stack.extend(&d.parents);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_and_query() {
        let mut c = Catalog::new();
        let raw = c.register("run7-raw", "/alcf/run7", 736, 736 << 20, );
        c.set_attr(raw, "sample", "gold-wire");
        c.set_attr(raw, "technique", "nf-hedm");
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(raw).unwrap().files, 736);
        assert_eq!(c.find_by_attr("sample", "gold-wire").len(), 1);
        assert!(c.find_by_attr("sample", "steel").is_empty());
    }

    #[test]
    fn growth_is_incremental() {
        let mut c = Catalog::new();
        let live = c.register("beamline-live", "/tmp/ingest", 0, 0);
        c.record_growth(live, 1, 64);
        c.record_growth(live, 1, 64);
        let d = c.get(live).unwrap();
        assert_eq!((d.files, d.bytes), (2, 128));
    }

    #[test]
    #[should_panic(expected = "growth on unregistered dataset")]
    fn growth_on_unknown_dataset_panics() {
        Catalog::new().record_growth(DatasetId(3), 1, 1);
    }

    #[test]
    fn provenance_chain() {
        let mut c = Catalog::new();
        let raw = c.register("raw", "/a", 10, 100);
        let reduced = c.register("reduced", "/b", 10, 20);
        let fit = c.register("microstructure", "/c", 1, 5);
        c.add_parent(reduced, raw);
        c.add_parent(fit, reduced);
        let lin = c.lineage(fit);
        assert!(lin.contains(&raw) && lin.contains(&reduced) && lin.contains(&fit));
        assert_eq!(lin.len(), 3);
    }

    #[test]
    #[should_panic(expected = "unknown parent")]
    fn bad_parent_panics() {
        let mut c = Catalog::new();
        let d = c.register("x", "/x", 1, 1);
        c.add_parent(d, DatasetId(99));
    }

    #[test]
    fn lineage_handles_diamonds() {
        let mut c = Catalog::new();
        let a = c.register("a", "/", 1, 1);
        let b1 = c.register("b1", "/", 1, 1);
        let b2 = c.register("b2", "/", 1, 1);
        let d = c.register("d", "/", 1, 1);
        c.add_parent(b1, a);
        c.add_parent(b2, a);
        c.add_parent(d, b1);
        c.add_parent(d, b2);
        assert_eq!(c.lineage(d).len(), 4);
    }
}
