//! GPFS-like shared parallel filesystem: data plane + parameters.
//!
//! The *data plane* is real: [`Blob`]s hold actual bytes (or a
//! deterministic synthetic generator for multi-GB scale datasets whose
//! content is irrelevant but whose *identity* must survive staging —
//! checksums verify that the right bytes landed on the right node).
//!
//! The *timing plane* lives in the flow network: `cluster::Topology`
//! materialises the filesystem as three links —
//!
//! - `pfs_backplane`: the installation's aggregate bandwidth. The
//!   paper's ALCF GPFS peaks at 240 GB/s (Bui et al. [4]).
//! - `pfs_disk`: a [`Degrading`](crate::simtime::flownet::Capacity::Degrading)
//!   stage traversed only by
//!   *uncoordinated* reads, modelling server-side prefetch loss and
//!   seek thrash when hundreds of thousands of independent streams hit
//!   the same stripes (the mechanism behind Fig 11's naive curve).
//!   Coordinated two-phase collective reads issue large aligned stripe
//!   requests and bypass it.
//! - `pfs_meta`: the metadata server, a link whose "bytes" are
//!   metadata operations (opens, stats, globs, readdirs). A naive
//!   implementation globbing on every rank congests this (SIV).
//!
//! [`GpfsParams`] carries the constants, calibrated in
//! EXPERIMENTS.md against the paper's measured end-points.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::units::{GB, MB};

/// File contents: real bytes or a deterministic synthetic stream.
#[derive(Clone, Debug)]
pub enum Blob {
    /// Actual bytes (science-path files: frames, reductions, results).
    Real(Arc<Vec<u8>>),
    /// Pseudo-random stream defined by (len, seed) — used for the
    /// multi-GB staging datasets so an 8,192-node experiment does not
    /// allocate terabytes. Checksummable and materialisable.
    Synthetic { len: u64, seed: u64 },
}

impl Blob {
    pub fn real(data: Vec<u8>) -> Blob {
        Blob::Real(Arc::new(data))
    }

    pub fn synthetic(len: u64, seed: u64) -> Blob {
        Blob::Synthetic { len, seed }
    }

    pub fn len(&self) -> u64 {
        match self {
            Blob::Real(d) => d.len() as u64,
            Blob::Synthetic { len, .. } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// FNV-1a-64 over the logical byte stream. Cheap identity check for
    /// "did staging deliver exactly these bytes".
    pub fn checksum(&self) -> u64 {
        const OFFSET: u64 = 0xcbf29ce484222325;
        const PRIME: u64 = 0x100000001b3;
        let mut h = OFFSET;
        match self {
            Blob::Real(d) => {
                for &b in d.iter() {
                    h ^= b as u64;
                    h = h.wrapping_mul(PRIME);
                }
            }
            Blob::Synthetic { len, seed } => {
                // Stream 8 bytes per splitmix64 step; cap work for huge
                // blobs by hashing the generator state every 64 KiB page
                // (identity-preserving and O(len/64KiB)).
                let pages = (*len + 65535) / 65536;
                let mut s = *seed;
                for p in 0..pages {
                    s = splitmix64(s ^ p);
                    h ^= s;
                    h = h.wrapping_mul(PRIME);
                }
                h ^= *len;
                h = h.wrapping_mul(PRIME);
            }
        }
        h
    }

    /// Materialise to owned bytes (tests / small synthetic files only).
    pub fn to_bytes(&self) -> Vec<u8> {
        match self {
            Blob::Real(d) => d.as_ref().clone(),
            Blob::Synthetic { len, seed } => {
                assert!(*len <= 64 * MB, "refusing to materialise {len} bytes");
                let mut out = Vec::with_capacity(*len as usize);
                let mut s = *seed;
                while (out.len() as u64) < *len {
                    s = splitmix64(s);
                    out.extend_from_slice(&s.to_le_bytes());
                }
                out.truncate(*len as usize);
                out
            }
        }
    }

    /// Identity comparison (length + checksum).
    pub fn same_content(&self, other: &Blob) -> bool {
        self.len() == other.len() && self.checksum() == other.checksum()
    }
}

fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// GPFS installation parameters (defaults: the paper's ALCF system).
#[derive(Clone, Copy, Debug)]
pub struct GpfsParams {
    /// Aggregate backplane bandwidth, bytes/s ("peak I/O performance
    /// of 240 GB/s", SVI).
    pub peak_bw: f64,
    /// Uncoordinated-read efficiency knee: no degradation below
    /// `degrade_pivot` concurrent streams.
    pub degrade_pivot: f64,
    /// Each additional `degrade_half` streams halve the excess
    /// efficiency. Calibrated so ~131K independent readers (8,192
    /// nodes x 16 ranks) deliver ~21 GB/s as measured in Fig 11.
    pub degrade_half: f64,
    /// Metadata server throughput, ops/s.
    pub meta_ops_per_sec: f64,
}

impl Default for GpfsParams {
    fn default() -> Self {
        GpfsParams {
            peak_bw: 240.0 * GB as f64,
            degrade_pivot: 6_000.0,
            degrade_half: 12_000.0,
            meta_ops_per_sec: 50_000.0,
        }
    }
}

/// The shared filesystem's namespace and contents. Deterministic
/// iteration (BTreeMap) keeps glob results and therefore simulations
/// reproducible.
#[derive(Debug, Default)]
pub struct ParallelFs {
    files: BTreeMap<String, Blob>,
}

impl ParallelFs {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn write(&mut self, path: impl Into<String>, data: Blob) {
        self.files.insert(path.into(), data);
    }

    pub fn read(&self, path: &str) -> Option<&Blob> {
        self.files.get(path)
    }

    pub fn exists(&self, path: &str) -> bool {
        self.files.contains_key(path)
    }

    pub fn delete(&mut self, path: &str) -> bool {
        self.files.remove(path).is_some()
    }

    pub fn file_count(&self) -> usize {
        self.files.len()
    }

    pub fn total_bytes(&self) -> u64 {
        self.files.values().map(Blob::len).sum()
    }

    /// Glob with `*` (any run, not crossing `/`) and `**` (any run
    /// including `/`) and `?` (one char, not `/`). Matches the subset
    /// of glob the Swift I/O hook file lists use (Fig 6).
    pub fn glob(&self, pattern: &str) -> Vec<String> {
        self.files
            .keys()
            .filter(|k| glob_match(pattern, k))
            .cloned()
            .collect()
    }

    /// Sum of sizes of all files matching `pattern`.
    pub fn glob_bytes(&self, pattern: &str) -> u64 {
        self.glob(pattern)
            .iter()
            .map(|p| self.files[p].len())
            .sum()
    }

    pub fn paths(&self) -> impl Iterator<Item = &String> {
        self.files.keys()
    }
}

/// Simple glob matcher: `*` (within a path segment), `**` (across
/// segments), `?` (single non-`/` char). Backtracking, no allocation.
pub fn glob_match(pattern: &str, text: &str) -> bool {
    fn inner(p: &[u8], t: &[u8]) -> bool {
        if p.is_empty() {
            return t.is_empty();
        }
        match p[0] {
            b'*' => {
                // "**" crosses '/', "*" does not.
                let crossing = p.len() > 1 && p[1] == b'*';
                let rest = if crossing { &p[2..] } else { &p[1..] };
                let mut i = 0;
                loop {
                    if inner(rest, &t[i..]) {
                        return true;
                    }
                    if i >= t.len() || (!crossing && t[i] == b'/') {
                        return false;
                    }
                    i += 1;
                }
            }
            b'?' => !t.is_empty() && t[0] != b'/' && inner(&p[1..], &t[1..]),
            c => !t.is_empty() && t[0] == c && inner(&p[1..], &t[1..]),
        }
    }
    inner(pattern.as_bytes(), text.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blob_real_roundtrip() {
        let b = Blob::real(vec![1, 2, 3, 4]);
        assert_eq!(b.len(), 4);
        assert_eq!(b.to_bytes(), vec![1, 2, 3, 4]);
        assert!(b.same_content(&Blob::real(vec![1, 2, 3, 4])));
        assert!(!b.same_content(&Blob::real(vec![1, 2, 3, 5])));
        assert!(!b.same_content(&Blob::real(vec![1, 2, 3])));
    }

    #[test]
    fn blob_synthetic_deterministic() {
        let a = Blob::synthetic(1 << 20, 42);
        let b = Blob::synthetic(1 << 20, 42);
        let c = Blob::synthetic(1 << 20, 43);
        assert_eq!(a.checksum(), b.checksum());
        assert_ne!(a.checksum(), c.checksum());
        assert_eq!(a.to_bytes(), b.to_bytes());
        assert_eq!(a.to_bytes().len(), 1 << 20);
    }

    #[test]
    fn blob_synthetic_huge_checksum_is_cheap() {
        // 2 TB: checksum must not materialise.
        let b = Blob::synthetic(2_000 * GB, 7);
        let _ = b.checksum();
        assert_eq!(b.len(), 2_000 * GB);
    }

    #[test]
    fn fs_write_read_delete() {
        let mut fs = ParallelFs::new();
        fs.write("/data/a.tif", Blob::real(vec![0; 100]));
        assert!(fs.exists("/data/a.tif"));
        assert_eq!(fs.read("/data/a.tif").unwrap().len(), 100);
        assert_eq!(fs.total_bytes(), 100);
        assert!(fs.delete("/data/a.tif"));
        assert!(!fs.exists("/data/a.tif"));
        assert!(!fs.delete("/data/a.tif"));
    }

    #[test]
    fn glob_basics() {
        assert!(glob_match("*.tif", "frame.tif"));
        assert!(!glob_match("*.tif", "frame.bin"));
        assert!(glob_match("data/??.bin", "data/01.bin"));
        assert!(!glob_match("data/??.bin", "data/001.bin"));
        assert!(glob_match("a*c", "abc"));
        assert!(glob_match("a*c", "ac"));
        assert!(!glob_match("a*c", "ab"));
    }

    #[test]
    fn glob_does_not_cross_slash() {
        assert!(!glob_match("data/*.tif", "data/sub/frame.tif"));
        assert!(glob_match("data/**.tif", "data/sub/frame.tif"));
        assert!(glob_match("**", "any/depth/of/path"));
    }

    #[test]
    fn fs_glob_deterministic_order() {
        let mut fs = ParallelFs::new();
        for i in [3, 1, 2] {
            fs.write(format!("/d/f{i}.bin"), Blob::real(vec![0; i]));
        }
        let hits = fs.glob("/d/f*.bin");
        assert_eq!(hits, vec!["/d/f1.bin", "/d/f2.bin", "/d/f3.bin"]);
        assert_eq!(fs.glob_bytes("/d/f*.bin"), 6);
    }

    #[test]
    fn gpfs_defaults_match_paper() {
        let p = GpfsParams::default();
        assert_eq!(p.peak_bw, 240.0 * GB as f64);
        // 8,192 nodes x 16 ranks of independent readers -> ~21 GB/s.
        let streams = 8192.0 * 16.0;
        let eff = crate::simtime::flownet::Capacity::Degrading {
            peak: p.peak_bw,
            pivot: p.degrade_pivot,
            half: p.degrade_half,
        }
        .effective(streams);
        assert!((eff - 21.0 * GB as f64).abs() < 1.0 * GB as f64, "{eff}");
    }
}
