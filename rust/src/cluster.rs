//! Machine models: the ALCF Blue Gene/Q systems and the APS Orthros
//! cluster, plus the node-local storage data plane.
//!
//! A [`MachineSpec`] carries the published hardware constants; a
//! [`Topology`] materialises the machine's bandwidth structure as
//! flow-network links. Aggregation note: symmetric layers made of `g`
//! identical links with uniformly spread load are modelled as one link
//! of capacity `g x link_bw` — exact for fair-shared symmetric bundles
//! and what keeps recomputation O(1) in machine size.
//!
//! BG/Q specifics that shape the paper's results:
//!
//! - Compute nodes have **no direct filesystem access**; all I/O
//!   forwards over per-I/O-node uplinks (1 ION per 128 compute nodes
//!   on Mira). The `/tmp` RAM disk itself "is actually an I/O node
//!   service" (SVI-B), so *writing staged data to /tmp* also rides the
//!   ION uplink — this is why Staging+Write tops out at 134 GB/s on
//!   8,192 nodes (64 IONs x ~2.1 GB/s).
//! - The 5D torus gives every node a ~1.8 GB/s usable injection rate;
//!   collective broadcast is effectively pipelined and never the
//!   staging bottleneck.
//! - Reading staged data back from /tmp was measured at a flat
//!   53.4 MB/s per process (10.8 +/- 0.1 s for 577 MB) independent of
//!   allocation size; we model it as a per-process rate cap.

use crate::pfs::{Blob, GpfsParams};
use crate::simtime::flownet::{Capacity, FlowNet, LinkClass, LinkId};
use crate::units::{GB, MB};

/// Hardware description of one machine.
#[derive(Clone, Copy, Debug)]
pub struct MachineSpec {
    pub name: &'static str,
    /// Compute nodes in the allocation.
    pub nodes: u32,
    /// Physical cores per node (BG/Q A2: 16; Orthros AMD: 64).
    pub cores_per_node: u32,
    /// Hardware threads per core (BG/Q: 4).
    pub threads_per_core: u32,
    /// Worker ranks per node the many-task runtime schedules.
    pub ranks_per_node: u32,
    /// Compute nodes served by one I/O node (0 = direct-attached FS).
    pub nodes_per_ion: u32,
    /// Per-ION uplink bandwidth, bytes/s.
    pub ion_bw: f64,
    /// Per-node torus injection bandwidth, bytes/s.
    pub torus_link_bw: f64,
    /// Per-process read bandwidth from node-local storage, bytes/s.
    pub ramdisk_proc_read_bw: f64,
    /// Node-local writes traverse the ION uplink (BG/Q /tmp semantics).
    pub local_write_via_ion: bool,
}

impl MachineSpec {
    pub fn total_cores(&self) -> u64 {
        self.nodes as u64 * self.cores_per_node as u64
    }

    pub fn total_ranks(&self) -> u64 {
        self.nodes as u64 * self.ranks_per_node as u64
    }

    pub fn hw_threads(&self) -> u64 {
        self.total_cores() * self.threads_per_core as u64
    }

    /// I/O nodes serving this allocation (at least one).
    pub fn n_ions(&self) -> u32 {
        if self.nodes_per_ion == 0 {
            0
        } else {
            self.nodes.div_ceil(self.nodes_per_ion).max(1)
        }
    }
}

/// ALCF BG/Q (Mira/Cetus class) allocation of `nodes` nodes.
///
/// Constants: 16 PowerPC A2 cores @ 1.6 GHz / 64 HW threads per node
/// (SVI); 128 nodes per ION with ~2.1 GB/s usable uplink (calibrated
/// against Fig 10's 134 GB/s at 8,192 nodes = 64 IONs); 1.8 GB/s torus
/// injection; 53.4 MB/s per-process /tmp read (SVI-B).
pub fn bgq(nodes: u32) -> MachineSpec {
    MachineSpec {
        name: "bgq",
        nodes,
        cores_per_node: 16,
        threads_per_core: 4,
        ranks_per_node: 16,
        nodes_per_ion: 128,
        ion_bw: 2.1 * GB as f64,
        torus_link_bw: 1.8 * GB as f64,
        ramdisk_proc_read_bw: 53.4 * MB as f64,
        local_write_via_ion: true,
    }
}

/// The APS sector-1 Orthros cluster: "a 320-core x86 cluster...
/// an Orthros node has 64 AMD cores running at 2.2 GHz" (SVI). Five
/// fat nodes, direct-attached NFS (modelled as a 1.25 GB/s backplane
/// via `GpfsParams` overrides in the experiment drivers), local disks.
pub fn orthros() -> MachineSpec {
    MachineSpec {
        name: "orthros",
        nodes: 5,
        cores_per_node: 64,
        threads_per_core: 1,
        ranks_per_node: 64,
        nodes_per_ion: 0, // direct-attached
        ion_bw: 0.0,
        torus_link_bw: 1.25 * GB as f64, // 10 GbE
        ramdisk_proc_read_bw: 500.0 * MB as f64,
        local_write_via_ion: false,
    }
}

/// The machine's bandwidth structure materialised as flownet links.
#[derive(Clone, Debug)]
pub struct Topology {
    pub spec: MachineSpec,
    pub gpfs: GpfsParams,
    /// Filesystem aggregate backplane (240 GB/s class).
    pub pfs_backplane: LinkId,
    /// Degrading server-side stage traversed by uncoordinated reads.
    pub pfs_disk: LinkId,
    /// Metadata server ("bytes" = metadata operations).
    pub pfs_meta: LinkId,
    /// Aggregated ION uplink layer (None for direct-attached machines).
    pub ion_layer: Option<LinkId>,
    /// Aggregated torus/interconnect bisection.
    pub torus: LinkId,
}

impl Topology {
    /// Create links for `spec` + `gpfs` in `net`. Each link declares
    /// its machine layer ([`LinkClass`]) at construction, so the flow
    /// network's component tracking and contention diagnostics can
    /// attribute load without string-matching names.
    pub fn build(spec: MachineSpec, gpfs: GpfsParams, net: &mut FlowNet) -> Topology {
        let pfs_backplane = net.add_link_classed(
            "pfs.backplane",
            Capacity::Fixed(gpfs.peak_bw),
            LinkClass::Backplane,
        );
        let pfs_disk = net.add_link_classed(
            "pfs.disk",
            Capacity::Degrading {
                peak: gpfs.peak_bw,
                pivot: gpfs.degrade_pivot,
                half: gpfs.degrade_half,
            },
            LinkClass::Disk,
        );
        let pfs_meta = net.add_link_classed(
            "pfs.meta",
            Capacity::Fixed(gpfs.meta_ops_per_sec),
            LinkClass::Meta,
        );
        let ion_layer = if spec.nodes_per_ion > 0 {
            Some(net.add_link_classed(
                "ion.layer",
                Capacity::Fixed(spec.n_ions() as f64 * spec.ion_bw),
                LinkClass::Ion,
            ))
        } else {
            None
        };
        let torus = net.add_link_classed(
            "torus.bisection",
            Capacity::Fixed(spec.nodes as f64 * spec.torus_link_bw),
            LinkClass::Interconnect,
        );
        Topology { spec, gpfs, pfs_backplane, pfs_disk, pfs_meta, ion_layer, torus }
    }

    /// Path of a *coordinated* (collective, large-aligned) GPFS read
    /// landing on compute nodes: backplane + ION layer.
    pub fn path_coordinated_read(&self) -> Vec<LinkId> {
        let mut p = vec![self.pfs_backplane];
        p.extend(self.ion_layer);
        p
    }

    /// Path of an *uncoordinated* per-rank GPFS read: adds the
    /// degrading disk stage.
    pub fn path_uncoordinated_read(&self) -> Vec<LinkId> {
        let mut p = vec![self.pfs_disk, self.pfs_backplane];
        p.extend(self.ion_layer);
        p
    }

    /// Path of a node-local RAM-disk write (BG/Q: via ION; clusters:
    /// genuinely local, pathless).
    pub fn path_local_write(&self) -> Vec<LinkId> {
        if self.spec.local_write_via_ion {
            self.ion_layer.into_iter().collect()
        } else {
            vec![]
        }
    }

    /// Path of metadata operations.
    pub fn path_meta(&self) -> Vec<LinkId> {
        vec![self.pfs_meta]
    }

    /// Path of interconnect traffic (broadcast / redistribution).
    pub fn path_torus(&self) -> Vec<LinkId> {
        vec![self.torus]
    }
}

/// Node-local storage data plane ("/tmp" on every node).
///
/// Replicas are stored once per *node range* (the staging hook writes
/// the same blob to every node), so memory is O(files), not
/// O(files x nodes), while per-node reads still verify membership and
/// return the actual bytes.
#[derive(Debug, Default)]
pub struct NodeStores {
    /// path -> newest-first list of (node_lo, node_hi, blob).
    entries: std::collections::HashMap<String, Vec<(u32, u32, Blob)>>,
}

impl NodeStores {
    pub fn new() -> Self {
        Self::default()
    }

    /// Write `data` at `path` on every node in `lo..=hi`.
    pub fn write_range(&mut self, lo: u32, hi: u32, path: impl Into<String>, data: Blob) {
        assert!(lo <= hi, "bad node range");
        self.entries.entry(path.into()).or_default().insert(0, (lo, hi, data));
    }

    /// Write on a single node.
    pub fn write(&mut self, node: u32, path: impl Into<String>, data: Blob) {
        self.write_range(node, node, path, data);
    }

    /// Read `path` as seen by `node` (newest replica covering it).
    pub fn read(&self, node: u32, path: &str) -> Option<&Blob> {
        self.entries.get(path)?.iter().find_map(|(lo, hi, b)| {
            if (*lo..=*hi).contains(&node) {
                Some(b)
            } else {
                None
            }
        })
    }

    pub fn exists_on(&self, node: u32, path: &str) -> bool {
        self.read(node, path).is_some()
    }

    /// Bytes resident on one node.
    pub fn bytes_on(&self, node: u32) -> u64 {
        self.entries
            .values()
            .map(|v| {
                v.iter()
                    .find(|(lo, hi, _)| (*lo..=*hi).contains(&node))
                    .map(|(_, _, b)| b.len())
                    .unwrap_or(0)
            })
            .sum()
    }

    /// Number of distinct paths stored anywhere.
    pub fn path_count(&self) -> usize {
        self.entries.len()
    }

    /// Paths visible to `node`, sorted (deterministic enumeration for
    /// the gather collective's local directory listing).
    pub fn paths_on(&self, node: u32) -> Vec<String> {
        let mut out: Vec<String> = self
            .entries
            .iter()
            .filter(|(_, v)| v.iter().any(|(lo, hi, _)| (*lo..=*hi).contains(&node)))
            .map(|(k, _)| k.clone())
            .collect();
        out.sort();
        out
    }

    pub fn clear(&mut self) {
        self.entries.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bgq_spec_constants() {
        let m = bgq(8192);
        assert_eq!(m.total_cores(), 131_072);
        assert_eq!(m.hw_threads(), 524_288); // paper: "524,288 hardware threads"
        assert_eq!(m.n_ions(), 64);
        assert_eq!(m.total_ranks(), 131_072);
    }

    #[test]
    fn small_bgq_has_one_ion() {
        assert_eq!(bgq(64).n_ions(), 1);
        assert_eq!(bgq(129).n_ions(), 2);
    }

    #[test]
    fn orthros_spec() {
        let m = orthros();
        assert_eq!(m.total_cores(), 320); // paper: "320-core x86 cluster"
        assert_eq!(m.n_ions(), 0);
    }

    #[test]
    fn topology_paths() {
        let mut net = FlowNet::new();
        let t = Topology::build(bgq(512), GpfsParams::default(), &mut net);
        assert_eq!(t.path_coordinated_read().len(), 2);
        assert_eq!(t.path_uncoordinated_read().len(), 3);
        assert_eq!(t.path_local_write().len(), 1); // via ION
        assert_eq!(t.path_meta().len(), 1);
    }

    #[test]
    fn orthros_local_write_is_pathless() {
        let mut net = FlowNet::new();
        let t = Topology::build(orthros(), GpfsParams::default(), &mut net);
        assert!(t.path_local_write().is_empty());
        assert_eq!(t.path_coordinated_read().len(), 1);
    }

    #[test]
    fn ion_layer_capacity_scales_with_allocation() {
        let mut net = FlowNet::new();
        let t8k = Topology::build(bgq(8192), GpfsParams::default(), &mut net);
        let f = net.start(vec![t8k.ion_layer.unwrap()], 1, GB);
        net.recompute();
        // 64 IONs x 2.1 GB/s = 134.4 GB/s — the Fig 10 ceiling.
        assert!((net.rate_each(f) - 134.4 * GB as f64).abs() < 0.1 * GB as f64);
    }

    #[test]
    fn node_store_replicas() {
        let mut ns = NodeStores::new();
        let blob = Blob::real(vec![9; 64]);
        ns.write_range(0, 511, "/tmp/param.txt", blob.clone());
        assert!(ns.exists_on(0, "/tmp/param.txt"));
        assert!(ns.exists_on(511, "/tmp/param.txt"));
        assert!(!ns.exists_on(512, "/tmp/param.txt"));
        assert!(ns.read(100, "/tmp/param.txt").unwrap().same_content(&blob));
        assert_eq!(ns.bytes_on(77), 64);
        assert_eq!(ns.bytes_on(1000), 0);
        assert_eq!(ns.path_count(), 1);
    }

    #[test]
    fn node_store_newest_wins() {
        let mut ns = NodeStores::new();
        ns.write_range(0, 10, "/tmp/x", Blob::real(vec![1]));
        ns.write(5, "/tmp/x", Blob::real(vec![2, 2]));
        assert_eq!(ns.read(5, "/tmp/x").unwrap().len(), 2);
        assert_eq!(ns.read(4, "/tmp/x").unwrap().len(), 1);
    }
}
