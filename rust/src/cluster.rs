//! Machine models: the ALCF Blue Gene/Q systems and the APS Orthros
//! cluster, plus the node-local storage data plane.
//!
//! A [`MachineSpec`] carries the published hardware constants; a
//! [`Topology`] materialises the machine's bandwidth structure as
//! flow-network links. Aggregation note: symmetric layers made of `g`
//! identical links with uniformly spread load are modelled as one link
//! of capacity `g x link_bw` — exact for fair-shared symmetric bundles
//! and what keeps recomputation O(1) in machine size.
//!
//! BG/Q specifics that shape the paper's results:
//!
//! - Compute nodes have **no direct filesystem access**; all I/O
//!   forwards over per-I/O-node uplinks (1 ION per 128 compute nodes
//!   on Mira). The `/tmp` RAM disk itself "is actually an I/O node
//!   service" (SVI-B), so *writing staged data to /tmp* also rides the
//!   ION uplink — this is why Staging+Write tops out at 134 GB/s on
//!   8,192 nodes (64 IONs x ~2.1 GB/s).
//! - The 5D torus gives every node a ~1.8 GB/s usable injection rate;
//!   collective broadcast is effectively pipelined and never the
//!   staging bottleneck.
//! - Reading staged data back from /tmp was measured at a flat
//!   53.4 MB/s per process (10.8 +/- 0.1 s for 577 MB) independent of
//!   allocation size; we model it as a per-process rate cap.

use crate::pfs::{Blob, GpfsParams};
use crate::simtime::flownet::{Capacity, FlowNet, LinkClass, LinkId};
use crate::units::{GB, MB};

/// Hardware description of one machine.
#[derive(Clone, Copy, Debug)]
pub struct MachineSpec {
    pub name: &'static str,
    /// Compute nodes in the allocation.
    pub nodes: u32,
    /// Physical cores per node (BG/Q A2: 16; Orthros AMD: 64).
    pub cores_per_node: u32,
    /// Hardware threads per core (BG/Q: 4).
    pub threads_per_core: u32,
    /// Worker ranks per node the many-task runtime schedules.
    pub ranks_per_node: u32,
    /// Compute nodes served by one I/O node (0 = direct-attached FS).
    pub nodes_per_ion: u32,
    /// Per-ION uplink bandwidth, bytes/s.
    pub ion_bw: f64,
    /// Per-node torus injection bandwidth, bytes/s.
    pub torus_link_bw: f64,
    /// Per-process read bandwidth from node-local storage, bytes/s.
    pub ramdisk_proc_read_bw: f64,
    /// Node-local writes traverse the ION uplink (BG/Q /tmp semantics).
    pub local_write_via_ion: bool,
    /// Per-node RAM-disk capacity in bytes (0 = not modelled). The
    /// staging regime the paper describes — data "cached in compute
    /// node memory for extended periods" — only has failure modes once
    /// this is finite; experiments apply it with
    /// [`NodeStores::set_capacity`].
    pub ramdisk_capacity: u64,
}

impl MachineSpec {
    pub fn total_cores(&self) -> u64 {
        self.nodes as u64 * self.cores_per_node as u64
    }

    pub fn total_ranks(&self) -> u64 {
        self.nodes as u64 * self.ranks_per_node as u64
    }

    pub fn hw_threads(&self) -> u64 {
        self.total_cores() * self.threads_per_core as u64
    }

    /// The RAM-disk byte budget per node, if modelled.
    pub fn ramdisk_cap(&self) -> Option<u64> {
        if self.ramdisk_capacity == 0 {
            None
        } else {
            Some(self.ramdisk_capacity)
        }
    }

    /// I/O nodes serving this allocation (at least one).
    pub fn n_ions(&self) -> u32 {
        if self.nodes_per_ion == 0 {
            0
        } else {
            self.nodes.div_ceil(self.nodes_per_ion).max(1)
        }
    }
}

/// ALCF BG/Q (Mira/Cetus class) allocation of `nodes` nodes.
///
/// Constants: 16 PowerPC A2 cores @ 1.6 GHz / 64 HW threads per node
/// (SVI); 128 nodes per ION with ~2.1 GB/s usable uplink (calibrated
/// against Fig 10's 134 GB/s at 8,192 nodes = 64 IONs); 1.8 GB/s torus
/// injection; 53.4 MB/s per-process /tmp read (SVI-B).
pub fn bgq(nodes: u32) -> MachineSpec {
    MachineSpec {
        name: "bgq",
        nodes,
        cores_per_node: 16,
        threads_per_core: 4,
        ranks_per_node: 16,
        nodes_per_ion: 128,
        ion_bw: 2.1 * GB as f64,
        torus_link_bw: 1.8 * GB as f64,
        ramdisk_proc_read_bw: 53.4 * MB as f64,
        local_write_via_ion: true,
        // BG/Q nodes carry 16 GB; /tmp must share it with the
        // application image, so roughly half is usable for staging.
        ramdisk_capacity: 8 * GB,
    }
}

/// The APS sector-1 Orthros cluster: "a 320-core x86 cluster...
/// an Orthros node has 64 AMD cores running at 2.2 GHz" (SVI). Five
/// fat nodes, direct-attached NFS (modelled as a 1.25 GB/s backplane
/// via `GpfsParams` overrides in the experiment drivers), local disks.
pub fn orthros() -> MachineSpec {
    MachineSpec {
        name: "orthros",
        nodes: 5,
        cores_per_node: 64,
        threads_per_core: 1,
        ranks_per_node: 64,
        nodes_per_ion: 0, // direct-attached
        ion_bw: 0.0,
        torus_link_bw: 1.25 * GB as f64, // 10 GbE
        ramdisk_proc_read_bw: 500.0 * MB as f64,
        local_write_via_ion: false,
        // Fat nodes with local disks: a generous staging budget.
        ramdisk_capacity: 256 * GB,
    }
}

/// The machine's bandwidth structure materialised as flownet links.
#[derive(Clone, Debug)]
pub struct Topology {
    pub spec: MachineSpec,
    pub gpfs: GpfsParams,
    /// Filesystem aggregate backplane (240 GB/s class).
    pub pfs_backplane: LinkId,
    /// Degrading server-side stage traversed by uncoordinated reads.
    pub pfs_disk: LinkId,
    /// Metadata server ("bytes" = metadata operations).
    pub pfs_meta: LinkId,
    /// Aggregated ION uplink layer (None for direct-attached machines).
    pub ion_layer: Option<LinkId>,
    /// Aggregated torus/interconnect bisection.
    pub torus: LinkId,
}

impl Topology {
    /// Create links for `spec` + `gpfs` in `net`. Each link declares
    /// its machine layer ([`LinkClass`]) at construction, so the flow
    /// network's component tracking and contention diagnostics can
    /// attribute load without string-matching names.
    pub fn build(spec: MachineSpec, gpfs: GpfsParams, net: &mut FlowNet) -> Topology {
        let pfs_backplane = net.add_link_classed(
            "pfs.backplane",
            Capacity::Fixed(gpfs.peak_bw),
            LinkClass::Backplane,
        );
        let pfs_disk = net.add_link_classed(
            "pfs.disk",
            Capacity::Degrading {
                peak: gpfs.peak_bw,
                pivot: gpfs.degrade_pivot,
                half: gpfs.degrade_half,
            },
            LinkClass::Disk,
        );
        let pfs_meta = net.add_link_classed(
            "pfs.meta",
            Capacity::Fixed(gpfs.meta_ops_per_sec),
            LinkClass::Meta,
        );
        let ion_layer = if spec.nodes_per_ion > 0 {
            Some(net.add_link_classed(
                "ion.layer",
                Capacity::Fixed(spec.n_ions() as f64 * spec.ion_bw),
                LinkClass::Ion,
            ))
        } else {
            None
        };
        let torus = net.add_link_classed(
            "torus.bisection",
            Capacity::Fixed(spec.nodes as f64 * spec.torus_link_bw),
            LinkClass::Interconnect,
        );
        Topology { spec, gpfs, pfs_backplane, pfs_disk, pfs_meta, ion_layer, torus }
    }

    /// Path of a *coordinated* (collective, large-aligned) GPFS read
    /// landing on compute nodes: backplane + ION layer.
    pub fn path_coordinated_read(&self) -> Vec<LinkId> {
        let mut p = vec![self.pfs_backplane];
        p.extend(self.ion_layer);
        p
    }

    /// Path of an *uncoordinated* per-rank GPFS read: adds the
    /// degrading disk stage.
    pub fn path_uncoordinated_read(&self) -> Vec<LinkId> {
        let mut p = vec![self.pfs_disk, self.pfs_backplane];
        p.extend(self.ion_layer);
        p
    }

    /// Path of a node-local RAM-disk write (BG/Q: via ION; clusters:
    /// genuinely local, pathless).
    pub fn path_local_write(&self) -> Vec<LinkId> {
        if self.spec.local_write_via_ion {
            self.ion_layer.into_iter().collect()
        } else {
            vec![]
        }
    }

    /// Path of metadata operations.
    pub fn path_meta(&self) -> Vec<LinkId> {
        vec![self.pfs_meta]
    }

    /// Path of interconnect traffic (broadcast / redistribution).
    pub fn path_torus(&self) -> Vec<LinkId> {
        vec![self.torus]
    }

    /// Apply this machine's node-local storage budget
    /// ([`MachineSpec::ramdisk_capacity`]) to the data plane. The
    /// experiment harnesses call this right after `Topology::build`;
    /// scenarios that want tighter pressure may override with
    /// [`NodeStores::set_capacity`] afterwards.
    pub fn apply_ramdisk_budget(&self, nodes: &mut NodeStores) {
        nodes.set_capacity(self.spec.ramdisk_cap());
    }
}

/// Bookkeeping mirror of [`NodeStores`]: which paths are resident on
/// which node ranges, plus eviction telemetry. `engine::SimCore` owns
/// one and keeps it exactly in sync with every engine-applied node
/// write (`SimCore::node_write_range`) and eviction
/// (`SimCore::evict_path`), so experiments can report hit rates and
/// evicted bytes without rescanning the data plane.
#[derive(Clone, Debug, Default)]
pub struct ResidencyTable {
    /// path -> disjoint, sorted, coalesced node ranges.
    by_path: std::collections::BTreeMap<String, Vec<(u32, u32)>>,
    /// Replicas evicted under capacity pressure (count).
    pub evictions: u64,
    /// Total bytes freed by evictions (per-node bytes x node span).
    pub evicted_bytes: u64,
}

impl ResidencyTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a stored write of `path` on `lo..=hi` that evicted
    /// `evicted` first.
    pub fn on_stored(&mut self, lo: u32, hi: u32, path: &str, evicted: &[Eviction]) {
        self.on_evicted(evicted);
        add_range(self.by_path.entry(path.to_string()).or_default(), lo, hi);
    }

    /// Record evictions (capacity pressure or forced).
    pub fn on_evicted(&mut self, evicted: &[Eviction]) {
        for ev in evicted {
            self.evictions += 1;
            self.evicted_bytes += ev.bytes * (ev.hi - ev.lo + 1) as u64;
            if let Some(ranges) = self.by_path.get_mut(&ev.path) {
                sub_range(ranges, ev.lo, ev.hi);
                if ranges.is_empty() {
                    self.by_path.remove(&ev.path);
                }
            }
        }
    }

    /// True when `path` is resident on `node`.
    pub fn resident(&self, node: u32, path: &str) -> bool {
        self.by_path
            .get(path)
            .is_some_and(|rs| rs.iter().any(|&(a, b)| (a..=b).contains(&node)))
    }

    /// Resident node ranges of `path` (sorted, coalesced).
    pub fn coverage(&self, path: &str) -> &[(u32, u32)] {
        self.by_path.get(path).map(Vec::as_slice).unwrap_or(&[])
    }

    /// All resident paths, sorted.
    pub fn resident_paths(&self) -> impl Iterator<Item = &String> {
        self.by_path.keys()
    }

    /// Exact-mirror check against the data plane: the table and the
    /// store must agree on every path's resident node set.
    pub fn mirrors(&self, stores: &NodeStores) -> bool {
        let mut want: std::collections::BTreeMap<String, Vec<(u32, u32)>> =
            std::collections::BTreeMap::new();
        for (path, reps) in stores.dump() {
            let ranges = want.entry(path).or_default();
            for (lo, hi, _) in reps {
                add_range(ranges, lo, hi);
            }
        }
        want == self.by_path
    }
}

/// Merge `[lo, hi]` into a sorted, disjoint, coalesced range set.
fn add_range(ranges: &mut Vec<(u32, u32)>, lo: u32, hi: u32) {
    ranges.push((lo, hi));
    ranges.sort_unstable();
    let mut out: Vec<(u32, u32)> = Vec::with_capacity(ranges.len());
    for &(a, b) in ranges.iter() {
        match out.last_mut() {
            Some((_, pb)) if a <= pb.saturating_add(1) => *pb = (*pb).max(b),
            _ => out.push((a, b)),
        }
    }
    *ranges = out;
}

/// Remove `[lo, hi]` from a sorted, disjoint range set.
fn sub_range(ranges: &mut Vec<(u32, u32)>, lo: u32, hi: u32) {
    let mut out: Vec<(u32, u32)> = Vec::with_capacity(ranges.len() + 1);
    for &(a, b) in ranges.iter() {
        if b < lo || a > hi {
            out.push((a, b));
            continue;
        }
        if a < lo {
            out.push((a, lo - 1));
        }
        if b > hi {
            out.push((hi + 1, b));
        }
    }
    *ranges = out;
}

/// A replica removed from a node range to make room for a write (or by
/// a forced [`NodeStores::evict_path`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Eviction {
    pub path: String,
    pub lo: u32,
    pub hi: u32,
    /// Per-node bytes the eviction freed.
    pub bytes: u64,
}

/// Outcome of a capacity-checked node-local write.
#[derive(Clone, Debug)]
pub enum StoreWrite {
    /// Replica stored on every node of the range; `evicted` lists the
    /// LRU victims removed to make room, in eviction order.
    Stored { evicted: Vec<Eviction> },
    /// Write refused and the store left untouched: even after evicting
    /// every unpinned replica, some node of the range would still be
    /// `short_bytes` over capacity.
    Rejected { short_bytes: u64 },
}

/// One path's replicas in a [`NodeStores::dump`] snapshot:
/// (lo, hi, per-node bytes) per replica.
pub type ReplicaSnapshot = Vec<(u32, u32, u64)>;

/// One resident replica: `blob` present on every node in `lo..=hi`.
#[derive(Clone, Debug)]
struct Replica {
    lo: u32,
    hi: u32,
    blob: Blob,
    /// LRU clock value of the last write or touch.
    last_use: u64,
    /// Monotone insertion sequence (deterministic LRU tie-break;
    /// residuals of a split replica keep their original seq).
    seq: u64,
}

impl Replica {
    fn covers(&self, node: u32) -> bool {
        (self.lo..=self.hi).contains(&node)
    }

    fn overlaps(&self, lo: u32, hi: u32) -> bool {
        self.lo <= hi && self.hi >= lo
    }
}

/// Node-local storage data plane ("/tmp" on every node), with the
/// residency semantics of a real RAM disk:
///
/// - Replicas are stored once per *node range* (the staging hook
///   writes the same blob to every node), so memory is O(files), not
///   O(files x nodes). Replicas of one path are node-disjoint: a write
///   replaces the overlapped portion of any older same-path replica.
/// - An optional uniform per-node **capacity** is enforced on every
///   write: least-recently-used unpinned replicas of other paths
///   covering a still-over-budget node of the write range are evicted
///   (whole replicas, LRU order, ties broken by insertion sequence
///   then path/lo order) until the write fits on every node of its
///   range. An infeasible write — pinned residents alone exceed the
///   budget — is rejected with the store untouched.
/// - **Pinned** paths are never evicted (the dataset a campaign is
///   actively computing on).
///
/// Enumeration is deterministic (BTreeMap): glob results, transfer
/// lists, and LRU victim order are reproducible across runs.
#[derive(Debug, Default)]
pub struct NodeStores {
    /// path -> node-disjoint replicas, sorted by `lo`.
    entries: std::collections::BTreeMap<String, Vec<Replica>>,
    /// Paths exempt from eviction, refcounted: several owners (e.g.
    /// two datasets delivering the same node-local path) may hold a
    /// pin independently and the path stays protected until every one
    /// releases it.
    pinned: std::collections::BTreeMap<String, u32>,
    /// Uniform per-node byte budget; None = unbounded.
    capacity: Option<u64>,
    /// Resident bytes per node (only nodes holding data appear).
    used: std::collections::BTreeMap<u32, u64>,
    /// LRU clock, bumped by writes and touches.
    clock: u64,
    /// Insertion sequence counter.
    seq: u64,
}

impl NodeStores {
    pub fn new() -> Self {
        Self::default()
    }

    /// Set or clear the uniform per-node capacity. Enforced on
    /// subsequent writes; existing contents are left as they are.
    pub fn set_capacity(&mut self, cap: Option<u64>) {
        self.capacity = cap;
    }

    pub fn capacity(&self) -> Option<u64> {
        self.capacity
    }

    /// Exempt `path` from eviction until a matching
    /// [`NodeStores::unpin`]. Refcounted: pin twice, unpin twice.
    pub fn pin(&mut self, path: impl Into<String>) {
        *self.pinned.entry(path.into()).or_insert(0) += 1;
    }

    /// Release one pin of `path` (no-op when not pinned).
    pub fn unpin(&mut self, path: &str) {
        if let Some(n) = self.pinned.get_mut(path) {
            *n -= 1;
            if *n == 0 {
                self.pinned.remove(path);
            }
        }
    }

    pub fn is_pinned(&self, path: &str) -> bool {
        self.pinned.contains_key(path)
    }

    /// Refresh the LRU clock of the replica covering (`node`, `path`).
    /// No-op when nothing covers it (the clock still advances).
    pub fn touch(&mut self, node: u32, path: &str) {
        self.clock += 1;
        let now = self.clock;
        if let Some(reps) = self.entries.get_mut(path) {
            if let Some(r) = reps.iter_mut().find(|r| r.covers(node)) {
                r.last_use = now;
            }
        }
    }

    /// Refresh the LRU clock of *every* replica of `path` overlapping
    /// `lo..=hi` (one clock bump shared by all). A range-wide hit must
    /// not leave split replicas of the reused path LRU-stale.
    pub fn touch_range(&mut self, lo: u32, hi: u32, path: &str) {
        self.clock += 1;
        let now = self.clock;
        if let Some(reps) = self.entries.get_mut(path) {
            for r in reps.iter_mut().filter(|r| r.overlaps(lo, hi)) {
                r.last_use = now;
            }
        }
    }

    /// Node ranges holding `path`: disjoint, sorted by `lo`.
    pub fn coverage_of(&self, path: &str) -> Vec<(u32, u32)> {
        self.entries
            .get(path)
            .map(|reps| reps.iter().map(|r| (r.lo, r.hi)).collect())
            .unwrap_or_default()
    }

    /// Write `data` at `path` on every node in `lo..=hi`, panicking if
    /// the capacity-checked write is rejected (legacy entry point for
    /// unbounded stores; capacity-aware callers use
    /// [`NodeStores::write_range_evicting`] or route through
    /// `SimCore::node_write_range` to keep metrics and the residency
    /// mirror in sync).
    pub fn write_range(&mut self, lo: u32, hi: u32, path: impl Into<String>, data: Blob) {
        let path = path.into();
        match self.write_range_evicting(lo, hi, &path, data) {
            StoreWrite::Stored { .. } => {}
            StoreWrite::Rejected { short_bytes } => panic!(
                "node store write of {path} on {lo}..={hi} exceeds capacity by {short_bytes} B"
            ),
        }
    }

    /// Write on a single node.
    pub fn write(&mut self, node: u32, path: impl Into<String>, data: Blob) {
        self.write_range(node, node, path, data);
    }

    /// Capacity-checked write of `data` at `path` on every node in
    /// `lo..=hi`. Evicts LRU unpinned replicas of *other* paths
    /// covering a still-over-budget node of the range until the write
    /// fits on every node (the overlapped portion of an older
    /// same-path replica is replaced, never counted). Rejection leaves
    /// the store byte-for-byte untouched.
    pub fn write_range_evicting(
        &mut self,
        lo: u32,
        hi: u32,
        path: &str,
        data: Blob,
    ) -> StoreWrite {
        assert!(lo <= hi, "bad node range");
        let need = data.len();
        let mut evicted = Vec::new();
        if let Some(cap) = self.capacity {
            if need > cap {
                return StoreWrite::Rejected { short_bytes: need - cap };
            }
            // Feasibility first, so rejection is a no-op: with every
            // eligible victim gone, only pinned other-path replicas
            // remain on the range's nodes. (Nothing pinned -> always
            // feasible, since `need <= cap` held above.)
            if !self.pinned.is_empty() {
                for n in lo..=hi {
                    let kept: u64 = self
                        .entries
                        .iter()
                        .filter(|(p, _)| {
                            p.as_str() != path && self.pinned.contains_key(p.as_str())
                        })
                        .flat_map(|(_, reps)| reps.iter())
                        .filter(|r| r.covers(n))
                        .map(|r| r.blob.len())
                        .sum();
                    if kept + need > cap {
                        return StoreWrite::Rejected { short_bytes: kept + need - cap };
                    }
                }
            }
            // Evict LRU victims until every node of the range fits.
            // Victims must cover at least one currently-over-budget
            // node: a merely range-overlapping replica on a node that
            // already fits would be destroyed without freeing anything
            // where it matters.
            loop {
                let over: Vec<u32> = (lo..=hi)
                    .filter(|&n| self.used_after_overwrite(n, path) + need > cap)
                    .collect();
                if over.is_empty() {
                    break;
                }
                let victim = self
                    .entries
                    .iter()
                    .filter(|(p, _)| {
                        p.as_str() != path && !self.pinned.contains_key(p.as_str())
                    })
                    .flat_map(|(p, reps)| reps.iter().map(move |r| (p, r)))
                    .filter(|(_, r)| over.iter().any(|&n| r.covers(n)))
                    .min_by_key(|(_, r)| (r.last_use, r.seq))
                    .map(|(p, r)| (p.clone(), r.lo));
                let (vpath, vlo) =
                    victim.expect("feasibility check guaranteed an evictable victim");
                let ev = self.remove_replica(&vpath, vlo);
                evicted.push(ev);
            }
        }
        // Replace the overlapped portion of older same-path replicas
        // and store the new one.
        self.clock += 1;
        self.seq += 1;
        let (now, seq) = (self.clock, self.seq);
        let mut reps = self.entries.remove(path).unwrap_or_default();
        let mut out: Vec<Replica> = Vec::with_capacity(reps.len() + 1);
        for r in reps.drain(..) {
            if !r.overlaps(lo, hi) {
                out.push(r);
                continue;
            }
            let (olo, ohi) = (r.lo.max(lo), r.hi.min(hi));
            let b = r.blob.len();
            if b > 0 {
                for n in olo..=ohi {
                    self.sub_used(n, b);
                }
            }
            if r.lo < lo {
                out.push(Replica { lo: r.lo, hi: lo - 1, ..r.clone() });
            }
            if r.hi > hi {
                out.push(Replica { lo: hi + 1, hi: r.hi, ..r });
            }
        }
        if need > 0 {
            for n in lo..=hi {
                *self.used.entry(n).or_insert(0) += need;
            }
        }
        out.push(Replica { lo, hi, blob: data, last_use: now, seq });
        out.sort_by_key(|r| r.lo);
        self.entries.insert(path.to_string(), out);
        StoreWrite::Stored { evicted }
    }

    /// Forcibly evict every replica of `path`. No-op when pinned.
    pub fn evict_path(&mut self, path: &str) -> Vec<Eviction> {
        if self.pinned.contains_key(path) {
            return Vec::new();
        }
        let Some(reps) = self.entries.remove(path) else {
            return Vec::new();
        };
        let mut out = Vec::new();
        for r in reps {
            let b = r.blob.len();
            if b > 0 {
                for n in r.lo..=r.hi {
                    self.sub_used(n, b);
                }
            }
            out.push(Eviction { path: path.to_string(), lo: r.lo, hi: r.hi, bytes: b });
        }
        out
    }

    /// Usage of `n` once the same-path replica covering it (if any) is
    /// replaced by the pending write.
    fn used_after_overwrite(&self, n: u32, path: &str) -> u64 {
        let mut u = self.used.get(&n).copied().unwrap_or(0);
        if let Some(reps) = self.entries.get(path) {
            if let Some(r) = reps.iter().find(|r| r.covers(n)) {
                u -= r.blob.len();
            }
        }
        u
    }

    /// Remove the replica of `path` starting at node `lo` (unique:
    /// replicas of one path are node-disjoint).
    fn remove_replica(&mut self, path: &str, lo: u32) -> Eviction {
        let reps = self.entries.get_mut(path).expect("victim path present");
        let idx = reps.iter().position(|r| r.lo == lo).expect("victim replica present");
        let r = reps.remove(idx);
        if reps.is_empty() {
            self.entries.remove(path);
        }
        let b = r.blob.len();
        if b > 0 {
            for n in r.lo..=r.hi {
                self.sub_used(n, b);
            }
        }
        Eviction { path: path.to_string(), lo: r.lo, hi: r.hi, bytes: b }
    }

    fn sub_used(&mut self, n: u32, b: u64) {
        let e = self.used.get_mut(&n).expect("usage accounting out of sync");
        *e -= b;
        if *e == 0 {
            self.used.remove(&n);
        }
    }

    /// Read `path` as seen by `node`.
    pub fn read(&self, node: u32, path: &str) -> Option<&Blob> {
        self.entries.get(path)?.iter().find(|r| r.covers(node)).map(|r| &r.blob)
    }

    pub fn exists_on(&self, node: u32, path: &str) -> bool {
        self.read(node, path).is_some()
    }

    /// Bytes resident on one node (O(1): incrementally accounted).
    pub fn bytes_on(&self, node: u32) -> u64 {
        self.used.get(&node).copied().unwrap_or(0)
    }

    /// True when every node of `lo..=hi` holds `path` with content
    /// identical to `want` — the incremental re-stage hit test (a
    /// stale replica, updated on the shared FS since staging, fails
    /// the checksum and is restaged).
    pub fn resident_matches(&self, lo: u32, hi: u32, path: &str, want: &Blob) -> bool {
        let Some(reps) = self.entries.get(path) else {
            return false;
        };
        let mut covered = 0u64;
        for r in reps {
            if !r.overlaps(lo, hi) {
                continue;
            }
            if !r.blob.same_content(want) {
                return false;
            }
            covered += (r.hi.min(hi) - r.lo.max(lo) + 1) as u64;
        }
        covered == (hi - lo + 1) as u64
    }

    /// Number of distinct paths stored anywhere.
    pub fn path_count(&self) -> usize {
        self.entries.len()
    }

    /// Paths visible to `node`, in sorted order by construction
    /// (deterministic enumeration for the gather collective's local
    /// directory listing and the hook's transfer lists).
    pub fn paths_on(&self, node: u32) -> Vec<String> {
        self.entries
            .iter()
            .filter(|(_, reps)| reps.iter().any(|r| r.covers(node)))
            .map(|(k, _)| k.clone())
            .collect()
    }

    /// Deterministic snapshot: (path, [(lo, hi, per-node bytes)]),
    /// paths sorted, replicas sorted by `lo`. Test/mirror support.
    pub fn dump(&self) -> Vec<(String, ReplicaSnapshot)> {
        self.entries
            .iter()
            .map(|(p, reps)| {
                (p.clone(), reps.iter().map(|r| (r.lo, r.hi, r.blob.len())).collect())
            })
            .collect()
    }

    /// Wipe all replicas, usage accounting, and pins (capacity and
    /// the LRU clock survive).
    pub fn clear(&mut self) {
        self.entries.clear();
        self.used.clear();
        self.pinned.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bgq_spec_constants() {
        let m = bgq(8192);
        assert_eq!(m.total_cores(), 131_072);
        assert_eq!(m.hw_threads(), 524_288); // paper: "524,288 hardware threads"
        assert_eq!(m.n_ions(), 64);
        assert_eq!(m.total_ranks(), 131_072);
    }

    #[test]
    fn small_bgq_has_one_ion() {
        assert_eq!(bgq(64).n_ions(), 1);
        assert_eq!(bgq(129).n_ions(), 2);
    }

    #[test]
    fn orthros_spec() {
        let m = orthros();
        assert_eq!(m.total_cores(), 320); // paper: "320-core x86 cluster"
        assert_eq!(m.n_ions(), 0);
    }

    #[test]
    fn topology_paths() {
        let mut net = FlowNet::new();
        let t = Topology::build(bgq(512), GpfsParams::default(), &mut net);
        assert_eq!(t.path_coordinated_read().len(), 2);
        assert_eq!(t.path_uncoordinated_read().len(), 3);
        assert_eq!(t.path_local_write().len(), 1); // via ION
        assert_eq!(t.path_meta().len(), 1);
    }

    #[test]
    fn orthros_local_write_is_pathless() {
        let mut net = FlowNet::new();
        let t = Topology::build(orthros(), GpfsParams::default(), &mut net);
        assert!(t.path_local_write().is_empty());
        assert_eq!(t.path_coordinated_read().len(), 1);
    }

    #[test]
    fn ion_layer_capacity_scales_with_allocation() {
        let mut net = FlowNet::new();
        let t8k = Topology::build(bgq(8192), GpfsParams::default(), &mut net);
        let f = net.start(vec![t8k.ion_layer.unwrap()], 1, GB);
        net.recompute();
        // 64 IONs x 2.1 GB/s = 134.4 GB/s — the Fig 10 ceiling.
        assert!((net.rate_each(f) - 134.4 * GB as f64).abs() < 0.1 * GB as f64);
    }

    #[test]
    fn node_store_replicas() {
        let mut ns = NodeStores::new();
        let blob = Blob::real(vec![9; 64]);
        ns.write_range(0, 511, "/tmp/param.txt", blob.clone());
        assert!(ns.exists_on(0, "/tmp/param.txt"));
        assert!(ns.exists_on(511, "/tmp/param.txt"));
        assert!(!ns.exists_on(512, "/tmp/param.txt"));
        assert!(ns.read(100, "/tmp/param.txt").unwrap().same_content(&blob));
        assert_eq!(ns.bytes_on(77), 64);
        assert_eq!(ns.bytes_on(1000), 0);
        assert_eq!(ns.path_count(), 1);
    }

    #[test]
    fn node_store_newest_wins() {
        let mut ns = NodeStores::new();
        ns.write_range(0, 10, "/tmp/x", Blob::real(vec![1]));
        ns.write(5, "/tmp/x", Blob::real(vec![2, 2]));
        assert_eq!(ns.read(5, "/tmp/x").unwrap().len(), 2);
        assert_eq!(ns.read(4, "/tmp/x").unwrap().len(), 1);
        // The overwrite replaced (not shadowed) the middle node.
        assert_eq!(ns.bytes_on(5), 2);
        assert_eq!(ns.bytes_on(4), 1);
    }

    #[test]
    fn machine_ramdisk_capacities() {
        assert_eq!(bgq(512).ramdisk_cap(), Some(8 * GB));
        assert_eq!(orthros().ramdisk_cap(), Some(256 * GB));
        let mut m = bgq(4);
        m.ramdisk_capacity = 0;
        assert_eq!(m.ramdisk_cap(), None);
    }

    #[test]
    fn topology_applies_machine_budget_to_store() {
        let mut net = FlowNet::new();
        let t = Topology::build(bgq(16), GpfsParams::default(), &mut net);
        let mut ns = NodeStores::new();
        assert_eq!(ns.capacity(), None);
        t.apply_ramdisk_budget(&mut ns);
        assert_eq!(ns.capacity(), Some(8 * GB));
    }

    #[test]
    fn capacity_evicts_lru_first() {
        let mut ns = NodeStores::new();
        ns.set_capacity(Some(100));
        ns.write_range(0, 3, "/tmp/a", Blob::real(vec![1; 40]));
        ns.write_range(0, 3, "/tmp/b", Blob::real(vec![2; 40]));
        // Refresh a: b becomes the LRU victim.
        ns.touch(1, "/tmp/a");
        let out = ns.write_range_evicting(0, 3, "/tmp/c", Blob::real(vec![3; 40]));
        match out {
            StoreWrite::Stored { evicted } => {
                assert_eq!(evicted.len(), 1);
                assert_eq!(evicted[0].path, "/tmp/b");
                assert_eq!(evicted[0].bytes, 40);
                assert_eq!((evicted[0].lo, evicted[0].hi), (0, 3));
            }
            other => panic!("expected Stored, got {other:?}"),
        }
        assert!(ns.exists_on(2, "/tmp/a"));
        assert!(!ns.exists_on(2, "/tmp/b"));
        assert!(ns.exists_on(2, "/tmp/c"));
        assert_eq!(ns.bytes_on(2), 80);
    }

    #[test]
    fn pinned_replicas_survive_pressure() {
        let mut ns = NodeStores::new();
        ns.set_capacity(Some(100));
        ns.write_range(0, 1, "/tmp/keep", Blob::real(vec![1; 60]));
        ns.pin("/tmp/keep");
        ns.write_range(0, 1, "/tmp/x", Blob::real(vec![2; 30]));
        // 60 pinned + 30 + 30 > 100: x is evicted, keep survives.
        let out = ns.write_range_evicting(0, 1, "/tmp/y", Blob::real(vec![3; 30]));
        assert!(matches!(out, StoreWrite::Stored { ref evicted } if evicted.len() == 1
            && evicted[0].path == "/tmp/x"));
        assert!(ns.exists_on(0, "/tmp/keep"));
        // A write that cannot fit beside the pinned resident is
        // rejected with the store untouched.
        let before = ns.dump();
        let out = ns.write_range_evicting(0, 1, "/tmp/z", Blob::real(vec![4; 50]));
        assert!(matches!(out, StoreWrite::Rejected { short_bytes: 10 }));
        assert_eq!(ns.dump(), before);
        // Unpinning makes the same write admissible again.
        ns.unpin("/tmp/keep");
        assert!(matches!(
            ns.write_range_evicting(0, 1, "/tmp/z", Blob::real(vec![4; 50])),
            StoreWrite::Stored { .. }
        ));
        assert!(ns.bytes_on(0) <= 100 && ns.bytes_on(1) <= 100);
    }

    #[test]
    fn oversized_blob_rejected_outright() {
        let mut ns = NodeStores::new();
        ns.set_capacity(Some(10));
        let out = ns.write_range_evicting(0, 0, "/tmp/big", Blob::real(vec![0; 25]));
        assert!(matches!(out, StoreWrite::Rejected { short_bytes: 15 }));
        assert_eq!(ns.path_count(), 0);
    }

    #[test]
    fn eviction_scoped_to_overlapping_ranges() {
        let mut ns = NodeStores::new();
        ns.set_capacity(Some(100));
        ns.write_range(0, 1, "/tmp/left", Blob::real(vec![1; 80]));
        ns.write_range(4, 5, "/tmp/right", Blob::real(vec![2; 80]));
        // Pressure on nodes 4-5 must not evict the disjoint left range.
        let out = ns.write_range_evicting(4, 5, "/tmp/new", Blob::real(vec![3; 60]));
        assert!(matches!(out, StoreWrite::Stored { ref evicted } if evicted.len() == 1
            && evicted[0].path == "/tmp/right"));
        assert!(ns.exists_on(0, "/tmp/left"));
        assert!(!ns.exists_on(4, "/tmp/right"));
    }

    #[test]
    fn residency_range_set_algebra() {
        let mut rs = Vec::new();
        add_range(&mut rs, 4, 7);
        add_range(&mut rs, 0, 1);
        assert_eq!(rs, vec![(0, 1), (4, 7)]);
        add_range(&mut rs, 2, 3); // bridges and coalesces
        assert_eq!(rs, vec![(0, 7)]);
        sub_range(&mut rs, 3, 5);
        assert_eq!(rs, vec![(0, 2), (6, 7)]);
        sub_range(&mut rs, 0, 7);
        assert!(rs.is_empty());
    }

    #[test]
    fn residency_table_mirrors_store() {
        let mut ns = NodeStores::new();
        let mut table = ResidencyTable::new();
        let w = |ns: &mut NodeStores, t: &mut ResidencyTable, lo, hi, p: &str| {
            match ns.write_range_evicting(lo, hi, p, Blob::real(vec![0; 4])) {
                StoreWrite::Stored { evicted } => t.on_stored(lo, hi, p, &evicted),
                StoreWrite::Rejected { .. } => {}
            }
        };
        w(&mut ns, &mut table, 0, 3, "/tmp/a");
        w(&mut ns, &mut table, 4, 7, "/tmp/a"); // coalesces to (0,7)
        w(&mut ns, &mut table, 2, 5, "/tmp/b");
        assert!(table.mirrors(&ns));
        assert!(table.resident(5, "/tmp/a"));
        assert_eq!(table.coverage("/tmp/a"), &[(0, 7)]);
        assert_eq!(table.resident_paths().count(), 2);
        table.on_evicted(&ns.evict_path("/tmp/b"));
        assert!(table.mirrors(&ns));
        assert!(!table.resident(3, "/tmp/b"));
        assert_eq!(table.evictions, 1);
        assert_eq!(table.evicted_bytes, 4 * 4);
    }

    #[test]
    fn touch_range_refreshes_split_replicas() {
        let mut ns = NodeStores::new();
        ns.set_capacity(Some(100));
        // Split /tmp/hot into three replicas via a same-content patch.
        ns.write_range(0, 5, "/tmp/hot", Blob::real(vec![1; 30]));
        ns.write_range(2, 3, "/tmp/hot", Blob::real(vec![1; 30]));
        ns.write_range(0, 5, "/tmp/cold", Blob::real(vec![2; 30]));
        assert_eq!(ns.coverage_of("/tmp/hot"), vec![(0, 1), (2, 3), (4, 5)]);
        assert!(ns.coverage_of("/tmp/none").is_empty());
        // A range-wide hit refreshes ALL hot replicas (not just the
        // one covering the probe node); cold is then the LRU victim.
        ns.touch_range(0, 5, "/tmp/hot");
        let out = ns.write_range_evicting(0, 5, "/tmp/new", Blob::real(vec![3; 60]));
        match out {
            StoreWrite::Stored { evicted } => {
                assert!(!evicted.is_empty());
                assert!(
                    evicted.iter().all(|e| e.path == "/tmp/cold"),
                    "hot replicas evicted despite the range-wide hit: {evicted:?}"
                );
            }
            other => panic!("expected Stored, got {other:?}"),
        }
        for n in 0..6u32 {
            assert!(ns.exists_on(n, "/tmp/hot"));
        }
    }

    #[test]
    fn victims_must_cover_an_over_budget_node() {
        // /tmp/old (LRU-oldest) lives only on node 0, which still fits
        // the incoming write; /tmp/busy fills node 5. The eviction must
        // take /tmp/busy (covering the over-budget node), not destroy
        // /tmp/old needlessly.
        let mut ns = NodeStores::new();
        ns.set_capacity(Some(100));
        ns.write_range(0, 0, "/tmp/old", Blob::real(vec![1; 40]));
        ns.write_range(5, 5, "/tmp/busy", Blob::real(vec![2; 80]));
        let out = ns.write_range_evicting(0, 5, "/tmp/new", Blob::real(vec![3; 60]));
        match out {
            StoreWrite::Stored { evicted } => {
                assert_eq!(evicted.len(), 1);
                assert_eq!(evicted[0].path, "/tmp/busy");
            }
            other => panic!("expected Stored, got {other:?}"),
        }
        assert!(ns.exists_on(0, "/tmp/old"), "node-0 replica destroyed needlessly");
        assert!(ns.exists_on(3, "/tmp/new"));
        assert_eq!(ns.bytes_on(0), 100);
        assert_eq!(ns.bytes_on(5), 60);
    }

    #[test]
    fn overwrite_splits_replicas_and_keeps_accounting() {
        let mut ns = NodeStores::new();
        ns.write_range(0, 9, "/tmp/x", Blob::real(vec![1; 10]));
        ns.write_range(3, 6, "/tmp/x", Blob::real(vec![2; 20]));
        assert_eq!(ns.dump(), vec![(
            "/tmp/x".to_string(),
            vec![(0, 2, 10), (3, 6, 20), (7, 9, 10)],
        )]);
        for n in 0..10u32 {
            let want = if (3..=6).contains(&n) { 20 } else { 10 };
            assert_eq!(ns.bytes_on(n), want, "node {n}");
        }
        assert_eq!(ns.bytes_on(10), 0);
    }

    #[test]
    fn paths_on_is_sorted_and_deterministic() {
        let build = || {
            let mut ns = NodeStores::new();
            for name in ["/tmp/z.bin", "/tmp/a.bin", "/tmp/m.bin", "/tmp/k.bin"] {
                ns.write_range(0, 7, name, Blob::real(vec![0; 4]));
            }
            ns.write_range(2, 3, "/tmp/partial.bin", Blob::real(vec![0; 4]));
            ns
        };
        let a = build();
        let b = build();
        let paths = a.paths_on(2);
        let mut sorted = paths.clone();
        sorted.sort();
        assert_eq!(paths, sorted, "paths_on must return sorted order");
        assert_eq!(paths.len(), 5);
        assert_eq!(a.paths_on(5).len(), 4);
        // Identical construction -> identical enumeration (no
        // HashMap iteration-order dependence).
        assert_eq!(a.paths_on(2), b.paths_on(2));
        assert_eq!(a.dump(), b.dump());
    }

    #[test]
    fn resident_matches_checks_coverage_and_content() {
        let mut ns = NodeStores::new();
        let blob = Blob::synthetic(1000, 7);
        ns.write_range(0, 3, "/tmp/d", blob.clone());
        assert!(ns.resident_matches(0, 3, "/tmp/d", &blob));
        assert!(ns.resident_matches(1, 2, "/tmp/d", &blob));
        // Partial coverage fails.
        assert!(!ns.resident_matches(0, 4, "/tmp/d", &blob));
        // Stale content fails.
        assert!(!ns.resident_matches(0, 3, "/tmp/d", &Blob::synthetic(1000, 8)));
        // A same-content patch over a sub-range still matches.
        ns.write_range(1, 2, "/tmp/d", blob.clone());
        assert!(ns.resident_matches(0, 3, "/tmp/d", &blob));
    }

    #[test]
    fn pins_are_refcounted_across_owners() {
        let mut ns = NodeStores::new();
        ns.write_range(0, 1, "/tmp/shared", Blob::real(vec![1; 8]));
        ns.pin("/tmp/shared"); // owner X
        ns.pin("/tmp/shared"); // owner Y
        ns.unpin("/tmp/shared"); // Y releases; X still holds it
        assert!(ns.is_pinned("/tmp/shared"));
        assert!(ns.evict_path("/tmp/shared").is_empty());
        ns.unpin("/tmp/shared");
        assert!(!ns.is_pinned("/tmp/shared"));
        // Unbalanced extra unpins are harmless no-ops.
        ns.unpin("/tmp/shared");
        assert_eq!(ns.evict_path("/tmp/shared").len(), 1);
    }

    #[test]
    fn forced_evict_path_respects_pins() {
        let mut ns = NodeStores::new();
        ns.write_range(0, 3, "/tmp/a", Blob::real(vec![1; 8]));
        ns.pin("/tmp/a");
        assert!(ns.evict_path("/tmp/a").is_empty());
        assert!(ns.exists_on(0, "/tmp/a"));
        ns.unpin("/tmp/a");
        let ev = ns.evict_path("/tmp/a");
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].bytes, 8);
        assert!(!ns.exists_on(0, "/tmp/a"));
        assert_eq!(ns.bytes_on(0), 0);
    }
}
