//! Machine models: the ALCF Blue Gene/Q systems and the APS Orthros
//! cluster.
//!
//! A [`MachineSpec`] carries the published hardware constants; a
//! [`Topology`] materialises the machine's bandwidth structure as
//! flow-network links. Aggregation note: symmetric layers made of `g`
//! identical links with uniformly spread load are modelled as one link
//! of capacity `g x link_bw` — exact for fair-shared symmetric bundles
//! and what keeps recomputation O(1) in machine size.
//!
//! The node-local storage *data plane* lives in [`crate::storage`]
//! (re-exported below for pre-extraction imports); this module owns
//! only what the machine dictates about it: per-tier capacities and
//! the SSD link class demotion/promotion traffic rides.
//!
//! BG/Q specifics that shape the paper's results:
//!
//! - Compute nodes have **no direct filesystem access**; all I/O
//!   forwards over per-I/O-node uplinks (1 ION per 128 compute nodes
//!   on Mira). The `/tmp` RAM disk itself "is actually an I/O node
//!   service" (SVI-B), so *writing staged data to /tmp* also rides the
//!   ION uplink — this is why Staging+Write tops out at 134 GB/s on
//!   8,192 nodes (64 IONs x ~2.1 GB/s).
//! - The 5D torus gives every node a ~1.8 GB/s usable injection rate;
//!   collective broadcast is effectively pipelined and never the
//!   staging bottleneck.
//! - Reading staged data back from /tmp was measured at a flat
//!   53.4 MB/s per process (10.8 +/- 0.1 s for 577 MB) independent of
//!   allocation size; we model it as a per-process rate cap.
//! - BG/Q nodes carry **no local disk** — there is no SSD tier to
//!   demote to ([`MachineSpec::ssd_cap`] is `None`), preserving paper
//!   fidelity: eviction there really does destroy the replica.

use crate::engine::{DemoteRoute, SimCore};
use crate::pfs::GpfsParams;
use crate::simtime::flownet::{Capacity, FlowNet, LinkClass, LinkId};
use crate::units::{GB, MB, TB};

// Backward-compatible surface: the storage subsystem was extracted
// from this module; everything that used to live here keeps resolving.
pub use crate::storage::{
    Eviction, NodeStores, PromoteOutcome, ReplicaSnapshot, ResidencyTable, StorageTier,
    StoreWrite, TierBudgets,
};

/// Hardware description of one machine.
#[derive(Clone, Copy, Debug)]
pub struct MachineSpec {
    pub name: &'static str,
    /// Compute nodes in the allocation.
    pub nodes: u32,
    /// Physical cores per node (BG/Q A2: 16; Orthros AMD: 64).
    pub cores_per_node: u32,
    /// Hardware threads per core (BG/Q: 4).
    pub threads_per_core: u32,
    /// Worker ranks per node the many-task runtime schedules.
    pub ranks_per_node: u32,
    /// Compute nodes served by one I/O node (0 = direct-attached FS).
    pub nodes_per_ion: u32,
    /// Per-ION uplink bandwidth, bytes/s.
    pub ion_bw: f64,
    /// Per-node torus injection bandwidth, bytes/s.
    pub torus_link_bw: f64,
    /// Per-process read bandwidth from node-local storage, bytes/s.
    pub ramdisk_proc_read_bw: f64,
    /// Node-local writes traverse the ION uplink (BG/Q /tmp semantics).
    pub local_write_via_ion: bool,
    /// Per-node RAM-disk capacity in bytes (0 = not modelled). The
    /// staging regime the paper describes — data "cached in compute
    /// node memory for extended periods" — only has failure modes once
    /// this is finite; experiments apply it with
    /// [`NodeStores::set_capacity`].
    pub ramdisk_capacity: u64,
    /// Per-node SSD / burst-buffer capacity in bytes (0 = no SSD
    /// tier). When present, RAM eviction demotes replicas here instead
    /// of discarding them ([`crate::storage::NodeStores`]).
    pub ssd_capacity: u64,
    /// Per-node SSD streaming bandwidth, bytes/s — the rate demotion
    /// and promotion transfers ride on the aggregated SSD link.
    pub ssd_bw: f64,
    /// Detector-to-facility beamline pipe bandwidth, bytes/s (0 = no
    /// beamline attached). Streaming frame ingest
    /// ([`crate::staging::ingest`]) rides this link into node memory.
    pub beamline_bw: f64,
}

impl MachineSpec {
    pub fn total_cores(&self) -> u64 {
        self.nodes as u64 * self.cores_per_node as u64
    }

    pub fn total_ranks(&self) -> u64 {
        self.nodes as u64 * self.ranks_per_node as u64
    }

    pub fn hw_threads(&self) -> u64 {
        self.total_cores() * self.threads_per_core as u64
    }

    /// The RAM-disk byte budget per node, if modelled.
    pub fn ramdisk_cap(&self) -> Option<u64> {
        if self.ramdisk_capacity == 0 {
            None
        } else {
            Some(self.ramdisk_capacity)
        }
    }

    /// The SSD-tier byte budget per node, if the machine has one.
    pub fn ssd_cap(&self) -> Option<u64> {
        if self.ssd_capacity == 0 {
            None
        } else {
            Some(self.ssd_capacity)
        }
    }

    /// Both managed tier budgets together.
    pub fn tier_budgets(&self) -> TierBudgets {
        TierBudgets { ram: self.ramdisk_cap(), ssd: self.ssd_cap() }
    }

    /// I/O nodes serving this allocation (at least one).
    pub fn n_ions(&self) -> u32 {
        if self.nodes_per_ion == 0 {
            0
        } else {
            self.nodes.div_ceil(self.nodes_per_ion).max(1)
        }
    }
}

/// ALCF BG/Q (Mira/Cetus class) allocation of `nodes` nodes.
///
/// Constants: 16 PowerPC A2 cores @ 1.6 GHz / 64 HW threads per node
/// (SVI); 128 nodes per ION with ~2.1 GB/s usable uplink (calibrated
/// against Fig 10's 134 GB/s at 8,192 nodes = 64 IONs); 1.8 GB/s torus
/// injection; 53.4 MB/s per-process /tmp read (SVI-B). No node-local
/// disk: the SSD tier is absent, as on the real machine.
pub fn bgq(nodes: u32) -> MachineSpec {
    MachineSpec {
        name: "bgq",
        nodes,
        cores_per_node: 16,
        threads_per_core: 4,
        ranks_per_node: 16,
        nodes_per_ion: 128,
        ion_bw: 2.1 * GB as f64,
        torus_link_bw: 1.8 * GB as f64,
        ramdisk_proc_read_bw: 53.4 * MB as f64,
        local_write_via_ion: true,
        // BG/Q nodes carry 16 GB; /tmp must share it with the
        // application image, so roughly half is usable for staging.
        ramdisk_capacity: 8 * GB,
        // Paper fidelity: BG/Q compute nodes are diskless.
        ssd_capacity: 0,
        ssd_bw: 0.0,
        // APS -> ALCF wide-area pipe (the transfer experiments'
        // calibrated inter-facility rate).
        beamline_bw: 1.25 * GB as f64,
    }
}

/// The APS sector-1 Orthros cluster: "a 320-core x86 cluster...
/// an Orthros node has 64 AMD cores running at 2.2 GHz" (SVI). Five
/// fat nodes, direct-attached NFS (modelled as a 1.25 GB/s backplane
/// via `GpfsParams` overrides in the experiment drivers), local disks.
/// The local disks are the SSD tier: 1 TB per node at a calibrated
/// 1.5 GB/s streaming rate (see EXPERIMENTS.md "SSD link").
pub fn orthros() -> MachineSpec {
    MachineSpec {
        name: "orthros",
        nodes: 5,
        cores_per_node: 64,
        threads_per_core: 1,
        ranks_per_node: 64,
        nodes_per_ion: 0, // direct-attached
        ion_bw: 0.0,
        torus_link_bw: 1.25 * GB as f64, // 10 GbE
        ramdisk_proc_read_bw: 500.0 * MB as f64,
        local_write_via_ion: false,
        // Fat nodes: a generous in-memory staging budget.
        ramdisk_capacity: 256 * GB,
        // The node-local disks become the demotion tier.
        ssd_capacity: TB,
        ssd_bw: 1.5 * GB as f64,
        // Same-sector beamline: the detector sits metres away (see
        // EXPERIMENTS.md "Beamline link").
        beamline_bw: 3.0 * GB as f64,
    }
}

/// The machine's bandwidth structure materialised as flownet links.
#[derive(Clone, Debug)]
pub struct Topology {
    pub spec: MachineSpec,
    pub gpfs: GpfsParams,
    /// Filesystem aggregate backplane (240 GB/s class).
    pub pfs_backplane: LinkId,
    /// Degrading server-side stage traversed by uncoordinated reads.
    pub pfs_disk: LinkId,
    /// Metadata server ("bytes" = metadata operations).
    pub pfs_meta: LinkId,
    /// Aggregated ION uplink layer (None for direct-attached machines).
    pub ion_layer: Option<LinkId>,
    /// Aggregated torus/interconnect bisection.
    pub torus: LinkId,
    /// Aggregated node-local SSD layer (None when the machine has no
    /// SSD tier). Demotion and promotion transfers ride this link.
    pub ssd_layer: Option<LinkId>,
    /// Detector-to-facility beamline pipe (None when no beamline is
    /// attached). Streaming frame ingest rides this link.
    pub beamline: Option<LinkId>,
}

impl Topology {
    /// Create links for `spec` + `gpfs` in `net`. Each link declares
    /// its machine layer ([`LinkClass`]) at construction, so the flow
    /// network's component tracking and contention diagnostics can
    /// attribute load without string-matching names.
    pub fn build(spec: MachineSpec, gpfs: GpfsParams, net: &mut FlowNet) -> Topology {
        let pfs_backplane = net.add_link_classed(
            "pfs.backplane",
            Capacity::Fixed(gpfs.peak_bw),
            LinkClass::Backplane,
        );
        let pfs_disk = net.add_link_classed(
            "pfs.disk",
            Capacity::Degrading {
                peak: gpfs.peak_bw,
                pivot: gpfs.degrade_pivot,
                half: gpfs.degrade_half,
            },
            LinkClass::Disk,
        );
        let pfs_meta = net.add_link_classed(
            "pfs.meta",
            Capacity::Fixed(gpfs.meta_ops_per_sec),
            LinkClass::Meta,
        );
        let ion_layer = if spec.nodes_per_ion > 0 {
            Some(net.add_link_classed(
                "ion.layer",
                Capacity::Fixed(spec.n_ions() as f64 * spec.ion_bw),
                LinkClass::Ion,
            ))
        } else {
            None
        };
        let torus = net.add_link_classed(
            "torus.bisection",
            Capacity::Fixed(spec.nodes as f64 * spec.torus_link_bw),
            LinkClass::Interconnect,
        );
        let ssd_layer = if spec.ssd_cap().is_some() {
            Some(net.add_link_classed(
                "ssd.layer",
                Capacity::Fixed(spec.nodes as f64 * spec.ssd_bw),
                LinkClass::Ssd,
            ))
        } else {
            None
        };
        // Added last so machines without a beamline allocate the same
        // LinkIds as before the ingest layer existed (bit-identity for
        // non-ingest runs).
        let beamline = if spec.beamline_bw > 0.0 {
            Some(net.add_link_classed(
                "beamline.link",
                Capacity::Fixed(spec.beamline_bw),
                LinkClass::Beamline,
            ))
        } else {
            None
        };
        Topology {
            spec,
            gpfs,
            pfs_backplane,
            pfs_disk,
            pfs_meta,
            ion_layer,
            torus,
            ssd_layer,
            beamline,
        }
    }

    /// Path of a *coordinated* (collective, large-aligned) GPFS read
    /// landing on compute nodes: backplane + ION layer.
    pub fn path_coordinated_read(&self) -> Vec<LinkId> {
        let mut p = vec![self.pfs_backplane];
        p.extend(self.ion_layer);
        p
    }

    /// Path of an *uncoordinated* per-rank GPFS read: adds the
    /// degrading disk stage.
    pub fn path_uncoordinated_read(&self) -> Vec<LinkId> {
        let mut p = vec![self.pfs_disk, self.pfs_backplane];
        p.extend(self.ion_layer);
        p
    }

    /// Path of a node-local RAM-disk write (BG/Q: via ION; clusters:
    /// genuinely local, pathless).
    pub fn path_local_write(&self) -> Vec<LinkId> {
        if self.spec.local_write_via_ion {
            self.ion_layer.into_iter().collect()
        } else {
            vec![]
        }
    }

    /// Path of SSD-tier traffic (demotion and promotion transfers):
    /// the aggregated node-local SSD layer. Empty when the machine has
    /// no SSD tier — but the engine only routes demotions when
    /// [`Topology::apply_storage_budgets`] installed the route, so a
    /// pathless (instantaneous) tier transfer cannot arise by accident.
    pub fn path_ssd(&self) -> Vec<LinkId> {
        self.ssd_layer.into_iter().collect()
    }

    /// Path of detector frame traffic: the shared beamline pipe every
    /// streaming ingest flow funnels through. Empty when no beamline
    /// is attached (frames then land instantaneously — only meaningful
    /// in unit tests; both machine specs attach one).
    pub fn path_beamline(&self) -> Vec<LinkId> {
        self.beamline.into_iter().collect()
    }

    /// Path of metadata operations.
    pub fn path_meta(&self) -> Vec<LinkId> {
        vec![self.pfs_meta]
    }

    /// Path of interconnect traffic (broadcast / redistribution).
    pub fn path_torus(&self) -> Vec<LinkId> {
        vec![self.torus]
    }

    /// Apply this machine's node-local **RAM** budget to the data
    /// plane. Superseded by [`Topology::apply_storage_budgets`], which
    /// also arms the SSD tier; kept for callers that only hold the
    /// store.
    pub fn apply_ramdisk_budget(&self, nodes: &mut NodeStores) {
        nodes.set_capacity(self.spec.ramdisk_cap());
    }

    /// Apply this machine's storage budgets ([`MachineSpec::ramdisk_cap`]
    /// + [`MachineSpec::ssd_cap`]) to the core's data plane and install
    /// the demotion route (the SSD link + per-node rate cap) so
    /// engine-applied evictions demote through the flow network. The
    /// experiment harnesses call this right after [`Topology::build`];
    /// scenarios that want tighter pressure may override with
    /// [`NodeStores::set_capacity`] / [`NodeStores::set_ssd_capacity`]
    /// afterwards.
    pub fn apply_storage_budgets(&self, core: &mut SimCore) {
        core.nodes.set_capacity(self.spec.ramdisk_cap());
        core.nodes.set_ssd_capacity(self.spec.ssd_cap());
        core.set_demote_route(
            self.ssd_layer
                .map(|l| DemoteRoute { path: vec![l], cap_each: self.spec.ssd_bw }),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bgq_spec_constants() {
        let m = bgq(8192);
        assert_eq!(m.total_cores(), 131_072);
        assert_eq!(m.hw_threads(), 524_288); // paper: "524,288 hardware threads"
        assert_eq!(m.n_ions(), 64);
        assert_eq!(m.total_ranks(), 131_072);
    }

    #[test]
    fn small_bgq_has_one_ion() {
        assert_eq!(bgq(64).n_ions(), 1);
        assert_eq!(bgq(129).n_ions(), 2);
    }

    #[test]
    fn orthros_spec() {
        let m = orthros();
        assert_eq!(m.total_cores(), 320); // paper: "320-core x86 cluster"
        assert_eq!(m.n_ions(), 0);
    }

    #[test]
    fn topology_paths() {
        let mut net = FlowNet::new();
        let t = Topology::build(bgq(512), GpfsParams::default(), &mut net);
        assert_eq!(t.path_coordinated_read().len(), 2);
        assert_eq!(t.path_uncoordinated_read().len(), 3);
        assert_eq!(t.path_local_write().len(), 1); // via ION
        assert_eq!(t.path_meta().len(), 1);
        // BG/Q is diskless: no SSD layer, paper fidelity.
        assert!(t.ssd_layer.is_none());
        assert!(t.path_ssd().is_empty());
        // But it does have the APS -> ALCF beamline pipe.
        assert_eq!(t.path_beamline().len(), 1);
        assert_eq!(net.link_class(t.beamline.unwrap()), LinkClass::Beamline);
    }

    #[test]
    fn beamline_link_carries_the_spec_rate() {
        let mut net = FlowNet::new();
        let t = Topology::build(orthros(), GpfsParams::default(), &mut net);
        let l = t.beamline.unwrap();
        assert_eq!(net.link_class(l), LinkClass::Beamline);
        let f = net.start(vec![l], 1, GB);
        net.recompute();
        assert!((net.rate_each(f) - 3.0 * GB as f64).abs() < 1.0);

        // A spec with no beamline builds no link: pathless ingest.
        let mut spec = bgq(16);
        spec.beamline_bw = 0.0;
        let mut net = FlowNet::new();
        let t = Topology::build(spec, GpfsParams::default(), &mut net);
        assert!(t.beamline.is_none());
        assert!(t.path_beamline().is_empty());
    }

    #[test]
    fn orthros_local_write_is_pathless() {
        let mut net = FlowNet::new();
        let t = Topology::build(orthros(), GpfsParams::default(), &mut net);
        assert!(t.path_local_write().is_empty());
        assert_eq!(t.path_coordinated_read().len(), 1);
    }

    #[test]
    fn ion_layer_capacity_scales_with_allocation() {
        let mut net = FlowNet::new();
        let t8k = Topology::build(bgq(8192), GpfsParams::default(), &mut net);
        let f = net.start(vec![t8k.ion_layer.unwrap()], 1, GB);
        net.recompute();
        // 64 IONs x 2.1 GB/s = 134.4 GB/s — the Fig 10 ceiling.
        assert!((net.rate_each(f) - 134.4 * GB as f64).abs() < 0.1 * GB as f64);
    }

    #[test]
    fn machine_storage_capacities() {
        assert_eq!(bgq(512).ramdisk_cap(), Some(8 * GB));
        assert_eq!(orthros().ramdisk_cap(), Some(256 * GB));
        // BG/Q has no SSD tier (paper fidelity); Orthros models its
        // local disks as one.
        assert_eq!(bgq(512).ssd_cap(), None);
        assert_eq!(orthros().ssd_cap(), Some(TB));
        assert_eq!(
            orthros().tier_budgets(),
            TierBudgets { ram: Some(256 * GB), ssd: Some(TB) }
        );
        let mut m = bgq(4);
        m.ramdisk_capacity = 0;
        assert_eq!(m.ramdisk_cap(), None);
        assert_eq!(m.tier_budgets().total(), None);
    }

    #[test]
    fn topology_applies_machine_budget_to_store() {
        let mut net = FlowNet::new();
        let t = Topology::build(bgq(16), GpfsParams::default(), &mut net);
        let mut ns = NodeStores::new();
        assert_eq!(ns.capacity(), None);
        t.apply_ramdisk_budget(&mut ns);
        assert_eq!(ns.capacity(), Some(8 * GB));
    }

    #[test]
    fn storage_budgets_arm_both_tiers_and_the_demote_route() {
        // Orthros: RAM + SSD budgets land on the store, and the engine
        // gets the demotion route over the SSD link.
        let mut core = SimCore::new();
        let t = Topology::build(orthros(), GpfsParams::default(), &mut core.net);
        t.apply_storage_budgets(&mut core);
        assert_eq!(core.nodes.capacity(), Some(256 * GB));
        assert_eq!(core.nodes.ssd_capacity(), Some(1 * TB));
        assert!(core.demote_route().is_some());
        let l = t.ssd_layer.unwrap();
        assert_eq!(core.net.link_class(l), LinkClass::Ssd);
        // 5 nodes x 1.5 GB/s aggregated.
        let f = core.net.start(vec![l], 1, GB);
        core.net.recompute();
        assert!((core.net.rate_each(f) - 7.5 * GB as f64).abs() < 1.0);

        // BG/Q: no SSD tier, no route — eviction stays a discard.
        let mut core = SimCore::new();
        let t = Topology::build(bgq(16), GpfsParams::default(), &mut core.net);
        t.apply_storage_budgets(&mut core);
        assert_eq!(core.nodes.ssd_capacity(), None);
        assert!(core.demote_route().is_none());
    }
}
