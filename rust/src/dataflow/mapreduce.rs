//! The Fig 4/5 MapReduce pattern as a Swift-style task graph.
//!
//! The paper shows MapReduce expressed in ~20 lines of Swift: a
//! `foreach` map phase filling an array, and a recursive pairwise
//! `merge` reduction. Its defining property — noted explicitly ("this
//! dataflow expression of simplified MapReduce does not have a barrier
//! between the map and reduce phases") — is that a merge becomes
//! eligible the moment its two inputs exist, while other maps still
//! run. The test below asserts exactly that on the simulated cluster.

use crate::units::Duration;

use super::graph::{Task, TaskGraph, TaskId};

/// Build the Fig 4 graph: `n` map tasks and a pairwise merge tree.
/// `map_runtime(i)` and `merge_runtime(level)` control task costs.
/// Returns the graph and the final (root) merge task.
pub fn build<FM, FR>(
    n: usize,
    mut map_runtime: FM,
    mut merge_runtime: FR,
) -> (TaskGraph, TaskId)
where
    FM: FnMut(usize) -> Duration,
    FR: FnMut(u32) -> Duration,
{
    assert!(n >= 1, "need at least one map task");
    let mut g = TaskGraph::new();
    // Map phase: d[i] = map_function(find_file(i))  (Fig 4 lines 5-8).
    let mut level: Vec<TaskId> =
        (0..n).map(|i| g.add(Task::compute(format!("map{i}"), map_runtime(i)))).collect();
    // Reduce phase: recursive pairwise merge (Fig 4 lines 13-23).
    let mut depth = 0u32;
    while level.len() > 1 {
        depth += 1;
        let mut next = Vec::with_capacity(level.len().div_ceil(2));
        for pair in level.chunks(2) {
            if pair.len() == 2 {
                let t = Task::compute(format!("merge/L{depth}"), merge_runtime(depth))
                    .with_dep(pair[0])
                    .with_dep(pair[1]);
                next.push(g.add(t));
            } else {
                // Odd element passes through (Fig 4's start+s skew).
                next.push(pair[0]);
            }
        }
        level = next;
    }
    let root = level[0];
    (g, root)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{orthros, Topology};
    use crate::dataflow::sched::{run_workflow, SchedulerCfg};
    use crate::engine::SimCore;
    use crate::mpisim::Comm;
    use crate::pfs::GpfsParams;

    #[test]
    fn tree_shape() {
        let (g, root) = build(8, |_| Duration::from_secs(1), |_| Duration::from_secs(1));
        // 8 maps + 4 + 2 + 1 merges.
        assert_eq!(g.len(), 15);
        assert_eq!(root.0, 14);
        assert_eq!(g.roots().len(), 8);
    }

    #[test]
    fn odd_counts_pass_through() {
        let (g, _) = build(5, |_| Duration::ZERO, |_| Duration::ZERO);
        // 5 maps; level1: 2 merges + carry; level2: merge + carry;
        // level3: 1 merge = 5 + 2 + 1 + 1.
        assert_eq!(g.len(), 9);
    }

    #[test]
    fn single_map_needs_no_merge() {
        let (g, root) = build(1, |_| Duration::from_secs(2), |_| Duration::ZERO);
        assert_eq!(g.len(), 1);
        assert_eq!(root.0, 0);
    }

    #[test]
    fn no_barrier_between_map_and_reduce() {
        // One straggler map (100 s); everything else 1 s. If there were
        // a barrier, the first merge could not finish before t=100.
        let (g, root) = build(
            16,
            |i| if i == 15 { Duration::from_secs(100) } else { Duration::from_secs(1) },
            |_| Duration::from_secs(1),
        );
        let mut core = SimCore::new();
        let topo = Topology::build(orthros(), GpfsParams::default(), &mut core.net);
        let comm = Comm::world(&topo.spec);
        let stats = run_workflow(&mut core, &topo, &comm, g, SchedulerCfg::default());
        // First merge (maps 0+1) completes around t=2, long before the
        // straggler's t=100.
        let first_merge_done = stats.completion[16].secs_f64();
        assert!(first_merge_done < 5.0, "{first_merge_done}");
        // The root waits for the straggler's subtree.
        let root_done = stats.completion[root.0].secs_f64();
        assert!(root_done > 100.0, "{root_done}");
        // Total: straggler + its merge chain, not sum of phases.
        assert!(root_done < 110.0, "{root_done}");
    }
}
