//! Task graphs: the compiled form of a Swift dataflow program.
//!
//! A Swift `foreach` over N grid points (Fig 8) compiles to N
//! independent tasks; `merge(d, ...)` (Fig 4) compiles to a reduction
//! tree whose edges are dataflow dependencies. Tasks name their file
//! inputs so the scheduler can charge staged vs unstaged read costs
//! and verify the data plane actually holds the bytes.

use std::collections::VecDeque;

use crate::units::Duration;

/// Identifies a task within its graph.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct TaskId(pub usize);

/// A file a task reads before its compute phase.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TaskInput {
    /// Node-local (staged) or shared-FS path.
    pub path: String,
    /// Expected size; None = whatever the data plane holds.
    pub bytes: Option<u64>,
}

/// One leaf task (a C function invocation in the paper's workflows).
#[derive(Clone, Debug)]
pub struct Task {
    pub name: String,
    /// Pure compute duration (the FitOrientation/NLopt solve etc.).
    pub runtime: Duration,
    /// Files read before compute.
    pub inputs: Vec<TaskInput>,
    /// Dataflow dependencies (must complete first).
    pub deps: Vec<TaskId>,
    /// Bytes written to the shared FS at completion (size only; the
    /// science drivers write real blobs through effects instead).
    pub output_bytes: u64,
}

impl Task {
    pub fn compute(name: impl Into<String>, runtime: Duration) -> Task {
        Task {
            name: name.into(),
            runtime,
            inputs: Vec::new(),
            deps: Vec::new(),
            output_bytes: 0,
        }
    }

    pub fn with_input(mut self, path: impl Into<String>, bytes: Option<u64>) -> Task {
        self.inputs.push(TaskInput { path: path.into(), bytes });
        self
    }

    pub fn with_dep(mut self, dep: TaskId) -> Task {
        self.deps.push(dep);
        self
    }

    pub fn with_output(mut self, bytes: u64) -> Task {
        self.output_bytes = bytes;
        self
    }
}

/// A DAG of tasks.
#[derive(Clone, Debug, Default)]
pub struct TaskGraph {
    pub tasks: Vec<Task>,
}

impl TaskGraph {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, task: Task) -> TaskId {
        for d in &task.deps {
            assert!(d.0 < self.tasks.len(), "dep on unknown task {d:?}");
        }
        self.tasks.push(task);
        TaskId(self.tasks.len() - 1)
    }

    /// The Fig 8 pattern: `foreach i in [0..n) { body(i) }`.
    pub fn foreach<F: FnMut(usize) -> Task>(&mut self, n: usize, mut body: F) -> Vec<TaskId> {
        (0..n).map(|i| self.add(body(i))).collect()
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Tasks with no dependencies.
    pub fn roots(&self) -> Vec<TaskId> {
        self.tasks
            .iter()
            .enumerate()
            .filter(|(_, t)| t.deps.is_empty())
            .map(|(i, _)| TaskId(i))
            .collect()
    }

    /// Kahn's algorithm; Err(()) if the graph has a cycle.
    pub fn topo_order(&self) -> Result<Vec<TaskId>, ()> {
        let n = self.tasks.len();
        let mut indeg = vec![0usize; n];
        let mut out: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, t) in self.tasks.iter().enumerate() {
            indeg[i] = t.deps.len();
            for d in &t.deps {
                out[d.0].push(i);
            }
        }
        let mut q: VecDeque<usize> =
            (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(i) = q.pop_front() {
            order.push(TaskId(i));
            for &j in &out[i] {
                indeg[j] -= 1;
                if indeg[j] == 0 {
                    q.push_back(j);
                }
            }
        }
        if order.len() == n {
            Ok(order)
        } else {
            Err(())
        }
    }

    /// Sum of all task runtimes (the serial lower bound).
    pub fn total_work(&self) -> Duration {
        let ns = self.tasks.iter().map(|t| t.runtime.0).sum();
        Duration(ns)
    }

    /// Critical-path length through the dependency DAG.
    pub fn critical_path(&self) -> Duration {
        let order = self.topo_order().expect("cyclic graph");
        let mut finish = vec![0u64; self.tasks.len()];
        for id in order {
            let t = &self.tasks[id.0];
            let start = t.deps.iter().map(|d| finish[d.0]).max().unwrap_or(0);
            finish[id.0] = start + t.runtime.0;
        }
        Duration(finish.into_iter().max().unwrap_or(0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn foreach_builds_independent_tasks() {
        let mut g = TaskGraph::new();
        let ids = g.foreach(10, |i| Task::compute(format!("t{i}"), Duration::from_secs(1)));
        assert_eq!(ids.len(), 10);
        assert_eq!(g.roots().len(), 10);
        assert_eq!(g.total_work(), Duration::from_secs(10));
        assert_eq!(g.critical_path(), Duration::from_secs(1));
    }

    #[test]
    fn deps_shape_critical_path() {
        let mut g = TaskGraph::new();
        let a = g.add(Task::compute("a", Duration::from_secs(2)));
        let b = g.add(Task::compute("b", Duration::from_secs(3)).with_dep(a));
        let _c = g.add(Task::compute("c", Duration::from_secs(1)).with_dep(b));
        let _free = g.add(Task::compute("free", Duration::from_secs(4)));
        assert_eq!(g.critical_path(), Duration::from_secs(6));
        assert_eq!(g.roots().len(), 2);
    }

    #[test]
    fn topo_order_is_valid() {
        let mut g = TaskGraph::new();
        let a = g.add(Task::compute("a", Duration::ZERO));
        let b = g.add(Task::compute("b", Duration::ZERO).with_dep(a));
        let c = g.add(Task::compute("c", Duration::ZERO).with_dep(a));
        let d = g.add(Task::compute("d", Duration::ZERO).with_dep(b).with_dep(c));
        let order = g.topo_order().unwrap();
        let pos = |t: TaskId| order.iter().position(|&x| x == t).unwrap();
        assert!(pos(a) < pos(b) && pos(a) < pos(c) && pos(b) < pos(d) && pos(c) < pos(d));
    }

    #[test]
    #[should_panic(expected = "unknown task")]
    fn forward_dep_panics() {
        let mut g = TaskGraph::new();
        g.add(Task::compute("bad", Duration::ZERO).with_dep(TaskId(5)));
    }

    #[test]
    fn builder_helpers() {
        let t = Task::compute("x", Duration::from_secs(1))
            .with_input("/tmp/a.bin", Some(100))
            .with_output(50);
        assert_eq!(t.inputs.len(), 1);
        assert_eq!(t.inputs[0].bytes, Some(100));
        assert_eq!(t.output_bytes, 50);
    }
}
