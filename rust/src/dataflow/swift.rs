//! A Swift-subset frontend: parse the paper's workflow scripts and
//! compile them to task graphs.
//!
//! The paper programs its workflows in Swift (SIII); Fig 8 is the
//! production NF-HEDM stage-2 script. This module implements the
//! subset those figures use, so the repository's workflows are driven
//! by *the same scripts the paper shows*:
//!
//! ```swift
//! main {
//!     parameterFile = argv("p");
//!     microstructureFile = argv("m");
//!     start = toint(argp(1));
//!     end = toint(argp(2));
//!     foreach row in [start:end] {
//!         FitOrientation(parameterFile, row, microstructureFile);
//!     }
//! }
//! ```
//!
//! Semantics (faithful to implicitly-parallel Swift):
//! - every statement may run concurrently, ordered only by dataflow;
//! - `x = f(...)` makes later uses of `x` depend on that call;
//! - `foreach i in [a:b] { ... }` expands the body per index (`a..=b`,
//!   like Fig 8's row range), bodies mutually independent;
//! - *leaf functions* are host-registered builders mapping evaluated
//!   arguments to a [`Task`] (runtime model, inputs, outputs) — the
//!   "user code in compiled (C, C++) or scripting languages" of SIII.
//!
//! Not implemented (documented limits): user-defined Swift functions
//! and recursion (Fig 4's recursive merge is provided natively by
//! [`super::mapreduce`]), arrays, conditionals.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use super::graph::{Task, TaskGraph, TaskId};

/// A value in the interpreter.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
}

impl Value {
    pub fn as_str(&self) -> String {
        match self {
            Value::Str(s) => s.clone(),
            Value::Int(i) => i.to_string(),
        }
    }

    pub fn as_int(&self) -> Result<i64> {
        match self {
            Value::Int(i) => Ok(*i),
            Value::Str(s) => s.parse().map_err(|_| anyhow!("not an int: {s:?}")),
        }
    }
}

/// Builds a [`Task`] from a leaf-function invocation's evaluated args.
pub type LeafFn<'a> = Box<dyn FnMut(&[Value]) -> Task + 'a>;

/// The host environment a script runs against.
pub struct Env<'a> {
    /// Named arguments: `argv("p")`.
    pub argv: BTreeMap<String, String>,
    /// Positional arguments: `argp(1)`.
    pub argp: Vec<String>,
    /// Registered leaf functions.
    leaves: BTreeMap<String, LeafFn<'a>>,
}

impl<'a> Env<'a> {
    pub fn new() -> Self {
        Env { argv: BTreeMap::new(), argp: Vec::new(), leaves: BTreeMap::new() }
    }

    pub fn arg(mut self, key: &str, val: &str) -> Self {
        self.argv.insert(key.into(), val.into());
        self
    }

    pub fn pos(mut self, val: &str) -> Self {
        self.argp.push(val.into());
        self
    }

    pub fn leaf(mut self, name: &str, f: impl FnMut(&[Value]) -> Task + 'a) -> Self {
        self.leaves.insert(name.into(), Box::new(f));
        self
    }
}

impl Default for Env<'_> {
    fn default() -> Self {
        Self::new()
    }
}

// ---------------------------------------------------------------------------
// AST + parser (recursive descent over a token stream).
// ---------------------------------------------------------------------------

#[derive(Clone, Debug, PartialEq)]
enum Expr {
    Lit(Value),
    Var(String),
    /// builtin or leaf call.
    Call(String, Vec<Expr>),
}

#[derive(Clone, Debug)]
enum Stmt {
    /// `x = expr;`
    Assign(String, Expr),
    /// bare `f(args);`
    Call(Expr),
    /// `foreach i in [a:b] { body }`
    Foreach(String, Expr, Expr, Vec<Stmt>),
}

fn tokenize(src: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut chars = src.chars().peekable();
    while let Some(&c) = chars.peek() {
        match c {
            '/' => {
                chars.next();
                if chars.peek() == Some(&'/') {
                    while let Some(&d) = chars.peek() {
                        chars.next();
                        if d == '\n' {
                            break;
                        }
                    }
                } else {
                    out.push("/".into());
                }
            }
            c if c.is_whitespace() => {
                chars.next();
            }
            '"' => {
                chars.next();
                let mut s = String::from("\"");
                for d in chars.by_ref() {
                    if d == '"' {
                        break;
                    }
                    s.push(d);
                }
                out.push(s);
            }
            c if c.is_alphanumeric() || c == '_' => {
                let mut s = String::new();
                while let Some(&d) = chars.peek() {
                    if d.is_alphanumeric() || d == '_' {
                        s.push(d);
                        chars.next();
                    } else {
                        break;
                    }
                }
                out.push(s);
            }
            _ => {
                chars.next();
                out.push(c.to_string());
            }
        }
    }
    out
}

struct Parser {
    toks: Vec<String>,
    i: usize,
}

impl Parser {
    fn peek(&self) -> Option<&str> {
        self.toks.get(self.i).map(String::as_str)
    }

    fn next(&mut self) -> Result<String> {
        let t = self
            .toks
            .get(self.i)
            .cloned()
            .ok_or_else(|| anyhow!("unexpected end of script"))?;
        self.i += 1;
        Ok(t)
    }

    fn expect(&mut self, t: &str) -> Result<()> {
        let got = self.next()?;
        if got != t {
            bail!("expected {t:?}, got {got:?}");
        }
        Ok(())
    }

    fn program(&mut self) -> Result<Vec<Stmt>> {
        self.expect("main")?;
        self.expect("{")?;
        let body = self.block_body()?;
        if self.peek().is_some() {
            bail!("trailing tokens after main block");
        }
        Ok(body)
    }

    fn block_body(&mut self) -> Result<Vec<Stmt>> {
        let mut stmts = Vec::new();
        loop {
            match self.peek() {
                Some("}") => {
                    self.next()?;
                    return Ok(stmts);
                }
                Some(_) => stmts.push(self.stmt()?),
                None => bail!("unterminated block"),
            }
        }
    }

    fn stmt(&mut self) -> Result<Stmt> {
        if self.peek() == Some("foreach") {
            self.next()?;
            let var = self.next()?;
            self.expect("in")?;
            self.expect("[")?;
            let lo = self.expr()?;
            self.expect(":")?;
            let hi = self.expr()?;
            self.expect("]")?;
            self.expect("{")?;
            let body = self.block_body()?;
            return Ok(Stmt::Foreach(var, lo, hi, body));
        }
        let first = self.next()?;
        if self.peek() == Some("=") {
            self.next()?;
            let e = self.expr()?;
            self.expect(";")?;
            Ok(Stmt::Assign(first, e))
        } else if self.peek() == Some("(") {
            let call = self.call_after_name(first)?;
            self.expect(";")?;
            Ok(Stmt::Call(call))
        } else {
            bail!("expected '=' or '(' after {first:?}")
        }
    }

    fn call_after_name(&mut self, name: String) -> Result<Expr> {
        self.expect("(")?;
        let mut args = Vec::new();
        if self.peek() != Some(")") {
            loop {
                args.push(self.expr()?);
                match self.peek() {
                    Some(",") => {
                        self.next()?;
                    }
                    Some(")") => break,
                    other => bail!("expected ',' or ')', got {other:?}"),
                }
            }
        }
        self.expect(")")?;
        Ok(Expr::Call(name, args))
    }

    fn expr(&mut self) -> Result<Expr> {
        let t = self.next()?;
        if let Some(s) = t.strip_prefix('"') {
            return Ok(Expr::Lit(Value::Str(s.to_string())));
        }
        if let Ok(n) = t.parse::<i64>() {
            return Ok(Expr::Lit(Value::Int(n)));
        }
        if t == "-" {
            let n = self.next()?;
            let n: i64 = n.parse().map_err(|_| anyhow!("bad negative literal"))?;
            return Ok(Expr::Lit(Value::Int(-n)));
        }
        if self.peek() == Some("(") {
            return self.call_after_name(t);
        }
        Ok(Expr::Var(t))
    }
}

// ---------------------------------------------------------------------------
// Interpreter: evaluate the script, emitting tasks into a TaskGraph
// with def-use dataflow dependencies.
// ---------------------------------------------------------------------------

struct Interp<'e, 'a> {
    env: &'e mut Env<'a>,
    graph: TaskGraph,
    /// Variable -> (value, producing task if any).
    vars: BTreeMap<String, (Value, Option<TaskId>)>,
}

impl Interp<'_, '_> {
    fn eval(&mut self, e: &Expr, deps: &mut Vec<TaskId>) -> Result<Value> {
        match e {
            Expr::Lit(v) => Ok(v.clone()),
            Expr::Var(name) => {
                let (v, producer) = self
                    .vars
                    .get(name)
                    .ok_or_else(|| anyhow!("undefined variable {name:?}"))?
                    .clone();
                if let Some(t) = producer {
                    deps.push(t);
                }
                Ok(v)
            }
            Expr::Call(name, args) => match name.as_str() {
                "argv" => {
                    let key = self.eval(&args[0], deps)?.as_str();
                    self.env
                        .argv
                        .get(&key)
                        .map(|s| Value::Str(s.clone()))
                        .ok_or_else(|| anyhow!("missing argv {key:?}"))
                }
                "argp" => {
                    let idx = self.eval(&args[0], deps)?.as_int()? as usize;
                    self.env
                        .argp
                        .get(idx.checked_sub(1).ok_or_else(|| anyhow!("argp(0)"))?)
                        .map(|s| Value::Str(s.clone()))
                        .ok_or_else(|| anyhow!("missing argp {idx}"))
                }
                "toint" | "string2int" => {
                    let v = self.eval(&args[0], deps)?;
                    Ok(Value::Int(v.as_int()?))
                }
                "strcat" => {
                    let mut s = String::new();
                    for a in args {
                        s.push_str(&self.eval(a, deps)?.as_str());
                    }
                    Ok(Value::Str(s))
                }
                _ => bail!("{name:?} is a leaf function; call it as a statement"),
            },
        }
    }

    fn exec_call(&mut self, e: &Expr) -> Result<Option<TaskId>> {
        let Expr::Call(name, args) = e else { bail!("not a call") };
        let mut deps = Vec::new();
        let vals: Vec<Value> = args
            .iter()
            .map(|a| self.eval(a, &mut deps))
            .collect::<Result<_>>()?;
        let leaf = self
            .env
            .leaves
            .get_mut(name.as_str())
            .ok_or_else(|| anyhow!("unknown leaf function {name:?}"))?;
        let mut task = leaf(&vals);
        deps.sort();
        deps.dedup();
        for d in deps {
            task = task.with_dep(d);
        }
        Ok(Some(self.graph.add(task)))
    }

    fn exec_block(&mut self, stmts: &[Stmt]) -> Result<()> {
        for s in stmts {
            match s {
                Stmt::Assign(name, expr) => match expr {
                    Expr::Call(f, _) if self.env.leaves.contains_key(f.as_str()) => {
                        let t = self.exec_call(expr)?;
                        self.vars
                            .insert(name.clone(), (Value::Str(name.clone()), t));
                    }
                    _ => {
                        let mut deps = Vec::new();
                        let v = self.eval(expr, &mut deps)?;
                        // Pure expressions carry their producers forward.
                        let producer = deps.into_iter().next();
                        self.vars.insert(name.clone(), (v, producer));
                    }
                },
                Stmt::Call(expr) => {
                    self.exec_call(expr)?;
                }
                Stmt::Foreach(var, lo, hi, body) => {
                    let mut deps = Vec::new();
                    let lo = self.eval(lo, &mut deps)?.as_int()?;
                    let hi = self.eval(hi, &mut deps)?.as_int()?;
                    let saved = self.vars.get(var).cloned();
                    for i in lo..=hi {
                        self.vars.insert(var.clone(), (Value::Int(i), None));
                        self.exec_block(body)?;
                    }
                    match saved {
                        Some(v) => {
                            self.vars.insert(var.clone(), v);
                        }
                        None => {
                            self.vars.remove(var);
                        }
                    }
                }
            }
        }
        Ok(())
    }
}

/// Parse and evaluate `src` against `env`; returns the compiled task
/// graph (run it with [`super::sched::run_workflow`]).
pub fn compile(src: &str, env: &mut Env) -> Result<TaskGraph> {
    let mut p = Parser { toks: tokenize(src), i: 0 };
    let stmts = p.program()?;
    let mut interp = Interp { env, graph: TaskGraph::new(), vars: BTreeMap::new() };
    interp.exec_block(&stmts)?;
    if interp.graph.is_empty() {
        bail!("script produced no tasks");
    }
    Ok(interp.graph)
}

/// The paper's Fig 8 script, verbatim (modulo the line-wrap artifact).
pub const FIG8_NF_STAGE2: &str = r#"
main {
    parameterFile = argv("p");
    microstructureFile = argv("m");
    start = toint(argp(1));
    end = toint(argp(2));
    foreach row in [start:end] {
        FitOrientation(parameterFile, row, microstructureFile);
    }
}
"#;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::units::Duration;

    fn fit_env(count: std::rc::Rc<std::cell::RefCell<Vec<Vec<Value>>>>) -> Env<'static> {
        Env::new()
            .arg("p", "/tmp/hedm/ps.txt")
            .arg("m", "/projects/out/micro.bin")
            .pos("0")
            .pos("9")
            .leaf("FitOrientation", move |args| {
                count.borrow_mut().push(args.to_vec());
                Task::compute("fit", Duration::from_secs(30))
                    .with_input(args[0].as_str(), None)
            })
    }

    #[test]
    fn fig8_compiles_to_row_tasks() {
        let calls = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
        let mut env = fit_env(calls.clone());
        let g = compile(FIG8_NF_STAGE2, &mut env).unwrap();
        assert_eq!(g.len(), 10); // rows 0..=9
        assert_eq!(g.roots().len(), 10); // implicitly parallel
        let calls = calls.borrow();
        assert_eq!(calls[3][0], Value::Str("/tmp/hedm/ps.txt".into()));
        assert_eq!(calls[3][1], Value::Int(3));
        assert_eq!(calls[3][2], Value::Str("/projects/out/micro.bin".into()));
        // Every task reads the staged parameter file.
        assert!(g.tasks.iter().all(|t| t.inputs[0].path == "/tmp/hedm/ps.txt"));
    }

    #[test]
    fn dataflow_dependencies_from_assignment() {
        // b consumes a's output variable: b depends on a; c is free.
        let src = r#"
        main {
            x = produce("in");
            consume(x);
            other("y");
        }
        "#;
        let mut env = Env::new()
            .leaf("produce", |_| Task::compute("p", Duration::from_secs(1)))
            .leaf("consume", |_| Task::compute("c", Duration::from_secs(1)))
            .leaf("other", |_| Task::compute("o", Duration::from_secs(1)));
        let g = compile(src, &mut env).unwrap();
        assert_eq!(g.len(), 3);
        assert_eq!(g.tasks[1].deps, vec![crate::dataflow::graph::TaskId(0)]);
        assert!(g.tasks[2].deps.is_empty());
    }

    #[test]
    fn foreach_bodies_are_independent() {
        let src = r#"
        main {
            foreach i in [1:4] {
                work(i);
            }
        }
        "#;
        let mut env =
            Env::new().leaf("work", |_| Task::compute("w", Duration::from_secs(1)));
        let g = compile(src, &mut env).unwrap();
        assert_eq!(g.len(), 4);
        assert_eq!(g.roots().len(), 4);
        assert_eq!(g.critical_path(), Duration::from_secs(1));
    }

    #[test]
    fn chains_inside_foreach() {
        // Per-iteration two-stage pipeline: reduce(i) -> fit(i).
        let src = r#"
        main {
            foreach i in [0:9] {
                r = reduce(i);
                fit(r);
            }
        }
        "#;
        let mut env = Env::new()
            .leaf("reduce", |_| Task::compute("r", Duration::from_secs(2)))
            .leaf("fit", |_| Task::compute("f", Duration::from_secs(3)));
        let g = compile(src, &mut env).unwrap();
        assert_eq!(g.len(), 20);
        assert_eq!(g.critical_path(), Duration::from_secs(5));
        assert_eq!(g.roots().len(), 10);
    }

    #[test]
    fn comments_and_builtins() {
        let src = r#"
        main {
            // threshold sweep tag
            tag = strcat("run-", argv("id"));
            work(tag);
        }
        "#;
        let seen = std::rc::Rc::new(std::cell::RefCell::new(String::new()));
        let seen2 = seen.clone();
        let mut env = Env::new().arg("id", "7").leaf("work", move |args| {
            *seen2.borrow_mut() = args[0].as_str();
            Task::compute("w", Duration::ZERO)
        });
        compile(src, &mut env).unwrap();
        assert_eq!(*seen.borrow(), "run-7");
    }

    #[test]
    fn error_cases() {
        let mut env = Env::new();
        assert!(compile("", &mut env).is_err());
        assert!(compile("main { x = ; }", &mut env).is_err());
        assert!(compile("main { nosuch(1); }", &mut env).is_err());
        assert!(compile("main { x = argv(\"missing\"); work(x); }", &mut env).is_err());
        assert!(compile("main { foreach i in [1:3] { }", &mut env).is_err());
    }

    #[test]
    fn fig8_runs_on_the_simulated_machine() {
        use crate::cluster::{orthros, Topology};
        use crate::dataflow::sched::{run_workflow, SchedulerCfg};
        use crate::engine::SimCore;
        use crate::mpisim::Comm;
        use crate::pfs::{Blob, GpfsParams};

        let mut env = Env::new()
            .arg("p", "/tmp/hedm/ps.txt")
            .arg("m", "/projects/out/micro.bin")
            .pos("0")
            .pos("600") // the Fig 2 grid: 601 points
            .leaf("FitOrientation", |args| {
                Task::compute(format!("fit{}", args[1].as_str()), Duration::from_secs(30))
                    .with_input(args[0].as_str(), None)
            });
        let g = compile(FIG8_NF_STAGE2, &mut env).unwrap();
        assert_eq!(g.len(), 601);

        let mut core = SimCore::new();
        let topo = Topology::build(orthros(), GpfsParams::default(), &mut core.net);
        let comm = Comm::world(&topo.spec);
        core.node_write_range(0, 4, "/tmp/hedm/ps.txt", Blob::synthetic(1 << 20, 1));
        let stats = run_workflow(&mut core, &topo, &comm, g, SchedulerCfg::default());
        // 601 x 30 s on 320 cores ~= 2 waves -> ~60 s.
        let m = stats.makespan.secs_f64();
        assert!(m > 55.0 && m < 75.0, "{m}");
    }
}
