//! The task scheduler: ADLB-style worker pool over the simulated
//! machine.
//!
//! ADLB (Lusk et al. [8]) gives Swift/T its work distribution: worker
//! ranks pull ready tasks from server ranks; dispatch costs a small
//! round-trip. The simulation models exactly that: a free-slot pool
//! (one slot per worker rank), a FIFO ready queue released by
//! dataflow dependencies, a fixed per-dispatch overhead, and per-task
//! input-read charging:
//!
//! - input present in the node-local store -> RAM-disk stream at the
//!   machine's measured per-process rate (53.4 MB/s on BG/Q /tmp);
//! - input only on the shared FS -> uncoordinated GPFS read through
//!   the degrading path (the naive mode's cost, per task);
//! - input cached by a previous task of the same worker process
//!   (SVI-B) -> free.
//!
//! Determinism: slot pool and ready queue are strictly ordered; equal
//! event times break by insertion sequence in the engine's heap.
//!
//! Two schedulers share one set of placement/plan-building internals:
//!
//! - [`Scheduler`] — the original single-workflow form ([`run_workflow`]):
//!   one task graph, run to completion.
//! - [`SessionScheduler`] — the interactive serving form: many
//!   independently-submitted session graphs share the worker pool
//!   concurrently, with **session-fair** dispatch (the next free slot
//!   goes to the admitted session with the least compute dispatched so
//!   far) and per-session accounting. With exactly one session the
//!   fair policy degenerates to the FIFO baseline and the two are
//!   bit-identical (tested).
//!
//! # Failure handling
//!
//! The [`SessionScheduler`] additionally tolerates node death (the
//! [`crate::chaos`] event source): [`SessionScheduler::on_node_failure`]
//! aborts the engine plans of every task that was computing on the dead
//! node — the engine emits **no** `PlanDone` for an aborted plan, so
//! resubmitting the task under the same tag yields exactly one
//! completion per task (the TLA `NoTaskDuplication` / `NoTaskLoss`
//! invariants) — returns the lost tasks to their sessions' ready
//! queues, and frees the slots for the warm replacement node. With
//! [`SchedulerCfg::work_stealing`] enabled the lost tasks requeue at
//! the *front* of the ready queue so the next free slot anywhere on
//! the machine steals them immediately; disabled, they requeue at the
//! back like freshly-released dependents. Either way a run with zero
//! failures never reaches this code, so both settings are
//! decision-identical to the seed FIFO scheduler until a node actually
//! dies (tested).

use std::collections::BTreeSet;
use std::collections::HashSet;
use std::collections::VecDeque;
use std::mem::size_of;

use crate::cluster::Topology;
use crate::engine::{Director, Notice, SimCore};
use crate::mpisim::Comm;
use crate::simtime::plan::{Plan, PlanId};
use crate::units::{Duration, SimTime};

use super::graph::{TaskGraph, TaskId};

/// Tag namespace for scheduler-owned plans (avoids collision with
/// staging/transfer plans sharing the engine).
pub const TASK_TAG_BASE: u64 = 1 << 48;

/// Identifies an analysis session inside a [`SessionScheduler`].
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct SessionId(pub u32);

/// Engine tag of a session task:
/// `TASK_TAG_BASE + (session << 32) + task`.
pub fn session_task_tag(sid: SessionId, tid: TaskId) -> u64 {
    assert!((tid.0 as u64) < (1 << 32), "task index overflows tag");
    assert!((sid.0 as u64) < (1 << 16), "session index overflows tag");
    TASK_TAG_BASE + ((sid.0 as u64) << 32) + tid.0 as u64
}

/// Inverse of [`session_task_tag`]; `None` for non-task tags.
pub fn decode_task_tag(tag: u64) -> Option<(SessionId, TaskId)> {
    let rel = tag.checked_sub(TASK_TAG_BASE)?;
    Some((SessionId((rel >> 32) as u32), TaskId((rel & 0xffff_ffff) as usize)))
}

/// Which implementation drives the [`SessionScheduler`] fair pick.
/// Both compute the same session — the admitted session with the
/// least dispatched compute, ties to the lower id — so schedules are
/// bit-identical; only the cost per pick differs. Debug builds assert
/// the equivalence on every single pick.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FairPick {
    /// The seed implementation: a linear scan of the live-session
    /// list. O(live) per dispatched task — fine to a few hundred
    /// concurrent sessions, quadratic pain at 10⁴.
    Scan,
    /// Indexed: an ordered set keyed `(dispatched_work, session_id)`
    /// holding exactly the live sessions with ready tasks, updated in
    /// place as keys change. O(log live) per dispatched task.
    Indexed,
}

/// Scheduler configuration.
#[derive(Clone, Copy, Debug)]
pub struct SchedulerCfg {
    /// ADLB dispatch round-trip per task.
    pub dispatch_overhead: Duration,
    /// Enable the worker-process input cache (SVI-B optimisation).
    pub cache_inputs: bool,
    /// Locality-aware placement: prefer free slots on nodes whose
    /// RAM disk already holds every staged input of the task, falling
    /// back to the baseline slot (and its re-stage-from-GPFS read
    /// path) when no replica-holding node has a free slot. When every
    /// node holds the inputs — the workload fits in node memory — the
    /// preferred slot *is* the baseline slot, so placement, timing,
    /// and stats are bit-identical to the baseline scheduler.
    pub locality_aware: bool,
    /// Fair-pick implementation (see [`FairPick`]); schedules are
    /// identical either way.
    pub fair_pick: FairPick,
    /// Intern session input paths to dense ids at admission and drive
    /// every per-task storage query (coverage, reads, LRU touches,
    /// cache keys) through the id surface instead of string lookups.
    /// Queries answer identically (the interner is a bijection), so
    /// this is cost-only; off reproduces the seed string-keyed walks
    /// for A/B measurement.
    pub interned_paths: bool,
    /// When a node dies, requeue its lost tasks at the *front* of
    /// their sessions' ready queues so the next free slot anywhere
    /// steals them immediately, instead of behind every
    /// already-released task. Only node failures exercise the switch,
    /// so at failure rate zero it is decision-identical to the seed
    /// FIFO scheduler (tested).
    pub work_stealing: bool,
}

impl Default for SchedulerCfg {
    fn default() -> Self {
        SchedulerCfg {
            dispatch_overhead: Duration::from_micros(500),
            cache_inputs: false,
            locality_aware: false,
            fair_pick: FairPick::Indexed,
            interned_paths: true,
            work_stealing: false,
        }
    }
}

/// Outcome of a workflow run.
#[derive(Clone, Debug)]
pub struct WorkflowStats {
    /// Virtual time from scheduler start to last task completion.
    pub makespan: Duration,
    pub tasks_run: usize,
    /// Worker-seconds of pure compute in the graph.
    pub total_work: Duration,
    /// total_work / (makespan * workers): 1.0 = perfectly packed.
    pub utilization: f64,
    /// Completion time of every task, by TaskId index.
    pub completion: Vec<SimTime>,
    /// Bytes read from node-local staged replicas / the node SSD tier
    /// / the shared FS.
    pub staged_read_bytes: u64,
    pub ssd_read_bytes: u64,
    pub unstaged_read_bytes: u64,
    /// Reads skipped by the worker input cache.
    pub cache_hits: u64,
}

/// Input-read accounting shared by [`Scheduler`] and each session of a
/// [`SessionScheduler`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReadStats {
    /// Bytes read from node-local staged RAM replicas.
    pub staged_bytes: u64,
    /// Bytes streamed from the node-local SSD tier (demoted replicas
    /// read in place, still never touching the shared FS).
    pub ssd_bytes: u64,
    /// Bytes read (or re-read) from the shared FS.
    pub unstaged_bytes: u64,
    /// Bytes streamed from a surviving peer's RAM replica over the
    /// interconnect — the node-failure recovery read path, reachable
    /// only after a failure erased the local replica of a dataset
    /// that has no shared-FS fallback (zero in every failure-free
    /// run).
    pub peer_bytes: u64,
    /// Reads skipped by the worker input cache.
    pub cache_hits: u64,
}

/// Index into `free_slots` of the slot `tid` should occupy.
/// Baseline: the top of the LIFO pool. Locality-aware: the topmost
/// slot whose node already holds every staged input in RAM; failing
/// that, the topmost slot where every input is at least node-local
/// (RAM or the SSD tier — a local stream still beats a shared-FS
/// re-read); top-of-pool fallback when none (or when the task reads
/// nothing). `ids` (when the caller pre-interned the task's input
/// paths) routes the coverage lookups through the O(1) id surface;
/// the answers are identical either way.
fn pick_slot_in(
    core: &SimCore,
    cfg: &SchedulerCfg,
    graph: &TaskGraph,
    tid: TaskId,
    ids: Option<&[u32]>,
    free_slots: &[u32],
) -> usize {
    let top = free_slots.len() - 1;
    if !cfg.locality_aware {
        return top;
    }
    let task = &graph.tasks[tid.0];
    if task.inputs.is_empty() {
        return top;
    }
    // Resolve each input's resident coverage once per task, not once
    // per free slot: the slot scan then tests plain ranges. Each
    // resolution is a borrow of the store's memoized coverage (no
    // replica rescan, no allocation) — the serve/campaign dispatch
    // inner loop runs this per task.
    let ram_cov: Vec<&[(u32, u32)]> = match ids {
        Some(ids) => ids.iter().map(|&id| core.nodes.coverage_of_id(id)).collect(),
        None => task.inputs.iter().map(|i| core.nodes.coverage_of(&i.path)).collect(),
    };
    let in_cov = |c: &[(u32, u32)], node: u32| c.iter().any(|&(a, b)| (a..=b).contains(&node));
    if ram_cov.iter().all(|c| !c.is_empty()) {
        for (idx, &node) in free_slots.iter().enumerate().rev() {
            if ram_cov.iter().all(|c| in_cov(c, node)) {
                return idx;
            }
        }
    }
    // RAM placement failed; try nodes where every input is at least
    // node-local counting the SSD tier (only on machines that model
    // one — coverage is empty otherwise, costing nothing extra).
    let ssd_cov: Vec<&[(u32, u32)]> = match ids {
        Some(ids) => ids
            .iter()
            .map(|&id| core.nodes.coverage_of_tier_id(crate::storage::StorageTier::Ssd, id))
            .collect(),
        None => task
            .inputs
            .iter()
            .map(|i| core.nodes.coverage_of_tier(crate::storage::StorageTier::Ssd, &i.path))
            .collect(),
    };
    if ram_cov
        .iter()
        .zip(&ssd_cov)
        .all(|(r, s)| !r.is_empty() || !s.is_empty())
    {
        for (idx, &node) in free_slots.iter().enumerate().rev() {
            if ram_cov
                .iter()
                .zip(&ssd_cov)
                .all(|(r, s)| in_cov(r, node) || in_cov(s, node))
            {
                return idx;
            }
        }
    }
    top
}

/// First surviving RAM holder of `path` (lowest node id) and the
/// replica's length — the node-failure recovery read source. Only
/// consulted after the local RAM, local SSD, and shared-FS branches
/// all miss, which cannot happen in a failure-free run (a `/tmp` path
/// was either staged onto this node or never existed here at all), so
/// the no-failure schedule never depends on it.
fn peer_replica(core: &SimCore, path: &str, id: Option<u32>) -> Option<(u32, u64)> {
    let donor = match id {
        Some(id) => core.nodes.coverage_of_id(id),
        None => core.nodes.coverage_of(path),
    }
    .first()
    .map(|&(lo, _)| lo)?;
    let len = match id {
        Some(id) => core.nodes.read_id(donor, id),
        None => core.nodes.read(donor, path),
    }
    .map(crate::pfs::Blob::len)?;
    Some((donor, len))
}

/// Per-node length of `path` in the SSD tier, when the machine times
/// SSD streams (one lookup for the dispatch hot path; None on a
/// machine without an SSD layer, so a pathless infinite-rate flow can
/// never arise).
fn ssd_stream_len(
    core: &SimCore,
    topo: &Topology,
    node: u32,
    path: &str,
    id: Option<u32>,
) -> Option<u64> {
    if topo.ssd_layer.is_none() {
        return None;
    }
    match id {
        Some(id) => core.nodes.read_tier_id(crate::storage::StorageTier::Ssd, node, id),
        None => core.nodes.read_tier(crate::storage::StorageTier::Ssd, node, path),
    }
    .map(crate::pfs::Blob::len)
}

/// Build the per-task plan: dispatch overhead -> input reads ->
/// compute -> output write. `cache` and `reads` carry the caller's
/// (per-workflow or per-session) input-cache and byte accounting.
/// `ids` (input paths pre-interned at admission, aligned with
/// `task.inputs`) routes the storage reads and LRU touches through
/// the id surface; behaviour is identical either way.
#[allow(clippy::too_many_arguments)]
fn build_task_plan(
    core: &mut SimCore,
    topo: &Topology,
    cfg: &SchedulerCfg,
    graph: &TaskGraph,
    tid: TaskId,
    node: u32,
    tag: u64,
    ids: Option<&[u32]>,
    cache: &mut HashSet<(u32, u32)>,
    reads: &mut ReadStats,
) -> Plan {
    let task = &graph.tasks[tid.0];
    let mut p = Plan::new(tag);
    let mut prev = p.delay(cfg.dispatch_overhead, vec![], "dispatch");

    // Input reads.
    let mut local_bytes = 0u64;
    for (j, input) in task.inputs.iter().enumerate() {
        let pid = ids.map(|ids| ids[j]);
        // (node, path-id) worker cache: insert returns false when the
        // path is already warm on this node. Keys are dense ids —
        // interned here on first sight when the caller didn't
        // pre-intern — so a long-lived serving core holds u32 pairs,
        // not per-entry String clones. Ids are bijective with paths,
        // so hit/miss behaviour matches the string-keyed seed cache
        // exactly.
        if cfg.cache_inputs {
            let key = match pid {
                Some(id) => id,
                None => core.nodes.intern_path(&input.path),
            };
            if !cache.insert((node, key)) {
                reads.cache_hits += 1;
                continue;
            }
        }
        let staged = match pid {
            Some(id) => core.nodes.read_id(node, id).map(crate::pfs::Blob::len),
            None => core.nodes.read(node, &input.path).map(crate::pfs::Blob::len),
        };
        if let Some(blob_len) = staged {
            // Staged: node-local stream, perfectly scalable -> a
            // pure delay at the per-process RAM-disk rate (not a
            // flownet flow; it contends with nothing).
            let bytes = input.bytes.unwrap_or(blob_len);
            local_bytes += bytes;
            reads.staged_bytes += bytes;
            // The read refreshes the replica's LRU recency.
            match pid {
                Some(id) => core.nodes.touch_id(node, id),
                None => core.nodes.touch(node, &input.path),
            }
        } else if let Some(blob_len) = ssd_stream_len(core, topo, node, &input.path, pid) {
            // Demoted to the node's SSD tier: stream it in place over
            // the machine's SSD layer — slower than RAM, but still
            // off the shared FS. The read refreshes the SSD replica's
            // recency, like the RAM branch's touch.
            let bytes = input.bytes.unwrap_or(blob_len);
            reads.ssd_bytes += bytes;
            match pid {
                Some(id) => core.nodes.touch_tier_id(crate::storage::StorageTier::Ssd, node, id),
                None => core.nodes.touch_tier(crate::storage::StorageTier::Ssd, node, &input.path),
            }
            prev = p.flow_capped(
                topo.path_ssd(),
                1,
                bytes,
                topo.spec.ssd_bw,
                vec![prev],
                "read",
            );
        } else if let Some(blob) = core.pfs.read(&input.path) {
            // Not staged: fall back to an uncoordinated GPFS read —
            // this IS the per-task naive I/O pattern.
            let bytes = input.bytes.unwrap_or(blob.len());
            reads.unstaged_bytes += bytes;
            prev = p.flow(
                topo.path_uncoordinated_read(),
                1,
                bytes,
                vec![prev],
                "read",
            );
        } else if let Some((donor, blob_len)) = peer_replica(core, &input.path, pid) {
            // Recovery read: a failure erased this node's replica of a
            // node-local-only path, but a peer still holds it — stream
            // it over the interconnect instead of dying. The donor
            // read refreshes that replica's recency like any other.
            let bytes = input.bytes.unwrap_or(blob_len);
            reads.peer_bytes += bytes;
            match pid {
                Some(id) => core.nodes.touch_id(donor, id),
                None => core.nodes.touch(donor, &input.path),
            }
            prev = p.flow(topo.path_torus(), 1, bytes, vec![prev], "read");
        } else if let Some(bytes) = input.bytes {
            // Size-only input (pure timing model, no data plane).
            reads.unstaged_bytes += bytes;
            prev = p.flow(
                topo.path_uncoordinated_read(),
                1,
                bytes,
                vec![prev],
                "read",
            );
        } else {
            panic!(
                "task {:?} input {:?} not found on node {node} nor shared FS",
                task.name, input.path
            );
        }
    }
    if local_bytes > 0 {
        let dur = crate::units::transfer_time(local_bytes, topo.spec.ramdisk_proc_read_bw);
        prev = p.delay(dur, vec![prev], "read");
    }

    // Compute.
    prev = p.delay(task.runtime, vec![prev], "compute");

    // Output write to the shared FS (small results, coordinated).
    if task.output_bytes > 0 {
        p.flow(
            topo.path_coordinated_read(), // same links, reverse dir
            1,
            task.output_bytes,
            vec![prev],
            "output",
        );
    }
    p
}

/// Dataflow bookkeeping for one task graph: the ready queue released
/// by dependencies and per-task completion state. Both schedulers run
/// their graphs through this one implementation, so the
/// single-session [`SessionScheduler`] == [`Scheduler`] bit-identity
/// is structural, not hand-synced.
struct GraphRun {
    graph: TaskGraph,
    /// Tasks whose deps are satisfied, FIFO.
    ready: VecDeque<TaskId>,
    /// Unsatisfied dependency counts.
    missing: Vec<u32>,
    /// Dependents adjacency.
    dependents: Vec<Vec<u32>>,
    /// Node a running task occupies.
    running_node: Vec<u32>,
    /// Engine plan id of a running task (`u32::MAX` when not
    /// running), so a node failure can abort exactly the plans that
    /// died with the node.
    running_plan: Vec<u32>,
    completion: Vec<SimTime>,
    remaining: usize,
}

impl GraphRun {
    fn new(graph: TaskGraph) -> GraphRun {
        let n = graph.len();
        assert!(n > 0, "empty task graph");
        graph.topo_order().expect("task graph has a cycle");
        let mut missing = vec![0u32; n];
        let mut dependents: Vec<Vec<u32>> = vec![Vec::new(); n];
        let mut ready = VecDeque::new();
        for (i, t) in graph.tasks.iter().enumerate() {
            missing[i] = t.deps.len() as u32;
            for d in &t.deps {
                dependents[d.0].push(i as u32);
            }
            if t.deps.is_empty() {
                ready.push_back(TaskId(i));
            }
        }
        GraphRun {
            ready,
            missing,
            dependents,
            running_node: vec![u32::MAX; n],
            running_plan: vec![u32::MAX; n],
            completion: vec![SimTime::ZERO; n],
            remaining: n,
            graph,
        }
    }

    /// Record `tid` as dispatched onto `node`.
    fn launch(&mut self, tid: TaskId, node: u32) {
        self.running_node[tid.0] = node;
    }

    /// Mark `tid` complete at `now`, release newly-ready dependents
    /// into the queue, and return the node it occupied.
    fn complete(&mut self, tid: TaskId, now: SimTime) -> u32 {
        self.completion[tid.0] = now;
        self.remaining -= 1;
        let node = std::mem::replace(&mut self.running_node[tid.0], u32::MAX);
        debug_assert_ne!(node, u32::MAX, "completion of non-running task");
        self.running_plan[tid.0] = u32::MAX;
        for d in std::mem::take(&mut self.dependents[tid.0]) {
            self.missing[d as usize] -= 1;
            if self.missing[d as usize] == 0 {
                self.ready.push_back(TaskId(d as usize));
            }
        }
        node
    }

    fn is_done(&self) -> bool {
        self.remaining == 0
    }
}

/// The worker slot pool: node ids, one entry per free rank, LIFO.
/// Highest node pushed first so pop() hands out node 0 first —
/// deterministic and friendly to small debug traces.
fn build_slot_pool(comm: &Comm) -> Vec<u32> {
    let mut free_slots = Vec::with_capacity(comm.size() as usize);
    for node in (comm.node_lo..=comm.node_hi).rev() {
        for _ in 0..comm.ranks_per_node {
            free_slots.push(node);
        }
    }
    free_slots
}

/// The scheduler; implements [`Director`] so the engine drives it.
pub struct Scheduler {
    topo: Topology,
    comm: Comm,
    cfg: SchedulerCfg,
    run: GraphRun,
    /// Free worker slots (see [`build_slot_pool`]).
    free_slots: Vec<u32>,
    /// (node, path-id) pairs already read by some worker on that node.
    cache: HashSet<(u32, u32)>,
    start: Option<SimTime>,
    reads: ReadStats,
}

impl Scheduler {
    pub fn new(topo: Topology, comm: Comm, graph: TaskGraph, cfg: SchedulerCfg) -> Scheduler {
        Scheduler {
            topo,
            comm,
            cfg,
            run: GraphRun::new(graph),
            free_slots: build_slot_pool(&comm),
            cache: HashSet::new(),
            start: None,
            reads: ReadStats::default(),
        }
    }

    /// Launch as many ready tasks as there are free slots.
    fn dispatch(&mut self, core: &mut SimCore) {
        if self.start.is_none() {
            self.start = Some(core.now);
        }
        while !self.run.ready.is_empty() && !self.free_slots.is_empty() {
            let tid = self.run.ready.pop_front().unwrap();
            let idx = pick_slot_in(core, &self.cfg, &self.run.graph, tid, None, &self.free_slots);
            // swap_remove of the top index == pop: the baseline path
            // and a satisfied locality preference at the top slot are
            // byte-identical in slot-pool evolution.
            let node = self.free_slots.swap_remove(idx);
            self.run.launch(tid, node);
            let plan = build_task_plan(
                core,
                &self.topo,
                &self.cfg,
                &self.run.graph,
                tid,
                node,
                TASK_TAG_BASE + tid.0 as u64,
                None,
                &mut self.cache,
                &mut self.reads,
            );
            let pid = core.submit(plan);
            self.run.running_plan[tid.0] = pid.0 as u32;
        }
    }

    fn on_task_done(&mut self, core: &mut SimCore, tid: TaskId) {
        let node = self.run.complete(tid, core.now);
        self.free_slots.push(node);
        self.dispatch(core);
    }

    pub fn is_done(&self) -> bool {
        self.run.is_done()
    }

    pub fn stats(&self, end: SimTime) -> WorkflowStats {
        assert!(self.is_done(), "workflow incomplete");
        let start = self.start.unwrap_or(SimTime::ZERO);
        let makespan = end - start;
        let total_work = self.run.graph.total_work();
        let workers = self.comm.size() as f64;
        let util = if makespan.0 == 0 {
            0.0
        } else {
            total_work.secs_f64() / (makespan.secs_f64() * workers)
        };
        WorkflowStats {
            makespan,
            tasks_run: self.run.graph.len(),
            total_work,
            utilization: util,
            completion: self.run.completion.clone(),
            staged_read_bytes: self.reads.staged_bytes,
            ssd_read_bytes: self.reads.ssd_bytes,
            unstaged_read_bytes: self.reads.unstaged_bytes,
            cache_hits: self.reads.cache_hits,
        }
    }
}

impl Director for Scheduler {
    fn on_notice(&mut self, core: &mut SimCore, notice: Notice) {
        if let Notice::PlanDone { tag, .. } = notice {
            if tag >= TASK_TAG_BASE {
                self.on_task_done(core, TaskId((tag - TASK_TAG_BASE) as usize));
            }
        }
    }
}

/// Run `graph` on `core` over `comm` and return the stats. The
/// scheduler starts at `core.now` (run staging first on the same core
/// to model the paper's phase structure).
pub fn run_workflow(
    core: &mut SimCore,
    topo: &Topology,
    comm: &Comm,
    graph: TaskGraph,
    cfg: SchedulerCfg,
) -> WorkflowStats {
    let mut sched = Scheduler::new(topo.clone(), *comm, graph, cfg);
    let t0 = core.now;
    sched.start = Some(t0);
    sched.dispatch(core);
    core.run(&mut sched);
    assert!(sched.is_done(), "workflow did not complete");
    sched.stats(core.now)
}

// ----------------------------------------------------------------------
// Session-fair multi-graph scheduling (interactive serving)
// ----------------------------------------------------------------------

/// Per-session outcome of a [`SessionScheduler`] run.
#[derive(Clone, Debug)]
pub struct SessionStats {
    /// When the session's graph was handed to the scheduler.
    pub submitted: SimTime,
    /// When its last task completed.
    pub finished: SimTime,
    pub tasks_run: usize,
    /// Worker-seconds of pure compute in the session's graph.
    pub total_work: Duration,
    pub reads: ReadStats,
    /// Completion time of every task, by TaskId index.
    pub completion: Vec<SimTime>,
}

impl SessionStats {
    /// Execution span inside the scheduler (excludes admission
    /// queueing and staging, which the serving layer accounts).
    pub fn makespan(&self) -> Duration {
        self.finished - self.submitted
    }
}

/// One admitted session's state: the shared [`GraphRun`] dataflow
/// bookkeeping plus the per-tenant accounting the fair policy needs.
struct SessionRun {
    run: GraphRun,
    /// Per-session worker input cache (sessions are independent
    /// tenants; one session's reads must not warm another's cache).
    cache: HashSet<(u32, u32)>,
    reads: ReadStats,
    submitted: SimTime,
    finished: SimTime,
    /// Pure compute dispatched so far — the fair-share key.
    dispatched_work: Duration,
    /// Graph shape captured at admission, so `stats` still answers
    /// after the completed session's storage is released.
    tasks_run: usize,
    total_work: Duration,
    /// Input paths interned to dense ids at admission, aligned with
    /// each task's `inputs` (`cfg.interned_paths` only; released with
    /// the graph on completion).
    input_ids: Option<Vec<Vec<u32>>>,
}

impl SessionRun {
    /// Drop the completed session's heavyweight state — the task
    /// graph (name + input-path strings per task), the dataflow
    /// bookkeeping, the interned-id table, and the worker cache —
    /// mirroring the engine's plan-storage release: a serving core's
    /// memory tracks live sessions, not total sessions served.
    /// Completion times and read stats stay for `stats()`.
    fn release_storage(&mut self) {
        debug_assert!(self.run.is_done());
        self.run.graph.tasks = Vec::new();
        self.run.ready = VecDeque::new();
        self.run.missing = Vec::new();
        self.run.dependents = Vec::new();
        self.run.running_node = Vec::new();
        self.run.running_plan = Vec::new();
        self.cache = HashSet::new();
        self.input_ids = None;
    }

    /// Resident bytes of this session's scheduler-side bookkeeping:
    /// container capacities (not lengths — allocator-held memory is
    /// what bounds a serving core), string payloads, and the struct
    /// header. After `release_storage` only the completion vector,
    /// counters, and the header remain.
    fn state_bytes(&self) -> u64 {
        let g = &self.run.graph;
        let mut b = (g.tasks.capacity() * size_of::<super::graph::Task>()) as u64;
        for t in &g.tasks {
            b += t.name.capacity() as u64;
            b += (t.inputs.capacity() * size_of::<super::graph::TaskInput>()) as u64;
            b += t.inputs.iter().map(|i| i.path.capacity() as u64).sum::<u64>();
            b += (t.deps.capacity() * size_of::<TaskId>()) as u64;
        }
        b += (self.run.ready.capacity() * size_of::<TaskId>()) as u64;
        b += self.run.missing.capacity() as u64 * 4;
        b += (self.run.dependents.capacity() * size_of::<Vec<u32>>()) as u64;
        b += self.run.dependents.iter().map(|d| d.capacity() as u64 * 4).sum::<u64>();
        b += self.run.running_node.capacity() as u64 * 4;
        b += self.run.running_plan.capacity() as u64 * 4;
        b += (self.run.completion.capacity() * size_of::<SimTime>()) as u64;
        b += (self.cache.capacity() * size_of::<(u32, u32)>()) as u64;
        if let Some(ids) = &self.input_ids {
            b += (ids.capacity() * size_of::<Vec<u32>>()) as u64;
            b += ids.iter().map(|v| v.capacity() as u64 * 4).sum::<u64>();
        }
        b + size_of::<SessionRun>() as u64
    }
}

/// Many concurrent session graphs over one worker pool, session-fair.
///
/// Dispatch policy: whenever a slot frees, the next task comes from
/// the session with the least compute **dispatched** so far (ties to
/// the lower [`SessionId`]), FIFO within the session. Non-preemptive,
/// deterministic, and with a single session bit-identical to
/// [`Scheduler`] — same slot-pool evolution, same plans, same
/// completion times (the fair pick always selects the only session,
/// and dataflow/placement/plan-building are the same [`GraphRun`] /
/// [`pick_slot_in`] / [`build_task_plan`] code, not a copy).
pub struct SessionScheduler {
    topo: Topology,
    cfg: SchedulerCfg,
    /// Free worker slots (see [`build_slot_pool`]).
    free_slots: Vec<u32>,
    sessions: Vec<SessionRun>,
    /// Incomplete sessions, unordered (completion swap-removes). The
    /// [`FairPick::Scan`] pick scans only these, so its dispatch cost
    /// tracks live sessions, not total sessions ever served.
    live: Vec<u32>,
    /// `live_pos[sid]` = index of `sid` in `live` (`usize::MAX` once
    /// complete), so completion removes a session in O(1) instead of
    /// scanning `live`.
    live_pos: Vec<usize>,
    /// The [`FairPick::Indexed`] structure: exactly the live sessions
    /// with a non-empty ready queue, keyed `(dispatched_work, sid)`.
    /// Its minimum is the scan's `min_by_key` by construction. Keys
    /// are removed before `dispatched_work` changes and re-inserted
    /// after — an in-place decrease-key on an ordered set.
    pick_queue: BTreeSet<(Duration, u32)>,
}

impl SessionScheduler {
    pub fn new(topo: Topology, comm: Comm, cfg: SchedulerCfg) -> SessionScheduler {
        SessionScheduler {
            topo,
            cfg,
            free_slots: build_slot_pool(&comm),
            sessions: Vec::new(),
            live: Vec::new(),
            live_pos: Vec::new(),
            pick_queue: BTreeSet::new(),
        }
    }

    /// Admit a session's task graph; its ready tasks compete for free
    /// slots immediately. Returns the session's id.
    pub fn add_session(&mut self, core: &mut SimCore, graph: TaskGraph) -> SessionId {
        // Fail at admission, not mid-dispatch deep into a run: the tag
        // encoding carries the session index in 16 bits.
        assert!(
            self.sessions.len() < (1 << 16),
            "session count exceeds the task-tag namespace (65536)"
        );
        let sid = SessionId(self.sessions.len() as u32);
        let (tasks_run, total_work) = (graph.len(), graph.total_work());
        // Intern every input path once, up front: the per-task hot
        // path then never walks a string-keyed map.
        let input_ids: Option<Vec<Vec<u32>>> = self.cfg.interned_paths.then(|| {
            graph
                .tasks
                .iter()
                .map(|t| t.inputs.iter().map(|i| core.nodes.intern_path(&i.path)).collect())
                .collect()
        });
        self.sessions.push(SessionRun {
            run: GraphRun::new(graph),
            cache: HashSet::new(),
            reads: ReadStats::default(),
            submitted: core.now,
            finished: core.now,
            dispatched_work: Duration::ZERO,
            tasks_run,
            total_work,
            input_ids,
        });
        self.live_pos.push(self.live.len());
        self.live.push(sid.0);
        // A fresh graph always has ready roots (acyclic + non-empty).
        self.pick_queue.insert((Duration::ZERO, sid.0));
        self.dispatch(core);
        sid
    }

    /// The session the next free slot should serve: least dispatched
    /// compute, ties to the lower id; `None` when nothing is ready.
    /// The `live` list is unordered, but the (work, id) key makes the
    /// minimum — and therefore the schedule — order-independent.
    /// [`FairPick::Indexed`] reads the same minimum off `pick_queue`
    /// in O(log live); debug builds cross-check it against the scan on
    /// every pick, so the differential suites exercise the
    /// decision-for-decision equivalence, not just end states.
    fn next_session(&self) -> Option<usize> {
        let scan = || {
            self.live
                .iter()
                .map(|&i| i as usize)
                .filter(|&i| !self.sessions[i].run.ready.is_empty())
                .min_by_key(|&i| (self.sessions[i].dispatched_work, i))
        };
        match self.cfg.fair_pick {
            FairPick::Scan => scan(),
            FairPick::Indexed => {
                let pick = self.pick_queue.iter().next().map(|&(_, sid)| sid as usize);
                debug_assert_eq!(pick, scan(), "indexed fair pick diverged from the scan");
                pick
            }
        }
    }

    /// Hand out free slots session-fairly until slots or work run out.
    fn dispatch(&mut self, core: &mut SimCore) {
        while !self.free_slots.is_empty() {
            let Some(s) = self.next_session() else { break };
            // The pick's key is about to change: pull it out of the
            // index first, re-insert under the new key after dispatch
            // (and only if the session still has ready work).
            self.pick_queue.remove(&(self.sessions[s].dispatched_work, s as u32));
            let tid = self.sessions[s].run.ready.pop_front().unwrap();
            let sref = &self.sessions[s];
            let ids = sref.input_ids.as_ref().map(|v| v[tid.0].as_slice());
            let idx = pick_slot_in(core, &self.cfg, &sref.run.graph, tid, ids, &self.free_slots);
            // swap_remove of the top index == pop, matching the
            // baseline scheduler byte-for-byte.
            let node = self.free_slots.swap_remove(idx);
            let sess = &mut self.sessions[s];
            sess.run.launch(tid, node);
            sess.dispatched_work += sess.run.graph.tasks[tid.0].runtime;
            let tag = session_task_tag(SessionId(s as u32), tid);
            let refill = !sess.run.ready.is_empty();
            let new_key = (sess.dispatched_work, s as u32);
            let SessionRun { run, cache, reads, input_ids, .. } = sess;
            let ids = input_ids.as_ref().map(|v| v[tid.0].as_slice());
            let plan = build_task_plan(
                core,
                &self.topo,
                &self.cfg,
                &run.graph,
                tid,
                node,
                tag,
                ids,
                cache,
                reads,
            );
            if refill {
                self.pick_queue.insert(new_key);
            }
            let pid = core.submit(plan);
            self.sessions[s].run.running_plan[tid.0] = pid.0 as u32;
        }
    }

    /// Route a task-plan completion. Returns the session that became
    /// fully complete on this event, if any.
    pub fn on_plan_done(&mut self, core: &mut SimCore, tag: u64) -> Option<SessionId> {
        let (sid, tid) = decode_task_tag(tag)?;
        let sess = &mut self.sessions[sid.0 as usize];
        let node = sess.run.complete(tid, core.now);
        self.free_slots.push(node);
        let just_done = sess.run.is_done();
        if just_done {
            sess.finished = core.now;
            sess.release_storage();
            // A done session has an empty ready queue, so it holds no
            // pick_queue key; the O(1) live_pos removal replaces the
            // seed's linear `position` scan of `live`.
            debug_assert!(!self.pick_queue.contains(&(sess.dispatched_work, sid.0)));
            let pos = self.live_pos[sid.0 as usize];
            debug_assert_eq!(self.live[pos], sid.0, "live_pos out of sync");
            self.live.swap_remove(pos);
            self.live_pos[sid.0 as usize] = usize::MAX;
            if pos < self.live.len() {
                let moved = self.live[pos];
                self.live_pos[moved as usize] = pos;
            }
        } else if !sess.run.ready.is_empty() {
            // The completion may have released dependents into an
            // empty ready queue; (re-)index the session. BTreeSet
            // insert is idempotent when the key was already present.
            self.pick_queue.insert((sess.dispatched_work, sid.0));
        }
        self.dispatch(core);
        just_done.then_some(sid)
    }

    /// Node-death recovery: abort the engine plan of every task that
    /// was computing on `node`, requeue the tasks in their sessions,
    /// free the slots for the warm replacement, and redispatch.
    /// Returns the number of tasks lost (and requeued).
    ///
    /// Exactly-once: each lost task is requeued here and nowhere else.
    /// [`SimCore::abort_plan`] emits no `PlanDone` for the dead plan,
    /// so the task's eventual re-dispatch under the same tag produces
    /// the single completion its session ever observes; if the task's
    /// completion notice was already pending at the kill instant the
    /// engine delivered it *before* the kill timer fired (pending
    /// notices drain before the next heap pop), the task is already
    /// complete, and it is not requeued — either way exactly one
    /// completion. `dispatched_work` is **not** rewound: the compute
    /// was genuinely spent, and charging it keeps the fair-share key
    /// honest about what each session cost the machine.
    ///
    /// With [`SchedulerCfg::work_stealing`] the lost tasks go to the
    /// *front* of the ready queue (in task order, so FIFO among
    /// themselves) and the freed slots go to whichever sessions the
    /// fair pick chooses — idle nodes steal the failed node's backlog
    /// immediately. Without it they queue behind already-ready work.
    pub fn on_node_failure(&mut self, core: &mut SimCore, node: u32) -> usize {
        let mut lost_total = 0;
        for s in 0..self.sessions.len() {
            let sess = &mut self.sessions[s];
            if sess.run.is_done() {
                continue;
            }
            // Tasks of this session caught computing on the dead node,
            // in task order for deterministic requeueing.
            let lost: Vec<usize> = sess
                .run
                .running_node
                .iter()
                .enumerate()
                .filter(|&(_, &n)| n == node)
                .map(|(t, _)| t)
                .collect();
            if lost.is_empty() {
                continue;
            }
            let had_ready = !sess.run.ready.is_empty();
            for &t in &lost {
                let pid = std::mem::replace(&mut sess.run.running_plan[t], u32::MAX);
                debug_assert_ne!(pid, u32::MAX, "lost task has no live plan");
                let aborted = core.abort_plan(PlanId(pid as usize));
                debug_assert!(aborted, "lost task's plan already completed");
                sess.run.running_node[t] = u32::MAX;
                // The rank the task occupied belongs to the warm
                // replacement node and is free again.
                self.free_slots.push(node);
            }
            if self.cfg.work_stealing {
                for &t in lost.iter().rev() {
                    sess.run.ready.push_front(TaskId(t));
                }
            } else {
                for &t in &lost {
                    sess.run.ready.push_back(TaskId(t));
                }
            }
            // The session gained ready work; index it if it wasn't.
            if !had_ready {
                self.pick_queue.insert((sess.dispatched_work, s as u32));
            }
            lost_total += lost.len();
        }
        if lost_total > 0 {
            self.dispatch(core);
        }
        lost_total
    }

    /// True when every admitted session has completed.
    pub fn all_done(&self) -> bool {
        self.live.is_empty()
    }

    pub fn session_done(&self, sid: SessionId) -> bool {
        self.sessions[sid.0 as usize].run.is_done()
    }

    pub fn session_count(&self) -> usize {
        self.sessions.len()
    }

    /// Incomplete sessions still holding full graph state.
    pub fn live_count(&self) -> usize {
        self.live.len()
    }

    /// Resident bytes of the scheduler's own bookkeeping across every
    /// admitted session (live sessions carry their graphs; completed
    /// ones only completion times and counters — the scale harness
    /// reports this per idle session to bound serving-core growth).
    pub fn state_bytes(&self) -> u64 {
        self.sessions.iter().map(SessionRun::state_bytes).sum::<u64>()
            + (self.sessions.capacity() * size_of::<SessionRun>()) as u64
            + self.free_slots.capacity() as u64 * 4
            + self.live.capacity() as u64 * 4
            + (self.live_pos.capacity() * size_of::<usize>()) as u64
            // BTreeSet node payload + rough structural overhead.
            + self.pick_queue.len() as u64 * (size_of::<(Duration, u32)>() + 16) as u64
    }

    pub fn stats(&self, sid: SessionId) -> SessionStats {
        let s = &self.sessions[sid.0 as usize];
        assert!(s.run.is_done(), "session {sid:?} incomplete");
        SessionStats {
            submitted: s.submitted,
            finished: s.finished,
            tasks_run: s.tasks_run,
            total_work: s.total_work,
            reads: s.reads,
            completion: s.run.completion.clone(),
        }
    }
}

/// Standalone use (no serving layer on top): the scheduler consumes
/// task completions directly.
impl Director for SessionScheduler {
    fn on_notice(&mut self, core: &mut SimCore, notice: Notice) {
        if let Notice::PlanDone { tag, .. } = notice {
            self.on_plan_done(core, tag);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{bgq, orthros, Topology};
    use crate::dataflow::graph::Task;
    use crate::pfs::{Blob, GpfsParams};
    use crate::units::MB;

    fn orthros_core() -> (SimCore, Topology) {
        let mut core = SimCore::new();
        let topo = Topology::build(orthros(), GpfsParams::default(), &mut core.net);
        (core, topo)
    }

    #[test]
    fn single_task_runs_for_its_runtime() {
        let (mut core, topo) = orthros_core();
        let comm = Comm::world(&topo.spec);
        let mut g = TaskGraph::new();
        g.add(Task::compute("t", Duration::from_secs(10)));
        let stats = run_workflow(&mut core, &topo, &comm, g, SchedulerCfg::default());
        assert!((stats.makespan.secs_f64() - 10.0).abs() < 0.01);
        assert_eq!(stats.tasks_run, 1);
    }

    #[test]
    fn perfect_task_farm_packs_cores() {
        // 640 x 10 s tasks on 320 cores = exactly 2 waves ~= 20 s.
        let (mut core, topo) = orthros_core();
        let comm = Comm::world(&topo.spec);
        let mut g = TaskGraph::new();
        g.foreach(640, |i| Task::compute(format!("t{i}"), Duration::from_secs(10)));
        let stats = run_workflow(&mut core, &topo, &comm, g, SchedulerCfg::default());
        assert!((stats.makespan.secs_f64() - 20.0).abs() < 0.1, "{:?}", stats.makespan);
        assert!(stats.utilization > 0.98, "{}", stats.utilization);
    }

    #[test]
    fn makespan_scales_inversely_with_cores() {
        // The Fig 12/13 property: same workload, half the cores -> ~2x.
        let run = |nodes: u32| {
            let mut core = SimCore::new();
            let mut spec = orthros();
            spec.nodes = nodes;
            let topo = Topology::build(spec, GpfsParams::default(), &mut core.net);
            let comm = Comm::world(&topo.spec);
            let mut g = TaskGraph::new();
            let mut rng = crate::util::prng::Pcg64::new(7);
            g.foreach(720, |i| {
                Task::compute(
                    format!("t{i}"),
                    Duration::from_secs_f64(rng.log_uniform(5.0, 160.0)),
                )
            });
            run_workflow(&mut core, &topo, &comm, g, SchedulerCfg::default())
                .makespan
                .secs_f64()
        };
        let t5 = run(5);
        let t2 = run(2);
        // Sub-linear (the 160 s stragglers bound the makespan at high
        // core counts) but clearly better with 2.5x the cores — the
        // same flattening the paper's Fig 12 shows.
        let ratio = t2 / t5;
        assert!(ratio > 1.5 && ratio < 2.6, "t5={t5} t2={t2} ratio={ratio}");
    }

    #[test]
    fn dependencies_serialize() {
        let (mut core, topo) = orthros_core();
        let comm = Comm::world(&topo.spec);
        let mut g = TaskGraph::new();
        let a = g.add(Task::compute("a", Duration::from_secs(5)));
        g.add(Task::compute("b", Duration::from_secs(5)).with_dep(a));
        let stats = run_workflow(&mut core, &topo, &comm, g, SchedulerCfg::default());
        assert!(stats.makespan.secs_f64() >= 10.0);
    }

    #[test]
    fn staged_input_charges_ramdisk_rate() {
        let (mut core, topo) = orthros_core();
        let comm = Comm::world(&topo.spec);
        core.node_write_range(0, 4, "/tmp/d/in.bin", Blob::synthetic(500 * MB, 1));
        let mut g = TaskGraph::new();
        g.add(Task::compute("t", Duration::ZERO).with_input("/tmp/d/in.bin", None));
        let stats = run_workflow(&mut core, &topo, &comm, g, SchedulerCfg::default());
        // 500 MB at orthros local 500 MB/s = 1 s.
        assert!((stats.makespan.secs_f64() - 1.0).abs() < 0.01, "{:?}", stats.makespan);
        assert_eq!(stats.staged_read_bytes, 500 * MB);
        assert_eq!(stats.unstaged_read_bytes, 0);
    }

    #[test]
    fn unstaged_input_falls_back_to_gpfs() {
        let (mut core, topo) = orthros_core();
        let comm = Comm::world(&topo.spec);
        core.pfs.write("/data/in.bin", Blob::synthetic(100 * MB, 2));
        let mut g = TaskGraph::new();
        g.add(Task::compute("t", Duration::ZERO).with_input("/data/in.bin", None));
        let stats = run_workflow(&mut core, &topo, &comm, g, SchedulerCfg::default());
        assert_eq!(stats.unstaged_read_bytes, 100 * MB);
        assert_eq!(stats.staged_read_bytes, 0);
    }

    #[test]
    fn cache_eliminates_repeat_reads() {
        // SVI-B: "tasks after the first do not need to perform Read
        // operations at all".
        let run = |cache: bool| {
            let (mut core, topo) = orthros_core();
            let comm = Comm::world(&topo.spec);
            core.node_write_range(0, 4, "/tmp/d/in.bin", Blob::synthetic(500 * MB, 1));
            let mut g = TaskGraph::new();
            // 2 sequential waves per core would re-read without cache.
            g.foreach(640, |i| {
                Task::compute(format!("t{i}"), Duration::from_secs(1))
                    .with_input("/tmp/d/in.bin", None)
            });
            let cfg = SchedulerCfg { cache_inputs: cache, ..Default::default() };
            run_workflow(&mut core, &topo, &comm, g, cfg)
        };
        let cold = run(false);
        let warm = run(true);
        assert!(warm.cache_hits > 0);
        assert!(
            warm.makespan.secs_f64() < cold.makespan.secs_f64(),
            "warm={:?} cold={:?}",
            warm.makespan,
            cold.makespan
        );
        // Cold: every task pays the 1 s read; warm: one read per node.
        assert!((cold.makespan.secs_f64() - 4.0).abs() < 0.2, "{:?}", cold.makespan);
        assert!((warm.makespan.secs_f64() - 3.0).abs() < 0.2, "{:?}", warm.makespan);
    }

    #[test]
    fn locality_identical_when_inputs_fit_everywhere() {
        // Differential guarantee: on a workload whose staged inputs
        // are resident on *every* node, the cache-aware scheduler is
        // bit-identical to the baseline — same placement, same
        // completion times, same byte accounting.
        let run = |locality: bool| {
            let (mut core, topo) = orthros_core();
            let comm = Comm::world(&topo.spec);
            core.node_write_range(0, 4, "/tmp/d/in.bin", Blob::synthetic(100 * MB, 1));
            let mut g = TaskGraph::new();
            let mut rng = crate::util::prng::Pcg64::new(21);
            g.foreach(640, |i| {
                Task::compute(
                    format!("t{i}"),
                    Duration::from_secs_f64(rng.log_uniform(1.0, 20.0)),
                )
                .with_input("/tmp/d/in.bin", None)
                .with_output(MB)
            });
            let cfg = SchedulerCfg { locality_aware: locality, ..Default::default() };
            run_workflow(&mut core, &topo, &comm, g, cfg)
        };
        let base = run(false);
        let loc = run(true);
        assert_eq!(base.makespan, loc.makespan);
        assert_eq!(base.completion, loc.completion);
        assert_eq!(base.staged_read_bytes, loc.staged_read_bytes);
        assert_eq!(base.unstaged_read_bytes, loc.unstaged_read_bytes);
        assert_eq!(base.cache_hits, loc.cache_hits);
    }

    #[test]
    fn locality_cuts_pfs_traffic_on_partial_residency() {
        // The replica lives on nodes 0-1 only (128 slots); a burst of
        // 128 readers floods in after a barrier scrambled the slot
        // pool. The baseline scheduler places many of them on
        // replica-less nodes and re-reads from the shared FS; the
        // locality-aware scheduler steers all of them to the replica
        // holders: strictly fewer shared-FS bytes, no-worse makespan.
        let run = |locality: bool| {
            let mut core = SimCore::new();
            let gpfs = crate::pfs::GpfsParams {
                peak_bw: 1.25e9, // the Orthros NFS backplane model
                ..Default::default()
            };
            let topo = Topology::build(orthros(), gpfs, &mut core.net);
            let comm = Comm::world(&topo.spec);
            core.pfs.write("/data/in.bin", Blob::synthetic(100 * MB, 3));
            core.node_write_range(0, 1, "/data/in.bin", Blob::synthetic(100 * MB, 3));
            let mut g = TaskGraph::new();
            let mut rng = crate::util::prng::Pcg64::new(5);
            let wave1 = g.foreach(320, |i| {
                Task::compute(
                    format!("w1/{i}"),
                    Duration::from_secs_f64(rng.log_uniform(1.0, 10.0)),
                )
            });
            let mut barrier = Task::compute("barrier", Duration::from_secs(1));
            for id in wave1 {
                barrier = barrier.with_dep(id);
            }
            let b = g.add(barrier);
            g.foreach(128, |i| {
                Task::compute(format!("w2/{i}"), Duration::from_secs(5))
                    .with_dep(b)
                    .with_input("/data/in.bin", None)
            });
            let cfg = SchedulerCfg { locality_aware: locality, ..Default::default() };
            run_workflow(&mut core, &topo, &comm, g, cfg)
        };
        let base = run(false);
        let loc = run(true);
        assert!(base.unstaged_read_bytes > 0, "baseline must spill to the shared FS");
        assert_eq!(loc.unstaged_read_bytes, 0, "locality must place on replica holders");
        assert!(loc.staged_read_bytes > base.staged_read_bytes);
        assert!(
            loc.makespan <= base.makespan,
            "locality makespan {:?} vs baseline {:?}",
            loc.makespan,
            base.makespan
        );
    }

    fn random_graph(seed: u64, n: usize, input: Option<&str>) -> TaskGraph {
        let mut g = TaskGraph::new();
        let mut rng = crate::util::prng::Pcg64::new(seed);
        g.foreach(n, |i| {
            let mut t = Task::compute(
                format!("t{i}"),
                Duration::from_secs_f64(rng.log_uniform(1.0, 30.0)),
            );
            if let Some(p) = input {
                t = t.with_input(p, None).with_output(MB / 10);
            }
            t
        });
        g
    }

    #[test]
    fn single_session_bit_identical_to_workflow_scheduler() {
        // The session-fair property: with exactly one session the fair
        // policy always picks it, so placement, plan construction, and
        // completion times must match the baseline scheduler
        // bit-for-bit — including under locality-aware placement,
        // input caching, and partial residency.
        for (locality, cache) in [(false, false), (true, false), (true, true)] {
            let build = || {
                let mut core = SimCore::new();
                let topo = Topology::build(orthros(), GpfsParams::default(), &mut core.net);
                let comm = Comm::world(&topo.spec);
                core.pfs.write("/data/in.bin", Blob::synthetic(50 * MB, 4));
                core.node_write_range(0, 2, "/data/in.bin", Blob::synthetic(50 * MB, 4));
                (core, topo, comm)
            };
            let cfg = SchedulerCfg {
                locality_aware: locality,
                cache_inputs: cache,
                ..Default::default()
            };
            let (mut core_a, topo_a, comm_a) = build();
            let base = run_workflow(
                &mut core_a,
                &topo_a,
                &comm_a,
                random_graph(13, 500, Some("/data/in.bin")),
                cfg,
            );
            let (mut core_b, topo_b, comm_b) = build();
            let mut ss = SessionScheduler::new(topo_b.clone(), comm_b, cfg);
            let sid = ss.add_session(&mut core_b, random_graph(13, 500, Some("/data/in.bin")));
            core_b.run(&mut ss);
            assert!(ss.all_done());
            let s = ss.stats(sid);
            assert_eq!(base.completion, s.completion, "locality={locality} cache={cache}");
            assert_eq!(core_a.now, core_b.now);
            assert_eq!(base.staged_read_bytes, s.reads.staged_bytes);
            assert_eq!(base.unstaged_read_bytes, s.reads.unstaged_bytes);
            assert_eq!(base.cache_hits, s.reads.cache_hits);
        }
    }

    #[test]
    fn sessions_share_the_machine_fairly() {
        // Two equal sessions submitted together on a tiny machine must
        // interleave: both finish well before a serial schedule would,
        // and neither is starved (finish times are close).
        let mut core = SimCore::new();
        let mut spec = orthros();
        spec.nodes = 1; // 64 slots
        let topo = Topology::build(spec, GpfsParams::default(), &mut core.net);
        let comm = Comm::world(&topo.spec);
        let mut ss = SessionScheduler::new(topo, comm, SchedulerCfg::default());
        let a = ss.add_session(&mut core, random_graph(1, 256, None));
        let b = ss.add_session(&mut core, random_graph(2, 256, None));
        core.run(&mut ss);
        let (sa, sb) = (ss.stats(a), ss.stats(b));
        let (fa, fb) = (sa.finished.secs_f64(), sb.finished.secs_f64());
        // Fair sharing: both sessions run concurrently, so the later
        // finisher is within ~35% of the earlier one — a FIFO
        // (session-unfair) schedule would finish A near t/2.
        assert!((fa - fb).abs() / fa.max(fb) < 0.35, "fa={fa} fb={fb}");
    }

    #[test]
    fn fair_pick_prefers_least_dispatched_session() {
        // A 1-slot machine alternates two sessions of equal-cost
        // tasks: after each completion the other session has less
        // dispatched work and must win the slot.
        let mut core = SimCore::new();
        let mut spec = orthros();
        spec.nodes = 1;
        spec.ranks_per_node = 1;
        let topo = Topology::build(spec, GpfsParams::default(), &mut core.net);
        let comm = Comm::world(&topo.spec);
        let mut ss = SessionScheduler::new(topo, comm, SchedulerCfg::default());
        let mk = |tag: &str| {
            let mut g = TaskGraph::new();
            g.foreach(4, |i| Task::compute(format!("{tag}{i}"), Duration::from_secs(10)));
            g
        };
        let a = ss.add_session(&mut core, mk("a"));
        let b = ss.add_session(&mut core, mk("b"));
        core.run(&mut ss);
        let (sa, sb) = (ss.stats(a), ss.stats(b));
        // Strict alternation: a0 b0 a1 b1 ... so every A task k
        // completes before B task k, and B task k before A task k+1.
        for k in 0..4 {
            assert!(sa.completion[k] < sb.completion[k]);
            if k + 1 < 4 {
                assert!(sb.completion[k] < sa.completion[k + 1]);
            }
        }
        // Completed sessions released their graph + cache storage
        // (stats above still answered from the captured shape).
        assert!(ss
            .sessions
            .iter()
            .all(|s| s.run.graph.tasks.is_empty() && s.cache.is_empty()));
        assert_eq!(sa.tasks_run, 4);
        assert_eq!(sa.total_work, Duration::from_secs(40));
    }

    #[test]
    fn session_tags_round_trip() {
        let tag = session_task_tag(SessionId(7), TaskId(123));
        assert_eq!(decode_task_tag(tag), Some((SessionId(7), TaskId(123))));
        assert_eq!(decode_task_tag(5), None);
        // The baseline scheduler's tags decode as session 0.
        assert_eq!(
            decode_task_tag(TASK_TAG_BASE + 9),
            Some((SessionId(0), TaskId(9)))
        );
    }

    #[test]
    #[should_panic(expected = "not found")]
    fn missing_input_panics() {
        let (mut core, topo) = orthros_core();
        let comm = Comm::world(&topo.spec);
        let mut g = TaskGraph::new();
        g.add(Task::compute("t", Duration::ZERO).with_input("/nope", None));
        run_workflow(&mut core, &topo, &comm, g, SchedulerCfg::default());
    }

    #[test]
    fn throughput_models_agree_on_makespan() {
        // A contended workload (every task reads from the shared FS
        // through the degrading uncoordinated path) must produce the
        // same makespan under the global and the component-incremental
        // throughput models.
        let run = |mode: crate::simtime::flownet::ThroughputMode| {
            let mut core = SimCore::with_mode(mode);
            let topo = Topology::build(orthros(), GpfsParams::default(), &mut core.net);
            let comm = Comm::world(&topo.spec);
            core.pfs.write("/data/shared.bin", Blob::synthetic(64 * MB, 9));
            let mut g = TaskGraph::new();
            let mut rng = crate::util::prng::Pcg64::new(11);
            g.foreach(400, |i| {
                Task::compute(
                    format!("t{i}"),
                    Duration::from_secs_f64(rng.log_uniform(1.0, 20.0)),
                )
                .with_input("/data/shared.bin", None)
                .with_output(MB)
            });
            run_workflow(&mut core, &topo, &comm, g, SchedulerCfg::default())
                .makespan
                .secs_f64()
        };
        let slow = run(crate::simtime::flownet::ThroughputMode::Slow);
        let fast = run(crate::simtime::flownet::ThroughputMode::Fast);
        assert!(
            (slow - fast).abs() < 1e-5,
            "makespan diverged: slow {slow} vs fast {fast}"
        );
    }

    #[test]
    fn bgq_scale_task_farm_is_tractable() {
        // 100K grid points on 512 BG/Q nodes (8,192 ranks): the engine
        // must handle this in well under a second of host time.
        let mut core = SimCore::new();
        let topo = Topology::build(bgq(512), GpfsParams::default(), &mut core.net);
        let comm = Comm::world(&topo.spec);
        let mut g = TaskGraph::new();
        let mut rng = crate::util::prng::Pcg64::new(3);
        g.foreach(100_000, |i| {
            Task::compute(format!("g{i}"), Duration::from_secs_f64(rng.range_f64(20.0, 40.0)))
        });
        let stats = run_workflow(&mut core, &topo, &comm, g, SchedulerCfg::default());
        // ~100000*30s / 8192 cores ~= 366 s.
        let t = stats.makespan.secs_f64();
        assert!(t > 300.0 && t < 450.0, "{t}");
        assert!(stats.utilization > 0.9);
    }

    #[test]
    fn scan_and_indexed_fair_pick_bit_identical() {
        // The perf knobs must be cost-only: every combination of
        // fair-pick implementation and interned-path routing yields
        // the same schedule, byte accounting, and virtual clock.
        // (Debug builds additionally assert the indexed pick equals
        // the scan on every single dispatch decision.)
        let run = |fair_pick: FairPick, interned: bool| {
            let mut core = SimCore::new();
            let mut spec = orthros();
            spec.nodes = 2;
            let topo = Topology::build(spec, GpfsParams::default(), &mut core.net);
            let comm = Comm::world(&topo.spec);
            core.pfs.write("/data/in.bin", Blob::synthetic(20 * MB, 8));
            core.node_write_range(0, 0, "/data/in.bin", Blob::synthetic(20 * MB, 8));
            let cfg = SchedulerCfg {
                cache_inputs: true,
                locality_aware: true,
                fair_pick,
                interned_paths: interned,
                ..Default::default()
            };
            let mut ss = SessionScheduler::new(topo, comm, cfg);
            let sids: Vec<SessionId> = (0u64..12)
                .map(|i| ss.add_session(&mut core, random_graph(50 + i, 40, Some("/data/in.bin"))))
                .collect();
            core.run(&mut ss);
            assert!(ss.all_done());
            let stats: Vec<SessionStats> = sids.iter().map(|&s| ss.stats(s)).collect();
            (core.now, stats)
        };
        let (now0, base) = run(FairPick::Scan, false);
        for (fp, interned) in [
            (FairPick::Scan, true),
            (FairPick::Indexed, false),
            (FairPick::Indexed, true),
        ] {
            let (now, stats) = run(fp, interned);
            assert_eq!(now, now0, "{fp:?} interned={interned}");
            for (a, b) in base.iter().zip(&stats) {
                assert_eq!(a.completion, b.completion, "{fp:?} interned={interned}");
                assert_eq!(a.reads, b.reads, "{fp:?} interned={interned}");
            }
        }
    }

    #[test]
    fn completed_sessions_release_all_storage() {
        // Long-lived serving cores: once a session completes, every
        // heavyweight container is back to zero capacity — resident
        // bytes per finished session are the struct header plus its
        // completion vector, nothing proportional to graph strings,
        // dataflow bookkeeping, interned-id tables, or cache entries.
        let mut core = SimCore::new();
        let mut spec = orthros();
        spec.nodes = 1;
        let topo = Topology::build(spec, GpfsParams::default(), &mut core.net);
        let comm = Comm::world(&topo.spec);
        core.pfs.write("/data/in.bin", Blob::synthetic(MB, 6));
        let cfg = SchedulerCfg {
            cache_inputs: true,
            locality_aware: true,
            ..Default::default()
        };
        let mut ss = SessionScheduler::new(topo, comm, cfg);
        for seed in 0u64..20 {
            ss.add_session(&mut core, random_graph(100 + seed, 12, Some("/data/in.bin")));
        }
        core.run(&mut ss);
        assert!(ss.all_done());
        assert_eq!(ss.live_count(), 0);
        assert!(ss.pick_queue.is_empty());
        for s in &ss.sessions {
            assert_eq!(s.run.graph.tasks.capacity(), 0);
            assert_eq!(s.run.missing.capacity(), 0);
            assert_eq!(s.run.dependents.capacity(), 0);
            assert_eq!(s.run.running_node.capacity(), 0);
            assert!(s.run.ready.is_empty());
            assert_eq!(s.cache.capacity(), 0);
            assert!(s.input_ids.is_none());
            // Bounded idle footprint: header + completion vector (and
            // whatever empty capacity the drained ready deque kept).
            let bound = size_of::<SessionRun>() as u64
                + (s.run.ready.capacity() * size_of::<TaskId>()) as u64
                + (s.run.completion.capacity() * size_of::<SimTime>()) as u64;
            assert_eq!(s.state_bytes(), bound);
        }
    }

    /// Test harness: a [`SessionScheduler`] plus one scheduled node
    /// kill, wired together the way the serving layer does it.
    struct KillOnce {
        ss: SessionScheduler,
        node: u32,
        lost: usize,
    }

    impl Director for KillOnce {
        fn on_notice(&mut self, core: &mut SimCore, notice: Notice) {
            match notice {
                Notice::Timer { .. } => {
                    core.fail_node(self.node);
                    self.lost += self.ss.on_node_failure(core, self.node);
                }
                Notice::PlanDone { tag, .. } => {
                    self.ss.on_plan_done(core, tag);
                }
                _ => {}
            }
        }
    }

    #[test]
    fn node_failure_requeues_lost_tasks_exactly_once() {
        // 2 nodes x 1 rank, 4 x 10 s tasks: t0 lands on node 0, t1 on
        // node 1, t2/t3 wait. Killing node 0 at t=5 aborts t0
        // mid-compute. With stealing, t0 jumps the queue and reruns
        // 5->15; without, it waits behind t2/t3 and reruns 15->25.
        // Either way every task completes exactly once (a duplicate
        // completion would trip GraphRun::complete's non-running
        // assert) and the makespan is identical — stealing only
        // reorders who waits.
        let run = |steal: bool| {
            let mut core = SimCore::new();
            let mut spec = orthros();
            spec.nodes = 2;
            spec.ranks_per_node = 1;
            let topo = Topology::build(spec, GpfsParams::default(), &mut core.net);
            let comm = Comm::world(&topo.spec);
            let cfg = SchedulerCfg { work_stealing: steal, ..Default::default() };
            let mut ss = SessionScheduler::new(topo, comm, cfg);
            let mut g = TaskGraph::new();
            g.foreach(4, |i| Task::compute(format!("t{i}"), Duration::from_secs(10)));
            let sid = ss.add_session(&mut core, g);
            core.timer(SimTime::ZERO + Duration::from_secs(5), 1);
            let mut d = KillOnce { ss, node: 0, lost: 0 };
            core.run(&mut d);
            assert!(d.ss.all_done());
            assert_eq!(d.lost, 1, "exactly the one running task was lost");
            assert_eq!(core.metrics.count("chaos.plans.aborted"), 1);
            (d.ss.stats(sid), core.now)
        };
        let (steal, now_s) = run(true);
        let (fifo, now_f) = run(false);
        assert_eq!(steal.completion.len(), 4);
        // The re-run of t0 finishes ~15 s stealing, ~25 s FIFO.
        assert!((steal.completion[0].secs_f64() - 15.0).abs() < 0.1, "{:?}", steal.completion);
        assert!((fifo.completion[0].secs_f64() - 25.0).abs() < 0.1, "{:?}", fifo.completion);
        assert!((now_s.secs_f64() - 25.0).abs() < 0.1);
        assert_eq!(now_s, now_f, "stealing reorders, it does not change the makespan here");
    }

    #[test]
    fn work_stealing_is_decision_identical_without_failures() {
        // The SchedulerCfg switch must be invisible until a node
        // actually dies: identical completion times, byte accounting,
        // and virtual clock across a mixed multi-session run.
        let run = |steal: bool| {
            let mut core = SimCore::new();
            let mut spec = orthros();
            spec.nodes = 2;
            let topo = Topology::build(spec, GpfsParams::default(), &mut core.net);
            let comm = Comm::world(&topo.spec);
            core.pfs.write("/data/in.bin", Blob::synthetic(20 * MB, 8));
            core.node_write_range(0, 0, "/data/in.bin", Blob::synthetic(20 * MB, 8));
            let cfg = SchedulerCfg {
                cache_inputs: true,
                locality_aware: true,
                work_stealing: steal,
                ..Default::default()
            };
            let mut ss = SessionScheduler::new(topo, comm, cfg);
            let sids: Vec<SessionId> = (0u64..8)
                .map(|i| ss.add_session(&mut core, random_graph(70 + i, 30, Some("/data/in.bin"))))
                .collect();
            core.run(&mut ss);
            assert!(ss.all_done());
            let stats: Vec<SessionStats> = sids.iter().map(|&s| ss.stats(s)).collect();
            (core.now, stats)
        };
        let (now0, base) = run(false);
        let (now1, steal) = run(true);
        assert_eq!(now0, now1);
        for (a, b) in base.iter().zip(&steal) {
            assert_eq!(a.completion, b.completion);
            assert_eq!(a.reads, b.reads);
            assert_eq!(a.reads.peer_bytes, 0, "peer reads need a failure to exist");
        }
    }
}
