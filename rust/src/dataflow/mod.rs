//! Swift/T-like many-task dataflow engine (SIII).
//!
//! Swift programs are implicitly parallel: `foreach` bodies and
//! function calls become *tasks* ordered only by dataflow. The Swift/T
//! toolchain compiles them onto Turbine + the ADLB load balancer; here
//! the compiled form is a [`graph::TaskGraph`] (tasks, file edges,
//! dataflow deps) executed by [`sched::Scheduler`] over the simulated
//! machine:
//!
//! - ready tasks are dispatched to free worker ranks (one task per
//!   rank — the ADLB worker model), with a per-dispatch overhead
//!   representing the load balancer round-trip;
//! - a task charges its *input reads* before computing: node-local
//!   RAM-disk streams for staged inputs, degraded GPFS reads for
//!   anything not staged (which is exactly the naive baseline);
//! - the worker-process **input cache** (SVI-B: "Swift/T reuses the
//!   same processes for subsequent tasks, [so] HEDM tasks after the
//!   first do not need to perform Read operations at all") is a
//!   per-(node, file) read-once table;
//! - outputs can be written back to the shared filesystem.
//!
//! [`mapreduce`] expresses the paper's Fig 4/5 MapReduce-with-no-
//! barrier pattern as a task graph and asserts its defining property
//! (reduction starts before the map phase ends).

pub mod graph;
pub mod mapreduce;
pub mod sched;
pub mod swift;

pub use graph::{Task, TaskGraph, TaskId, TaskInput};
pub use sched::{run_workflow, FairPick, Scheduler, SchedulerCfg, WorkflowStats};
