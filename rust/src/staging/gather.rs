//! Output gathering: the inverse collective of the I/O hook.
//!
//! SIV's *Future directions* notes that "the leader hook is a generic
//! mechanism that may be generalized for more complex functionality";
//! the obvious second operation — and the one the Related Work section
//! observes other systems focus on — is the *write* direction:
//! collecting per-node result files from node-local storage back into
//! the shared filesystem. Without coordination, 8,192 nodes each
//! creating result files produce a metadata storm and uncoordinated
//! small writes; the gather collective mirrors the staged read:
//!
//! 1. each leader enumerates its node-local matches (no shared-FS
//!    metadata touched),
//! 2. results funnel over the torus to the I/O aggregators,
//! 3. aggregators issue large coordinated writes and *one* rank
//!    creates the (few) output files.
//!
//! Used by the NF stage-2 driver to collect the per-layer
//! microstructure shards.

use anyhow::{anyhow, Result};

use crate::cluster::Topology;
use crate::mpisim::read_all::n_aggregators;
use crate::mpisim::Comm;
use crate::pfs::Blob;
use crate::simtime::plan::{Effect, Plan, StepId};

/// What a gather resolved and will deliver.
#[derive(Clone, Debug, Default)]
pub struct GatherManifest {
    /// (node-local path, shared-FS destination) per collected file.
    pub files: Vec<(String, String)>,
    pub total_bytes: u64,
}

/// Build the gather plan: collect every node-local file matching
/// `pattern` (on node `comm.node_lo`'s replica view — gathers follow a
/// symmetric layout) into `dst_prefix` on the shared filesystem.
///
/// `per_node_bytes` is the data contributed by each node (the files
/// are per-node shards; the data plane stores the canonical shard).
pub fn gather_plan(
    plan: &mut Plan,
    core_nodes: &crate::cluster::NodeStores,
    topo: &Topology,
    comm: &Comm,
    pattern: &str,
    dst_prefix: &str,
    deps: Vec<StepId>,
) -> Result<(GatherManifest, StepId)> {
    // Leaders enumerate locally (free of shared-FS metadata).
    let probe_node = comm.node_lo;
    let mut files = Vec::new();
    let mut total = 0u64;
    let mut blobs: Vec<(String, Blob)> = Vec::new();
    // NodeStores has no glob; enumerate via the canonical replica list.
    for path in crate::staging::spec_paths(core_nodes, probe_node, pattern) {
        let blob = core_nodes
            .read(probe_node, &path)
            .ok_or_else(|| anyhow!("gather: {path} vanished"))?
            .clone();
        let base = path.rsplit('/').next().unwrap_or(&path).to_string();
        let dst = format!("{}/{}", dst_prefix.trim_end_matches('/'), base);
        total += blob.len();
        files.push((path.clone(), dst.clone()));
        blobs.push((dst, blob));
    }
    if files.is_empty() {
        return Err(anyhow!("gather: no node-local files match {pattern:?}"));
    }

    let n = comm.nodes() as u64;
    let per_node_bytes = total; // each node contributes its shard set
    // Phase 1: funnel shards over the torus to the aggregators.
    let funnel = plan.flow_capped(
        topo.path_torus(),
        n,
        per_node_bytes,
        topo.spec.torus_link_bw,
        deps,
        "gather-funnel",
    );
    // Phase 2: aggregators write large coordinated streams to GPFS.
    let naggr = n_aggregators(topo, comm);
    let write = plan.flow(
        topo.path_coordinated_read(), // same links, write direction
        naggr,
        (per_node_bytes * n).div_ceil(naggr),
        vec![funnel],
        "gather-write",
    );
    // Phase 3: one rank creates the output files (few metadata ops).
    let meta = plan.flow(topo.path_meta(), 1, files.len() as u64, vec![write], "gather-meta");
    // Data plane: the shards land in the shared filesystem.
    let mut last = meta;
    for (dst, blob) in blobs {
        last = plan.effect(
            Effect::PfsWrite { path: dst, data: blob },
            vec![meta],
            "gather-write",
        );
    }
    let done = plan.delay(crate::units::Duration::ZERO, vec![last], "gather-write");
    Ok((GatherManifest { files, total_bytes: total }, done))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{bgq, Topology};
    use crate::engine::SimCore;
    use crate::pfs::GpfsParams;
    use crate::units::MB;

    fn setup(nodes: u32) -> (SimCore, Topology) {
        let mut core = SimCore::new();
        let topo = Topology::build(bgq(nodes), GpfsParams::default(), &mut core.net);
        let (lo, hi) = (0, nodes - 1);
        for i in 0..8u64 {
            core.nodes.write_range(
                lo,
                hi,
                format!("/tmp/out/shard_{i}.bin"),
                Blob::synthetic(MB, 0x007 + i),
            );
        }
        (core, topo)
    }

    #[test]
    fn gather_lands_in_pfs() {
        let (mut core, topo) = setup(64);
        let comm = Comm::leader(&topo.spec);
        let mut p = Plan::new(0);
        let nodes = std::mem::take(&mut core.nodes);
        let (manifest, _) = gather_plan(
            &mut p, &nodes, &topo, &comm, "/tmp/out/*.bin", "/projects/results", vec![],
        )
        .unwrap();
        core.nodes = nodes;
        core.submit(p);
        core.run_to_completion();
        assert_eq!(manifest.files.len(), 8);
        for i in 0..8u64 {
            let got = core.pfs.read(&format!("/projects/results/shard_{i}.bin")).unwrap();
            let want = core.nodes.read(0, &format!("/tmp/out/shard_{i}.bin")).unwrap();
            assert!(got.same_content(want));
        }
    }

    #[test]
    fn gather_no_match_errors() {
        let (mut core, topo) = setup(4);
        let comm = Comm::leader(&topo.spec);
        let mut p = Plan::new(0);
        let nodes = std::mem::take(&mut core.nodes);
        assert!(gather_plan(&mut p, &nodes, &topo, &comm, "/none/*", "/r", vec![]).is_err());
    }

    #[test]
    fn gather_time_scales_with_nodes() {
        let t = |nodes: u32| {
            let (mut core, topo) = setup(nodes);
            let comm = Comm::leader(&topo.spec);
            let mut p = Plan::new(0);
            let nodes_store = std::mem::take(&mut core.nodes);
            gather_plan(
                &mut p, &nodes_store, &topo, &comm, "/tmp/out/*.bin", "/r", vec![],
            )
            .unwrap();
            core.nodes = nodes_store;
            core.submit(p);
            core.run_to_completion();
            core.now.secs_f64()
        };
        // More nodes => more total shard bytes through GPFS.
        assert!(t(1024) > t(64), "gather must cost more at scale");
    }
}
