//! The baseline: uncoordinated per-task input I/O ("the original I/O
//! approach, in which each task reads input data independently from
//! GPFS, without the use of collectives" — SVI-B).
//!
//! Model, per the paper's measured behaviour:
//!
//! - Every worker rank opens the shared files itself and reads its
//!   node's share of the dataset straight from GPFS. With
//!   `nodes x ranks_per_node` independent streams the filesystem's
//!   delivered bandwidth collapses along the degrading server stage
//!   (21 GB/s at 8,192 x 16 streams, vs 240 GB/s peak).
//! - There is no separate Write/Read phase: bytes land directly in
//!   task memory (we still populate the node store so the science
//!   tasks find their inputs — the data plane is identical, only the
//!   timing differs).
//! - Optionally ([`naive_plan_with_glob_storm`]) every rank also runs
//!   the globs itself — the metadata anti-pattern SIV warns about;
//!   kept separate because the paper's Fig 11 baseline charges only
//!   the reads. Used by the ablation bench.

use anyhow::{anyhow, Result};

use crate::cluster::Topology;
use crate::mpisim::Comm;
use crate::pfs::ParallelFs;
use crate::simtime::plan::{Effect, Plan, StepId};
use crate::staging::hook::StagedManifest;
use crate::staging::spec::HookSpec;

/// Build the naive-path plan: every rank of `comm` (the full worker
/// communicator, not just leaders) pulls the dataset uncoordinated.
pub fn naive_plan(
    plan: &mut Plan,
    pfs: &ParallelFs,
    topo: &Topology,
    comm: &Comm,
    spec: &HookSpec,
    deps: Vec<StepId>,
) -> Result<(StagedManifest, StepId)> {
    build(plan, pfs, topo, comm, spec, deps, false)
}

/// Naive path *plus* the glob-on-every-rank metadata storm.
pub fn naive_plan_with_glob_storm(
    plan: &mut Plan,
    pfs: &ParallelFs,
    topo: &Topology,
    comm: &Comm,
    spec: &HookSpec,
    deps: Vec<StepId>,
) -> Result<(StagedManifest, StepId)> {
    build(plan, pfs, topo, comm, spec, deps, true)
}

fn build(
    plan: &mut Plan,
    pfs: &ParallelFs,
    topo: &Topology,
    comm: &Comm,
    spec: &HookSpec,
    deps: Vec<StepId>,
    glob_storm: bool,
) -> Result<(StagedManifest, StepId)> {
    let (transfers, meta_ops) = spec.resolve(pfs);
    if transfers.is_empty() {
        return Err(anyhow!("spec matched no files"));
    }
    let mut total_bytes = 0u64;
    let mut blobs = Vec::with_capacity(transfers.len());
    for t in &transfers {
        let blob = pfs
            .read(&t.src)
            .ok_or_else(|| anyhow!("resolved file vanished: {}", t.src))?
            .clone();
        total_bytes += blob.len();
        blobs.push(blob);
    }

    let ranks = comm.size();

    // Metadata: every rank opens (at least) its slice of the dataset.
    // With the glob storm, every rank additionally re-runs the globs.
    let meta_per_rank = if glob_storm { meta_ops + 1 } else { 1 };
    let meta = plan.flow(topo.path_meta(), ranks, meta_per_rank, deps, "naive-meta");

    // Uncoordinated reads: node dataset share striped across the
    // node's ranks (the application-level memory cache means each node
    // moves the dataset once), but the *stream count* the servers see
    // is the full rank count — that is what degrades GPFS.
    let bytes_per_rank = total_bytes.div_ceil(comm.ranks_per_node as u64);
    let read = plan.flow(
        topo.path_uncoordinated_read(),
        ranks,
        bytes_per_rank,
        vec![meta],
        "naive-read",
    );

    // Data plane: inputs end up accessible on every node (task memory).
    let (lo, hi) = comm.node_range();
    let mut last = read;
    for (t, blob) in transfers.iter().zip(blobs) {
        last = plan.effect(
            Effect::NodeWrite { nodes: (lo, hi), path: t.dst.clone(), data: blob },
            vec![read],
            "naive-read",
        );
    }
    let done = plan.delay(crate::units::Duration::ZERO, vec![last, read], "naive-read");
    Ok((StagedManifest { transfers, total_bytes, meta_ops }, done))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{bgq, Topology};
    use crate::engine::SimCore;
    use crate::pfs::{Blob, GpfsParams};
    use crate::units::MB;

    fn run_naive(nodes: u32, storm: bool) -> (f64, SimCore) {
        let mut core = SimCore::new();
        let topo = Topology::build(bgq(nodes), GpfsParams::default(), &mut core.net);
        for i in 0..64 {
            core.pfs.write(
                format!("/data/f{i:03}.bin"),
                Blob::synthetic(577 * MB / 64, i),
            );
        }
        let spec = HookSpec::parse("broadcast to /tmp/d { /data/*.bin }").unwrap();
        let comm = Comm::world(&topo.spec);
        let mut p = Plan::new(0);
        if storm {
            naive_plan_with_glob_storm(&mut p, &core.pfs, &topo, &comm, &spec, vec![])
                .unwrap();
        } else {
            naive_plan(&mut p, &core.pfs, &topo, &comm, &spec, vec![]).unwrap();
        }
        core.submit(p);
        core.run_to_completion();
        (core.now.secs_f64(), core)
    }

    #[test]
    fn paper_number_210s_at_8192_nodes() {
        // SVI-B: naive input takes ~210 s on 8,192 nodes (21 GB/s
        // aggregate for 577 MB x 8192 nodes).
        let (t, _) = run_naive(8192, false);
        assert!((t - 210.0).abs() < 25.0, "naive@8192 = {t}");
    }

    #[test]
    fn naive_is_fine_at_small_scale() {
        // Below the contention knee the naive path is ION-limited and
        // competitive — the crossover the paper's scaling implies.
        let (t, _) = run_naive(64, false);
        assert!(t < 25.0, "naive@64 = {t}");
    }

    #[test]
    fn data_plane_matches_staged_path() {
        let (_, core) = run_naive(16, false);
        for i in [0usize, 31, 63] {
            let orig = core.pfs.read(&format!("/data/f{i:03}.bin")).unwrap();
            let got = core.nodes.read(7, &format!("/tmp/d/f{i:03}.bin")).unwrap();
            assert!(got.same_content(orig));
        }
    }

    #[test]
    fn glob_storm_costs_more() {
        let (plain, _) = run_naive(512, false);
        let (storm, _) = run_naive(512, true);
        // 512 x 16 ranks re-running the globs adds ~10 s of metadata
        // serialisation on top of the bandwidth-bound read.
        assert!(storm > plain + 8.0, "plain={plain} storm={storm}");
    }
}
