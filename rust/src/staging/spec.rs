//! The I/O hook specification language (Fig 6).
//!
//! The paper's hook is a Tcl fragment evaluated inside the Swift/T
//! runtime; users write *broadcast definitions* — glob lists targeting
//! node-local directories — through a high-level wrapper syntax. We
//! implement the same surface:
//!
//! ```text
//! # stage parameter and layer data to the node-local RAM disk
//! broadcast to /tmp/hedm {
//!     /projects/HEDM/params/ps.txt
//!     /projects/HEDM/layer0/*.bin
//! }
//! broadcast to /tmp/pylib {
//!     /soft/pythonlibs/**.py
//! }
//! ```
//!
//! `parse` produces [`BroadcastDef`]s; `resolve` (on rank 0 only — the
//! whole point of SIV's metadata design) expands the globs against
//! the shared filesystem into a concrete transfer manifest.

use anyhow::{anyhow, bail, Result};

use crate::pfs::ParallelFs;

/// One `broadcast to <dir> { patterns... }` block.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BroadcastDef {
    /// Node-local target directory (e.g. `/tmp/hedm`).
    pub target: String,
    /// Glob patterns over the shared filesystem.
    pub patterns: Vec<String>,
}

/// A parsed hook specification.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HookSpec {
    pub defs: Vec<BroadcastDef>,
}

/// A single resolved transfer: shared-FS source -> node-local dest.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Transfer {
    pub src: String,
    pub dst: String,
}

impl HookSpec {
    /// Parse the Fig 6 surface syntax. Lines starting with `#` are
    /// comments; whitespace is free-form.
    pub fn parse(text: &str) -> Result<HookSpec> {
        let mut defs = Vec::new();
        let mut tokens = tokenize(text);
        while let Some(tok) = tokens.next() {
            match tok.as_str() {
                "broadcast" => {
                    let to = tokens.next().ok_or_else(|| anyhow!("expected 'to'"))?;
                    if to != "to" {
                        bail!("expected 'to' after 'broadcast', got {to:?}");
                    }
                    let target = tokens
                        .next()
                        .ok_or_else(|| anyhow!("expected target directory"))?;
                    if target.starts_with('{') {
                        bail!("missing target directory before '{{'");
                    }
                    let open = tokens.next().ok_or_else(|| anyhow!("expected '{{'"))?;
                    if open != "{" {
                        bail!("expected '{{' after target, got {open:?}");
                    }
                    let mut patterns = Vec::new();
                    loop {
                        let t = tokens.next().ok_or_else(|| anyhow!("unterminated block"))?;
                        if t == "}" {
                            break;
                        }
                        patterns.push(t);
                    }
                    if patterns.is_empty() {
                        bail!("empty broadcast block for {target:?}");
                    }
                    defs.push(BroadcastDef { target, patterns });
                }
                other => bail!("unexpected token {other:?} (expected 'broadcast')"),
            }
        }
        if defs.is_empty() {
            bail!("hook spec contains no broadcast definitions");
        }
        Ok(HookSpec { defs })
    }

    /// Expand globs against the shared filesystem (rank 0 only!).
    /// Returns the transfer manifest and the number of metadata
    /// operations the expansion performed (globs + per-match stats),
    /// which the plan builder charges to the metadata server.
    pub fn resolve(&self, pfs: &ParallelFs) -> (Vec<Transfer>, u64) {
        let mut transfers = Vec::new();
        let mut meta_ops = 0u64;
        for def in &self.defs {
            for pat in &def.patterns {
                meta_ops += 1; // the glob/readdir itself
                let hits = pfs.glob(pat);
                meta_ops += hits.len() as u64; // stat per match
                for src in hits {
                    let base = src.rsplit('/').next().unwrap_or(&src).to_string();
                    let dst = format!("{}/{}", def.target.trim_end_matches('/'), base);
                    transfers.push(Transfer { src, dst });
                }
            }
        }
        (transfers, meta_ops)
    }

    /// Total number of patterns across all defs.
    pub fn pattern_count(&self) -> usize {
        self.defs.iter().map(|d| d.patterns.len()).sum()
    }
}

/// Whitespace tokenizer treating `{` and `}` as standalone tokens and
/// `#` as a to-end-of-line comment.
fn tokenize(text: &str) -> impl Iterator<Item = String> + '_ {
    text.lines()
        .map(|l| l.split('#').next().unwrap_or(""))
        .flat_map(|l| {
            l.replace('{', " { ")
                .replace('}', " } ")
                .split_whitespace()
                .map(str::to_string)
                .collect::<Vec<_>>()
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pfs::Blob;

    const SPEC: &str = r#"
        # HEDM staging spec
        broadcast to /tmp/hedm {
            /projects/HEDM/params/ps.txt
            /projects/HEDM/layer0/*.bin
        }
        broadcast to /tmp/pylib {
            /soft/pythonlibs/**.py
        }
    "#;

    #[test]
    fn parses_fig6_style_spec() {
        let spec = HookSpec::parse(SPEC).unwrap();
        assert_eq!(spec.defs.len(), 2);
        assert_eq!(spec.defs[0].target, "/tmp/hedm");
        assert_eq!(spec.defs[0].patterns.len(), 2);
        assert_eq!(spec.defs[1].patterns, vec!["/soft/pythonlibs/**.py"]);
        assert_eq!(spec.pattern_count(), 3);
    }

    #[test]
    fn rejects_malformed_specs() {
        assert!(HookSpec::parse("").is_err());
        assert!(HookSpec::parse("broadcast /tmp {a}").is_err());
        assert!(HookSpec::parse("broadcast to /tmp { }").is_err());
        assert!(HookSpec::parse("broadcast to /tmp { a.txt ").is_err());
        assert!(HookSpec::parse("stage to /tmp { a }").is_err());
    }

    #[test]
    fn resolve_expands_globs_and_counts_meta_ops() {
        let mut pfs = ParallelFs::new();
        pfs.write("/projects/HEDM/params/ps.txt", Blob::real(vec![1; 10]));
        pfs.write("/projects/HEDM/layer0/f0.bin", Blob::real(vec![2; 20]));
        pfs.write("/projects/HEDM/layer0/f1.bin", Blob::real(vec![3; 20]));
        pfs.write("/soft/pythonlibs/numpy/core.py", Blob::real(vec![4; 5]));
        let spec = HookSpec::parse(SPEC).unwrap();
        let (transfers, meta_ops) = spec.resolve(&pfs);
        assert_eq!(transfers.len(), 4);
        assert!(transfers
            .iter()
            .any(|t| t.src == "/projects/HEDM/layer0/f1.bin"
                && t.dst == "/tmp/hedm/f1.bin"));
        assert!(transfers
            .iter()
            .any(|t| t.dst == "/tmp/pylib/core.py"));
        // 3 globs + 4 stats.
        assert_eq!(meta_ops, 7);
    }

    #[test]
    fn resolve_empty_matches_is_ok() {
        let pfs = ParallelFs::new();
        let spec = HookSpec::parse("broadcast to /tmp { /nope/*.bin }").unwrap();
        let (transfers, meta_ops) = spec.resolve(&pfs);
        assert!(transfers.is_empty());
        assert_eq!(meta_ops, 1);
    }
}
