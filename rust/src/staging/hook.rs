//! The staged path: the Swift I/O hook proper (SIV, Fig 9).
//!
//! Phases, exactly as the paper instruments them:
//!
//! 1. **Glob (rank 0 only).** The leader rank expands the hook spec's
//!    patterns against the shared filesystem; *one* process pays the
//!    metadata cost. ("A naive implementation would simply run the
//!    glob on each process... congesting the shared filesystem.")
//! 2. **List broadcast.** The resolved transfer list is `MPI_Bcast` to
//!    the leader communicator (a few KB; latency-bound).
//! 3. **Staging.** `MPI_File_read_all` per batch: aggregators read
//!    disjoint stripes from GPFS at coordinated-access rates, the
//!    torus allgather assembles full replicas in node memory.
//! 4. **Write.** Each leader writes the replica to the node-local RAM
//!    disk. On BG/Q `/tmp` is an I/O-node service, so this rides the
//!    ION uplink — the phase that dominates at 8,192 nodes and caps
//!    Fig 10 at ~134 GB/s.
//!
//! The data plane is real: every resolved file's [`crate::pfs::Blob`] is
//! replicated into [`crate::cluster::NodeStores`] under the target
//! directory, and integration tests checksum-verify node replicas
//! against the filesystem originals.

use anyhow::{anyhow, Result};

use crate::cluster::Topology;
use crate::mpisim::{bcast::bcast_plan, read_all::read_all_plan, Comm};
use crate::pfs::ParallelFs;
use crate::simtime::plan::{Effect, Plan, StepId};
use crate::staging::spec::{HookSpec, Transfer};
use crate::units::GB;

/// Local-disk write bandwidth for machines whose node-local storage is
/// genuinely local (clusters); BG/Q instead routes via the ION layer.
pub const LOCAL_DISK_WRITE_BW: f64 = 1.0 * GB as f64;

/// Approximate wire size of one transfer-list entry in the broadcast.
pub const LIST_ENTRY_BYTES: u64 = 96;

/// What the hook resolved and will deliver.
#[derive(Clone, Debug, Default)]
pub struct StagedManifest {
    pub transfers: Vec<Transfer>,
    pub total_bytes: u64,
    pub meta_ops: u64,
}

/// Build the staged-path plan for `spec` over the leader communicator
/// `comm`. Appends to `plan`; returns the manifest and the final step.
pub fn staged_plan(
    plan: &mut Plan,
    pfs: &ParallelFs,
    topo: &Topology,
    comm: &Comm,
    spec: &HookSpec,
    deps: Vec<StepId>,
) -> Result<(StagedManifest, StepId)> {
    // Rank 0 resolves the globs NOW (plan build time = hook execution
    // start); the per-op cost is charged to the metadata server below.
    let (transfers, meta_ops) = spec.resolve(pfs);
    if transfers.is_empty() {
        return Err(anyhow!("hook spec matched no files"));
    }
    let mut total_bytes = 0u64;
    let mut blobs = Vec::with_capacity(transfers.len());
    for t in &transfers {
        let blob = pfs
            .read(&t.src)
            .ok_or_else(|| anyhow!("resolved file vanished: {}", t.src))?
            .clone();
        total_bytes += blob.len();
        blobs.push(blob);
    }

    // Phase 1: rank-0 glob - `meta_ops` operations by ONE process.
    let glob = plan.flow(topo.path_meta(), 1, meta_ops, deps, "glob");

    // Phase 2: broadcast the transfer list to all leaders.
    let list_bytes = transfers.len() as u64 * LIST_ENTRY_BYTES;
    let list = bcast_plan(plan, topo, comm, list_bytes, vec![glob], "list-bcast");

    // Phases 3+4: collective read + node-local write of the batch.
    let batch = transfers.iter().cloned().zip(blobs).collect();
    let done = bulk_stage_phases(plan, topo, comm, batch, total_bytes, vec![list]);

    Ok((StagedManifest { transfers, total_bytes, meta_ops }, done))
}

/// Phases 3+4 of the hook, shared by the full stager above and the
/// incremental re-stager (`staging::residency::incremental_plan`):
/// `MPI_File_read_all` of `total_bytes` across the batch (one open per
/// file), the node-local write (ION-routed on BG/Q, local-disk capped
/// on clusters), and the data-plane replication effects.
pub(crate) fn bulk_stage_phases(
    plan: &mut Plan,
    topo: &Topology,
    comm: &Comm,
    batch: Vec<(Transfer, crate::pfs::Blob)>,
    total_bytes: u64,
    deps: Vec<StepId>,
) -> StepId {
    let staged = read_all_plan(plan, topo, comm, total_bytes, batch.len() as u64, deps, "staging");

    let write_path = topo.path_local_write();
    let cap = if write_path.is_empty() { LOCAL_DISK_WRITE_BW } else { f64::INFINITY };
    let write = plan.flow_capped(
        write_path,
        comm.nodes() as u64,
        total_bytes,
        cap,
        vec![staged],
        "write",
    );

    // Data plane: the replicas land on every node of the communicator.
    let (lo, hi) = comm.node_range();
    let mut last = write;
    for (t, blob) in batch {
        last = plan.effect(
            Effect::NodeWrite { nodes: (lo, hi), path: t.dst, data: blob },
            vec![write],
            "write",
        );
    }
    plan.delay(crate::units::Duration::ZERO, vec![last, write], "write")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{bgq, orthros, Topology};
    use crate::engine::SimCore;
    use crate::pfs::{Blob, GpfsParams};
    use crate::units::MB;

    fn setup(nodes: u32, files: usize, bytes_each: u64) -> (SimCore, Topology, HookSpec) {
        let mut core = SimCore::new();
        let topo = Topology::build(bgq(nodes), GpfsParams::default(), &mut core.net);
        for i in 0..files {
            core.pfs.write(
                format!("/projects/HEDM/layer0/f{i:04}.bin"),
                Blob::synthetic(bytes_each, i as u64),
            );
        }
        let spec = HookSpec::parse("broadcast to /tmp/hedm { /projects/HEDM/layer0/*.bin }")
            .unwrap();
        (core, topo, spec)
    }

    #[test]
    fn staged_data_lands_on_every_node_bit_exact() {
        let (mut core, topo, spec) = setup(16, 8, 1 * MB);
        let comm = Comm::leader(&topo.spec);
        let mut p = Plan::new(0);
        let (manifest, _) =
            staged_plan(&mut p, &core.pfs, &topo, &comm, &spec, vec![]).unwrap();
        core.submit(p);
        core.run_to_completion();
        assert_eq!(manifest.transfers.len(), 8);
        for t in &manifest.transfers {
            let orig = core.pfs.read(&t.src).unwrap().clone();
            for node in [0u32, 7, 15] {
                let replica = core.nodes.read(node, &t.dst).unwrap();
                assert!(replica.same_content(&orig), "{} on node {node}", t.dst);
            }
        }
        assert_eq!(core.nodes.bytes_on(3), 8 * MB);
    }

    #[test]
    fn paper_numbers_8192_nodes_577mb() {
        // The headline Fig 10/SVI-B datapoint: 577 MB to 8,192 nodes.
        // Paper: staging+write 134 GB/s aggregate (~35 s + read 10.8 s
        // = 46.75 s total input time).
        let (mut core, topo, spec) = setup(8192, 64, 577 * MB / 64);
        let comm = Comm::leader(&topo.spec);
        let mut p = Plan::new(0);
        let (manifest, done) =
            staged_plan(&mut p, &core.pfs, &topo, &comm, &spec, vec![]).unwrap();
        crate::staging::read_phase(&mut p, &topo, &Comm::world(&topo.spec),
                                   manifest.total_bytes, vec![done]);
        core.submit(p);
        core.run_to_completion();
        let stage_write = core.metrics.phase_window("write").unwrap().1.secs_f64();
        let total = core.now.secs_f64();
        // Staging+Write ~ 35 s (paper: 577*8192/134.4 GB/s = 35.2 s).
        assert!((stage_write - 35.2).abs() < 2.0, "stage+write={stage_write}");
        // Total input ~ 46.75 s (paper SVI-B).
        assert!((total - 46.75).abs() < 2.5, "total={total}");
    }

    #[test]
    fn rank0_globs_exactly_once() {
        let (core, topo, spec) = setup(4, 10, 1000);
        let comm = Comm::leader(&topo.spec);
        let mut p = Plan::new(0);
        let (manifest, _) =
            staged_plan(&mut p, &core.pfs, &topo, &comm, &spec, vec![]).unwrap();
        // 1 glob + 10 stats, by one rank: meta ops = 11.
        assert_eq!(manifest.meta_ops, 11);
        let globs = p.steps_labeled("glob");
        assert_eq!(globs.len(), 1);
    }

    #[test]
    fn empty_spec_errors() {
        let (core, topo, _) = setup(4, 0, 0);
        let spec = HookSpec::parse("broadcast to /tmp { /nothing/*.x }").unwrap();
        let comm = Comm::leader(&topo.spec);
        let mut p = Plan::new(0);
        assert!(staged_plan(&mut p, &core.pfs, &topo, &comm, &spec, vec![]).is_err());
    }

    #[test]
    fn cluster_local_write_uses_local_disk() {
        let mut core = SimCore::new();
        let topo = Topology::build(orthros(), GpfsParams::default(), &mut core.net);
        core.pfs.write("/data/a.bin", Blob::synthetic(100 * MB, 1));
        let spec = HookSpec::parse("broadcast to /tmp { /data/a.bin }").unwrap();
        let comm = Comm::leader(&topo.spec);
        let mut p = Plan::new(0);
        staged_plan(&mut p, &core.pfs, &topo, &comm, &spec, vec![]).unwrap();
        core.submit(p);
        core.run_to_completion();
        // Write phase: 100 MB at 1 GB/s local disk = 0.1 s per node
        // (parallel) — not an ION bottleneck.
        assert!(core.now.secs_f64() < 1.0, "{}", core.now);
        assert!(core.nodes.exists_on(4, "/tmp/a.bin"));
    }
}
