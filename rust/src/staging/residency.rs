//! Node-memory residency: which datasets live on which nodes, and the
//! incremental re-stage path that exploits it.
//!
//! The paper's premise is that staged data is "cached in compute node
//! memory for *extended periods*, during which time various processing
//! tasks may efficiently access it". Once node memory is finite that
//! regime needs management:
//!
//! - [`ResidencyTable`] — the bookkeeping mirror of
//!   [`crate::storage::NodeStores`]: path -> resident node ranges per
//!   storage tier, plus displacement telemetry. `SimCore` owns one and
//!   keeps it exactly in sync with every engine-applied node write,
//!   demotion, promotion, and eviction, so experiments can report hit
//!   rates and evicted bytes without rescanning the data plane.
//! - [`incremental_plan`] — the hook's re-stage path: rank 0 still
//!   globs the full spec (discovering what exists costs the same
//!   either way), then plans per file the cheapest tier that holds
//!   matching content: RAM-resident files are **hits** (nothing
//!   moves), SSD-resident files are **promoted** back over the
//!   machine's local SSD link (cheap, uncontended with the shared FS),
//!   and only the rest are re-staged from GPFS (expensive, shared).
//!   A replica whose shared-FS original changed since staging fails
//!   the content check in *both* tiers and is restaged — staleness
//!   against the catalog's view of the dataset is detected by
//!   checksum, not by trust. With [`Residency::peer_copy`] armed (the
//!   chaos recovery mode, see [`crate::chaos`]) a fourth source slots
//!   in between: a file resident-and-matching on *some* nodes but
//!   torn elsewhere by a node failure is **peer-copied** — surviving
//!   holders stream it over the interconnect to exactly the missing
//!   nodes, never touching the shared FS.
//! - [`Residency`] — the session-level manager binding catalog
//!   [`DatasetId`]s to hook specs: stages datasets incrementally,
//!   refreshes LRU recency for hits, pins the active dataset so the
//!   workflow computing on it can never have its inputs evicted
//!   mid-run, and accumulates hit/miss statistics across a whole
//!   interactive session.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::catalog::DatasetId;
use crate::cluster::Topology;
use crate::engine::SimCore;
use crate::mpisim::{bcast::bcast_plan, Comm};
use crate::pfs::{Blob, ParallelFs};
use crate::simtime::plan::{Effect, Plan, StepId};
use crate::staging::hook::{bulk_stage_phases, LIST_ENTRY_BYTES};
use crate::staging::spec::{HookSpec, Transfer};
use crate::storage::{NodeStores, StorageTier};
use crate::units::Duration;

/// The bookkeeping mirror lives beside the store it mirrors
/// ([`crate::storage::ResidencyTable`], owned by `SimCore`);
/// re-exported here as part of the residency surface.
pub use crate::storage::ResidencyTable;

/// What an incremental stage resolved: the delta it moved, the SSD
/// promotions it planned, and the resident files it skipped.
#[derive(Clone, Debug, Default)]
pub struct IncrementalManifest {
    /// Files transferred from the shared FS this invocation (missing
    /// or stale in both node-local tiers).
    pub staged: Vec<Transfer>,
    /// Files promoted from the node-local SSD tier (resident there
    /// with matching content, absent or stale in RAM).
    pub promoted: Vec<Transfer>,
    /// Files peer-copied from surviving RAM holders to the nodes a
    /// failure stripped ([`Residency::peer_copy`] recovery mode only;
    /// always empty otherwise).
    pub copied: Vec<Transfer>,
    /// Files already RAM-resident with matching content on every node.
    pub hits: Vec<Transfer>,
    pub staged_bytes: u64,
    pub promoted_bytes: u64,
    /// Bytes re-replicated over the interconnect by the peer-copy leg.
    pub copied_bytes: u64,
    pub hit_bytes: u64,
    pub meta_ops: u64,
}

impl IncrementalManifest {
    pub fn total_files(&self) -> usize {
        self.staged.len() + self.promoted.len() + self.copied.len() + self.hits.len()
    }

    pub fn total_bytes(&self) -> u64 {
        self.staged_bytes + self.promoted_bytes + self.copied_bytes + self.hit_bytes
    }

    /// RAM-hit fraction of the resolved file set.
    pub fn hit_rate(&self) -> f64 {
        if self.total_files() == 0 {
            0.0
        } else {
            self.hits.len() as f64 / self.total_files() as f64
        }
    }

    /// Fraction served without touching the shared FS (RAM hits +
    /// SSD promotions + peer copies) — the tiered generalisation of
    /// the hit rate.
    pub fn local_rate(&self) -> f64 {
        if self.total_files() == 0 {
            0.0
        } else {
            (self.hits.len() + self.promoted.len() + self.copied.len()) as f64
                / self.total_files() as f64
        }
    }

    /// Every file the stage delivers or reuses, in manifest order.
    pub fn all_files(&self) -> impl Iterator<Item = &Transfer> {
        self.hits
            .iter()
            .chain(self.promoted.iter())
            .chain(self.copied.iter())
            .chain(self.staged.iter())
    }
}

/// Nodes in `lo..=hi` *not* holding a RAM replica of `path` matching
/// `want`, coalesced into inclusive ranges — empty when every node
/// matches, the full range when none do. The peer-copy leg's gap
/// computation; only consulted when the path has some RAM coverage,
/// i.e. after a failure (or node-scoped eviction) tore a hole in an
/// otherwise-resident replica set.
fn missing_ranges(nodes: &NodeStores, lo: u32, hi: u32, path: &str, want: &Blob) -> Vec<(u32, u32)> {
    let mut out: Vec<(u32, u32)> = Vec::new();
    for n in lo..=hi {
        if nodes.read(n, path).is_some_and(|b| b.same_content(want)) {
            continue;
        }
        match out.last_mut() {
            Some(r) if r.1 + 1 == n => r.1 = n,
            _ => out.push((n, n)),
        }
    }
    out
}

/// Build the incremental re-stage plan for `spec` over the leader
/// communicator `comm`: glob everything, then per file take the
/// cheapest tier holding matching content — RAM hit (free), SSD
/// promotion (a timed transfer over the machine's local SSD link,
/// never touching the shared FS), or GPFS re-stage (the full
/// collective path) for what is missing or stale everywhere. Appends
/// to `plan`; returns the manifest and the final step. With every file
/// RAM-resident the plan reduces to the metadata pass (a few ms),
/// which is what makes sub-10-minute interactive cycles survive memory
/// pressure.
///
/// `peer_copy` arms the node-failure recovery source between the RAM
/// hit and the SSD promotion: a file matching on *some* nodes of the
/// range but torn elsewhere is re-replicated from the survivors over
/// the interconnect ([`crate::cluster::Topology::path_torus`]) to
/// exactly the missing nodes — cheaper than both alternatives and
/// invisible to the shared FS. It is a behaviour switch, not just a
/// cost one (node-scoped LRU eviction can also tear ranges), so the
/// serving layer arms it only when chaos is configured and the
/// default-off keeps failure-free runs byte-identical to the seed.
#[allow(clippy::too_many_arguments)]
pub fn incremental_plan(
    plan: &mut Plan,
    pfs: &ParallelFs,
    nodes: &NodeStores,
    topo: &Topology,
    comm: &Comm,
    spec: &HookSpec,
    peer_copy: bool,
    deps: Vec<StepId>,
) -> Result<(IncrementalManifest, StepId)> {
    let (transfers, meta_ops) = spec.resolve(pfs);
    if transfers.is_empty() {
        return Err(anyhow!("hook spec matched no files"));
    }
    let (lo, hi) = comm.node_range();
    // Promotion is only planned when the machine times it: a topology
    // without an SSD layer never demoted anything through the engine.
    let can_promote = topo.ssd_layer.is_some();
    let mut staged = Vec::new();
    let mut promoted = Vec::new();
    let mut copied = Vec::new();
    let mut hits = Vec::new();
    let mut blobs = Vec::new();
    // Per copied file: the gap ranges to fill and the content to land
    // (checked identical to what the surviving holders have).
    let mut copy_gaps: Vec<Vec<(u32, u32)>> = Vec::new();
    let mut copy_blobs: Vec<Blob> = Vec::new();
    let (mut staged_bytes, mut promoted_bytes, mut copied_bytes, mut hit_bytes) =
        (0u64, 0u64, 0u64, 0u64);
    for t in &transfers {
        let blob = pfs
            .read(&t.src)
            .ok_or_else(|| anyhow!("resolved file vanished: {}", t.src))?
            .clone();
        // The coverage pre-filter keeps the common misses cheap: only
        // a path with *some* RAM residency pays the per-node gap scan.
        let gaps = if peer_copy && !nodes.coverage_of(&t.dst).is_empty() {
            let g = missing_ranges(nodes, lo, hi, &t.dst, &blob);
            // Survivors must exist (gaps != the whole range, which is
            // the stale-everywhere case) and gaps must exist (empty
            // means a full RAM hit, taken below).
            (!g.is_empty() && g != [(lo, hi)]).then_some(g)
        } else {
            None
        };
        if nodes.resident_matches(lo, hi, &t.dst, &blob) {
            hit_bytes += blob.len();
            hits.push(t.clone());
        } else if let Some(gaps) = gaps {
            copied_bytes += blob.len();
            copied.push(t.clone());
            copy_gaps.push(gaps);
            copy_blobs.push(blob);
        } else if can_promote
            && nodes.resident_matches_tier(StorageTier::Ssd, lo, hi, &t.dst, &blob)
        {
            promoted_bytes += blob.len();
            promoted.push(t.clone());
        } else {
            staged_bytes += blob.len();
            staged.push(t.clone());
            blobs.push(blob);
        }
    }

    // Phase 1: rank-0 glob — discovering what exists costs the full
    // metadata pass whether or not bytes then move.
    let glob = plan.flow(topo.path_meta(), 1, meta_ops, deps, "glob");
    let manifest = IncrementalManifest {
        staged: staged.clone(),
        promoted: promoted.clone(),
        copied: copied.clone(),
        hits,
        staged_bytes,
        promoted_bytes,
        copied_bytes,
        hit_bytes,
        meta_ops,
    };
    let mut tails = vec![glob];
    // Promotion leg: every node streams its promoted set back from the
    // local SSD (one member per node over the aggregated SSD layer,
    // capped at the per-node device rate), then the data plane moves
    // the replicas SSD -> RAM.
    if !promoted.is_empty() {
        let span = (hi - lo + 1) as u64;
        let pflow = plan.flow_capped(
            topo.path_ssd(),
            span,
            promoted_bytes,
            topo.spec.ssd_bw,
            vec![glob],
            "promote",
        );
        for t in &promoted {
            let eff = plan.effect(
                Effect::NodePromote { nodes: (lo, hi), path: t.dst.clone() },
                vec![pflow],
                "promote",
            );
            tails.push(eff);
        }
    }
    // Peer-copy leg: surviving RAM holders stream each torn file over
    // the interconnect to exactly its missing nodes — one flow member
    // per missing node, no shared-FS traffic. The landed content is
    // the shared-FS original, which the gap scan proved bit-identical
    // to what the survivors hold.
    for ((t, gaps), blob) in copied.iter().zip(&copy_gaps).zip(copy_blobs) {
        let members: u64 = gaps.iter().map(|&(a, b)| (b - a + 1) as u64).sum();
        let cflow = plan.flow(topo.path_torus(), members, blob.len(), vec![glob], "peer-copy");
        for &(a, b) in gaps {
            let eff = plan.effect(
                Effect::NodeWrite { nodes: (a, b), path: t.dst.clone(), data: blob.clone() },
                vec![cflow],
                "peer-copy",
            );
            tails.push(eff);
        }
    }
    // Staging leg: broadcast only the *delta* transfer list, then the
    // collective read + node-local write of the delta only.
    if !staged.is_empty() {
        let list_bytes = staged.len() as u64 * LIST_ENTRY_BYTES;
        let list = bcast_plan(plan, topo, comm, list_bytes, vec![glob], "list-bcast");
        let stage_done = bulk_stage_phases(
            plan,
            topo,
            comm,
            staged.into_iter().zip(blobs).collect(),
            staged_bytes,
            vec![list],
        );
        tails.push(stage_done);
    }
    let label = if manifest.staged.is_empty()
        && manifest.promoted.is_empty()
        && manifest.copied.is_empty()
    {
        "stage-skip"
    } else {
        "stage-join"
    };
    let done = plan.delay(Duration::ZERO, tails, label);
    Ok((manifest, done))
}

/// Cumulative residency telemetry across a session.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ResidencyStats {
    pub stages: u64,
    pub file_hits: u64,
    pub file_misses: u64,
    /// Files served by SSD promotion (neither a RAM hit nor a GPFS
    /// re-stage).
    pub file_promotions: u64,
    /// Files peer-copied from surviving RAM holders after a node
    /// failure tore their replica range.
    pub file_copies: u64,
    pub hit_bytes: u64,
    pub staged_bytes: u64,
    /// Bytes promoted from the SSD tier instead of re-staged.
    pub promoted_bytes: u64,
    /// Bytes re-replicated over the interconnect by peer copies.
    pub copied_bytes: u64,
}

impl ResidencyStats {
    fn total_files(&self) -> u64 {
        self.file_hits + self.file_misses + self.file_promotions + self.file_copies
    }

    /// RAM-hit fraction of all resolved files.
    pub fn hit_rate(&self) -> f64 {
        let total = self.total_files();
        if total == 0 {
            0.0
        } else {
            self.file_hits as f64 / total as f64
        }
    }

    /// Fraction served without touching the shared FS (RAM hits +
    /// SSD promotions + peer copies).
    pub fn local_rate(&self) -> f64 {
        let total = self.total_files();
        if total == 0 {
            0.0
        } else {
            (self.file_hits + self.file_promotions + self.file_copies) as f64 / total as f64
        }
    }
}

/// Session-level residency manager: binds catalog datasets to hook
/// specs and drives incremental staging with pinning and LRU upkeep.
///
/// Two staging shapes share one implementation:
///
/// - [`Residency::stage_dataset`] — synchronous: submit, run the core
///   to completion, verify. For single-tenant harnesses that own the
///   event loop.
/// - [`Residency::begin_stage`] / [`Residency::commit_stage`] — the
///   serving form: `begin_stage` pins and submits the transfer plan
///   under a caller-chosen engine tag *without running the core* (it
///   is safe inside a [`crate::engine::Director`] callback, where
///   re-entering the run loop would steal other tenants' events);
///   when the plan's `PlanDone` arrives, `commit_stage` verifies
///   delivery and books the stats.
#[derive(Debug, Default)]
pub struct Residency {
    bindings: BTreeMap<DatasetId, HookSpec>,
    /// Node-local paths each dataset last delivered.
    delivered: BTreeMap<DatasetId, Vec<String>>,
    /// Pins this manager currently holds, keyed by owning dataset —
    /// released exactly once (NodeStores pins are refcounted, so a
    /// path shared by two datasets stays protected until both let go).
    pinned_paths: BTreeMap<DatasetId, Vec<String>>,
    /// Stages submitted by `begin_stage` awaiting `commit_stage`.
    in_flight: BTreeMap<DatasetId, IncrementalManifest>,
    /// Arm the peer-copy recovery source in [`incremental_plan`]
    /// (chaos mode): torn replica ranges re-replicate from surviving
    /// holders instead of the shared FS. Off (the default) reproduces
    /// the seed classification exactly.
    pub peer_copy: bool,
    pub stats: ResidencyStats,
}

impl Residency {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bind a catalogued dataset to the hook spec that stages it.
    pub fn bind(&mut self, id: DatasetId, spec: HookSpec) {
        self.bindings.insert(id, spec);
    }

    pub fn spec_of(&self, id: DatasetId) -> Option<&HookSpec> {
        self.bindings.get(&id)
    }

    /// Node-local paths the dataset delivered on its last stage.
    pub fn paths_of(&self, id: DatasetId) -> &[String] {
        self.delivered.get(&id).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Incrementally stage dataset `id` onto `comm`'s nodes and run
    /// the transfer to completion. Hits refresh the LRU clock; every
    /// delivered path is left **pinned** — call
    /// [`Residency::unpin_dataset`] once the analysis cycle using it
    /// finishes. If memory pressure rejects any of the writes (pinned
    /// residents exceed the node budget), the call returns `Err` with
    /// this dataset's pins released, rather than a manifest for data
    /// that never landed.
    pub fn stage_dataset(
        &mut self,
        core: &mut SimCore,
        topo: &Topology,
        comm: &Comm,
        id: DatasetId,
    ) -> Result<IncrementalManifest> {
        self.begin_stage(core, topo, comm, id, 0)?;
        core.run_to_completion();
        self.commit_stage(core, comm, id)
    }

    /// Build, pin, and **submit** the incremental stage of dataset
    /// `id` as a plan tagged `tag`, without running the core: the
    /// serving half of [`Residency::stage_dataset`], safe inside a
    /// director callback. The caller must invoke
    /// [`Residency::commit_stage`] once the plan's `PlanDone { tag }`
    /// notice arrives; until then the dataset counts as in flight and
    /// a second `begin_stage` for it errors.
    pub fn begin_stage(
        &mut self,
        core: &mut SimCore,
        topo: &Topology,
        comm: &Comm,
        id: DatasetId,
        tag: u64,
    ) -> Result<IncrementalManifest> {
        if self.in_flight.contains_key(&id) {
            return Err(anyhow!("dataset {id:?} already has a stage in flight"));
        }
        let spec = self
            .bindings
            .get(&id)
            .ok_or_else(|| anyhow!("dataset {id:?} has no bound hook spec"))?
            .clone();
        let mut plan = Plan::new(tag);
        let (m, _done) = incremental_plan(
            &mut plan,
            &core.pfs,
            &core.nodes,
            topo,
            comm,
            &spec,
            self.peer_copy,
            vec![],
        )?;
        let (lo, hi) = comm.node_range();
        // Refresh this dataset's pins atomically: release whatever it
        // still holds from a previous stage (a path the spec no longer
        // resolves must not keep a stale pin forever), then take the
        // fresh set. Nothing simulates in between, so no eviction can
        // strike in the gap.
        for p in self.pinned_paths.remove(&id).unwrap_or_default() {
            core.nodes.unpin(&p);
        }
        // Reuse refreshes recency on every replica of the hit path —
        // a range-wide hit must not leave split replicas LRU-stale.
        for t in &m.hits {
            core.nodes.touch_range(lo, hi, &t.dst);
        }
        // Pin before the transfer lands so staging file k can never
        // evict file k-1 of its own dataset. Pins cover both tiers, so
        // a planned promotion's SSD copy cannot be discarded between
        // submission and the promote effect.
        for t in m.all_files() {
            core.nodes.pin(t.dst.clone());
        }
        core.submit(plan);
        self.in_flight.insert(id, m.clone());
        Ok(m)
    }

    /// Verify a stage submitted by [`Residency::begin_stage`] after
    /// its plan completed: every promised replica must be resident
    /// with content matching the shared-FS original. On success books
    /// stats and the delivery record; on failure (the engine rejected
    /// a write under memory pressure — metric `node.write.rejected`)
    /// releases this dataset's pins and returns `Err` rather than a
    /// manifest for data that never landed.
    pub fn commit_stage(
        &mut self,
        core: &mut SimCore,
        comm: &Comm,
        id: DatasetId,
    ) -> Result<IncrementalManifest> {
        let m = self
            .in_flight
            .remove(&id)
            .ok_or_else(|| anyhow!("dataset {id:?} has no stage in flight"))?;
        let (lo, hi) = comm.node_range();
        for t in m.all_files() {
            let landed = core
                .pfs
                .read(&t.src)
                .is_some_and(|want| core.nodes.resident_matches(lo, hi, &t.dst, want));
            if !landed {
                for t2 in m.all_files() {
                    core.nodes.unpin(&t2.dst);
                }
                // The delivery record must not outlive a failed stage:
                // paths_of()/dataset_resident_on() reporting unpinned,
                // possibly-stale replicas would misplace work.
                self.delivered.remove(&id);
                return Err(anyhow!(
                    "staging {} -> {} was rejected under memory pressure \
                     (pinned residents exceed the node budget)",
                    t.src,
                    t.dst
                ));
            }
        }
        self.stats.stages += 1;
        self.stats.file_hits += m.hits.len() as u64;
        self.stats.file_misses += m.staged.len() as u64;
        self.stats.file_promotions += m.promoted.len() as u64;
        self.stats.file_copies += m.copied.len() as u64;
        self.stats.hit_bytes += m.hit_bytes;
        self.stats.staged_bytes += m.staged_bytes;
        self.stats.promoted_bytes += m.promoted_bytes;
        self.stats.copied_bytes += m.copied_bytes;
        let fresh: Vec<String> = m.all_files().map(|t| t.dst.clone()).collect();
        self.pinned_paths.insert(id, fresh.clone());
        self.delivered.insert(id, fresh);
        Ok(m)
    }

    /// Release the pins [`Residency::stage_dataset`] took. Idempotent:
    /// each stage's pins are released exactly once, so a double unpin
    /// can never strip a pin another dataset holds on a shared path.
    pub fn unpin_dataset(&mut self, core: &mut SimCore, id: DatasetId) {
        for p in self.pinned_paths.remove(&id).unwrap_or_default() {
            core.nodes.unpin(&p);
        }
    }

    /// True when every path the dataset delivered is resident on
    /// `node` (locality query for placement decisions).
    pub fn dataset_resident_on(&self, core: &SimCore, id: DatasetId, node: u32) -> bool {
        let paths = self.paths_of(id);
        !paths.is_empty() && paths.iter().all(|p| core.nodes.exists_on(node, p))
    }

    /// Resident bookkeeping bytes this manager holds (same accounting
    /// convention as [`crate::storage::NodeStores::state_bytes`]: heap
    /// payload plus a rough 16 B/entry structural overhead per map
    /// node). A long-lived serving core binds thousands of datasets;
    /// this is the number that must stay proportional to *bound*
    /// datasets, not to stages performed.
    pub fn state_bytes(&self) -> u64 {
        use std::mem::size_of;
        let entry = |v: usize| (v + size_of::<DatasetId>() + 16) as u64;
        let strings = |v: &Vec<String>| -> u64 {
            v.capacity() as u64 * size_of::<String>() as u64
                + v.iter().map(|s| s.capacity() as u64).sum::<u64>()
        };
        let transfers = |v: &Vec<Transfer>| -> u64 {
            v.capacity() as u64 * size_of::<Transfer>() as u64
                + v.iter().map(|t| (t.src.capacity() + t.dst.capacity()) as u64).sum::<u64>()
        };
        self.bindings.len() as u64 * entry(size_of::<HookSpec>())
            + self.delivered.values().map(|v| entry(0) + strings(v)).sum::<u64>()
            + self.pinned_paths.values().map(|v| entry(0) + strings(v)).sum::<u64>()
            + self
                .in_flight
                .values()
                .map(|m| {
                    entry(size_of::<IncrementalManifest>())
                        + transfers(&m.staged)
                        + transfers(&m.promoted)
                        + transfers(&m.copied)
                        + transfers(&m.hits)
                })
                .sum::<u64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::Catalog;
    use crate::cluster::{bgq, Topology};
    use crate::pfs::{Blob, GpfsParams};
    use crate::units::MB;

    fn setup(nodes: u32, files: usize) -> (SimCore, Topology, HookSpec) {
        let mut core = SimCore::new();
        let topo = Topology::build(bgq(nodes), GpfsParams::default(), &mut core.net);
        for i in 0..files {
            core.pfs
                .write(format!("/projects/ds/f{i:03}.bin"), Blob::synthetic(MB, 100 + i as u64));
        }
        let spec = HookSpec::parse("broadcast to /tmp/ds { /projects/ds/*.bin }").unwrap();
        (core, topo, spec)
    }

    #[test]
    fn first_stage_moves_everything_second_nothing() {
        let (mut core, topo, spec) = setup(8, 10);
        let comm = crate::mpisim::Comm::leader(&topo.spec);
        let mut p = Plan::new(0);
        let (m1, _) =
            incremental_plan(&mut p, &core.pfs, &core.nodes, &topo, &comm, &spec, false, vec![])
                .unwrap();
        assert_eq!(m1.staged.len(), 10);
        assert_eq!(m1.hits.len(), 0);
        core.submit(p);
        core.run_to_completion();
        let t_first = core.now;

        let mut p = Plan::new(1);
        let (m2, _) =
            incremental_plan(&mut p, &core.pfs, &core.nodes, &topo, &comm, &spec, false, vec![])
                .unwrap();
        assert_eq!(m2.staged.len(), 0);
        assert_eq!(m2.hits.len(), 10);
        assert_eq!(m2.hit_rate(), 1.0);
        core.submit(p);
        core.run_to_completion();
        // All-hit restage is metadata-only: far under a second.
        assert!((core.now - t_first).secs_f64() < 0.1, "{}", core.now - t_first);
    }

    #[test]
    fn stale_pfs_content_forces_restage() {
        let (mut core, topo, spec) = setup(4, 4);
        let comm = crate::mpisim::Comm::leader(&topo.spec);
        let mut p = Plan::new(0);
        incremental_plan(&mut p, &core.pfs, &core.nodes, &topo, &comm, &spec, false, vec![])
            .unwrap();
        core.submit(p);
        core.run_to_completion();
        // The detector writes a new f001 (same path, new bytes).
        core.pfs.write("/projects/ds/f001.bin", Blob::synthetic(MB, 999));
        let mut p = Plan::new(1);
        let (m, _) =
            incremental_plan(&mut p, &core.pfs, &core.nodes, &topo, &comm, &spec, false, vec![])
                .unwrap();
        assert_eq!(m.staged.len(), 1, "only the stale file restages");
        assert_eq!(m.staged[0].src, "/projects/ds/f001.bin");
        core.submit(p);
        core.run_to_completion();
        let want = core.pfs.read("/projects/ds/f001.bin").unwrap();
        assert!(core.nodes.read(2, "/tmp/ds/f001.bin").unwrap().same_content(want));
    }

    #[test]
    fn residency_manager_tracks_hits_and_pins() {
        let (mut core, topo, spec) = setup(4, 6);
        let comm = crate::mpisim::Comm::leader(&topo.spec);
        let mut catalog = Catalog::new();
        let id = catalog.register("ds", "/projects/ds", 6, 6 * MB);
        let mut res = Residency::new();
        res.bind(id, spec);
        let m = res.stage_dataset(&mut core, &topo, &comm, id).unwrap();
        assert_eq!(m.staged.len(), 6);
        assert!(core.nodes.is_pinned("/tmp/ds/f000.bin"));
        assert!(res.dataset_resident_on(&core, id, 3));
        let m = res.stage_dataset(&mut core, &topo, &comm, id).unwrap();
        assert_eq!(m.hits.len(), 6);
        assert_eq!(res.stats.file_hits, 6);
        assert_eq!(res.stats.file_misses, 6);
        assert_eq!(res.stats.hit_rate(), 0.5);
        res.unpin_dataset(&mut core, id);
        assert!(!core.nodes.is_pinned("/tmp/ds/f000.bin"));
        // The engine kept the residency mirror in sync throughout.
        assert!(core.residency.mirrors(&core.nodes));
    }

    #[test]
    fn manager_state_tracks_bindings_not_stage_count() {
        let (mut core, topo, spec) = setup(4, 6);
        let comm = crate::mpisim::Comm::leader(&topo.spec);
        let mut catalog = Catalog::new();
        let id = catalog.register("ds", "/projects/ds", 6, 6 * MB);
        let mut res = Residency::new();
        assert_eq!(res.state_bytes(), 0);
        res.bind(id, spec);
        let bound = res.state_bytes();
        assert!(bound > 0);
        res.stage_dataset(&mut core, &topo, &comm, id).unwrap();
        let staged = res.state_bytes();
        assert!(staged > bound, "delivered/pinned paths are accounted");
        // Re-staging the same dataset must not grow the footprint: the
        // serving loop stages on every re-open, and a footprint that
        // scaled with stage count would leak on a long-lived core.
        for _ in 0..5 {
            res.stage_dataset(&mut core, &topo, &comm, id).unwrap();
        }
        assert_eq!(res.state_bytes(), staged, "footprint grew with stage count");
        res.unpin_dataset(&mut core, id);
        assert!(res.state_bytes() < staged, "released pins leave the books");
    }

    #[test]
    fn over_pinned_budget_surfaces_as_error() {
        let (mut core, topo, spec) = setup(2, 4);
        let comm = crate::mpisim::Comm::leader(&topo.spec);
        // A pinned blocker leaves room for less than one file.
        core.nodes.set_capacity(Some(2 * MB));
        core.nodes.write_range(0, 1, "/tmp/blocker", Blob::synthetic(MB + MB / 2, 9));
        core.nodes.pin("/tmp/blocker");
        let mut catalog = Catalog::new();
        let id = catalog.register("ds", "/projects/ds", 4, 4 * MB);
        let mut res = Residency::new();
        res.bind(id, spec);
        let out = res.stage_dataset(&mut core, &topo, &comm, id);
        assert!(out.is_err(), "rejected staging must surface as an error");
        assert!(core.node_write_rejections() > 0);
        // This dataset's pins were released; the blocker keeps its pin
        // and the store stayed within budget throughout.
        assert!(!core.nodes.is_pinned("/tmp/ds/f000.bin"));
        assert!(core.nodes.is_pinned("/tmp/blocker"));
        assert!(core.nodes.bytes_on(0) <= 2 * MB);
        assert_eq!(res.stats.stages, 0, "failed stages must not book stats");
    }

    #[test]
    fn deleted_file_releases_its_stale_pin() {
        let (mut core, topo, spec) = setup(4, 3);
        let comm = crate::mpisim::Comm::leader(&topo.spec);
        let mut catalog = Catalog::new();
        let id = catalog.register("ds", "/projects/ds", 3, 3 * MB);
        let mut res = Residency::new();
        res.bind(id, spec);
        res.stage_dataset(&mut core, &topo, &comm, id).unwrap();
        assert!(core.nodes.is_pinned("/tmp/ds/f002.bin"));
        // The file disappears from the shared FS; the next stage
        // resolves two files and must drop the stale third pin.
        core.pfs.delete("/projects/ds/f002.bin");
        let m = res.stage_dataset(&mut core, &topo, &comm, id).unwrap();
        assert_eq!(m.total_files(), 2);
        assert!(!core.nodes.is_pinned("/tmp/ds/f002.bin"));
        assert!(core.nodes.is_pinned("/tmp/ds/f001.bin"));
        // The orphaned replica is now evictable.
        assert_eq!(core.evict_path("/tmp/ds/f002.bin").len(), 1);
    }

    #[test]
    fn begin_commit_split_matches_sync_stage() {
        // The serving-shaped begin/commit pair must land exactly what
        // the synchronous call lands: same manifest, same pins, same
        // stats — and the in-flight guard rejects a double begin.
        let (mut core, topo, spec) = setup(4, 5);
        let comm = crate::mpisim::Comm::leader(&topo.spec);
        let mut catalog = Catalog::new();
        let id = catalog.register("ds", "/projects/ds", 5, 5 * MB);
        let mut res = Residency::new();
        res.bind(id, spec);
        let m = res.begin_stage(&mut core, &topo, &comm, id, 77).unwrap();
        assert_eq!(m.staged.len(), 5);
        assert!(
            res.begin_stage(&mut core, &topo, &comm, id, 78).is_err(),
            "double begin must error"
        );
        // Commit before the transfer lands must fail verification...
        // (nothing has simulated yet, so no bytes are resident).
        assert!(res.commit_stage(&mut core, &comm, id).is_err());
        // ...so re-begin and drive the plan properly this time.
        let _ = res.begin_stage(&mut core, &topo, &comm, id, 79).unwrap();
        core.run_to_completion();
        let m = res.commit_stage(&mut core, &comm, id).unwrap();
        assert_eq!(m.total_files(), 5);
        assert_eq!(res.stats.stages, 1);
        assert!(core.nodes.is_pinned("/tmp/ds/f000.bin"));
        assert!(res.dataset_resident_on(&core, id, 2));
        // Commit without a begin errors.
        assert!(res.commit_stage(&mut core, &comm, id).is_err());
        res.unpin_dataset(&mut core, id);
        assert!(core.residency.mirrors(&core.nodes));
    }

    #[test]
    fn evicted_dataset_promotes_from_ssd_not_gpfs() {
        // Orthros-class machine (SSD tier live) with a RAM slice that
        // holds exactly one 2 MB dataset: staging the second dataset
        // demotes the first whole, and re-opening the first is pure
        // promotion — zero GPFS re-staging.
        let mut core = SimCore::new();
        let mut machine = crate::cluster::orthros();
        machine.nodes = 4;
        let topo = Topology::build(machine, GpfsParams::default(), &mut core.net);
        topo.apply_storage_budgets(&mut core);
        core.nodes.set_capacity(Some(2 * MB));
        let comm = crate::mpisim::Comm::leader(&topo.spec);
        let mut catalog = Catalog::new();
        let mut res = Residency::new();
        let mut ids = Vec::new();
        for d in 0..2u64 {
            for f in 0..2u64 {
                core.pfs.write(
                    format!("/projects/tds{d}/f{f}.bin"),
                    Blob::synthetic(MB, 10 + d * 2 + f),
                );
            }
            let id = catalog.register(format!("tds{d}"), format!("/projects/tds{d}"), 2, 2 * MB);
            let spec = HookSpec::parse(&format!(
                "broadcast to /tmp/tds{d} {{ /projects/tds{d}/*.bin }}"
            ))
            .unwrap();
            res.bind(id, spec);
            ids.push(id);
        }
        let m0 = res.stage_dataset(&mut core, &topo, &comm, ids[0]).unwrap();
        assert_eq!(m0.staged.len(), 2);
        res.unpin_dataset(&mut core, ids[0]);
        let m1 = res.stage_dataset(&mut core, &topo, &comm, ids[1]).unwrap();
        assert_eq!(m1.staged.len(), 2);
        res.unpin_dataset(&mut core, ids[1]);
        // Dataset 0 was displaced — but demoted, and the engine billed
        // the transfers over the SSD link.
        assert_eq!(core.metrics.count("node.demotions"), 2);
        let staged_before = res.stats.staged_bytes;
        let m2 = res.stage_dataset(&mut core, &topo, &comm, ids[0]).unwrap();
        assert_eq!(m2.promoted.len(), 2, "re-open must promote, not re-stage");
        assert!(m2.staged.is_empty() && m2.hits.is_empty());
        assert_eq!(m2.promoted_bytes, 2 * MB);
        assert_eq!(m2.local_rate(), 1.0);
        assert_eq!(res.stats.staged_bytes, staged_before, "no GPFS bytes moved");
        assert_eq!(res.stats.file_promotions, 2);
        assert!(core.metrics.bytes("node.promote") >= 2 * MB);
        // Promoted replicas are byte-identical to the originals and
        // pinned; the mirror tracked every tier move.
        for f in 0..2 {
            let want = core.pfs.read(&format!("/projects/tds0/f{f}.bin")).unwrap();
            let got = core.nodes.read(2, &format!("/tmp/tds0/f{f}.bin")).unwrap();
            assert!(got.same_content(want));
        }
        assert!(core.nodes.is_pinned("/tmp/tds0/f0.bin"));
        assert!(core.residency.mirrors(&core.nodes));
        res.unpin_dataset(&mut core, ids[0]);
    }

    #[test]
    fn torn_replica_peer_copies_from_survivors() {
        // A node failure strips node 2's replicas of a dataset staged
        // on 4 nodes. With peer_copy armed, the re-stage classifies
        // every torn file as a copy — zero shared-FS traffic — and
        // lands content identical to the originals on exactly the
        // missing node. Disarmed (the seed behaviour), the same tear
        // re-stages from GPFS.
        let run = |armed: bool| {
            let (mut core, topo, spec) = setup(4, 3);
            let comm = crate::mpisim::Comm::leader(&topo.spec);
            let mut catalog = Catalog::new();
            let id = catalog.register("ds", "/projects/ds", 3, 3 * MB);
            let mut res = Residency::new();
            res.peer_copy = armed;
            res.bind(id, spec);
            res.stage_dataset(&mut core, &topo, &comm, id).unwrap();
            res.unpin_dataset(&mut core, id);
            core.fail_node(2);
            let m = res.stage_dataset(&mut core, &topo, &comm, id).unwrap();
            // Whatever the source, recovery must restore bit-identical
            // content on the stripped node and keep the mirror true.
            for f in 0..3 {
                let want = core.pfs.read(&format!("/projects/ds/f00{f}.bin")).unwrap();
                let got = core.nodes.read(2, &format!("/tmp/ds/f00{f}.bin")).unwrap();
                assert!(got.same_content(want), "armed={armed} f{f}");
            }
            assert!(core.residency.mirrors(&core.nodes));
            res.unpin_dataset(&mut core, id);
            (m, res.stats)
        };
        let (m, stats) = run(true);
        assert_eq!(m.copied.len(), 3, "torn files must peer-copy");
        assert!(m.staged.is_empty() && m.promoted.is_empty() && m.hits.is_empty());
        assert_eq!(m.copied_bytes, 3 * MB);
        assert_eq!(m.local_rate(), 1.0);
        assert_eq!(stats.file_copies, 3);
        assert_eq!(stats.staged_bytes, 3 * MB, "only the first stage touched GPFS");
        let (m, stats) = run(false);
        assert_eq!(m.staged.len(), 3, "seed behaviour: the tear re-stages from GPFS");
        assert!(m.copied.is_empty());
        assert_eq!(stats.file_copies, 0);
        assert_eq!(stats.staged_bytes, 6 * MB);
    }

    #[test]
    fn unbound_dataset_errors() {
        let (mut core, topo, _) = setup(2, 1);
        let comm = crate::mpisim::Comm::leader(&topo.spec);
        let mut res = Residency::new();
        assert!(res
            .stage_dataset(&mut core, &topo, &comm, crate::catalog::DatasetId(9))
            .is_err());
    }
}
