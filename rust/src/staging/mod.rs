//! The Swift I/O hook (SIV) — the paper's key contribution — and the
//! naive per-task baseline it is evaluated against.
//!
//! - [`spec`]: the hook specification language of Fig 6 — a list of
//!   *broadcast definitions*, each mapping glob patterns on the shared
//!   filesystem to a node-local target directory.
//! - [`hook`]: the staged path. Executed on the *leader communicator*
//!   (one rank per node): rank 0 performs the globs (exactly one
//!   process touches filesystem metadata), `MPI_Bcast`s the file list,
//!   then `MPI_File_read_all` replicates each file's bytes to every
//!   node, which writes them to the local RAM disk.
//! - [`naive`]: the original I/O approach — "each task reads input
//!   data independently from GPFS, without the use of collectives" —
//!   including the glob-on-every-rank metadata storm the paper calls
//!   out as the naive implementation hazard.
//! - [`read_phase`]: tasks reading their staged replica from /tmp, the
//!   flat 53.4 MB/s-per-process phase of Fig 9.
//! - [`residency`]: the capacity era of the hook — the residency
//!   table mirroring node-local contents, the incremental re-stage
//!   plan (move only missing/stale files), and the session manager
//!   binding catalog datasets to hook specs.
//! - [`service`]: the interactive serving layer — seeded multi-session
//!   workloads over staged, pinned, node-resident datasets, with
//!   capacity admission and session-fair scheduling.
//! - [`ingest`]: the beamline ingest source — a seeded detector
//!   streaming fixed-size frames over the machine's beamline link into
//!   node memory *while sessions read*, with RAM -> SSD -> GPFS
//!   backpressure spill and a detector-stall counter when even the
//!   GPFS leg saturates.
//! - [`policy`]: the elastic multi-tenant layer — weighted-fair
//!   admission across tenants, the seeded elastic node-pool schedule
//!   with modeled warm-up, and the pluggable keep-alive / prewarm
//!   policies driven by per-tenant access history.

pub mod gather;
pub mod hook;
pub mod ingest;
pub mod naive;
pub mod policy;
pub mod residency;
pub mod service;
pub mod spec;

pub use gather::{gather_plan, GatherManifest};
pub use hook::{staged_plan, StagedManifest};
pub use ingest::{IngestCfg, IngestMode, IngestOutcome};
pub use naive::naive_plan;
pub use policy::{
    AdmitQueue, ElasticCfg, PolicyKind, TenantHistory, TenantId, TenantsCfg,
};
pub use residency::{
    incremental_plan, IncrementalManifest, Residency, ResidencyStats, ResidencyTable,
};
pub use service::{
    generate_workload, run_serve, run_serve_specs, ServeMode, ServeOutcome, ServiceCfg,
    SessionSpec,
};
pub use spec::{BroadcastDef, HookSpec};

/// Node-local paths on `node` matching `pattern` (the gather
/// collective's local "glob" — touches no shared-FS metadata).
pub fn spec_paths(
    nodes: &crate::cluster::NodeStores,
    node: u32,
    pattern: &str,
) -> Vec<String> {
    nodes
        .paths_on(node)
        .into_iter()
        .filter(|p| crate::pfs::glob_match(pattern, p))
        .collect()
}

use crate::cluster::Topology;
use crate::mpisim::Comm;
use crate::simtime::plan::{Plan, StepId};

/// Append the *Read* phase (Fig 9): every rank of `comm` reads
/// `bytes_per_rank` from its node-local replica at the machine's
/// per-process RAM-disk bandwidth. Perfectly scalable by construction
/// (the paper measured 10.8 +/- 0.1 s regardless of allocation size).
pub fn read_phase(
    plan: &mut Plan,
    topo: &Topology,
    comm: &Comm,
    bytes_per_rank: u64,
    deps: Vec<StepId>,
) -> StepId {
    plan.flow_capped(
        vec![], // node-local: no shared resource
        comm.size(),
        bytes_per_rank,
        topo.spec.ramdisk_proc_read_bw,
        deps,
        "read",
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{bgq, Topology};
    use crate::engine::SimCore;
    use crate::pfs::GpfsParams;
    use crate::units::MB;

    #[test]
    fn spec_paths_sorted_and_reproducible() {
        // Hook transfer lists must be identical across runs: the local
        // glob enumerates the BTreeMap-backed store in sorted order.
        let build = || {
            let mut ns = crate::cluster::NodeStores::new();
            for name in ["/tmp/out/9.bin", "/tmp/out/1.bin", "/tmp/out/5.bin"] {
                ns.write_range(0, 3, name, crate::pfs::Blob::real(vec![0; 2]));
            }
            ns
        };
        let a = spec_paths(&build(), 2, "/tmp/out/*.bin");
        let b = spec_paths(&build(), 2, "/tmp/out/*.bin");
        assert_eq!(a, b);
        assert_eq!(a, vec!["/tmp/out/1.bin", "/tmp/out/5.bin", "/tmp/out/9.bin"]);
        let mut sorted = a.clone();
        sorted.sort();
        assert_eq!(a, sorted);
    }

    #[test]
    fn read_phase_is_flat_in_node_count() {
        // The paper's signature observation: 577 MB per process at
        // 53.4 MB/s = 10.8 s whether 64 or 8,192 nodes.
        for nodes in [64u32, 8192] {
            let mut core = SimCore::new();
            let topo = Topology::build(bgq(nodes), GpfsParams::default(), &mut core.net);
            let comm = Comm::world(&topo.spec);
            let mut p = Plan::new(0);
            read_phase(&mut p, &topo, &comm, 577 * MB, vec![]);
            core.submit(p);
            core.run_to_completion();
            let t = core.now.secs_f64();
            assert!((t - 10.8).abs() < 0.1, "nodes={nodes} t={t}");
        }
    }
}
