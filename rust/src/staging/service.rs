//! Interactive beamline serving: many analysis sessions over staged,
//! node-resident data.
//!
//! The paper's headline regime is *interactive*: data "staged into and
//! cached in compute node memory for extended periods, during which
//! time various processing tasks may efficiently access it", cutting
//! beamline turnaround from months to minutes. Every other driver in
//! this repo is a one-shot batch experiment; this module is the
//! serving layer that regime implies:
//!
//! - a **seeded workload generator** ([`generate_workload`]): analysis
//!   sessions arrive over simulated time as a Poisson process
//!   (exponential inter-arrival gaps), each opening one catalogued
//!   dataset and submitting a mix of NF-HEDM (many short fits) and
//!   FF-HEDM (fewer long fits) task batches of varying size;
//! - **admission control** against the node-memory budget: a session
//!   is admitted when its dataset's working set fits beside the
//!   already-open datasets (FIFO, head-of-line — deterministic);
//!   admitted datasets are staged incrementally and **pinned** through
//!   [`crate::staging::Residency`] for exactly the span sessions hold
//!   them open, then unpinned so the space serves the next tenant;
//! - **session-fair execution** through
//!   [`crate::dataflow::sched::SessionScheduler`]: every admitted
//!   session's task DAG runs concurrently against one
//!   [`SimCore`], sharing the worker pool fairly, with locality-aware
//!   placement reused as-is;
//! - **per-session turnaround accounting**: arrival -> last task
//!   completion, observed into [`crate::metrics::Metrics`] and
//!   reported as P50/P95/P99 ([`crate::metrics::Percentiles`]).
//!
//! Two serving modes isolate the paper's contribution:
//! [`ServeMode::Staged`] (stage once per dataset activation, tasks
//! read node-local replicas) vs [`ServeMode::Naive`] (every task
//! re-reads its inputs from the shared FS through the uncoordinated
//! path). The `serve` experiment contrasts them across a scenario
//! matrix; staged serving must win on P99 turnaround everywhere.
//!
//! Everything is deterministic: same seed, same turnaround table,
//! bit-for-bit (tested in `rust/tests/integration_serve.rs`).
//!
//! # Chaos serving
//!
//! [`ServiceCfg::chaos`] arms seeded node-failure injection (see
//! [`crate::chaos`]): kill timers fire under [`CHAOS_TAG_BASE`], each
//! dropping one node's replicas and in-flight work. The service then
//! (a) routes the loss to
//! [`SessionScheduler::on_node_failure`] so every lost task is
//! reassigned exactly once, and (b) re-stages every open dataset the
//! kill tore, through the residency manager's peer-copy-first recovery
//! path. A chaos config with zero failures schedules nothing and is
//! bit-identical to no chaos config at all (tested).
//!
//! # Streaming ingest
//!
//! [`ServiceCfg::ingest`] attaches a beamline detector
//! ([`crate::staging::ingest`]): frames stream over the machine's
//! beamline link and land in node tiers (RAM, then SSD, then GPFS
//! spill) *while the serving loop runs*. The catalog record grows per
//! landed frame, and a session opening the live dataset blocks only
//! until the frames its tasks read have landed (every task scans the
//! full dataset, so that is all of them); whatever spilled to GPFS is
//! re-staged through the ordinary hook path before the waiters start.
//! `ingest: None` — and `Some` with zero frames — is bit-identical to
//! the pre-ingest service (tested).
//!
//! # Elastic multi-tenant serving
//!
//! Three policy layers (see [`crate::staging::policy`]) turn the
//! single-queue, static-budget service into an elastic multi-tenant
//! one, each off by default and bit-identical to the seed path when
//! disarmed (all tested):
//!
//! - [`ServiceCfg::tenants`] splits sessions across weighted tenants.
//!   Admission picks the backlogged tenant with the least normalized
//!   service (admitted bytes / weight, compared exactly), head-of-line
//!   blocking on the picked session; with equal weights the pick
//!   degenerates to the globally earliest arrival — the literal seed
//!   FIFO order.
//! - [`ServiceCfg::elastic`] leases nodes in and out of the *staging
//!   budget* on a seeded schedule (timers under
//!   [`crate::staging::policy::ELASTIC_TAG_BASE`]): a joining node
//!   pays a modeled warm-up before its RAM counts toward admission,
//!   and departures shrink the budget — warm pins are reclaimed first,
//!   the admitted working set drains through ordinary closes, and the
//!   evicted replicas re-stage later through the existing
//!   demote/promote machinery.
//! - [`ServiceCfg::policy`] arms prewarm/keep-alive: a closing dataset
//!   can stay pinned (`Warm`) through a predicted idle gap under an
//!   expiry grant (timers under
//!   [`crate::staging::policy::KEEPALIVE_TAG_BASE`]), and a predicted
//!   next dataset can be prewarmed into leftover budget, so reopens
//!   and predicted sessions find their data resident. Soft (warm +
//!   prewarming) bytes are budget-accounted: `admitted + soft <=
//!   effective budget` at every admission and prewarm, so staging can
//!   never be rejected by a full store.

use crate::catalog::{Catalog, DatasetId};
use crate::chaos::{kill_schedule, ChaosCfg, CHAOS_TAG_BASE};
use crate::cluster::{orthros, Topology};
use crate::dataflow::graph::{Task, TaskGraph};
use crate::dataflow::sched::{
    ReadStats, SchedulerCfg, SessionId, SessionScheduler, TASK_TAG_BASE,
};
use crate::engine::{Director, KernelStats, Notice, SimCore, DEMOTE_TAG};
use crate::metrics::Percentiles;
use crate::mpisim::Comm;
use crate::pfs::{Blob, GpfsParams};
use crate::simtime::flownet::ThroughputMode;
use crate::simtime::heap::HeapKind;
use crate::staging::ingest::{Ingest, IngestCfg, IngestMode, IngestOutcome, INGEST_TAG_BASE};
use crate::staging::policy::{
    elastic_tag, keepalive_tag, min_warm, pool_schedule, AdmitQueue, ElasticCfg, PolicyKind,
    ServePolicy, TenantHistory, TenantId, TenantsCfg, ELASTIC_TAG_BASE, KEEPALIVE_TAG_BASE,
};
use crate::staging::{HookSpec, Residency};
use crate::units::{Duration, SimTime, StateBytes, GB, MB};
use crate::util::prng::Pcg64;

/// Tag namespace for staging plans the service submits (one per
/// dataset activation), below the scheduler's [`TASK_TAG_BASE`].
pub const STAGE_TAG_BASE: u64 = 1 << 47;

// Checked tag allocation for the bands the serving director
// multiplexes on one timer/plan namespace: arrival < elastic <
// keep-alive < ingest < chaos < demote < stage < task. Each helper
// debug-asserts its index cannot reach the band above
// (regression-tested at 10^4 sessions in
// `tag_bands_stay_disjoint_at_ten_thousand_sessions`).

fn session_tag(s: usize) -> u64 {
    let tag = s as u64;
    debug_assert!(tag < ELASTIC_TAG_BASE, "session index {s} collides with the elastic band");
    tag
}

fn kill_tag(k: usize) -> u64 {
    let tag = CHAOS_TAG_BASE + k as u64;
    debug_assert!(tag < DEMOTE_TAG, "kill index {k} collides with the demotion tag");
    tag
}

fn stage_tag(d: usize) -> u64 {
    let tag = STAGE_TAG_BASE + d as u64;
    debug_assert!(tag < TASK_TAG_BASE, "dataset index {d} collides with the task band");
    tag
}

/// How sessions read their data.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeMode {
    /// Stage each opened dataset into node memory once (incremental,
    /// pinned while open); tasks read node-local replicas.
    Staged,
    /// No staging: every task re-reads its inputs from the shared FS
    /// through the uncoordinated (degrading) path.
    Naive,
}

/// Serve scenario parameters. All sizes are per node; the workload is
/// entirely determined by `seed`.
#[derive(Clone, Debug)]
pub struct ServiceCfg {
    pub seed: u64,
    /// Sessions in the workload.
    pub sessions: usize,
    /// Mean inter-arrival gap (seconds) of the Poisson process.
    pub mean_gap_secs: f64,
    /// Distinct catalogued datasets sessions draw from.
    pub datasets: usize,
    pub files_per_dataset: usize,
    pub file_bytes: u64,
    /// Per-node RAM staging budget override (None = machine default).
    /// The admission layer keeps the open (pinned) working set within
    /// whatever budget the store ends up with.
    pub ramdisk_slice: Option<u64>,
    /// Per-node SSD-tier budget override: None = machine default,
    /// `Some(0)` disables the tier entirely (the discard-eviction
    /// baseline the `tiers` experiment compares against). Closed
    /// datasets demote here under RAM pressure and are promoted back
    /// on re-open instead of re-staged from the shared FS.
    pub ssd_slice: Option<u64>,
    pub mode: ServeMode,
    pub sched: SchedulerCfg,
    /// Seeded node-failure injection. `None` (and `Some` with zero
    /// failures) runs bit-identically to the pre-chaos service; `Some`
    /// with failures arms kill timers, peer-copy recovery staging, and
    /// exactly-once task reassignment.
    pub chaos: Option<ChaosCfg>,
    /// Beamline detector streaming one dataset in while sessions run.
    /// `None` (and `Some` with zero frames) runs bit-identically to
    /// the pre-ingest service. Requires [`ServeMode::Staged`], one
    /// frame per dataset file (`frames == files_per_dataset`,
    /// `frame_bytes == file_bytes`), and no chaos injection.
    pub ingest: Option<IngestCfg>,
    /// Weighted tenants sessions are partitioned across. The default
    /// single unit-weight tenant — and any all-equal weight vector —
    /// admits in the exact seed FIFO order (rule E1; tested).
    pub tenants: TenantsCfg,
    /// Prewarm / keep-alive policy. [`PolicyKind::None`] (the
    /// default) is bit-identical to the policy-free close path.
    pub policy: PolicyKind,
    /// Elastic node-pool schedule. `None` (and `Some` with zero
    /// events) serves against the static budget, bit-identically to
    /// the seed. Arming it requires [`ServeMode::Staged`], a finite
    /// RAM budget, and neither chaos kills nor a streaming detector.
    pub elastic: Option<ElasticCfg>,
}

impl Default for ServiceCfg {
    fn default() -> Self {
        ServiceCfg {
            seed: 42,
            sessions: 24,
            mean_gap_secs: 30.0,
            datasets: 4,
            files_per_dataset: 6,
            file_bytes: 16 * MB,
            ramdisk_slice: None,
            ssd_slice: None,
            mode: ServeMode::Staged,
            sched: SchedulerCfg { locality_aware: true, ..Default::default() },
            chaos: None,
            ingest: None,
            tenants: TenantsCfg::default(),
            policy: PolicyKind::None,
            elastic: None,
        }
    }
}

impl ServiceCfg {
    /// Per-dataset staged footprint.
    pub fn dataset_bytes(&self) -> u64 {
        self.files_per_dataset as u64 * self.file_bytes
    }
}

/// Task-batch flavour within a session.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchKind {
    /// NF-HEDM: many short orientation fits (2-12 s).
    Nf,
    /// FF-HEDM: fewer, longer fits (log-uniform 5-40 s).
    Ff,
}

/// One task batch of a session.
#[derive(Clone, Copy, Debug)]
pub struct Batch {
    pub kind: BatchKind,
    pub tasks: usize,
}

/// One generated analysis session.
#[derive(Clone, Debug)]
pub struct SessionSpec {
    /// When the scientist shows up.
    pub arrival: SimTime,
    /// Which dataset the session opens (index into the catalog).
    pub dataset: usize,
    /// Owning tenant: dataset-partitioned via [`TenantsCfg::owner`]
    /// in generated workloads, free-form in hand-built specs.
    pub tenant: TenantId,
    pub batches: Vec<Batch>,
}

impl SessionSpec {
    pub fn task_count(&self) -> usize {
        self.batches.iter().map(|b| b.tasks).sum()
    }
}

/// Generate the session workload: Poisson arrivals, uniform dataset
/// choice, 1-3 batches per session with mixed NF/FF kinds and varying
/// sizes. Fully determined by `cfg.seed`. The owning tenant is the
/// dataset's fixed partition owner ([`TenantsCfg::owner`]) — no PRNG
/// draw, so the arrival/dataset stream is unchanged from the
/// pre-tenant generator. Degenerate shapes (zero sessions or zero
/// datasets to draw from) produce the empty workload — serving them
/// is a clean no-op, not a panic.
pub fn generate_workload(cfg: &ServiceCfg) -> Vec<SessionSpec> {
    if cfg.sessions == 0 || cfg.datasets == 0 {
        return Vec::new();
    }
    let mut rng = Pcg64::new(cfg.seed);
    let mut t = SimTime::ZERO;
    (0..cfg.sessions)
        .map(|_| {
            // Exponential inter-arrival gap: -ln(1-U) * mean.
            let gap = -(1.0 - rng.f64()).ln() * cfg.mean_gap_secs;
            t = t + Duration::from_secs_f64(gap);
            let dataset = rng.below(cfg.datasets as u64) as usize;
            let n_batches = 1 + rng.below(3) as usize;
            let batches = (0..n_batches)
                .map(|_| {
                    if rng.f64() < 0.5 {
                        Batch { kind: BatchKind::Nf, tasks: 24 + rng.below(25) as usize }
                    } else {
                        Batch { kind: BatchKind::Ff, tasks: 8 + rng.below(9) as usize }
                    }
                })
                .collect();
            SessionSpec { arrival: t, dataset, tenant: cfg.tenants.owner(dataset), batches }
        })
        .collect()
}

/// Build one session's task DAG. Every task reads the session's full
/// dataset (the paper's FitOrientation access pattern: each task scans
/// the staged layer) from node-local replicas ([`ServeMode::Staged`])
/// or from the shared FS ([`ServeMode::Naive`]); runtimes come from a
/// per-session PRNG stream so both modes fit identical compute.
pub fn session_graph(cfg: &ServiceCfg, spec: &SessionSpec, session: usize) -> TaskGraph {
    let mut g = TaskGraph::new();
    let mut rng = Pcg64::new(cfg.seed ^ (0x5E55_0000 + session as u64).wrapping_mul(0x9E37_79B9));
    let d = spec.dataset;
    let prefix = match cfg.mode {
        ServeMode::Staged => format!("/tmp/serve/ds{d}"),
        ServeMode::Naive => format!("/projects/serve/ds{d}"),
    };
    for (bi, b) in spec.batches.iter().enumerate() {
        for i in 0..b.tasks {
            let (label, secs) = match b.kind {
                BatchKind::Nf => ("nf", rng.normal_ms(6.0, 1.5).clamp(2.0, 12.0)),
                BatchKind::Ff => ("ff", rng.log_uniform(5.0, 40.0)),
            };
            let mut t = Task::compute(
                format!("s{session}/b{bi}/{label}{i}"),
                Duration::from_secs_f64(secs),
            )
            .with_output(50_000);
            for f in 0..cfg.files_per_dataset {
                t = t.with_input(format!("{prefix}/f{f:03}.bin"), None);
            }
            g.add(t);
        }
    }
    g
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum DsState {
    /// Not resident-pinned; next open must stage (incrementally).
    Cold,
    /// A stage plan is in flight; sessions wait on its completion.
    Staging,
    /// Staged, verified, and pinned; sessions start immediately.
    Resident,
    /// Closed but still pinned under a keep-alive grant (or a landed
    /// prewarm): the next open is a free warm hit. Its bytes are
    /// *soft*-charged against the budget and reclaimed under
    /// pressure, latest-expiry pin first.
    Warm,
}

/// The serving director: owns session lifecycle (arrive -> admit ->
/// stage -> run -> close), delegating execution to the session-fair
/// scheduler and staging to the residency manager.
pub struct Service {
    cfg: ServiceCfg,
    topo: Topology,
    leader: Comm,
    specs: Vec<SessionSpec>,
    res: Residency,
    /// The metadata catalog: pre-registered datasets plus the live
    /// dataset's per-frame growth.
    catalog: Catalog,
    /// The streaming detector, when one is attached.
    ing: Option<Ingest>,
    /// Workload index of the dataset the detector writes.
    ingest_ds: Option<usize>,
    ds_ids: Vec<DatasetId>,
    ds_state: Vec<DsState>,
    /// Open-session count per dataset; pins released at zero.
    ds_users: Vec<u32>,
    /// Sessions awaiting a dataset's in-flight stage.
    ds_waiters: Vec<Vec<usize>>,
    sched: SessionScheduler,
    /// Scheduler SessionId index -> workload session index.
    sid_to_session: Vec<usize>,
    done_at: Vec<Option<SimTime>>,
    /// Weighted-fair admission queue (seed FIFO at equal weights).
    admit: AdmitQueue,
    /// Bytes of currently-open datasets (the admitted working set).
    admitted_bytes: u64,
    /// The prewarm/keep-alive policy in force ([`PolicyKind::build`]).
    policy: Box<dyn ServePolicy>,
    /// Per-tenant access history feeding the policy.
    hist: Vec<TenantHistory>,
    /// One prewarm attempt per (tenant, prediction): re-armed at the
    /// tenant's next arrival, so a reclaimed prewarm is never
    /// re-issued inside the same admission pass.
    prewarm_hint: Vec<Option<usize>>,
    /// Bytes held by warm pins and in-flight prewarms; admission and
    /// prewarming keep `admitted_bytes + soft_bytes` within the
    /// effective budget.
    soft_bytes: u64,
    /// Per-dataset soft charge (0 or the dataset footprint).
    soft_of: Vec<u64>,
    /// Tenant whose prediction started an in-flight prewarm stage.
    prewarming: Vec<Option<TenantId>>,
    /// Active keep-alive grant id per dataset; a grant timer firing
    /// after its grant was superseded is detected here and ignored.
    grant_of: Vec<Option<u64>>,
    /// Grant id -> dataset (grants are issued monotonically).
    grant_ds: Vec<usize>,
    /// When each warm pin's grant expires (reclaim priority).
    warm_expiry: Vec<Option<SimTime>>,
    /// Tenant charged for the GPFS bytes of the dataset's most recent
    /// stage (admission or prewarm; recovery keeps the previous one).
    stage_tenant: Vec<Option<TenantId>>,
    /// The materialised elastic pool schedule; index k is the
    /// warm-delta of the timer armed under `ELASTIC_TAG_BASE + k`.
    /// Empty = elastic disarmed (the budget stays physical).
    pool_deltas: Vec<(SimTime, i32)>,
    /// Nodes currently warm (leased and warmed up).
    warm_nodes: u32,
    total_nodes: u32,
    /// Fewest warm nodes the pool ever held.
    pub min_warm_nodes: u32,
    /// Elastic pool events that fired.
    pub pool_events: usize,
    /// When each session was admitted (naive mode: at arrival).
    admitted_at: Vec<Option<SimTime>>,
    /// Session indices in admission order.
    admission_order: Vec<usize>,
    /// Hard-admitted bytes charged per tenant.
    tenant_admitted: Vec<u64>,
    /// GPFS stage bytes attributed per tenant.
    tenant_gpfs: Vec<u64>,
    /// Sessions admitted straight onto a kept-warm dataset.
    pub warm_hits: usize,
    /// Prewarm stages initiated.
    pub prewarms: usize,
    /// Keep-alive grants issued at dataset close.
    pub keepalive_grants: usize,
    /// Warm pins reclaimed under budget pressure or pool shrink.
    pub reclaims: usize,
    /// Per-tier node budgets admission accounts: the open (pinned)
    /// working set must fit `budgets.ram`; `budgets.ssd` is the
    /// demotion reservoir closed-but-warm datasets overflow into, so
    /// re-opens promote locally instead of re-staging from GPFS.
    budgets: crate::storage::TierBudgets,
    /// Deepest the admission queue ever got.
    pub peak_queue: usize,
    /// The materialised kill schedule; index k is the victim of the
    /// timer armed under `CHAOS_TAG_BASE + k`. Empty = chaos disarmed.
    kills: Vec<(SimTime, u32)>,
    /// Kills that actually fired.
    pub node_failures: usize,
    /// Dispatched tasks lost to kills and reassigned (exactly once).
    pub lost_tasks: usize,
}

impl Service {
    fn on_arrival(&mut self, core: &mut SimCore, s: usize) {
        match self.cfg.mode {
            ServeMode::Naive => {
                self.admitted_at[s] = Some(core.now);
                self.start_tasks(core, s);
            }
            ServeMode::Staged => {
                let t = self.specs[s].tenant;
                self.hist[t].record_open(self.specs[s].dataset, core.now);
                // The tenant showed up: its standing prediction is
                // stale, re-arm the prewarm pass for it.
                self.prewarm_hint[t] = None;
                self.admit.push(t, s);
                self.try_admit(core);
                // Depth after the admission pass: counts sessions the
                // budget actually made wait, not the arrival itself.
                self.peak_queue = self.peak_queue.max(self.admit.len());
            }
        }
    }

    /// Admit while the picked head fits the effective budget:
    /// weighted-fair across tenants ([`AdmitQueue`]) with head-of-line
    /// blocking on the picked session — deterministic, and the literal
    /// seed FIFO under equal weights. Warm pins are reclaimed
    /// (latest-expiry first) when the head needs their budget; soft
    /// charges of a warm or prewarming dataset the head opens harden
    /// into admitted bytes instead.
    fn try_admit(&mut self, core: &mut SimCore) {
        while let Some((t, s)) = self.admit.peek() {
            let d = self.specs[s].dataset;
            let need = if self.ds_users[d] > 0 || self.soft_of[d] > 0 {
                0
            } else {
                self.cfg.dataset_bytes()
            };
            if let Some(b) = self.eff_budget() {
                while self.admitted_bytes + self.soft_bytes + need > b
                    && self.reclaim_for_pressure(core)
                {}
                if self.admitted_bytes + self.soft_bytes + need > b {
                    break;
                }
            }
            let popped = self.admit.pop();
            debug_assert_eq!(popped, Some((t, s)));
            self.admit.on_admitted(t, need);
            self.tenant_admitted[t] += need;
            self.admitted_at[s] = Some(core.now);
            self.admission_order.push(s);
            self.ds_users[d] += 1;
            self.admitted_bytes += need;
            if self.soft_of[d] > 0 {
                // The session opened a warm or prewarming dataset:
                // the soft charge hardens into admitted bytes and any
                // outstanding keep-alive grant is superseded.
                debug_assert_eq!(self.ds_users[d], 1);
                self.admitted_bytes += self.soft_of[d];
                self.soft_bytes -= self.soft_of[d];
                self.soft_of[d] = 0;
                self.grant_of[d] = None;
                self.warm_expiry[d] = None;
                self.prewarming[d] = None;
            }
            match self.ds_state[d] {
                DsState::Resident => self.start_tasks(core, s),
                DsState::Warm => {
                    // The keep-alive (or prewarm) paid off: the
                    // replicas are still pinned, nothing to stage.
                    self.warm_hits += 1;
                    self.ds_state[d] = DsState::Resident;
                    self.start_tasks(core, s);
                }
                DsState::Staging => self.ds_waiters[d].push(s),
                DsState::Cold => {
                    if self.ingest_pending(d) {
                        // Frames are still arriving: the session
                        // blocks exactly until the frames its tasks
                        // read have landed (all of them — every task
                        // scans the full dataset).
                        self.ds_state[d] = DsState::Staging;
                        self.ds_waiters[d].push(s);
                    } else if self.nothing_to_stage(d) {
                        self.ds_state[d] = DsState::Resident;
                        self.start_tasks(core, s);
                    } else {
                        self.ds_state[d] = DsState::Staging;
                        self.stage_tenant[d] = Some(t);
                        self.ds_waiters[d].push(s);
                        self.res
                            .begin_stage(
                                core,
                                &self.topo,
                                &self.leader,
                                self.ds_ids[d],
                                stage_tag(d),
                            )
                            .expect("serve: begin_stage failed");
                    }
                }
            }
        }
        if self.cfg.policy.prewarms() {
            self.try_prewarm(core);
        }
    }

    /// The admission budget with the elastic pool applied: the
    /// physical RAM budget scaled by the warm share of the machine
    /// (`None` = no RAM capacity configured, unbounded admission).
    /// With the pool disarmed this is exactly the physical budget.
    fn eff_budget(&self) -> Option<u64> {
        let b = self.budgets.ram?;
        if self.pool_deltas.is_empty() {
            return Some(b);
        }
        Some((b as u128 * self.warm_nodes as u128 / self.total_nodes as u128) as u64)
    }

    /// Reclaim one warm pin under budget pressure, latest-expiry pin
    /// first (the most speculative hold goes first), dataset index
    /// breaking ties. Prewarming datasets have a stage in flight and
    /// are not reclaimable; returns false when nothing was warm.
    fn reclaim_for_pressure(&mut self, core: &mut SimCore) -> bool {
        let victim = (0..self.ds_state.len())
            .filter(|&d| self.ds_state[d] == DsState::Warm)
            .max_by_key(|&d| (self.warm_expiry[d], d));
        match victim {
            Some(d) => {
                self.reclaims += 1;
                self.release_warm(core, d);
                true
            }
            None => false,
        }
    }

    /// Drop a warm pin: unpin the replicas, release the soft charge,
    /// supersede any outstanding grant, and return the dataset to
    /// `Cold`. Shared by grant expiry, budget pressure, pool shrink,
    /// and chaos tears.
    fn release_warm(&mut self, core: &mut SimCore, d: usize) {
        debug_assert_eq!(self.ds_state[d], DsState::Warm);
        self.res.unpin_dataset(core, self.ds_ids[d]);
        self.soft_bytes -= self.soft_of[d];
        self.soft_of[d] = 0;
        self.grant_of[d] = None;
        self.warm_expiry[d] = None;
        self.ds_state[d] = DsState::Cold;
    }

    /// Transition a still-pinned, fully staged dataset to warm under
    /// a keep-alive grant of `secs`. Precondition: its bytes are
    /// soft-charged. A non-positive grant releases immediately.
    fn make_warm(&mut self, core: &mut SimCore, d: usize, secs: f64) {
        debug_assert_eq!(self.soft_of[d], self.cfg.dataset_bytes());
        self.ds_state[d] = DsState::Warm;
        if !(secs > 0.0 && secs.is_finite()) {
            self.release_warm(core, d);
            return;
        }
        let at = core.now + Duration::from_secs_f64(secs);
        let g = self.grant_ds.len() as u64;
        self.grant_ds.push(d);
        self.grant_of[d] = Some(g);
        self.warm_expiry[d] = Some(at);
        core.timer(at, keepalive_tag(g));
    }

    /// Prewarm pass: stage each tenant's predicted-next dataset into
    /// leftover budget so the predicted session finds it warm. At
    /// most one attempt per (tenant, prediction) until the tenant's
    /// next arrival clears the hint — without it, a reclaimed prewarm
    /// would be re-issued inside the same admission pass, forever.
    fn try_prewarm(&mut self, core: &mut SimCore) {
        let ds = self.cfg.dataset_bytes();
        if ds == 0 {
            return;
        }
        for t in 0..self.hist.len() {
            let Some(d) = self.policy.prewarm(&self.hist[t]) else { continue };
            if d >= self.ds_state.len()
                || self.prewarm_hint[t] == Some(d)
                || self.ds_state[d] != DsState::Cold
                || self.ingest_ds == Some(d)
            {
                continue;
            }
            let fits = match self.eff_budget() {
                Some(b) => self.admitted_bytes + self.soft_bytes + ds <= b,
                None => true,
            };
            if !fits {
                continue;
            }
            self.prewarm_hint[t] = Some(d);
            self.prewarms += 1;
            self.soft_of[d] = ds;
            self.soft_bytes += ds;
            self.prewarming[d] = Some(t);
            self.stage_tenant[d] = Some(t);
            self.ds_state[d] = DsState::Staging;
            self.res
                .begin_stage(core, &self.topo, &self.leader, self.ds_ids[d], stage_tag(d))
                .expect("serve: prewarm begin_stage failed");
        }
    }

    /// The live dataset still has frames in flight: sessions opening
    /// it wait for the detector, not for a stage plan.
    fn ingest_pending(&self, d: usize) -> bool {
        self.ingest_ds == Some(d) && self.ing.as_ref().is_some_and(|i| !i.complete())
    }

    /// Opening this dataset would move nothing: zero-file datasets,
    /// and a fully streamed-in live dataset with no GPFS spills (the
    /// hook's glob would match no files — every frame is already
    /// node-resident and pinned by the detector).
    fn nothing_to_stage(&self, d: usize) -> bool {
        if self.cfg.files_per_dataset == 0 {
            return true;
        }
        self.ingest_ds == Some(d)
            && self.ing.as_ref().is_some_and(|i| i.complete() && i.gpfs_frames() == 0)
    }

    fn on_stage_done(&mut self, core: &mut SimCore, d: usize) {
        debug_assert_eq!(self.ds_state[d], DsState::Staging);
        // Byte accounting lives in `Residency::stats`; no second
        // counter to keep in sync here.
        match self.res.commit_stage(core, &self.leader, self.ds_ids[d]) {
            Ok(m) => {
                // GPFS attribution: the tenant whose open (or
                // prediction) triggered this stage pays its bytes.
                if let Some(t) = self.stage_tenant[d] {
                    self.tenant_gpfs[t] += m.staged_bytes;
                }
            }
            Err(e) => {
                // Without chaos a failed commit is an admission bug.
                // With chaos, a kill can tear replicas the in-flight
                // stage classified as hits; re-stage the delta (the
                // residency manager recovers via peer copy / SSD
                // promote / GPFS re-read) and keep waiters waiting.
                assert!(
                    !self.kills.is_empty(),
                    "serve: stage rejected under memory pressure (admission bug): {e}"
                );
                self.res
                    .begin_stage(core, &self.topo, &self.leader, self.ds_ids[d], stage_tag(d))
                    .expect("serve: recovery begin_stage failed");
                return;
            }
        }
        if self.prewarming[d].take().is_some() {
            // A prewarm landed with no takers yet (an admission onto
            // it would have cleared the flag): hold the dataset warm
            // under the policy's grant until the predicted session
            // shows up.
            debug_assert_eq!(self.ds_users[d], 0);
            debug_assert!(self.ds_waiters[d].is_empty());
            let t = self.stage_tenant[d].expect("prewarm without a tenant");
            let secs = self.policy.keepalive_secs(&self.hist[t], d);
            self.make_warm(core, d, secs);
            if self.ds_state[d] == DsState::Cold {
                // The policy granted nothing: the freed soft charge
                // may admit a queued session.
                self.try_admit(core);
            }
            return;
        }
        self.ds_state[d] = DsState::Resident;
        for s in std::mem::take(&mut self.ds_waiters[d]) {
            self.start_tasks(core, s);
        }
        if self.ds_users[d] == 0 {
            // Every user left while a recovery stage was in flight
            // (only possible under chaos): close the dataset now that
            // the stage has landed.
            self.close_dataset(core, d, None);
        }
    }

    /// Last user out: consult the policy — either keep the dataset
    /// pinned (warm) through the predicted idle gap under a
    /// keep-alive grant, or unpin so the space serves the next tenant
    /// (the seed path, and the literal [`PolicyKind::None`]
    /// behaviour). Replicas stay resident until evicted either way,
    /// so a re-open usually restages nothing (all hits).
    fn close_dataset(&mut self, core: &mut SimCore, d: usize, tenant: Option<TenantId>) {
        let ds = self.cfg.dataset_bytes();
        self.admitted_bytes -= ds;
        let secs = match tenant {
            Some(t) if ds > 0 => self.policy.keepalive_secs(&self.hist[t], d),
            _ => 0.0,
        };
        if secs > 0.0 && secs.is_finite() {
            self.keepalive_grants += 1;
            self.soft_of[d] = ds;
            self.soft_bytes += ds;
            self.make_warm(core, d, secs);
        } else {
            self.res.unpin_dataset(core, self.ds_ids[d]);
            self.ds_state[d] = DsState::Cold;
        }
        self.try_admit(core);
    }

    fn start_tasks(&mut self, core: &mut SimCore, s: usize) {
        let g = session_graph(&self.cfg, &self.specs[s], s);
        let sid = self.sched.add_session(core, g);
        debug_assert_eq!(sid.0 as usize, self.sid_to_session.len());
        self.sid_to_session.push(s);
    }

    fn on_tasks_done(&mut self, core: &mut SimCore, sid: SessionId) {
        let s = self.sid_to_session[sid.0 as usize];
        debug_assert!(self.done_at[s].is_none(), "session completed twice");
        self.done_at[s] = Some(core.now);
        let turnaround = (core.now - self.specs[s].arrival).secs_f64();
        core.metrics.observe("session.turnaround", turnaround);
        if self.cfg.mode == ServeMode::Staged {
            let d = self.specs[s].dataset;
            let t = self.specs[s].tenant;
            self.hist[t].record_close(d, core.now);
            self.ds_users[d] -= 1;
            // Close only when no recovery stage is in flight; a
            // Staging dataset closes when its stage lands instead
            // (see `on_stage_done`), keeping pin/commit ordering sane.
            if self.ds_users[d] == 0 && self.ds_state[d] == DsState::Resident {
                self.close_dataset(core, d, Some(t));
            }
        }
    }

    /// A chaos kill fired: fail the node (replicas, mirrors, in-flight
    /// plans), reassign its lost tasks exactly once, and re-stage every
    /// open dataset the kill tore.
    fn on_kill(&mut self, core: &mut SimCore, k: usize) {
        let node = self.kills[k].1;
        self.node_failures += 1;
        core.fail_node(node);
        self.lost_tasks += self.sched.on_node_failure(core, node);
        let mut released = false;
        for d in 0..self.ds_ids.len() {
            if self.ds_state[d] == DsState::Resident
                && !self.res.dataset_resident_on(core, self.ds_ids[d], node)
            {
                self.ds_state[d] = DsState::Staging;
                self.res
                    .begin_stage(core, &self.topo, &self.leader, self.ds_ids[d], stage_tag(d))
                    .expect("serve: recovery begin_stage failed");
            } else if self.ds_state[d] == DsState::Warm
                && !self.res.dataset_resident_on(core, self.ds_ids[d], node)
            {
                // The kill tore a speculative warm pin: drop the
                // grant rather than re-stage speculation — the next
                // open re-stages through the ordinary cold path.
                released = true;
                self.release_warm(core, d);
            }
        }
        if released {
            self.try_admit(core);
        }
    }

    /// A keep-alive grant expired: if it is still the dataset's
    /// active grant (not superseded by a re-open or a reclaim),
    /// release the warm pin and let the freed budget admit.
    fn on_keepalive(&mut self, core: &mut SimCore, g: u64) {
        let d = self.grant_ds[g as usize];
        if self.grant_of[d] != Some(g) {
            return;
        }
        debug_assert_eq!(self.ds_state[d], DsState::Warm);
        self.release_warm(core, d);
        self.try_admit(core);
    }

    /// An elastic pool event fired: a leased node finished warming up
    /// (+1) or a lease ended (-1). The effective budget follows the
    /// warm count; shrinks reclaim warm pins first, and an admitted
    /// working set already over the shrunk budget drains through
    /// ordinary closes (the *physical* store is untouched, so nothing
    /// in flight can be rejected).
    fn on_pool_event(&mut self, core: &mut SimCore, k: usize) {
        let delta = self.pool_deltas[k].1;
        self.pool_events += 1;
        self.warm_nodes = (self.warm_nodes as i64 + delta as i64) as u32;
        debug_assert!(self.warm_nodes >= 1 && self.warm_nodes <= self.total_nodes);
        self.min_warm_nodes = self.min_warm_nodes.min(self.warm_nodes);
        if delta < 0 {
            if let Some(b) = self.eff_budget() {
                while self.admitted_bytes + self.soft_bytes > b
                    && self.reclaim_for_pressure(core)
                {}
            }
        } else {
            self.try_admit(core);
        }
    }

    /// A detector cadence tick fired.
    fn on_ingest_timer(&mut self, core: &mut SimCore) {
        let ing = self.ing.as_mut().expect("ingest tick without a detector");
        ing.on_timer(core, &self.topo);
    }

    /// An ingest frame's wire or spill plan finished: land it, and
    /// when it was the last frame, release the sessions the live
    /// dataset is blocking.
    fn on_ingest_plan_done(&mut self, core: &mut SimCore, tag: u64) {
        let ing = self.ing.as_mut().expect("ingest plan without a detector");
        if ing.on_plan_done(core, &self.topo, &mut self.catalog, tag) {
            self.on_ingest_complete(core);
        }
    }

    /// Every frame has landed: the live dataset behaves like any other
    /// from here on. If sessions are already waiting, re-stage
    /// whatever spilled to GPFS (nothing spilled means they start
    /// immediately — the frames are resident and pinned).
    fn on_ingest_complete(&mut self, core: &mut SimCore) {
        let d = self.ingest_ds.expect("ingest completion without a detector");
        if self.ds_state[d] != DsState::Staging {
            // No session has opened the live dataset yet; admission
            // treats it as a normal cold dataset when one does.
            return;
        }
        if self.ing.as_ref().is_some_and(|i| i.gpfs_frames() > 0) {
            // Attribute the spill re-stage to the earliest waiter's
            // tenant (the session whose open is paying for it).
            self.stage_tenant[d] = self.ds_waiters[d].first().map(|&s| self.specs[s].tenant);
            self.res
                .begin_stage(core, &self.topo, &self.leader, self.ds_ids[d], stage_tag(d))
                .expect("serve: spill re-stage failed");
        } else {
            self.ds_state[d] = DsState::Resident;
            for s in std::mem::take(&mut self.ds_waiters[d]) {
                self.start_tasks(core, s);
            }
        }
    }
}

impl Director for Service {
    fn on_notice(&mut self, core: &mut SimCore, notice: Notice) {
        match notice {
            Notice::Timer { tag } => {
                // Session-arrival tags are small workload indices;
                // elastic pool events, keep-alive expiries, detector
                // ticks, and chaos kill timers live in their own
                // bands above them.
                if tag >= CHAOS_TAG_BASE {
                    self.on_kill(core, (tag - CHAOS_TAG_BASE) as usize);
                } else if tag >= INGEST_TAG_BASE {
                    self.on_ingest_timer(core);
                } else if tag >= KEEPALIVE_TAG_BASE {
                    self.on_keepalive(core, tag - KEEPALIVE_TAG_BASE);
                } else if tag >= ELASTIC_TAG_BASE {
                    self.on_pool_event(core, (tag - ELASTIC_TAG_BASE) as usize);
                } else {
                    self.on_arrival(core, tag as usize);
                }
            }
            Notice::PlanDone { tag, .. } => {
                if tag >= TASK_TAG_BASE {
                    if let Some(sid) = self.sched.on_plan_done(core, tag) {
                        self.on_tasks_done(core, sid);
                    }
                } else if tag >= STAGE_TAG_BASE {
                    self.on_stage_done(core, (tag - STAGE_TAG_BASE) as usize);
                } else if tag == DEMOTE_TAG {
                    // Eviction's demotion flows: the engine booked the
                    // tier move when it planned them; completion needs
                    // no action. (Checked before the ingest band —
                    // DEMOTE_TAG sits numerically above it.)
                } else if tag >= INGEST_TAG_BASE {
                    self.on_ingest_plan_done(core, tag);
                }
            }
            _ => {}
        }
    }
}

/// Aggregate outcome of one serve run.
#[derive(Clone, Debug)]
pub struct ServeOutcome {
    /// Per-session turnaround (arrival -> last task done), seconds, by
    /// session index (arrival order). Bit-identical across same-seed
    /// runs.
    pub turnaround_secs: Vec<f64>,
    /// Turnaround percentiles; `None` when the workload was empty.
    pub percentiles: Option<Percentiles>,
    /// Total virtual time until the machine drained.
    pub virtual_secs: f64,
    /// Bytes the staging path actually moved from GPFS (0 in naive
    /// mode).
    pub staged_bytes: u64,
    /// Bytes served by SSD-tier promotion instead of GPFS re-staging.
    pub promoted_bytes: u64,
    /// Bytes recovery staging copied between surviving peers' RAM
    /// instead of re-reading GPFS (0 without chaos).
    pub copied_bytes: u64,
    /// Bytes RAM eviction demoted into the SSD tier (survived) over
    /// the run.
    pub demoted_bytes: u64,
    /// Input-read accounting summed over all sessions.
    pub reads: ReadStats,
    pub peak_queue: usize,
    pub sessions: usize,
    /// Scheduler bookkeeping resident after the machine drained, over
    /// sessions served — a long-lived serving core must hold a few
    /// hundred bytes per *completed* session (stats headers), never
    /// retained task graphs.
    pub sched_state: StateBytes,
    /// Residency-manager bookkeeping over catalogued datasets.
    pub residency_state: StateBytes,
    /// Chaos kills that fired during the run.
    pub node_failures: usize,
    /// Dispatched tasks lost to kills and reassigned exactly once.
    pub lost_tasks: usize,
    /// What the detector did, when one was attached.
    pub ingest: Option<IngestOutcome>,
    /// Per-session owning tenant, by session index.
    pub tenant_of: Vec<TenantId>,
    /// Session indices in admission order: arrival order under the
    /// seed FIFO, the weighted-fair pick order otherwise. Empty in
    /// naive mode (arrival *is* admission there).
    pub admission_order: Vec<usize>,
    /// Per-session admission wait (arrival -> admitted), seconds.
    pub admit_wait_secs: Vec<f64>,
    /// Hard-admitted working-set bytes charged per tenant.
    pub tenant_admitted_bytes: Vec<u64>,
    /// GPFS stage bytes attributed per tenant (the tenant whose open
    /// or prediction triggered each stage).
    pub tenant_gpfs_bytes: Vec<u64>,
    /// Sessions admitted straight onto a kept-warm dataset.
    pub warm_hits: usize,
    /// Prewarm stages initiated.
    pub prewarms: usize,
    /// Keep-alive grants issued at dataset close.
    pub keepalive_grants: usize,
    /// Warm pins reclaimed under budget pressure or pool shrink.
    pub reclaims: usize,
    /// Elastic pool events (warm-up completions + leaves) that fired.
    pub pool_events: usize,
    /// Fewest warm nodes the elastic pool ever held (`nodes` when the
    /// pool is disarmed).
    pub min_warm_nodes: u32,
    /// Events the engine processed draining the run. **Kernel-
    /// sensitive**: the wheel kernel reclaims stale flow checks before
    /// they pop, so its raw count can be lower than the seed
    /// kernel's — compare [`ServeOutcome::useful_events`] across
    /// kernels, never this.
    pub events_processed: u64,
    /// Kernel observability snapshot at drain (heap occupancy peaks,
    /// stale-check economy).
    pub kernel: KernelStats,
}

impl ServeOutcome {
    /// Events that did real work: total pops minus the stale flow
    /// checks that fired as no-ops. Identical across event-heap
    /// backends (the wheel kernel turns would-be stale pops into
    /// eager cancels; everything else is bit-identical), so this is
    /// the cross-kernel comparison figure.
    pub fn useful_events(&self) -> u64 {
        self.events_processed - self.kernel.stale_check_pops
    }
}

/// Run one serve scenario on an Orthros-class cluster of `nodes` fat
/// nodes (64 ranks each, 500 MB/s per-process local reads, 1.25 GB/s
/// shared NFS backplane — the campaign experiment's machine model).
pub fn run_serve(nodes: u32, cfg: &ServiceCfg, mode: ThroughputMode) -> ServeOutcome {
    run_serve_specs(nodes, cfg, mode, generate_workload(cfg))
}

/// [`run_serve`] with an explicit event-heap backend (`Seed` is the
/// differential baseline for the kernel bench and property suite).
pub fn run_serve_kernel(
    nodes: u32,
    cfg: &ServiceCfg,
    mode: ThroughputMode,
    kind: HeapKind,
) -> ServeOutcome {
    run_serve_specs_kernel(nodes, cfg, mode, kind, generate_workload(cfg))
}

/// Run a serve scenario over an explicit session list: the property
/// harness hand-builds adversarial multi-tenant schedules, while
/// [`run_serve`] generates the list from the seed. Every spec's
/// dataset and tenant must be in range for `cfg`.
pub fn run_serve_specs(
    nodes: u32,
    cfg: &ServiceCfg,
    mode: ThroughputMode,
    specs: Vec<SessionSpec>,
) -> ServeOutcome {
    run_serve_specs_kernel(nodes, cfg, mode, HeapKind::default(), specs)
}

/// [`run_serve_specs`] with an explicit event-heap backend.
pub fn run_serve_specs_kernel(
    nodes: u32,
    cfg: &ServiceCfg,
    mode: ThroughputMode,
    kind: HeapKind,
    specs: Vec<SessionSpec>,
) -> ServeOutcome {
    assert!(nodes >= 1);
    cfg.tenants.validate();
    for sp in &specs {
        assert!(sp.dataset < cfg.datasets, "session dataset {} out of range", sp.dataset);
        assert!(sp.tenant < cfg.tenants.count(), "session tenant {} out of range", sp.tenant);
    }
    let mut core = SimCore::with_parts(mode, kind);
    let mut spec = orthros();
    spec.nodes = nodes;
    let gpfs = GpfsParams { peak_bw: 1.25 * GB as f64, ..Default::default() };
    let topo = Topology::build(spec, gpfs, &mut core.net);
    topo.apply_storage_budgets(&mut core);
    if let Some(slice) = cfg.ramdisk_slice {
        let b = core.nodes.capacity().map_or(slice, |c| c.min(slice));
        core.nodes.set_capacity(Some(b));
    }
    match cfg.ssd_slice {
        // 0 disables the tier: eviction discards, the pre-tiering
        // baseline.
        Some(0) => core.nodes.set_ssd_capacity(None),
        Some(slice) => {
            let b = core.nodes.ssd_capacity().map_or(slice, |c| c.min(slice));
            core.nodes.set_ssd_capacity(Some(b));
        }
        None => {}
    }

    // The detector, when armed. Zero frames means "no detector": the
    // run must be bit-identical to `ingest: None`.
    let ingest_cfg = cfg.ingest.clone().filter(|i| i.frames > 0);
    if let Some(i) = &ingest_cfg {
        assert_eq!(cfg.mode, ServeMode::Staged, "ingest requires staged serving");
        assert!(i.dataset < cfg.datasets, "ingest dataset index out of range");
        assert_eq!(i.frames, cfg.files_per_dataset, "one frame per dataset file");
        assert_eq!(i.frame_bytes, cfg.file_bytes, "frame size must match the file size");
    }
    let live_ds = ingest_cfg.as_ref().map(|i| i.dataset);

    // The shared-FS datasets + their catalog records and hook specs.
    // The live dataset is registered empty — no pre-written files, no
    // catalogued bytes; the detector grows it frame by frame.
    let mut catalog = Catalog::new();
    let mut res = Residency::new();
    let mut ds_ids = Vec::new();
    for d in 0..cfg.datasets {
        let live = live_ds == Some(d);
        if !live {
            for f in 0..cfg.files_per_dataset {
                core.pfs.write(
                    format!("/projects/serve/ds{d}/f{f:03}.bin"),
                    Blob::synthetic(cfg.file_bytes, 0x5EB0_0000 + (d * 1000 + f) as u64),
                );
            }
        }
        let id = catalog.register(
            format!("serve-ds{d}"),
            format!("/projects/serve/ds{d}"),
            if live { 0 } else { cfg.files_per_dataset as u64 },
            if live { 0 } else { cfg.dataset_bytes() },
        );
        catalog.set_attr(id, "technique", "hedm");
        let spec = HookSpec::parse(&format!(
            "broadcast to /tmp/serve/ds{d} {{ /projects/serve/ds{d}/*.bin }}"
        ))
        .unwrap();
        res.bind(id, spec);
        ds_ids.push(id);
    }
    let mut budgets = crate::storage::TierBudgets {
        ram: core.nodes.capacity(),
        ssd: core.nodes.ssd_capacity(),
    };
    if let Some(i) = &ingest_cfg {
        if i.mode == IngestMode::Stream {
            // Reserve the detector's RAM slice out of the admission
            // budget: live frames pin node RAM that admission must
            // never hand to sessions. The reservation is also what
            // makes a RAM-slice frame write always feasible — pinned
            // session data plus live frames can never exceed the
            // store.
            budgets.ram = budgets.ram.map(|b| {
                assert!(i.ram_slice < b, "detector RAM slice swallows the node budget ({b})");
                b - i.ram_slice
            });
        }
    }
    if cfg.mode == ServeMode::Staged {
        if let Some(b) = budgets.ram {
            assert!(
                cfg.dataset_bytes() <= b,
                "a single dataset ({}) must fit the node RAM budget ({b})",
                cfg.dataset_bytes()
            );
        }
    }

    let n = specs.len();
    for (s, sp) in specs.iter().enumerate() {
        core.timer(sp.arrival, session_tag(s));
    }
    // Arm chaos: one kill timer per scheduled failure, and the
    // peer-copy recovery source in the residency manager. A zero-kill
    // schedule arms nothing, keeping the run bit-identical to
    // `chaos: None` (tested in `rust/tests/integration_chaos.rs`).
    let kills = cfg
        .chaos
        .as_ref()
        .map(|c| kill_schedule(c, nodes))
        .unwrap_or_default();
    for (k, &(at, _)) in kills.iter().enumerate() {
        core.timer(at, kill_tag(k));
    }
    res.peer_copy = !kills.is_empty();
    // A kill tearing pinned live frames would leave the detector's
    // recorded tiers wrong; the two failure models stay separate.
    assert!(
        ingest_cfg.is_none() || kills.is_empty(),
        "node-failure injection is not supported while a detector streams"
    );
    // Arm the elastic pool: one timer per warm-delta event. Zero
    // events materialise nothing, keeping the run bit-identical to
    // `elastic: None` (tested). The schedule's floor guarantees even
    // the smallest effective budget admits one working set, so
    // admission can never deadlock on a shrunken pool.
    let pool_deltas = cfg
        .elastic
        .filter(|e| e.events > 0)
        .map(|e| {
            assert_eq!(cfg.mode, ServeMode::Staged, "the elastic pool requires staged serving");
            pool_schedule(&e, nodes)
        })
        .unwrap_or_default();
    for (k, &(at, _)) in pool_deltas.iter().enumerate() {
        core.timer(at, elastic_tag(k));
    }
    if !pool_deltas.is_empty() {
        assert!(
            kills.is_empty() && ingest_cfg.is_none(),
            "the elastic pool composes with neither chaos kills nor a streaming detector"
        );
        let b = budgets.ram.expect("the elastic pool requires a RAM budget");
        let floor = (b as u128 * min_warm(&pool_deltas, nodes) as u128 / nodes as u128) as u64;
        assert!(
            cfg.dataset_bytes() <= floor,
            "a dataset ({}) must fit the smallest elastic budget ({floor})",
            cfg.dataset_bytes()
        );
    }
    let world = Comm::world(&topo.spec);
    let leader = Comm::leader(&topo.spec);
    let mut svc = Service {
        sched: SessionScheduler::new(topo.clone(), world, cfg.sched),
        cfg: cfg.clone(),
        topo,
        leader,
        specs,
        res,
        catalog,
        ing: ingest_cfg.as_ref().map(|i| Ingest::new(i.clone(), ds_ids[i.dataset])),
        ingest_ds: live_ds,
        ds_ids,
        ds_state: vec![DsState::Cold; cfg.datasets],
        ds_users: vec![0; cfg.datasets],
        ds_waiters: vec![Vec::new(); cfg.datasets],
        sid_to_session: Vec::new(),
        done_at: vec![None; n],
        admit: AdmitQueue::new(&cfg.tenants),
        admitted_bytes: 0,
        policy: cfg.policy.build(),
        hist: vec![TenantHistory::default(); cfg.tenants.count()],
        prewarm_hint: vec![None; cfg.tenants.count()],
        soft_bytes: 0,
        soft_of: vec![0; cfg.datasets],
        prewarming: vec![None; cfg.datasets],
        grant_of: vec![None; cfg.datasets],
        grant_ds: Vec::new(),
        warm_expiry: vec![None; cfg.datasets],
        stage_tenant: vec![None; cfg.datasets],
        pool_deltas,
        warm_nodes: nodes,
        total_nodes: nodes,
        min_warm_nodes: nodes,
        pool_events: 0,
        admitted_at: vec![None; n],
        admission_order: Vec::new(),
        tenant_admitted: vec![0; cfg.tenants.count()],
        tenant_gpfs: vec![0; cfg.tenants.count()],
        warm_hits: 0,
        prewarms: 0,
        keepalive_grants: 0,
        reclaims: 0,
        budgets,
        peak_queue: 0,
        kills,
        node_failures: 0,
        lost_tasks: 0,
    };
    if let Some(ing) = svc.ing.as_mut() {
        ing.start(&mut core);
    }
    core.run(&mut svc);

    assert!(
        svc.done_at.iter().all(Option::is_some),
        "serve run drained with unserved sessions"
    );
    // Starvation-freedom at run level: the drained queue means every
    // arrival was eventually admitted, and every keep-alive grant
    // expired or was superseded (no soft charge outlives its timer).
    assert!(svc.admit.is_empty(), "serve run drained with queued sessions");
    debug_assert_eq!(svc.soft_bytes, 0, "a warm pin outlived its grant");
    assert_eq!(core.node_write_rejections(), 0, "admission let a write be rejected");
    if svc.node_failures == 0 {
        // Promotion plans pin their SSD copies, so a planned promotion
        // can neither miss nor be rejected mid-flight — unless a chaos
        // kill dropped the pinned copy underneath the plan, which the
        // recovery path absorbs.
        assert_eq!(core.metrics.count("node.promote.missed"), 0, "promotion missed its SSD copy");
        assert_eq!(core.metrics.count("node.promote.rejected"), 0, "promotion rejected");
    }
    // The detector drained with the rest of the machine: every frame
    // landed somewhere, its content is intact at the recorded tier,
    // and the catalog saw exactly the frames that landed. The ttfr
    // the ingest experiment compares is the earliest completion of a
    // session reading the live dataset.
    let ingest = svc.ing.as_ref().map(|ing| {
        assert!(ing.complete(), "serve run drained with detector frames in flight");
        ing.verify(&core, &svc.topo);
        let d = svc.ingest_ds.expect("detector without a live dataset");
        let rec = svc.catalog.get(svc.ds_ids[d]).expect("live dataset unregistered");
        assert_eq!(rec.files, cfg.files_per_dataset as u64, "catalog growth lost frames");
        assert_eq!(rec.bytes, cfg.dataset_bytes(), "catalog growth lost bytes");
        let mut first: Option<f64> = None;
        for (s, sp) in svc.specs.iter().enumerate() {
            if sp.dataset == d {
                let t = svc.done_at[s].unwrap().secs_f64();
                first = Some(first.map_or(t, |f: f64| f.min(t)));
            }
        }
        ing.outcome(first)
    });
    let turnaround_secs: Vec<f64> = (0..n)
        .map(|s| (svc.done_at[s].unwrap() - svc.specs[s].arrival).secs_f64())
        .collect();
    // Single source of truth: the reported percentiles are computed
    // from the turnaround table itself. The metrics sample series
    // (observed at each session close) must agree — any divergence
    // means the two recording sites drifted.
    let mut sorted = turnaround_secs.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let percentiles = Percentiles::from_sorted(&sorted);
    debug_assert_eq!(
        percentiles,
        core.metrics.percentiles("session.turnaround"),
        "Service turnaround table and metrics series diverged"
    );
    let mut reads = ReadStats::default();
    for i in 0..svc.sched.session_count() {
        let st = svc.sched.stats(SessionId(i as u32));
        reads.staged_bytes += st.reads.staged_bytes;
        reads.ssd_bytes += st.reads.ssd_bytes;
        reads.unstaged_bytes += st.reads.unstaged_bytes;
        reads.peer_bytes += st.reads.peer_bytes;
        reads.cache_hits += st.reads.cache_hits;
    }
    let admit_wait_secs: Vec<f64> = (0..n)
        .map(|s| (svc.admitted_at[s].unwrap() - svc.specs[s].arrival).secs_f64())
        .collect();
    ServeOutcome {
        turnaround_secs,
        percentiles,
        virtual_secs: core.now.secs_f64(),
        staged_bytes: svc.res.stats.staged_bytes,
        promoted_bytes: svc.res.stats.promoted_bytes,
        copied_bytes: svc.res.stats.copied_bytes,
        demoted_bytes: core.metrics.bytes("node.demote"),
        reads,
        peak_queue: svc.peak_queue,
        sessions: n,
        sched_state: StateBytes::new(svc.sched.state_bytes(), svc.sched.session_count() as u64),
        residency_state: StateBytes::new(svc.res.state_bytes(), cfg.datasets as u64),
        node_failures: svc.node_failures,
        lost_tasks: svc.lost_tasks,
        ingest,
        tenant_of: svc.specs.iter().map(|sp| sp.tenant).collect(),
        admission_order: std::mem::take(&mut svc.admission_order),
        admit_wait_secs,
        tenant_admitted_bytes: std::mem::take(&mut svc.tenant_admitted),
        tenant_gpfs_bytes: std::mem::take(&mut svc.tenant_gpfs),
        warm_hits: svc.warm_hits,
        prewarms: svc.prewarms,
        keepalive_grants: svc.keepalive_grants,
        reclaims: svc.reclaims,
        pool_events: svc.pool_events,
        min_warm_nodes: svc.min_warm_nodes,
        events_processed: core.events_processed,
        kernel: core.kernel_stats(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(mode: ServeMode) -> ServiceCfg {
        ServiceCfg {
            sessions: 10,
            mean_gap_secs: 20.0,
            datasets: 3,
            files_per_dataset: 4,
            file_bytes: 8 * MB,
            mode,
            ..Default::default()
        }
    }

    #[test]
    fn workload_is_seeded_and_plausible() {
        let cfg = ServiceCfg::default();
        let a = generate_workload(&cfg);
        let b = generate_workload(&cfg);
        assert_eq!(a.len(), cfg.sessions);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.dataset, y.dataset);
            assert_eq!(x.task_count(), y.task_count());
        }
        // Arrivals are non-decreasing; datasets in range; batches 1-3.
        for w in a.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        for s in &a {
            assert!(s.dataset < cfg.datasets);
            assert!((1..=3).contains(&s.batches.len()));
            assert!(s.task_count() >= 8);
        }
        let mut other = cfg.clone();
        other.seed = 43;
        let c = generate_workload(&other);
        assert!(a.iter().zip(&c).any(|(x, y)| x.arrival != y.arrival));
    }

    #[test]
    fn graphs_fit_identical_compute_in_both_modes() {
        let staged = small_cfg(ServeMode::Staged);
        let naive = small_cfg(ServeMode::Naive);
        let spec = &generate_workload(&staged)[3];
        let gs = session_graph(&staged, spec, 3);
        let gn = session_graph(&naive, spec, 3);
        assert_eq!(gs.len(), gn.len());
        for (a, b) in gs.tasks.iter().zip(&gn.tasks) {
            assert_eq!(a.runtime, b.runtime);
            assert!(a.inputs[0].path.starts_with("/tmp/serve/"));
            assert!(b.inputs[0].path.starts_with("/projects/serve/"));
            assert_eq!(a.inputs.len(), staged.files_per_dataset);
        }
    }

    #[test]
    fn staged_serving_runs_and_pins_correctly() {
        let out = run_serve(2, &small_cfg(ServeMode::Staged), ThroughputMode::Fast);
        assert_eq!(out.sessions, 10);
        assert_eq!(out.turnaround_secs.len(), 10);
        assert!(out.turnaround_secs.iter().all(|&t| t > 0.0));
        // Staged tasks never touch the shared FS for input reads.
        assert_eq!(out.reads.unstaged_bytes, 0);
        assert!(out.reads.staged_bytes > 0);
        // Residency reuse: total staged bytes are far below
        // sessions x dataset (most activations are all-hit).
        let per_ds = small_cfg(ServeMode::Staged).dataset_bytes();
        assert!(out.staged_bytes <= 3 * per_ds, "{}", out.staged_bytes);
        let p = out.percentiles.unwrap();
        assert!(p.p50 <= p.p95);
        assert!(p.p95 <= p.p99);
        // Completed sessions released their graphs: the drained core
        // keeps only per-session stats headers.
        assert_eq!(out.sched_state.units, 10);
        assert!(
            out.sched_state.per_unit() < 1024,
            "resident {} per served session",
            out.sched_state.per_unit()
        );
        assert!(out.residency_state.total > 0);
    }

    #[test]
    fn naive_serving_reads_shared_fs_only() {
        let out = run_serve(2, &small_cfg(ServeMode::Naive), ThroughputMode::Fast);
        assert_eq!(out.staged_bytes, 0);
        assert_eq!(out.reads.staged_bytes, 0);
        assert!(out.reads.unstaged_bytes > 0);
        assert_eq!(out.peak_queue, 0, "naive mode admits instantly");
    }

    #[test]
    fn staged_beats_naive_on_tails_and_mean() {
        let s = run_serve(2, &small_cfg(ServeMode::Staged), ThroughputMode::Fast);
        let n = run_serve(2, &small_cfg(ServeMode::Naive), ThroughputMode::Fast);
        let (sp, np) = (s.percentiles.unwrap(), n.percentiles.unwrap());
        assert!(sp.p99 < np.p99, "staged p99 {} vs naive p99 {}", sp.p99, np.p99);
        assert!(sp.p95 < np.p95);
        let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len() as f64;
        assert!(
            mean(&s.turnaround_secs) < mean(&n.turnaround_secs),
            "staged mean {} vs naive mean {}",
            mean(&s.turnaround_secs),
            mean(&n.turnaround_secs)
        );
    }

    #[test]
    fn admission_queues_under_tight_budget_and_still_serves_all() {
        // Budget of ~1.5 datasets: at most one dataset open at a time
        // (plus in-flight hits), so sessions for other datasets queue.
        let mut cfg = small_cfg(ServeMode::Staged);
        cfg.ramdisk_slice = Some(cfg.dataset_bytes() * 3 / 2);
        let out = run_serve(2, &cfg, ThroughputMode::Fast);
        assert_eq!(out.turnaround_secs.len(), 10);
        assert!(out.peak_queue > 0, "tight budget must queue sessions");
        // Determinism under pressure.
        let again = run_serve(2, &cfg, ThroughputMode::Fast);
        assert_eq!(out.turnaround_secs, again.turnaround_secs);
    }

    #[test]
    fn ssd_tier_absorbs_pressure_and_cuts_gpfs_restaging() {
        // Budget of ~1.5 datasets: transitions evict. With the SSD
        // tier live the evicted files demote and re-opens promote
        // locally; with it disabled every re-open re-stages from the
        // shared FS.
        let mut cfg = small_cfg(ServeMode::Staged);
        cfg.ramdisk_slice = Some(cfg.dataset_bytes() * 3 / 2);
        let mut discard = cfg.clone();
        discard.ssd_slice = Some(0);
        let tiered = run_serve(2, &cfg, ThroughputMode::Fast);
        let base = run_serve(2, &discard, ThroughputMode::Fast);
        assert!(tiered.demoted_bytes > 0, "pressure must demote");
        assert!(tiered.promoted_bytes > 0, "re-opens must promote");
        assert_eq!(base.promoted_bytes, 0, "disabled tier must not promote");
        assert_eq!(base.demoted_bytes, 0);
        assert!(
            tiered.staged_bytes < base.staged_bytes,
            "promotions must cut GPFS re-staging: tiered {} vs discard {}",
            tiered.staged_bytes,
            base.staged_bytes
        );
        // Neither policy ever sends task reads to the shared FS.
        assert_eq!(tiered.reads.unstaged_bytes, 0);
        assert_eq!(base.reads.unstaged_bytes, 0);
        // Determinism holds with tier traffic in the network.
        let again = run_serve(2, &cfg, ThroughputMode::Fast);
        assert_eq!(tiered.turnaround_secs, again.turnaround_secs);
        assert_eq!(tiered.promoted_bytes, again.promoted_bytes);
    }

    #[test]
    fn chaos_serving_recovers_and_stays_deterministic() {
        let mut cfg = small_cfg(ServeMode::Staged);
        cfg.chaos = Some(ChaosCfg { seed: 9, failures: 3, mean_gap_secs: 60.0 });
        // `run_serve` itself asserts every session completed — no task
        // loss — and that no node write was ever rejected.
        let out = run_serve(2, &cfg, ThroughputMode::Fast);
        assert_eq!(out.node_failures, 3);
        assert_eq!(out.turnaround_secs.len(), 10);
        // Recovery keeps task reads off the shared FS: torn replicas
        // are served from the surviving peer until re-staging lands.
        assert_eq!(out.reads.unstaged_bytes, 0);
        // The whole chaotic run is bit-reproducible.
        let again = run_serve(2, &cfg, ThroughputMode::Fast);
        assert_eq!(out.turnaround_secs, again.turnaround_secs);
        assert_eq!(out.lost_tasks, again.lost_tasks);
        assert_eq!(out.copied_bytes, again.copied_bytes);
        assert_eq!(out.staged_bytes, again.staged_bytes);
        assert_eq!(out.virtual_secs, again.virtual_secs);
    }

    #[test]
    fn zero_failure_chaos_is_bit_identical_to_none() {
        let mut cfg = small_cfg(ServeMode::Staged);
        cfg.chaos = Some(ChaosCfg { failures: 0, ..Default::default() });
        let armed = run_serve(2, &cfg, ThroughputMode::Fast);
        let plain = run_serve(2, &small_cfg(ServeMode::Staged), ThroughputMode::Fast);
        assert_eq!(armed.turnaround_secs, plain.turnaround_secs);
        assert_eq!(armed.virtual_secs, plain.virtual_secs);
        assert_eq!(armed.staged_bytes, plain.staged_bytes);
        assert_eq!(armed.node_failures, 0);
        assert_eq!(armed.lost_tasks, 0);
        assert_eq!(armed.copied_bytes, 0);
    }

    #[test]
    fn throughput_models_agree_on_turnarounds() {
        for mode in [ServeMode::Staged, ServeMode::Naive] {
            let fast = run_serve(2, &small_cfg(mode), ThroughputMode::Fast);
            let slow = run_serve(2, &small_cfg(mode), ThroughputMode::Slow);
            for (f, s) in fast.turnaround_secs.iter().zip(&slow.turnaround_secs) {
                assert!((f - s).abs() < 1e-5, "mode {mode:?}: fast {f} vs slow {s}");
            }
        }
    }

    #[test]
    fn degenerate_configs_no_op_cleanly() {
        // Zero sessions: nothing arrives, nothing runs, no panic.
        let mut cfg = small_cfg(ServeMode::Staged);
        cfg.sessions = 0;
        let out = run_serve(2, &cfg, ThroughputMode::Fast);
        assert_eq!(out.sessions, 0);
        assert!(out.turnaround_secs.is_empty());
        assert!(out.percentiles.is_none(), "empty runs report no percentiles");
        assert_eq!(out.staged_bytes, 0);

        // Zero datasets: the workload collapses to empty.
        let mut cfg = small_cfg(ServeMode::Staged);
        cfg.datasets = 0;
        assert!(generate_workload(&cfg).is_empty());
        let out = run_serve(2, &cfg, ThroughputMode::Fast);
        assert_eq!(out.sessions, 0);
        assert!(out.percentiles.is_none());

        // Zero files per dataset: sessions are pure compute; staging
        // is skipped entirely (the hook would glob no files).
        let mut cfg = small_cfg(ServeMode::Staged);
        cfg.files_per_dataset = 0;
        let out = run_serve(2, &cfg, ThroughputMode::Fast);
        assert_eq!(out.sessions, 10);
        assert!(out.turnaround_secs.iter().all(|&t| t > 0.0));
        assert_eq!(out.staged_bytes, 0);
        assert!(out.percentiles.is_some());
    }

    #[test]
    fn tag_bands_stay_disjoint_at_ten_thousand_sessions() {
        let n = 10_000;
        let mut tags: Vec<u64> = (0..n).map(session_tag).collect();
        tags.extend((0..n).map(elastic_tag));
        tags.extend((0..n as u64).map(keepalive_tag));
        tags.extend((0..n).map(crate::staging::ingest::ingest_tag));
        tags.extend((0..n).map(kill_tag));
        tags.push(DEMOTE_TAG);
        tags.extend((0..n).map(stage_tag));
        tags.sort_unstable();
        let before = tags.len();
        tags.dedup();
        assert_eq!(tags.len(), before, "tag bands overlap");
        assert!(tags.iter().all(|&t| t < TASK_TAG_BASE));
    }

    /// Hand-built session spec: one NF batch, explicit timing and
    /// ownership (the adversarial-schedule building block).
    fn spec(arrival_secs: u64, dataset: usize, tenant: TenantId, tasks: usize) -> SessionSpec {
        SessionSpec {
            arrival: SimTime(arrival_secs * 1_000_000_000),
            dataset,
            tenant,
            batches: vec![Batch { kind: BatchKind::Nf, tasks }],
        }
    }

    #[test]
    fn equal_weight_tenants_are_bit_identical_to_seed_fifo() {
        // Rule E1: any all-equal weight vector admits in the exact
        // seed FIFO order, so the whole run replays bit-identically —
        // under budget pressure, where admission order matters.
        let mut cfg = small_cfg(ServeMode::Staged);
        cfg.ramdisk_slice = Some(cfg.dataset_bytes() * 3 / 2);
        let plain = run_serve(2, &cfg, ThroughputMode::Fast);
        let mut multi = cfg.clone();
        multi.tenants = TenantsCfg { weights: vec![7, 7, 7] };
        let out = run_serve(2, &multi, ThroughputMode::Fast);
        assert_eq!(out.turnaround_secs, plain.turnaround_secs);
        assert_eq!(out.virtual_secs, plain.virtual_secs);
        assert_eq!(out.staged_bytes, plain.staged_bytes);
        assert_eq!(out.peak_queue, plain.peak_queue);
        assert_eq!(out.admission_order, plain.admission_order);
        assert_eq!(out.warm_hits, 0);
        assert_eq!(out.keepalive_grants, 0);
        // The per-tenant split covers the whole working set.
        assert_eq!(out.tenant_admitted_bytes.len(), 3);
        assert_eq!(
            out.tenant_admitted_bytes.iter().sum::<u64>(),
            plain.tenant_admitted_bytes.iter().sum::<u64>()
        );
    }

    #[test]
    fn zero_event_elastic_is_bit_identical_to_none() {
        let mut cfg = small_cfg(ServeMode::Staged);
        cfg.ramdisk_slice = Some(cfg.dataset_bytes() * 2);
        let mut armed = cfg.clone();
        armed.elastic = Some(ElasticCfg::default());
        assert_eq!(armed.elastic.unwrap().events, 0);
        let a = run_serve(2, &armed, ThroughputMode::Fast);
        let b = run_serve(2, &cfg, ThroughputMode::Fast);
        assert_eq!(a.turnaround_secs, b.turnaround_secs);
        assert_eq!(a.virtual_secs, b.virtual_secs);
        assert_eq!(a.staged_bytes, b.staged_bytes);
        assert_eq!(a.pool_events, 0);
        assert_eq!(a.min_warm_nodes, 2);
    }

    #[test]
    fn keep_alive_turns_reopens_into_warm_hits() {
        // One hot dataset (0) reopened after a 400 s idle gap, three
        // sweepers (1-3) in between. Budget: two datasets; SSD tier
        // disabled, so an evicted replica is gone for good. Without a
        // policy the sweepers evict ds0 and the reopen re-stages it
        // from GPFS (5 full stages); with a 500 s keep-alive ds0
        // stays pinned through the gap — latest-expiry-first reclaim
        // sacrifices the sweepers' pins instead — and the reopen is a
        // free warm hit (4 full stages, nothing ever re-staged).
        let mut cfg = small_cfg(ServeMode::Staged);
        cfg.datasets = 4;
        cfg.ssd_slice = Some(0);
        cfg.ramdisk_slice = Some(cfg.dataset_bytes() * 2);
        let specs = vec![
            spec(0, 0, 0, 4),
            spec(60, 1, 0, 4),
            spec(120, 2, 0, 4),
            spec(180, 3, 0, 4),
            spec(400, 0, 0, 4),
        ];
        let ds = cfg.dataset_bytes();
        let base = run_serve_specs(2, &cfg, ThroughputMode::Fast, specs.clone());
        assert_eq!(base.warm_hits, 0);
        assert_eq!(base.keepalive_grants, 0);
        assert_eq!(base.staged_bytes, 5 * ds, "LRU evicts ds0; its reopen re-stages");
        let mut warm = cfg.clone();
        warm.policy = PolicyKind::FixedKeepAlive(500.0);
        let out = run_serve_specs(2, &warm, ThroughputMode::Fast, specs.clone());
        assert_eq!(out.warm_hits, 1, "the reopen must hit the warm pin");
        assert_eq!(out.reclaims, 2, "each sweeper reclaims the latest-expiry pin");
        assert_eq!(out.staged_bytes, 4 * ds, "no dataset is ever re-staged");
        assert!(out.keepalive_grants >= 4);
        assert!(out.staged_bytes < base.staged_bytes, "keep-alive must cut GPFS bytes");
        // Deterministic with keep-alive timers in the loop.
        let again = run_serve_specs(2, &warm, ThroughputMode::Fast, specs);
        assert_eq!(out.turnaround_secs, again.turnaround_secs);
        assert_eq!(out.virtual_secs, again.virtual_secs);
    }

    #[test]
    fn adaptive_policy_prewarms_the_predicted_dataset() {
        // A strict dataset cycle 0 -> 1 -> 2 -> 0 -> ... with 60 s
        // gaps. After one full lap the successor counts predict the
        // next dataset, and the idle budget (all three datasets fit)
        // lets the adaptive policy prewarm it: later arrivals land as
        // warm hits on datasets whose own keep-alive had lapsed.
        let mut cfg = small_cfg(ServeMode::Staged);
        cfg.ramdisk_slice = Some(cfg.dataset_bytes() * 3);
        cfg.policy = PolicyKind::Adaptive {
            default_keepalive_secs: 100.0,
            max_keepalive_secs: 600.0,
        };
        let specs: Vec<SessionSpec> =
            (0..7).map(|i| spec(60 * i as u64, i % 3, 0, 4)).collect();
        let ds = cfg.dataset_bytes();
        let out = run_serve_specs(2, &cfg, ThroughputMode::Fast, specs.clone());
        assert!(out.prewarms >= 1, "the cycle must trigger a prewarm");
        assert!(out.warm_hits >= 2, "prewarm + keep-alive must produce warm hits");
        assert_eq!(out.staged_bytes, 3 * ds, "every reopen is all-hit, nothing re-staged");
        assert!(out.keepalive_grants >= 5);
        let again = run_serve_specs(2, &cfg, ThroughputMode::Fast, specs);
        assert_eq!(out.turnaround_secs, again.turnaround_secs);
        assert_eq!(out.prewarms, again.prewarms);
        assert_eq!(out.warm_hits, again.warm_hits);
        assert_eq!(out.virtual_secs, again.virtual_secs);
    }

    #[test]
    fn elastic_churn_serves_all_and_stays_deterministic() {
        let mut cfg = small_cfg(ServeMode::Staged);
        cfg.ramdisk_slice = Some(cfg.dataset_bytes() * 4);
        cfg.elastic = Some(ElasticCfg {
            seed: 5,
            events: 12,
            mean_gap_secs: 40.0,
            min_nodes: 2,
            warmup_secs: 30.0,
        });
        let out = run_serve(4, &cfg, ThroughputMode::Fast);
        assert_eq!(out.turnaround_secs.len(), 10);
        assert!(out.pool_events > 0, "churn must fire pool events");
        assert!(out.min_warm_nodes >= 2, "the pool floor must hold");
        // The walk starts at the full pool, so its first move is a
        // forced leave: the pool provably shrinks at least once.
        assert!(out.min_warm_nodes < 4, "churn must actually shrink the pool");
        let again = run_serve(4, &cfg, ThroughputMode::Fast);
        assert_eq!(out.turnaround_secs, again.turnaround_secs);
        assert_eq!(out.virtual_secs, again.virtual_secs);
        assert_eq!(out.pool_events, again.pool_events);
        assert_eq!(out.min_warm_nodes, again.min_warm_nodes);
    }

    /// A small serve scenario with the detector streaming dataset 0.
    fn live_cfg(ram_slice: u64, ssd_slice: Option<u64>) -> ServiceCfg {
        let mut cfg = small_cfg(ServeMode::Staged);
        cfg.ssd_slice = ssd_slice;
        cfg.ingest = Some(IngestCfg {
            seed: 7,
            frames: cfg.files_per_dataset,
            frame_bytes: cfg.file_bytes,
            frame_gap_secs: 5.0,
            buffer_frames: 4,
            ram_slice,
            dataset: 0,
            mode: IngestMode::Stream,
        });
        cfg
    }

    #[test]
    fn streaming_ingest_serves_sessions_from_live_frames() {
        let cfg = live_cfg(64 * MB, None);
        let out = run_serve(2, &cfg, ThroughputMode::Fast);
        let ing = out.ingest.clone().unwrap();
        assert_eq!(ing.frames, 4);
        assert_eq!((ing.ram_frames, ing.ssd_frames, ing.gpfs_frames), (4, 0, 0));
        assert_eq!(ing.stalls, 0, "a relaxed cadence must never stall");
        assert!(ing.ingest_done_secs > 0.0);
        // ttfr is reported exactly when some session read the live
        // dataset.
        let touched = generate_workload(&cfg).iter().any(|s| s.dataset == 0);
        assert_eq!(ing.first_result_secs.is_some(), touched);
        // Sessions on the live dataset read pinned RAM frames; no task
        // read ever touched the shared FS.
        assert_eq!(out.reads.unstaged_bytes, 0);
        assert_eq!(out.sessions, 10);
        // Bit-reproducible with the detector in the event loop.
        let again = run_serve(2, &cfg, ThroughputMode::Fast);
        assert_eq!(out.turnaround_secs, again.turnaround_secs);
        assert_eq!(out.ingest, again.ingest);
        assert_eq!(out.virtual_secs, again.virtual_secs);
    }

    #[test]
    fn tight_slices_spill_frames_down_the_tier_ladder() {
        // One frame fits the RAM slice, one the SSD tier; the other
        // two spill to GPFS and are re-staged when sessions open the
        // live dataset.
        let cfg = live_cfg(8 * MB, Some(8 * MB));
        let out = run_serve(2, &cfg, ThroughputMode::Fast);
        let ing = out.ingest.clone().unwrap();
        assert_eq!((ing.ram_frames, ing.ssd_frames, ing.gpfs_frames), (1, 1, 2));
        assert_eq!(out.reads.unstaged_bytes, 0, "spilled frames are staged, not read raw");
        let again = run_serve(2, &cfg, ThroughputMode::Fast);
        assert_eq!(out.turnaround_secs, again.turnaround_secs);
        assert_eq!(out.ingest, again.ingest);
    }

    #[test]
    fn zero_frame_ingest_is_bit_identical_to_none() {
        let mut armed = small_cfg(ServeMode::Staged);
        armed.ingest = Some(IngestCfg { frames: 0, ..IngestCfg::default() });
        let a = run_serve(2, &armed, ThroughputMode::Fast);
        let b = run_serve(2, &small_cfg(ServeMode::Staged), ThroughputMode::Fast);
        assert!(a.ingest.is_none(), "zero frames means no detector");
        assert_eq!(a.turnaround_secs, b.turnaround_secs);
        assert_eq!(a.virtual_secs, b.virtual_secs);
        assert_eq!(a.staged_bytes, b.staged_bytes);
        assert_eq!(a.peak_queue, b.peak_queue);
    }

    #[test]
    fn streaming_beats_gpfs_first_on_time_to_first_result() {
        let stream = run_serve(2, &live_cfg(64 * MB, None), ThroughputMode::Fast);
        let mut gcfg = live_cfg(64 * MB, None);
        gcfg.ingest.as_mut().unwrap().mode = IngestMode::GpfsFirst;
        let gpfs = run_serve(2, &gcfg, ThroughputMode::Fast);
        let s = stream.ingest.unwrap();
        let g = gpfs.ingest.unwrap();
        // The baseline pays the shared-FS leg per frame before the
        // data is addressable at all...
        assert!(
            s.ingest_done_secs < g.ingest_done_secs,
            "stream done {} vs gpfs-first done {}",
            s.ingest_done_secs,
            g.ingest_done_secs
        );
        assert_eq!((g.ram_frames, g.ssd_frames), (0, 0));
        // ...and then a full dataset stage before any session starts.
        if let (Some(a), Some(b)) = (s.first_result_secs, g.first_result_secs) {
            assert!(a < b, "streaming ttfr {a} vs gpfs-first ttfr {b}");
        }
    }
}
