//! Interactive beamline serving: many analysis sessions over staged,
//! node-resident data.
//!
//! The paper's headline regime is *interactive*: data "staged into and
//! cached in compute node memory for extended periods, during which
//! time various processing tasks may efficiently access it", cutting
//! beamline turnaround from months to minutes. Every other driver in
//! this repo is a one-shot batch experiment; this module is the
//! serving layer that regime implies:
//!
//! - a **seeded workload generator** ([`generate_workload`]): analysis
//!   sessions arrive over simulated time as a Poisson process
//!   (exponential inter-arrival gaps), each opening one catalogued
//!   dataset and submitting a mix of NF-HEDM (many short fits) and
//!   FF-HEDM (fewer long fits) task batches of varying size;
//! - **admission control** against the node-memory budget: a session
//!   is admitted when its dataset's working set fits beside the
//!   already-open datasets (FIFO, head-of-line — deterministic);
//!   admitted datasets are staged incrementally and **pinned** through
//!   [`crate::staging::Residency`] for exactly the span sessions hold
//!   them open, then unpinned so the space serves the next tenant;
//! - **session-fair execution** through
//!   [`crate::dataflow::sched::SessionScheduler`]: every admitted
//!   session's task DAG runs concurrently against one
//!   [`SimCore`], sharing the worker pool fairly, with locality-aware
//!   placement reused as-is;
//! - **per-session turnaround accounting**: arrival -> last task
//!   completion, observed into [`crate::metrics::Metrics`] and
//!   reported as P50/P95/P99 ([`crate::metrics::Percentiles`]).
//!
//! Two serving modes isolate the paper's contribution:
//! [`ServeMode::Staged`] (stage once per dataset activation, tasks
//! read node-local replicas) vs [`ServeMode::Naive`] (every task
//! re-reads its inputs from the shared FS through the uncoordinated
//! path). The `serve` experiment contrasts them across a scenario
//! matrix; staged serving must win on P99 turnaround everywhere.
//!
//! Everything is deterministic: same seed, same turnaround table,
//! bit-for-bit (tested in `rust/tests/integration_serve.rs`).
//!
//! # Chaos serving
//!
//! [`ServiceCfg::chaos`] arms seeded node-failure injection (see
//! [`crate::chaos`]): kill timers fire under [`CHAOS_TAG_BASE`], each
//! dropping one node's replicas and in-flight work. The service then
//! (a) routes the loss to
//! [`SessionScheduler::on_node_failure`] so every lost task is
//! reassigned exactly once, and (b) re-stages every open dataset the
//! kill tore, through the residency manager's peer-copy-first recovery
//! path. A chaos config with zero failures schedules nothing and is
//! bit-identical to no chaos config at all (tested).
//!
//! # Streaming ingest
//!
//! [`ServiceCfg::ingest`] attaches a beamline detector
//! ([`crate::staging::ingest`]): frames stream over the machine's
//! beamline link and land in node tiers (RAM, then SSD, then GPFS
//! spill) *while the serving loop runs*. The catalog record grows per
//! landed frame, and a session opening the live dataset blocks only
//! until the frames its tasks read have landed (every task scans the
//! full dataset, so that is all of them); whatever spilled to GPFS is
//! re-staged through the ordinary hook path before the waiters start.
//! `ingest: None` — and `Some` with zero frames — is bit-identical to
//! the pre-ingest service (tested).

use std::collections::VecDeque;

use crate::catalog::{Catalog, DatasetId};
use crate::chaos::{kill_schedule, ChaosCfg, CHAOS_TAG_BASE};
use crate::cluster::{orthros, Topology};
use crate::dataflow::graph::{Task, TaskGraph};
use crate::dataflow::sched::{
    ReadStats, SchedulerCfg, SessionId, SessionScheduler, TASK_TAG_BASE,
};
use crate::engine::{Director, Notice, SimCore, DEMOTE_TAG};
use crate::metrics::Percentiles;
use crate::mpisim::Comm;
use crate::pfs::{Blob, GpfsParams};
use crate::simtime::flownet::ThroughputMode;
use crate::staging::ingest::{Ingest, IngestCfg, IngestMode, IngestOutcome, INGEST_TAG_BASE};
use crate::staging::{HookSpec, Residency};
use crate::units::{Duration, SimTime, StateBytes, GB, MB};
use crate::util::prng::Pcg64;

/// Tag namespace for staging plans the service submits (one per
/// dataset activation), below the scheduler's [`TASK_TAG_BASE`].
pub const STAGE_TAG_BASE: u64 = 1 << 47;

// Checked tag allocation for the bands the serving director
// multiplexes on one timer/plan namespace: arrival < ingest < chaos <
// demote < stage < task. Each helper debug-asserts its index cannot
// reach the band above (regression-tested at 10^4 sessions in
// `tag_bands_stay_disjoint_at_ten_thousand_sessions`).

fn session_tag(s: usize) -> u64 {
    let tag = s as u64;
    debug_assert!(tag < INGEST_TAG_BASE, "session index {s} collides with the ingest band");
    tag
}

fn kill_tag(k: usize) -> u64 {
    let tag = CHAOS_TAG_BASE + k as u64;
    debug_assert!(tag < DEMOTE_TAG, "kill index {k} collides with the demotion tag");
    tag
}

fn stage_tag(d: usize) -> u64 {
    let tag = STAGE_TAG_BASE + d as u64;
    debug_assert!(tag < TASK_TAG_BASE, "dataset index {d} collides with the task band");
    tag
}

/// How sessions read their data.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeMode {
    /// Stage each opened dataset into node memory once (incremental,
    /// pinned while open); tasks read node-local replicas.
    Staged,
    /// No staging: every task re-reads its inputs from the shared FS
    /// through the uncoordinated (degrading) path.
    Naive,
}

/// Serve scenario parameters. All sizes are per node; the workload is
/// entirely determined by `seed`.
#[derive(Clone, Debug)]
pub struct ServiceCfg {
    pub seed: u64,
    /// Sessions in the workload.
    pub sessions: usize,
    /// Mean inter-arrival gap (seconds) of the Poisson process.
    pub mean_gap_secs: f64,
    /// Distinct catalogued datasets sessions draw from.
    pub datasets: usize,
    pub files_per_dataset: usize,
    pub file_bytes: u64,
    /// Per-node RAM staging budget override (None = machine default).
    /// The admission layer keeps the open (pinned) working set within
    /// whatever budget the store ends up with.
    pub ramdisk_slice: Option<u64>,
    /// Per-node SSD-tier budget override: None = machine default,
    /// `Some(0)` disables the tier entirely (the discard-eviction
    /// baseline the `tiers` experiment compares against). Closed
    /// datasets demote here under RAM pressure and are promoted back
    /// on re-open instead of re-staged from the shared FS.
    pub ssd_slice: Option<u64>,
    pub mode: ServeMode,
    pub sched: SchedulerCfg,
    /// Seeded node-failure injection. `None` (and `Some` with zero
    /// failures) runs bit-identically to the pre-chaos service; `Some`
    /// with failures arms kill timers, peer-copy recovery staging, and
    /// exactly-once task reassignment.
    pub chaos: Option<ChaosCfg>,
    /// Beamline detector streaming one dataset in while sessions run.
    /// `None` (and `Some` with zero frames) runs bit-identically to
    /// the pre-ingest service. Requires [`ServeMode::Staged`], one
    /// frame per dataset file (`frames == files_per_dataset`,
    /// `frame_bytes == file_bytes`), and no chaos injection.
    pub ingest: Option<IngestCfg>,
}

impl Default for ServiceCfg {
    fn default() -> Self {
        ServiceCfg {
            seed: 42,
            sessions: 24,
            mean_gap_secs: 30.0,
            datasets: 4,
            files_per_dataset: 6,
            file_bytes: 16 * MB,
            ramdisk_slice: None,
            ssd_slice: None,
            mode: ServeMode::Staged,
            sched: SchedulerCfg { locality_aware: true, ..Default::default() },
            chaos: None,
            ingest: None,
        }
    }
}

impl ServiceCfg {
    /// Per-dataset staged footprint.
    pub fn dataset_bytes(&self) -> u64 {
        self.files_per_dataset as u64 * self.file_bytes
    }
}

/// Task-batch flavour within a session.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchKind {
    /// NF-HEDM: many short orientation fits (2-12 s).
    Nf,
    /// FF-HEDM: fewer, longer fits (log-uniform 5-40 s).
    Ff,
}

/// One task batch of a session.
#[derive(Clone, Copy, Debug)]
pub struct Batch {
    pub kind: BatchKind,
    pub tasks: usize,
}

/// One generated analysis session.
#[derive(Clone, Debug)]
pub struct SessionSpec {
    /// When the scientist shows up.
    pub arrival: SimTime,
    /// Which dataset the session opens (index into the catalog).
    pub dataset: usize,
    pub batches: Vec<Batch>,
}

impl SessionSpec {
    pub fn task_count(&self) -> usize {
        self.batches.iter().map(|b| b.tasks).sum()
    }
}

/// Generate the session workload: Poisson arrivals, uniform dataset
/// choice, 1-3 batches per session with mixed NF/FF kinds and varying
/// sizes. Fully determined by `cfg.seed`. Degenerate shapes (zero
/// sessions or zero datasets to draw from) produce the empty
/// workload — serving them is a clean no-op, not a panic.
pub fn generate_workload(cfg: &ServiceCfg) -> Vec<SessionSpec> {
    if cfg.sessions == 0 || cfg.datasets == 0 {
        return Vec::new();
    }
    let mut rng = Pcg64::new(cfg.seed);
    let mut t = SimTime::ZERO;
    (0..cfg.sessions)
        .map(|_| {
            // Exponential inter-arrival gap: -ln(1-U) * mean.
            let gap = -(1.0 - rng.f64()).ln() * cfg.mean_gap_secs;
            t = t + Duration::from_secs_f64(gap);
            let dataset = rng.below(cfg.datasets as u64) as usize;
            let n_batches = 1 + rng.below(3) as usize;
            let batches = (0..n_batches)
                .map(|_| {
                    if rng.f64() < 0.5 {
                        Batch { kind: BatchKind::Nf, tasks: 24 + rng.below(25) as usize }
                    } else {
                        Batch { kind: BatchKind::Ff, tasks: 8 + rng.below(9) as usize }
                    }
                })
                .collect();
            SessionSpec { arrival: t, dataset, batches }
        })
        .collect()
}

/// Build one session's task DAG. Every task reads the session's full
/// dataset (the paper's FitOrientation access pattern: each task scans
/// the staged layer) from node-local replicas ([`ServeMode::Staged`])
/// or from the shared FS ([`ServeMode::Naive`]); runtimes come from a
/// per-session PRNG stream so both modes fit identical compute.
pub fn session_graph(cfg: &ServiceCfg, spec: &SessionSpec, session: usize) -> TaskGraph {
    let mut g = TaskGraph::new();
    let mut rng = Pcg64::new(cfg.seed ^ (0x5E55_0000 + session as u64).wrapping_mul(0x9E37_79B9));
    let d = spec.dataset;
    let prefix = match cfg.mode {
        ServeMode::Staged => format!("/tmp/serve/ds{d}"),
        ServeMode::Naive => format!("/projects/serve/ds{d}"),
    };
    for (bi, b) in spec.batches.iter().enumerate() {
        for i in 0..b.tasks {
            let (label, secs) = match b.kind {
                BatchKind::Nf => ("nf", rng.normal_ms(6.0, 1.5).clamp(2.0, 12.0)),
                BatchKind::Ff => ("ff", rng.log_uniform(5.0, 40.0)),
            };
            let mut t = Task::compute(
                format!("s{session}/b{bi}/{label}{i}"),
                Duration::from_secs_f64(secs),
            )
            .with_output(50_000);
            for f in 0..cfg.files_per_dataset {
                t = t.with_input(format!("{prefix}/f{f:03}.bin"), None);
            }
            g.add(t);
        }
    }
    g
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum DsState {
    /// Not resident-pinned; next open must stage (incrementally).
    Cold,
    /// A stage plan is in flight; sessions wait on its completion.
    Staging,
    /// Staged, verified, and pinned; sessions start immediately.
    Resident,
}

/// The serving director: owns session lifecycle (arrive -> admit ->
/// stage -> run -> close), delegating execution to the session-fair
/// scheduler and staging to the residency manager.
pub struct Service {
    cfg: ServiceCfg,
    topo: Topology,
    leader: Comm,
    specs: Vec<SessionSpec>,
    res: Residency,
    /// The metadata catalog: pre-registered datasets plus the live
    /// dataset's per-frame growth.
    catalog: Catalog,
    /// The streaming detector, when one is attached.
    ing: Option<Ingest>,
    /// Workload index of the dataset the detector writes.
    ingest_ds: Option<usize>,
    ds_ids: Vec<DatasetId>,
    ds_state: Vec<DsState>,
    /// Open-session count per dataset; pins released at zero.
    ds_users: Vec<u32>,
    /// Sessions awaiting a dataset's in-flight stage.
    ds_waiters: Vec<Vec<usize>>,
    sched: SessionScheduler,
    /// Scheduler SessionId index -> workload session index.
    sid_to_session: Vec<usize>,
    done_at: Vec<Option<SimTime>>,
    /// FIFO admission queue (session indices).
    admit_queue: VecDeque<usize>,
    /// Bytes of currently-open datasets (the admitted working set).
    admitted_bytes: u64,
    /// Per-tier node budgets admission accounts: the open (pinned)
    /// working set must fit `budgets.ram`; `budgets.ssd` is the
    /// demotion reservoir closed-but-warm datasets overflow into, so
    /// re-opens promote locally instead of re-staging from GPFS.
    budgets: crate::storage::TierBudgets,
    /// Deepest the admission queue ever got.
    pub peak_queue: usize,
    /// The materialised kill schedule; index k is the victim of the
    /// timer armed under `CHAOS_TAG_BASE + k`. Empty = chaos disarmed.
    kills: Vec<(SimTime, u32)>,
    /// Kills that actually fired.
    pub node_failures: usize,
    /// Dispatched tasks lost to kills and reassigned (exactly once).
    pub lost_tasks: usize,
}

impl Service {
    fn on_arrival(&mut self, core: &mut SimCore, s: usize) {
        match self.cfg.mode {
            ServeMode::Naive => self.start_tasks(core, s),
            ServeMode::Staged => {
                self.admit_queue.push_back(s);
                self.try_admit(core);
                // Depth after the admission pass: counts sessions the
                // budget actually made wait, not the arrival itself.
                self.peak_queue = self.peak_queue.max(self.admit_queue.len());
            }
        }
    }

    /// Admit from the queue front while the working set fits: FIFO,
    /// head-of-line blocking — simple and deterministic.
    fn try_admit(&mut self, core: &mut SimCore) {
        while let Some(&s) = self.admit_queue.front() {
            let d = self.specs[s].dataset;
            let need = if self.ds_users[d] > 0 { 0 } else { self.cfg.dataset_bytes() };
            if let Some(b) = self.budgets.ram {
                if self.admitted_bytes + need > b {
                    break;
                }
            }
            self.admit_queue.pop_front();
            self.ds_users[d] += 1;
            self.admitted_bytes += need;
            match self.ds_state[d] {
                DsState::Resident => self.start_tasks(core, s),
                DsState::Staging => self.ds_waiters[d].push(s),
                DsState::Cold => {
                    if self.ingest_pending(d) {
                        // Frames are still arriving: the session
                        // blocks exactly until the frames its tasks
                        // read have landed (all of them — every task
                        // scans the full dataset).
                        self.ds_state[d] = DsState::Staging;
                        self.ds_waiters[d].push(s);
                    } else if self.nothing_to_stage(d) {
                        self.ds_state[d] = DsState::Resident;
                        self.start_tasks(core, s);
                    } else {
                        self.ds_state[d] = DsState::Staging;
                        self.ds_waiters[d].push(s);
                        self.res
                            .begin_stage(
                                core,
                                &self.topo,
                                &self.leader,
                                self.ds_ids[d],
                                stage_tag(d),
                            )
                            .expect("serve: begin_stage failed");
                    }
                }
            }
        }
    }

    /// The live dataset still has frames in flight: sessions opening
    /// it wait for the detector, not for a stage plan.
    fn ingest_pending(&self, d: usize) -> bool {
        self.ingest_ds == Some(d) && self.ing.as_ref().is_some_and(|i| !i.complete())
    }

    /// Opening this dataset would move nothing: zero-file datasets,
    /// and a fully streamed-in live dataset with no GPFS spills (the
    /// hook's glob would match no files — every frame is already
    /// node-resident and pinned by the detector).
    fn nothing_to_stage(&self, d: usize) -> bool {
        if self.cfg.files_per_dataset == 0 {
            return true;
        }
        self.ingest_ds == Some(d)
            && self.ing.as_ref().is_some_and(|i| i.complete() && i.gpfs_frames() == 0)
    }

    fn on_stage_done(&mut self, core: &mut SimCore, d: usize) {
        debug_assert_eq!(self.ds_state[d], DsState::Staging);
        // Byte accounting lives in `Residency::stats`; no second
        // counter to keep in sync here.
        match self.res.commit_stage(core, &self.leader, self.ds_ids[d]) {
            Ok(_) => {}
            Err(e) => {
                // Without chaos a failed commit is an admission bug.
                // With chaos, a kill can tear replicas the in-flight
                // stage classified as hits; re-stage the delta (the
                // residency manager recovers via peer copy / SSD
                // promote / GPFS re-read) and keep waiters waiting.
                assert!(
                    !self.kills.is_empty(),
                    "serve: stage rejected under memory pressure (admission bug): {e}"
                );
                self.res
                    .begin_stage(core, &self.topo, &self.leader, self.ds_ids[d], stage_tag(d))
                    .expect("serve: recovery begin_stage failed");
                return;
            }
        }
        self.ds_state[d] = DsState::Resident;
        for s in std::mem::take(&mut self.ds_waiters[d]) {
            self.start_tasks(core, s);
        }
        if self.ds_users[d] == 0 {
            // Every user left while a recovery stage was in flight
            // (only possible under chaos): close the dataset now that
            // the stage has landed.
            self.close_dataset(core, d);
        }
    }

    /// Last user out: unpin so the space serves the next tenant.
    /// Replicas stay resident until evicted, so a re-open usually
    /// restages nothing (all hits).
    fn close_dataset(&mut self, core: &mut SimCore, d: usize) {
        self.res.unpin_dataset(core, self.ds_ids[d]);
        self.admitted_bytes -= self.cfg.dataset_bytes();
        self.ds_state[d] = DsState::Cold;
        self.try_admit(core);
    }

    fn start_tasks(&mut self, core: &mut SimCore, s: usize) {
        let g = session_graph(&self.cfg, &self.specs[s], s);
        let sid = self.sched.add_session(core, g);
        debug_assert_eq!(sid.0 as usize, self.sid_to_session.len());
        self.sid_to_session.push(s);
    }

    fn on_tasks_done(&mut self, core: &mut SimCore, sid: SessionId) {
        let s = self.sid_to_session[sid.0 as usize];
        debug_assert!(self.done_at[s].is_none(), "session completed twice");
        self.done_at[s] = Some(core.now);
        let turnaround = (core.now - self.specs[s].arrival).secs_f64();
        core.metrics.observe("session.turnaround", turnaround);
        if self.cfg.mode == ServeMode::Staged {
            let d = self.specs[s].dataset;
            self.ds_users[d] -= 1;
            // Close only when no recovery stage is in flight; a
            // Staging dataset closes when its stage lands instead
            // (see `on_stage_done`), keeping pin/commit ordering sane.
            if self.ds_users[d] == 0 && self.ds_state[d] == DsState::Resident {
                self.close_dataset(core, d);
            }
        }
    }

    /// A chaos kill fired: fail the node (replicas, mirrors, in-flight
    /// plans), reassign its lost tasks exactly once, and re-stage every
    /// open dataset the kill tore.
    fn on_kill(&mut self, core: &mut SimCore, k: usize) {
        let node = self.kills[k].1;
        self.node_failures += 1;
        core.fail_node(node);
        self.lost_tasks += self.sched.on_node_failure(core, node);
        for d in 0..self.ds_ids.len() {
            if self.ds_state[d] == DsState::Resident
                && !self.res.dataset_resident_on(core, self.ds_ids[d], node)
            {
                self.ds_state[d] = DsState::Staging;
                self.res
                    .begin_stage(core, &self.topo, &self.leader, self.ds_ids[d], stage_tag(d))
                    .expect("serve: recovery begin_stage failed");
            }
        }
    }

    /// A detector cadence tick fired.
    fn on_ingest_timer(&mut self, core: &mut SimCore) {
        let ing = self.ing.as_mut().expect("ingest tick without a detector");
        ing.on_timer(core, &self.topo);
    }

    /// An ingest frame's wire or spill plan finished: land it, and
    /// when it was the last frame, release the sessions the live
    /// dataset is blocking.
    fn on_ingest_plan_done(&mut self, core: &mut SimCore, tag: u64) {
        let ing = self.ing.as_mut().expect("ingest plan without a detector");
        if ing.on_plan_done(core, &self.topo, &mut self.catalog, tag) {
            self.on_ingest_complete(core);
        }
    }

    /// Every frame has landed: the live dataset behaves like any other
    /// from here on. If sessions are already waiting, re-stage
    /// whatever spilled to GPFS (nothing spilled means they start
    /// immediately — the frames are resident and pinned).
    fn on_ingest_complete(&mut self, core: &mut SimCore) {
        let d = self.ingest_ds.expect("ingest completion without a detector");
        if self.ds_state[d] != DsState::Staging {
            // No session has opened the live dataset yet; admission
            // treats it as a normal cold dataset when one does.
            return;
        }
        if self.ing.as_ref().is_some_and(|i| i.gpfs_frames() > 0) {
            self.res
                .begin_stage(core, &self.topo, &self.leader, self.ds_ids[d], stage_tag(d))
                .expect("serve: spill re-stage failed");
        } else {
            self.ds_state[d] = DsState::Resident;
            for s in std::mem::take(&mut self.ds_waiters[d]) {
                self.start_tasks(core, s);
            }
        }
    }
}

impl Director for Service {
    fn on_notice(&mut self, core: &mut SimCore, notice: Notice) {
        match notice {
            Notice::Timer { tag } => {
                // Session-arrival tags are small workload indices;
                // detector ticks and chaos kill timers live in their
                // own bands above them.
                if tag >= CHAOS_TAG_BASE {
                    self.on_kill(core, (tag - CHAOS_TAG_BASE) as usize);
                } else if tag >= INGEST_TAG_BASE {
                    self.on_ingest_timer(core);
                } else {
                    self.on_arrival(core, tag as usize);
                }
            }
            Notice::PlanDone { tag, .. } => {
                if tag >= TASK_TAG_BASE {
                    if let Some(sid) = self.sched.on_plan_done(core, tag) {
                        self.on_tasks_done(core, sid);
                    }
                } else if tag >= STAGE_TAG_BASE {
                    self.on_stage_done(core, (tag - STAGE_TAG_BASE) as usize);
                } else if tag == DEMOTE_TAG {
                    // Eviction's demotion flows: the engine booked the
                    // tier move when it planned them; completion needs
                    // no action. (Checked before the ingest band —
                    // DEMOTE_TAG sits numerically above it.)
                } else if tag >= INGEST_TAG_BASE {
                    self.on_ingest_plan_done(core, tag);
                }
            }
            _ => {}
        }
    }
}

/// Aggregate outcome of one serve run.
#[derive(Clone, Debug)]
pub struct ServeOutcome {
    /// Per-session turnaround (arrival -> last task done), seconds, by
    /// session index (arrival order). Bit-identical across same-seed
    /// runs.
    pub turnaround_secs: Vec<f64>,
    /// Turnaround percentiles; `None` when the workload was empty.
    pub percentiles: Option<Percentiles>,
    /// Total virtual time until the machine drained.
    pub virtual_secs: f64,
    /// Bytes the staging path actually moved from GPFS (0 in naive
    /// mode).
    pub staged_bytes: u64,
    /// Bytes served by SSD-tier promotion instead of GPFS re-staging.
    pub promoted_bytes: u64,
    /// Bytes recovery staging copied between surviving peers' RAM
    /// instead of re-reading GPFS (0 without chaos).
    pub copied_bytes: u64,
    /// Bytes RAM eviction demoted into the SSD tier (survived) over
    /// the run.
    pub demoted_bytes: u64,
    /// Input-read accounting summed over all sessions.
    pub reads: ReadStats,
    pub peak_queue: usize,
    pub sessions: usize,
    /// Scheduler bookkeeping resident after the machine drained, over
    /// sessions served — a long-lived serving core must hold a few
    /// hundred bytes per *completed* session (stats headers), never
    /// retained task graphs.
    pub sched_state: StateBytes,
    /// Residency-manager bookkeeping over catalogued datasets.
    pub residency_state: StateBytes,
    /// Chaos kills that fired during the run.
    pub node_failures: usize,
    /// Dispatched tasks lost to kills and reassigned exactly once.
    pub lost_tasks: usize,
    /// What the detector did, when one was attached.
    pub ingest: Option<IngestOutcome>,
}

/// Run one serve scenario on an Orthros-class cluster of `nodes` fat
/// nodes (64 ranks each, 500 MB/s per-process local reads, 1.25 GB/s
/// shared NFS backplane — the campaign experiment's machine model).
pub fn run_serve(nodes: u32, cfg: &ServiceCfg, mode: ThroughputMode) -> ServeOutcome {
    assert!(nodes >= 1);
    let mut core = SimCore::with_mode(mode);
    let mut spec = orthros();
    spec.nodes = nodes;
    let gpfs = GpfsParams { peak_bw: 1.25 * GB as f64, ..Default::default() };
    let topo = Topology::build(spec, gpfs, &mut core.net);
    topo.apply_storage_budgets(&mut core);
    if let Some(slice) = cfg.ramdisk_slice {
        let b = core.nodes.capacity().map_or(slice, |c| c.min(slice));
        core.nodes.set_capacity(Some(b));
    }
    match cfg.ssd_slice {
        // 0 disables the tier: eviction discards, the pre-tiering
        // baseline.
        Some(0) => core.nodes.set_ssd_capacity(None),
        Some(slice) => {
            let b = core.nodes.ssd_capacity().map_or(slice, |c| c.min(slice));
            core.nodes.set_ssd_capacity(Some(b));
        }
        None => {}
    }

    // The detector, when armed. Zero frames means "no detector": the
    // run must be bit-identical to `ingest: None`.
    let ingest_cfg = cfg.ingest.clone().filter(|i| i.frames > 0);
    if let Some(i) = &ingest_cfg {
        assert_eq!(cfg.mode, ServeMode::Staged, "ingest requires staged serving");
        assert!(i.dataset < cfg.datasets, "ingest dataset index out of range");
        assert_eq!(i.frames, cfg.files_per_dataset, "one frame per dataset file");
        assert_eq!(i.frame_bytes, cfg.file_bytes, "frame size must match the file size");
    }
    let live_ds = ingest_cfg.as_ref().map(|i| i.dataset);

    // The shared-FS datasets + their catalog records and hook specs.
    // The live dataset is registered empty — no pre-written files, no
    // catalogued bytes; the detector grows it frame by frame.
    let mut catalog = Catalog::new();
    let mut res = Residency::new();
    let mut ds_ids = Vec::new();
    for d in 0..cfg.datasets {
        let live = live_ds == Some(d);
        if !live {
            for f in 0..cfg.files_per_dataset {
                core.pfs.write(
                    format!("/projects/serve/ds{d}/f{f:03}.bin"),
                    Blob::synthetic(cfg.file_bytes, 0x5EB0_0000 + (d * 1000 + f) as u64),
                );
            }
        }
        let id = catalog.register(
            format!("serve-ds{d}"),
            format!("/projects/serve/ds{d}"),
            if live { 0 } else { cfg.files_per_dataset as u64 },
            if live { 0 } else { cfg.dataset_bytes() },
        );
        catalog.set_attr(id, "technique", "hedm");
        let spec = HookSpec::parse(&format!(
            "broadcast to /tmp/serve/ds{d} {{ /projects/serve/ds{d}/*.bin }}"
        ))
        .unwrap();
        res.bind(id, spec);
        ds_ids.push(id);
    }
    let mut budgets = crate::storage::TierBudgets {
        ram: core.nodes.capacity(),
        ssd: core.nodes.ssd_capacity(),
    };
    if let Some(i) = &ingest_cfg {
        if i.mode == IngestMode::Stream {
            // Reserve the detector's RAM slice out of the admission
            // budget: live frames pin node RAM that admission must
            // never hand to sessions. The reservation is also what
            // makes a RAM-slice frame write always feasible — pinned
            // session data plus live frames can never exceed the
            // store.
            budgets.ram = budgets.ram.map(|b| {
                assert!(i.ram_slice < b, "detector RAM slice swallows the node budget ({b})");
                b - i.ram_slice
            });
        }
    }
    if cfg.mode == ServeMode::Staged {
        if let Some(b) = budgets.ram {
            assert!(
                cfg.dataset_bytes() <= b,
                "a single dataset ({}) must fit the node RAM budget ({b})",
                cfg.dataset_bytes()
            );
        }
    }

    let specs = generate_workload(cfg);
    let n = specs.len();
    for (s, sp) in specs.iter().enumerate() {
        core.timer(sp.arrival, session_tag(s));
    }
    // Arm chaos: one kill timer per scheduled failure, and the
    // peer-copy recovery source in the residency manager. A zero-kill
    // schedule arms nothing, keeping the run bit-identical to
    // `chaos: None` (tested in `rust/tests/integration_chaos.rs`).
    let kills = cfg
        .chaos
        .as_ref()
        .map(|c| kill_schedule(c, nodes))
        .unwrap_or_default();
    for (k, &(at, _)) in kills.iter().enumerate() {
        core.timer(at, kill_tag(k));
    }
    res.peer_copy = !kills.is_empty();
    // A kill tearing pinned live frames would leave the detector's
    // recorded tiers wrong; the two failure models stay separate.
    assert!(
        ingest_cfg.is_none() || kills.is_empty(),
        "node-failure injection is not supported while a detector streams"
    );
    let world = Comm::world(&topo.spec);
    let leader = Comm::leader(&topo.spec);
    let mut svc = Service {
        sched: SessionScheduler::new(topo.clone(), world, cfg.sched),
        cfg: cfg.clone(),
        topo,
        leader,
        specs,
        res,
        catalog,
        ing: ingest_cfg.as_ref().map(|i| Ingest::new(i.clone(), ds_ids[i.dataset])),
        ingest_ds: live_ds,
        ds_ids,
        ds_state: vec![DsState::Cold; cfg.datasets],
        ds_users: vec![0; cfg.datasets],
        ds_waiters: vec![Vec::new(); cfg.datasets],
        sid_to_session: Vec::new(),
        done_at: vec![None; n],
        admit_queue: VecDeque::new(),
        admitted_bytes: 0,
        budgets,
        peak_queue: 0,
        kills,
        node_failures: 0,
        lost_tasks: 0,
    };
    if let Some(ing) = svc.ing.as_mut() {
        ing.start(&mut core);
    }
    core.run(&mut svc);

    assert!(
        svc.done_at.iter().all(Option::is_some),
        "serve run drained with unserved sessions"
    );
    assert_eq!(core.node_write_rejections(), 0, "admission let a write be rejected");
    if svc.node_failures == 0 {
        // Promotion plans pin their SSD copies, so a planned promotion
        // can neither miss nor be rejected mid-flight — unless a chaos
        // kill dropped the pinned copy underneath the plan, which the
        // recovery path absorbs.
        assert_eq!(core.metrics.count("node.promote.missed"), 0, "promotion missed its SSD copy");
        assert_eq!(core.metrics.count("node.promote.rejected"), 0, "promotion rejected");
    }
    // The detector drained with the rest of the machine: every frame
    // landed somewhere, its content is intact at the recorded tier,
    // and the catalog saw exactly the frames that landed. The ttfr
    // the ingest experiment compares is the earliest completion of a
    // session reading the live dataset.
    let ingest = svc.ing.as_ref().map(|ing| {
        assert!(ing.complete(), "serve run drained with detector frames in flight");
        ing.verify(&core, &svc.topo);
        let d = svc.ingest_ds.expect("detector without a live dataset");
        let rec = svc.catalog.get(svc.ds_ids[d]).expect("live dataset unregistered");
        assert_eq!(rec.files, cfg.files_per_dataset as u64, "catalog growth lost frames");
        assert_eq!(rec.bytes, cfg.dataset_bytes(), "catalog growth lost bytes");
        let mut first: Option<f64> = None;
        for (s, sp) in svc.specs.iter().enumerate() {
            if sp.dataset == d {
                let t = svc.done_at[s].unwrap().secs_f64();
                first = Some(first.map_or(t, |f: f64| f.min(t)));
            }
        }
        ing.outcome(first)
    });
    let turnaround_secs: Vec<f64> = (0..n)
        .map(|s| (svc.done_at[s].unwrap() - svc.specs[s].arrival).secs_f64())
        .collect();
    // Single source of truth: the reported percentiles are computed
    // from the turnaround table itself. The metrics sample series
    // (observed at each session close) must agree — any divergence
    // means the two recording sites drifted.
    let mut sorted = turnaround_secs.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let percentiles = Percentiles::from_sorted(&sorted);
    debug_assert_eq!(
        percentiles,
        core.metrics.percentiles("session.turnaround"),
        "Service turnaround table and metrics series diverged"
    );
    let mut reads = ReadStats::default();
    for i in 0..svc.sched.session_count() {
        let st = svc.sched.stats(SessionId(i as u32));
        reads.staged_bytes += st.reads.staged_bytes;
        reads.ssd_bytes += st.reads.ssd_bytes;
        reads.unstaged_bytes += st.reads.unstaged_bytes;
        reads.peer_bytes += st.reads.peer_bytes;
        reads.cache_hits += st.reads.cache_hits;
    }
    ServeOutcome {
        turnaround_secs,
        percentiles,
        virtual_secs: core.now.secs_f64(),
        staged_bytes: svc.res.stats.staged_bytes,
        promoted_bytes: svc.res.stats.promoted_bytes,
        copied_bytes: svc.res.stats.copied_bytes,
        demoted_bytes: core.metrics.bytes("node.demote"),
        reads,
        peak_queue: svc.peak_queue,
        sessions: n,
        sched_state: StateBytes::new(svc.sched.state_bytes(), svc.sched.session_count() as u64),
        residency_state: StateBytes::new(svc.res.state_bytes(), cfg.datasets as u64),
        node_failures: svc.node_failures,
        lost_tasks: svc.lost_tasks,
        ingest,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(mode: ServeMode) -> ServiceCfg {
        ServiceCfg {
            sessions: 10,
            mean_gap_secs: 20.0,
            datasets: 3,
            files_per_dataset: 4,
            file_bytes: 8 * MB,
            mode,
            ..Default::default()
        }
    }

    #[test]
    fn workload_is_seeded_and_plausible() {
        let cfg = ServiceCfg::default();
        let a = generate_workload(&cfg);
        let b = generate_workload(&cfg);
        assert_eq!(a.len(), cfg.sessions);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.dataset, y.dataset);
            assert_eq!(x.task_count(), y.task_count());
        }
        // Arrivals are non-decreasing; datasets in range; batches 1-3.
        for w in a.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        for s in &a {
            assert!(s.dataset < cfg.datasets);
            assert!((1..=3).contains(&s.batches.len()));
            assert!(s.task_count() >= 8);
        }
        let mut other = cfg.clone();
        other.seed = 43;
        let c = generate_workload(&other);
        assert!(a.iter().zip(&c).any(|(x, y)| x.arrival != y.arrival));
    }

    #[test]
    fn graphs_fit_identical_compute_in_both_modes() {
        let staged = small_cfg(ServeMode::Staged);
        let naive = small_cfg(ServeMode::Naive);
        let spec = &generate_workload(&staged)[3];
        let gs = session_graph(&staged, spec, 3);
        let gn = session_graph(&naive, spec, 3);
        assert_eq!(gs.len(), gn.len());
        for (a, b) in gs.tasks.iter().zip(&gn.tasks) {
            assert_eq!(a.runtime, b.runtime);
            assert!(a.inputs[0].path.starts_with("/tmp/serve/"));
            assert!(b.inputs[0].path.starts_with("/projects/serve/"));
            assert_eq!(a.inputs.len(), staged.files_per_dataset);
        }
    }

    #[test]
    fn staged_serving_runs_and_pins_correctly() {
        let out = run_serve(2, &small_cfg(ServeMode::Staged), ThroughputMode::Fast);
        assert_eq!(out.sessions, 10);
        assert_eq!(out.turnaround_secs.len(), 10);
        assert!(out.turnaround_secs.iter().all(|&t| t > 0.0));
        // Staged tasks never touch the shared FS for input reads.
        assert_eq!(out.reads.unstaged_bytes, 0);
        assert!(out.reads.staged_bytes > 0);
        // Residency reuse: total staged bytes are far below
        // sessions x dataset (most activations are all-hit).
        let per_ds = small_cfg(ServeMode::Staged).dataset_bytes();
        assert!(out.staged_bytes <= 3 * per_ds, "{}", out.staged_bytes);
        let p = out.percentiles.unwrap();
        assert!(p.p50 <= p.p95);
        assert!(p.p95 <= p.p99);
        // Completed sessions released their graphs: the drained core
        // keeps only per-session stats headers.
        assert_eq!(out.sched_state.units, 10);
        assert!(
            out.sched_state.per_unit() < 1024,
            "resident {} per served session",
            out.sched_state.per_unit()
        );
        assert!(out.residency_state.total > 0);
    }

    #[test]
    fn naive_serving_reads_shared_fs_only() {
        let out = run_serve(2, &small_cfg(ServeMode::Naive), ThroughputMode::Fast);
        assert_eq!(out.staged_bytes, 0);
        assert_eq!(out.reads.staged_bytes, 0);
        assert!(out.reads.unstaged_bytes > 0);
        assert_eq!(out.peak_queue, 0, "naive mode admits instantly");
    }

    #[test]
    fn staged_beats_naive_on_tails_and_mean() {
        let s = run_serve(2, &small_cfg(ServeMode::Staged), ThroughputMode::Fast);
        let n = run_serve(2, &small_cfg(ServeMode::Naive), ThroughputMode::Fast);
        let (sp, np) = (s.percentiles.unwrap(), n.percentiles.unwrap());
        assert!(sp.p99 < np.p99, "staged p99 {} vs naive p99 {}", sp.p99, np.p99);
        assert!(sp.p95 < np.p95);
        let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len() as f64;
        assert!(
            mean(&s.turnaround_secs) < mean(&n.turnaround_secs),
            "staged mean {} vs naive mean {}",
            mean(&s.turnaround_secs),
            mean(&n.turnaround_secs)
        );
    }

    #[test]
    fn admission_queues_under_tight_budget_and_still_serves_all() {
        // Budget of ~1.5 datasets: at most one dataset open at a time
        // (plus in-flight hits), so sessions for other datasets queue.
        let mut cfg = small_cfg(ServeMode::Staged);
        cfg.ramdisk_slice = Some(cfg.dataset_bytes() * 3 / 2);
        let out = run_serve(2, &cfg, ThroughputMode::Fast);
        assert_eq!(out.turnaround_secs.len(), 10);
        assert!(out.peak_queue > 0, "tight budget must queue sessions");
        // Determinism under pressure.
        let again = run_serve(2, &cfg, ThroughputMode::Fast);
        assert_eq!(out.turnaround_secs, again.turnaround_secs);
    }

    #[test]
    fn ssd_tier_absorbs_pressure_and_cuts_gpfs_restaging() {
        // Budget of ~1.5 datasets: transitions evict. With the SSD
        // tier live the evicted files demote and re-opens promote
        // locally; with it disabled every re-open re-stages from the
        // shared FS.
        let mut cfg = small_cfg(ServeMode::Staged);
        cfg.ramdisk_slice = Some(cfg.dataset_bytes() * 3 / 2);
        let mut discard = cfg.clone();
        discard.ssd_slice = Some(0);
        let tiered = run_serve(2, &cfg, ThroughputMode::Fast);
        let base = run_serve(2, &discard, ThroughputMode::Fast);
        assert!(tiered.demoted_bytes > 0, "pressure must demote");
        assert!(tiered.promoted_bytes > 0, "re-opens must promote");
        assert_eq!(base.promoted_bytes, 0, "disabled tier must not promote");
        assert_eq!(base.demoted_bytes, 0);
        assert!(
            tiered.staged_bytes < base.staged_bytes,
            "promotions must cut GPFS re-staging: tiered {} vs discard {}",
            tiered.staged_bytes,
            base.staged_bytes
        );
        // Neither policy ever sends task reads to the shared FS.
        assert_eq!(tiered.reads.unstaged_bytes, 0);
        assert_eq!(base.reads.unstaged_bytes, 0);
        // Determinism holds with tier traffic in the network.
        let again = run_serve(2, &cfg, ThroughputMode::Fast);
        assert_eq!(tiered.turnaround_secs, again.turnaround_secs);
        assert_eq!(tiered.promoted_bytes, again.promoted_bytes);
    }

    #[test]
    fn chaos_serving_recovers_and_stays_deterministic() {
        let mut cfg = small_cfg(ServeMode::Staged);
        cfg.chaos = Some(ChaosCfg { seed: 9, failures: 3, mean_gap_secs: 60.0 });
        // `run_serve` itself asserts every session completed — no task
        // loss — and that no node write was ever rejected.
        let out = run_serve(2, &cfg, ThroughputMode::Fast);
        assert_eq!(out.node_failures, 3);
        assert_eq!(out.turnaround_secs.len(), 10);
        // Recovery keeps task reads off the shared FS: torn replicas
        // are served from the surviving peer until re-staging lands.
        assert_eq!(out.reads.unstaged_bytes, 0);
        // The whole chaotic run is bit-reproducible.
        let again = run_serve(2, &cfg, ThroughputMode::Fast);
        assert_eq!(out.turnaround_secs, again.turnaround_secs);
        assert_eq!(out.lost_tasks, again.lost_tasks);
        assert_eq!(out.copied_bytes, again.copied_bytes);
        assert_eq!(out.staged_bytes, again.staged_bytes);
        assert_eq!(out.virtual_secs, again.virtual_secs);
    }

    #[test]
    fn zero_failure_chaos_is_bit_identical_to_none() {
        let mut cfg = small_cfg(ServeMode::Staged);
        cfg.chaos = Some(ChaosCfg { failures: 0, ..Default::default() });
        let armed = run_serve(2, &cfg, ThroughputMode::Fast);
        let plain = run_serve(2, &small_cfg(ServeMode::Staged), ThroughputMode::Fast);
        assert_eq!(armed.turnaround_secs, plain.turnaround_secs);
        assert_eq!(armed.virtual_secs, plain.virtual_secs);
        assert_eq!(armed.staged_bytes, plain.staged_bytes);
        assert_eq!(armed.node_failures, 0);
        assert_eq!(armed.lost_tasks, 0);
        assert_eq!(armed.copied_bytes, 0);
    }

    #[test]
    fn throughput_models_agree_on_turnarounds() {
        for mode in [ServeMode::Staged, ServeMode::Naive] {
            let fast = run_serve(2, &small_cfg(mode), ThroughputMode::Fast);
            let slow = run_serve(2, &small_cfg(mode), ThroughputMode::Slow);
            for (f, s) in fast.turnaround_secs.iter().zip(&slow.turnaround_secs) {
                assert!((f - s).abs() < 1e-5, "mode {mode:?}: fast {f} vs slow {s}");
            }
        }
    }

    #[test]
    fn degenerate_configs_no_op_cleanly() {
        // Zero sessions: nothing arrives, nothing runs, no panic.
        let mut cfg = small_cfg(ServeMode::Staged);
        cfg.sessions = 0;
        let out = run_serve(2, &cfg, ThroughputMode::Fast);
        assert_eq!(out.sessions, 0);
        assert!(out.turnaround_secs.is_empty());
        assert!(out.percentiles.is_none(), "empty runs report no percentiles");
        assert_eq!(out.staged_bytes, 0);

        // Zero datasets: the workload collapses to empty.
        let mut cfg = small_cfg(ServeMode::Staged);
        cfg.datasets = 0;
        assert!(generate_workload(&cfg).is_empty());
        let out = run_serve(2, &cfg, ThroughputMode::Fast);
        assert_eq!(out.sessions, 0);
        assert!(out.percentiles.is_none());

        // Zero files per dataset: sessions are pure compute; staging
        // is skipped entirely (the hook would glob no files).
        let mut cfg = small_cfg(ServeMode::Staged);
        cfg.files_per_dataset = 0;
        let out = run_serve(2, &cfg, ThroughputMode::Fast);
        assert_eq!(out.sessions, 10);
        assert!(out.turnaround_secs.iter().all(|&t| t > 0.0));
        assert_eq!(out.staged_bytes, 0);
        assert!(out.percentiles.is_some());
    }

    #[test]
    fn tag_bands_stay_disjoint_at_ten_thousand_sessions() {
        let n = 10_000;
        let mut tags: Vec<u64> = (0..n).map(session_tag).collect();
        tags.extend((0..n).map(crate::staging::ingest::ingest_tag));
        tags.extend((0..n).map(kill_tag));
        tags.push(DEMOTE_TAG);
        tags.extend((0..n).map(stage_tag));
        tags.sort_unstable();
        let before = tags.len();
        tags.dedup();
        assert_eq!(tags.len(), before, "tag bands overlap");
        assert!(tags.iter().all(|&t| t < TASK_TAG_BASE));
    }

    /// A small serve scenario with the detector streaming dataset 0.
    fn live_cfg(ram_slice: u64, ssd_slice: Option<u64>) -> ServiceCfg {
        let mut cfg = small_cfg(ServeMode::Staged);
        cfg.ssd_slice = ssd_slice;
        cfg.ingest = Some(IngestCfg {
            seed: 7,
            frames: cfg.files_per_dataset,
            frame_bytes: cfg.file_bytes,
            frame_gap_secs: 5.0,
            buffer_frames: 4,
            ram_slice,
            dataset: 0,
            mode: IngestMode::Stream,
        });
        cfg
    }

    #[test]
    fn streaming_ingest_serves_sessions_from_live_frames() {
        let cfg = live_cfg(64 * MB, None);
        let out = run_serve(2, &cfg, ThroughputMode::Fast);
        let ing = out.ingest.clone().unwrap();
        assert_eq!(ing.frames, 4);
        assert_eq!((ing.ram_frames, ing.ssd_frames, ing.gpfs_frames), (4, 0, 0));
        assert_eq!(ing.stalls, 0, "a relaxed cadence must never stall");
        assert!(ing.ingest_done_secs > 0.0);
        // ttfr is reported exactly when some session read the live
        // dataset.
        let touched = generate_workload(&cfg).iter().any(|s| s.dataset == 0);
        assert_eq!(ing.first_result_secs.is_some(), touched);
        // Sessions on the live dataset read pinned RAM frames; no task
        // read ever touched the shared FS.
        assert_eq!(out.reads.unstaged_bytes, 0);
        assert_eq!(out.sessions, 10);
        // Bit-reproducible with the detector in the event loop.
        let again = run_serve(2, &cfg, ThroughputMode::Fast);
        assert_eq!(out.turnaround_secs, again.turnaround_secs);
        assert_eq!(out.ingest, again.ingest);
        assert_eq!(out.virtual_secs, again.virtual_secs);
    }

    #[test]
    fn tight_slices_spill_frames_down_the_tier_ladder() {
        // One frame fits the RAM slice, one the SSD tier; the other
        // two spill to GPFS and are re-staged when sessions open the
        // live dataset.
        let cfg = live_cfg(8 * MB, Some(8 * MB));
        let out = run_serve(2, &cfg, ThroughputMode::Fast);
        let ing = out.ingest.clone().unwrap();
        assert_eq!((ing.ram_frames, ing.ssd_frames, ing.gpfs_frames), (1, 1, 2));
        assert_eq!(out.reads.unstaged_bytes, 0, "spilled frames are staged, not read raw");
        let again = run_serve(2, &cfg, ThroughputMode::Fast);
        assert_eq!(out.turnaround_secs, again.turnaround_secs);
        assert_eq!(out.ingest, again.ingest);
    }

    #[test]
    fn zero_frame_ingest_is_bit_identical_to_none() {
        let mut armed = small_cfg(ServeMode::Staged);
        armed.ingest = Some(IngestCfg { frames: 0, ..IngestCfg::default() });
        let a = run_serve(2, &armed, ThroughputMode::Fast);
        let b = run_serve(2, &small_cfg(ServeMode::Staged), ThroughputMode::Fast);
        assert!(a.ingest.is_none(), "zero frames means no detector");
        assert_eq!(a.turnaround_secs, b.turnaround_secs);
        assert_eq!(a.virtual_secs, b.virtual_secs);
        assert_eq!(a.staged_bytes, b.staged_bytes);
        assert_eq!(a.peak_queue, b.peak_queue);
    }

    #[test]
    fn streaming_beats_gpfs_first_on_time_to_first_result() {
        let stream = run_serve(2, &live_cfg(64 * MB, None), ThroughputMode::Fast);
        let mut gcfg = live_cfg(64 * MB, None);
        gcfg.ingest.as_mut().unwrap().mode = IngestMode::GpfsFirst;
        let gpfs = run_serve(2, &gcfg, ThroughputMode::Fast);
        let s = stream.ingest.unwrap();
        let g = gpfs.ingest.unwrap();
        // The baseline pays the shared-FS leg per frame before the
        // data is addressable at all...
        assert!(
            s.ingest_done_secs < g.ingest_done_secs,
            "stream done {} vs gpfs-first done {}",
            s.ingest_done_secs,
            g.ingest_done_secs
        );
        assert_eq!((g.ram_frames, g.ssd_frames), (0, 0));
        // ...and then a full dataset stage before any session starts.
        if let (Some(a), Some(b)) = (s.first_result_secs, g.first_result_secs) {
            assert!(a < b, "streaming ttfr {a} vs gpfs-first ttfr {b}");
        }
    }
}
