//! Elastic multi-tenant serving policy: weighted-fair admission, the
//! elastic node-pool schedule, and pluggable prewarm/keep-alive
//! policies driven by per-tenant access history.
//!
//! Three independent mechanisms, all consumed by
//! [`crate::staging::service`]:
//!
//! - **Weighted-fair admission** ([`AdmitQueue`]): each session
//!   carries a [`TenantId`]; admission picks the backlogged tenant
//!   with the least *normalized service* (admitted bytes divided by
//!   weight, compared exactly by integer cross-multiplication — no
//!   floats, no division), then admits that tenant's earliest-arrival
//!   session, head-of-line blocking on it. When every configured
//!   weight is equal the pick degenerates to the globally
//!   earliest-arrival session — the literal seed FIFO order, so
//!   equal-weight runs are bit-identical to the pre-tenant service
//!   (admission rule E1; tested). For two continuously backlogged
//!   tenants the admitted-bytes deviation from the weight share is
//!   provably below one max-session working set (rule E2; see
//!   DESIGN.md and `tests/property_service.rs`).
//! - **Elastic node pool** ([`ElasticCfg`], [`pool_schedule`]): nodes
//!   lease in and out of the *staging budget* on a seeded schedule
//!   (the chaos-style timer pattern under [`ELASTIC_TAG_BASE`]). A
//!   joining node pays a modeled warm-up before its RAM counts toward
//!   admission; a departing node first cancels the newest still-warming
//!   join (LIFO), otherwise removes a warm node. The warm count never
//!   dips below [`ElasticCfg::min_nodes`] (rule E3; tested).
//! - **Prewarm / keep-alive policies** ([`ServePolicy`]): a trait
//!   object the service consults at dataset close (how long to keep
//!   the closing dataset pinned through the predicted idle gap) and
//!   after admission passes (which dataset to prewarm into free
//!   budget), fed by [`TenantHistory`] — per-tenant reopen-gap samples
//!   and dataset-successor counts (rule E4). [`PolicyKind`] is the
//!   config-level selector; [`PolicyKind::None`] is bit-identical to
//!   the policy-free service (tested).

use std::collections::{BTreeMap, VecDeque};

use crate::staging::ingest::INGEST_TAG_BASE;
use crate::units::{Duration, SimTime};
use crate::util::prng::Pcg64;

/// A tenant (beamline / user group) index into
/// [`TenantsCfg::weights`].
pub type TenantId = usize;

/// Tag namespace for elastic pool warm/leave events, below the ingest
/// band (`1 << 44`). Strictly a **timer** namespace — no plan is ever
/// submitted with an elastic tag. The upper half of the band
/// ([`KEEPALIVE_TAG_BASE`]) holds keep-alive expiry timers.
pub const ELASTIC_TAG_BASE: u64 = 1 << 43;

/// Tag namespace for keep-alive grant-expiry timers: the upper half of
/// the elastic band, still below [`INGEST_TAG_BASE`]. One tag per
/// grant, indexed by a monotone grant sequence so stale expirations
/// are detected by id, never by guesswork.
pub const KEEPALIVE_TAG_BASE: u64 = ELASTIC_TAG_BASE + (1 << 42);

/// Checked tag for elastic pool event `k`.
pub fn elastic_tag(k: usize) -> u64 {
    let tag = ELASTIC_TAG_BASE + k as u64;
    debug_assert!(tag < KEEPALIVE_TAG_BASE, "pool event {k} collides with the keep-alive band");
    tag
}

/// Checked tag for keep-alive grant `g`.
pub fn keepalive_tag(g: u64) -> u64 {
    let tag = KEEPALIVE_TAG_BASE + g;
    debug_assert!(tag < INGEST_TAG_BASE, "grant {g} collides with the ingest band");
    tag
}

// ---------------------------------------------------------------------
// Tenants
// ---------------------------------------------------------------------

/// The tenant population: one positive weight per tenant. The default
/// is a single weight-1 tenant — the pre-tenant service, bit-identical
/// to the seed FIFO path.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TenantsCfg {
    /// Admission weight per tenant; larger = a larger share of
    /// admitted bytes under contention. All weights must be positive.
    pub weights: Vec<u32>,
}

impl Default for TenantsCfg {
    fn default() -> Self {
        TenantsCfg { weights: vec![1] }
    }
}

impl TenantsCfg {
    pub fn count(&self) -> usize {
        self.weights.len()
    }

    /// All tenants share one weight (including the single-tenant
    /// case): admission takes the literal seed FIFO path.
    pub fn equal_weights(&self) -> bool {
        self.weights.windows(2).all(|w| w[0] == w[1])
    }

    /// The tenant that owns dataset `d` in generated workloads: a
    /// fixed partition (`d % tenants`), so tenant assignment consumes
    /// no PRNG draws and the generated arrival/dataset stream is
    /// unchanged from the pre-tenant workload.
    pub fn owner(&self, dataset: usize) -> TenantId {
        dataset % self.count().max(1)
    }

    pub fn validate(&self) {
        assert!(!self.weights.is_empty(), "tenant population is empty");
        assert!(self.weights.iter().all(|&w| w > 0), "tenant weights must be positive");
    }
}

// ---------------------------------------------------------------------
// Weighted-fair admission
// ---------------------------------------------------------------------

/// The multi-tenant admission queue. Sessions are pushed in arrival
/// order (a global sequence number records it); [`AdmitQueue::pick`]
/// chooses which tenant's head to admit next.
#[derive(Clone, Debug)]
pub struct AdmitQueue {
    weights: Vec<u64>,
    /// Admitted bytes charged per tenant (`u128`: the comparison
    /// cross-multiplies by a weight and must never overflow).
    served: Vec<u128>,
    /// Per-tenant FIFO of (arrival sequence, session index).
    queues: Vec<VecDeque<(u64, usize)>>,
    seq: u64,
    len: usize,
    equal: bool,
}

impl AdmitQueue {
    pub fn new(tenants: &TenantsCfg) -> AdmitQueue {
        tenants.validate();
        AdmitQueue {
            weights: tenants.weights.iter().map(|&w| w as u64).collect(),
            served: vec![0; tenants.count()],
            queues: vec![VecDeque::new(); tenants.count()],
            seq: 0,
            len: 0,
            equal: tenants.equal_weights(),
        }
    }

    pub fn push(&mut self, tenant: TenantId, session: usize) {
        self.queues[tenant].push_back((self.seq, session));
        self.seq += 1;
        self.len += 1;
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The tenant whose head admission would pick next, if any queue
    /// is non-empty. Equal weights: the globally earliest arrival (the
    /// seed FIFO order, rule E1). Otherwise: the least normalized
    /// service `served/weight`, compared exactly as
    /// `served[a] * w[b] < served[b] * w[a]`; ties break to the
    /// earlier arrival, so the pick is total and deterministic.
    fn pick(&self) -> Option<TenantId> {
        let mut best: Option<TenantId> = None;
        for (t, q) in self.queues.iter().enumerate() {
            let Some(&(seq, _)) = q.front() else { continue };
            let Some(b) = best else {
                best = Some(t);
                continue;
            };
            let b_seq = self.queues[b].front().unwrap().0;
            let better = if self.equal {
                seq < b_seq
            } else {
                let (sa, sb) = (self.served[t], self.served[b]);
                let (wa, wb) = (self.weights[t], self.weights[b]);
                match (sa * wb as u128).cmp(&(sb * wa as u128)) {
                    std::cmp::Ordering::Less => true,
                    std::cmp::Ordering::Greater => false,
                    std::cmp::Ordering::Equal => seq < b_seq,
                }
            };
            if better {
                best = Some(t);
            }
        }
        best
    }

    /// The (tenant, session) the next admission would take, without
    /// removing it. Admission blocks head-of-line on exactly this
    /// session when it does not fit the budget.
    pub fn peek(&self) -> Option<(TenantId, usize)> {
        let t = self.pick()?;
        Some((t, self.queues[t].front().unwrap().1))
    }

    /// Remove the picked head (the same session [`AdmitQueue::peek`]
    /// returned).
    pub fn pop(&mut self) -> Option<(TenantId, usize)> {
        let t = self.pick()?;
        let (_, s) = self.queues[t].pop_front().unwrap();
        self.len -= 1;
        Some((t, s))
    }

    /// Charge `bytes` of admitted working set to `tenant` (zero for
    /// admissions that joined an already-open dataset: they consumed
    /// no budget, so they move no virtual service).
    pub fn on_admitted(&mut self, tenant: TenantId, bytes: u64) {
        self.served[tenant] += bytes as u128;
    }
}

// ---------------------------------------------------------------------
// Elastic node pool
// ---------------------------------------------------------------------

/// Parameters of the seeded elastic node-pool process. The pool is
/// modeled in *budget space*: the physical per-node store capacity is
/// unchanged (a leased-out node's replicas stay until evicted), but
/// the admission budget scales with the warm share of the machine.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ElasticCfg {
    /// PRNG seed; the entire pool schedule is a pure function of the
    /// config plus the node count.
    pub seed: u64,
    /// Number of lease-change events to inject. Zero disarms the
    /// elastic pool entirely — a run with `events: 0` is bit-identical
    /// to one with no elastic config at all (tested).
    pub events: usize,
    /// Mean of the exponential gap between lease changes, seconds.
    pub mean_gap_secs: f64,
    /// The leased (and therefore warm) node count never drops below
    /// this floor, so admission always retains enough budget for one
    /// working set (validated by the service).
    pub min_nodes: u32,
    /// Modeled warm-up cost: a joining node's RAM counts toward the
    /// admission budget only this many seconds after the join.
    pub warmup_secs: f64,
}

impl Default for ElasticCfg {
    fn default() -> Self {
        ElasticCfg {
            seed: 0xE1A5,
            events: 0,
            mean_gap_secs: 300.0,
            min_nodes: 1,
            warmup_secs: 120.0,
        }
    }
}

/// Exponential sample with the given mean (inverse-CDF on the open
/// unit interval; `1 - u` keeps the log away from zero).
fn exp_secs(rng: &mut Pcg64, mean: f64) -> f64 {
    -mean * (1.0 - rng.f64()).ln()
}

/// Materialise the pool schedule as **warm-delta events**: `(time,
/// +1)` when a joined node finishes warming up, `(time, -1)` when a
/// warm node leases out. The underlying process is a random walk on
/// the leased count within `[min_nodes, nodes]` (exponential gaps,
/// fair coin in the interior). A leave first cancels the newest join
/// still warming up (LIFO — that node never becomes warm and emits no
/// event); only then does it remove a warm node. All `nodes` start
/// warm, and the warm count implied by the deltas never drops below
/// `min_nodes` (tested). Deterministic in the config; callers arm each
/// entry as an engine timer under [`ELASTIC_TAG_BASE`].
pub fn pool_schedule(cfg: &ElasticCfg, nodes: u32) -> Vec<(SimTime, i32)> {
    assert!(nodes > 0, "cannot lease an empty machine");
    assert!(
        cfg.min_nodes >= 1 && cfg.min_nodes <= nodes,
        "min_nodes {} out of range for {} nodes",
        cfg.min_nodes,
        nodes
    );
    assert!(cfg.warmup_secs >= 0.0 && cfg.warmup_secs.is_finite(), "bad warm-up");
    let warmup = Duration::from_secs_f64(cfg.warmup_secs);
    let mut rng = Pcg64::new(cfg.seed);
    let mut t = SimTime::ZERO;
    // Every node starts leased and warm; joins above `nodes` are
    // impossible (the walk reflects at the boundaries).
    let mut leased = nodes;
    let mut events: Vec<(SimTime, i32)> = Vec::new();
    // Indices into `events` of joins still warming up, newest last.
    let mut warming: Vec<usize> = Vec::new();
    for _ in 0..cfg.events {
        t += Duration::from_secs_f64(exp_secs(&mut rng, cfg.mean_gap_secs));
        let join = if leased <= cfg.min_nodes {
            true
        } else if leased >= nodes {
            false
        } else {
            rng.f64() < 0.5
        };
        if join {
            leased += 1;
            events.push((t + warmup, 1));
            warming.push(events.len() - 1);
        } else {
            leased -= 1;
            while let Some(&i) = warming.last() {
                if events[i].0 <= t {
                    warming.pop();
                } else {
                    break;
                }
            }
            if let Some(i) = warming.pop() {
                // Cancel the newest still-warming join: it leaves the
                // pool before its RAM ever counted.
                events[i].1 = 0;
            } else {
                events.push((t, -1));
            }
        }
    }
    events.retain(|&(_, d)| d != 0);
    // Warm-up completions land `warmup` after their join and can
    // interleave with later leaves; the timer order is by time,
    // generation order breaking ties (stable sort).
    events.sort_by_key(|&(at, _)| at);
    events
}

/// Minimum warm-node count implied by a delta schedule that starts
/// with all `nodes` warm.
pub fn min_warm(schedule: &[(SimTime, i32)], nodes: u32) -> u32 {
    let mut warm = nodes as i64;
    let mut min = warm;
    for &(_, d) in schedule {
        warm += d as i64;
        min = min.min(warm);
    }
    min as u32
}

// ---------------------------------------------------------------------
// Prewarm / keep-alive policies
// ---------------------------------------------------------------------

/// Per-tenant access history the policies consume: reopen-gap samples
/// and dataset-successor counts. Recording mutates only serving-layer
/// bookkeeping — never the simulation core — so history is recorded
/// unconditionally without perturbing policy-off runs.
#[derive(Clone, Debug, Default)]
pub struct TenantHistory {
    /// Last close time per dataset (for reopen-gap sampling).
    last_close: BTreeMap<usize, SimTime>,
    /// Close->reopen gap samples per dataset, seconds.
    gaps: BTreeMap<usize, Vec<f64>>,
    /// Successor counts: dataset opened previously -> (next dataset ->
    /// times observed).
    succ: BTreeMap<usize, BTreeMap<usize, u32>>,
    /// The dataset this tenant opened most recently.
    last_open: Option<usize>,
}

impl TenantHistory {
    /// The tenant opened (arrived for) dataset `d` at `now`.
    pub fn record_open(&mut self, d: usize, now: SimTime) {
        if let Some(closed) = self.last_close.get(&d) {
            let gap = (now - *closed).secs_f64();
            self.gaps.entry(d).or_default().push(gap);
        }
        if let Some(prev) = self.last_open {
            *self.succ.entry(prev).or_default().entry(d).or_insert(0) += 1;
        }
        self.last_open = Some(d);
    }

    /// The tenant's session on dataset `d` completed at `now`.
    pub fn record_close(&mut self, d: usize, now: SimTime) {
        self.last_close.insert(d, now);
    }

    /// Mean observed close->reopen gap for dataset `d`, if any.
    pub fn mean_gap_secs(&self, d: usize) -> Option<f64> {
        let gaps = self.gaps.get(&d)?;
        if gaps.is_empty() {
            return None;
        }
        Some(gaps.iter().sum::<f64>() / gaps.len() as f64)
    }

    /// The most frequent successor of the tenant's most recent open
    /// (ties break to the smaller dataset index).
    pub fn predicted_next(&self) -> Option<usize> {
        let succ = self.succ.get(&self.last_open?)?;
        succ.iter()
            .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(a.0)))
            .map(|(&d, _)| d)
    }
}

/// A prewarm/keep-alive policy: consulted at dataset close (how long
/// to keep the dataset pinned through the predicted idle gap) and
/// after admission passes (which dataset to prewarm into free budget).
/// Implementations must be pure functions of the history — the whole
/// run stays bit-reproducible.
pub trait ServePolicy {
    fn name(&self) -> &'static str;

    /// Keep-alive grant, seconds, when a tenant with history `hist`
    /// closes `dataset`. Zero (or negative) releases immediately — the
    /// seed close path.
    fn keepalive_secs(&self, hist: &TenantHistory, dataset: usize) -> f64;

    /// Dataset to prewarm for a tenant with history `hist`, if the
    /// policy predicts one. The service validates fit and state.
    fn prewarm(&self, hist: &TenantHistory) -> Option<usize>;
}

/// The seed behaviour: no keep-alive, no prewarm.
pub struct NoPolicy;

impl ServePolicy for NoPolicy {
    fn name(&self) -> &'static str {
        "none"
    }

    fn keepalive_secs(&self, _hist: &TenantHistory, _dataset: usize) -> f64 {
        0.0
    }

    fn prewarm(&self, _hist: &TenantHistory) -> Option<usize> {
        None
    }
}

/// Keep every closing dataset pinned a fixed grace period; never
/// prewarm. The dslab-faas "fixed keepalive" analogue.
pub struct FixedKeepAlive {
    pub secs: f64,
}

impl ServePolicy for FixedKeepAlive {
    fn name(&self) -> &'static str {
        "fixed-keepalive"
    }

    fn keepalive_secs(&self, _hist: &TenantHistory, _dataset: usize) -> f64 {
        self.secs
    }

    fn prewarm(&self, _hist: &TenantHistory) -> Option<usize> {
        None
    }
}

/// History-driven policy: keep-alive covers the mean observed reopen
/// gap times a safety margin (a configured default before any sample
/// exists, everything capped), and prewarm predicts the most frequent
/// successor dataset.
pub struct Adaptive {
    /// Grant before any reopen-gap sample exists, seconds.
    pub default_keepalive_secs: f64,
    /// Hard cap on any grant, seconds.
    pub max_keepalive_secs: f64,
    /// Multiplier over the mean observed gap.
    pub margin: f64,
}

impl ServePolicy for Adaptive {
    fn name(&self) -> &'static str {
        "adaptive"
    }

    fn keepalive_secs(&self, hist: &TenantHistory, dataset: usize) -> f64 {
        let g = match hist.mean_gap_secs(dataset) {
            Some(mean) => mean * self.margin,
            None => self.default_keepalive_secs,
        };
        g.min(self.max_keepalive_secs)
    }

    fn prewarm(&self, hist: &TenantHistory) -> Option<usize> {
        hist.predicted_next()
    }
}

/// Config-level policy selector (keeps
/// [`crate::staging::service::ServiceCfg`] `Clone + Debug` while the
/// service works against a [`ServePolicy`] trait object).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PolicyKind {
    /// No keep-alive, no prewarm: bit-identical to the policy-free
    /// service (tested).
    None,
    /// Fixed keep-alive grace period, seconds; no prewarm.
    FixedKeepAlive(f64),
    /// History-driven keep-alive + successor prewarm.
    Adaptive {
        default_keepalive_secs: f64,
        max_keepalive_secs: f64,
    },
}

impl PolicyKind {
    pub fn build(&self) -> Box<dyn ServePolicy> {
        match *self {
            PolicyKind::None => Box::new(NoPolicy),
            PolicyKind::FixedKeepAlive(secs) => Box::new(FixedKeepAlive { secs }),
            PolicyKind::Adaptive { default_keepalive_secs, max_keepalive_secs } => {
                Box::new(Adaptive {
                    default_keepalive_secs,
                    max_keepalive_secs,
                    margin: 1.5,
                })
            }
        }
    }

    /// Whether this policy can ever prewarm (gates the prediction pass
    /// in the admission loop).
    pub fn prewarms(&self) -> bool {
        matches!(self, PolicyKind::Adaptive { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tag_bands_are_ordered() {
        assert!(ELASTIC_TAG_BASE < KEEPALIVE_TAG_BASE);
        assert!(KEEPALIVE_TAG_BASE < INGEST_TAG_BASE);
        assert!(INGEST_TAG_BASE < crate::chaos::CHAOS_TAG_BASE);
        assert_eq!(elastic_tag(0), ELASTIC_TAG_BASE);
        assert_eq!(keepalive_tag(0), KEEPALIVE_TAG_BASE);
    }

    #[test]
    fn equal_weights_pick_is_global_fifo() {
        let tenants = TenantsCfg { weights: vec![3, 3, 3] };
        assert!(tenants.equal_weights());
        let mut q = AdmitQueue::new(&tenants);
        q.push(2, 10);
        q.push(0, 11);
        q.push(1, 12);
        // Arrival order regardless of served bytes.
        q.on_admitted(2, 0);
        assert_eq!(q.peek(), Some((2, 10)));
        assert_eq!(q.pop(), Some((2, 10)));
        assert_eq!(q.pop(), Some((0, 11)));
        assert_eq!(q.pop(), Some((1, 12)));
        assert!(q.is_empty());
    }

    #[test]
    fn weighted_pick_tracks_least_normalized_service() {
        let tenants = TenantsCfg { weights: vec![1, 3] };
        let mut q = AdmitQueue::new(&tenants);
        for s in 0..4 {
            q.push(0, s);
            q.push(1, 100 + s);
        }
        // Both at zero service: tie breaks to the earlier arrival
        // (tenant 0's session 0).
        assert_eq!(q.pop(), Some((0, 0)));
        q.on_admitted(0, 100);
        // v0 = 100/1 > v1 = 0/3.
        assert_eq!(q.pop(), Some((1, 100)));
        q.on_admitted(1, 100);
        // v0 = 100 > v1 = 100/3: tenant 1 keeps the pick until its
        // normalized service catches up.
        assert_eq!(q.pop(), Some((1, 101)));
        q.on_admitted(1, 100);
        assert_eq!(q.pop(), Some((1, 102)));
        q.on_admitted(1, 100);
        // v1 = 300/3 = 100 = v0: tie, earlier arrival is tenant 0's.
        assert_eq!(q.pop(), Some((0, 1)));
        assert_eq!(q.len(), 3);
    }

    #[test]
    fn zero_byte_admissions_do_not_move_service() {
        let tenants = TenantsCfg { weights: vec![1, 2] };
        let mut q = AdmitQueue::new(&tenants);
        q.push(0, 0);
        q.push(1, 1);
        let (t, _) = q.pop().unwrap();
        q.on_admitted(t, 0);
        // A free admission leaves the virtual clocks tied; the next
        // pick is the other tenant only via the arrival tie-break.
        assert_eq!(q.pop(), Some((1, 1)));
    }

    #[test]
    fn pool_schedule_is_deterministic_and_bounded() {
        let cfg = ElasticCfg {
            seed: 11,
            events: 200,
            mean_gap_secs: 30.0,
            min_nodes: 2,
            warmup_secs: 45.0,
        };
        let a = pool_schedule(&cfg, 8);
        let b = pool_schedule(&cfg, 8);
        assert_eq!(a, b);
        assert!(!a.is_empty());
        for w in a.windows(2) {
            assert!(w[0].0 <= w[1].0, "pool events must be time-ordered");
        }
        // The warm count stays within [min_nodes, nodes] at all times.
        let mut warm = 8i64;
        for &(_, d) in &a {
            warm += d as i64;
            assert!((2..=8).contains(&warm), "warm count {warm} escaped the pool bounds");
        }
        assert!(min_warm(&a, 8) >= 2);
        let c = pool_schedule(&ElasticCfg { seed: 12, ..cfg }, 8);
        assert_ne!(a, c, "different seed, different schedule");
    }

    #[test]
    fn zero_events_is_empty() {
        let cfg = ElasticCfg::default();
        assert_eq!(cfg.events, 0);
        assert!(pool_schedule(&cfg, 4).is_empty());
        assert_eq!(min_warm(&[], 4), 4);
    }

    #[test]
    fn leaves_cancel_warming_joins_first() {
        // Force an immediate join-then-leave: with warmup far longer
        // than any gap, every leave that follows a join within the
        // warm-up window must cancel it instead of emitting -1 — the
        // schedule can never imply fewer warm nodes than leases.
        let cfg = ElasticCfg {
            seed: 3,
            events: 400,
            mean_gap_secs: 10.0,
            min_nodes: 1,
            warmup_secs: 1e6,
        };
        let sched = pool_schedule(&cfg, 4);
        let mut warm = 4i64;
        for &(_, d) in &sched {
            warm += d as i64;
            assert!(warm >= 1, "warm count {warm} dipped below the floor");
        }
    }

    #[test]
    fn history_learns_gaps_and_successors() {
        let mut h = TenantHistory::default();
        let t = |s: u64| SimTime(s * 1_000_000_000);
        h.record_open(0, t(0));
        h.record_close(0, t(50));
        h.record_open(1, t(60));
        h.record_close(1, t(100));
        h.record_open(0, t(650));
        assert_eq!(h.mean_gap_secs(0), Some(600.0));
        assert_eq!(h.mean_gap_secs(1), None);
        // After 0 came 1 once; after 1 came 0 once.
        assert_eq!(h.predicted_next(), Some(1));
        h.record_open(1, t(700));
        assert_eq!(h.predicted_next(), Some(0));
    }

    #[test]
    fn policies_behave_as_configured() {
        let h = TenantHistory::default();
        assert_eq!(PolicyKind::None.build().keepalive_secs(&h, 0), 0.0);
        assert_eq!(PolicyKind::None.build().prewarm(&h), None);
        assert!(!PolicyKind::None.prewarms());
        let fixed = PolicyKind::FixedKeepAlive(300.0).build();
        assert_eq!(fixed.keepalive_secs(&h, 3), 300.0);
        assert_eq!(fixed.prewarm(&h), None);
        let kind = PolicyKind::Adaptive {
            default_keepalive_secs: 200.0,
            max_keepalive_secs: 1000.0,
        };
        assert!(kind.prewarms());
        let ad = kind.build();
        // No samples: the default; with samples: mean x margin, capped.
        assert_eq!(ad.keepalive_secs(&h, 0), 200.0);
        let mut h = TenantHistory::default();
        h.record_open(0, SimTime(0));
        h.record_close(0, SimTime(0));
        h.record_open(0, SimTime(400_000_000_000));
        assert_eq!(ad.keepalive_secs(&h, 0), 600.0);
        h.record_close(0, SimTime(400_000_000_000));
        h.record_open(0, SimTime(2_400_000_000_000));
        // Mean gap (400 + 2000) / 2 = 1200, x1.5 = 1800, capped at
        // 1000.
        assert_eq!(ad.keepalive_secs(&h, 0), 1000.0);
    }

    #[test]
    fn owner_partition_covers_all_tenants() {
        let t = TenantsCfg { weights: vec![1, 2, 3] };
        let owners: Vec<TenantId> = (0..7).map(|d| t.owner(d)).collect();
        assert_eq!(owners, vec![0, 1, 2, 0, 1, 2, 0]);
        assert!(!t.equal_weights());
        assert!(TenantsCfg::default().equal_weights());
    }
}
