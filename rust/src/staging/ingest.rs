//! Beamline ingest: a seeded detector streams fixed-size frames over
//! the machine's beamline link into node memory *while sessions read*.
//!
//! The paper's workflow stages datasets a detector already wrote to
//! the shared filesystem. The interactive regime it argues for wants
//! the opposite order: frames should land where analysis reads them —
//! node RAM — the moment they cross the beamline, with the shared FS
//! demoted to an overflow target. This module is that source:
//!
//! - [`Ingest`] emits `frames` fixed-size frames at a seeded, jittered
//!   cadence over [`Topology::path_beamline`]. Each frame is one plan
//!   under the [`INGEST_TAG_BASE`] tag band; the serving director
//!   routes its `PlanDone` back here to land the bytes.
//! - **Backpressure ladder**: a frame that fits the detector's RAM
//!   slice lands in node RAM (pinned — live data must never be
//!   evicted under a reader). One that does not takes the node-local
//!   SSD tier via [`SimCore::node_write_range_ssd`]; when even that
//!   rejects, the frame *spills* to GPFS over the shared-FS links and
//!   is staged back like any cold file. When frames outrun every tier
//!   the detector **stalls**: a tick that finds the frame buffer full
//!   drops no data but stops the cadence until a landing drains a
//!   slot — the paper's "beamline ran faster than the facility could
//!   swallow" failure mode, surfaced as a counter instead of an error.
//! - **Incremental visibility**: every landed frame grows the catalog
//!   record ([`crate::catalog::Catalog::record_growth`]), so a session
//!   admitted mid-stream observes exactly how much has arrived and the
//!   serving layer blocks it only until the frames it reads exist.
//!
//! Frame content is bit-identical to what the write-to-GPFS-first
//! baseline produces for the same dataset ([`IngestMode::GpfsFirst`]),
//! so the two modes are directly comparable and a spilled frame passes
//! the hook's checksum verification when re-staged.

use crate::catalog::{Catalog, DatasetId};
use crate::chaos::CHAOS_TAG_BASE;
use crate::cluster::Topology;
use crate::engine::SimCore;
use crate::pfs::Blob;
use crate::simtime::plan::{Effect, Plan, StepId};
use crate::storage::{StorageTier, StoreWrite};
use crate::units::{Duration, SimTime, MB};
use crate::util::prng::Pcg64;

/// Tag band of ingest plans and detector tick timers: above raw
/// session-arrival indices, below [`CHAOS_TAG_BASE`]. Timer tags and
/// plan tags arrive as distinct [`crate::engine::Notice`] variants, so
/// `ingest_tag(k)` names both frame `k`'s cadence tick and its wire
/// plan without collision; spill plans use `ingest_tag(frames + k)`.
pub const INGEST_TAG_BASE: u64 = 1 << 44;

/// Checked tag allocation for ingest plan or tick `k`: the band must
/// stay strictly below the chaos kill-timer band.
pub fn ingest_tag(k: usize) -> u64 {
    let tag = INGEST_TAG_BASE + k as u64;
    debug_assert!(tag < CHAOS_TAG_BASE, "ingest tag {k} overflows into the chaos band");
    tag
}

/// Where detector frames go before a session can read them.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum IngestMode {
    /// Frames stream over the beamline straight into node tiers
    /// (RAM, then SSD, then GPFS spill) — the staged-ingest path.
    Stream,
    /// Frames stream over the beamline and then take the shared-FS
    /// links down to GPFS; sessions stage the whole dataset afterwards
    /// — the facility's traditional write-then-stage baseline.
    GpfsFirst,
}

/// Detector configuration. `frames == 0` disables ingest (the serving
/// layer treats it as "no detector attached").
#[derive(Clone, Debug, PartialEq)]
pub struct IngestCfg {
    pub seed: u64,
    /// Frames the detector emits over the run.
    pub frames: usize,
    /// Bytes per frame — must equal the serving layer's file size so a
    /// landed frame is exactly one dataset file.
    pub frame_bytes: u64,
    /// Mean seconds between frames; actual gaps are jittered to
    /// `[0.75, 1.25) x` this.
    pub frame_gap_secs: f64,
    /// Emitted-but-unlanded frames the detector can buffer before its
    /// cadence stalls.
    pub buffer_frames: usize,
    /// Node-RAM bytes reserved for live frames; frames beyond it take
    /// the SSD, then GPFS.
    pub ram_slice: u64,
    /// Which serving dataset the detector writes (index into the
    /// workload's dataset space).
    pub dataset: usize,
    pub mode: IngestMode,
}

impl Default for IngestCfg {
    fn default() -> Self {
        IngestCfg {
            seed: 0xDE7EC7,
            frames: 0,
            frame_bytes: 16 * MB,
            frame_gap_secs: 1.0,
            buffer_frames: 4,
            ram_slice: 256 * MB,
            dataset: 0,
            mode: IngestMode::Stream,
        }
    }
}

/// What a finished ingest run did.
#[derive(Clone, Debug, PartialEq)]
pub struct IngestOutcome {
    pub frames: usize,
    /// Frames landed per tier, in spill order.
    pub ram_frames: usize,
    pub ssd_frames: usize,
    pub gpfs_frames: usize,
    /// Detector ticks that found the frame buffer full.
    pub stalls: u64,
    /// Virtual time at which the last frame landed in some tier.
    pub ingest_done_secs: f64,
    /// Virtual time of the first session result over the live dataset
    /// (`None` when no session read it) — the time-to-first-result the
    /// ingest experiment compares across modes.
    pub first_result_secs: Option<f64>,
}

impl IngestOutcome {
    /// Stalled ticks per emitted frame — the detector back-off rate.
    pub fn stall_rate(&self) -> f64 {
        if self.frames == 0 {
            0.0
        } else {
            self.stalls as f64 / self.frames as f64
        }
    }
}

/// The detector and its landing bookkeeping: emits frames on a seeded
/// cadence, lands each under the backpressure ladder, and records
/// which tier every frame ended in.
#[derive(Debug)]
pub struct Ingest {
    cfg: IngestCfg,
    ds_id: DatasetId,
    rng: Pcg64,
    /// Next frame index to emit.
    next_frame: usize,
    /// Frames emitted but not yet landed in any tier.
    in_flight: usize,
    /// The cadence is stopped waiting for a landing to drain a slot.
    stalled: bool,
    stalls: u64,
    landed: usize,
    /// RAM-slice bytes currently holding live frames.
    ram_bytes: u64,
    /// Tier each frame landed in (`None` until it lands).
    frame_tiers: Vec<Option<StorageTier>>,
    complete_at: Option<SimTime>,
}

impl Ingest {
    pub fn new(cfg: IngestCfg, ds_id: DatasetId) -> Self {
        assert!(cfg.frames > 0, "zero-frame ingest must be disabled, not constructed");
        assert!(cfg.frame_bytes > 0, "zero-byte frames");
        assert!(cfg.buffer_frames > 0, "detector needs at least one buffer slot");
        assert!(cfg.frame_gap_secs > 0.0, "non-positive frame cadence");
        let frames = cfg.frames;
        Ingest {
            rng: Pcg64::new(cfg.seed ^ 0x1_46E57),
            cfg,
            ds_id,
            next_frame: 0,
            in_flight: 0,
            stalled: false,
            stalls: 0,
            landed: 0,
            ram_bytes: 0,
            frame_tiers: vec![None; frames],
            complete_at: None,
        }
    }

    pub fn dataset_id(&self) -> DatasetId {
        self.ds_id
    }

    /// True once every frame has landed in some tier.
    pub fn complete(&self) -> bool {
        self.landed == self.cfg.frames
    }

    /// Detector ticks that found the frame buffer full.
    pub fn stalls(&self) -> u64 {
        self.stalls
    }

    /// Tier each landed frame ended in, by frame index.
    pub fn frame_tiers(&self) -> &[Option<StorageTier>] {
        &self.frame_tiers
    }

    /// Frames that ended on GPFS (spills, or every frame under
    /// [`IngestMode::GpfsFirst`]) — the set a session stage must move.
    pub fn gpfs_frames(&self) -> usize {
        self.tier_count(StorageTier::Gpfs)
    }

    fn tier_count(&self, tier: StorageTier) -> usize {
        self.frame_tiers.iter().filter(|t| **t == Some(tier)).count()
    }

    /// Node-local path frame `k` serves from (the serving layer's
    /// staged-file naming, so tasks read frames like staged files).
    fn node_path(&self, k: usize) -> String {
        format!("/tmp/serve/ds{}/f{k:03}.bin", self.cfg.dataset)
    }

    /// Shared-FS path frame `k` spills to (the serving layer's source
    /// naming, so the hook's glob re-stages exactly the spilled set).
    fn pfs_path(&self, k: usize) -> String {
        format!("/projects/serve/ds{}/f{k:03}.bin", self.cfg.dataset)
    }

    /// Frame content — same synthesis the serving layer uses for
    /// pre-written datasets, keeping both ingest modes bit-comparable.
    fn frame_blob(&self, k: usize) -> Blob {
        Blob::synthetic(self.cfg.frame_bytes, 0x5EB0_0000 + (self.cfg.dataset * 1000 + k) as u64)
    }

    /// Jittered gap to the next frame: `[0.75, 1.25) x` the cadence,
    /// drawn from the detector's own seeded stream.
    fn gap(&mut self) -> Duration {
        Duration::from_secs_f64(self.cfg.frame_gap_secs * (0.75 + 0.5 * self.rng.f64()))
    }

    fn arm_tick(&mut self, core: &mut SimCore) {
        let gap = self.gap();
        core.timer(core.now + gap, ingest_tag(self.next_frame));
    }

    /// Arm the first detector tick. Call once, before running the
    /// core; everything after is driven by the director's notices.
    pub fn start(&mut self, core: &mut SimCore) {
        assert_eq!(self.next_frame, 0, "ingest already started");
        self.arm_tick(core);
    }

    /// A cadence tick fired: emit the next frame, or stall if every
    /// buffer slot is still in flight (the landing that drains a slot
    /// restarts the cadence).
    pub fn on_timer(&mut self, core: &mut SimCore, topo: &Topology) {
        debug_assert!(self.next_frame < self.cfg.frames, "tick after the last frame");
        if self.in_flight == self.cfg.buffer_frames {
            self.stalls += 1;
            self.stalled = true;
            core.metrics.incr("ingest.stall");
            return;
        }
        self.emit(core, topo);
    }

    fn emit(&mut self, core: &mut SimCore, topo: &Topology) {
        let k = self.next_frame;
        self.next_frame += 1;
        self.in_flight += 1;
        let mut p = Plan::new(ingest_tag(k));
        let wire = wire_step(&mut p, topo, self.cfg.frame_bytes);
        if self.cfg.mode == IngestMode::GpfsFirst {
            // The baseline pays the shared-FS leg per frame before any
            // byte is addressable: beamline, then backplane, then the
            // data-plane write.
            let write = p.flow(
                topo.path_coordinated_read(), // same links, write direction
                1,
                self.cfg.frame_bytes,
                vec![wire],
                "ingest.gpfs",
            );
            p.effect(
                Effect::PfsWrite { path: self.pfs_path(k), data: self.frame_blob(k) },
                vec![write],
                "ingest.gpfs",
            );
        }
        core.metrics.add_bytes("ingest.wire", self.cfg.frame_bytes);
        core.submit(p);
        if self.next_frame < self.cfg.frames {
            self.arm_tick(core);
        }
    }

    /// An ingest-tagged `PlanDone` arrived: land the frame it carried.
    /// Returns `true` when this landing completed the whole ingest.
    pub fn on_plan_done(
        &mut self,
        core: &mut SimCore,
        topo: &Topology,
        catalog: &mut Catalog,
        tag: u64,
    ) -> bool {
        let k = (tag - INGEST_TAG_BASE) as usize;
        if k >= self.cfg.frames {
            // Spill plan: the frame's bytes reached GPFS.
            self.land(core, topo, catalog, k - self.cfg.frames, StorageTier::Gpfs);
        } else if self.cfg.mode == IngestMode::GpfsFirst {
            self.land(core, topo, catalog, k, StorageTier::Gpfs);
        } else {
            self.land_stream(core, topo, catalog, k);
        }
        self.complete()
    }

    /// The backpressure ladder: RAM slice, then SSD, then GPFS spill.
    fn land_stream(
        &mut self,
        core: &mut SimCore,
        topo: &Topology,
        catalog: &mut Catalog,
        k: usize,
    ) {
        let fb = self.cfg.frame_bytes;
        let (lo, hi) = (0, topo.spec.nodes - 1);
        let path = self.node_path(k);
        if self.ram_bytes + fb <= self.cfg.ram_slice {
            // The serving layer budgets admissions against the store
            // capacity *minus* the RAM slice, so a write inside the
            // slice is always feasible (pinned residents + this frame
            // never exceed the store).
            let w = core.node_write_range(lo, hi, &path, self.frame_blob(k));
            assert!(
                matches!(w, StoreWrite::Stored { .. }),
                "RAM-slice frame write rejected: the slice reservation leaked"
            );
            core.nodes.pin(path);
            self.ram_bytes += fb;
            self.land(core, topo, catalog, k, StorageTier::Ram);
            return;
        }
        match core.node_write_range_ssd(lo, hi, &path, self.frame_blob(k)) {
            StoreWrite::Stored { .. } => {
                core.nodes.pin(path);
                self.land(core, topo, catalog, k, StorageTier::Ssd);
            }
            StoreWrite::Rejected { .. } => {
                // Node tiers are full: spill to GPFS over the shared
                // FS. The frame stays in flight (it occupies a buffer
                // slot until its bytes are safe *somewhere*), which is
                // what lets a saturated GPFS leg stall the detector.
                core.metrics.add_bytes("ingest.spill", fb);
                let mut p = Plan::new(ingest_tag(self.cfg.frames + k));
                let write = p.flow(topo.path_coordinated_read(), 1, fb, vec![], "ingest.spill");
                p.effect(
                    Effect::PfsWrite { path: self.pfs_path(k), data: self.frame_blob(k) },
                    vec![write],
                    "ingest.spill",
                );
                core.submit(p);
            }
        }
    }

    fn land(
        &mut self,
        core: &mut SimCore,
        topo: &Topology,
        catalog: &mut Catalog,
        k: usize,
        tier: StorageTier,
    ) {
        debug_assert!(self.frame_tiers[k].is_none(), "frame {k} landed twice");
        self.frame_tiers[k] = Some(tier);
        self.landed += 1;
        self.in_flight -= 1;
        catalog.record_growth(self.ds_id, 1, self.cfg.frame_bytes);
        core.metrics.incr(match tier {
            StorageTier::Ram => "ingest.land.ram",
            StorageTier::Ssd => "ingest.land.ssd",
            StorageTier::Gpfs => "ingest.land.gpfs",
        });
        if self.complete() {
            self.complete_at = Some(core.now);
        } else if self.stalled {
            // A slot drained: emit the frame the stalled tick owed
            // immediately (the detector buffered it), which also
            // re-arms the cadence for the frames after it.
            self.stalled = false;
            self.emit(core, topo);
        }
    }

    /// End-of-run invariant: every frame's content is present and
    /// bit-identical at the tier its landing recorded. RAM and SSD
    /// frames are pinned so nothing can have displaced them; a spilled
    /// frame's GPFS original must exist even if a later stage also
    /// made it node-resident.
    pub fn verify(&self, core: &SimCore, topo: &Topology) {
        let (lo, hi) = (0, topo.spec.nodes - 1);
        for (k, tier) in self.frame_tiers.iter().enumerate() {
            let tier = tier.unwrap_or_else(|| panic!("frame {k} never landed"));
            let want = self.frame_blob(k);
            match tier {
                StorageTier::Ram => assert!(
                    core.nodes.resident_matches(lo, hi, &self.node_path(k), &want),
                    "RAM frame {k} lost or corrupted"
                ),
                StorageTier::Ssd => assert!(
                    core.nodes.resident_matches_tier(
                        StorageTier::Ssd,
                        lo,
                        hi,
                        &self.node_path(k),
                        &want
                    ),
                    "SSD frame {k} lost or corrupted"
                ),
                StorageTier::Gpfs => assert!(
                    core.pfs.read(&self.pfs_path(k)).is_some_and(|b| b.same_content(&want)),
                    "GPFS frame {k} lost or corrupted"
                ),
            }
        }
    }

    /// Summarise a completed ingest. `first_result_secs` is the
    /// serving layer's first session turnaround over the live dataset.
    pub fn outcome(&self, first_result_secs: Option<f64>) -> IngestOutcome {
        IngestOutcome {
            frames: self.cfg.frames,
            ram_frames: self.tier_count(StorageTier::Ram),
            ssd_frames: self.tier_count(StorageTier::Ssd),
            gpfs_frames: self.tier_count(StorageTier::Gpfs),
            stalls: self.stalls,
            ingest_done_secs: self
                .complete_at
                .expect("outcome of an incomplete ingest")
                .secs_f64(),
            first_result_secs,
        }
    }
}

/// The beamline hop of one frame. With no beamline attached (unit
/// tests only; both machine specs have one) the frame materialises
/// instantaneously.
fn wire_step(p: &mut Plan, topo: &Topology, bytes: u64) -> StepId {
    let path = topo.path_beamline();
    if path.is_empty() {
        p.delay(Duration::ZERO, vec![], "ingest.wire")
    } else {
        p.flow(path, 1, bytes, vec![], "ingest.wire")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::sched::TASK_TAG_BASE;
    use crate::engine::{Director, Notice};
    use crate::pfs::GpfsParams;
    use crate::staging::service::STAGE_TAG_BASE;

    #[test]
    fn tag_band_sits_between_arrivals_and_chaos() {
        use crate::staging::policy::{elastic_tag, keepalive_tag, ELASTIC_TAG_BASE};
        assert_eq!(ingest_tag(0), INGEST_TAG_BASE);
        assert_eq!(ingest_tag(7), INGEST_TAG_BASE + 7);
        // Arrival tags are raw session indices — far below the band.
        assert!(INGEST_TAG_BASE > 1 << 32);
        // Band order: elastic < keep-alive < ingest < chaos < stage <
        // task.
        assert!(1 << 32 < ELASTIC_TAG_BASE);
        assert!(elastic_tag(1 << 20) < keepalive_tag(0));
        assert!(keepalive_tag(1 << 20) < INGEST_TAG_BASE);
        assert!(ingest_tag(1 << 20) < CHAOS_TAG_BASE);
        assert!(CHAOS_TAG_BASE < STAGE_TAG_BASE);
        assert!(STAGE_TAG_BASE < TASK_TAG_BASE);
    }

    #[test]
    fn cadence_is_seeded_and_jittered() {
        let gaps = |seed: u64| -> Vec<f64> {
            let cfg = IngestCfg { seed, frames: 1, frame_gap_secs: 2.0, ..IngestCfg::default() };
            let mut ing = Ingest::new(cfg, DatasetId(0));
            (0..100).map(|_| ing.gap().secs_f64()).collect()
        };
        let a = gaps(7);
        assert_eq!(a, gaps(7), "same seed, same cadence");
        assert_ne!(a, gaps(8), "different seed, different cadence");
        for g in &a {
            assert!((1.5..2.5).contains(g), "gap {g} outside the jitter band");
        }
    }

    /// Forwards ingest-tagged notices to the detector, as the serving
    /// director does.
    struct Drive {
        topo: Topology,
        catalog: Catalog,
        ing: Ingest,
    }

    impl Director for Drive {
        fn on_notice(&mut self, core: &mut SimCore, notice: Notice) {
            match notice {
                Notice::Timer { tag } if tag >= INGEST_TAG_BASE => {
                    self.ing.on_timer(core, &self.topo);
                }
                Notice::PlanDone { tag, .. } if tag >= INGEST_TAG_BASE => {
                    self.ing.on_plan_done(core, &self.topo, &mut self.catalog, tag);
                }
                _ => {}
            }
        }
    }

    fn drive(cfg: IngestCfg, ram_cap: u64, ssd_cap: Option<u64>) -> (SimCore, Drive) {
        let mut core = SimCore::new();
        let mut machine = crate::cluster::orthros();
        machine.nodes = 2;
        let topo = Topology::build(machine, GpfsParams::default(), &mut core.net);
        core.nodes.set_capacity(Some(ram_cap));
        core.nodes.set_ssd_capacity(ssd_cap);
        let mut catalog = Catalog::new();
        let id = catalog.register("live", "/projects/serve/ds0", 0, 0);
        let mut ing = Ingest::new(cfg, id);
        ing.start(&mut core);
        let mut d = Drive { topo, catalog, ing };
        core.run(&mut d);
        (core, d)
    }

    #[test]
    fn frames_fill_ram_then_ssd_then_spill_to_gpfs() {
        let cfg = IngestCfg {
            seed: 42,
            frames: 6,
            frame_bytes: MB,
            frame_gap_secs: 0.05,
            buffer_frames: 6,
            ram_slice: 2 * MB,
            ..IngestCfg::default()
        };
        let (core, d) = drive(cfg, 64 * MB, Some(2 * MB));
        assert!(d.ing.complete());
        let out = d.ing.outcome(None);
        assert_eq!((out.ram_frames, out.ssd_frames, out.gpfs_frames), (2, 2, 2));
        // Spill order is monotone: RAM frames first, then SSD, then
        // the GPFS overflow.
        use StorageTier::{Gpfs, Ram, Ssd};
        let tiers: Vec<_> = d.ing.frame_tiers().iter().map(|t| t.unwrap()).collect();
        assert_eq!(tiers, [Ram, Ram, Ssd, Ssd, Gpfs, Gpfs]);
        // Landed frames are pinned, catalogued, and verifiable.
        assert!(core.nodes.is_pinned("/tmp/serve/ds0/f000.bin"));
        assert!(core.nodes.is_pinned("/tmp/serve/ds0/f003.bin"));
        let ds = d.catalog.get(d.ing.dataset_id()).unwrap();
        assert_eq!((ds.files, ds.bytes), (6, 6 * MB));
        assert!(core.pfs.read("/projects/serve/ds0/f004.bin").is_some());
        assert!(core.pfs.read("/projects/serve/ds0/f000.bin").is_none(), "no spurious spill");
        d.ing.verify(&core, &d.topo);
        assert!(core.residency.mirrors(&core.nodes));
        assert_eq!(core.metrics.count("ingest.land.ram"), 2);
        assert_eq!(core.metrics.count("ingest.land.ssd"), 2);
        assert_eq!(core.metrics.count("ingest.land.gpfs"), 2);
        assert_eq!(core.metrics.bytes("ingest.wire"), 6 * MB);
    }

    #[test]
    fn gpfs_first_lands_everything_on_the_shared_fs() {
        let cfg = IngestCfg {
            seed: 42,
            frames: 4,
            frame_bytes: MB,
            frame_gap_secs: 0.05,
            mode: IngestMode::GpfsFirst,
            ..IngestCfg::default()
        };
        let (core, d) = drive(cfg, 64 * MB, None);
        let out = d.ing.outcome(None);
        assert_eq!((out.ram_frames, out.ssd_frames, out.gpfs_frames), (0, 0, 4));
        assert_eq!(core.nodes.bytes_on(0), 0, "no node tier is touched");
        d.ing.verify(&core, &d.topo);
        // The baseline's frames are bit-identical to streamed ones.
        let want = Blob::synthetic(MB, 0x5EB0_0000);
        assert!(core.pfs.read("/projects/serve/ds0/f000.bin").unwrap().same_content(&want));
    }

    #[test]
    fn full_buffer_stalls_the_cadence_without_losing_frames() {
        // One buffer slot and a cadence much faster than the wire:
        // every second tick finds the slot occupied and stalls.
        let cfg = IngestCfg {
            seed: 42,
            frames: 8,
            frame_bytes: 64 * MB,
            frame_gap_secs: 0.001,
            buffer_frames: 1,
            ram_slice: u64::MAX,
            ..IngestCfg::default()
        };
        let (core, d) = drive(cfg, 1024 * MB, None);
        assert!(d.ing.complete(), "stalls defer frames, never drop them");
        let out = d.ing.outcome(None);
        assert_eq!(out.ram_frames, 8);
        assert!(out.stalls > 0, "cadence outran the wire yet never stalled");
        assert_eq!(core.metrics.count("ingest.stall"), out.stalls);
        assert!(out.stall_rate() > 0.0);
    }

    #[test]
    fn replay_is_bit_identical() {
        let run = || {
            let cfg = IngestCfg {
                seed: 9,
                frames: 5,
                frame_bytes: MB,
                frame_gap_secs: 0.02,
                ram_slice: 3 * MB,
                ..IngestCfg::default()
            };
            let (core, d) = drive(cfg, 64 * MB, Some(MB));
            (core.now, d.ing.outcome(None))
        };
        assert_eq!(run(), run());
    }
}
