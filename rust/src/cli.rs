//! The `xstage` command-line interface.
//!
//! One subcommand per paper experiment plus utility commands, all
//! declared in one dispatch table ([`commands`]) from which the help
//! text ([`usage`]) is generated — the two cannot drift apart (a test
//! asserts every command appears exactly once in the help). Run
//! `xstage --help` (or read the [`commands`] table below) for the
//! full list; this comment deliberately does not repeat it.

use anyhow::{bail, Result};

use crate::experiments;
use crate::util::args::Args;

/// One dispatchable subcommand: its name, a flags hint, a one-line
/// summary (both rendered into [`usage`]), and its entry point.
pub struct Command {
    pub name: &'static str,
    pub flags: &'static str,
    pub summary: &'static str,
    run: fn(&Args) -> Result<()>,
}

/// The dispatch table. [`usage`] renders from this, so help text and
/// dispatchable commands stay in sync by construction.
pub fn commands() -> &'static [Command] {
    &COMMANDS
}

static COMMANDS: [Command; 16] = [
    Command {
        name: "fig10",
        flags: "[--nodes a,b,c]",
        summary: "Staging+Write aggregate bandwidth vs nodes",
        run: |args| {
            let sweep = args.u32_list_or("nodes", experiments::BGQ_SWEEP)?;
            experiments::fig10::run(&sweep).print();
            Ok(())
        },
    },
    Command {
        name: "fig11",
        flags: "[--nodes a,b,c]",
        summary: "End-to-end input: I/O hook vs naive",
        run: |args| {
            let sweep = args.u32_list_or("nodes", experiments::BGQ_SWEEP)?;
            experiments::fig11::run(&sweep).print();
            Ok(())
        },
    },
    Command {
        name: "fig12",
        flags: "[--cores a,b,c]",
        summary: "FF-HEDM stage 1 makespan scaling",
        run: |args| {
            let sweep = args.u32_list_or("cores", experiments::ORTHROS_SWEEP)?;
            experiments::fig12::run(&sweep).print();
            Ok(())
        },
    },
    Command {
        name: "fig13",
        flags: "[--cores a,b,c]",
        summary: "FF-HEDM stage 2 makespan scaling",
        run: |args| {
            let sweep = args.u32_list_or("cores", experiments::ORTHROS_SWEEP)?;
            experiments::fig13::run(&sweep).print();
            Ok(())
        },
    },
    Command {
        name: "reduction",
        flags: "",
        summary: "NF-HEDM data reduction on the cluster (SVI-A)",
        run: |_| {
            experiments::reduction::run().print();
            Ok(())
        },
    },
    Command {
        name: "cache",
        flags: "",
        summary: "Worker input-cache experiment (SVI-B)",
        run: |_| {
            experiments::cache::run().print();
            Ok(())
        },
    },
    Command {
        name: "reuse",
        flags: "",
        summary: "Staged-data reuse across interactive cycles (SI)",
        run: |_| {
            experiments::reuse::run().print();
            Ok(())
        },
    },
    Command {
        name: "campaign",
        flags: "",
        summary: "Multi-campaign residency session under memory pressure",
        run: |_| {
            experiments::campaign::run().print();
            Ok(())
        },
    },
    Command {
        name: "serve",
        flags: "[--sessions N] [--seed S]",
        summary: "Interactive serving matrix: staged-resident vs naive re-read",
        run: |args| {
            let sessions = args.u64_or("sessions", experiments::serve::SESSIONS as u64)?;
            anyhow::ensure!(
                (1..=65536).contains(&sessions),
                "--sessions must be in 1..=65536, got {sessions}"
            );
            let seed =
                args.u64_or("seed", crate::staging::service::ServiceCfg::default().seed)?;
            experiments::serve::run_with(sessions as usize, seed).print();
            Ok(())
        },
    },
    Command {
        name: "tiers",
        flags: "[--sessions N] [--seed S]",
        summary: "Tiered-storage matrix: demote-to-SSD vs discard eviction",
        run: |args| {
            let sessions = args.u64_or("sessions", experiments::tiers::SESSIONS as u64)?;
            anyhow::ensure!(
                (1..=65536).contains(&sessions),
                "--sessions must be in 1..=65536, got {sessions}"
            );
            let seed =
                args.u64_or("seed", crate::staging::service::ServiceCfg::default().seed)?;
            experiments::tiers::run_with(sessions as usize, seed).print();
            Ok(())
        },
    },
    Command {
        name: "scale",
        flags: "[--nodes a,b,c] [--sessions x,y,z] [--seed S]",
        summary: "Fleet-scale matrix: seed vs flattened scheduler hot paths",
        run: |args| {
            let nodes = args.u32_list_or("nodes", experiments::scale::NODE_SWEEP)?;
            let sessions = args.u32_list_or("sessions", experiments::scale::SESSION_SWEEP)?;
            anyhow::ensure!(
                nodes.len() == sessions.len(),
                "--nodes and --sessions must have the same length \
                 ({} vs {})",
                nodes.len(),
                sessions.len()
            );
            anyhow::ensure!(
                sessions.iter().all(|&s| (1..=65536).contains(&s)),
                "--sessions entries must be in 1..=65536"
            );
            let seed = args.u64_or("seed", experiments::scale::SEED)?;
            experiments::scale::run_with(&nodes, &sessions, seed).print();
            Ok(())
        },
    },
    Command {
        name: "chaos",
        flags: "[--sessions N] [--seed S]",
        summary: "Chaos matrix: node-failure injection, FIFO vs work stealing",
        run: |args| {
            let sessions = args.u64_or("sessions", experiments::chaos::SESSIONS as u64)?;
            anyhow::ensure!(
                (1..=65536).contains(&sessions),
                "--sessions must be in 1..=65536, got {sessions}"
            );
            let seed = args.u64_or("seed", experiments::chaos::SEED)?;
            experiments::chaos::run_with(sessions as usize, seed).print();
            Ok(())
        },
    },
    Command {
        name: "ingest",
        flags: "[--sessions N] [--seed S]",
        summary: "Ingest matrix: streaming detector vs GPFS-first baseline",
        run: |args| {
            let sessions = args.u64_or("sessions", experiments::ingest::SESSIONS as u64)?;
            anyhow::ensure!(
                (1..=65536).contains(&sessions),
                "--sessions must be in 1..=65536, got {sessions}"
            );
            let seed = args.u64_or("seed", experiments::ingest::SEED)?;
            experiments::ingest::run_with(sessions as usize, seed).print();
            Ok(())
        },
    },
    Command {
        name: "elastic",
        flags: "[--sessions N] [--seed S]",
        summary: "Elastic matrix: weighted tenants, keep-alive/prewarm, pool churn",
        run: |args| {
            let sessions = args.u64_or("sessions", experiments::elastic::SESSIONS as u64)?;
            anyhow::ensure!(
                (1..=65536).contains(&sessions),
                "--sessions must be in 1..=65536, got {sessions}"
            );
            let seed = args.u64_or("seed", experiments::elastic::SEED)?;
            experiments::elastic::run_with(sessions as usize, seed).print();
            Ok(())
        },
    },
    Command {
        name: "all",
        flags: "",
        summary: "Run every experiment table in order",
        run: |_| {
            experiments::fig10::default().print();
            println!();
            experiments::fig11::default().print();
            println!();
            experiments::fig12::default().print();
            println!();
            experiments::fig13::default().print();
            println!();
            experiments::reduction::run().print();
            println!();
            experiments::cache::run().print();
            println!();
            experiments::reuse::run().print();
            println!();
            experiments::campaign::run().print();
            println!();
            experiments::serve::run().print();
            println!();
            experiments::tiers::run().print();
            println!();
            // One reduced fleet point: the full scale matrix is its
            // own command (`xstage scale`) / bench.
            experiments::scale::run_with(&[128], &[500], experiments::scale::SEED).print();
            println!();
            experiments::chaos::run_with(8, experiments::chaos::SEED).print();
            println!();
            experiments::ingest::run_with(4, experiments::ingest::SEED).print();
            println!();
            experiments::elastic::run_with(6, experiments::elastic::SEED).print();
            Ok(())
        },
    },
    Command {
        name: "runtime-check",
        flags: "",
        summary: "Load AOT artifacts and smoke-execute on PJRT",
        run: |_| runtime_check(),
    },
];

/// Render the help text from the dispatch table.
pub fn usage() -> String {
    let name_w = COMMANDS.iter().map(|c| c.name.len()).max().unwrap_or(0);
    let sum_w = COMMANDS.iter().map(|c| c.summary.len()).max().unwrap_or(0);
    let mut out = String::from("usage: xstage <command> [flags]\n\ncommands:\n");
    for c in &COMMANDS {
        let line = format!("  {:<name_w$}  {:<sum_w$}  {}", c.name, c.summary, c.flags);
        out.push_str(line.trim_end());
        out.push('\n');
    }
    out
}

/// Dispatch a parsed command line.
pub fn dispatch(args: &Args) -> Result<()> {
    let Some(cmd) = args.command.as_deref() else {
        bail!("{}", usage());
    };
    match COMMANDS.iter().find(|c| c.name == cmd) {
        Some(c) => (c.run)(args),
        None => bail!("unknown command {cmd:?}\n{}", usage()),
    }
}

fn runtime_check() -> Result<()> {
    use crate::runtime::{Runtime, TensorF32};
    if !Runtime::artifacts_available() {
        bail!("no artifacts found — run `make artifacts` first");
    }
    let mut rt = Runtime::load(Runtime::default_dir())?;
    println!("platform: {}", rt.platform());
    println!("entry points: {}", rt.manifest.entry_points.len());
    for (name, ep) in rt.manifest.entry_points.clone() {
        println!("  {name}: {} -> {} tensors", ep.inputs.len(), ep.outputs.len());
    }
    let x = TensorF32::scalar_vec(vec![1.0, 2.0, 3.0, 4.0]);
    let y = TensorF32::scalar_vec(vec![5.0, 6.0, 7.0, 8.0]);
    let outs = rt.call("smoke_addmul", &[x, y])?;
    anyhow::ensure!(outs[0].data == vec![6.0, 8.0, 10.0, 12.0], "bad add");
    println!("smoke_addmul OK: {:?}", outs[0].data);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn unknown_command_errors() {
        assert!(dispatch(&parse("nonsense")).is_err());
        assert!(dispatch(&parse("")).is_err());
    }

    #[test]
    fn help_text_stays_in_sync_with_dispatch_table() {
        // Every dispatchable command appears exactly once as a help
        // line, and every help line names a dispatchable command —
        // the property that rotted when `campaign` and `serve`
        // predated the old hand-maintained USAGE string.
        let help = usage();
        let listed: Vec<&str> = help
            .lines()
            .skip_while(|l| *l != "commands:")
            .skip(1)
            .filter(|l| !l.trim().is_empty())
            .map(|l| l.split_whitespace().next().unwrap())
            .collect();
        let names: Vec<&str> = commands().iter().map(|c| c.name).collect();
        assert_eq!(listed, names, "help lines != dispatch table");
        for c in commands() {
            assert_eq!(
                help.matches(&format!("  {} ", c.name)).count()
                    + help.matches(&format!("  {}\n", c.name)).count(),
                1,
                "{} must appear exactly once in help",
                c.name
            );
        }
        // The newer subcommands are really there.
        assert!(names.contains(&"campaign") && names.contains(&"serve"));
        // Unknown-command errors carry the generated help.
        let err = dispatch(&parse("nonsense")).unwrap_err().to_string();
        assert!(err.contains("commands:") && err.contains("serve"));
    }

    #[test]
    fn every_command_dispatches_to_its_table_entry() {
        // Resolution only (running every experiment here would be a
        // full evaluation pass): an unknown name misses the table, a
        // known name resolves to the entry whose name matches.
        for c in commands() {
            let found = commands().iter().find(|k| k.name == c.name).unwrap();
            assert!(std::ptr::eq(found, c));
        }
        assert!(commands().iter().all(|c| !c.summary.is_empty()));
    }

    #[test]
    fn fig12_small_sweep_runs() {
        dispatch(&parse("fig12 --cores 64,128")).unwrap();
    }

    #[test]
    fn cache_runs() {
        dispatch(&parse("cache")).unwrap();
    }

    #[test]
    fn campaign_runs() {
        dispatch(&parse("campaign")).unwrap();
    }

    #[test]
    fn serve_small_matrix_runs() {
        dispatch(&parse("serve --sessions 6 --seed 9")).unwrap();
    }

    #[test]
    fn scale_small_point_runs() {
        dispatch(&parse("scale --nodes 8 --sessions 30 --seed 5")).unwrap();
    }

    #[test]
    fn chaos_small_matrix_runs() {
        dispatch(&parse("chaos --sessions 6 --seed 9")).unwrap();
    }

    #[test]
    fn ingest_small_matrix_runs() {
        dispatch(&parse("ingest --sessions 3 --seed 9")).unwrap();
    }

    #[test]
    fn elastic_small_matrix_runs() {
        dispatch(&parse("elastic --sessions 6 --seed 9")).unwrap();
    }

    #[test]
    fn scale_rejects_mismatched_sweeps() {
        assert!(dispatch(&parse("scale --nodes 8,16 --sessions 30")).is_err());
    }
}
