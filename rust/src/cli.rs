//! The `xstage` command-line interface.
//!
//! One subcommand per paper experiment plus utility commands:
//!
//! ```text
//! xstage fig10 [--nodes 512,1024,...]   staging+write bandwidth sweep
//! xstage fig11 [--nodes ...]            staged vs naive end-to-end
//! xstage fig12 [--cores 64,128,...]     FF stage-1 makespan scaling
//! xstage fig13 [--cores ...]            FF stage-2 makespan scaling
//! xstage reduction                      SVI-A cluster reduction
//! xstage cache                          SVI-B worker-cache experiment
//! xstage all                            every table, in order
//! xstage runtime-check                  load artifacts + smoke-execute
//! ```

use anyhow::{bail, Result};

use crate::experiments;
use crate::util::args::Args;

pub const USAGE: &str = "usage: xstage <command> [flags]

commands:
  fig10       Staging+Write aggregate bandwidth vs nodes   [--nodes a,b,c]
  fig11       End-to-end input: I/O hook vs naive          [--nodes a,b,c]
  fig12       FF-HEDM stage 1 makespan scaling             [--cores a,b,c]
  fig13       FF-HEDM stage 2 makespan scaling             [--cores a,b,c]
  reduction   NF-HEDM data reduction on the cluster (SVI-A)
  cache       Worker input-cache experiment (SVI-B)
  reuse       Staged-data reuse across interactive cycles (SI)
  campaign    Multi-campaign residency session under memory pressure
  all         Run every experiment table in order
  runtime-check  Load AOT artifacts and smoke-execute on PJRT
";

/// Dispatch a parsed command line; returns the process exit code.
pub fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_deref() {
        Some("fig10") => {
            let sweep = args.u32_list_or("nodes", experiments::BGQ_SWEEP)?;
            experiments::fig10::run(&sweep).print();
        }
        Some("fig11") => {
            let sweep = args.u32_list_or("nodes", experiments::BGQ_SWEEP)?;
            experiments::fig11::run(&sweep).print();
        }
        Some("fig12") => {
            let sweep = args.u32_list_or("cores", experiments::ORTHROS_SWEEP)?;
            experiments::fig12::run(&sweep).print();
        }
        Some("fig13") => {
            let sweep = args.u32_list_or("cores", experiments::ORTHROS_SWEEP)?;
            experiments::fig13::run(&sweep).print();
        }
        Some("reduction") => experiments::reduction::run().print(),
        Some("reuse") => experiments::reuse::run().print(),
        Some("cache") => experiments::cache::run().print(),
        Some("campaign") => experiments::campaign::run().print(),
        Some("all") => {
            experiments::fig10::default().print();
            println!();
            experiments::fig11::default().print();
            println!();
            experiments::fig12::default().print();
            println!();
            experiments::fig13::default().print();
            println!();
            experiments::reduction::run().print();
            println!();
            experiments::cache::run().print();
            println!();
            experiments::reuse::run().print();
            println!();
            experiments::campaign::run().print();
        }
        Some("runtime-check") => runtime_check()?,
        Some(other) => bail!("unknown command {other:?}\n{USAGE}"),
        None => bail!("{USAGE}"),
    }
    Ok(())
}

fn runtime_check() -> Result<()> {
    use crate::runtime::{Runtime, TensorF32};
    if !Runtime::artifacts_available() {
        bail!("no artifacts found — run `make artifacts` first");
    }
    let mut rt = Runtime::load(Runtime::default_dir())?;
    println!("platform: {}", rt.platform());
    println!("entry points: {}", rt.manifest.entry_points.len());
    for (name, ep) in rt.manifest.entry_points.clone() {
        println!("  {name}: {} -> {} tensors", ep.inputs.len(), ep.outputs.len());
    }
    let x = TensorF32::scalar_vec(vec![1.0, 2.0, 3.0, 4.0]);
    let y = TensorF32::scalar_vec(vec![5.0, 6.0, 7.0, 8.0]);
    let outs = rt.call("smoke_addmul", &[x, y])?;
    anyhow::ensure!(outs[0].data == vec![6.0, 8.0, 10.0, 12.0], "bad add");
    println!("smoke_addmul OK: {:?}", outs[0].data);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn unknown_command_errors() {
        assert!(dispatch(&parse("nonsense")).is_err());
        assert!(dispatch(&parse("")).is_err());
    }

    #[test]
    fn fig12_small_sweep_runs() {
        dispatch(&parse("fig12 --cores 64,128")).unwrap();
    }

    #[test]
    fn cache_runs() {
        dispatch(&parse("cache")).unwrap();
    }

    #[test]
    fn campaign_runs() {
        dispatch(&parse("campaign")).unwrap();
    }
}
