//! The max-min fair-share rate assigner (progressive filling), shared
//! by both throughput models.
//!
//! [`assign_rates`] runs the classic water-filling loop — repeat
//! { freeze either the flows whose per-member cap is below every
//! link's fair share, or every flow through the bottleneck link } —
//! restricted to an explicit set of flows. The restriction is exact
//! when the set is closed under link-sharing (a union of connected
//! components): no flow outside the set can contend for any link the
//! set touches, so capacities and stream counts computed from the set
//! alone equal their global values. The slow model passes the whole
//! active set; the fast model passes one dirty component at a time.
//!
//! Cost is O(touched links × freeze rounds + Σ path lengths); the
//! per-link scratch in [`NetState`] is stamped, so nothing is ever
//! cleared at O(total links).

use super::state::NetState;
use super::{FlowId, LinkClass, LinkId};

/// Per-touched-link accumulator for one assignment pass.
struct Acc {
    link: u32,
    cap_left: f64,
    members_left: f64,
    streams: f64,
}

/// Assign max-min fair rates to `flows` (which must be a union of
/// link-connected components of the active set). Flows must be synced
/// before rates are overwritten; pathless flows get their cap.
pub(crate) fn assign_rates(st: &mut NetState, flows: &[FlowId]) {
    assign_rates_filtered(st, flows, None)
}

/// [`assign_rates`] restricted to the links whose class `skip_class`
/// does **not** match: matching links contribute no capacity constraint
/// and collect no load, and a flow whose entire path is skipped is
/// treated as pathless (rate = its cap). The hierarchical settle uses
/// this to water-fill one spoke group at a time with the shared hub
/// links excluded, then separately verifies the hubs have slack — the
/// exactness condition for the split (see `hier`). With `None` the
/// behaviour is byte-identical to the unfiltered pass.
pub(crate) fn assign_rates_filtered(
    st: &mut NetState,
    flows: &[FlowId],
    skip_class: Option<fn(LinkClass) -> bool>,
) {
    st.stamp += 1;
    let stamp = st.stamp;
    // Split-borrow the state so link scratch and slot reads don't alias.
    let NetState { links, slots, link_stamp, link_slot, .. } = st;
    let skip = |l: usize| skip_class.is_some_and(|s| s(links[l].class));

    // Collect the touched links, in ascending link order so bottleneck
    // selection is deterministic and identical to a whole-network scan.
    // Skipped links are never stamped, so `link_slot` holds garbage for
    // them — every later path walk must apply the same filter.
    let mut accs: Vec<Acc> = Vec::new();
    for &id in flows {
        for &LinkId(l) in &slots[id.idx()].flow.path {
            if skip(l) {
                continue;
            }
            if link_stamp[l] != stamp {
                link_stamp[l] = stamp;
                accs.push(Acc { link: l as u32, cap_left: 0.0, members_left: 0.0, streams: 0.0 });
            }
        }
    }
    accs.sort_by_key(|a| a.link);
    for (i, a) in accs.iter().enumerate() {
        link_slot[a.link as usize] = i as u32;
    }

    // Stream counts (for degrading capacities), then effective capacity.
    for &id in flows {
        let f = &slots[id.idx()].flow;
        for &LinkId(l) in &f.path {
            if skip(l) {
                continue;
            }
            accs[link_slot[l] as usize].streams += f.members as f64;
        }
    }
    for a in accs.iter_mut() {
        a.cap_left = links[a.link as usize].cap.effective(a.streams);
    }

    // Seed: pathless flows run at their cap; the rest enter unfrozen.
    let mut unfrozen: Vec<FlowId> = Vec::with_capacity(flows.len());
    for &id in flows {
        let f = &mut slots[id.idx()].flow;
        if f.path.is_empty() || f.path.iter().all(|&LinkId(l)| skip(l)) {
            // An in-RAM copy or per-process local stream; rate is its
            // cap (INFINITY = instantaneous). Under a filter, a flow
            // whose links are all skipped is constrained by nothing in
            // this pass — the caller's feasibility check owns it.
            f.rate_each = f.cap_each;
            continue;
        }
        f.rate_each = 0.0;
        unfrozen.push(id);
        let members = f.members as f64;
        for &LinkId(l) in &f.path {
            if skip(l) {
                continue;
            }
            accs[link_slot[l] as usize].members_left += members;
        }
    }

    while !unfrozen.is_empty() {
        // Candidate A: bottleneck link share.
        let mut link_best: Option<(f64, usize)> = None;
        for (ai, a) in accs.iter().enumerate() {
            if a.members_left > 0.0 {
                let share = a.cap_left / a.members_left;
                if link_best.map_or(true, |(s, _)| share < s) {
                    link_best = Some((share, ai));
                }
            }
        }
        // Candidate B: smallest per-member rate cap among unfrozen.
        let cap_best = unfrozen
            .iter()
            .map(|id| slots[id.idx()].flow.cap_each)
            .fold(f64::INFINITY, f64::min);

        let freeze_at_cap = match link_best {
            Some((s, _)) => cap_best < s,
            None => cap_best.is_finite(),
        };
        if freeze_at_cap {
            // Freeze the cap-limited flows at their cap.
            let mut still = Vec::with_capacity(unfrozen.len());
            for id in unfrozen.drain(..) {
                let cap = slots[id.idx()].flow.cap_each;
                if cap <= cap_best {
                    slots[id.idx()].flow.rate_each = cap;
                    let members = slots[id.idx()].flow.members as f64;
                    for &LinkId(l) in &slots[id.idx()].flow.path {
                        if skip(l) {
                            continue;
                        }
                        let a = &mut accs[link_slot[l] as usize];
                        a.cap_left -= cap * members;
                        a.members_left -= members;
                    }
                } else {
                    still.push(id);
                }
            }
            unfrozen = still;
        } else {
            let Some((share, bott_ai)) = link_best else { break };
            let bott = accs[bott_ai].link as usize;
            // Freeze every unfrozen flow through the bottleneck.
            let mut still = Vec::with_capacity(unfrozen.len());
            for id in unfrozen.drain(..) {
                let through = slots[id.idx()].flow.path.iter().any(|l| l.0 == bott);
                if through {
                    slots[id.idx()].flow.rate_each = share;
                    let members = slots[id.idx()].flow.members as f64;
                    for &LinkId(l) in &slots[id.idx()].flow.path {
                        if skip(l) {
                            continue;
                        }
                        let a = &mut accs[link_slot[l] as usize];
                        a.cap_left -= share * members;
                        a.members_left -= members;
                    }
                } else {
                    still.push(id);
                }
            }
            unfrozen = still;
        }
        // Guard against FP drift leaving tiny negative capacity.
        for a in accs.iter_mut() {
            if a.cap_left < 0.0 {
                a.cap_left = 0.0;
            }
        }
    }
}
