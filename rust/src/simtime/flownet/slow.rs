//! The reference throughput model: global recompute on every change.
//!
//! This is the seed implementation's behaviour, restated against the
//! [`ThroughputModel`] boundary: any start or completion marks the
//! whole network dirty; a settle syncs and re-waterfills *every*
//! active flow and produces a single component covering all of them,
//! whose fresh id invalidates the previously scheduled check (the old
//! global-epoch scheme, expressed as never-reused component ids).
//!
//! O(active) per network event — quadratic over a churny run — but
//! small, obviously correct, and therefore the differential-testing
//! oracle for [`super::fast::FastModel`].

use crate::units::{Duration, SimTime};

use super::model::{CompCheck, ThroughputModel};
use super::state::NetState;
use super::{CompId, FlowId, ThroughputMode};

#[derive(Debug, Default)]
pub(crate) struct SlowModel {
    /// The single live component: (id, members, earliest completion).
    comp: Option<GlobalComp>,
    next_comp: u64,
    dirty: bool,
    /// Ids retired since the last drain (the old global component's
    /// id, recorded when a settle replaces it).
    retired: Vec<u64>,
}

#[derive(Debug)]
struct GlobalComp {
    id: u64,
    members: Vec<FlowId>,
    next: Option<(SimTime, FlowId)>,
}

impl SlowModel {
    pub(crate) fn new() -> SlowModel {
        SlowModel { comp: None, next_comp: 1, dirty: false, retired: Vec::new() }
    }
}

impl ThroughputModel for SlowModel {
    fn mode(&self) -> ThroughputMode {
        ThroughputMode::Slow
    }

    fn on_start(&mut self, _st: &mut NetState, _id: FlowId) {
        self.dirty = true;
    }

    fn on_complete(&mut self, _st: &mut NetState, _id: FlowId) {
        self.dirty = true;
    }

    fn dirty_comp(&mut self, _st: &mut NetState, comp: CompId) {
        if self.comp.as_ref().map_or(false, |c| c.id == comp.0) {
            self.dirty = true;
        }
    }

    fn invalidate_all(&mut self, _st: &mut NetState) {
        self.dirty = true;
    }

    fn is_dirty(&self) -> bool {
        self.dirty
    }

    fn settle(&mut self, st: &mut NetState, out: &mut Vec<CompCheck>) {
        if !self.dirty {
            return;
        }
        self.dirty = false;
        let members = st.active.clone();
        let id = self.next_comp;
        self.next_comp += 1;
        let next = super::model::settle_component(st, &members, CompId(id), out);
        if let Some(old) = self.comp.replace(GlobalComp { id, members, next }) {
            self.retired.push(old.id);
        }
    }

    fn comp_members(&self, comp: CompId) -> Option<&[FlowId]> {
        match &self.comp {
            Some(c) if c.id == comp.0 => Some(&c.members),
            _ => None,
        }
    }

    fn comp_count(&self) -> usize {
        usize::from(self.comp.is_some())
    }

    fn drain_retired(&mut self, out: &mut Vec<u64>) {
        out.append(&mut self.retired);
    }

    fn next_completion(&self, st: &NetState) -> Option<(Duration, FlowId)> {
        let c = self.comp.as_ref()?;
        let (at, id) = c.next?;
        Some((at - st.now, id))
    }
}
