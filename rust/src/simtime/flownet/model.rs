//! The throughput-model boundary.
//!
//! A [`ThroughputModel`] decides *when and for whom* fair-share rates
//! are recomputed and *which completion checks* the engine should
//! schedule; the arithmetic itself is the shared water-filling pass in
//! the private `waterfill` module. Two implementations:
//!
//! - `slow::SlowModel` — the reference algorithm: every
//!   change invalidates everything; one global component is rebuilt
//!   per settle. O(active) per network event, provably simple. Kept as
//!   the differential-testing oracle.
//! - `fast::FastModel` — the incremental algorithm: active
//!   flows are partitioned into link-connected components; a change
//!   dirties only the components it touches, and only those are
//!   recomputed and rescheduled. Cost per event scales with the dirty
//!   component, not the machine.
//!
//! **Check staleness.** Component ids are allocated from a
//! never-reused counter, so a [`CompCheck`] whose component has since
//! been invalidated simply names a dead id — the engine's event can
//! stay in the heap and is ignored when it fires (logical
//! cancellation, replacing the old single global epoch).

use crate::units::{Duration, SimTime};

use super::state::{eta_secs, NetState};
use super::{CompId, FlowId, ThroughputMode};

/// A completion check the engine should schedule: "at `at`, look at
/// component `comp` for drained flows".
#[derive(Clone, Copy, Debug)]
pub struct CompCheck {
    pub comp: CompId,
    pub at: SimTime,
}

/// The shared settle epilogue both models run per rebuilt component:
/// materialise member progress at the *old* rates, assign new
/// fair-share rates, fold the earliest completion (ties to the first
/// member in the given order), and emit the component's check.
/// Returns the earliest (time, flow) for the component's record.
pub(crate) fn settle_component(
    st: &mut NetState,
    members: &[FlowId],
    comp: CompId,
    out: &mut Vec<CompCheck>,
) -> Option<(SimTime, FlowId)> {
    for &m in members {
        st.sync_flow(m);
    }
    super::waterfill::assign_rates(st, members);
    let now = st.now;
    let mut next: Option<(SimTime, FlowId)> = None;
    for &m in members {
        let f = &st.slots[m.idx()].flow;
        if let Some(e) = eta_secs(f) {
            let at = now + Duration::from_secs_f64(e);
            if next.map_or(true, |(t, _)| at < t) {
                next = Some((at, m));
            }
        }
    }
    if let Some((at, _)) = next {
        out.push(CompCheck { comp, at });
    }
    next
}

/// Strategy for recomputing fair-share rates and scheduling
/// completion checks. See module docs for the contract; invariants are
/// documented in `DESIGN.md`.
pub trait ThroughputModel {
    fn mode(&self) -> ThroughputMode;

    /// `id` just became active (already registered in `st`).
    fn on_start(&mut self, st: &mut NetState, id: FlowId);

    /// `id` is about to leave the active set (still registered).
    fn on_complete(&mut self, st: &mut NetState, id: FlowId);

    /// Invalidate `comp` so the next settle recomputes its members.
    /// No-op when `comp` is already stale.
    fn dirty_comp(&mut self, st: &mut NetState, comp: CompId);

    /// Invalidate everything (benchmarks / diagnostics).
    fn invalidate_all(&mut self, st: &mut NetState);

    /// True when a settle would do work.
    fn is_dirty(&self) -> bool;

    /// Recompute rates for everything dirty; push one [`CompCheck`]
    /// per rebuilt component that has a finite next completion.
    fn settle(&mut self, st: &mut NetState, out: &mut Vec<CompCheck>);

    /// Members of `comp`, or `None` when the id is stale.
    fn comp_members(&self, comp: CompId) -> Option<&[FlowId]>;

    /// Append the ids of components retired since the last drain to
    /// `out`, clearing the internal record. Ids are never reused, so
    /// each id is reported exactly once, at the settle/kill that
    /// replaced or removed it. The engine uses this to reclaim the
    /// retired components' pending `FlowCheck` timers eagerly instead
    /// of letting them fire as stale no-ops.
    fn drain_retired(&mut self, out: &mut Vec<u64>);

    /// Number of live components (diagnostics/benchmarks).
    fn comp_count(&self) -> usize;

    /// Earliest scheduled completion over all live components,
    /// relative to `st.now`.
    fn next_completion(&self, st: &NetState) -> Option<(Duration, FlowId)>;
}
