//! Shared flow/link storage for the throughput models.
//!
//! [`NetState`] owns what both the slow and fast models operate on:
//!
//! - the link table, each link carrying its *active membership list*
//!   (which flows traverse it) with O(1) swap-remove bookkeeping;
//! - a slab of flow slots with a free list, so a long-running
//!   simulation that starts and completes millions of flows keeps a
//!   bounded footprint (generation counters make stale [`FlowId`]s
//!   detectable instead of aliasing a reused slot);
//! - the active-flow list, also swap-removed in O(1);
//! - the network-local virtual clock for *lazy* progress accounting:
//!   a flow's `remaining_each` is stored as of its `synced_at`
//!   timestamp and materialised linearly at the current rate on read,
//!   so advancing time is O(1) instead of O(active flows).

use crate::units::SimTime;

use super::{Capacity, CompId, FlowId, LinkClass, LinkId};

/// Bytes of residue below which a flow counts as drained (absorbs the
/// nanosecond-ceiling rounding of completion times).
pub(crate) const DRAIN_EPS: f64 = 0.5;

#[derive(Debug)]
pub(crate) struct Link {
    pub(crate) name: String,
    pub(crate) class: LinkClass,
    pub(crate) cap: Capacity,
    /// Active flows through this link as `(flow, index of this link in
    /// that flow's path)`. Unordered; removal is swap-remove with the
    /// back-pointer fixed up via `Flow::link_pos`.
    pub(crate) members: Vec<(FlowId, u32)>,
}

#[derive(Debug)]
pub(crate) struct Flow {
    pub(crate) path: Vec<LinkId>,
    /// `link_pos[i]` = index of this flow's entry in
    /// `links[path[i]].members`.
    pub(crate) link_pos: Vec<u32>,
    pub(crate) members: u64,
    /// Bytes still to move per member, valid as of `synced_at`.
    pub(crate) remaining_each: f64,
    /// Current fair-share rate, bytes/sec per member.
    pub(crate) rate_each: f64,
    /// Per-member rate cap; INFINITY when uncapped.
    pub(crate) cap_each: f64,
    /// Time `remaining_each` was last materialised at.
    pub(crate) synced_at: SimTime,
    /// Position in `NetState::active` (valid while live).
    pub(crate) active_pos: u32,
    /// Owning component (fast model; `CompId::NONE` when unassigned).
    pub(crate) comp: CompId,
    /// Queued for recompute (fast model).
    pub(crate) dirty: bool,
    /// Flood-fill visit stamp (fast model).
    pub(crate) visit: u64,
}

/// Expected completion delay of a synced flow at its current rate.
/// `Some(0.0)`: drained or instantaneous; `None`: starved.
pub(crate) fn eta_secs(f: &Flow) -> Option<f64> {
    if f.rate_each == f64::INFINITY || f.remaining_each <= DRAIN_EPS {
        Some(0.0)
    } else if f.rate_each > 0.0 {
        Some(f.remaining_each / f.rate_each)
    } else {
        None
    }
}

#[derive(Debug)]
pub(crate) struct Slot {
    pub(crate) gen: u32,
    pub(crate) live: bool,
    pub(crate) flow: Flow,
}

/// Storage shared by every [`super::ThroughputModel`]; see module docs.
#[derive(Debug, Default)]
pub struct NetState {
    pub(crate) links: Vec<Link>,
    pub(crate) slots: Vec<Slot>,
    pub(crate) free: Vec<u32>,
    pub(crate) active: Vec<FlowId>,
    /// Network-local virtual clock (sum of `advance` deltas).
    pub(crate) now: SimTime,
    // Waterfill scratch, stamped so reuse costs O(touched links), not
    // O(all links), per recompute.
    pub(crate) link_stamp: Vec<u64>,
    pub(crate) link_slot: Vec<u32>,
    pub(crate) stamp: u64,
}

impl NetState {
    pub(crate) fn add_link(&mut self, name: String, class: LinkClass, cap: Capacity) -> LinkId {
        self.links.push(Link { name, class, cap, members: Vec::new() });
        self.link_stamp.push(0);
        self.link_slot.push(0);
        LinkId(self.links.len() - 1)
    }

    /// Allocate a slot (reusing the free list), register the flow on
    /// its links and the active list, and return its id.
    pub(crate) fn start_flow(
        &mut self,
        path: Vec<LinkId>,
        members: u64,
        bytes_each: u64,
        cap_each: f64,
    ) -> FlowId {
        assert!(members > 0, "empty bundle");
        assert!(cap_each > 0.0, "non-positive rate cap");
        for l in &path {
            assert!(l.0 < self.links.len(), "bad link id {l:?}");
        }
        let link_pos = vec![0u32; path.len()];
        let flow = Flow {
            path,
            link_pos,
            members,
            remaining_each: bytes_each as f64,
            rate_each: 0.0,
            cap_each,
            synced_at: self.now,
            active_pos: self.active.len() as u32,
            comp: CompId::NONE,
            dirty: false,
            visit: 0,
        };
        let idx = match self.free.pop() {
            Some(i) => {
                let slot = &mut self.slots[i as usize];
                debug_assert!(!slot.live);
                slot.live = true;
                slot.flow = flow;
                i as usize
            }
            None => {
                self.slots.push(Slot { gen: 0, live: true, flow });
                self.slots.len() - 1
            }
        };
        let id = FlowId::new(idx as u32, self.slots[idx].gen);
        self.active.push(id);
        // Register on each path link, recording the back-pointer.
        let npath = self.slots[idx].flow.path.len();
        for pi in 0..npath {
            let LinkId(l) = self.slots[idx].flow.path[pi];
            self.links[l].members.push((id, pi as u32));
            self.slots[idx].flow.link_pos[pi] = (self.links[l].members.len() - 1) as u32;
        }
        id
    }

    /// Unregister `id` everywhere and release its slot. The caller must
    /// have validated liveness.
    pub(crate) fn remove_flow(&mut self, id: FlowId) {
        let idx = id.idx();
        debug_assert!(self.slots[idx].live && self.slots[idx].gen == id.gen());
        // Links: swap-remove each membership entry, fixing the moved
        // entry's back-pointer.
        let npath = self.slots[idx].flow.path.len();
        for pi in 0..npath {
            let LinkId(l) = self.slots[idx].flow.path[pi];
            let pos = self.slots[idx].flow.link_pos[pi] as usize;
            self.links[l].members.swap_remove(pos);
            if pos < self.links[l].members.len() {
                let (moved, moved_pi) = self.links[l].members[pos];
                self.slots[moved.idx()].flow.link_pos[moved_pi as usize] = pos as u32;
            }
        }
        // Active list: swap-remove, fixing the moved flow's position.
        let apos = self.slots[idx].flow.active_pos as usize;
        debug_assert_eq!(self.active[apos], id);
        self.active.swap_remove(apos);
        if apos < self.active.len() {
            let moved = self.active[apos];
            self.slots[moved.idx()].flow.active_pos = apos as u32;
        }
        // Release: bump the generation so stale ids are detectable.
        let slot = &mut self.slots[idx];
        slot.live = false;
        slot.gen = slot.gen.wrapping_add(1);
        slot.flow.remaining_each = 0.0;
        self.free.push(idx as u32);
    }

    /// The flow for `id` if it is still live (generation-checked).
    pub(crate) fn flow(&self, id: FlowId) -> Option<&Flow> {
        let slot = self.slots.get(id.idx())?;
        if slot.live && slot.gen == id.gen() {
            Some(&slot.flow)
        } else {
            None
        }
    }

    pub(crate) fn flow_mut(&mut self, id: FlowId) -> Option<&mut Flow> {
        let slot = self.slots.get_mut(id.idx())?;
        if slot.live && slot.gen == id.gen() {
            Some(&mut slot.flow)
        } else {
            None
        }
    }

    /// Materialise a live flow's `remaining_each` to `self.now`.
    pub(crate) fn sync_flow(&mut self, id: FlowId) {
        let now = self.now;
        let f = &mut self.slots[id.idx()].flow;
        let dt = now - f.synced_at;
        if dt.0 > 0 {
            if f.rate_each.is_finite() {
                f.remaining_each = (f.remaining_each - f.rate_each * dt.secs_f64()).max(0.0);
            } else {
                // Instantaneous flow: any positive elapsed time drains it.
                f.remaining_each = 0.0;
            }
        }
        f.synced_at = now;
    }

    /// Pure read of a live flow's remaining bytes as of `self.now`.
    pub(crate) fn remaining_at_now(&self, id: FlowId) -> f64 {
        let f = &self.slots[id.idx()].flow;
        let dt = self.now - f.synced_at;
        if dt.0 == 0 {
            return f.remaining_each;
        }
        if f.rate_each.is_finite() {
            (f.remaining_each - f.rate_each * dt.secs_f64()).max(0.0)
        } else {
            0.0
        }
    }
}
