//! Flow-level network model with max-min fair bandwidth sharing.
//!
//! Every bandwidth-shaped resource in the simulated testbed is a link:
//! a GPFS storage server, the filesystem's aggregate backplane
//! (240 GB/s on the paper's installation), a BG/Q I/O-node uplink, a
//! compute-node torus injection port, the APS↔ALCF WAN pipe.
//! Concurrent transfers are flows traversing a *path* (an ordered set —
//! order is irrelevant to the math) of links.
//!
//! **Flow bundles.** The paper's workloads are symmetric at enormous
//! fan-out (8,192 nodes all staging the same 577 MB dataset). Modelling
//! each per-node transfer as its own flow would make every rate
//! recomputation O(nodes × links). Instead a flow has a `members`
//! count: `members` identical transfers advancing in lockstep, each
//! consuming one fair share on every link of the path. A collective
//! over 8K nodes is then a handful of bundles and recomputation cost is
//! independent of machine size (measured in the `hotpath` bench).
//!
//! **Max-min fairness** via progressive filling (water-filling): repeat
//! { find the link whose remaining capacity divided by its unfrozen
//! member count is smallest; freeze every unfrozen flow through it at
//! that per-member share }. This is the classic fluid approximation of
//! TCP/interconnect fair sharing used by flow-level simulators. The
//! pass itself lives in the private `waterfill` module; *when* it runs
//! and *over which flows* is the [`ThroughputModel`] boundary:
//!
//! - [`ThroughputMode::Slow`] — the reference algorithm: every change
//!   recomputes every active flow (the seed implementation; kept as
//!   the differential-testing oracle).
//! - [`ThroughputMode::Fast`] — the default: active flows are
//!   partitioned into link-connected components and only the dirty
//!   component is recomputed and rescheduled; unrelated components'
//!   completion checks are never invalidated. Cost per network event
//!   scales with what actually changed.
//!
//! **Degrading capacity.** GPFS's delivered bandwidth collapses under
//! many uncoordinated readers (disk-head thrash and prefetch loss; the
//! mechanism behind the paper's Fig 11 naive curve). A link may
//! therefore declare [`Capacity::Degrading`], an efficiency that decays
//! with the total number of concurrent streams:
//!
//! ```text
//! effective(n) = peak / (1 + max(0, n - pivot) / half)
//! ```
//!
//! With `pivot` streams or fewer there is no penalty; each additional
//! `half` streams halve the *additional* efficiency. The constants for
//! the GPFS model are calibrated in `pfs::GpfsParams` against the
//! paper's measured 21 GB/s naive aggregate at 8K nodes.

mod fast;
mod hier;
pub mod model;
mod slow;
mod state;
mod waterfill;

pub use model::{CompCheck, ThroughputModel};
pub use state::NetState;

use crate::units::{Duration, SimTime};
use state::DRAIN_EPS;

/// Identifies a link within one [`FlowNet`].
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct LinkId(pub usize);

/// Identifies a flow within one [`FlowNet`].
///
/// Encodes a storage slot index plus a per-slot generation, so slots
/// freed by completed flows are reused (bounded memory under churn)
/// while stale ids remain detectable: queries against a completed
/// flow's id keep answering "done / zero remaining" even after the
/// slot hosts a newer flow.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct FlowId(pub u64);

impl FlowId {
    pub(crate) fn new(idx: u32, gen: u32) -> FlowId {
        FlowId(((gen as u64) << 32) | idx as u64)
    }

    pub(crate) fn idx(self) -> usize {
        (self.0 & 0xffff_ffff) as usize
    }

    pub(crate) fn gen(self) -> u32 {
        (self.0 >> 32) as u32
    }
}

/// Identifies a connected component of the active flow set. Ids are
/// never reused; a scheduled completion check naming a dead component
/// is stale and ignored (logical cancellation in the event heap).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct CompId(pub u64);

impl CompId {
    /// Sentinel: flow not (yet) assigned to a component.
    pub const NONE: CompId = CompId(0);
}

/// What a link models, declared at construction so component and
/// contention diagnostics can attribute load to a machine layer.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum LinkClass {
    /// Filesystem aggregate backplane.
    Backplane,
    /// Degrading server-side disk stage (uncoordinated reads).
    Disk,
    /// Metadata server ("bytes" are metadata operations).
    Meta,
    /// I/O-node uplink layer.
    Ion,
    /// Torus / cluster interconnect bisection.
    Interconnect,
    /// Node-local SSD / burst-buffer layer (storage-tier demotion and
    /// promotion traffic).
    Ssd,
    /// Wide-area pipe between facilities.
    Wan,
    /// Detector-to-facility beamline pipe (streaming frame ingest).
    Beamline,
    /// Anything else (tests, ad-hoc scenarios).
    Other,
}

/// Which [`ThroughputModel`] a [`FlowNet`] runs.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ThroughputMode {
    /// Global recompute on every change (reference oracle).
    Slow,
    /// Component-scoped incremental recompute (default).
    Fast,
}

/// Link capacity model, bytes/second.
#[derive(Clone, Copy, Debug)]
pub enum Capacity {
    /// Constant capacity regardless of stream count.
    Fixed(f64),
    /// Stream-count-dependent capacity (see module docs).
    Degrading { peak: f64, pivot: f64, half: f64 },
}

impl Capacity {
    /// Effective capacity when `streams` concurrent members traverse it.
    pub fn effective(&self, streams: f64) -> f64 {
        match *self {
            Capacity::Fixed(c) => c,
            Capacity::Degrading { peak, pivot, half } => {
                let excess = (streams - pivot).max(0.0);
                peak / (1.0 + excess / half)
            }
        }
    }
}

/// The flow network. Owned by the simulation engine; rates are
/// recomputed by the configured [`ThroughputModel`] whenever the
/// active flow set changes.
pub struct FlowNet {
    st: NetState,
    model: Box<dyn ThroughputModel>,
}

impl FlowNet {
    /// A network running the default (fast, component-incremental)
    /// throughput model.
    pub fn new() -> Self {
        FlowNet::with_mode(ThroughputMode::Fast)
    }

    pub fn with_mode(mode: ThroughputMode) -> Self {
        let model: Box<dyn ThroughputModel> = match mode {
            ThroughputMode::Slow => Box::new(slow::SlowModel::new()),
            ThroughputMode::Fast => Box::new(fast::FastModel::new()),
        };
        FlowNet { st: NetState::default(), model }
    }

    pub fn mode(&self) -> ThroughputMode {
        self.model.mode()
    }

    // ------------------------------------------------------------------
    // topology
    // ------------------------------------------------------------------

    pub fn add_link(&mut self, name: impl Into<String>, cap: Capacity) -> LinkId {
        self.add_link_classed(name, cap, LinkClass::Other)
    }

    /// [`FlowNet::add_link`] with the machine layer declared up front.
    pub fn add_link_classed(
        &mut self,
        name: impl Into<String>,
        cap: Capacity,
        class: LinkClass,
    ) -> LinkId {
        self.st.add_link(name.into(), class, cap)
    }

    pub fn link_name(&self, id: LinkId) -> &str {
        &self.st.links[id.0].name
    }

    pub fn link_class(&self, id: LinkId) -> LinkClass {
        self.st.links[id.0].class
    }

    pub fn link_count(&self) -> usize {
        self.st.links.len()
    }

    // ------------------------------------------------------------------
    // flow lifecycle
    // ------------------------------------------------------------------

    /// Begin a bundle of `members` identical transfers of `bytes_each`
    /// bytes across `path`. Returns its id; rates become valid after
    /// the next [`FlowNet::recompute`] / settle.
    pub fn start(&mut self, path: Vec<LinkId>, members: u64, bytes_each: u64) -> FlowId {
        self.start_capped(path, members, bytes_each, f64::INFINITY)
    }

    /// [`FlowNet::start`] with a per-member rate cap.
    pub fn start_capped(
        &mut self,
        path: Vec<LinkId>,
        members: u64,
        bytes_each: u64,
        cap_each: f64,
    ) -> FlowId {
        let id = self.st.start_flow(path, members, bytes_each, cap_each);
        self.model.on_start(&mut self.st, id);
        id
    }

    /// Mark a flow complete and remove it from the active set.
    pub fn complete(&mut self, id: FlowId) {
        assert!(self.st.flow(id).is_some(), "double completion of {id:?}");
        self.model.on_complete(&mut self.st, id);
        self.st.remove_flow(id);
    }

    /// Abort a flow mid-transfer (its endpoint died): remove it from
    /// the active set without crediting the remaining bytes. The freed
    /// capacity redistributes at the next settle, like a completion.
    /// Returns false when the flow had already drained (stale id) —
    /// cancelling a flow that raced to completion is a no-op, not an
    /// error, since the killing event and the completion check may
    /// land at the same virtual instant.
    pub fn cancel(&mut self, id: FlowId) -> bool {
        if self.st.flow(id).is_none() {
            return false;
        }
        self.model.on_complete(&mut self.st, id);
        self.st.remove_flow(id);
        true
    }

    /// Advance virtual time by `dt`. O(1): flow progress is lazy —
    /// materialised from rates on read or at the next settle.
    pub fn advance(&mut self, dt: Duration) {
        self.st.now += dt;
    }

    // ------------------------------------------------------------------
    // settling & completion checks
    // ------------------------------------------------------------------

    /// Recompute whatever the model considers dirty (legacy entry
    /// point for callers that poll [`FlowNet::next_completion`]
    /// instead of scheduling the returned checks).
    pub fn recompute(&mut self) {
        let mut sink = Vec::new();
        self.model.settle(&mut self.st, &mut sink);
    }

    /// Recompute everything dirty; returns the completion checks the
    /// caller should schedule (one per rebuilt component).
    pub fn settle_checks(&mut self) -> Vec<CompCheck> {
        let mut out = Vec::new();
        self.model.settle(&mut self.st, &mut out);
        out
    }

    /// Invalidate all rates and recompute from scratch (benchmarks,
    /// diagnostics; regular operation never needs this).
    pub fn force_recompute(&mut self) {
        self.model.invalidate_all(&mut self.st);
        self.recompute()
    }

    /// True when a settle would do work.
    pub fn is_dirty(&self) -> bool {
        self.model.is_dirty()
    }

    /// Handle a fired completion check: the drained flows of `comp`
    /// (sorted; empty when the check is stale). The caller completes
    /// each returned flow and settles. A live component with nothing
    /// drained (completion-time rounding residue) is re-dirtied so the
    /// next settle reschedules its check.
    pub fn check(&mut self, comp: CompId) -> Vec<FlowId> {
        let members: Vec<FlowId> = match self.model.comp_members(comp) {
            Some(m) => m.to_vec(),
            None => return Vec::new(),
        };
        let mut drained = Vec::new();
        let mut live = 0usize;
        for id in members {
            let Some(f) = self.st.flow(id) else { continue };
            live += 1;
            if f.rate_each == f64::INFINITY || self.st.remaining_at_now(id) <= DRAIN_EPS {
                drained.push(id);
            }
        }
        if drained.is_empty() && live > 0 {
            self.model.dirty_comp(&mut self.st, comp);
        }
        drained.sort();
        drained
    }

    /// True when `comp` still names a live component — i.e. a pending
    /// completion check for it is *not* stale. Used by the engine's
    /// stale-pop accounting.
    pub fn comp_live(&self, comp: CompId) -> bool {
        self.model.comp_members(comp).is_some()
    }

    /// Append the ids of components retired since the last drain to
    /// `out` (see [`ThroughputModel::drain_retired`]). The engine
    /// drains this after every settle to reclaim the retired
    /// components' pending checks from the event heap eagerly.
    pub fn drain_retired(&mut self, out: &mut Vec<u64>) {
        self.model.drain_retired(out);
    }

    /// The earliest (time-from-now, flow) completion at current rates,
    /// across all components. Valid after a settle.
    pub fn next_completion(&self, now: SimTime) -> Option<(SimTime, FlowId)> {
        self.model
            .next_completion(&self.st)
            .map(|(eta, id)| (now + eta, id))
    }

    // ------------------------------------------------------------------
    // queries
    // ------------------------------------------------------------------

    pub fn is_done(&self, id: FlowId) -> bool {
        self.st.flow(id).is_none()
    }

    /// Bytes still to move per member, materialised to the current
    /// virtual time.
    pub fn remaining_each(&self, id: FlowId) -> f64 {
        if self.st.flow(id).is_some() {
            self.st.remaining_at_now(id)
        } else {
            0.0
        }
    }

    /// Current per-member rate, bytes/sec (0.0 once completed).
    pub fn rate_each(&self, id: FlowId) -> f64 {
        self.st.flow(id).map_or(0.0, |f| f.rate_each)
    }

    pub fn active_count(&self) -> usize {
        self.st.active.len()
    }

    /// Live components (1 global component in slow mode).
    pub fn comp_count(&self) -> usize {
        self.model.comp_count()
    }

    /// Flow slots ever allocated — stays bounded under churn because
    /// completed slots are free-listed.
    pub fn slots_allocated(&self) -> usize {
        self.st.slots.len()
    }
}

impl Default for FlowNet {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for FlowNet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FlowNet")
            .field("mode", &self.mode())
            .field("links", &self.link_count())
            .field("active", &self.active_count())
            .field("comps", &self.comp_count())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GB: f64 = 1e9;

    /// Run a scenario under both throughput models.
    fn both(f: impl Fn(FlowNet)) {
        f(FlowNet::with_mode(ThroughputMode::Slow));
        f(FlowNet::with_mode(ThroughputMode::Fast));
    }

    #[test]
    fn single_flow_gets_full_capacity() {
        both(|mut net| {
            let l = net.add_link("l", Capacity::Fixed(10.0 * GB));
            let f = net.start(vec![l], 1, 1_000_000_000);
            net.recompute();
            assert_eq!(net.rate_each(f), 10.0 * GB);
            let (t, id) = net.next_completion(SimTime::ZERO).unwrap();
            assert_eq!(id, f);
            assert_eq!(t.secs_f64(), 0.1);
        });
    }

    #[test]
    fn two_flows_share_equally() {
        both(|mut net| {
            let l = net.add_link("l", Capacity::Fixed(10.0 * GB));
            let a = net.start(vec![l], 1, 1_000_000_000);
            let b = net.start(vec![l], 1, 2_000_000_000);
            net.recompute();
            assert_eq!(net.rate_each(a), 5.0 * GB);
            assert_eq!(net.rate_each(b), 5.0 * GB);
        });
    }

    #[test]
    fn bundle_members_each_take_a_share() {
        both(|mut net| {
            let l = net.add_link("l", Capacity::Fixed(10.0 * GB));
            let bundle = net.start(vec![l], 9, GB as u64);
            let solo = net.start(vec![l], 1, GB as u64);
            net.recompute();
            // 10 members total: 1 GB/s each.
            assert!((net.rate_each(bundle) - GB).abs() < 1.0);
            assert!((net.rate_each(solo) - GB).abs() < 1.0);
        });
    }

    #[test]
    fn bundle_equivalent_to_individual_flows() {
        // N individual flows and one N-member bundle finish at the same time.
        both(|mut net1| {
            let l1 = net1.add_link("l", Capacity::Fixed(8.0 * GB));
            for _ in 0..16 {
                net1.start(vec![l1], 1, GB as u64);
            }
            net1.recompute();
            let t1 = net1.next_completion(SimTime::ZERO).unwrap().0;

            let mut net2 = FlowNet::with_mode(net1.mode());
            let l2 = net2.add_link("l", Capacity::Fixed(8.0 * GB));
            net2.start(vec![l2], 16, GB as u64);
            net2.recompute();
            let t2 = net2.next_completion(SimTime::ZERO).unwrap().0;
            assert_eq!(t1, t2);
        });
    }

    #[test]
    fn water_filling_classic() {
        // Textbook max-min: flows A (link1), B (link1+link2), C (link2).
        // cap1 = 10, cap2 = 4 -> B and C bottleneck on link2 at 2 each;
        // A then gets the link1 remainder: 8.
        both(|mut net| {
            let l1 = net.add_link("1", Capacity::Fixed(10.0));
            let l2 = net.add_link("2", Capacity::Fixed(4.0));
            let a = net.start(vec![l1], 1, 100);
            let b = net.start(vec![l1, l2], 1, 100);
            let c = net.start(vec![l2], 1, 100);
            net.recompute();
            assert!((net.rate_each(b) - 2.0).abs() < 1e-9);
            assert!((net.rate_each(c) - 2.0).abs() < 1e-9);
            assert!((net.rate_each(a) - 8.0).abs() < 1e-9);
        });
    }

    #[test]
    fn completion_frees_capacity() {
        both(|mut net| {
            let l = net.add_link("l", Capacity::Fixed(10.0 * GB));
            let a = net.start(vec![l], 1, GB as u64);
            let b = net.start(vec![l], 1, 10 * GB as u64);
            net.recompute();
            let (t, first) = net.next_completion(SimTime::ZERO).unwrap();
            assert_eq!(first, a);
            net.advance(t - SimTime::ZERO);
            net.complete(a);
            net.recompute();
            assert_eq!(net.rate_each(b), 10.0 * GB);
            assert!(net.is_done(a));
            assert_eq!(net.active_count(), 1);
        });
    }

    #[test]
    fn degrading_capacity_collapses_under_streams() {
        let cap = Capacity::Degrading { peak: 240.0 * GB, pivot: 2048.0, half: 1024.0 };
        assert_eq!(cap.effective(100.0), 240.0 * GB);
        assert_eq!(cap.effective(2048.0), 240.0 * GB);
        // 2048 excess streams = 2 halves -> a third of peak.
        assert!((cap.effective(4096.0) - 80.0 * GB).abs() < 1.0);
    }

    #[test]
    fn degrading_link_in_network() {
        both(|mut net| {
            let l = net.add_link(
                "gpfs",
                Capacity::Degrading { peak: 100.0, pivot: 1.0, half: 1.0 },
            );
            let f = net.start(vec![l], 3, 100);
            net.recompute();
            // 3 streams: effective = 100/(1+2) = 33.33 total, /3 members.
            assert!((net.rate_each(f) - 100.0 / 3.0 / 3.0).abs() < 1e-9);
        });
    }

    #[test]
    fn pathless_flow_is_instantaneous() {
        both(|mut net| {
            let f = net.start(vec![], 1, 1 << 40);
            net.recompute();
            let (t, id) = net.next_completion(SimTime::ZERO).unwrap();
            assert_eq!(id, f);
            assert_eq!(t, SimTime::ZERO);
        });
    }

    #[test]
    fn advance_conserves_bytes() {
        both(|mut net| {
            let l = net.add_link("l", Capacity::Fixed(100.0));
            let f = net.start(vec![l], 1, 1000);
            net.recompute();
            net.advance(Duration::from_secs(3));
            assert!((net.remaining_each(f) - 700.0).abs() < 1e-6);
        });
    }

    #[test]
    fn starved_flow_never_completes() {
        both(|mut net| {
            let dead = net.add_link("dead", Capacity::Fixed(0.0));
            net.start(vec![dead], 1, 100);
            net.recompute();
            assert!(net.next_completion(SimTime::ZERO).is_none());
        });
    }

    #[test]
    fn per_member_cap_limits_rate() {
        both(|mut net| {
            let l = net.add_link("l", Capacity::Fixed(10.0 * GB));
            let capped = net.start_capped(vec![l], 1, GB as u64, 2.0 * GB);
            net.recompute();
            assert_eq!(net.rate_each(capped), 2.0 * GB);
        });
    }

    #[test]
    fn cap_surplus_redistributed() {
        // One capped flow (2 GB/s) + one uncapped on a 10 GB/s link:
        // the uncapped flow takes the 8 GB/s remainder, not a 5/5 split.
        both(|mut net| {
            let l = net.add_link("l", Capacity::Fixed(10.0 * GB));
            let capped = net.start_capped(vec![l], 1, GB as u64, 2.0 * GB);
            let free = net.start(vec![l], 1, GB as u64);
            net.recompute();
            assert_eq!(net.rate_each(capped), 2.0 * GB);
            assert!((net.rate_each(free) - 8.0 * GB).abs() < 1.0);
        });
    }

    #[test]
    fn cap_above_fair_share_is_inert() {
        both(|mut net| {
            let l = net.add_link("l", Capacity::Fixed(10.0 * GB));
            let a = net.start_capped(vec![l], 1, GB as u64, 100.0 * GB);
            let b = net.start(vec![l], 1, GB as u64);
            net.recompute();
            assert!((net.rate_each(a) - 5.0 * GB).abs() < 1.0);
            assert!((net.rate_each(b) - 5.0 * GB).abs() < 1.0);
        });
    }

    #[test]
    fn pathless_capped_flow_runs_at_cap() {
        both(|mut net| {
            let f = net.start_capped(vec![], 16, 1_000, 100.0);
            net.recompute();
            assert_eq!(net.rate_each(f), 100.0);
            let (t, _) = net.next_completion(SimTime::ZERO).unwrap();
            assert_eq!(t.secs_f64(), 10.0);
        });
    }

    #[test]
    fn cancel_frees_capacity_and_tolerates_stale_ids() {
        both(|mut net| {
            let l = net.add_link("l", Capacity::Fixed(10.0 * GB));
            let a = net.start(vec![l], 1, GB as u64);
            let b = net.start(vec![l], 1, GB as u64);
            net.recompute();
            assert_eq!(net.rate_each(b), 5.0 * GB);
            assert!(net.cancel(a));
            net.recompute();
            // The aborted flow's share redistributed; nothing of `a`
            // survives to complete later.
            assert_eq!(net.rate_each(b), 10.0 * GB);
            assert!(net.is_done(a));
            assert!(!net.cancel(a), "second cancel must be a stale no-op");
            net.advance(Duration::from_secs(1));
            net.complete(b);
            assert!(!net.cancel(b), "cancel after completion must be a no-op");
            assert_eq!(net.active_count(), 0);
        });
    }

    #[test]
    #[should_panic(expected = "double completion")]
    fn double_complete_panics() {
        let mut net = FlowNet::new();
        let l = net.add_link("l", Capacity::Fixed(1.0));
        let f = net.start(vec![l], 1, 1);
        net.recompute();
        net.complete(f);
        net.complete(f);
    }

    #[test]
    #[should_panic(expected = "bad link id")]
    fn bad_link_id_panics() {
        let mut net = FlowNet::new();
        net.start(vec![LinkId(7)], 1, 1);
    }

    #[test]
    fn link_classes_declared_at_construction() {
        let mut net = FlowNet::new();
        let bp = net.add_link_classed("pfs.backplane", Capacity::Fixed(1.0), LinkClass::Backplane);
        let other = net.add_link("ad-hoc", Capacity::Fixed(1.0));
        assert_eq!(net.link_class(bp), LinkClass::Backplane);
        assert_eq!(net.link_class(other), LinkClass::Other);
        assert_eq!(net.link_name(bp), "pfs.backplane");
        assert_eq!(net.link_count(), 2);
    }

    #[test]
    fn slots_are_reused_under_churn() {
        both(|mut net| {
            let l = net.add_link("l", Capacity::Fixed(GB));
            let mut last = None;
            for _ in 0..100 {
                let f = net.start(vec![l], 1, GB as u64);
                net.recompute();
                net.complete(f);
                net.recompute();
                last = Some(f);
            }
            // The slab never grows past the peak concurrency (1 flow).
            assert_eq!(net.slots_allocated(), 1);
            assert_eq!(net.active_count(), 0);
            // A completed id stays "done" even though its slot was reused.
            assert!(net.is_done(last.unwrap()));
            assert_eq!(net.remaining_each(last.unwrap()), 0.0);
        });
    }

    #[test]
    fn stale_flow_id_reads_as_done() {
        let mut net = FlowNet::new();
        let l = net.add_link("l", Capacity::Fixed(GB));
        let old = net.start(vec![l], 1, GB as u64);
        net.recompute();
        net.complete(old);
        // New flow reuses the slot; the old id must not alias it.
        let new = net.start(vec![l], 1, 5 * GB as u64);
        net.recompute();
        assert_ne!(old, new);
        assert!(net.is_done(old));
        assert!(!net.is_done(new));
        assert_eq!(net.rate_each(old), 0.0);
        assert_eq!(net.rate_each(new), GB);
    }

    // ------------------------------------------------------------------
    // component semantics (fast model)
    // ------------------------------------------------------------------

    #[test]
    fn disjoint_flows_form_separate_components() {
        let mut net = FlowNet::with_mode(ThroughputMode::Fast);
        let l1 = net.add_link("1", Capacity::Fixed(GB));
        let l2 = net.add_link("2", Capacity::Fixed(GB));
        net.start(vec![l1], 1, GB as u64);
        net.start(vec![l2], 1, GB as u64);
        let checks = net.settle_checks();
        assert_eq!(net.comp_count(), 2);
        assert_eq!(checks.len(), 2);
        assert_ne!(checks[0].comp, checks[1].comp);
    }

    #[test]
    fn start_merges_overlapping_components_only() {
        let mut net = FlowNet::with_mode(ThroughputMode::Fast);
        let l1 = net.add_link("1", Capacity::Fixed(GB));
        let l2 = net.add_link("2", Capacity::Fixed(GB));
        let a = net.start(vec![l1], 1, GB as u64);
        let b = net.start(vec![l2], 1, 2 * GB as u64);
        let first = net.settle_checks();
        assert_eq!(first.len(), 2);
        let rate_b = net.rate_each(b);

        // A third flow on l1 merges with `a` but must not touch `b`.
        let c = net.start(vec![l1], 1, GB as u64);
        let second = net.settle_checks();
        assert_eq!(second.len(), 1, "only the touched component resettles");
        assert_eq!(net.comp_count(), 2);
        assert_eq!(net.rate_each(b), rate_b, "unrelated component keeps its rate");
        assert_eq!(net.rate_each(a), 0.5 * GB);
        assert_eq!(net.rate_each(c), 0.5 * GB);

        // Drive to completion through the check API. a's pre-merge
        // component died in the merge: its check is stale. b's is not.
        net.advance(Duration::from_secs(2));
        let (a_old, b_comp) = (first[0].comp, first[1].comp);
        assert!(net.check(a_old).is_empty(), "pre-merge check must be stale");
        let drained_b = net.check(b_comp);
        assert_eq!(drained_b, vec![b]);
        net.complete(b);
        let merged = second[0].comp;
        let drained_ac = net.check(merged);
        assert_eq!(drained_ac, vec![a, c]);
    }

    #[test]
    fn check_on_stale_component_is_empty() {
        let mut net = FlowNet::with_mode(ThroughputMode::Fast);
        let l = net.add_link("l", Capacity::Fixed(GB));
        let a = net.start(vec![l], 1, GB as u64);
        let checks = net.settle_checks();
        assert_eq!(checks.len(), 1);
        // Another start on the same link invalidates the component.
        net.start(vec![l], 1, GB as u64);
        let _ = net.settle_checks();
        net.advance(Duration::from_secs(10));
        assert!(net.check(checks[0].comp).is_empty(), "stale check must be ignored");
        assert!(!net.is_done(a), "stale check completed nothing");
    }

    #[test]
    fn premature_check_reschedules() {
        both(|mut net| {
            let l = net.add_link("l", Capacity::Fixed(GB));
            net.start(vec![l], 1, 10 * GB as u64);
            let checks = net.settle_checks();
            assert_eq!(checks.len(), 1);
            // Fire the check well before the flow drains.
            net.advance(Duration::from_secs(1));
            assert!(net.check(checks[0].comp).is_empty());
            // The component was re-dirtied: a settle produces a fresh
            // check with a fresh id.
            assert!(net.is_dirty());
            let again = net.settle_checks();
            assert_eq!(again.len(), 1);
            assert_ne!(again[0].comp, checks[0].comp);
        });
    }

    #[test]
    fn slow_mode_has_single_global_component() {
        let mut net = FlowNet::with_mode(ThroughputMode::Slow);
        let l1 = net.add_link("1", Capacity::Fixed(GB));
        let l2 = net.add_link("2", Capacity::Fixed(GB));
        net.start(vec![l1], 1, GB as u64);
        net.start(vec![l2], 1, GB as u64);
        let checks = net.settle_checks();
        assert_eq!(net.comp_count(), 1);
        assert_eq!(checks.len(), 1);
    }

    #[test]
    fn force_recompute_preserves_rates() {
        both(|mut net| {
            let l = net.add_link("l", Capacity::Fixed(10.0 * GB));
            let a = net.start(vec![l], 1, GB as u64);
            let b = net.start(vec![l], 1, GB as u64);
            net.recompute();
            let (ra, rb) = (net.rate_each(a), net.rate_each(b));
            net.force_recompute();
            assert_eq!(net.rate_each(a), ra);
            assert_eq!(net.rate_each(b), rb);
        });
    }

    #[test]
    fn instantaneous_flows_drain_via_check() {
        both(|mut net| {
            // Infinite-rate pathless flow: its component's check fires
            // immediately and reports it drained — no repeated zero-ETA
            // polling (the seed's FlowCheck re-report bug).
            let f = net.start(vec![], 4, 1 << 30);
            let checks = net.settle_checks();
            assert_eq!(checks.len(), 1);
            assert_eq!(checks[0].at, SimTime::ZERO);
            let drained = net.check(checks[0].comp);
            assert_eq!(drained, vec![f]);
            net.complete(f);
            assert_eq!(net.active_count(), 0);
        });
    }
}
