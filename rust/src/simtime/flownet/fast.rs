//! The incremental throughput model: component-scoped recompute.
//!
//! Active flows partition into *connected components* of the
//! flow/link sharing graph (two flows are adjacent when they traverse
//! a common link). Max-min fairness decomposes exactly across
//! components — no capacity or stream count crosses a component
//! boundary — so a start or completion only perturbs the components
//! it touches. This model maintains that partition and recomputes
//! only dirty components:
//!
//! - **start**: the new flow may glue several components together;
//!   every component overlapping its links is invalidated and its
//!   members marked dirty, along with the new flow.
//! - **complete**: the departing flow's component is invalidated (its
//!   remainder may both change rates and split).
//! - **settle**: flood-fill from each dirty flow over the link
//!   membership lists rebuilds exact components for the dirty region
//!   only; each gets a fresh never-reused id, synced members, rates
//!   from the shared water-filling pass, and one completion check.
//!
//! Components never reached by the flood fill keep their ids, rates,
//! and scheduled checks — the heap entries of untouched components
//! are *never* invalidated, which is what turns the seed's
//! O(total activity) cost per event into O(dirty component).
//!
//! Invariants (`DESIGN.md` §throughput-model):
//!   I1 settled active flows are exactly partitioned by live comps;
//!   I2 every link-neighbour of a dirty flow is dirty (kills are
//!      transitive through overlap at invalidation time);
//!   I3 comp ids are never reused; a check naming a dead id is stale;
//!   I4 `remaining_each` is valid as of `synced_at` and linear in
//!      between settles.

use std::collections::BTreeMap;

use crate::units::{Duration, SimTime};

use super::model::{CompCheck, ThroughputModel};
use super::state::NetState;
use super::{CompId, FlowId, LinkId, ThroughputMode};

#[derive(Debug)]
struct Comp {
    /// Sorted member list (canonical water-filling order).
    members: Vec<FlowId>,
    /// Earliest completion among members as of the building settle.
    next: Option<(SimTime, FlowId)>,
}

#[derive(Debug)]
pub(crate) struct FastModel {
    /// Live components by id. BTreeMap: deterministic iteration for
    /// the global next-completion query.
    comps: BTreeMap<u64, Comp>,
    /// Never-reused id source (0 is `CompId::NONE`).
    next_comp: u64,
    /// Flows awaiting recompute (their comps already invalidated).
    dirty: Vec<FlowId>,
    /// Flood-fill visit stamp, bumped once per settle.
    round: u64,
    /// Ids retired since the last [`ThroughputModel::drain_retired`]:
    /// each id lands here exactly once, at the kill/absorb that
    /// removed it from `comps`.
    retired: Vec<u64>,
}

impl FastModel {
    pub(crate) fn new() -> FastModel {
        FastModel {
            comps: BTreeMap::new(),
            next_comp: 1,
            dirty: Vec::new(),
            round: 0,
            retired: Vec::new(),
        }
    }

    fn mark_dirty(&mut self, st: &mut NetState, id: FlowId) {
        if let Some(f) = st.flow_mut(id) {
            if !f.dirty {
                f.dirty = true;
                self.dirty.push(id);
            }
        }
    }

    /// Remove `comp` and mark its members (minus `except`) dirty.
    fn kill(&mut self, st: &mut NetState, comp: CompId, except: Option<FlowId>) {
        let Some(c) = self.comps.remove(&comp.0) else { return };
        self.retired.push(comp.0);
        for m in c.members {
            if Some(m) == except {
                continue;
            }
            if let Some(f) = st.flow_mut(m) {
                f.comp = CompId::NONE;
            } else {
                continue;
            }
            self.mark_dirty(st, m);
        }
    }
}

impl ThroughputModel for FastModel {
    fn mode(&self) -> ThroughputMode {
        ThroughputMode::Fast
    }

    fn on_start(&mut self, st: &mut NetState, id: FlowId) {
        // Invalidate every component sharing a link with the new flow:
        // the start may merge them and changes their rates.
        let mut kills: Vec<u64> = Vec::new();
        {
            let idx = id.idx();
            for pi in 0..st.slots[idx].flow.path.len() {
                let LinkId(l) = st.slots[idx].flow.path[pi];
                for &(fid, _) in &st.links[l].members {
                    if fid == id {
                        continue;
                    }
                    let c = st.slots[fid.idx()].flow.comp;
                    if c != CompId::NONE {
                        kills.push(c.0);
                    }
                }
            }
        }
        // One kill per unique component (a busy link lists every
        // member flow, all sharing the same comp id).
        kills.sort_unstable();
        kills.dedup();
        for c in kills {
            self.kill(st, CompId(c), None);
        }
        self.mark_dirty(st, id);
    }

    fn on_complete(&mut self, st: &mut NetState, id: FlowId) {
        let comp = match st.flow(id) {
            Some(f) => f.comp,
            None => return,
        };
        if comp != CompId::NONE {
            // The remainder of the component changes rates (and may
            // split into several); recompute all of it.
            self.kill(st, comp, Some(id));
        }
        // If `id` was only dirty (never settled), the dirty entry goes
        // stale with the slot generation — settle skips it.
    }

    fn dirty_comp(&mut self, st: &mut NetState, comp: CompId) {
        self.kill(st, comp, None);
    }

    fn invalidate_all(&mut self, st: &mut NetState) {
        let comps: Vec<u64> = self.comps.keys().copied().collect();
        for c in comps {
            self.kill(st, CompId(c), None);
        }
        // Flows started but never settled are already in the dirty list.
    }

    fn is_dirty(&self) -> bool {
        !self.dirty.is_empty()
    }

    fn settle(&mut self, st: &mut NetState, out: &mut Vec<CompCheck>) {
        if self.dirty.is_empty() {
            return;
        }
        self.round += 1;
        let round = self.round;
        let seeds = std::mem::take(&mut self.dirty);
        let mut stack: Vec<FlowId> = Vec::new();
        for seed in seeds {
            match st.flow(seed) {
                // Completed before this settle, or already absorbed
                // into a component rebuilt earlier this round.
                None => continue,
                Some(f) if !f.dirty => continue,
                Some(_) => {}
            }
            // Flood-fill the connected component containing `seed`.
            let mut members: Vec<FlowId> = Vec::new();
            st.slots[seed.idx()].flow.visit = round;
            stack.push(seed);
            while let Some(fid) = stack.pop() {
                members.push(fid);
                // Live component reached through a shared link: only
                // possible when a hierarchical split left sibling
                // components sharing hub links (I2 covers everything
                // else). Retire it so its members aren't double-owned;
                // its scheduled check goes stale with the dead id.
                let c = st.slots[fid.idx()].flow.comp;
                if c != CompId::NONE {
                    if self.comps.remove(&c.0).is_some() {
                        self.retired.push(c.0);
                    }
                    st.slots[fid.idx()].flow.comp = CompId::NONE;
                }
                let fidx = fid.idx();
                for pi in 0..st.slots[fidx].flow.path.len() {
                    let LinkId(l) = st.slots[fidx].flow.path[pi];
                    for mi in 0..st.links[l].members.len() {
                        let (nid, _) = st.links[l].members[mi];
                        if st.slots[nid.idx()].flow.visit != round {
                            st.slots[nid.idx()].flow.visit = round;
                            stack.push(nid);
                        }
                    }
                }
            }
            members.sort();
            // Giant components settle hierarchically when the spoke /
            // hub structure allows an exact split (see `hier`); the
            // flat pass below is the fallback and the only path for
            // ordinary-sized components.
            if let Some(groups) = super::hier::try_split(st, &members, &mut self.round) {
                for g in groups {
                    let cid = self.next_comp;
                    self.next_comp += 1;
                    for &m in &g {
                        let f = &mut st.slots[m.idx()].flow;
                        f.comp = CompId(cid);
                        f.dirty = false;
                    }
                    let next = super::hier::finish_group(st, &g, CompId(cid), out);
                    self.comps.insert(cid, Comp { members: g, next });
                }
                continue;
            }
            let cid = self.next_comp;
            self.next_comp += 1;
            for &m in &members {
                let f = &mut st.slots[m.idx()].flow;
                f.comp = CompId(cid);
                f.dirty = false;
            }
            let next = super::model::settle_component(st, &members, CompId(cid), out);
            self.comps.insert(cid, Comp { members, next });
        }
    }

    fn comp_members(&self, comp: CompId) -> Option<&[FlowId]> {
        self.comps.get(&comp.0).map(|c| &c.members[..])
    }

    fn drain_retired(&mut self, out: &mut Vec<u64>) {
        out.append(&mut self.retired);
    }

    fn comp_count(&self) -> usize {
        self.comps.len()
    }

    fn next_completion(&self, st: &NetState) -> Option<(Duration, FlowId)> {
        let mut best: Option<(SimTime, FlowId)> = None;
        for c in self.comps.values() {
            if let Some((at, id)) = c.next {
                if best.map_or(true, |(t, _)| at < t) {
                    best = Some((at, id));
                }
            }
        }
        best.map(|(at, id)| (at - st.now, id))
    }
}
