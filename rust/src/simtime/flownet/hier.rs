//! Hierarchical settling for giant components.
//!
//! At fleet scale one component can span the whole machine: thousands
//! of node-layer flows (cluster spokes) all touching a handful of
//! shared filesystem-side links (hubs). The flat water-filling pass is
//! O(touched links × freeze rounds); with thousands of independent
//! spoke bottlenecks the freeze rounds grow with the spoke count and
//! the settle goes quadratic. This module restores linearity by
//! splitting the settle *when doing so is provably exact*:
//!
//! 1. partition the component's flows into **spoke groups** — the
//!    connected components of the sharing graph with hub-class links
//!    ([`hub_class`]: the facility-wide Backplane/Disk/Meta/Wan/
//!    Beamline layers) removed;
//! 2. water-fill each group independently with hub links excluded
//!    ([`super::waterfill::assign_rates_filtered`]);
//! 3. verify every hub link has **strict slack** under the combined
//!    group rates (with its degrading capacity evaluated at the full
//!    stream count).
//!
//! When step 3 holds, the per-group allocation *is* the global max-min
//! allocation: every flow is frozen either at its rate cap or at a
//! saturated group-internal link (spoke groups share no non-hub link
//! by construction, so each group's saturated bottlenecks stay
//! saturated globally), and the slack hubs impose no constraint. The
//! max-min allocation is unique, so the split is exact — the slow
//! oracle differential suite holds within the existing FP tolerance.
//! If any hub binds (or any rate is non-finite), the split is
//! abandoned before anything observable changes and the caller falls
//! back to the flat settle, so behaviour is conservative by
//! construction.
//!
//! Components smaller than [`GIANT_COMPONENT_MIN`] never attempt the
//! split: for them the flat pass is already cheap, and keeping the
//! gate above every workload the differential suites replay makes the
//! fast model byte-identical to its pre-hierarchical behaviour there.
//!
//! One structural consequence: sibling groups settled this way hold
//! distinct component ids while *sharing* hub links, so a later
//! flood-fill (e.g. after one sibling's flow completes) can reach live
//! sibling components through a hub. The fast model's settle absorbs
//! such components on contact — see the stale-comp removal in
//! `fast::FastModel::settle`.

use super::model::CompCheck;
use super::state::{eta_secs, NetState};
use super::{CompId, FlowId, LinkClass, LinkId};
use crate::units::{Duration, SimTime};

/// Flow-count threshold below which a component settles flat. All
/// pre-fleet workloads sit far below it, so the hierarchical path is
/// provably dormant for them.
pub(crate) const GIANT_COMPONENT_MIN: usize = 256;

/// True for the shared facility-wide link layers a fleet-spanning
/// component funnels through; false for the per-node / cluster layers
/// that partition into spoke groups. The beamline ingest pipe is a
/// hub for the same reason the WAN is: one shared facility-wide link
/// every detector stream funnels through.
pub(crate) fn hub_class(c: LinkClass) -> bool {
    matches!(
        c,
        LinkClass::Backplane
            | LinkClass::Disk
            | LinkClass::Meta
            | LinkClass::Wan
            | LinkClass::Beamline
    )
}

/// Attempt the hierarchical settle of one component (`members`:
/// sorted, live, synced-or-stale — this function syncs before touching
/// rates). On success the members' rates are the exact global max-min
/// rates and the sorted spoke groups are returned for the caller to
/// register as separate components. On `None` nothing observable
/// changed (any partially-written rates are recomputed by the caller's
/// flat pass over the same synced state).
///
/// `round` is the fast model's flood-fill stamp source; it is bumped
/// once so this fill cannot collide with the caller's.
pub(crate) fn try_split(
    st: &mut NetState,
    members: &[FlowId],
    round: &mut u64,
) -> Option<Vec<Vec<FlowId>>> {
    if members.len() < GIANT_COMPONENT_MIN {
        return None;
    }
    *round += 1;
    let r = *round;

    // Spoke groups: flood-fill over non-hub links only. Seeding in
    // sorted member order with sorted group output keeps everything
    // downstream deterministic.
    let mut groups: Vec<Vec<FlowId>> = Vec::new();
    let mut stack: Vec<FlowId> = Vec::new();
    for &seed in members {
        if st.slots[seed.idx()].flow.visit == r {
            continue;
        }
        st.slots[seed.idx()].flow.visit = r;
        stack.push(seed);
        let mut g: Vec<FlowId> = Vec::new();
        while let Some(fid) = stack.pop() {
            g.push(fid);
            let fidx = fid.idx();
            for pi in 0..st.slots[fidx].flow.path.len() {
                let LinkId(l) = st.slots[fidx].flow.path[pi];
                if hub_class(st.links[l].class) {
                    continue;
                }
                for mi in 0..st.links[l].members.len() {
                    let (nid, _) = st.links[l].members[mi];
                    if st.slots[nid.idx()].flow.visit != r {
                        st.slots[nid.idx()].flow.visit = r;
                        stack.push(nid);
                    }
                }
            }
        }
        g.sort();
        groups.push(g);
    }
    if groups.len() < 2 {
        // Hub removal didn't disconnect anything; a split buys nothing.
        return None;
    }

    // Materialise progress at the old rates, then water-fill each
    // group with the hubs excluded.
    for &m in members {
        st.sync_flow(m);
    }
    for g in &groups {
        super::waterfill::assign_rates_filtered(st, g, Some(hub_class));
    }

    // Exactness condition: strict slack on every hub link under the
    // combined rates. (A component's links carry only the component's
    // own flows, so the link member lists are exactly the loads.)
    let mut hubs: Vec<usize> = Vec::new();
    for &m in members {
        for &LinkId(l) in &st.slots[m.idx()].flow.path {
            if hub_class(st.links[l].class) {
                hubs.push(l);
            }
        }
    }
    hubs.sort_unstable();
    hubs.dedup();
    for &l in &hubs {
        let mut load = 0.0f64;
        let mut streams = 0.0f64;
        for &(fid, _) in &st.links[l].members {
            let f = &st.slots[fid.idx()].flow;
            load += f.rate_each * f.members as f64;
            streams += f.members as f64;
        }
        let cap = st.links[l].cap.effective(streams);
        // Written so NaN or infinite load also falls back to flat.
        if !(load <= (1.0 - 1e-9) * cap) {
            return None;
        }
    }
    Some(groups)
}

/// The settle epilogue for one already-rated spoke group: fold the
/// earliest completion (ties to the first member in sorted order, like
/// `model::settle_component`) and emit the group's check. Rates were
/// assigned by [`try_split`]; nothing is recomputed here.
pub(crate) fn finish_group(
    st: &NetState,
    members: &[FlowId],
    comp: CompId,
    out: &mut Vec<CompCheck>,
) -> Option<(SimTime, FlowId)> {
    let now = st.now;
    let mut next: Option<(SimTime, FlowId)> = None;
    for &m in members {
        let f = &st.slots[m.idx()].flow;
        if let Some(e) = eta_secs(f) {
            let at = now + Duration::from_secs_f64(e);
            if next.map_or(true, |(t, _)| at < t) {
                next = Some((at, m));
            }
        }
    }
    if let Some((at, _)) = next {
        out.push(CompCheck { comp, at });
    }
    next
}

#[cfg(test)]
mod tests {
    use super::super::{Capacity, FlowNet, LinkClass, ThroughputMode};
    use super::*;

    /// Drive `net` to empty one completion at a time; returns the
    /// per-flow completion times in completion order.
    fn run_to_empty(net: &mut FlowNet) -> Vec<(FlowId, SimTime)> {
        let mut now = SimTime::ZERO;
        let mut done = Vec::new();
        net.recompute();
        while let Some((t, id)) = net.next_completion(now) {
            net.advance(t - now);
            now = t;
            net.complete(id);
            done.push((id, now));
            net.recompute();
        }
        assert_eq!(net.active_count(), 0, "flows starved");
        done
    }

    /// A fleet-shaped net: `n` spoke links (cluster layer) feeding one
    /// hub link (filesystem layer), one flow per spoke crossing both.
    /// Returns the flow ids in spoke order.
    fn hub_and_spoke(net: &mut FlowNet, n: usize, hub_cap: f64) -> Vec<FlowId> {
        let hub = net.add_link_classed("hub", Capacity::Fixed(hub_cap), LinkClass::Backplane);
        (0..n)
            .map(|i| {
                let spoke = net.add_link_classed(
                    format!("spoke{i}"),
                    Capacity::Fixed(100.0),
                    LinkClass::Ion,
                );
                // Distinct byte counts -> distinct completion times ->
                // a model-independent completion order.
                net.start(vec![spoke, hub], 1, 10_000 + 7 * i as u64)
            })
            .collect()
    }

    #[test]
    fn giant_hub_and_spoke_splits_and_matches_oracle() {
        // 300 spokes ≥ GIANT_COMPONENT_MIN, hub with ample slack
        // (300 × 100 < 1e6): the fast model must split into one
        // component per spoke and agree with the slow oracle on every
        // rate and completion time.
        let n = 300;
        assert!(n >= GIANT_COMPONENT_MIN);
        let mut fast = FlowNet::with_mode(ThroughputMode::Fast);
        let ff = hub_and_spoke(&mut fast, n, 1e6);
        let mut slow = FlowNet::with_mode(ThroughputMode::Slow);
        let sf = hub_and_spoke(&mut slow, n, 1e6);

        fast.recompute();
        slow.recompute();
        assert_eq!(fast.comp_count(), n, "hierarchical settle must split per spoke");
        assert_eq!(slow.comp_count(), 1);
        for (a, b) in ff.iter().zip(&sf) {
            let (ra, rb) = (fast.rate_each(*a), slow.rate_each(*b));
            assert!((ra - rb).abs() < 1e-6, "rate diverged: fast {ra} slow {rb}");
            assert!((ra - 100.0).abs() < 1e-6, "spoke-bound rate expected, got {ra}");
        }

        // Churn to empty: every completion re-floods and re-splits the
        // remainder (exercising the sibling-absorption path); the two
        // models must complete the same flows at the same times.
        let fd = run_to_empty(&mut fast);
        let sd = run_to_empty(&mut slow);
        assert_eq!(fd.len(), n);
        assert_eq!(fd.len(), sd.len());
        for ((fa, ta), (fb, tb)) in fd.iter().zip(&sd) {
            // Flow ids are allocation-order identical across the nets.
            assert_eq!(fa, fb);
            let dt = (ta.secs_f64() - tb.secs_f64()).abs();
            assert!(dt < 1e-6, "completion diverged: {ta:?} vs {tb:?}");
        }
    }

    #[test]
    fn binding_hub_falls_back_to_flat_settle() {
        // Hub capacity far below the spoke aggregate: no slack, so the
        // split must be rejected and the flat (exact) pass used — one
        // component, hub-fair rates, still matching the oracle.
        let n = 300;
        let mut fast = FlowNet::with_mode(ThroughputMode::Fast);
        let ff = hub_and_spoke(&mut fast, n, 3_000.0);
        let mut slow = FlowNet::with_mode(ThroughputMode::Slow);
        let sf = hub_and_spoke(&mut slow, n, 3_000.0);
        fast.recompute();
        slow.recompute();
        assert_eq!(fast.comp_count(), 1, "binding hub must keep one component");
        for (a, b) in ff.iter().zip(&sf) {
            let (ra, rb) = (fast.rate_each(*a), slow.rate_each(*b));
            assert!((ra - rb).abs() < 1e-6, "rate diverged: fast {ra} slow {rb}");
            assert!((ra - 10.0).abs() < 1e-6, "hub share expected, got {ra}");
        }
    }

    #[test]
    fn small_components_never_split() {
        // Below the gate the hierarchical path must be dormant even on
        // a perfectly splittable topology: one component, as before.
        let n = 10;
        let mut net = FlowNet::with_mode(ThroughputMode::Fast);
        hub_and_spoke(&mut net, n, 1e6);
        net.recompute();
        assert_eq!(net.comp_count(), 1);
    }

    #[test]
    fn start_on_a_spoke_reabsorbs_siblings() {
        // After a split, a start touching only one spoke dirties that
        // spoke's component; the resettle flood-fill then reaches every
        // sibling *through the hub* and must absorb their live
        // components before re-splitting — the I2 exception the fast
        // settle handles explicitly. Differential against the oracle
        // through the whole churn.
        let n = 300;
        let run = |mode: ThroughputMode| {
            let mut net = FlowNet::with_mode(mode);
            hub_and_spoke(&mut net, n, 1e6);
            net.recompute();
            // Link ids: hub is 0, spoke i is i+1.
            net.start(vec![LinkId(1 + 17)], 1, 4_242);
            net.recompute();
            run_to_empty(&mut net)
        };
        let fd = run(ThroughputMode::Fast);
        let sd = run(ThroughputMode::Slow);
        assert_eq!(fd.len(), n + 1);
        assert_eq!(fd.len(), sd.len());
        for ((fa, ta), (fb, tb)) in fd.iter().zip(&sd) {
            assert_eq!(fa, fb);
            let dt = (ta.secs_f64() - tb.secs_f64()).abs();
            assert!(dt < 1e-6, "completion diverged: {ta:?} vs {tb:?}");
        }
    }

    #[test]
    fn hub_only_flows_group_alone() {
        // A flow whose entire path is hub-class joins no spoke group;
        // it settles as its own singleton with its cap honoured (the
        // filtered water-fill treats it as pathless, the hub slack
        // check still bounds it).
        let n = GIANT_COMPONENT_MIN;
        let mut net = FlowNet::with_mode(ThroughputMode::Fast);
        let flows = hub_and_spoke(&mut net, n, 1e9);
        let hub_only = net.start_capped(
            vec![super::super::LinkId(0)], // the hub link
            1,
            1_000_000,
            50.0,
        );
        net.recompute();
        assert_eq!(net.comp_count(), n + 1);
        assert_eq!(net.rate_each(hub_only), 50.0);
        assert!((net.rate_each(flows[0]) - 100.0).abs() < 1e-6);
    }
}
