//! Plan DAGs: the unit of work the engine executes under contention.
//!
//! An MPI collective, a staging hook invocation, or a cross-lab
//! transfer is expressed as a [`Plan`]: a DAG of primitive [`Step`]s
//! (flow-network transfers, fixed delays, instantaneous data-plane
//! effects). Plans are *pure data* built by plan-builder functions in
//! `mpisim`/`staging`/`transfer`, which makes the collective algorithms
//! unit-testable without running the clock: tests assert on the DAG
//! shape (who reads which stripe, how many rounds the broadcast tree
//! has) and then on the simulated durations.

use std::sync::Arc;

use crate::pfs::Blob;
use crate::simtime::flownet::LinkId;
use crate::units::Duration;

/// Identifies a plan registered with the engine.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct PlanId(pub usize);

/// Identifies a step within its plan.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct StepId(pub usize);

/// Instantaneous data-plane side effect, applied when the step fires.
#[derive(Clone, Debug)]
pub enum Effect {
    /// Create/overwrite a file in the shared parallel filesystem.
    PfsWrite { path: String, data: Blob },
    /// Replicate a file into the node-local stores of `nodes`
    /// (inclusive range) — the RAM-disk write of the staging hook.
    NodeWrite { nodes: (u32, u32), path: String, data: Blob },
    /// Promote a replica from the SSD tier into RAM on `nodes`
    /// (inclusive range) — the data-plane half of the cheap re-stage
    /// path; the timed half is the SSD-link flow it depends on.
    NodePromote { nodes: (u32, u32), path: String },
    /// Deliver an opaque tag to the director (progress notification).
    Notify(u64),
}

/// A primitive unit of simulated work.
#[derive(Clone, Debug)]
pub enum Step {
    /// A bundle of `members` identical transfers of `bytes_each` over
    /// `path`, optionally rate-capped per member (e.g. a torus
    /// injection port or a per-process RAM-disk stream).
    Flow {
        path: Vec<LinkId>,
        members: u64,
        bytes_each: u64,
        cap_each: f64,
    },
    /// A fixed virtual-time delay (compute, service latency).
    Delay(Duration),
    /// An instantaneous side effect.
    Effect(Effect),
}

/// One node of the plan DAG.
#[derive(Clone, Debug)]
pub struct PlanStep {
    pub step: Step,
    pub deps: Vec<StepId>,
    /// Label for metrics/phase attribution (e.g. "staging", "write").
    pub label: &'static str,
}

/// A DAG of steps. Executed by `engine::SimCore`; completion is
/// reported to the director with `tag`.
#[derive(Clone, Debug, Default)]
pub struct Plan {
    pub steps: Vec<PlanStep>,
    pub tag: u64,
}

impl Plan {
    pub fn new(tag: u64) -> Self {
        Plan { steps: Vec::new(), tag }
    }

    /// Append a step depending on `deps`; returns its id.
    pub fn add(&mut self, step: Step, deps: Vec<StepId>, label: &'static str) -> StepId {
        for d in &deps {
            assert!(d.0 < self.steps.len(), "forward dep {d:?}");
        }
        self.steps.push(PlanStep { step, deps, label });
        StepId(self.steps.len() - 1)
    }

    /// Convenience: uncapped flow step.
    pub fn flow(
        &mut self,
        path: Vec<LinkId>,
        members: u64,
        bytes_each: u64,
        deps: Vec<StepId>,
        label: &'static str,
    ) -> StepId {
        self.add(
            Step::Flow { path, members, bytes_each, cap_each: f64::INFINITY },
            deps,
            label,
        )
    }

    /// Convenience: per-member rate-capped flow step.
    pub fn flow_capped(
        &mut self,
        path: Vec<LinkId>,
        members: u64,
        bytes_each: u64,
        cap_each: f64,
        deps: Vec<StepId>,
        label: &'static str,
    ) -> StepId {
        self.add(Step::Flow { path, members, bytes_each, cap_each }, deps, label)
    }

    pub fn delay(&mut self, dur: Duration, deps: Vec<StepId>, label: &'static str) -> StepId {
        self.add(Step::Delay(dur), deps, label)
    }

    pub fn effect(&mut self, e: Effect, deps: Vec<StepId>, label: &'static str) -> StepId {
        self.add(Step::Effect(e), deps, label)
    }

    /// A barrier step depending on everything currently in the plan.
    pub fn barrier(&mut self, label: &'static str) -> StepId {
        let deps: Vec<StepId> = (0..self.steps.len()).map(StepId).collect();
        self.delay(Duration::ZERO, deps, label)
    }

    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    /// Total bytes moved by all flow steps (members * bytes_each).
    pub fn total_flow_bytes(&self) -> u64 {
        self.steps
            .iter()
            .map(|s| match &s.step {
                Step::Flow { members, bytes_each, .. } => members * bytes_each,
                _ => 0,
            })
            .sum()
    }

    /// Steps with a given label (for tests/metrics).
    pub fn steps_labeled(&self, label: &str) -> Vec<StepId> {
        self.steps
            .iter()
            .enumerate()
            .filter(|(_, s)| s.label == label)
            .map(|(i, _)| StepId(i))
            .collect()
    }
}

/// Helper for building `Effect::NodeWrite` blobs.
pub fn real_blob(data: Vec<u8>) -> Blob {
    Blob::Real(Arc::new(data))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_tracks_deps() {
        let mut p = Plan::new(7);
        let a = p.delay(Duration::from_secs(1), vec![], "a");
        let b = p.delay(Duration::from_secs(2), vec![a], "b");
        let c = p.barrier("c");
        assert_eq!(p.len(), 3);
        assert_eq!(p.steps[b.0].deps, vec![a]);
        assert_eq!(p.steps[c.0].deps, vec![a, b]);
        assert_eq!(p.tag, 7);
    }

    #[test]
    #[should_panic(expected = "forward dep")]
    fn forward_dep_panics() {
        let mut p = Plan::new(0);
        p.delay(Duration::ZERO, vec![StepId(3)], "bad");
    }

    #[test]
    fn total_flow_bytes_counts_members() {
        let mut p = Plan::new(0);
        p.flow(vec![], 8, 100, vec![], "x");
        p.flow(vec![], 1, 42, vec![], "y");
        p.delay(Duration::ZERO, vec![], "z");
        assert_eq!(p.total_flow_bytes(), 842);
    }

    #[test]
    fn steps_labeled_filters() {
        let mut p = Plan::new(0);
        p.delay(Duration::ZERO, vec![], "stage");
        p.delay(Duration::ZERO, vec![], "write");
        p.delay(Duration::ZERO, vec![], "stage");
        assert_eq!(p.steps_labeled("stage").len(), 2);
        assert_eq!(p.steps_labeled("write"), vec![StepId(1)]);
    }
}
