//! Discrete-event simulation substrate.
//!
//! Three building blocks, each independently tested:
//!
//! - [`heap`]: a deterministic event heap (ties broken by sequence
//!   number, so identical runs replay identically).
//! - [`flownet`]: a flow-level network model with **max-min fair
//!   sharing** over capacity-constrained links. All bandwidth-shaped
//!   behaviour in the simulation (GPFS servers, BG/Q I/O-node uplinks,
//!   torus links, NFS, WAN) is expressed as links; concurrent
//!   transfers are *flow bundles* (N identical members) so that
//!   8,192-node collectives cost O(bundles), not O(nodes), per
//!   recompute. Rate maintenance is pluggable behind the
//!   [`flownet::ThroughputModel`] boundary: a slow global reference
//!   pass and the default fast component-incremental pass (see
//!   `DESIGN.md`).
//! - [`plan`]: static DAGs of primitive steps (flow / delay / effect)
//!   used by the MPI collectives and the staging hook; the engine
//!   executes them with dependency ordering under contention.

pub mod flownet;
pub mod heap;
pub mod plan;

pub use flownet::{Capacity, CompId, FlowId, FlowNet, LinkClass, LinkId, ThroughputMode};
pub use heap::EventHeap;
pub use plan::{Effect, Plan, PlanId, Step, StepId};
