//! Deterministic event heap.
//!
//! A thin wrapper over `BinaryHeap` that (a) orders by time, (b) breaks
//! ties by insertion sequence, so simulation runs are bit-reproducible
//! regardless of hash-map iteration order upstream, and (c) supports
//! *logical cancellation*: events carry an identity that is checked
//! against current state when they fire (the engine's flow-completion
//! checks name a network component whose id is never reused — a check
//! for an invalidated component is simply ignored on pop, so nothing
//! is ever removed from the middle of the heap).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::units::SimTime;

/// An entry in the heap: fires `event` at `time`.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
struct Entry<E> {
    time: SimTime,
    seq: u64,
    event: E,
}

/// Deterministic min-heap of timed events.
#[derive(Debug)]
pub struct EventHeap<E> {
    heap: BinaryHeap<Reverse<Entry<E>>>,
    seq: u64,
}

impl<E: Ord + Copy> EventHeap<E> {
    pub fn new() -> Self {
        EventHeap { heap: BinaryHeap::new(), seq: 0 }
    }

    /// Schedule `event` at absolute virtual time `time`.
    pub fn push(&mut self, time: SimTime, event: E) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(Entry { time, seq, event }));
    }

    /// Pop the earliest event, if any.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        self.heap.pop().map(|Reverse(e)| (e.time, e.event))
    }

    /// Time of the earliest pending event.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|Reverse(e)| e.time)
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

impl<E: Ord + Copy> Default for EventHeap<E> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::units::Duration;

    #[test]
    fn orders_by_time() {
        let mut h = EventHeap::new();
        h.push(SimTime(30), 3u32);
        h.push(SimTime(10), 1);
        h.push(SimTime(20), 2);
        assert_eq!(h.pop(), Some((SimTime(10), 1)));
        assert_eq!(h.pop(), Some((SimTime(20), 2)));
        assert_eq!(h.pop(), Some((SimTime(30), 3)));
        assert_eq!(h.pop(), None);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut h = EventHeap::new();
        let t = SimTime::ZERO + Duration::from_secs(1);
        h.push(t, 7u32);
        h.push(t, 3);
        h.push(t, 9);
        assert_eq!(h.pop().unwrap().1, 7);
        assert_eq!(h.pop().unwrap().1, 3);
        assert_eq!(h.pop().unwrap().1, 9);
    }

    #[test]
    fn peek_does_not_consume() {
        let mut h = EventHeap::new();
        h.push(SimTime(5), 1u8);
        assert_eq!(h.peek_time(), Some(SimTime(5)));
        assert_eq!(h.len(), 1);
        assert!(!h.is_empty());
    }

    #[test]
    fn interleaved_push_pop() {
        let mut h = EventHeap::new();
        h.push(SimTime(10), 1u32);
        h.push(SimTime(5), 0);
        assert_eq!(h.pop().unwrap().1, 0);
        h.push(SimTime(7), 2);
        assert_eq!(h.pop().unwrap().1, 2);
        assert_eq!(h.pop().unwrap().1, 1);
    }
}
