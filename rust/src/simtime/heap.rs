//! Deterministic event heap: a two-level bucketed timer wheel.
//!
//! The heap (a) orders by time, (b) breaks ties by insertion sequence,
//! so simulation runs are bit-reproducible regardless of hash-map
//! iteration order upstream, and (c) supports two kinds of
//! cancellation:
//!
//! - *logical* — events carry an identity that is checked against
//!   current state when they fire (the engine's flow-completion checks
//!   name a network component whose id is never reused; a check for an
//!   invalidated component is simply ignored on pop), and
//! - *eager* — [`EventHeap::cancel`] removes a still-pending entry by
//!   its `(time, seq)` coordinates, so churn-heavy runs (chaos kills,
//!   elastic re-settles) reclaim stale timers instead of carrying them
//!   to their pop.
//!
//! Two backends sit behind one API, selected by [`HeapKind`]:
//!
//! - [`HeapKind::Seed`] — the original thin `BinaryHeap` wrapper,
//!   kept as the differential baseline (`tests/property_kernel.rs`
//!   drives both backends in lockstep and `benches/kernel.rs` measures
//!   the wheel against it).
//! - [`HeapKind::Wheel`] (default) — a two-level bucketed timer wheel:
//!   a 1024-bucket near-future wheel of 2^26 ns (~67 ms) ticks
//!   (~68.7 s horizon) plus a far-future overflow heap. Pops within
//!   the current tick drain a sorted run; bucket occupancy is a
//!   bitmap, so advancing to the next armed tick is a word scan, not
//!   a sift. The wheel relies on the engine's monotone contract —
//!   every push is at or after the last popped time — which holds by
//!   construction (`SimCore` asserts `t >= now` on every pop and every
//!   schedule).
//!
//! Pop order is identical across backends by a total order argument:
//! both pop strictly ascending `(time, seq)`, and `(time, seq)` is
//! unique per entry (`seq` is a monotone counter), so the sequence of
//! live entries popped is the same regardless of internal layout.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashSet};

use crate::units::SimTime;

/// Bucket granularity: one wheel tick is 2^26 ns (~67 ms).
const GRAN_BITS: u32 = 26;
/// 2^10 = 1024 buckets: the wheel covers ~68.7 s of virtual time.
const WHEEL_BITS: u32 = 10;
const BUCKETS: usize = 1 << WHEEL_BITS;
const TICK_MASK: u64 = (BUCKETS as u64) - 1;

/// Which event-heap backend a simulation core runs on.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub enum HeapKind {
    /// The seed `BinaryHeap` wrapper (differential baseline).
    Seed,
    /// The two-level bucketed timer wheel.
    #[default]
    Wheel,
}

/// Occupancy counters observed over a heap's lifetime — the kernel
/// observability surface reported through `metrics` and the
/// `BENCH_kernel.json` state lines.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub struct HeapStats {
    /// Peak number of pending entries (live, post-cancel).
    pub peak_depth: usize,
    /// Peak entries resident in the near-future wheel (0 on `Seed`).
    pub peak_wheel: usize,
    /// Peak entries resident in the far-future overflow heap (0 on
    /// `Seed`).
    pub peak_overflow: usize,
}

/// An entry in the heap: fires `event` at `time`.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
struct Entry<E> {
    time: SimTime,
    seq: u64,
    event: E,
}

fn tick_of(time: SimTime) -> u64 {
    time.0 >> GRAN_BITS
}

/// Deterministic min-heap of timed events.
#[derive(Debug)]
pub struct EventHeap<E> {
    backend: Backend<E>,
    seq: u64,
    len: usize,
    stats: HeapStats,
}

#[derive(Debug)]
enum Backend<E> {
    Seed {
        heap: BinaryHeap<Reverse<Entry<E>>>,
        /// Seqs cancelled while pending; skipped lazily on pop.
        cancelled: HashSet<u64>,
    },
    Wheel(Wheel<E>),
}

/// The two-level wheel. Layout invariants (W1–W3, argued in
/// DESIGN.md "Event core"):
///
/// - **W1 (window).** Bucketed entries have tick in
///   `(cursor_tick, base_tick + BUCKETS)`; entries at `cursor_tick`
///   live in the sorted `cur` run; overflow entries have tick
///   `>= base_tick + BUCKETS`. Location by tick is therefore exact,
///   which is what makes `cancel` O(bucket).
/// - **W2 (monotone base).** `base_tick` and `cursor_tick` only
///   advance. A refill happens only when the wheel is empty, sets
///   `base_tick` to the overflow minimum's tick, and migrates
///   ascending until the overflow top clears the new horizon — so the
///   remainder is provably above it and every entry migrates at most
///   once.
/// - **W3 (sorted run).** `cur` is ascending `(time, seq)` from
///   `cur_pos`; same-tick pushes binary-insert into the live tail
///   (their seq is larger than every resident seq, so insertion order
///   is preserved within equal times).
#[derive(Debug)]
struct Wheel<E> {
    buckets: Vec<Vec<Entry<E>>>,
    /// One bit per bucket: set iff the bucket is non-empty.
    occ: Vec<u64>,
    /// Entries in `buckets` (excludes `cur` and overflow).
    in_buckets: usize,
    /// The wheel window covers ticks `[base_tick, base_tick+BUCKETS)`.
    base_tick: u64,
    /// Tick currently draining through `cur`.
    cursor_tick: u64,
    /// Sorted drain run for `cursor_tick`; `cur_pos` is the next pop.
    cur: Vec<Entry<E>>,
    cur_pos: usize,
    overflow: BinaryHeap<Reverse<Entry<E>>>,
    /// Seqs cancelled while in overflow; dropped on migration or pop.
    cancelled: HashSet<u64>,
}

impl<E: Ord + Copy> Wheel<E> {
    fn new() -> Self {
        Wheel {
            buckets: (0..BUCKETS).map(|_| Vec::new()).collect(),
            occ: vec![0u64; BUCKETS / 64],
            in_buckets: 0,
            base_tick: 0,
            cursor_tick: 0,
            cur: Vec::new(),
            cur_pos: 0,
            overflow: BinaryHeap::new(),
            cancelled: HashSet::new(),
        }
    }

    fn horizon(&self) -> u64 {
        self.base_tick + BUCKETS as u64
    }

    fn live_in_cur(&self) -> usize {
        self.cur.len() - self.cur_pos
    }

    fn wheel_live(&self) -> usize {
        self.in_buckets + self.live_in_cur()
    }

    fn overflow_live(&self) -> usize {
        self.overflow.len() - self.cancelled.len()
    }

    fn push(&mut self, e: Entry<E>) {
        let tick = tick_of(e.time);
        debug_assert!(
            tick >= self.cursor_tick,
            "wheel push behind the cursor: tick {tick} < {}",
            self.cursor_tick
        );
        if tick == self.cursor_tick {
            // W3: the new seq is larger than every resident seq, so
            // the first slot whose time is strictly later keeps the
            // run sorted and FIFO within equal times.
            let at = self.cur_pos
                + self.cur[self.cur_pos..].partition_point(|r| r.time <= e.time);
            self.cur.insert(at, e);
        } else if tick < self.horizon() {
            let idx = (tick & TICK_MASK) as usize;
            self.buckets[idx].push(e);
            self.occ[idx / 64] |= 1 << (idx % 64);
            self.in_buckets += 1;
        } else {
            self.overflow.push(Reverse(e));
        }
    }

    fn pop(&mut self) -> Option<Entry<E>> {
        loop {
            if self.cur_pos < self.cur.len() {
                let e = self.cur[self.cur_pos];
                self.cur_pos += 1;
                return Some(e);
            }
            if self.in_buckets > 0 {
                let tick = self
                    .next_occupied(self.cursor_tick + 1)
                    .expect("occupancy bitmap out of sync with in_buckets");
                let idx = (tick & TICK_MASK) as usize;
                // Recycle the drained run's allocation as the next
                // bucket's backing store (and vice versa).
                self.cur.clear();
                self.cur_pos = 0;
                std::mem::swap(&mut self.cur, &mut self.buckets[idx]);
                self.occ[idx / 64] &= !(1 << (idx % 64));
                self.in_buckets -= self.cur.len();
                // Unique seqs: (time, seq) never ties, so unstable
                // sorting is deterministic.
                self.cur.sort_unstable_by_key(|e| (e.time, e.seq));
                self.cursor_tick = tick;
                continue;
            }
            if self.overflow.is_empty() {
                return None;
            }
            self.refill();
        }
    }

    /// First armed tick in `[from, horizon)`, by word-scanning the
    /// occupancy bitmap (ticks map bijectively onto bucket indices
    /// within one window, so every set bit met along the scan is the
    /// tick the scan position says it is).
    fn next_occupied(&self, from: u64) -> Option<u64> {
        let horizon = self.horizon();
        let mut tick = from;
        while tick < horizon {
            let idx = (tick & TICK_MASK) as usize;
            let bit = idx % 64;
            let w = self.occ[idx / 64] >> bit;
            if w != 0 {
                let cand = tick + w.trailing_zeros() as u64;
                return (cand < horizon).then_some(cand);
            }
            tick += 64 - bit as u64;
        }
        None
    }

    /// Wheel empty, overflow not: advance the window to the overflow
    /// minimum and migrate everything below the new horizon (W2).
    fn refill(&mut self) {
        debug_assert_eq!(self.in_buckets, 0);
        debug_assert_eq!(self.cur_pos, self.cur.len());
        // Cancelled entries that bubbled to the top are dropped here
        // rather than steering the new base.
        while let Some(Reverse(top)) = self.overflow.peek() {
            if !self.cancelled.remove(&top.seq) {
                break;
            }
            self.overflow.pop();
        }
        let Some(Reverse(top)) = self.overflow.peek() else { return };
        let base = tick_of(top.time);
        debug_assert!(base >= self.horizon(), "overflow entry inside the wheel window");
        self.base_tick = base;
        self.cursor_tick = base - 1;
        let horizon = self.horizon();
        while let Some(Reverse(top)) = self.overflow.peek() {
            if tick_of(top.time) >= horizon {
                break;
            }
            let Reverse(e) = self.overflow.pop().unwrap();
            if self.cancelled.remove(&e.seq) {
                continue;
            }
            let idx = (tick_of(e.time) & TICK_MASK) as usize;
            self.buckets[idx].push(e);
            self.occ[idx / 64] |= 1 << (idx % 64);
            self.in_buckets += 1;
        }
    }

    fn peek_time(&self) -> Option<SimTime> {
        if self.cur_pos < self.cur.len() {
            return Some(self.cur[self.cur_pos].time);
        }
        if self.in_buckets > 0 {
            let tick = self
                .next_occupied(self.cursor_tick + 1)
                .expect("occupancy bitmap out of sync with in_buckets");
            let bucket = &self.buckets[(tick & TICK_MASK) as usize];
            return bucket.iter().map(|e| e.time).min();
        }
        // `peek` is `&self`, so a tombstoned overflow top falls back
        // to a filtered scan (rare: only when the earliest far-future
        // entry was cancelled and nothing has popped since).
        let Reverse(top) = self.overflow.peek()?;
        if !self.cancelled.contains(&top.seq) {
            return Some(top.time);
        }
        self.overflow
            .iter()
            .filter(|Reverse(e)| !self.cancelled.contains(&e.seq))
            .map(|Reverse(e)| e.time)
            .min()
    }

    fn cancel(&mut self, time: SimTime, seq: u64) -> bool {
        let tick = tick_of(time);
        if tick >= self.horizon() {
            // W1: at or past the horizon means overflow, exactly.
            debug_assert!(self.overflow.iter().any(|Reverse(e)| e.seq == seq));
            return self.cancelled.insert(seq);
        }
        if tick == self.cursor_tick {
            // In the sorted live tail: locate by (time, seq) and
            // remove preserving order.
            let tail = &self.cur[self.cur_pos..];
            let at = tail.partition_point(|r| (r.time, r.seq) < (time, seq));
            if at < tail.len() && tail[at].seq == seq {
                self.cur.remove(self.cur_pos + at);
                return true;
            }
            return false;
        }
        // In a bucket (unsorted): swap-remove, clear the bit if empty.
        let idx = (tick & TICK_MASK) as usize;
        let bucket = &mut self.buckets[idx];
        let Some(at) = bucket.iter().position(|e| e.seq == seq) else { return false };
        bucket.swap_remove(at);
        if bucket.is_empty() {
            self.occ[idx / 64] &= !(1 << (idx % 64));
        }
        self.in_buckets -= 1;
        true
    }
}

impl<E: Ord + Copy> EventHeap<E> {
    pub fn new() -> Self {
        Self::with_kind(HeapKind::default())
    }

    pub fn with_kind(kind: HeapKind) -> Self {
        let backend = match kind {
            HeapKind::Seed => {
                Backend::Seed { heap: BinaryHeap::new(), cancelled: HashSet::new() }
            }
            HeapKind::Wheel => Backend::Wheel(Wheel::new()),
        };
        EventHeap { backend, seq: 0, len: 0, stats: HeapStats::default() }
    }

    pub fn kind(&self) -> HeapKind {
        match self.backend {
            Backend::Seed { .. } => HeapKind::Seed,
            Backend::Wheel(_) => HeapKind::Wheel,
        }
    }

    /// Schedule `event` at absolute virtual time `time`; returns the
    /// entry's sequence number — the handle [`cancel`](Self::cancel)
    /// takes. `time` must be at or after the last popped time (the
    /// engine's monotone-clock contract); the wheel backend
    /// debug-asserts it.
    pub fn push(&mut self, time: SimTime, event: E) -> u64 {
        let seq = self.seq;
        self.seq += 1;
        let e = Entry { time, seq, event };
        match &mut self.backend {
            Backend::Seed { heap, .. } => heap.push(Reverse(e)),
            Backend::Wheel(w) => {
                w.push(e);
                self.stats.peak_wheel = self.stats.peak_wheel.max(w.wheel_live());
                self.stats.peak_overflow = self.stats.peak_overflow.max(w.overflow_live());
            }
        }
        self.len += 1;
        self.stats.peak_depth = self.stats.peak_depth.max(self.len);
        seq
    }

    /// Pop the earliest live event, if any.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let popped = match &mut self.backend {
            Backend::Seed { heap, cancelled } => loop {
                let Some(Reverse(e)) = heap.pop() else { break None };
                if cancelled.remove(&e.seq) {
                    continue;
                }
                break Some(e);
            },
            Backend::Wheel(w) => w.pop(),
        };
        popped.map(|e| {
            self.len -= 1;
            (e.time, e.event)
        })
    }

    /// Time of the earliest pending live event.
    pub fn peek_time(&self) -> Option<SimTime> {
        match &self.backend {
            Backend::Seed { heap, cancelled } => {
                if cancelled.is_empty() {
                    return heap.peek().map(|Reverse(e)| e.time);
                }
                heap.iter()
                    .filter(|Reverse(e)| !cancelled.contains(&e.seq))
                    .map(|Reverse(e)| e.time)
                    .min()
            }
            Backend::Wheel(w) => w.peek_time(),
        }
    }

    /// Eagerly remove a pending entry by its `(time, seq)`
    /// coordinates (as returned by [`push`](Self::push)). Returns
    /// whether an entry was reclaimed; cancelling an entry that
    /// already popped (or was already cancelled) is a no-op. `time`
    /// must be the exact scheduled time — it is what locates the
    /// entry in O(bucket) on the wheel.
    pub fn cancel(&mut self, time: SimTime, seq: u64) -> bool {
        let hit = match &mut self.backend {
            Backend::Seed { heap, cancelled } => {
                heap.iter().any(|Reverse(e)| e.seq == seq && e.time == time)
                    && cancelled.insert(seq)
            }
            Backend::Wheel(w) => w.cancel(time, seq),
        };
        if hit {
            self.len -= 1;
        }
        hit
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Live entries pending (cancelled entries are not counted).
    pub fn len(&self) -> usize {
        self.len
    }

    /// Lifetime occupancy counters (peaks are of live entries).
    pub fn stats(&self) -> HeapStats {
        self.stats
    }
}

impl<E: Ord + Copy> Default for EventHeap<E> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::units::Duration;

    fn both() -> [EventHeap<u32>; 2] {
        [EventHeap::with_kind(HeapKind::Seed), EventHeap::with_kind(HeapKind::Wheel)]
    }

    #[test]
    fn orders_by_time() {
        for mut h in both() {
            h.push(SimTime(30), 3u32);
            h.push(SimTime(10), 1);
            h.push(SimTime(20), 2);
            assert_eq!(h.pop(), Some((SimTime(10), 1)));
            assert_eq!(h.pop(), Some((SimTime(20), 2)));
            assert_eq!(h.pop(), Some((SimTime(30), 3)));
            assert_eq!(h.pop(), None);
        }
    }

    #[test]
    fn ties_break_by_insertion_order() {
        for mut h in both() {
            let t = SimTime::ZERO + Duration::from_secs(1);
            h.push(t, 7u32);
            h.push(t, 3);
            h.push(t, 9);
            assert_eq!(h.pop().unwrap().1, 7);
            assert_eq!(h.pop().unwrap().1, 3);
            assert_eq!(h.pop().unwrap().1, 9);
        }
    }

    #[test]
    fn peek_does_not_consume() {
        for mut h in both() {
            h.push(SimTime(5), 1u8);
            assert_eq!(h.peek_time(), Some(SimTime(5)));
            assert_eq!(h.len(), 1);
            assert!(!h.is_empty());
        }
    }

    #[test]
    fn interleaved_push_pop() {
        for mut h in both() {
            h.push(SimTime(10), 1u32);
            h.push(SimTime(5), 0);
            assert_eq!(h.pop().unwrap().1, 0);
            h.push(SimTime(7), 2);
            assert_eq!(h.pop().unwrap().1, 2);
            assert_eq!(h.pop().unwrap().1, 1);
        }
    }

    /// One second of virtual time is ~15 ticks; one hour crosses the
    /// wheel horizon into overflow and back out through refills.
    #[test]
    fn wheel_spans_ticks_and_overflow() {
        let secs = |s: u64| SimTime::ZERO + Duration::from_secs(s);
        for mut h in both() {
            // Far-future first, then near, then same-tick jitter.
            h.push(secs(3_600), 4u32);
            h.push(secs(7_200), 5);
            h.push(secs(1), 1);
            h.push(SimTime(secs(1).0 + 1), 2);
            h.push(secs(120), 3);
            let order: Vec<u32> = std::iter::from_fn(|| h.pop().map(|(_, e)| e)).collect();
            assert_eq!(order, [1, 2, 3, 4, 5]);
            assert!(h.is_empty());
        }
    }

    #[test]
    fn cancel_reclaims_pending_entries() {
        let secs = |s: u64| SimTime::ZERO + Duration::from_secs(s);
        for mut h in both() {
            let s1 = h.push(secs(1), 1u32);
            let s2 = h.push(secs(2), 2);
            let s3 = h.push(secs(500), 3); // overflow on the wheel
            assert_eq!(h.len(), 3);
            assert!(h.cancel(secs(2), s2));
            assert!(!h.cancel(secs(2), s2), "double cancel must be a no-op");
            assert!(h.cancel(secs(500), s3));
            assert_eq!(h.len(), 1);
            assert_eq!(h.peek_time(), Some(secs(1)));
            assert_eq!(h.pop(), Some((secs(1), 1)));
            assert_eq!(h.pop(), None);
            let _ = s1;
        }
    }

    #[test]
    fn cancelled_overflow_entries_never_resurface() {
        let secs = |s: u64| SimTime::ZERO + Duration::from_secs(s);
        for mut h in both() {
            let s1 = h.push(secs(400), 1u32); // beyond the ~68.7 s horizon
            h.push(secs(401), 2);
            h.push(secs(1), 0);
            assert!(h.cancel(secs(400), s1));
            assert_eq!(h.pop(), Some((secs(1), 0)));
            // The refill that services secs(401) must drop the
            // cancelled secs(400) entry, not steer the window by it.
            assert_eq!(h.pop(), Some((secs(401), 2)));
            assert_eq!(h.pop(), None);
        }
    }

    #[test]
    fn stats_track_peaks() {
        let secs = |s: u64| SimTime::ZERO + Duration::from_secs(s);
        let mut h = EventHeap::with_kind(HeapKind::Wheel);
        h.push(secs(1), 1u32);
        h.push(secs(2), 2);
        h.push(secs(900), 3);
        let st = h.stats();
        assert_eq!(st.peak_depth, 3);
        assert_eq!(st.peak_wheel, 2);
        assert_eq!(st.peak_overflow, 1);
        while h.pop().is_some() {}
        assert_eq!(h.stats().peak_depth, 3, "peaks survive the drain");
    }
}
