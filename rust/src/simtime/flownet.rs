//! Flow-level network model with max-min fair bandwidth sharing.
//!
//! Every bandwidth-shaped resource in the simulated testbed is a
//! [`Link`]: a GPFS storage server, the filesystem's aggregate
//! backplane (240 GB/s on the paper's installation), a BG/Q I/O-node
//! uplink, a compute-node torus injection port, the APS↔ALCF WAN pipe.
//! Concurrent transfers are [`Flow`]s traversing a *path* (an ordered
//! set — order is irrelevant to the math) of links.
//!
//! **Flow bundles.** The paper's workloads are symmetric at enormous
//! fan-out (8,192 nodes all staging the same 577 MB dataset). Modelling
//! each per-node transfer as its own flow would make every rate
//! recomputation O(nodes × links). Instead a flow has a `members`
//! count: `members` identical transfers advancing in lockstep, each
//! consuming one fair share on every link of the path. A collective
//! over 8K nodes is then a handful of bundles and recomputation cost is
//! independent of machine size (measured in the `hotpath` bench).
//!
//! **Max-min fairness** via progressive filling (water-filling): repeat
//! { find the link whose remaining capacity divided by its unfrozen
//! member count is smallest; freeze every unfrozen flow through it at
//! that per-member share }. This is the classic fluid approximation of
//! TCP/interconnect fair sharing used by flow-level simulators.
//!
//! **Degrading capacity.** GPFS's delivered bandwidth collapses under
//! many uncoordinated readers (disk-head thrash and prefetch loss; the
//! mechanism behind the paper's Fig 11 naive curve). A link may
//! therefore declare [`Capacity::Degrading`], an efficiency that decays
//! with the total number of concurrent streams:
//!
//! ```text
//! effective(n) = peak / (1 + max(0, n - pivot) / half)
//! ```
//!
//! With `pivot` streams or fewer there is no penalty; each additional
//! `half` streams halve the *additional* efficiency. The constants for
//! the GPFS model are calibrated in `pfs::GpfsParams` against the
//! paper's measured 21 GB/s naive aggregate at 8K nodes.

use crate::units::{Duration, SimTime};

/// Identifies a link within one [`FlowNet`].
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct LinkId(pub usize);

/// Identifies a flow within one [`FlowNet`].
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct FlowId(pub usize);

/// Link capacity model, bytes/second.
#[derive(Clone, Copy, Debug)]
pub enum Capacity {
    /// Constant capacity regardless of stream count.
    Fixed(f64),
    /// Stream-count-dependent capacity (see module docs).
    Degrading { peak: f64, pivot: f64, half: f64 },
}

impl Capacity {
    /// Effective capacity when `streams` concurrent members traverse it.
    pub fn effective(&self, streams: f64) -> f64 {
        match *self {
            Capacity::Fixed(c) => c,
            Capacity::Degrading { peak, pivot, half } => {
                let excess = (streams - pivot).max(0.0);
                peak / (1.0 + excess / half)
            }
        }
    }
}

#[derive(Debug)]
struct Link {
    #[allow(dead_code)]
    name: String,
    cap: Capacity,
}

#[derive(Debug)]
struct Flow {
    path: Vec<LinkId>,
    members: u64,
    /// Bytes still to move, per member.
    remaining_each: f64,
    /// Current fair-share rate, bytes/sec per member.
    rate_each: f64,
    /// Upper bound on the per-member rate (e.g. a torus injection port
    /// or a per-process RAM-disk stream); INFINITY when uncapped.
    cap_each: f64,
    active: bool,
}

/// The flow network. Owned by the simulation engine; rates are
/// recomputed whenever the active flow set changes.
#[derive(Debug, Default)]
pub struct FlowNet {
    links: Vec<Link>,
    flows: Vec<Flow>,
    active: Vec<FlowId>,
    /// Rate-recompute epoch; completion events scheduled under an older
    /// epoch are stale and must be ignored by the engine.
    pub epoch: u64,
}

impl FlowNet {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_link(&mut self, name: impl Into<String>, cap: Capacity) -> LinkId {
        self.links.push(Link { name: name.into(), cap });
        LinkId(self.links.len() - 1)
    }

    /// Begin a bundle of `members` identical transfers of `bytes_each`
    /// bytes across `path`. Returns its id; rates become valid after
    /// the next [`FlowNet::recompute`].
    pub fn start(&mut self, path: Vec<LinkId>, members: u64, bytes_each: u64) -> FlowId {
        self.start_capped(path, members, bytes_each, f64::INFINITY)
    }

    /// [`FlowNet::start`] with a per-member rate cap.
    pub fn start_capped(
        &mut self,
        path: Vec<LinkId>,
        members: u64,
        bytes_each: u64,
        cap_each: f64,
    ) -> FlowId {
        assert!(members > 0, "empty bundle");
        assert!(cap_each > 0.0, "non-positive rate cap");
        for l in &path {
            assert!(l.0 < self.links.len(), "bad link id {l:?}");
        }
        let id = FlowId(self.flows.len());
        self.flows.push(Flow {
            path,
            members,
            remaining_each: bytes_each as f64,
            rate_each: 0.0,
            cap_each,
            active: true,
        });
        self.active.push(id);
        id
    }

    /// Advance all active flows by `dt` of virtual time at current rates.
    pub fn advance(&mut self, dt: Duration) {
        let secs = dt.secs_f64();
        if secs == 0.0 {
            return;
        }
        for &id in &self.active {
            let f = &mut self.flows[id.0];
            f.remaining_each = (f.remaining_each - f.rate_each * secs).max(0.0);
        }
    }

    /// Max-min fair-share rate assignment (see module docs). Call after
    /// any change to the active set; bumps the epoch.
    pub fn recompute(&mut self) {
        self.epoch += 1;
        let nlinks = self.links.len();
        // Total members per link (for degrading-capacity stream counts).
        let mut streams = vec![0.0f64; nlinks];
        for &id in &self.active {
            let f = &self.flows[id.0];
            for l in &f.path {
                streams[l.0] += f.members as f64;
            }
        }
        let mut cap_left: Vec<f64> = (0..nlinks)
            .map(|i| self.links[i].cap.effective(streams[i]))
            .collect();
        let mut members_left = vec![0.0f64; nlinks];
        let mut unfrozen: Vec<FlowId> = Vec::with_capacity(self.active.len());
        for &id in &self.active {
            let f = &mut self.flows[id.0];
            if f.path.is_empty() {
                // Pathless flow: an in-RAM copy or per-process local
                // stream; rate is its cap (INFINITY = instantaneous).
                f.rate_each = f.cap_each;
                continue;
            }
            f.rate_each = 0.0;
            unfrozen.push(id);
            for l in &f.path {
                members_left[l.0] += f.members as f64;
            }
        }
        while !unfrozen.is_empty() {
            // Candidate A: bottleneck link share.
            let mut link_best: Option<(f64, usize)> = None;
            for l in 0..nlinks {
                if members_left[l] > 0.0 {
                    let share = cap_left[l] / members_left[l];
                    if link_best.map_or(true, |(s, _)| share < s) {
                        link_best = Some((share, l));
                    }
                }
            }
            // Candidate B: smallest per-member rate cap among unfrozen.
            let cap_best = unfrozen
                .iter()
                .map(|id| self.flows[id.0].cap_each)
                .fold(f64::INFINITY, f64::min);

            let freeze_at_cap = match link_best {
                Some((s, _)) => cap_best < s,
                None => cap_best.is_finite(),
            };
            if freeze_at_cap {
                // Freeze the cap-limited flows at their cap.
                let mut still = Vec::with_capacity(unfrozen.len());
                for id in unfrozen.drain(..) {
                    let cap = self.flows[id.0].cap_each;
                    if cap <= cap_best {
                        let members = self.flows[id.0].members as f64;
                        self.flows[id.0].rate_each = cap;
                        for l in &self.flows[id.0].path {
                            cap_left[l.0] -= cap * members;
                            members_left[l.0] -= members;
                        }
                    } else {
                        still.push(id);
                    }
                }
                unfrozen = still;
            } else {
                let Some((share, bott)) = link_best else { break };
                // Freeze every unfrozen flow through the bottleneck.
                let mut still = Vec::with_capacity(unfrozen.len());
                for id in unfrozen.drain(..) {
                    let through = self.flows[id.0].path.iter().any(|l| l.0 == bott);
                    if through {
                        let members = self.flows[id.0].members as f64;
                        self.flows[id.0].rate_each = share;
                        for l in &self.flows[id.0].path {
                            cap_left[l.0] -= share * members;
                            members_left[l.0] -= members;
                        }
                    } else {
                        still.push(id);
                    }
                }
                unfrozen = still;
            }
            // Guard against FP drift leaving tiny negative capacity.
            for c in cap_left.iter_mut() {
                if *c < 0.0 {
                    *c = 0.0;
                }
            }
        }
    }

    /// The earliest (time-from-now, flow) completion at current rates.
    pub fn next_completion(&self, now: SimTime) -> Option<(SimTime, FlowId)> {
        let mut best: Option<(f64, FlowId)> = None;
        for &id in &self.active {
            let f = &self.flows[id.0];
            let eta = if f.rate_each == f64::INFINITY || f.remaining_each <= 0.0 {
                0.0
            } else if f.rate_each > 0.0 {
                f.remaining_each / f.rate_each
            } else {
                continue; // starved: no capacity at all
            };
            if best.map_or(true, |(t, _)| eta < t) {
                best = Some((eta, id));
            }
        }
        best.map(|(eta, id)| (now + Duration::from_secs_f64(eta), id))
    }

    /// Mark a flow complete and remove it from the active set.
    pub fn complete(&mut self, id: FlowId) {
        let f = &mut self.flows[id.0];
        assert!(f.active, "double completion of {id:?}");
        f.active = false;
        f.remaining_each = 0.0;
        self.active.retain(|&a| a != id);
    }

    pub fn is_done(&self, id: FlowId) -> bool {
        !self.flows[id.0].active
    }

    pub fn remaining_each(&self, id: FlowId) -> f64 {
        self.flows[id.0].remaining_each
    }

    /// Current per-member rate, bytes/sec.
    pub fn rate_each(&self, id: FlowId) -> f64 {
        self.flows[id.0].rate_each
    }

    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    pub fn link_name(&self, id: LinkId) -> &str {
        &self.links[id.0].name
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GB: f64 = 1e9;

    fn net_one_link(cap: f64) -> (FlowNet, LinkId) {
        let mut net = FlowNet::new();
        let l = net.add_link("l", Capacity::Fixed(cap));
        (net, l)
    }

    #[test]
    fn single_flow_gets_full_capacity() {
        let (mut net, l) = net_one_link(10.0 * GB);
        let f = net.start(vec![l], 1, 1_000_000_000);
        net.recompute();
        assert_eq!(net.rate_each(f), 10.0 * GB);
        let (t, id) = net.next_completion(SimTime::ZERO).unwrap();
        assert_eq!(id, f);
        assert_eq!(t.secs_f64(), 0.1);
    }

    #[test]
    fn two_flows_share_equally() {
        let (mut net, l) = net_one_link(10.0 * GB);
        let a = net.start(vec![l], 1, 1_000_000_000);
        let b = net.start(vec![l], 1, 2_000_000_000);
        net.recompute();
        assert_eq!(net.rate_each(a), 5.0 * GB);
        assert_eq!(net.rate_each(b), 5.0 * GB);
    }

    #[test]
    fn bundle_members_each_take_a_share() {
        let (mut net, l) = net_one_link(10.0 * GB);
        let bundle = net.start(vec![l], 9, GB as u64);
        let solo = net.start(vec![l], 1, GB as u64);
        net.recompute();
        // 10 members total: 1 GB/s each.
        assert!((net.rate_each(bundle) - GB).abs() < 1.0);
        assert!((net.rate_each(solo) - GB).abs() < 1.0);
    }

    #[test]
    fn bundle_equivalent_to_individual_flows() {
        // N individual flows and one N-member bundle finish at the same time.
        let (mut net1, l1) = net_one_link(8.0 * GB);
        for _ in 0..16 {
            net1.start(vec![l1], 1, GB as u64);
        }
        net1.recompute();
        let t1 = net1.next_completion(SimTime::ZERO).unwrap().0;

        let (mut net2, l2) = net_one_link(8.0 * GB);
        net2.start(vec![l2], 16, GB as u64);
        net2.recompute();
        let t2 = net2.next_completion(SimTime::ZERO).unwrap().0;
        assert_eq!(t1, t2);
    }

    #[test]
    fn water_filling_classic() {
        // Textbook max-min: flows A (link1), B (link1+link2), C (link2).
        // cap1 = 10, cap2 = 4 -> B and C bottleneck on link2 at 2 each;
        // A then gets the link1 remainder: 8.
        let mut net = FlowNet::new();
        let l1 = net.add_link("1", Capacity::Fixed(10.0));
        let l2 = net.add_link("2", Capacity::Fixed(4.0));
        let a = net.start(vec![l1], 1, 100);
        let b = net.start(vec![l1, l2], 1, 100);
        let c = net.start(vec![l2], 1, 100);
        net.recompute();
        assert!((net.rate_each(b) - 2.0).abs() < 1e-9);
        assert!((net.rate_each(c) - 2.0).abs() < 1e-9);
        assert!((net.rate_each(a) - 8.0).abs() < 1e-9);
    }

    #[test]
    fn completion_frees_capacity() {
        let (mut net, l) = net_one_link(10.0 * GB);
        let a = net.start(vec![l], 1, GB as u64);
        let b = net.start(vec![l], 1, 10 * GB as u64);
        net.recompute();
        let (t, first) = net.next_completion(SimTime::ZERO).unwrap();
        assert_eq!(first, a);
        net.advance(t - SimTime::ZERO);
        net.complete(a);
        net.recompute();
        assert_eq!(net.rate_each(b), 10.0 * GB);
        assert!(net.is_done(a));
        assert_eq!(net.active_count(), 1);
    }

    #[test]
    fn degrading_capacity_collapses_under_streams() {
        let cap = Capacity::Degrading { peak: 240.0 * GB, pivot: 2048.0, half: 1024.0 };
        assert_eq!(cap.effective(100.0), 240.0 * GB);
        assert_eq!(cap.effective(2048.0), 240.0 * GB);
        // 2048 excess streams = 2 halves -> a third of peak.
        assert!((cap.effective(4096.0) - 80.0 * GB).abs() < 1.0);
    }

    #[test]
    fn degrading_link_in_network() {
        let mut net = FlowNet::new();
        let l = net.add_link(
            "gpfs",
            Capacity::Degrading { peak: 100.0, pivot: 1.0, half: 1.0 },
        );
        let f = net.start(vec![l], 3, 100);
        net.recompute();
        // 3 streams: effective = 100/(1+2) = 33.33 total, /3 members.
        assert!((net.rate_each(f) - 100.0 / 3.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn pathless_flow_is_instantaneous() {
        let mut net = FlowNet::new();
        let f = net.start(vec![], 1, 1 << 40);
        net.recompute();
        let (t, id) = net.next_completion(SimTime::ZERO).unwrap();
        assert_eq!(id, f);
        assert_eq!(t, SimTime::ZERO);
    }

    #[test]
    fn advance_conserves_bytes() {
        let (mut net, l) = net_one_link(100.0);
        let f = net.start(vec![l], 1, 1000);
        net.recompute();
        net.advance(Duration::from_secs(3));
        assert!((net.remaining_each(f) - 700.0).abs() < 1e-6);
    }

    #[test]
    fn starved_flow_never_completes() {
        let (mut net, l) = net_one_link(10.0);
        let _hog = net.start(vec![l], 1_000_000, 1 << 40);
        net.recompute();
        // Everyone gets a (tiny) share under fairness; nothing is starved,
        // but a zero-capacity link starves everything.
        let mut net2 = FlowNet::new();
        let dead = net2.add_link("dead", Capacity::Fixed(0.0));
        net2.start(vec![dead], 1, 100);
        net2.recompute();
        assert!(net2.next_completion(SimTime::ZERO).is_none());
    }

    #[test]
    fn per_member_cap_limits_rate() {
        let (mut net, l) = net_one_link(10.0 * GB);
        let capped = net.start_capped(vec![l], 1, GB as u64, 2.0 * GB);
        net.recompute();
        assert_eq!(net.rate_each(capped), 2.0 * GB);
    }

    #[test]
    fn cap_surplus_redistributed() {
        // One capped flow (2 GB/s) + one uncapped on a 10 GB/s link:
        // the uncapped flow takes the 8 GB/s remainder, not a 5/5 split.
        let (mut net, l) = net_one_link(10.0 * GB);
        let capped = net.start_capped(vec![l], 1, GB as u64, 2.0 * GB);
        let free = net.start(vec![l], 1, GB as u64);
        net.recompute();
        assert_eq!(net.rate_each(capped), 2.0 * GB);
        assert!((net.rate_each(free) - 8.0 * GB).abs() < 1.0);
    }

    #[test]
    fn cap_above_fair_share_is_inert() {
        let (mut net, l) = net_one_link(10.0 * GB);
        let a = net.start_capped(vec![l], 1, GB as u64, 100.0 * GB);
        let b = net.start(vec![l], 1, GB as u64);
        net.recompute();
        assert!((net.rate_each(a) - 5.0 * GB).abs() < 1.0);
        assert!((net.rate_each(b) - 5.0 * GB).abs() < 1.0);
    }

    #[test]
    fn pathless_capped_flow_runs_at_cap() {
        let mut net = FlowNet::new();
        let f = net.start_capped(vec![], 16, 1_000, 100.0);
        net.recompute();
        assert_eq!(net.rate_each(f), 100.0);
        let (t, _) = net.next_completion(SimTime::ZERO).unwrap();
        assert_eq!(t.secs_f64(), 10.0);
    }

    #[test]
    fn epoch_bumps_on_recompute() {
        let (mut net, l) = net_one_link(1.0);
        let e0 = net.epoch;
        net.start(vec![l], 1, 1);
        net.recompute();
        assert!(net.epoch > e0);
    }

    #[test]
    #[should_panic(expected = "double completion")]
    fn double_complete_panics() {
        let (mut net, l) = net_one_link(1.0);
        let f = net.start(vec![l], 1, 1);
        net.recompute();
        net.complete(f);
        net.complete(f);
    }
}
