//! The real PJRT execution path (`--features pjrt-artifacts`).
//!
//! Compiles each manifest entry point once on the PJRT CPU client,
//! caches the loaded executables, and exposes the typed f32 call
//! helper. Requires the `xla` dependency to be a real `xla-rs`
//! checkout; the vendored `xla-stub` satisfies the API for offline
//! builds but fails at client construction with a clear error.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use super::manifest::Manifest;
use super::TensorF32;

/// A loaded artifact set + PJRT client with compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

fn to_literal(t: &TensorF32) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(&t.data).reshape(&dims)?)
}

impl Runtime {
    /// Load the artifact directory (does not compile anything yet;
    /// executables compile lazily on first call and are cached).
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, dir, manifest, executables: HashMap::new() })
    }

    /// The conventional artifact location relative to the repo root.
    pub fn default_dir() -> PathBuf {
        super::default_artifact_dir()
    }

    /// True if an artifact set exists at the default location (tests
    /// use this to skip gracefully before `make artifacts`).
    pub fn artifacts_available() -> bool {
        Self::default_dir().join("manifest.json").exists()
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.executables.contains_key(name) {
            let ep = self
                .manifest
                .entry_points
                .get(name)
                .ok_or_else(|| anyhow!("unknown entry point {name:?}"))?;
            let path = self.dir.join(&ep.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.executables.insert(name.to_string(), exe);
        }
        Ok(&self.executables[name])
    }

    /// Execute entry point `name` with f32 inputs; returns the f32
    /// outputs in manifest order. Shapes are validated against the
    /// manifest before dispatch.
    pub fn call(&mut self, name: &str, inputs: &[TensorF32]) -> Result<Vec<TensorF32>> {
        let ep = self
            .manifest
            .entry_points
            .get(name)
            .ok_or_else(|| anyhow!("unknown entry point {name:?}"))?
            .clone();
        if inputs.len() != ep.inputs.len() {
            return Err(anyhow!(
                "{name}: expected {} inputs, got {}",
                ep.inputs.len(),
                inputs.len()
            ));
        }
        for (i, (t, spec)) in inputs.iter().zip(&ep.inputs).enumerate() {
            if t.shape != spec.shape {
                return Err(anyhow!(
                    "{name}: input {i} shape {:?} != manifest {:?}",
                    t.shape,
                    spec.shape
                ));
            }
        }
        let lits: Vec<xla::Literal> = inputs.iter().map(to_literal).collect::<Result<_>>()?;
        let exe = self.executable(name)?;
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = result.to_tuple()?;
        if parts.len() != ep.outputs.len() {
            return Err(anyhow!(
                "{name}: got {} outputs, manifest says {}",
                parts.len(),
                ep.outputs.len()
            ));
        }
        let mut outs = Vec::with_capacity(parts.len());
        for (lit, spec) in parts.into_iter().zip(&ep.outputs) {
            let data = lit.to_vec::<f32>()?;
            outs.push(TensorF32::new(spec.shape.clone(), data));
        }
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Skip when `make artifacts` has not run (unit tests must pass on
    /// a fresh checkout; integration coverage runs post-artifacts).
    macro_rules! require_artifacts {
        () => {
            if !Runtime::artifacts_available() {
                eprintln!("skipping: run `make artifacts` first");
                return;
            }
        };
    }

    #[test]
    fn smoke_addmul_roundtrip() {
        require_artifacts!();
        let mut rt = Runtime::load(Runtime::default_dir()).unwrap();
        let x = TensorF32::scalar_vec(vec![1.0, 2.0, 3.0, 4.0]);
        let y = TensorF32::scalar_vec(vec![10.0, 20.0, 30.0, 40.0]);
        let outs = rt.call("smoke_addmul", &[x, y]).unwrap();
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0].data, vec![11.0, 22.0, 33.0, 44.0]);
        assert_eq!(outs[1].data, vec![10.0, 40.0, 90.0, 160.0]);
    }

    #[test]
    fn call_rejects_wrong_arity_and_shape() {
        require_artifacts!();
        let mut rt = Runtime::load(Runtime::default_dir()).unwrap();
        let x = TensorF32::scalar_vec(vec![1.0; 4]);
        assert!(rt.call("smoke_addmul", &[x.clone()]).is_err());
        let bad = TensorF32::scalar_vec(vec![1.0; 5]);
        assert!(rt.call("smoke_addmul", &[x.clone(), bad]).is_err());
        let y = TensorF32::scalar_vec(vec![1.0; 4]);
        assert!(rt
            .call("no_such_entry", &[x, y])
            .unwrap_err()
            .to_string()
            .contains("unknown entry point"));
    }

    #[test]
    fn executables_are_cached() {
        require_artifacts!();
        let mut rt = Runtime::load(Runtime::default_dir()).unwrap();
        let x = TensorF32::scalar_vec(vec![0.0; 4]);
        let y = TensorF32::scalar_vec(vec![0.0; 4]);
        rt.call("smoke_addmul", &[x.clone(), y.clone()]).unwrap();
        assert_eq!(rt.executables.len(), 1);
        rt.call("smoke_addmul", &[x, y]).unwrap();
        assert_eq!(rt.executables.len(), 1);
    }
}
