//! Artifact manifest: the contract between `python/compile/aot.py`
//! and the Rust runtime.
//!
//! `manifest.json` records, for every entry point, the artifact file,
//! input/output shapes and dtypes, plus the full geometry configuration
//! and the reciprocal-lattice vectors the kernels were traced with.
//! The Rust HEDM geometry (`hedm::geometry`) mirrors those constants;
//! an integration test cross-checks them so the detector simulator and
//! the fitting kernel can never drift apart silently.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Shape+dtype of one tensor in an entry-point signature.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One AOT-lowered callable.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EntryPoint {
    pub file: String,
    pub sha256: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The geometry configuration the artifacts were traced with
/// (mirror of python `compile.geometry.Config`).
#[derive(Clone, Debug, PartialEq)]
pub struct GeomConfig {
    pub wavelength: f64,
    pub lattice_a: f64,
    pub det_dist: f64,
    pub pixel_size: f64,
    pub frame: usize,
    pub omega_steps: usize,
    pub s_max: usize,
    pub o_max: usize,
    pub b_batch: usize,
    pub omega_weight: f64,
    pub match_tol: f64,
    pub dark_frames: usize,
    pub intensity_threshold: f64,
    pub log_threshold: f64,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub config: GeomConfig,
    /// (s_max, 3) reciprocal-lattice vectors as traced.
    pub gvectors: Vec<[f32; 3]>,
    pub gvector_mask: Vec<f32>,
    pub entry_points: BTreeMap<String, EntryPoint>,
}

fn tensor_spec(v: &Json) -> Result<TensorSpec> {
    let shape = v
        .expect("shape")?
        .as_f64_vec()
        .ok_or_else(|| anyhow!("bad shape"))?
        .into_iter()
        .map(|d| d as usize)
        .collect();
    let dtype = v
        .expect("dtype")?
        .as_str()
        .ok_or_else(|| anyhow!("bad dtype"))?
        .to_string();
    Ok(TensorSpec { shape, dtype })
}

fn num(v: &Json, key: &str) -> Result<f64> {
    v.expect(key)?
        .as_f64()
        .ok_or_else(|| anyhow!("{key}: not a number"))
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", dir.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text).map_err(|e| anyhow!("{e}"))?;
        let c = v.expect("config")?;
        let config = GeomConfig {
            wavelength: num(c, "wavelength")?,
            lattice_a: num(c, "lattice_a")?,
            det_dist: num(c, "det_dist")?,
            pixel_size: num(c, "pixel_size")?,
            frame: num(c, "frame")? as usize,
            omega_steps: num(c, "omega_steps")? as usize,
            s_max: num(c, "s_max")? as usize,
            o_max: num(c, "o_max")? as usize,
            b_batch: num(c, "b_batch")? as usize,
            omega_weight: num(c, "omega_weight")?,
            match_tol: num(c, "match_tol")?,
            dark_frames: num(c, "dark_frames")? as usize,
            intensity_threshold: num(c, "intensity_threshold")?,
            log_threshold: num(c, "log_threshold")?,
        };
        let gvectors = v
            .expect("gvectors")?
            .as_arr()
            .ok_or_else(|| anyhow!("gvectors: not an array"))?
            .iter()
            .map(|row| {
                let r = row.as_f64_vec().ok_or_else(|| anyhow!("bad gvector row"))?;
                if r.len() != 3 {
                    return Err(anyhow!("gvector row len {}", r.len()));
                }
                Ok([r[0] as f32, r[1] as f32, r[2] as f32])
            })
            .collect::<Result<Vec<_>>>()?;
        let gvector_mask = v
            .expect("gvector_mask")?
            .as_f64_vec()
            .ok_or_else(|| anyhow!("bad gvector_mask"))?
            .into_iter()
            .map(|x| x as f32)
            .collect();
        let mut entry_points = BTreeMap::new();
        for (name, ep) in v
            .expect("entry_points")?
            .as_obj()
            .ok_or_else(|| anyhow!("entry_points: not an object"))?
        {
            let inputs = ep
                .expect("inputs")?
                .as_arr()
                .ok_or_else(|| anyhow!("bad inputs"))?
                .iter()
                .map(tensor_spec)
                .collect::<Result<Vec<_>>>()?;
            let outputs = ep
                .expect("outputs")?
                .as_arr()
                .ok_or_else(|| anyhow!("bad outputs"))?
                .iter()
                .map(tensor_spec)
                .collect::<Result<Vec<_>>>()?;
            entry_points.insert(
                name.clone(),
                EntryPoint {
                    file: ep
                        .expect("file")?
                        .as_str()
                        .ok_or_else(|| anyhow!("bad file"))?
                        .to_string(),
                    sha256: ep
                        .get("sha256")
                        .and_then(Json::as_str)
                        .unwrap_or_default()
                        .to_string(),
                    inputs,
                    outputs,
                },
            );
        }
        if config.s_max != gvectors.len() {
            return Err(anyhow!(
                "manifest inconsistent: s_max {} != gvectors {}",
                config.s_max,
                gvectors.len()
            ));
        }
        Ok(Manifest { config, gvectors, gvector_mask, entry_points })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "config": {"wavelength": 0.172979, "lattice_a": 4.0782,
                 "det_dist": 250000.0, "pixel_size": 200.0, "frame": 512,
                 "omega_steps": 360, "s_max": 2, "o_max": 512,
                 "b_batch": 256, "omega_weight": 4.0, "match_tol": 6.0,
                 "dark_frames": 8, "intensity_threshold": 80.0,
                 "log_threshold": 12.0, "log_sigma": 1.2, "log_half": 2},
      "gvectors": [[1.0, 2.0, 3.0], [-1.0, -2.0, -3.0]],
      "gvector_mask": [1.0, 1.0],
      "entry_points": {
        "f": {"file": "f.hlo.txt", "sha256": "ab",
              "inputs": [{"shape": [2, 3], "dtype": "float32"}],
              "outputs": [{"shape": [2], "dtype": "float32"}]}
      }
    }"#;

    #[test]
    fn parses_minimal_manifest() {
        let m = Manifest::parse(MINI).unwrap();
        assert_eq!(m.config.frame, 512);
        assert!((m.config.wavelength - 0.172979).abs() < 1e-12);
        assert_eq!(m.gvectors.len(), 2);
        assert_eq!(m.gvectors[1], [-1.0, -2.0, -3.0]);
        let ep = &m.entry_points["f"];
        assert_eq!(ep.inputs[0].shape, vec![2, 3]);
        assert_eq!(ep.outputs[0].dtype, "float32");
    }

    #[test]
    fn rejects_inconsistent_smax() {
        let bad = MINI.replace(r#""s_max": 2"#, r#""s_max": 5"#);
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_missing_keys() {
        assert!(Manifest::parse("{}").is_err());
        let noconf = MINI.replace(r#""config""#, r#""konfig""#);
        assert!(Manifest::parse(&noconf).is_err());
    }

    #[test]
    fn loads_real_manifest_when_present() {
        let dir = crate::runtime::Runtime::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.entry_points.contains_key("fit_orientation"));
        assert!(m.entry_points.contains_key("reduce_frame"));
        assert_eq!(m.gvectors.len(), m.config.s_max);
        let fit = &m.entry_points["fit_orientation"];
        assert_eq!(fit.inputs[0].shape, vec![m.config.b_batch, 3]);
    }
}
