//! Default (feature-off) runtime: the same API surface as the real
//! `pjrt::Runtime` (compiled under `--features pjrt-artifacts`), with
//! every load refused up front.
//!
//! Built without `--features pjrt-artifacts` there is no PJRT client,
//! so [`Runtime::artifacts_available`] is unconditionally false —
//! which is the signal all artifact-dependent tests, benches, and
//! examples already use to skip — and [`Runtime::load`] explains how
//! to enable the real path instead of failing somewhere inside FFI.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use super::manifest::Manifest;
use super::TensorF32;

/// Stub artifact runtime; see module docs. Never constructible
/// (`load` always fails) — the `manifest` field exists because
/// callers like `hedm::fit::ArtifactScorer` compile against it.
pub struct Runtime {
    pub manifest: Manifest,
}

impl Runtime {
    /// Always fails: the crate was built without the `pjrt-artifacts`
    /// feature, so there is no PJRT client to execute artifacts with.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        Err(anyhow!(
            "cannot load artifacts from {}: xstage was built without the \
             `pjrt-artifacts` feature (rebuild with `--features pjrt-artifacts` \
             and a real `xla` dependency to execute AOT artifacts)",
            dir.as_ref().display()
        ))
    }

    /// The conventional artifact location relative to the repo root.
    pub fn default_dir() -> PathBuf {
        super::default_artifact_dir()
    }

    /// Always false without the `pjrt-artifacts` feature; tests and
    /// benches guard on this and skip.
    pub fn artifacts_available() -> bool {
        false
    }

    pub fn platform(&self) -> String {
        "none (pjrt-artifacts feature disabled)".to_string()
    }

    /// Unreachable in practice ([`Runtime::load`] never succeeds).
    pub fn call(&mut self, name: &str, _inputs: &[TensorF32]) -> Result<Vec<TensorF32>> {
        Err(anyhow!(
            "cannot execute entry point {name:?}: built without `pjrt-artifacts`"
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_fails_with_actionable_message() {
        let err = Runtime::load("artifacts").unwrap_err().to_string();
        assert!(err.contains("pjrt-artifacts"), "{err}");
    }

    #[test]
    fn artifacts_never_available() {
        assert!(!Runtime::artifacts_available());
    }
}
