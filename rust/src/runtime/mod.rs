//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas
//! artifacts from the Rust hot path.
//!
//! The compile path (`make artifacts`) runs Python exactly once:
//! `python/compile/aot.py` lowers each L2 entry point to HLO *text*
//! (text, not serialized proto — xla_extension 0.5.1 rejects jax>=0.5's
//! 64-bit instruction ids; the text parser reassigns them). This module
//! is the load path: parse `manifest.json`, compile each module once on
//! the PJRT CPU client, cache the executables, and expose typed f32
//! call helpers to the HEDM leaf tasks. Python never runs at request
//! time.
//!
//! **Feature gating.** The PJRT execution path needs the `xla` FFI
//! bindings and sits behind the `pjrt-artifacts` cargo feature. The
//! default build substitutes the stub [`Runtime`], whose `load` fails
//! with a clear message and whose `artifacts_available` is always
//! false — every artifact-dependent test, bench, and example already
//! guards on `Runtime::artifacts_available()` and skips gracefully, so
//! `cargo test -q` passes on a fresh checkout with no AOT artifacts
//! and no PJRT plugin. The manifest parser stays unconditional: it is
//! pure JSON and the geometry cross-checks rely on it.

pub mod manifest;

pub use manifest::{EntryPoint, Manifest};

#[cfg(feature = "pjrt-artifacts")]
mod pjrt;
#[cfg(feature = "pjrt-artifacts")]
pub use pjrt::Runtime;

#[cfg(not(feature = "pjrt-artifacts"))]
mod stub;
#[cfg(not(feature = "pjrt-artifacts"))]
pub use stub::Runtime;

use std::path::PathBuf;

/// An f32 tensor (shape + row-major data) crossing the FFI boundary.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorF32 {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl TensorF32 {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> TensorF32 {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        TensorF32 { shape, data }
    }

    pub fn scalar_vec(data: Vec<f32>) -> TensorF32 {
        TensorF32 { shape: vec![data.len()], data }
    }

    pub fn zeros(shape: Vec<usize>) -> TensorF32 {
        let n = shape.iter().product();
        TensorF32 { shape, data: vec![0.0; n] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// The conventional artifact location relative to the repo root.
pub fn default_artifact_dir() -> PathBuf {
    PathBuf::from(std::env::var("XSTAGE_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_validation() {
        let t = TensorF32::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
        let z = TensorF32::zeros(vec![4, 4]);
        assert_eq!(z.data.len(), 16);
        assert!(!z.is_empty());
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn tensor_bad_shape_panics() {
        TensorF32::new(vec![2, 3], vec![0.0; 5]);
    }
}
