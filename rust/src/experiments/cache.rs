//! SVI-B worker-cache experiment: "We modified NF-HEDM to cache all
//! inputs in application memory ... Since Swift/T reuses the same
//! processes for subsequent tasks, HEDM tasks after the first do not
//! need to perform Read operations at all. This approach reduces
//! input time to effectively zero for subsequent tasks."

use crate::cluster::{bgq, Topology};
use crate::dataflow::graph::{Task, TaskGraph};
use crate::dataflow::sched::{run_workflow, SchedulerCfg, WorkflowStats};
use crate::engine::SimCore;
use crate::metrics::Table;
use crate::mpisim::Comm;
use crate::pfs::{Blob, GpfsParams};
use crate::units::{Duration, MB};

use super::ExpResult;

/// Tasks-per-rank waves in the benchmark workload.
const WAVES: usize = 4;
/// Per-task staged input (a parameter+layer slice, not the full set).
const INPUT_BYTES: u64 = 64 * MB;

/// Run `waves * ranks` tasks, each reading the same staged input, with
/// or without the worker cache.
pub fn run_point(nodes: u32, cache: bool) -> WorkflowStats {
    let mut core = SimCore::new();
    let topo = Topology::build(bgq(nodes), GpfsParams::default(), &mut core.net);
    let comm = Comm::world(&topo.spec);
    let (lo, hi) = comm.node_range();
    core.node_write_range(lo, hi, "/tmp/hedm/inputs.bin", Blob::synthetic(INPUT_BYTES, 5));
    let mut g = TaskGraph::new();
    let n_tasks = comm.size() as usize * WAVES;
    g.foreach(n_tasks, |i| {
        Task::compute(format!("fit{i}"), Duration::from_secs(20))
            .with_input("/tmp/hedm/inputs.bin", None)
    });
    let cfg = SchedulerCfg { cache_inputs: cache, ..Default::default() };
    run_workflow(&mut core, &topo, &comm, g, cfg)
}

pub fn run() -> ExpResult {
    let nodes = 64;
    let cold = run_point(nodes, false);
    let warm = run_point(nodes, true);
    let mut table = Table::new(
        "SVI-B — worker input cache (4 waves x 20 s tasks, 64 MB staged input)",
        &["mode", "makespan (s)", "staged reads", "cache hits"],
    );
    table.row(&[
        "no cache".into(),
        format!("{:.1}", cold.makespan.secs_f64()),
        crate::units::fmt_bytes(cold.staged_read_bytes),
        cold.cache_hits.to_string(),
    ]);
    table.row(&[
        "cache".into(),
        format!("{:.1}", warm.makespan.secs_f64()),
        crate::units::fmt_bytes(warm.staged_read_bytes),
        warm.cache_hits.to_string(),
    ]);
    ExpResult {
        table,
        series: vec![
            (
                "makespan s".into(),
                vec![(0.0, cold.makespan.secs_f64()), (1.0, warm.makespan.secs_f64())],
            ),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_removes_read_time_for_subsequent_waves() {
        let cold = run_point(16, false);
        let warm = run_point(16, true);
        // Cold: every wave pays 64 MB / 53.4 MB/s ~= 1.2 s; warm: only
        // the first task per rank does.
        let per_read = INPUT_BYTES as f64 / (53.4 * MB as f64);
        let expect_cold = WAVES as f64 * (20.0 + per_read);
        let expect_warm = WAVES as f64 * 20.0 + per_read;
        assert!(
            (cold.makespan.secs_f64() - expect_cold).abs() < 1.0,
            "cold {} vs {expect_cold}",
            cold.makespan.secs_f64()
        );
        assert!(
            (warm.makespan.secs_f64() - expect_warm).abs() < 1.0,
            "warm {} vs {expect_warm}",
            warm.makespan.secs_f64()
        );
        assert!(warm.cache_hits > 0);
    }
}
