//! Fig 13: "Makespan scaling result for FF-HEDM stage 2" — 4,109
//! grain-indexing tasks (5-25 s each) on Orthros, makespan vs cores.

use crate::cluster::{orthros, Topology};
use crate::dataflow::sched::{run_workflow, SchedulerCfg};
use crate::engine::SimCore;
use crate::hedm::workloads;
use crate::metrics::Table;
use crate::mpisim::Comm;
use crate::pfs::GpfsParams;

use super::{ExpResult, ORTHROS_SWEEP};

/// Run the FF2 farm on `cores` Orthros cores; returns makespan seconds.
pub fn run_point(cores: u32, seed: u64) -> f64 {
    let mut core = SimCore::new();
    let mut spec = orthros();
    if cores >= 64 {
        spec.nodes = cores / 64;
    } else {
        spec.nodes = 1;
        spec.ranks_per_node = cores;
    }
    let topo = Topology::build(spec, GpfsParams::default(), &mut core.net);
    let comm = Comm::world(&topo.spec);
    let g = workloads::ff2_graph(seed);
    let stats = run_workflow(&mut core, &topo, &comm, g, SchedulerCfg::default());
    stats.makespan.secs_f64()
}

/// Sweep points fan out across `XSTAGE_JOBS` workers; the speedup
/// column's first-point baseline folds serially over the ordered
/// results (byte-identical at any worker count).
pub fn run(sweep: &[u32]) -> ExpResult {
    run_jobs(sweep, crate::util::par::jobs_from_env())
}

/// [`run`] with an explicit worker count.
pub fn run_jobs(sweep: &[u32], jobs: usize) -> ExpResult {
    let mut table = Table::new(
        "Fig 13 — FF-HEDM stage 2 makespan (4,109 tasks, 5-25 s each, Orthros)",
        &["cores", "makespan (s)", "speedup vs 64", "ideal"],
    );
    let mut pts = Vec::new();
    let mut base = None;
    let results = crate::util::par::matrix_map_jobs(sweep.to_vec(), jobs, |c| run_point(c, 43));
    for (&c, &m) in sweep.iter().zip(&results) {
        let b = *base.get_or_insert(m);
        table.row(&[
            c.to_string(),
            format!("{m:.1}"),
            format!("{:.2}x", b / m),
            format!("{:.2}x", c as f64 / sweep[0] as f64),
        ]);
        pts.push((c as f64, m));
    }
    ExpResult { table, series: vec![("makespan s".into(), pts)] }
}

pub fn default() -> ExpResult {
    run(ORTHROS_SWEEP)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn near_linear_scaling() {
        // 4,109 short tasks pack tightly: scaling stays near-ideal
        // through 320 cores (many waves, small stragglers) — the
        // contrast with Fig 12 the paper's two figures show.
        let m64 = run_point(64, 43);
        let m320 = run_point(320, 43);
        let speedup = m64 / m320;
        assert!(speedup > 4.3 && speedup <= 5.05, "{speedup}");
    }

    #[test]
    fn makespan_close_to_work_bound() {
        let m = run_point(320, 43);
        let ideal = workloads::ff2_graph(43).total_work().secs_f64() / 320.0;
        assert!(m / ideal < 1.15, "makespan {m}, ideal {ideal}");
    }
}
