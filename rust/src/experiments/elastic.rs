//! The elastic multi-tenant experiment: weighted-fair admission vs
//! seed FIFO under a bursty tenant mix, keep-alive / prewarm policies
//! under a diurnal reopen pattern, and serving through elastic
//! node-pool churn.
//!
//! Three matrices share one table:
//!
//! - **bursty** — a greedy tenant dumps a burst of sessions at t=0
//!   while a light tenant trickles in just behind it, every session on
//!   its own dataset so admission is the only coupling. The FIFO arm
//!   (equal weights — the literal seed path, rule E1) makes the light
//!   tenant wait out the whole burst; the weighted arm (victim weight
//!   4x) must beat it on the victim's P99 at every burst size.
//! - **diurnal** — a hot tenant reopens its dataset after a long idle
//!   gap while a sweeper tenant stages one-shot datasets through the
//!   same store, evicting whatever is unpinned. With policies off the
//!   reopen re-stages from GPFS; keep-alive (fixed or adaptive) holds
//!   the dataset warm through the gap, so the hot tenant's attributed
//!   GPFS bytes must drop at every sweeper count.
//! - **churn** — the generated serve workload replayed while the
//!   elastic pool leases nodes away and back on a seeded schedule
//!   (warm-up modeled); every session must still complete, and the
//!   zero-event row is the bit-identical static control.
//!
//! `benches/elastic.rs` turns the series into hard assertions
//! (per-point P99 and GPFS-byte wins, starvation-freedom).

use crate::metrics::{Percentiles, Table};
use crate::simtime::flownet::ThroughputMode;
use crate::staging::policy::{ElasticCfg, PolicyKind, TenantId, TenantsCfg};
use crate::staging::service::{
    run_serve, run_serve_specs, Batch, BatchKind, ServeOutcome, ServiceCfg, SessionSpec,
};
use crate::units::{fmt_bytes, SimTime, MB};

use super::ExpResult;

/// Burst sizes the greedy tenant throws at the queue.
pub const BURSTS: &[usize] = &[4, 6, 8];
/// Light-tenant sessions trailing each burst.
pub const VICTIM_SESSIONS: usize = 3;
/// Sweeper one-shots between the hot tenant's open and reopen. All
/// points are >= 3 so the policy-off arm really evicts the hot
/// dataset (store capacity is three working sets).
pub const SWEEPERS: &[usize] = &[3, 5, 7];
/// Elastic lease-change counts swept (0 is the static control row).
pub const CHURN_EVENTS: &[usize] = &[0, 8, 16];
/// Sessions per churn point (the CLI overrides this).
pub const SESSIONS: usize = 12;
/// Default seed.
pub const SEED: u64 = 42;

fn session(arrival_secs: u64, dataset: usize, tenant: TenantId, tasks: usize) -> SessionSpec {
    SessionSpec {
        arrival: SimTime(arrival_secs * 1_000_000_000),
        dataset,
        tenant,
        batches: vec![Batch { kind: BatchKind::Nf, tasks }],
    }
}

/// P99 of one tenant's turnaround samples.
pub fn tenant_p99(out: &ServeOutcome, tenant: TenantId) -> f64 {
    let mut v: Vec<f64> = out
        .turnaround_secs
        .iter()
        .zip(&out.tenant_of)
        .filter(|&(_, &t)| t == tenant)
        .map(|(&s, _)| s)
        .collect();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Percentiles::from_sorted(&v).expect("tenant served no sessions").p99
}

/// One bursty matrix point: greedy tenant 0 bursts `burst` sessions at
/// t=0, victim tenant 1 trails three sessions one second apart, every
/// session on a distinct dataset, budget two working sets.
pub fn bursty_point(burst: usize, weighted: bool, seed: u64) -> ServeOutcome {
    let cfg = ServiceCfg {
        seed,
        sessions: burst + VICTIM_SESSIONS,
        datasets: burst + VICTIM_SESSIONS,
        files_per_dataset: 4,
        file_bytes: 8 * MB,
        ramdisk_slice: Some(2 * 4 * 8 * MB),
        ssd_slice: Some(0),
        tenants: TenantsCfg { weights: if weighted { vec![1, 4] } else { vec![1, 1] } },
        ..Default::default()
    };
    let mut specs: Vec<SessionSpec> = (0..burst).map(|i| session(0, i, 0, 6)).collect();
    specs.extend(
        (0..VICTIM_SESSIONS).map(|i| session(1 + i as u64, burst + i, 1, 6)),
    );
    run_serve_specs(2, &cfg, ThroughputMode::Fast, specs)
}

/// One diurnal matrix point: hot tenant 0 opens dataset 0 at t=0 and
/// reopens it at t=500 s; sweeper tenant 1 stages `sweepers` one-shot
/// datasets through the three-working-set store in between. The SSD
/// tier is off, so an evicted hot dataset costs a full GPFS re-stage.
pub fn diurnal_point(sweepers: usize, policy: PolicyKind, seed: u64) -> ServeOutcome {
    let cfg = ServiceCfg {
        seed,
        sessions: sweepers + 2,
        datasets: sweepers + 1,
        files_per_dataset: 4,
        file_bytes: 8 * MB,
        ramdisk_slice: Some(3 * 4 * 8 * MB),
        ssd_slice: Some(0),
        tenants: TenantsCfg { weights: vec![1, 1] },
        policy,
        ..Default::default()
    };
    let mut specs = vec![session(0, 0, 0, 6), session(500, 0, 0, 6)];
    specs.extend((0..sweepers).map(|i| session(40 + 40 * i as u64, 1 + i, 1, 4)));
    run_serve_specs(2, &cfg, ThroughputMode::Fast, specs)
}

/// One churn point: the generated workload on four nodes while the
/// elastic pool walks its lease count between two and four.
pub fn churn_point(events: usize, sessions: usize, seed: u64) -> ServeOutcome {
    let cfg = ServiceCfg {
        seed,
        sessions,
        mean_gap_secs: 25.0,
        datasets: 3,
        files_per_dataset: 5,
        file_bytes: 8 * MB,
        ramdisk_slice: Some(4 * 5 * 8 * MB),
        elastic: Some(ElasticCfg {
            // Decorrelate the lease walk from the workload stream.
            seed: seed ^ 0xE1A5_71C0,
            events,
            mean_gap_secs: 40.0,
            min_nodes: 2,
            warmup_secs: 30.0,
        }),
        ..Default::default()
    };
    run_serve(4, &cfg, ThroughputMode::Fast)
}

/// The policy arms the diurnal matrix sweeps.
pub fn policy_arms() -> [(&'static str, PolicyKind); 3] {
    [
        ("none", PolicyKind::None),
        ("fixed", PolicyKind::FixedKeepAlive(600.0)),
        (
            "adaptive",
            PolicyKind::Adaptive { default_keepalive_secs: 600.0, max_keepalive_secs: 900.0 },
        ),
    ]
}

/// One point across the three matrices, so the whole experiment fans
/// out as a single flat point list. `arm` indexes the bursty
/// `[fifo, weighted]` pair or [`policy_arms`].
#[derive(Clone, Copy, Debug)]
enum Point {
    Bursty { burst: usize, arm: usize },
    Diurnal { sweepers: usize, arm: usize },
    Churn { events: usize },
}

/// Run all three matrices and render the combined table. Points fan
/// out across `XSTAGE_JOBS` workers (seeded, independent — the table
/// is byte-identical at any worker count).
pub fn run_with(sessions: usize, seed: u64) -> ExpResult {
    run_with_jobs(sessions, seed, crate::util::par::jobs_from_env())
}

/// [`run_with`] with an explicit worker count.
pub fn run_with_jobs(sessions: usize, seed: u64, jobs: usize) -> ExpResult {
    let mut table = Table::new(
        format!(
            "Elastic multi-tenant serving — bursty fairness, diurnal \
             keep-alive/prewarm, pool churn (seed {seed})"
        ),
        &["matrix", "point", "arm", "P50", "P99", "tenant P99", "tenant GPFS", "warm", "pool"],
    );
    let mut series: Vec<(String, Vec<(f64, f64)>)> = vec![
        ("fifo victim p99".into(), Vec::new()),
        ("weighted victim p99".into(), Vec::new()),
        ("none hot gpfs".into(), Vec::new()),
        ("fixed hot gpfs".into(), Vec::new()),
        ("adaptive hot gpfs".into(), Vec::new()),
        ("churn p99".into(), Vec::new()),
    ];

    let mut points: Vec<Point> = Vec::new();
    for &burst in BURSTS {
        for arm in 0..2 {
            points.push(Point::Bursty { burst, arm });
        }
    }
    for &sweepers in SWEEPERS {
        for arm in 0..policy_arms().len() {
            points.push(Point::Diurnal { sweepers, arm });
        }
    }
    for &events in CHURN_EVENTS {
        points.push(Point::Churn { events });
    }
    let results = crate::util::par::matrix_map_jobs(points.clone(), jobs, |pt| match pt {
        Point::Bursty { burst, arm } => bursty_point(burst, arm == 1, seed),
        Point::Diurnal { sweepers, arm } => {
            let (_, policy) = policy_arms().into_iter().nth(arm).unwrap();
            diurnal_point(sweepers, policy, seed)
        }
        Point::Churn { events } => churn_point(events, sessions, seed),
    });
    // Table and series fold serially over the ordered results.
    for (pt, out) in points.into_iter().zip(&results) {
        let p = out.percentiles.unwrap();
        match pt {
            Point::Bursty { burst, arm } => {
                let victim = tenant_p99(out, 1);
                table.row(&[
                    "bursty".into(),
                    burst.to_string(),
                    ["fifo", "weighted"][arm].into(),
                    format!("{:.1}", p.p50),
                    format!("{:.1}", p.p99),
                    format!("{victim:.1}"),
                    fmt_bytes(out.tenant_gpfs_bytes[1]),
                    "-".into(),
                    "-".into(),
                ]);
                series[arm].1.push((burst as f64, victim));
            }
            Point::Diurnal { sweepers, arm } => {
                let hot = out.tenant_gpfs_bytes[0];
                table.row(&[
                    "diurnal".into(),
                    sweepers.to_string(),
                    policy_arms()[arm].0.into(),
                    format!("{:.1}", p.p50),
                    format!("{:.1}", p.p99),
                    format!("{:.1}", tenant_p99(out, 0)),
                    fmt_bytes(hot),
                    format!("{}h/{}p/{}g", out.warm_hits, out.prewarms, out.keepalive_grants),
                    "-".into(),
                ]);
                series[2 + arm].1.push((sweepers as f64, hot as f64));
            }
            Point::Churn { events } => {
                table.row(&[
                    "churn".into(),
                    events.to_string(),
                    "elastic".into(),
                    format!("{:.1}", p.p50),
                    format!("{:.1}", p.p99),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    format!("{}ev / min {} warm", out.pool_events, out.min_warm_nodes),
                ]);
                series[5].1.push((events as f64, p.p99));
            }
        }
    }

    ExpResult { table, series }
}

pub fn run() -> ExpResult {
    run_with(SESSIONS, SEED)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weighted_admission_rescues_the_victim_tenant() {
        let fifo = bursty_point(6, false, 7);
        let weighted = bursty_point(6, true, 7);
        // Same sessions served either way, nobody starved.
        assert_eq!(fifo.sessions, weighted.sessions);
        assert!(fifo.admit_wait_secs.iter().all(|w| w.is_finite()));
        assert!(weighted.admit_wait_secs.iter().all(|w| w.is_finite()));
        // The weighted pick pulls the victim ahead of the tail of the
        // burst: strictly better victim P99, by about a full session.
        assert!(
            tenant_p99(&weighted, 1) < tenant_p99(&fifo, 1),
            "weighted {} !< fifo {}",
            tenant_p99(&weighted, 1),
            tenant_p99(&fifo, 1),
        );
        // Both arms move identical bytes from GPFS overall.
        assert_eq!(fifo.staged_bytes, weighted.staged_bytes);
    }

    #[test]
    fn keep_alive_cuts_hot_tenant_gpfs_bytes() {
        let none = diurnal_point(5, PolicyKind::None, 7);
        let per_ds = 4 * 8 * MB;
        // Policy-off: the sweepers evict the hot dataset, the reopen
        // re-stages it in full.
        assert_eq!(none.tenant_gpfs_bytes[0], 2 * per_ds);
        assert_eq!(none.warm_hits, 0);
        for (arm, policy) in policy_arms().into_iter().skip(1) {
            let out = diurnal_point(5, policy, 7);
            assert_eq!(out.tenant_gpfs_bytes[0], per_ds, "{arm}");
            assert!(out.warm_hits >= 1, "{arm}");
            assert!(out.keepalive_grants >= 1, "{arm}");
            // Every staged byte is attributed to exactly one tenant.
            assert_eq!(out.tenant_gpfs_bytes.iter().sum::<u64>(), out.staged_bytes, "{arm}");
        }
    }

    #[test]
    fn churn_control_row_is_static_and_all_points_serve() {
        let control = churn_point(0, 8, 7);
        assert_eq!(control.pool_events, 0);
        assert_eq!(control.min_warm_nodes, 4);
        let churned = churn_point(16, 8, 7);
        assert!(churned.pool_events > 0);
        assert!(churned.min_warm_nodes >= 2 && churned.min_warm_nodes < 4);
        assert_eq!(churned.turnaround_secs.len(), 8);
        // Deterministic replay.
        let again = churn_point(16, 8, 7);
        assert_eq!(churned.turnaround_secs, again.turnaround_secs);
        assert_eq!(churned.pool_events, again.pool_events);
    }

    #[test]
    fn elastic_experiment_table_renders() {
        let r = run_with(6, 9);
        assert_eq!(
            r.table.rows.len(),
            2 * BURSTS.len() + 3 * SWEEPERS.len() + CHURN_EVENTS.len()
        );
        let fifo = r.series_named("fifo victim p99").unwrap();
        let weighted = r.series_named("weighted victim p99").unwrap();
        assert_eq!(fifo.len(), BURSTS.len());
        for (f, w) in fifo.iter().zip(weighted) {
            assert!(w.1 < f.1, "burst {}: weighted {} !< fifo {}", f.0, w.1, f.1);
        }
        let none = r.series_named("none hot gpfs").unwrap();
        for arm in ["fixed hot gpfs", "adaptive hot gpfs"] {
            for (n, p) in none.iter().zip(r.series_named(arm).unwrap()) {
                assert!(p.1 < n.1, "{arm} point {}: {} !< {}", n.0, p.1, n.1);
            }
        }
        assert!(r.series_named("churn p99").unwrap().iter().all(|&(_, y)| y > 0.0));
    }
}
