//! Fig 12: "Makespan scaling result for FF-HEDM stage 1" — 720
//! peak-search jobs (5-160 s each) on Orthros, makespan vs cores.

use crate::cluster::{orthros, Topology};
use crate::dataflow::sched::{run_workflow, SchedulerCfg};
use crate::engine::SimCore;
use crate::hedm::workloads;
use crate::metrics::Table;
use crate::mpisim::Comm;
use crate::pfs::{Blob, GpfsParams};

use super::{ExpResult, ORTHROS_SWEEP};

/// Run the FF1 farm on `cores` Orthros cores; returns makespan seconds.
pub fn run_point(cores: u32, seed: u64) -> f64 {
    assert!(cores % 64 == 0 || cores < 64, "orthros nodes have 64 cores");
    let mut core = SimCore::new();
    let mut spec = orthros();
    if cores >= 64 {
        spec.nodes = cores / 64;
    } else {
        spec.nodes = 1;
        spec.ranks_per_node = cores;
    }
    let topo = Topology::build(spec, GpfsParams::default(), &mut core.net);
    let comm = Comm::world(&topo.spec);
    // Inputs staged node-locally (the cluster has node-local scratch).
    let (lo, hi) = comm.node_range();
    for i in 0..workloads::FF1_JOBS {
        core.node_write_range(
            lo,
            hi,
            &format!("/tmp/ff/frame_{i:04}.bin"),
            Blob::synthetic(workloads::FF1_INPUT_BYTES, i as u64),
        );
    }
    let g = workloads::ff1_graph(seed);
    let stats = run_workflow(&mut core, &topo, &comm, g, SchedulerCfg::default());
    stats.makespan.secs_f64()
}

/// Sweep points fan out across `XSTAGE_JOBS` workers; the speedup
/// column's first-point baseline folds serially over the ordered
/// results (byte-identical at any worker count).
pub fn run(sweep: &[u32]) -> ExpResult {
    run_jobs(sweep, crate::util::par::jobs_from_env())
}

/// [`run`] with an explicit worker count.
pub fn run_jobs(sweep: &[u32], jobs: usize) -> ExpResult {
    let mut table = Table::new(
        "Fig 12 — FF-HEDM stage 1 makespan (720 jobs, 5-160 s each, Orthros)",
        &["cores", "makespan (s)", "speedup vs 64", "ideal"],
    );
    let mut pts = Vec::new();
    let mut base = None;
    let results = crate::util::par::matrix_map_jobs(sweep.to_vec(), jobs, |c| run_point(c, 42));
    for (&c, &m) in sweep.iter().zip(&results) {
        let b = *base.get_or_insert(m);
        table.row(&[
            c.to_string(),
            format!("{m:.1}"),
            format!("{:.2}x", b / m),
            format!("{:.2}x", c as f64 / sweep[0] as f64),
        ]);
        pts.push((c as f64, m));
    }
    ExpResult { table, series: vec![("makespan s".into(), pts)] }
}

pub fn default() -> ExpResult {
    run(ORTHROS_SWEEP)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn makespan_decreases_with_cores() {
        let r = run(&[64, 320]);
        let pts = r.series_named("makespan s").unwrap();
        assert!(pts[1].1 < pts[0].1 * 0.35, "{pts:?}");
    }

    #[test]
    fn flattens_at_high_core_counts() {
        // Fig 12's visible sub-linearity: the 160 s stragglers bound
        // the makespan once cores are plentiful.
        let m320 = run_point(320, 42);
        let total_work: f64 = workloads::ff1_graph(42)
            .total_work()
            .secs_f64();
        let ideal = total_work / 320.0;
        assert!(m320 > ideal, "makespan {m320} vs ideal {ideal}");
        assert!(m320 >= 160.0, "cannot beat the longest task: {m320}");
    }
}
