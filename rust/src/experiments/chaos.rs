//! The chaos experiment: interactive serving under seeded node-failure
//! injection, FIFO requeue vs work stealing.
//!
//! Each matrix point replays the same seeded serve workload on an
//! Orthros-class cluster while a [`crate::chaos`] kill schedule fails
//! nodes mid-run: replicas and in-flight work are lost, the scheduler
//! reassigns every lost task exactly once (queue tail for FIFO, queue
//! front when stealing), and the residency manager re-stages torn
//! datasets from the cheapest surviving source (peer RAM copy → SSD
//! promote → GPFS re-read). The table sweeps the failure count for
//! both requeue policies and reports turnaround percentiles, lost
//! tasks, and recovery traffic; the zero-failure row doubles as the
//! control — both policies must reproduce it bit-identically, and
//! `benches/chaos.rs` asserts the injected-failure P99 stays within
//! 2x of it.

use crate::chaos::ChaosCfg;
use crate::dataflow::sched::SchedulerCfg;
use crate::metrics::Table;
use crate::simtime::flownet::ThroughputMode;
use crate::staging::service::{run_serve, ServeMode, ServeOutcome, ServiceCfg};
use crate::units::{fmt_bytes, MB};

use super::ExpResult;

/// Failure counts swept (0 is the control row).
pub const FAILURE_SWEEP: &[usize] = &[0, 2, 4];
/// Mean gap between kills (seconds) — dense enough that every non-zero
/// sweep point lands kills inside the serving window.
pub const MEAN_GAP_SECS: f64 = 90.0;
/// Orthros-class fat nodes per run; kills always leave survivors to
/// peer-copy from.
pub const NODES: u32 = 3;
/// Sessions per matrix point.
pub const SESSIONS: usize = 14;
/// Default workload/chaos seed.
pub const SEED: u64 = 42;

/// The serve scenario a chaos point runs: staged serving with chaos
/// armed at `failures` kills and the requeue policy selected.
pub fn cfg(failures: usize, stealing: bool, sessions: usize, seed: u64) -> ServiceCfg {
    ServiceCfg {
        seed,
        sessions,
        mean_gap_secs: 20.0,
        datasets: 3,
        files_per_dataset: 4,
        file_bytes: 8 * MB,
        mode: ServeMode::Staged,
        sched: SchedulerCfg {
            locality_aware: true,
            work_stealing: stealing,
            ..Default::default()
        },
        chaos: Some(ChaosCfg {
            // Decorrelate the kill stream from the workload stream.
            seed: seed ^ 0xC8A0_5EED,
            failures,
            mean_gap_secs: MEAN_GAP_SECS,
        }),
        ..Default::default()
    }
}

/// Run one matrix point.
pub fn run_point(failures: usize, stealing: bool, sessions: usize, seed: u64) -> ServeOutcome {
    run_serve(NODES, &cfg(failures, stealing, sessions, seed), ThroughputMode::Fast)
}

/// Run the failure-count x requeue-policy matrix and render the
/// table. Points fan out across `XSTAGE_JOBS` workers; the calm-P99
/// ratio column folds serially over the ordered results (it reads the
/// zero-failure row of the same policy).
pub fn run_with(sessions: usize, seed: u64) -> ExpResult {
    run_with_jobs(sessions, seed, crate::util::par::jobs_from_env())
}

/// [`run_with`] with an explicit worker count.
pub fn run_with_jobs(sessions: usize, seed: u64, jobs: usize) -> ExpResult {
    let mut table = Table::new(
        format!(
            "Chaos — serving under node-failure injection, {sessions} sessions/point \
             (turnaround seconds; P99 ratio vs the same policy's zero-failure control)"
        ),
        &[
            "failures",
            "policy",
            "P50",
            "P95",
            "P99",
            "lost tasks",
            "peer-copied",
            "re-staged",
            "P99 ratio",
        ],
    );
    let mut fifo_pts = Vec::new();
    let mut steal_pts = Vec::new();
    let mut calm_p99 = [0.0f64; 2];
    let mut points: Vec<(usize, usize, bool)> = Vec::new();
    for &failures in FAILURE_SWEEP {
        for (pi, stealing) in [false, true].into_iter().enumerate() {
            points.push((failures, pi, stealing));
        }
    }
    let results = crate::util::par::matrix_map_jobs(points.clone(), jobs, |(f, _, st)| {
        run_point(f, st, sessions, seed)
    });
    // The cross-point fold (the ratio column depends on the earlier
    // zero-failure row) stays serial, in point order.
    for ((failures, pi, stealing), out) in points.into_iter().zip(&results) {
        debug_assert_eq!(out.node_failures, failures);
        let p = out.percentiles.unwrap();
        if failures == 0 {
            calm_p99[pi] = p.p99;
        }
        table.row(&[
            failures.to_string(),
            if stealing { "steal" } else { "fifo" }.to_string(),
            format!("{:.1}", p.p50),
            format!("{:.1}", p.p95),
            format!("{:.1}", p.p99),
            out.lost_tasks.to_string(),
            fmt_bytes(out.copied_bytes),
            fmt_bytes(out.staged_bytes),
            format!("{:.2}x", p.p99 / calm_p99[pi]),
        ]);
        let pts = if stealing { &mut steal_pts } else { &mut fifo_pts };
        pts.push((failures as f64, p.p99));
    }
    ExpResult {
        table,
        series: vec![("fifo p99".into(), fifo_pts), ("steal p99".into(), steal_pts)],
    }
}

pub fn run() -> ExpResult {
    run_with(SESSIONS, SEED)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_failure_point_is_policy_invariant() {
        // The control row: with no kills, stealing never fires and
        // both policies reproduce the same run bit-for-bit.
        let fifo = run_point(0, false, 8, 7);
        let steal = run_point(0, true, 8, 7);
        assert_eq!(fifo.turnaround_secs, steal.turnaround_secs);
        assert_eq!(fifo.virtual_secs, steal.virtual_secs);
        assert_eq!(fifo.lost_tasks, 0);
        assert_eq!(steal.lost_tasks, 0);
        assert_eq!(fifo.copied_bytes, 0);
    }

    #[test]
    fn injected_failures_fire_and_recover() {
        for stealing in [false, true] {
            let out = run_point(3, stealing, 8, 7);
            assert_eq!(out.node_failures, 3, "stealing {stealing}");
            // Recovery never routes task reads to the shared FS.
            assert_eq!(out.reads.unstaged_bytes, 0);
            // Deterministic replay.
            let again = run_point(3, stealing, 8, 7);
            assert_eq!(out.turnaround_secs, again.turnaround_secs);
            assert_eq!(out.lost_tasks, again.lost_tasks);
        }
    }

    #[test]
    fn chaos_experiment_table_renders() {
        let r = run_with(6, 9);
        assert_eq!(r.table.rows.len(), 2 * FAILURE_SWEEP.len());
        let fifo = r.series_named("fifo p99").unwrap();
        let steal = r.series_named("steal p99").unwrap();
        assert_eq!(fifo.len(), FAILURE_SWEEP.len());
        assert_eq!(steal.len(), FAILURE_SWEEP.len());
        assert!(fifo.iter().all(|&(_, y)| y > 0.0));
        // The zero-failure control is identical across policies.
        assert_eq!(fifo[0].1, steal[0].1);
    }
}
