//! The interactive serving experiment: staged-resident serving vs
//! naive GPFS re-reads across a scenario matrix.
//!
//! Each matrix point runs the same seeded session workload twice —
//! [`ServeMode::Staged`] and [`ServeMode::Naive`] — on an
//! Orthros-class cluster (1.25 GB/s shared NFS backplane, 500 MB/s
//! per-process node-local reads), and reports per-session turnaround
//! P50/P95/P99. The matrix sweeps session arrival rate (mean
//! inter-arrival gap), dataset working-set size, and node count.
//! Staged serving must beat the naive baseline on P99 at every point
//! (asserted by `benches/serve.rs` and the integration tests).

use crate::metrics::Table;
use crate::simtime::flownet::ThroughputMode;
use crate::staging::service::{run_serve, ServeMode, ServeOutcome, ServiceCfg};
use crate::units::{fmt_bytes, MB};

use super::ExpResult;

/// Node counts swept (Orthros-class fat nodes, 64 ranks each).
pub const NODE_SWEEP: &[u32] = &[2, 4];
/// Mean inter-arrival gaps swept (seconds): bursty vs relaxed.
pub const GAP_SWEEP: &[f64] = &[15.0, 45.0];
/// Working sets swept: (files per dataset, bytes per file).
pub const WS_SWEEP: &[(usize, u64)] = &[(4, 12 * MB), (8, 24 * MB)];
/// Sessions per scenario run.
pub const SESSIONS: usize = 18;

/// One matrix point's scenario.
#[derive(Clone, Copy, Debug)]
pub struct ScenarioPoint {
    pub nodes: u32,
    pub mean_gap_secs: f64,
    pub files_per_dataset: usize,
    pub file_bytes: u64,
}

impl ScenarioPoint {
    pub fn cfg(&self, mode: ServeMode, sessions: usize, seed: u64) -> ServiceCfg {
        ServiceCfg {
            seed,
            sessions,
            mean_gap_secs: self.mean_gap_secs,
            files_per_dataset: self.files_per_dataset,
            file_bytes: self.file_bytes,
            mode,
            ..Default::default()
        }
    }

    pub fn working_set(&self) -> u64 {
        self.files_per_dataset as u64 * self.file_bytes
    }
}

/// The full scenario matrix (node count x arrival rate x working set).
pub fn matrix() -> Vec<ScenarioPoint> {
    let mut pts = Vec::new();
    for &nodes in NODE_SWEEP {
        for &mean_gap_secs in GAP_SWEEP {
            for &(files_per_dataset, file_bytes) in WS_SWEEP {
                pts.push(ScenarioPoint { nodes, mean_gap_secs, files_per_dataset, file_bytes });
            }
        }
    }
    pts
}

/// Run one matrix point under both serving modes with the same seed.
pub fn run_point(
    pt: &ScenarioPoint,
    sessions: usize,
    seed: u64,
) -> (ServeOutcome, ServeOutcome) {
    let staged = run_serve(
        pt.nodes,
        &pt.cfg(ServeMode::Staged, sessions, seed),
        ThroughputMode::Fast,
    );
    let naive = run_serve(
        pt.nodes,
        &pt.cfg(ServeMode::Naive, sessions, seed),
        ThroughputMode::Fast,
    );
    (staged, naive)
}

/// Run the whole matrix and render the comparison table. Points fan
/// out across `XSTAGE_JOBS` workers (seeded, independent — the table
/// is byte-identical at any worker count).
pub fn run_with(sessions: usize, seed: u64) -> ExpResult {
    run_with_jobs(sessions, seed, crate::util::par::jobs_from_env())
}

/// [`run_with`] with an explicit worker count.
pub fn run_with_jobs(sessions: usize, seed: u64, jobs: usize) -> ExpResult {
    let mut table = Table::new(
        format!(
            "Serve — staged-resident vs naive GPFS re-read, {sessions} sessions/point \
             (turnaround seconds)"
        ),
        &[
            "nodes",
            "gap (s)",
            "working set",
            "staged P50",
            "staged P95",
            "staged P99",
            "naive P50",
            "naive P95",
            "naive P99",
            "P99 win",
        ],
    );
    let mut staged_pts = Vec::new();
    let mut naive_pts = Vec::new();
    let pts = matrix();
    let results =
        crate::util::par::matrix_map_jobs(pts.clone(), jobs, |pt| run_point(&pt, sessions, seed));
    // Table and series fold serially over the ordered results.
    for (i, (pt, (s, n))) in pts.iter().zip(&results).enumerate() {
        let (sp, np) = (s.percentiles.unwrap(), n.percentiles.unwrap());
        table.row(&[
            pt.nodes.to_string(),
            format!("{:.0}", pt.mean_gap_secs),
            fmt_bytes(pt.working_set()),
            format!("{:.1}", sp.p50),
            format!("{:.1}", sp.p95),
            format!("{:.1}", sp.p99),
            format!("{:.1}", np.p50),
            format!("{:.1}", np.p95),
            format!("{:.1}", np.p99),
            format!("{:.2}x", np.p99 / sp.p99),
        ]);
        staged_pts.push((i as f64, sp.p99));
        naive_pts.push((i as f64, np.p99));
    }
    ExpResult {
        table,
        series: vec![
            ("staged p99".into(), staged_pts),
            ("naive p99".into(), naive_pts),
        ],
    }
}

pub fn run() -> ExpResult {
    run_with(SESSIONS, ServiceCfg::default().seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_covers_all_dimensions() {
        let pts = matrix();
        assert_eq!(pts.len(), NODE_SWEEP.len() * GAP_SWEEP.len() * WS_SWEEP.len());
        assert!(pts.iter().any(|p| p.nodes != pts[0].nodes));
        assert!(pts.iter().any(|p| p.working_set() != pts[0].working_set()));
    }

    #[test]
    fn staged_wins_p99_at_a_bursty_and_a_relaxed_point() {
        // The full matrix is the bench's job; here the two extreme
        // arrival-rate points must both show the staged P99 win.
        let pts = matrix();
        let bursty = pts.iter().find(|p| p.mean_gap_secs == GAP_SWEEP[0]).unwrap();
        let relaxed = pts.iter().find(|p| p.mean_gap_secs == GAP_SWEEP[1]).unwrap();
        for pt in [bursty, relaxed] {
            let (s, n) = run_point(pt, 12, 42);
            let (sp, np) = (s.percentiles.unwrap(), n.percentiles.unwrap());
            assert!(sp.p99 < np.p99, "staged {} vs naive {} at {pt:?}", sp.p99, np.p99);
        }
    }

    #[test]
    fn serve_experiment_table_renders() {
        let r = run_with(8, 7);
        assert_eq!(r.table.rows.len(), matrix().len());
        let p99s = r.series_named("staged p99").unwrap();
        assert_eq!(p99s.len(), matrix().len());
        assert!(p99s.iter().all(|&(_, y)| y > 0.0));
    }
}
