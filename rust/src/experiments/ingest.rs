//! The ingest experiment: a beamline detector streaming frames into
//! node memory while sessions read, vs the write-to-GPFS-then-stage
//! baseline the paper's Fig 7 workflow starts from.
//!
//! Each matrix point replays the same seeded serve workload on a
//! two-node Orthros-class cluster while a [`crate::staging::ingest`]
//! detector emits fixed-size frames over the machine's beamline link.
//! The matrix sweeps frame cadence x per-node RAM slice for both
//! landing modes: `stream` lands frames directly in the staging tiers
//! (RAM slice, then SSD, then GPFS spill under backpressure), while
//! `gpfs-first` writes every frame to the shared filesystem and stages
//! the whole dataset afterwards — the status quo the paper's
//! interactive loop is trying to beat. The table reports
//! time-to-first-result, ingest completion, detector stalls, and the
//! per-tier frame split; `benches/ingest.rs` asserts streaming wins
//! ttfr at every point and that a zero-rate detector reproduces the
//! plain service bit-for-bit.

use crate::dataflow::sched::SchedulerCfg;
use crate::metrics::Table;
use crate::simtime::flownet::ThroughputMode;
use crate::staging::ingest::{IngestCfg, IngestMode};
use crate::staging::service::{run_serve, ServeMode, ServeOutcome, ServiceCfg};
use crate::units::{fmt_bytes, MB};

use super::ExpResult;

/// Orthros-class fat nodes per run.
pub const NODES: u32 = 2;
/// Sessions per matrix point.
pub const SESSIONS: usize = 8;
/// Frames the detector emits (one per dataset file).
pub const FRAMES: usize = 12;
/// Detector frame size.
pub const FRAME_BYTES: u64 = 64 * MB;
/// Detector buffer depth before the cadence stalls.
pub const BUFFER_FRAMES: usize = 4;
/// Frame cadence sweep (seconds between frames; lower = hotter).
pub const GAP_SWEEP: &[f64] = &[0.1, 0.5];
/// Per-node RAM-slice sweep: the whole stream fits, or only a quarter
/// does and the rest rides the SSD -> GPFS spill ladder.
pub const SLICE_SWEEP: &[u64] = &[768 * MB, 192 * MB];
/// SSD tier budget: two frames deep.
pub const SSD_SLICE: u64 = 128 * MB;
/// Default workload/detector seed.
pub const SEED: u64 = 42;

/// The serve scenario an ingest point runs: every session reads the
/// one live dataset the detector is writing.
pub fn cfg(gap: f64, ram_slice: u64, mode: IngestMode, sessions: usize, seed: u64) -> ServiceCfg {
    let dataset_bytes = FRAMES as u64 * FRAME_BYTES;
    ServiceCfg {
        seed,
        sessions,
        mean_gap_secs: 2.0,
        datasets: 1,
        files_per_dataset: FRAMES,
        file_bytes: FRAME_BYTES,
        // Room for the frame slice plus twice the staged dataset, so
        // admission never queues on capacity and the sweep isolates
        // the landing mode.
        ramdisk_slice: Some(ram_slice + 2 * dataset_bytes),
        ssd_slice: Some(SSD_SLICE),
        mode: ServeMode::Staged,
        sched: SchedulerCfg { locality_aware: true, ..Default::default() },
        ingest: Some(IngestCfg {
            // Decorrelate the detector jitter from the workload stream.
            seed: seed ^ 0x1_D7C7,
            frames: FRAMES,
            frame_bytes: FRAME_BYTES,
            frame_gap_secs: gap,
            buffer_frames: BUFFER_FRAMES,
            ram_slice,
            dataset: 0,
            mode,
        }),
        ..Default::default()
    }
}

/// Run one matrix point.
pub fn run_point(
    gap: f64,
    ram_slice: u64,
    mode: IngestMode,
    sessions: usize,
    seed: u64,
) -> ServeOutcome {
    run_serve(NODES, &cfg(gap, ram_slice, mode, sessions, seed), ThroughputMode::Fast)
}

/// Run the cadence x RAM-slice x landing-mode matrix and render the
/// table. Points fan out across `XSTAGE_JOBS` workers (seeded,
/// independent — the table is byte-identical at any worker count).
pub fn run_with(sessions: usize, seed: u64) -> ExpResult {
    run_with_jobs(sessions, seed, crate::util::par::jobs_from_env())
}

/// [`run_with`] with an explicit worker count.
pub fn run_with_jobs(sessions: usize, seed: u64, jobs: usize) -> ExpResult {
    let mut table = Table::new(
        format!(
            "Ingest — streaming detector vs write-to-GPFS-then-stage, {sessions} \
             sessions/point ({FRAMES} frames of {} each; seconds)",
            fmt_bytes(FRAME_BYTES)
        ),
        &[
            "gap (s)",
            "RAM slice",
            "mode",
            "ttfr",
            "ingest done",
            "stalls",
            "ram/ssd/gpfs",
            "stall rate",
        ],
    );
    let mut stream_pts = Vec::new();
    let mut gpfs_pts = Vec::new();
    let mut points: Vec<(f64, u64, IngestMode)> = Vec::new();
    for &gap in GAP_SWEEP {
        for &slice in SLICE_SWEEP {
            for mode in [IngestMode::Stream, IngestMode::GpfsFirst] {
                points.push((gap, slice, mode));
            }
        }
    }
    let results = crate::util::par::matrix_map_jobs(points.clone(), jobs, |(gap, slice, mode)| {
        run_point(gap, slice, mode, sessions, seed)
    });
    // Table and series fold serially over the ordered results (the
    // series x-coordinate is the per-mode point index).
    for ((gap, slice, mode), out) in points.into_iter().zip(&results) {
        let ing = out.ingest.as_ref().expect("ingest point without a detector outcome");
        let ttfr = ing.first_result_secs.expect("no session read the live dataset");
        table.row(&[
            format!("{gap}"),
            fmt_bytes(slice),
            match mode {
                IngestMode::Stream => "stream",
                IngestMode::GpfsFirst => "gpfs-first",
            }
            .to_string(),
            format!("{ttfr:.1}"),
            format!("{:.1}", ing.ingest_done_secs),
            ing.stalls.to_string(),
            format!("{}/{}/{}", ing.ram_frames, ing.ssd_frames, ing.gpfs_frames),
            format!("{:.2}", ing.stall_rate()),
        ]);
        let pts = match mode {
            IngestMode::Stream => &mut stream_pts,
            IngestMode::GpfsFirst => &mut gpfs_pts,
        };
        pts.push((pts.len() as f64, ttfr));
    }
    ExpResult {
        table,
        series: vec![("stream ttfr".into(), stream_pts), ("gpfs ttfr".into(), gpfs_pts)],
    }
}

pub fn run() -> ExpResult {
    run_with(SESSIONS, SEED)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streaming_wins_ttfr_at_every_matrix_point() {
        for &gap in GAP_SWEEP {
            for &slice in SLICE_SWEEP {
                let s = run_point(gap, slice, IngestMode::Stream, 4, 7);
                let g = run_point(gap, slice, IngestMode::GpfsFirst, 4, 7);
                let (si, gi) = (s.ingest.unwrap(), g.ingest.unwrap());
                // Frame conservation on both sides of the comparison.
                assert_eq!(si.ram_frames + si.ssd_frames + si.gpfs_frames, FRAMES);
                assert_eq!((gi.ram_frames, gi.ssd_frames, gi.gpfs_frames), (0, 0, FRAMES));
                let (st, gt) = (si.first_result_secs.unwrap(), gi.first_result_secs.unwrap());
                assert!(st < gt, "gap {gap} slice {slice}: stream ttfr {st} vs gpfs {gt}");
            }
        }
    }

    #[test]
    fn tight_slice_points_spill_deterministically() {
        let tight = *SLICE_SWEEP.last().unwrap();
        let out = run_point(0.1, tight, IngestMode::Stream, 4, 7);
        let ing = out.ingest.clone().unwrap();
        assert!(ing.gpfs_frames > 0, "the tight slice must overflow to GPFS");
        assert!(ing.ram_frames > 0 && ing.ssd_frames > 0, "every tier takes frames");
        // Spilled frames are re-staged, never read raw off the FS.
        assert_eq!(out.reads.unstaged_bytes, 0);
        let again = run_point(0.1, tight, IngestMode::Stream, 4, 7);
        assert_eq!(out.ingest, again.ingest);
        assert_eq!(out.turnaround_secs, again.turnaround_secs);
    }

    #[test]
    fn ingest_experiment_table_renders() {
        let r = run_with(3, 9);
        assert_eq!(r.table.rows.len(), 2 * GAP_SWEEP.len() * SLICE_SWEEP.len());
        let stream = r.series_named("stream ttfr").unwrap();
        let gpfs = r.series_named("gpfs ttfr").unwrap();
        assert_eq!(stream.len(), GAP_SWEEP.len() * SLICE_SWEEP.len());
        assert_eq!(gpfs.len(), stream.len());
        for (s, g) in stream.iter().zip(gpfs) {
            assert!(s.1 > 0.0 && s.1 < g.1);
        }
    }
}
