//! SVI-A: the NF-HEDM data-reduction step on the cluster — "when run
//! on Orthros at our maximum allocation size of 320 cores, this data
//! reduction step required 106 s to process 736 images from two
//! detector distances."

use crate::cluster::{orthros, Topology};
use crate::dataflow::sched::{run_workflow, SchedulerCfg};
use crate::engine::SimCore;
use crate::hedm::workloads;
use crate::metrics::Table;
use crate::mpisim::Comm;
use crate::pfs::GpfsParams;

use super::ExpResult;

/// Run the reduction workload on `cores` Orthros cores.
pub fn run_point(cores: u32, seed: u64) -> f64 {
    let mut core = SimCore::new();
    let mut spec = orthros();
    spec.nodes = (cores / 64).max(1);
    let topo = Topology::build(spec, GpfsParams::default(), &mut core.net);
    let comm = Comm::world(&topo.spec);
    let g = workloads::nf_reduce_graph(seed);
    let stats = run_workflow(&mut core, &topo, &comm, g, SchedulerCfg::default());
    stats.makespan.secs_f64()
}

pub fn run() -> ExpResult {
    let mut table = Table::new(
        "SVI-A — NF reduction: 736 images on Orthros (paper: 106 s @ 320 cores)",
        &["cores", "makespan (s)", "paper (s)"],
    );
    let mut pts = Vec::new();
    for &c in &[64u32, 128, 192, 256, 320] {
        let m = run_point(c, 44);
        let paper = if c == 320 { "106".to_string() } else { "-".to_string() };
        table.row(&[c.to_string(), format!("{m:.1}"), paper]);
        pts.push((c as f64, m));
    }
    ExpResult { table, series: vec![("makespan s".into(), pts)] }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_paper_at_320_cores() {
        let m = run_point(320, 44);
        assert!((m - 106.0).abs() < 12.0, "reduction makespan {m}");
    }
}
