//! Multi-campaign interactive sessions under node-memory pressure —
//! the "extended period" caching regime the paper claims, stressed
//! until it breaks and managed back to health.
//!
//! Several beamline campaigns (each a catalogued dataset + hook spec)
//! share one machine whose combined staged footprint exceeds the
//! per-node RAM-disk budget. Scientists ping-pong between campaigns in
//! an interactive session; every activation stages its dataset and
//! runs an analysis wave over it. Two policies:
//!
//! - **full restage** — the pre-residency behaviour: every activation
//!   re-runs the whole hook, moving the entire dataset again;
//! - **residency** — the [`crate::staging::Residency`] manager:
//!   incremental re-stage of only the files LRU eviction displaced,
//!   pinning the active dataset, counting hits.
//!
//! Reported per policy: session turnaround, staged bytes, hit rate,
//! evicted bytes, and checksum mismatches (always zero — the data
//! plane is real and every replica is verified against the shared-FS
//! original after every activation).

use crate::catalog::Catalog;
use crate::cluster::{bgq, Topology};
use crate::dataflow::graph::{Task, TaskGraph};
use crate::dataflow::sched::{run_workflow, SchedulerCfg};
use crate::engine::SimCore;
use crate::metrics::Table;
use crate::mpisim::Comm;
use crate::pfs::{Blob, GpfsParams};
use crate::simtime::flownet::ThroughputMode;
use crate::simtime::plan::Plan;
use crate::staging::{staged_plan, HookSpec, Residency};
use crate::units::{fmt_bytes, Duration, MB};

use super::ExpResult;

/// Concurrent campaigns sharing the machine.
pub const CAMPAIGNS: usize = 3;
pub const FILES_PER_CAMPAIGN: usize = 16;
pub const FILE_BYTES: u64 = 16 * MB;
/// Per-campaign dataset footprint (256 MB).
pub const CAMPAIGN_BYTES: u64 = FILES_PER_CAMPAIGN as u64 * FILE_BYTES;
/// Per-node RAM-disk budget: holds 2.5 of the 3 campaigns, so the
/// combined 768 MB working set does not fit and LRU pressure is real.
pub const NODE_CAPACITY: u64 = 640 * MB;
/// The interactive activation order: campaigns A/B ping-pong with a
/// periodic C interleave (the third scientist checks in twice).
pub const SCHEDULE: &[usize] = &[0, 1, 0, 1, 0, 1, 2, 0, 1, 0, 1, 2];

/// One session's outcome under a policy.
#[derive(Clone, Copy, Debug)]
pub struct CampaignOutcome {
    /// Virtual session turnaround, seconds.
    pub session_secs: f64,
    /// Bytes the staging path actually moved from GPFS.
    pub staged_bytes: u64,
    /// File-level residency hit rate (0 for the full-restage policy).
    pub hit_rate: f64,
    /// Bytes displaced by LRU eviction (per-node bytes x node span).
    pub evicted_bytes: u64,
    /// Replicas that failed checksum verification (must be 0).
    pub checksum_mismatches: u64,
    pub activations: usize,
}

type DatasetBinding = (crate::catalog::DatasetId, HookSpec);

fn setup(
    nodes: u32,
    mode: ThroughputMode,
) -> (SimCore, Topology, Catalog, Vec<DatasetBinding>) {
    let mut core = SimCore::with_mode(mode);
    let topo = Topology::build(bgq(nodes), GpfsParams::default(), &mut core.net);
    // Narrow the machine's real budget to the scenario's staging
    // slice: /tmp also holds application state, and a 640 MB slice
    // against three 256 MB campaigns is what makes the working set
    // genuinely not fit. min() keeps the slice honest if a machine
    // ever models less than the slice. (BG/Q has no SSD tier, so
    // eviction here really discards — paper fidelity.)
    topo.apply_storage_budgets(&mut core);
    let budget = core.nodes.capacity().map_or(NODE_CAPACITY, |c| c.min(NODE_CAPACITY));
    core.nodes.set_capacity(Some(budget));
    let mut catalog = Catalog::new();
    let mut sets = Vec::new();
    for c in 0..CAMPAIGNS {
        for f in 0..FILES_PER_CAMPAIGN {
            core.pfs.write(
                format!("/projects/HEDM/campaign{c}/f{f:03}.bin"),
                Blob::synthetic(FILE_BYTES, 0xCA_0000 + (c * 1000 + f) as u64),
            );
        }
        let id = catalog.register(
            format!("campaign{c}"),
            format!("/projects/HEDM/campaign{c}"),
            FILES_PER_CAMPAIGN as u64,
            CAMPAIGN_BYTES,
        );
        catalog.set_attr(id, "technique", "nf-hedm");
        let spec = HookSpec::parse(&format!(
            "broadcast to /tmp/campaign{c} {{ /projects/HEDM/campaign{c}/*.bin }}"
        ))
        .unwrap();
        sets.push((id, spec));
    }
    (core, topo, catalog, sets)
}

/// One activation's analysis wave: every worker rank re-fits against
/// one of the campaign's staged files (round-robin over the dataset,
/// rotated per round so the whole dataset stays warm).
fn analysis_graph(comm: &Comm, c: usize, round: usize) -> TaskGraph {
    let mut g = TaskGraph::new();
    g.foreach(comm.size() as usize, |i| {
        let f = (i + round) % FILES_PER_CAMPAIGN;
        Task::compute(format!("r{round}/c{c}/fit{i}"), Duration::from_secs(5))
            .with_input(format!("/tmp/campaign{c}/f{f:03}.bin"), None)
    });
    g
}

/// Run the interactive session under one policy. `residency_mode`
/// selects incremental re-staging vs full restage per activation.
pub fn run_session(nodes: u32, residency_mode: bool, mode: ThroughputMode) -> CampaignOutcome {
    let (mut core, topo, catalog, sets) = setup(nodes, mode);
    let leader = Comm::leader(&topo.spec);
    let world = Comm::world(&topo.spec);
    let mut res = Residency::new();
    for (id, spec) in &sets {
        res.bind(*id, spec.clone());
    }
    // The catalogued footprint must genuinely exceed the node budget,
    // or the scenario degenerates to the unbounded-store regime.
    let footprint: u64 = sets.iter().map(|(id, _)| catalog.get(*id).unwrap().bytes).sum();
    assert!(footprint > NODE_CAPACITY, "campaign scenario requires memory pressure");
    let mut staged_bytes = 0u64;
    let mut mismatches = 0u64;
    for (round, &c) in SCHEDULE.iter().enumerate() {
        let (id, spec) = &sets[c];
        // (src, dst) pairs this activation delivered or reused.
        let delivered: Vec<(String, String)>;
        if residency_mode {
            let m = res.stage_dataset(&mut core, &topo, &leader, *id).unwrap();
            staged_bytes += m.staged_bytes;
            delivered = m.all_files().map(|t| (t.src.clone(), t.dst.clone())).collect();
        } else {
            let mut p = Plan::new(0);
            let (m, _done) =
                staged_plan(&mut p, &core.pfs, &topo, &leader, spec, vec![]).unwrap();
            // Symmetric with the residency policy: hold the active
            // dataset pinned while its transfer lands and its analysis
            // wave runs.
            for t in &m.transfers {
                core.nodes.pin(t.dst.clone());
            }
            core.submit(p);
            core.run_to_completion();
            staged_bytes += m.total_bytes;
            delivered = m.transfers.iter().map(|t| (t.src.clone(), t.dst.clone())).collect();
        }
        // Verify the data plane: every replica byte-identical to the
        // shared-FS original on representative nodes.
        for (src, dst) in &delivered {
            let want = core.pfs.read(src).expect("campaign file on PFS");
            for probe in [world.node_lo, (world.node_lo + world.node_hi) / 2, world.node_hi]
            {
                match core.nodes.read(probe, dst) {
                    Some(got) if got.same_content(want) => {}
                    _ => mismatches += 1,
                }
            }
        }
        // The analysis wave itself (locality-aware placement; on a
        // fully-replicated dataset it is identical to the baseline).
        let g = analysis_graph(&world, c, round);
        let cfg = SchedulerCfg { locality_aware: true, ..Default::default() };
        run_workflow(&mut core, &topo, &world, g, cfg);
        // Release the pins so the next campaign can claim the space.
        if residency_mode {
            res.unpin_dataset(&mut core, *id);
        } else {
            for (_, dst) in &delivered {
                core.nodes.unpin(dst);
            }
        }
    }
    debug_assert!(core.residency.mirrors(&core.nodes), "residency mirror diverged");
    // Every write in this scenario must have been admitted: a silent
    // rejection would mean the manifests over-promised.
    assert_eq!(core.node_write_rejections(), 0, "campaign write rejected under pressure");
    CampaignOutcome {
        session_secs: core.now.secs_f64(),
        staged_bytes,
        hit_rate: if residency_mode { res.stats.hit_rate() } else { 0.0 },
        evicted_bytes: core.residency.evicted_bytes,
        checksum_mismatches: mismatches,
        activations: SCHEDULE.len(),
    }
}

pub fn run() -> ExpResult {
    let nodes = 64;
    let full = run_session(nodes, false, ThroughputMode::Fast);
    let resi = run_session(nodes, true, ThroughputMode::Fast);
    let mut table = Table::new(
        format!(
            "Campaigns — {CAMPAIGNS} datasets x {} on {nodes} nodes, {} RAM disk, {} activations",
            fmt_bytes(CAMPAIGN_BYTES),
            fmt_bytes(NODE_CAPACITY),
            SCHEDULE.len(),
        ),
        &["policy", "session (s)", "staged", "hit rate", "evicted", "mismatches"],
    );
    for (name, o) in [("full restage", &full), ("residency", &resi)] {
        table.row(&[
            name.into(),
            format!("{:.1}", o.session_secs),
            fmt_bytes(o.staged_bytes),
            format!("{:.0}%", 100.0 * o.hit_rate),
            fmt_bytes(o.evicted_bytes),
            o.checksum_mismatches.to_string(),
        ]);
    }
    table.row(&[
        "saving".into(),
        format!("{:.1}", full.session_secs - resi.session_secs),
        format!("{:.1}x fewer", full.staged_bytes as f64 / resi.staged_bytes as f64),
        String::new(),
        String::new(),
        String::new(),
    ]);
    ExpResult {
        table,
        series: vec![
            (
                "staged MB".into(),
                vec![
                    (0.0, full.staged_bytes as f64 / MB as f64),
                    (1.0, resi.staged_bytes as f64 / MB as f64),
                ],
            ),
            (
                "session s".into(),
                vec![(0.0, full.session_secs), (1.0, resi.session_secs)],
            ),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn residency_stages_at_least_2x_fewer_bytes() {
        let full = run_session(16, false, ThroughputMode::Fast);
        let resi = run_session(16, true, ThroughputMode::Fast);
        assert_eq!(full.checksum_mismatches, 0, "full-restage data plane corrupt");
        assert_eq!(resi.checksum_mismatches, 0, "residency data plane corrupt");
        assert!(
            full.staged_bytes >= 2 * resi.staged_bytes,
            "residency must stage >=2x fewer bytes: full {} vs residency {}",
            full.staged_bytes,
            resi.staged_bytes
        );
        assert!(
            resi.session_secs <= full.session_secs,
            "residency {} s vs full {} s",
            resi.session_secs,
            full.session_secs
        );
        assert!(resi.hit_rate > 0.4, "hit rate {}", resi.hit_rate);
    }

    #[test]
    fn memory_pressure_is_real() {
        // The scenario only reproduces the paper's failure mode if the
        // working set genuinely exceeds the budget and evictions occur.
        assert!(CAMPAIGNS as u64 * CAMPAIGN_BYTES > NODE_CAPACITY);
        let resi = run_session(16, true, ThroughputMode::Fast);
        assert!(resi.evicted_bytes > 0, "no evictions — no pressure");
        // ...and yet some activations were pure cache hits.
        assert!(resi.hit_rate > 0.0);
    }

    #[test]
    fn throughput_models_agree_on_the_session() {
        for residency in [true, false] {
            let slow = run_session(8, residency, ThroughputMode::Slow);
            let fast = run_session(8, residency, ThroughputMode::Fast);
            assert!(
                (slow.session_secs - fast.session_secs).abs() < 1e-5,
                "residency={residency}: slow {} vs fast {}",
                slow.session_secs,
                fast.session_secs
            );
            assert_eq!(slow.staged_bytes, fast.staged_bytes);
            assert_eq!(slow.evicted_bytes, fast.evicted_bytes);
            assert_eq!(slow.checksum_mismatches, 0);
            assert_eq!(fast.checksum_mismatches, 0);
        }
    }
}
