//! Experiment drivers: one per table/figure in the paper's evaluation
//! (SVI). Each regenerates its figure as a paper-vs-measured table;
//! the CLI (`xstage <figN>`), the benches, and EXPERIMENTS.md all call
//! these, so there is exactly one implementation of every experiment.

pub mod cache;
pub mod campaign;
pub mod chaos;
pub mod elastic;
pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig13;
pub mod ingest;
pub mod reduction;
pub mod reuse;
pub mod scale;
pub mod serve;
pub mod tiers;

use crate::cluster::{bgq, Topology};
use crate::engine::SimCore;
use crate::pfs::{Blob, GpfsParams};
use crate::staging::HookSpec;
use crate::units::MB;

/// A single experiment outcome: the rendered table plus raw (x, y)
/// series for programmatic assertions in benches/tests.
#[derive(Clone, Debug)]
pub struct ExpResult {
    pub table: crate::metrics::Table,
    /// Named series: (label, points).
    pub series: Vec<(String, Vec<(f64, f64)>)>,
}

impl ExpResult {
    pub fn series_named(&self, label: &str) -> Option<&[(f64, f64)]> {
        self.series
            .iter()
            .find(|(l, _)| l == label)
            .map(|(_, pts)| pts.as_slice())
    }

    pub fn print(&self) {
        print!("{}", self.table.render());
    }
}

/// The SVI-B staged dataset: 577 MB in 64 files under /projects/HEDM.
pub const DATASET_BYTES: u64 = 577 * MB;
pub const DATASET_FILES: usize = 64;
pub const DATASET_GLOB: &str = "/projects/HEDM/layer0/*.bin";

/// Standard BG/Q experiment harness: core + topology + dataset + spec.
/// The machine's RAM-disk budget (8 GB/node on BG/Q) is live — the
/// 577 MB dataset fits comfortably, but the store is never unbounded.
pub fn bgq_setup(nodes: u32) -> (SimCore, Topology, HookSpec) {
    let mut core = SimCore::new();
    let topo = Topology::build(bgq(nodes), GpfsParams::default(), &mut core.net);
    topo.apply_storage_budgets(&mut core);
    let per_file = DATASET_BYTES / DATASET_FILES as u64;
    for i in 0..DATASET_FILES {
        core.pfs.write(
            format!("/projects/HEDM/layer0/f{i:04}.bin"),
            Blob::synthetic(per_file, 0xDA7A + i as u64),
        );
    }
    let spec =
        HookSpec::parse(&format!("broadcast to /tmp/hedm {{ {DATASET_GLOB} }}")).unwrap();
    (core, topo, spec)
}

/// Node counts swept by the BG/Q scaling figures.
pub const BGQ_SWEEP: &[u32] = &[512, 1024, 2048, 4096, 8192];

/// Orthros core counts swept by the cluster figures (1..=5 nodes of
/// 64 cores).
pub const ORTHROS_SWEEP: &[u32] = &[64, 128, 192, 256, 320];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn setup_creates_dataset() {
        let (core, topo, spec) = bgq_setup(64);
        assert_eq!(core.pfs.glob(DATASET_GLOB).len(), DATASET_FILES);
        assert_eq!(core.pfs.glob_bytes(DATASET_GLOB), DATASET_BYTES - DATASET_BYTES % 64);
        assert_eq!(topo.spec.nodes, 64);
        assert_eq!(spec.pattern_count(), 1);
    }
}
