//! The fleet-scale harness (`xstage scale`, `benches/scale.rs`):
//! thousands of BG/Q nodes times tens of thousands of concurrent
//! analysis sessions, run twice per matrix point — once on the seed
//! hot paths ([`PathMode::Seed`]: linear fair-pick scan, string-keyed
//! storage lookups) and once on the flattened ones ([`PathMode::Flat`]:
//! indexed fair pick, interned-id storage routing).
//!
//! The two modes are **bit-identical in virtual outcome** (asserted at
//! every point: same per-session finish times, same event count) —
//! the matrix measures pure host cost. Reported per point:
//!
//! - events/sec of engine throughput under each mode, and the speedup;
//! - host wall-time per simulated second (the interactivity budget:
//!   how much real time one virtual second of fleet costs);
//! - resident scheduler bytes per admitted session after the fleet
//!   drains (completed sessions must not hold graph storage);
//! - resident storage-bookkeeping bytes per interned path.
//!
//! Each session is a dependency *chain* of [`DEPTH`] tasks, so every
//! task completion re-runs the fair pick with the full concurrent
//! population live — the worst case for the seed's O(live) scan and
//! exactly the shape a long-lived serving core sees.

use std::time::Instant;

use crate::cluster::{bgq, Topology};
use crate::dataflow::sched::{SessionId, SessionScheduler};
use crate::dataflow::{FairPick, SchedulerCfg, Task, TaskGraph};
use crate::engine::{KernelStats, SimCore};
use crate::metrics::Table;
use crate::mpisim::Comm;
use crate::pfs::{Blob, GpfsParams};
use crate::simtime::flownet::ThroughputMode;
use crate::simtime::heap::HeapKind;
use crate::units::{fmt_bytes, Duration, SimTime, StateBytes, MB};

use super::ExpResult;

/// Fleet sizes swept, paired index-wise with [`SESSION_SWEEP`].
pub const NODE_SWEEP: &[u32] = &[512, 2048, 8192];
/// Concurrent sessions per point (all admitted up front).
pub const SESSION_SWEEP: &[u32] = &[1_000, 4_000, 10_000];
/// Tasks per session, chained by dependency.
pub const DEPTH: usize = 4;
/// Staged dataset files shared by all sessions (the SVI-B 64-file
/// layout, resident on every node).
pub const FILES: usize = 64;
pub const FILE_BYTES: u64 = 9 * MB;
/// Default deterministic seed for the matrix.
pub const SEED: u64 = 42;

/// Which hot-path implementations drive a run. Virtual outcomes are
/// identical; only host cost differs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PathMode {
    /// The pre-flattening implementations: linear fair-pick scan and
    /// string-keyed storage lookups on every task.
    Seed,
    /// Indexed fair pick + admission-time path interning.
    Flat,
}

impl PathMode {
    pub fn cfg(self) -> SchedulerCfg {
        let (fair_pick, interned_paths) = match self {
            PathMode::Seed => (FairPick::Scan, false),
            PathMode::Flat => (FairPick::Indexed, true),
        };
        SchedulerCfg { cache_inputs: true, fair_pick, interned_paths, ..Default::default() }
    }
}

/// One (nodes, sessions, mode) run's measurements.
#[derive(Clone, Debug)]
pub struct ScaleOutcome {
    pub nodes: u32,
    pub sessions: usize,
    /// Host seconds from first admission to fleet drain.
    pub host_secs: f64,
    /// Virtual clock at drain.
    pub now: SimTime,
    /// Engine events processed.
    pub events: u64,
    /// Per-session finish times (the cross-mode identity witness).
    pub finished: Vec<SimTime>,
    /// Scheduler bookkeeping bytes over admitted sessions, post-drain.
    pub sched_state: StateBytes,
    /// Node-store bookkeeping bytes over interned paths.
    pub store_state: StateBytes,
    /// Residency-mirror bookkeeping bytes over interned paths.
    pub residency_state: StateBytes,
    /// Kernel observability: event-heap occupancy peaks and the
    /// stale-check economy (`BENCH_scale.json` carries these as
    /// counter lines).
    pub kernel: KernelStats,
}

impl ScaleOutcome {
    pub fn events_per_sec(&self) -> f64 {
        self.events as f64 / self.host_secs.max(1e-9)
    }

    /// Host seconds spent per simulated second (interactivity budget).
    pub fn wall_per_sim_sec(&self) -> f64 {
        self.host_secs / self.now.secs_f64().max(1e-9)
    }

    /// Events minus stale flow-check pops — identical across event-heap
    /// backends (the wheel reclaims would-be stale pops eagerly), so
    /// the cross-kernel comparison figure.
    pub fn useful_events(&self) -> u64 {
        self.events - self.kernel.stale_check_pops
    }
}

/// The session workload: a chain of [`DEPTH`] tasks, each reading one
/// staged dataset file, runtimes log-uniform in 5–50 s. Seeded per
/// session, so the workload is identical across modes by construction.
pub fn session_graph(seed: u64, session: u64) -> TaskGraph {
    let mut rng =
        crate::util::prng::Pcg64::new(seed ^ (session + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let mut g = TaskGraph::new();
    let mut prev = None;
    for t in 0..DEPTH {
        let file = rng.range_u64(0, FILES as u64 - 1);
        let mut task = Task::compute(
            format!("s{session}/t{t}"),
            Duration::from_secs_f64(rng.log_uniform(5.0, 50.0)),
        )
        .with_input(format!("/tmp/hedm/f{file:04}.bin"), None);
        if let Some(p) = prev {
            task = task.with_dep(p);
        }
        prev = Some(g.add(task));
    }
    g
}

/// Run one matrix point: build the BG/Q fleet, stage the dataset on
/// every node, admit all sessions, and drain.
pub fn run_point(nodes: u32, sessions: usize, mode: PathMode, seed: u64) -> ScaleOutcome {
    run_point_kernel(nodes, sessions, mode, seed, HeapKind::default())
}

/// [`run_point`] with an explicit event-heap backend (`Seed` is the
/// differential baseline for `benches/kernel.rs` and the kernel
/// property suite).
pub fn run_point_kernel(
    nodes: u32,
    sessions: usize,
    mode: PathMode,
    seed: u64,
    kind: HeapKind,
) -> ScaleOutcome {
    let mut core = SimCore::with_parts(ThroughputMode::Fast, kind);
    let topo = Topology::build(bgq(nodes), GpfsParams::default(), &mut core.net);
    topo.apply_storage_budgets(&mut core);
    for i in 0..FILES {
        core.node_write_range(
            0,
            nodes - 1,
            &format!("/tmp/hedm/f{i:04}.bin"),
            Blob::synthetic(FILE_BYTES, 0x5CA1E + i as u64),
        );
    }
    let comm = Comm::world(&topo.spec);
    let mut ss = SessionScheduler::new(topo, comm, mode.cfg());
    let t0 = Instant::now();
    for s in 0..sessions {
        ss.add_session(&mut core, session_graph(seed, s as u64));
    }
    core.run(&mut ss);
    let host_secs = t0.elapsed().as_secs_f64();
    assert!(ss.all_done(), "scale point left incomplete sessions");
    let finished = (0..sessions).map(|i| ss.stats(SessionId(i as u32)).finished).collect();
    let paths = core.nodes.interned_paths() as u64;
    ScaleOutcome {
        nodes,
        sessions,
        host_secs,
        now: core.now,
        events: core.events_processed,
        finished,
        sched_state: StateBytes::new(ss.state_bytes(), sessions as u64),
        store_state: StateBytes::new(core.nodes.state_bytes(), paths),
        residency_state: StateBytes::new(core.residency.state_bytes(), paths),
        kernel: core.kernel_stats(),
    }
}

/// Run both modes at one point and assert the virtual outcomes match
/// bit-for-bit.
pub fn run_point_both(nodes: u32, sessions: usize, seed: u64) -> (ScaleOutcome, ScaleOutcome) {
    let seed_out = run_point(nodes, sessions, PathMode::Seed, seed);
    let flat_out = run_point(nodes, sessions, PathMode::Flat, seed);
    assert_eq!(seed_out.now, flat_out.now, "virtual clock diverged at {nodes} nodes");
    assert_eq!(seed_out.events, flat_out.events, "event count diverged at {nodes} nodes");
    assert_eq!(
        seed_out.finished, flat_out.finished,
        "session finish times diverged at {nodes} nodes"
    );
    (seed_out, flat_out)
}

/// Run the matrix (`nodes[i]` paired with `sessions[i]`) and render
/// the comparison table. Host-time columns vary with the machine (and
/// with `XSTAGE_JOBS` — points time-share cores under the parallel
/// runner); the virtual columns and the seed/flat identity do not.
pub fn run_with(nodes: &[u32], sessions: &[u32], seed: u64) -> ExpResult {
    run_with_jobs(nodes, sessions, seed, crate::util::par::jobs_from_env())
}

/// [`run_with`] with an explicit worker count.
pub fn run_with_jobs(nodes: &[u32], sessions: &[u32], seed: u64, jobs: usize) -> ExpResult {
    assert_eq!(nodes.len(), sessions.len(), "--nodes and --sessions must pair up");
    let mut table = Table::new(
        "Scale — fleet matrix, seed vs flattened hot paths (identical virtual outcomes)"
            .to_string(),
        &[
            "nodes",
            "sessions",
            "seed ev/s",
            "flat ev/s",
            "speedup",
            "ms-host/sim-s",
            "B/session",
            "B/path",
        ],
    );
    let mut speedup_pts = Vec::new();
    let mut evps_pts = Vec::new();
    let pts: Vec<(u32, u32)> = nodes.iter().copied().zip(sessions.iter().copied()).collect();
    let results = crate::util::par::matrix_map_jobs(pts.clone(), jobs, |(n, s)| {
        run_point_both(n, s as usize, seed)
    });
    // Table and series fold serially over the ordered results.
    for ((n, s), (seed_out, flat_out)) in pts.into_iter().zip(&results) {
        let speedup = flat_out.events_per_sec() / seed_out.events_per_sec().max(1e-9);
        table.row(&[
            n.to_string(),
            s.to_string(),
            format!("{:.0}", seed_out.events_per_sec()),
            format!("{:.0}", flat_out.events_per_sec()),
            format!("{speedup:.1}x"),
            format!("{:.3}", flat_out.wall_per_sim_sec() * 1e3),
            fmt_bytes(flat_out.sched_state.per_unit()),
            fmt_bytes(flat_out.store_state.per_unit()),
        ]);
        speedup_pts.push((n as f64, speedup));
        evps_pts.push((n as f64, flat_out.events_per_sec()));
    }
    ExpResult {
        table,
        series: vec![
            ("speedup".into(), speedup_pts),
            ("flat events/sec".into(), evps_pts),
        ],
    }
}

pub fn run() -> ExpResult {
    run_with(NODE_SWEEP, SESSION_SWEEP, SEED)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seed_and_flat_agree_on_a_small_point() {
        // The identity assertions live inside run_point_both; at a
        // debug-build point the Indexed mode additionally cross-checks
        // the scan on every single pick.
        let (seed_out, flat_out) = run_point_both(16, 60, 7);
        assert_eq!(seed_out.finished.len(), 60);
        assert!(seed_out.events > 0);
        assert!(flat_out.now > SimTime::ZERO);
    }

    #[test]
    fn drained_fleet_keeps_per_session_state_small() {
        let out = run_point(8, 50, PathMode::Flat, 3);
        // Completed sessions released graph/cache/id storage: the
        // post-drain scheduler footprint per admitted session is a
        // few hundred bytes (header + completion times), never the
        // admitted graph.
        assert!(
            out.sched_state.per_unit() < 1024,
            "resident {} per session",
            out.sched_state.per_unit()
        );
        assert_eq!(out.store_state.units, (FILES) as u64);
    }

    #[test]
    fn workload_is_deterministic_per_seed() {
        let a = session_graph(9, 4);
        let b = session_graph(9, 4);
        assert_eq!(a.len(), DEPTH);
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.runtime, y.runtime);
            assert_eq!(x.inputs[0].path, y.inputs[0].path);
        }
        // Different sessions draw different chains.
        let c = session_graph(9, 5);
        assert!(a.tasks.iter().zip(&c.tasks).any(|(x, y)| x.runtime != y.runtime));
    }

    #[test]
    fn table_renders_with_speedup_series() {
        let r = run_with(&[8], &[40], 5);
        assert_eq!(r.table.rows.len(), 1);
        let sp = r.series_named("speedup").unwrap();
        assert_eq!(sp.len(), 1);
        assert!(sp[0].1 > 0.0);
    }
}
