//! Fig 11 + SVI-B wall times: end-to-end input performance, Swift I/O
//! hook vs the naive per-task GPFS baseline.
//!
//! Paper: staged end-to-end reaches **101 GB/s** on 8,192 nodes vs
//! **21 GB/s** naive; wall time drops from **210 s to 46.75 s** (4.7x);
//! the Read phase is a flat **10.8 +/- 0.1 s** (53.4 MB/s/process) at
//! every allocation size.

use crate::metrics::Table;
use crate::mpisim::Comm;
use crate::simtime::plan::Plan;
use crate::staging::{naive_plan, read_phase, staged_plan};
use crate::units::GB;

use super::{bgq_setup, ExpResult, BGQ_SWEEP, DATASET_BYTES};

/// Phase breakdown of one staged run.
#[derive(Clone, Copy, Debug)]
pub struct StagedPhases {
    pub stage_write_secs: f64,
    pub read_secs: f64,
    pub total_secs: f64,
}

/// Staged path: hook + per-process read phase. Returns phases.
pub fn run_staged(nodes: u32) -> StagedPhases {
    let (mut core, topo, spec) = bgq_setup(nodes);
    let leader = Comm::leader(&topo.spec);
    let world = Comm::world(&topo.spec);
    let mut p = Plan::new(0);
    let (manifest, done) =
        staged_plan(&mut p, &core.pfs, &topo, &leader, &spec, vec![]).unwrap();
    read_phase(&mut p, &topo, &world, manifest.total_bytes, vec![done]);
    core.submit(p);
    core.run_to_completion();
    let stage_write = core.metrics.phase_window("write").unwrap().1.secs_f64();
    let (read_start, read_end) = core.metrics.phase_window("read").unwrap();
    StagedPhases {
        stage_write_secs: stage_write,
        read_secs: (read_end - read_start).secs_f64(),
        total_secs: core.now.secs_f64(),
    }
}

/// Naive path: uncoordinated per-task reads. Returns wall seconds.
pub fn run_naive(nodes: u32) -> f64 {
    let (mut core, topo, spec) = bgq_setup(nodes);
    let world = Comm::world(&topo.spec);
    let mut p = Plan::new(0);
    naive_plan(&mut p, &core.pfs, &topo, &world, &spec, vec![]).unwrap();
    core.submit(p);
    core.run_to_completion();
    core.now.secs_f64()
}

/// Sweep points fan out across `XSTAGE_JOBS` workers (independent —
/// the table is byte-identical at any worker count).
pub fn run(sweep: &[u32]) -> ExpResult {
    run_jobs(sweep, crate::util::par::jobs_from_env())
}

/// [`run`] with an explicit worker count.
pub fn run_jobs(sweep: &[u32], jobs: usize) -> ExpResult {
    let mut table = Table::new(
        "Fig 11 — End-to-end input bandwidth: I/O hook vs naive (577 MB/node)",
        &[
            "nodes",
            "staged (s)",
            "read (s)",
            "staged GB/s",
            "naive (s)",
            "naive GB/s",
            "speedup",
        ],
    );
    let mut staged_pts = Vec::new();
    let mut naive_pts = Vec::new();
    let results = crate::util::par::matrix_map_jobs(sweep.to_vec(), jobs, |n| {
        (run_staged(n), run_naive(n))
    });
    for (&n, &(s, naive_secs)) in sweep.iter().zip(&results) {
        let bytes = n as f64 * DATASET_BYTES as f64;
        let s_bw = bytes / s.total_secs / GB as f64;
        let n_bw = bytes / naive_secs / GB as f64;
        table.row(&[
            n.to_string(),
            format!("{:.2}", s.total_secs),
            format!("{:.2}", s.read_secs),
            format!("{s_bw:.1}"),
            format!("{naive_secs:.1}"),
            format!("{n_bw:.1}"),
            format!("{:.1}x", naive_secs / s.total_secs),
        ]);
        staged_pts.push((n as f64, s_bw));
        naive_pts.push((n as f64, n_bw));
    }
    ExpResult {
        table,
        series: vec![
            ("staged GB/s".into(), staged_pts),
            ("naive GB/s".into(), naive_pts),
        ],
    }
}

pub fn default() -> ExpResult {
    run(BGQ_SWEEP)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_numbers_at_8192() {
        let s = run_staged(8192);
        let n = run_naive(8192);
        // SVI-B: 46.75 s vs 210 s, read flat at 10.8 s.
        assert!((s.total_secs - 46.75).abs() < 2.5, "staged {}", s.total_secs);
        assert!((s.read_secs - 10.8).abs() < 0.2, "read {}", s.read_secs);
        assert!((n - 210.0).abs() < 25.0, "naive {n}");
        let speedup = n / s.total_secs;
        assert!((speedup - 4.7).abs() < 0.7, "speedup {speedup}");
        // Fig 11: 101 vs 21 GB/s.
        let bytes = 8192.0 * DATASET_BYTES as f64;
        assert!((bytes / s.total_secs / GB as f64 - 101.0).abs() < 6.0);
        assert!((bytes / n / GB as f64 - 21.0).abs() < 3.0);
    }

    #[test]
    fn read_phase_flat_across_scales() {
        // "The Read phase consistently takes 10.8 +/- 0.1 s regardless
        // of allocation size."
        let small = run_staged(512);
        let large = run_staged(4096);
        assert!((small.read_secs - large.read_secs).abs() < 0.1);
        assert!((small.read_secs - 10.8).abs() < 0.2);
    }

    #[test]
    fn hook_advantage_grows_with_scale() {
        // The crossover shape: naive is competitive small, loses big.
        let r512 = run_naive(512) / run_staged(512).total_secs;
        let r8192 = run_naive(8192) / run_staged(8192).total_secs;
        assert!(r8192 > r512 * 1.5, "512: {r512}, 8192: {r8192}");
    }
}
