//! Fig 10: "Staging+Write performance for NF-HEDM" — aggregate
//! bandwidth of the Swift I/O hook (GPFS -> node-local RAM disk) as a
//! function of node count. Paper endpoint: "at our highest reported
//! node count, 8,192 nodes, the system delivers data at an aggregate
//! rate of 134 GB/s".

use crate::metrics::Table;
use crate::mpisim::Comm;
use crate::simtime::plan::Plan;
use crate::staging::staged_plan;
use crate::units::GB;

use super::{bgq_setup, ExpResult, BGQ_SWEEP, DATASET_BYTES};

/// One sweep point: staging+write wall time and aggregate bandwidth.
pub fn run_point(nodes: u32) -> (f64, f64) {
    let (mut core, topo, spec) = bgq_setup(nodes);
    let comm = Comm::leader(&topo.spec);
    let mut p = Plan::new(0);
    staged_plan(&mut p, &core.pfs, &topo, &comm, &spec, vec![]).unwrap();
    core.submit(p);
    core.run_to_completion();
    let secs = core.now.secs_f64();
    let agg = nodes as f64 * DATASET_BYTES as f64 / secs;
    (secs, agg)
}

/// Sweep points fan out across `XSTAGE_JOBS` workers (independent —
/// the table is byte-identical at any worker count).
pub fn run(sweep: &[u32]) -> ExpResult {
    run_jobs(sweep, crate::util::par::jobs_from_env())
}

/// [`run`] with an explicit worker count.
pub fn run_jobs(sweep: &[u32], jobs: usize) -> ExpResult {
    let mut table = Table::new(
        "Fig 10 — Staging+Write aggregate bandwidth (577 MB replica -> every node)",
        &["nodes", "time (s)", "agg GB/s", "paper GB/s (8192: 134)"],
    );
    let mut pts = Vec::new();
    let results = crate::util::par::matrix_map_jobs(sweep.to_vec(), jobs, run_point);
    for (&n, &(secs, agg)) in sweep.iter().zip(&results) {
        let paper = if n == 8192 { "134".to_string() } else { "~linear".to_string() };
        table.row(&[
            n.to_string(),
            format!("{secs:.2}"),
            format!("{:.1}", agg / GB as f64),
            paper,
        ]);
        pts.push((n as f64, agg / GB as f64));
    }
    ExpResult { table, series: vec![("staging+write GB/s".into(), pts)] }
}

pub fn default() -> ExpResult {
    run(BGQ_SWEEP)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoint_matches_paper() {
        let (secs, agg) = run_point(8192);
        // Paper: ~35 s, 134 GB/s.
        assert!((agg / GB as f64 - 134.0).abs() < 8.0, "agg={}", agg / GB as f64);
        assert!((secs - 35.2).abs() < 2.0, "{secs}");
    }

    #[test]
    fn scaling_is_near_linear() {
        let r = run(&[512, 2048, 8192]);
        let pts = r.series_named("staging+write GB/s").unwrap();
        // Aggregate bandwidth grows ~proportionally with nodes (the
        // ION layer scales with the allocation).
        let slope1 = pts[1].1 / pts[0].1;
        let slope2 = pts[2].1 / pts[1].1;
        assert!((slope1 - 4.0).abs() < 0.8, "{slope1}");
        assert!((slope2 - 4.0).abs() < 0.8, "{slope2}");
    }
}
