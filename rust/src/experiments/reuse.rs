//! SI future-work experiment: "For interactive analysis, the staged
//! data could be reused over several human-in-the-loop cycles
//! (although we do not address that here)." We address it: compare
//! restaging the working set on every analysis cycle against staging
//! once and reusing the node-local replicas, over a session of
//! parameter-tweaking cycles on the same layer.

use crate::dataflow::graph::{Task, TaskGraph};
use crate::dataflow::sched::{run_workflow, SchedulerCfg};
use crate::metrics::Table;
use crate::mpisim::Comm;
use crate::simtime::plan::Plan;
use crate::staging::{read_phase, staged_plan};
use crate::units::Duration;

use super::{bgq_setup, ExpResult};

/// One analysis cycle's compute: a short re-fit pass (the scientist
/// tweaked a threshold and reruns) — 2 waves of 20 s tasks.
fn cycle_graph(comm: &Comm, staged_path: &str, cycle: u64) -> TaskGraph {
    let mut g = TaskGraph::new();
    let n = comm.size() as usize * 2;
    g.foreach(n, |i| {
        Task::compute(
            format!("c{cycle}/fit{i}"),
            Duration::from_secs(20),
        )
        .with_input(staged_path.to_string(), None)
    });
    g
}

/// Run a `cycles`-cycle interactive session; returns total seconds.
pub fn run_session(nodes: u32, cycles: u32, restage_each: bool) -> f64 {
    let (mut core, topo, spec) = bgq_setup(nodes);
    let leader = Comm::leader(&topo.spec);
    let world = Comm::world(&topo.spec);
    let mut staged_path = String::new();
    for c in 0..cycles {
        if restage_each || c == 0 {
            let mut p = Plan::new(0);
            let (m, done) =
                staged_plan(&mut p, &core.pfs, &topo, &leader, &spec, vec![]).unwrap();
            read_phase(&mut p, &topo, &world, m.total_bytes, vec![done]);
            staged_path = m.transfers[0].dst.clone();
            core.submit(p);
            core.run_to_completion();
        }
        let g = cycle_graph(&world, &staged_path, c as u64);
        let cfg = SchedulerCfg { cache_inputs: true, ..Default::default() };
        run_workflow(&mut core, &topo, &world, g, cfg);
    }
    core.now.secs_f64()
}

pub fn run() -> ExpResult {
    let nodes = 2048;
    let cycles = 5;
    let restage = run_session(nodes, cycles, true);
    let reuse = run_session(nodes, cycles, false);
    let mut table = Table::new(
        format!(
            "SI future work — staged-data reuse over {cycles} interactive cycles ({nodes} nodes)"
        ),
        &["policy", "session (s)", "per cycle (s)"],
    );
    table.row(&[
        "restage every cycle".into(),
        format!("{restage:.1}"),
        format!("{:.1}", restage / cycles as f64),
    ]);
    table.row(&[
        "stage once, reuse".into(),
        format!("{reuse:.1}"),
        format!("{:.1}", reuse / cycles as f64),
    ]);
    table.row(&[
        "saving".into(),
        format!("{:.1}", restage - reuse),
        format!("{:.0}%", 100.0 * (1.0 - reuse / restage)),
    ]);
    ExpResult {
        table,
        series: vec![(
            "session s".into(),
            vec![(0.0, restage), (1.0, reuse)],
        )],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuse_beats_restaging() {
        let restage = run_session(512, 3, true);
        let reuse = run_session(512, 3, false);
        // Each avoided restage saves roughly one staging+read pass.
        assert!(reuse < restage - 2.0 * 40.0, "restage {restage}, reuse {reuse}");
    }

    #[test]
    fn single_cycle_policies_equal() {
        let a = run_session(512, 1, true);
        let b = run_session(512, 1, false);
        assert_eq!(a, b);
    }
}
