//! The tiered-storage experiment: demote-to-SSD eviction vs the
//! discard-eviction baseline, across a working-set x RAM-budget x
//! SSD-budget matrix.
//!
//! Every matrix point runs the same seeded interactive serve workload
//! twice on an Orthros-class cluster whose per-node RAM staging slice
//! is **smaller than the total working set** (so closed datasets get
//! evicted) while RAM + SSD together hold it (so demotion preserves
//! them):
//!
//! - **tiered** — the SSD slice is live: eviction demotes, re-opens
//!   promote back over the local SSD link;
//! - **discard** — the SSD tier is disabled (`ssd_slice = Some(0)`):
//!   eviction destroys the replica and every re-open pays a full GPFS
//!   re-stage, the pre-tiering behaviour.
//!
//! The acceptance bar (asserted by `benches/tiers.rs` and the
//! integration tests): at every matrix point where the working set
//! overflows RAM but fits RAM+SSD, tiered serving beats the discard
//! baseline on P99 session turnaround, moves strictly fewer GPFS
//! bytes, suffers zero checksum mismatches (every stage is verified
//! against the shared-FS originals by `Residency::commit_stage`), and
//! reproduces bit-identically across same-seed runs.

use crate::metrics::Table;
use crate::simtime::flownet::ThroughputMode;
use crate::staging::service::{run_serve, ServeMode, ServeOutcome, ServiceCfg};
use crate::units::{fmt_bytes, MB};

use super::ExpResult;

/// Orthros-class fat nodes per scenario.
pub const NODES: u32 = 2;
/// Sessions per scenario run.
pub const SESSIONS: usize = 12;
/// Distinct datasets the sessions ping-pong over.
pub const DATASETS: usize = 4;
/// Mean inter-arrival gap (seconds): bursty enough that re-opens of
/// evicted datasets sit on session critical paths.
pub const MEAN_GAP_SECS: f64 = 15.0;

/// Working sets swept: (files per dataset, bytes per file). The total
/// working set is `DATASETS x files x bytes` — datasets are large
/// enough that a GPFS re-stage is a visible chunk of a session's
/// critical path.
pub const WS_SWEEP: &[(usize, u64)] = &[(6, 64 * MB), (10, 64 * MB)];
/// RAM budgets swept, as fractions of the total working set — all
/// below `2 / DATASETS`, so at most one dataset is ever open
/// (admission is head-of-line FIFO): the admission chain serialises
/// and every re-stage second pushes the tail turnaround directly.
pub const RAM_FRACS: &[f64] = &[0.30, 0.45];
/// SSD budgets swept, as fractions of the total working set — chosen
/// so RAM + SSD always covers it (the "fits SSD" half of the claim).
pub const SSD_FRACS: &[f64] = &[0.80, 1.00];

/// One matrix point's scenario.
#[derive(Clone, Copy, Debug)]
pub struct TierPoint {
    pub files_per_dataset: usize,
    pub file_bytes: u64,
    /// Per-node RAM staging slice, bytes.
    pub ram_budget: u64,
    /// Per-node SSD slice, bytes (the tiered run; the discard run
    /// disables the tier).
    pub ssd_budget: u64,
}

impl TierPoint {
    pub fn working_set(&self) -> u64 {
        DATASETS as u64 * self.files_per_dataset as u64 * self.file_bytes
    }

    pub fn dataset_bytes(&self) -> u64 {
        self.files_per_dataset as u64 * self.file_bytes
    }

    /// The regime the experiment claims a win in: working set
    /// overflows RAM but fits RAM + SSD; each dataset is individually
    /// RAM-admissible yet two never fit together, so dataset
    /// transitions (and their re-stages) sit on the serial admission
    /// chain.
    pub fn overflow_regime(&self) -> bool {
        self.working_set() > self.ram_budget
            && self.working_set() <= self.ram_budget + self.ssd_budget
            && self.dataset_bytes() <= self.ram_budget
            && 2 * self.dataset_bytes() > self.ram_budget
    }

    pub fn cfg(&self, ssd: bool, sessions: usize, seed: u64) -> ServiceCfg {
        ServiceCfg {
            seed,
            sessions,
            mean_gap_secs: MEAN_GAP_SECS,
            datasets: DATASETS,
            files_per_dataset: self.files_per_dataset,
            file_bytes: self.file_bytes,
            ramdisk_slice: Some(self.ram_budget),
            ssd_slice: Some(if ssd { self.ssd_budget } else { 0 }),
            mode: ServeMode::Staged,
            ..Default::default()
        }
    }
}

/// The full matrix (working set x RAM budget x SSD budget). Every
/// point satisfies [`TierPoint::overflow_regime`] by construction —
/// asserted, so a sweep edit cannot silently leave the claimed regime.
pub fn matrix() -> Vec<TierPoint> {
    let mut pts = Vec::new();
    for &(files_per_dataset, file_bytes) in WS_SWEEP {
        let ws = DATASETS as u64 * files_per_dataset as u64 * file_bytes;
        for &rf in RAM_FRACS {
            for &sf in SSD_FRACS {
                let pt = TierPoint {
                    files_per_dataset,
                    file_bytes,
                    ram_budget: (ws as f64 * rf) as u64,
                    ssd_budget: (ws as f64 * sf) as u64,
                };
                assert!(pt.overflow_regime(), "matrix point outside the claimed regime: {pt:?}");
                pts.push(pt);
            }
        }
    }
    pts
}

/// Run one matrix point under both eviction policies with the same
/// seed: (tiered, discard).
pub fn run_point(pt: &TierPoint, sessions: usize, seed: u64) -> (ServeOutcome, ServeOutcome) {
    let tiered = run_serve(NODES, &pt.cfg(true, sessions, seed), ThroughputMode::Fast);
    let discard = run_serve(NODES, &pt.cfg(false, sessions, seed), ThroughputMode::Fast);
    (tiered, discard)
}

/// Run the whole matrix and render the comparison table. Points fan
/// out across `XSTAGE_JOBS` workers (seeded, independent — the table
/// is byte-identical at any worker count).
pub fn run_with(sessions: usize, seed: u64) -> ExpResult {
    run_with_jobs(sessions, seed, crate::util::par::jobs_from_env())
}

/// [`run_with`] with an explicit worker count.
pub fn run_with_jobs(sessions: usize, seed: u64, jobs: usize) -> ExpResult {
    let mut table = Table::new(
        format!(
            "Tiers — demote-to-SSD vs discard eviction, {sessions} sessions/point, \
             {DATASETS} datasets (turnaround seconds)"
        ),
        &[
            "working set",
            "RAM",
            "SSD",
            "tiered P50",
            "tiered P99",
            "discard P50",
            "discard P99",
            "P99 win",
            "GPFS saved",
            "promoted",
        ],
    );
    let mut tiered_pts = Vec::new();
    let mut discard_pts = Vec::new();
    let pts = matrix();
    let results =
        crate::util::par::matrix_map_jobs(pts.clone(), jobs, |pt| run_point(&pt, sessions, seed));
    // Table and series fold serially over the ordered results.
    for (i, (pt, (t, d))) in pts.iter().zip(&results).enumerate() {
        let (tp, dp) = (t.percentiles.unwrap(), d.percentiles.unwrap());
        table.row(&[
            fmt_bytes(pt.working_set()),
            fmt_bytes(pt.ram_budget),
            fmt_bytes(pt.ssd_budget),
            format!("{:.1}", tp.p50),
            format!("{:.1}", tp.p99),
            format!("{:.1}", dp.p50),
            format!("{:.1}", dp.p99),
            format!("{:.2}x", dp.p99 / tp.p99),
            format!(
                "{:.1}x fewer",
                d.staged_bytes as f64 / t.staged_bytes.max(1) as f64
            ),
            fmt_bytes(t.promoted_bytes),
        ]);
        tiered_pts.push((i as f64, tp.p99));
        discard_pts.push((i as f64, dp.p99));
    }
    ExpResult {
        table,
        series: vec![
            ("tiered p99".into(), tiered_pts),
            ("discard p99".into(), discard_pts),
        ],
    }
}

pub fn run() -> ExpResult {
    run_with(SESSIONS, ServiceCfg::default().seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_covers_all_dimensions_in_regime() {
        let pts = matrix();
        assert_eq!(pts.len(), WS_SWEEP.len() * RAM_FRACS.len() * SSD_FRACS.len());
        assert!(pts.iter().all(TierPoint::overflow_regime));
        assert!(pts.iter().any(|p| p.working_set() != pts[0].working_set()));
        assert!(pts.iter().any(|p| p.ram_budget != pts[0].ram_budget));
        assert!(pts.iter().any(|p| p.ssd_budget != pts[0].ssd_budget));
    }

    #[test]
    fn tiered_beats_discard_at_extreme_points() {
        // The full matrix is the bench's job; the tightest and the
        // loosest RAM budgets must both show the tiered P99 win, the
        // GPFS byte saving, and live tier traffic.
        let pts = matrix();
        let tight = pts.iter().min_by_key(|p| p.ram_budget).unwrap();
        let loose = pts.iter().max_by_key(|p| p.ram_budget).unwrap();
        for pt in [tight, loose] {
            let (t, d) = run_point(pt, 8, 42);
            let (tp, dp) = (t.percentiles.unwrap(), d.percentiles.unwrap());
            assert!(
                tp.p99 < dp.p99,
                "tiered P99 {} vs discard P99 {} at {pt:?}",
                tp.p99,
                dp.p99
            );
            assert!(t.staged_bytes < d.staged_bytes, "no GPFS saving at {pt:?}");
            assert!(t.promoted_bytes > 0 && t.demoted_bytes > 0, "tier idle at {pt:?}");
            assert_eq!(d.promoted_bytes, 0, "discard baseline must not promote");
        }
    }

    #[test]
    fn tiers_experiment_table_renders() {
        let r = run_with(6, 7);
        assert_eq!(r.table.rows.len(), matrix().len());
        let p99s = r.series_named("tiered p99").unwrap();
        assert_eq!(p99s.len(), matrix().len());
        assert!(p99s.iter().all(|&(_, y)| y > 0.0));
    }
}
