//! `xstage` — leader entrypoint for the staging framework.
//!
//! See `xstage --help` / [`xstage::cli::usage`].

use xstage::cli;
use xstage::util::args::Args;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if args.has("help") || args.command.as_deref() == Some("help") {
        println!("{}", cli::usage());
        return;
    }
    if let Err(e) = cli::dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
