//! # xstage — Big Data Staging with MPI-IO for Interactive X-ray Science
//!
//! A production-quality reproduction of Wozniak et al., *"Big Data
//! Staging with MPI-IO for Interactive X-ray Science"*: the Swift/T
//! **I/O hook** (collective MPI-IO staging of shared input data into
//! compute-node-local storage) driving **HEDM** (high-energy
//! diffraction microscopy) many-task analysis workflows.
//!
//! The paper's testbed — an 8,192-node IBM Blue Gene/Q with a 240 GB/s
//! GPFS installation, the 320-core Orthros cluster at the APS, and a
//! synchrotron beamline detector — is reproduced as a deterministic
//! flow-level discrete-event simulation whose *data plane is real*:
//! files hold actual bytes, the staging hook really replicates them
//! into per-node stores, the reduction and orientation-fitting math
//! really runs (through AOT-compiled JAX/Pallas artifacts on the PJRT
//! CPU client), and ground-truth grain orientations are genuinely
//! recovered. Only *time* and *scale* are modeled.
//!
//! ## Layer map (see DESIGN.md)
//!
//! - [`simtime`] — event heap, max-min fair-share flow network (slow
//!   reference + fast component-incremental throughput models behind
//!   `flownet::ThroughputModel`), plan DAGs
//! - [`engine`] — the simulation core executing plans over a machine
//! - [`pfs`] — GPFS-like parallel filesystem (striping, metadata server)
//! - [`cluster`] — BG/Q and Orthros machine models (torus, I/O nodes,
//!   per-tier storage budgets and the SSD link class)
//! - [`storage`] — the multi-tier node-local storage subsystem:
//!   RAM tier + SSD demotion tier ([`storage::NodeStores`]), the
//!   per-tier residency mirror, and [`storage::StorageTier`]
//! - [`mpisim`] — MPI substrate: communicators, broadcast, two-phase
//!   collective file read (`MPI_File_read_all`)
//! - [`staging`] — **the paper's contribution**: the Swift I/O hook,
//!   the naive per-task baseline, residency-managed re-staging, and
//!   the interactive multi-session serving layer (`staging::service`)
//! - [`dataflow`] — Swift/T-like engine: futures, `foreach`, ADLB-style
//!   load balancing, the worker-local input cache
//! - [`hedm`] — the science: detector simulator, stage-1 reduction,
//!   connected components, NF/FF stage-2 orientation fitting/indexing
//! - [`runtime`] — PJRT executor for the AOT artifacts (behind the
//!   `pjrt-artifacts` feature; a graceful stub otherwise)
//! - [`chaos`] — seeded node-failure injection: reproducible kill
//!   schedules driving replica loss, exactly-once task reassignment,
//!   work stealing, and recovery re-staging
//! - [`transfer`] / [`catalog`] — Globus-like transfer + metadata catalog
//! - [`metrics`] — phase accounting and report tables
//! - [`experiments`] — one driver per paper table/figure
//!
//! ## Quickstart
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! cargo run --release -- fig11 --nodes 8192
//! cargo run --release -- serve --sessions 18
//! ```

pub mod catalog;
pub mod chaos;
pub mod cli;
pub mod cluster;
pub mod dataflow;
pub mod engine;
pub mod experiments;
pub mod hedm;
pub mod metrics;
pub mod mpisim;
pub mod pfs;
pub mod runtime;
pub mod simtime;
pub mod staging;
pub mod storage;
pub mod transfer;
pub mod units;
pub mod util;
