//! Phase accounting, sample distributions, and report tables.
//!
//! The paper's evaluation reports *phase wall times* (Staging, Write,
//! Read — Fig 9/10/11) and derived aggregate bandwidths. [`Metrics`]
//! tracks, per label, the wall-clock *span* (earliest start to latest
//! finish across all concurrent steps carrying the label) plus simple
//! byte/op counters and observed sample series (per-session
//! turnarounds in the serve experiment report as P50/P95/P99 via
//! [`Percentiles`]); [`Table`] renders the paper-vs-measured rows the
//! experiment drivers print.

use std::cell::Cell;
use std::collections::BTreeMap;

use crate::units::{Duration, SimTime};

#[derive(Clone, Copy, Debug)]
struct Span {
    first_start: SimTime,
    last_end: SimTime,
    open: u64,
    started: u64,
}

/// P50/P95/P99 of an observed sample series (nearest-rank).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Percentiles {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Percentiles {
    /// P50/P95/P99 of an **ascending-sorted** sample slice; `None`
    /// when the slice is empty (a zero-completion matrix point).
    pub fn from_sorted(sorted: &[f64]) -> Option<Percentiles> {
        Some(Percentiles {
            p50: percentile(sorted, 50.0)?,
            p95: percentile(sorted, 95.0)?,
            p99: percentile(sorted, 99.0)?,
        })
    }

    /// Render `[P50, P95, P99]` table cells, `"-"` for an empty
    /// series: experiment tables report zero-completion points as
    /// empty cells instead of aborting the whole run.
    pub fn cells(p: Option<Percentiles>) -> [String; 3] {
        match p {
            Some(p) => [p.p50, p.p95, p.p99].map(|v| format!("{v:.1}")),
            None => ["-", "-", "-"].map(String::from),
        }
    }

    /// P50/P95/P99 by three nested `select_nth_unstable` passes
    /// instead of a full sort — expected O(n), with each pass confined
    /// to the left partition of the previous one (the three ranks are
    /// nested). Produces exactly what [`Percentiles::from_sorted`]
    /// would on the sorted copy (`select_nth_unstable` places the
    /// element that sorting would put at that index); `samples` is
    /// reordered arbitrarily. `None` when empty.
    pub fn select(samples: &mut [f64]) -> Option<Percentiles> {
        let n = samples.len();
        if n == 0 {
            return None;
        }
        let rank_idx = |q: f64| ((q * n as f64 / 100.0).ceil() as usize).clamp(1, n) - 1;
        let (i50, i95, i99) = (rank_idx(50.0), rank_idx(95.0), rank_idx(99.0));
        let cmp = |a: &f64, b: &f64| a.partial_cmp(b).expect("non-finite sample");
        let (below99, p99, _) = samples.select_nth_unstable_by(i99, cmp);
        let p99 = *p99;
        let p95 = if i95 == i99 { p99 } else { *below99.select_nth_unstable_by(i95, cmp).1 };
        let p50 = if i50 == i95 {
            p95
        } else {
            // i50 < i95: the P50 sits strictly left of the P95 slot,
            // and everything there is already <= P95.
            *below99[..i95].select_nth_unstable_by(i50, cmp).1
        };
        Some(Percentiles { p50, p95, p99 })
    }
}

/// One observed sample series: insertion-order raw observations plus
/// a cached percentile summary, so repeated P50/P95/P99 queries after
/// the series stops growing cost nothing.
#[derive(Clone, Debug, Default)]
pub struct Series {
    raw: Vec<f64>,
    /// Valid while `raw` is unchanged since the computing query; any
    /// push invalidates. `Cell`: summaries stay queryable by `&self`.
    cached: Cell<Option<Percentiles>>,
}

impl Series {
    pub fn push(&mut self, v: f64) {
        self.raw.push(v);
        self.cached.set(None);
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.raw
    }

    pub fn len(&self) -> usize {
        self.raw.len()
    }

    pub fn is_empty(&self) -> bool {
        self.raw.is_empty()
    }

    /// Nearest-rank P50/P95/P99 via [`Percentiles::select`], cached
    /// until the next [`Series::push`]; `None` when empty.
    pub fn percentiles(&self) -> Option<Percentiles> {
        if self.raw.is_empty() {
            return None;
        }
        if let Some(p) = self.cached.get() {
            return Some(p);
        }
        let mut scratch = self.raw.clone();
        let p = Percentiles::select(&mut scratch);
        self.cached.set(p);
        p
    }
}

/// Nearest-rank percentile of an **ascending-sorted** sample slice:
/// the smallest sample such that at least `q`% of the set is <= it,
/// `None` for an empty set. Deterministic (no interpolation), so
/// percentile tables are bit-reproducible across runs.
pub fn percentile(sorted: &[f64], q: f64) -> Option<f64> {
    assert!((0.0..=100.0).contains(&q), "bad percentile {q}");
    let n = sorted.len();
    if n == 0 {
        return None;
    }
    // q*n first, one division last: whenever q*n/100 is mathematically
    // an integer the quotient is exact in IEEE, so ceil never rounds a
    // representation error up to the next rank (q/100 first would,
    // e.g. q=7, n=100).
    let rank = (q * n as f64 / 100.0).ceil() as usize;
    Some(sorted[rank.clamp(1, n) - 1])
}

/// Phase spans + counters + sample series for one simulation run.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    spans: BTreeMap<&'static str, Span>,
    bytes: BTreeMap<&'static str, u64>,
    counts: BTreeMap<&'static str, u64>,
    samples: BTreeMap<&'static str, Series>,
    /// High-water gauges ([`Metrics::record_max`]): kernel occupancy
    /// peaks and other "largest value seen" figures.
    gauges: BTreeMap<&'static str, f64>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn phase_start(&mut self, label: &'static str, now: SimTime) {
        let s = self.spans.entry(label).or_insert(Span {
            first_start: now,
            last_end: now,
            open: 0,
            started: 0,
        });
        s.open += 1;
        s.started += 1;
        if now < s.first_start {
            s.first_start = now;
        }
    }

    pub fn phase_end(&mut self, label: &'static str, now: SimTime) {
        let s = self.spans.get_mut(label).expect("end before start");
        debug_assert!(s.open > 0);
        s.open -= 1;
        if now > s.last_end {
            s.last_end = now;
        }
    }

    /// Wall-clock span of a phase: first start to last finish.
    pub fn phase_span(&self, label: &str) -> Option<Duration> {
        self.spans.get(label).map(|s| s.last_end - s.first_start)
    }

    /// When the phase first started / last ended.
    pub fn phase_window(&self, label: &str) -> Option<(SimTime, SimTime)> {
        self.spans.get(label).map(|s| (s.first_start, s.last_end))
    }

    /// How many steps carried this label.
    pub fn phase_count(&self, label: &str) -> u64 {
        self.spans.get(label).map_or(0, |s| s.started)
    }

    pub fn add_bytes(&mut self, label: &'static str, n: u64) {
        *self.bytes.entry(label).or_insert(0) += n;
    }

    pub fn bytes(&self, label: &str) -> u64 {
        self.bytes.get(label).copied().unwrap_or(0)
    }

    pub fn incr(&mut self, label: &'static str) {
        *self.counts.entry(label).or_insert(0) += 1;
    }

    pub fn add_count(&mut self, label: &'static str, n: u64) {
        *self.counts.entry(label).or_insert(0) += n;
    }

    pub fn count(&self, label: &str) -> u64 {
        self.counts.get(label).copied().unwrap_or(0)
    }

    pub fn labels(&self) -> impl Iterator<Item = &&'static str> {
        self.spans.keys()
    }

    /// Record one observation of a sample series (e.g. a session's
    /// turnaround in seconds). Insertion order is preserved.
    pub fn observe(&mut self, label: &'static str, v: f64) {
        assert!(v.is_finite(), "non-finite observation for {label}: {v}");
        self.samples.entry(label).or_default().push(v);
    }

    /// The raw observations of a series, in insertion order.
    pub fn samples(&self, label: &str) -> &[f64] {
        self.samples.get(label).map(Series::as_slice).unwrap_or(&[])
    }

    /// The full [`Series`] behind a label (cached-percentile access).
    pub fn series(&self, label: &str) -> Option<&Series> {
        self.samples.get(label)
    }

    /// Nearest-rank P50/P95/P99 of a series; `None` with no samples.
    /// Selection, not a full sort, and cached on the series until its
    /// next observation.
    pub fn percentiles(&self, label: &str) -> Option<Percentiles> {
        self.samples.get(label)?.percentiles()
    }

    /// Record a high-water gauge: keeps the largest value ever passed
    /// under `label` (the engine folds kernel occupancy peaks in at
    /// every drain, so repeated runs stay monotone).
    pub fn record_max(&mut self, label: &'static str, v: f64) {
        let g = self.gauges.entry(label).or_insert(f64::NEG_INFINITY);
        if v > *g {
            *g = v;
        }
    }

    /// The recorded high-water value, `None` when never recorded.
    pub fn gauge(&self, label: &str) -> Option<f64> {
        self.gauges.get(label).copied()
    }
}

/// A paper-vs-measured report table (fixed-width text, stable order —
/// EXPERIMENTS.md embeds these verbatim).
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "ragged row");
        self.rows.push(cells.to_vec());
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!("{:>w$}  ", c, w = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_covers_concurrent_steps() {
        let mut m = Metrics::new();
        m.phase_start("stage", SimTime(1_000));
        m.phase_start("stage", SimTime(2_000));
        m.phase_end("stage", SimTime(5_000));
        m.phase_end("stage", SimTime(9_000));
        assert_eq!(m.phase_span("stage").unwrap(), Duration(8_000));
        assert_eq!(m.phase_count("stage"), 2);
        assert_eq!(m.phase_window("stage").unwrap(), (SimTime(1_000), SimTime(9_000)));
    }

    #[test]
    fn counters() {
        let mut m = Metrics::new();
        m.add_bytes("pfs.write", 100);
        m.add_bytes("pfs.write", 50);
        m.incr("tasks");
        m.add_count("tasks", 4);
        assert_eq!(m.bytes("pfs.write"), 150);
        assert_eq!(m.count("tasks"), 5);
        assert_eq!(m.bytes("missing"), 0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Fig X", &["nodes", "GB/s"]);
        t.row(&["512".into(), "16.4".into()]);
        t.row(&["8192".into(), "134.0".into()]);
        let s = t.render();
        assert!(s.contains("== Fig X =="));
        assert!(s.contains("8192"));
        assert!(s.lines().count() >= 5);
    }

    #[test]
    #[should_panic(expected = "ragged row")]
    fn ragged_row_panics() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into()]);
    }

    #[test]
    fn nearest_rank_percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 50.0), Some(50.0));
        assert_eq!(percentile(&xs, 95.0), Some(95.0));
        assert_eq!(percentile(&xs, 99.0), Some(99.0));
        assert_eq!(percentile(&xs, 100.0), Some(100.0));
        assert_eq!(percentile(&xs, 0.0), Some(1.0));
        // Small sets: P99 of 4 samples is the max.
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0], 99.0), Some(4.0));
        assert_eq!(percentile(&[7.5], 50.0), Some(7.5));
    }

    #[test]
    fn observed_series_report_percentiles() {
        let mut m = Metrics::new();
        for v in [5.0, 1.0, 3.0, 2.0, 4.0] {
            m.observe("session.turnaround", v);
        }
        assert_eq!(m.samples("session.turnaround"), &[5.0, 1.0, 3.0, 2.0, 4.0]);
        let p = m.percentiles("session.turnaround").unwrap();
        assert_eq!(p.p50, 3.0);
        assert_eq!(p.p95, 5.0);
        assert_eq!(p.p99, 5.0);
        assert!(m.percentiles("missing").is_none());
        assert!(m.samples("missing").is_empty());
    }

    #[test]
    fn selection_matches_full_sort_everywhere() {
        // Percentiles::select must agree with the sorted nearest-rank
        // definition on every size, including the rank-collision
        // shortcuts (i50 == i95 == i99 on tiny sets).
        let mut state = 0x2545_f491_4f6c_dd1du64;
        for n in 1..=257 {
            let mut xs: Vec<f64> = (0..n)
                .map(|_| {
                    state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                    ((state >> 33) % 1_000) as f64 / 10.0
                })
                .collect();
            let mut sorted = xs.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let want = Percentiles::from_sorted(&sorted).unwrap();
            let got = Percentiles::select(&mut xs).unwrap();
            assert_eq!(got, want, "n={n}");
        }
        assert_eq!(Percentiles::select(&mut []), None);
    }

    #[test]
    fn series_caches_until_next_push() {
        let mut s = Series::default();
        for v in [9.0, 1.0, 5.0] {
            s.push(v);
        }
        let p = s.percentiles().unwrap();
        assert_eq!((p.p50, p.p99), (5.0, 9.0));
        // Cached: a second query returns the same summary.
        assert_eq!(s.percentiles(), Some(p));
        // A push invalidates and the summary tracks the new data.
        s.push(100.0);
        assert_eq!(s.percentiles().unwrap().p99, 100.0);
        assert_eq!(s.as_slice(), &[9.0, 1.0, 5.0, 100.0], "raw order preserved");
    }

    #[test]
    fn record_max_keeps_high_water() {
        let mut m = Metrics::new();
        assert_eq!(m.gauge("kernel.heap.peak_depth"), None);
        m.record_max("kernel.heap.peak_depth", 4.0);
        m.record_max("kernel.heap.peak_depth", 11.0);
        m.record_max("kernel.heap.peak_depth", 7.0);
        assert_eq!(m.gauge("kernel.heap.peak_depth"), Some(11.0));
    }

    #[test]
    fn empty_sample_sets_report_none_not_panic() {
        // Regression: `percentile` used to assert non-emptiness, so a
        // zero-completion matrix point (every session rejected, or a
        // chaos run killing the whole machine) aborted the entire
        // experiment instead of reporting an empty cell.
        assert_eq!(percentile(&[], 50.0), None);
        assert_eq!(percentile(&[], 99.0), None);
        assert_eq!(Percentiles::from_sorted(&[]), None);
        assert_eq!(Percentiles::cells(None), ["-", "-", "-"]);
        let mut m = Metrics::new();
        assert!(m.percentiles("never-observed").is_none());
        m.observe("one", 2.5);
        let p = m.percentiles("one").unwrap();
        assert_eq!((p.p50, p.p95, p.p99), (2.5, 2.5, 2.5));
        assert_eq!(Percentiles::cells(Some(p)), ["2.5", "2.5", "2.5"]);
    }
}
