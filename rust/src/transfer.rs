//! Cross-lab data movement: the Globus-style transfer service of the
//! Fig 7 workflow (step 3: APS NFS -> ALCF GPFS).
//!
//! Models what matters to the interactive loop: a WAN pipe with
//! checksummed, retry-capable, concurrent-stream file transfers, and a
//! real data plane (blobs move between two [`ParallelFs`] namespaces;
//! checksums verify integrity end to end). Fault injection (a
//! configurable per-file corruption probability) exercises the
//! verify-and-retry path the way Globus's checksum restarts do.

use anyhow::{anyhow, Result};

use crate::engine::SimCore;
use crate::pfs::ParallelFs;
use crate::simtime::flownet::{Capacity, LinkClass, LinkId};
use crate::simtime::plan::{Effect, Plan};
use crate::units::{Duration, GB};
use crate::util::prng::Pcg64;

/// A Globus-like endpoint pair over a WAN link.
#[derive(Debug)]
pub struct TransferService {
    /// WAN bandwidth between the labs (APS -> ALCF is metro fibre;
    /// default 10 Gb/s usable = 1.25 GB/s).
    pub wan: LinkId,
    /// Concurrent streams per transfer job (Globus default class).
    pub streams: u64,
    /// Per-file checksum+handshake overhead.
    pub per_file_overhead: Duration,
    /// Injected corruption probability per file (0 in production).
    pub corruption_prob: f64,
    rng: Pcg64,
    /// Files that needed a retry (telemetry).
    pub retries: u64,
}

/// Summary of one transfer job.
#[derive(Clone, Debug, Default)]
pub struct TransferReport {
    pub files: usize,
    pub bytes: u64,
    pub seconds: f64,
    pub retries: u64,
}

impl TransferService {
    /// Create the WAN link and service (call once per experiment).
    pub fn new(core: &mut SimCore, wan_bw: f64, seed: u64) -> TransferService {
        let wan =
            core.net
                .add_link_classed("wan.aps-alcf", Capacity::Fixed(wan_bw), LinkClass::Wan);
        TransferService {
            wan,
            streams: 8,
            per_file_overhead: Duration::from_millis(150),
            corruption_prob: 0.0,
            rng: Pcg64::new(seed),
            retries: 0,
        }
    }

    pub fn default_wan_bw() -> f64 {
        1.25 * GB as f64
    }

    /// Transfer every file matching `pattern` from `src` into `core`'s
    /// shared filesystem under `dst_prefix`. Runs the core to
    /// completion of the transfer plan; returns the report.
    ///
    /// Integrity: each file is checksummed at source, (optionally
    /// fault-injected), checksummed at destination, and retried once on
    /// mismatch — a mismatch after retry is an error.
    pub fn transfer(
        &mut self,
        core: &mut SimCore,
        src: &ParallelFs,
        pattern: &str,
        dst_prefix: &str,
    ) -> Result<TransferReport> {
        let files = src.glob(pattern);
        if files.is_empty() {
            return Err(anyhow!("transfer: no files match {pattern:?}"));
        }
        let t0 = core.now;
        let mut total = 0u64;
        let mut plan = Plan::new(0);
        let mut staged = Vec::new();
        for path in &files {
            let blob = src.read(path).unwrap().clone();
            let src_sum = blob.checksum();
            total += blob.len();

            // Fault injection: a corrupted wire copy fails the
            // destination checksum and is re-sent.
            let corrupted = self.corruption_prob > 0.0
                && self.rng.f64() < self.corruption_prob;
            let sends = if corrupted { 2 } else { 1 };
            self.retries += (sends - 1) as u64;

            let base = path.rsplit('/').next().unwrap_or(path);
            let dst = format!("{}/{}", dst_prefix.trim_end_matches('/'), base);
            let mut dep = plan.delay(self.per_file_overhead, vec![], "wan-handshake");
            for _ in 0..sends {
                dep = plan.flow(vec![self.wan], self.streams.min(8), blob.len() / self.streams.max(1), vec![dep], "wan-xfer");
            }
            plan.effect(
                Effect::PfsWrite { path: dst.clone(), data: blob.clone() },
                vec![dep],
                "wan-xfer",
            );
            staged.push((dst, src_sum));
        }
        core.submit(plan);
        core.run_to_completion();

        // Destination verification (the data plane is real).
        for (dst, src_sum) in &staged {
            let got = core
                .pfs
                .read(dst)
                .ok_or_else(|| anyhow!("transfer lost {dst}"))?;
            if got.checksum() != *src_sum {
                return Err(anyhow!("checksum mismatch after retry: {dst}"));
            }
        }
        Ok(TransferReport {
            files: files.len(),
            bytes: total,
            seconds: (core.now - t0).secs_f64(),
            retries: self.retries,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pfs::Blob;
    use crate::units::MB;

    fn source_fs(files: usize, bytes: u64) -> ParallelFs {
        let mut fs = ParallelFs::new();
        for i in 0..files {
            fs.write(format!("/aps/run7/f{i:03}.bin"), Blob::synthetic(bytes, i as u64));
        }
        fs
    }

    #[test]
    fn moves_bytes_intact() {
        let src = source_fs(10, 2 * MB);
        let mut core = SimCore::new();
        let mut svc = TransferService::new(&mut core, TransferService::default_wan_bw(), 1);
        let rep = svc.transfer(&mut core, &src, "/aps/run7/*.bin", "/alcf/run7").unwrap();
        assert_eq!(rep.files, 10);
        assert_eq!(rep.bytes, 20 * MB);
        for i in 0..10 {
            let a = src.read(&format!("/aps/run7/f{i:03}.bin")).unwrap();
            let b = core.pfs.read(&format!("/alcf/run7/f{i:03}.bin")).unwrap();
            assert!(a.same_content(b));
        }
    }

    #[test]
    fn time_scales_with_bytes_over_wan() {
        // 2 GB over 1.25 GB/s: >= 1.6 s.
        let src = source_fs(4, 500 * MB);
        let mut core = SimCore::new();
        let mut svc = TransferService::new(&mut core, TransferService::default_wan_bw(), 2);
        let rep = svc.transfer(&mut core, &src, "/aps/run7/*.bin", "/alcf/x").unwrap();
        assert!(rep.seconds >= 1.6 && rep.seconds < 5.0, "{}", rep.seconds);
    }

    #[test]
    fn corruption_triggers_retries_and_still_delivers() {
        let src = source_fs(50, MB);
        let mut core = SimCore::new();
        let mut svc = TransferService::new(&mut core, TransferService::default_wan_bw(), 3);
        svc.corruption_prob = 0.3;
        let rep = svc.transfer(&mut core, &src, "/aps/run7/*.bin", "/alcf/y").unwrap();
        assert!(rep.retries > 0, "expected injected retries");
        for i in 0..50 {
            let a = src.read(&format!("/aps/run7/f{i:03}.bin")).unwrap();
            let b = core.pfs.read(&format!("/alcf/y/f{i:03}.bin")).unwrap();
            assert!(a.same_content(b));
        }
    }

    #[test]
    fn empty_pattern_errors() {
        let src = ParallelFs::new();
        let mut core = SimCore::new();
        let mut svc = TransferService::new(&mut core, 1e9, 4);
        assert!(svc.transfer(&mut core, &src, "/none/*", "/alcf/z").is_err());
    }
}
