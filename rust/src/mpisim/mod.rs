//! MPI substrate: communicators and the collective algorithms the
//! Swift I/O hook is built on (SIV).
//!
//! The two collectives the paper uses are implemented as plan
//! builders, mirroring the real algorithms:
//!
//! - [`bcast::bcast_plan`] — binomial-tree `MPI_Bcast`, used to ship
//!   the globbed file list (and any small config) from rank 0 to every
//!   leader rank without each rank hitting the filesystem.
//! - [`read_all::read_all_plan`] — two-phase collective
//!   `MPI_File_read_all`: a subset of ranks act as I/O *aggregators*
//!   issuing large aligned stripe reads (the access pattern GPFS
//!   serves at full backplane rate), then the stripes are
//!   redistributed/allgathered over the torus so every node holds the
//!   full replica.
//!
//! Plans carry no rank-level data structures — bundles keep the cost
//! of an 8,192-node collective constant — but the *algorithms* (round
//! counts, aggregator fan-in, stripe math) are computed exactly and
//! unit-tested against hand-worked examples.

pub mod bcast;
pub mod read_all;

use crate::cluster::MachineSpec;

/// A communicator: a dense set of ranks over a node range.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Comm {
    /// Inclusive node range within the machine.
    pub node_lo: u32,
    pub node_hi: u32,
    /// Ranks per node in this communicator.
    pub ranks_per_node: u32,
}

impl Comm {
    /// COMM_WORLD over the whole allocation.
    pub fn world(spec: &MachineSpec) -> Comm {
        Comm {
            node_lo: 0,
            node_hi: spec.nodes - 1,
            ranks_per_node: spec.ranks_per_node,
        }
    }

    /// The *leader communicator* (SIV): "exactly one ADLB worker
    /// process per node". The I/O hook executes on this.
    pub fn leader(spec: &MachineSpec) -> Comm {
        Comm { node_lo: 0, node_hi: spec.nodes - 1, ranks_per_node: 1 }
    }

    /// A sub-communicator over a node subrange.
    pub fn sub(&self, node_lo: u32, node_hi: u32) -> Comm {
        assert!(node_lo >= self.node_lo && node_hi <= self.node_hi && node_lo <= node_hi);
        Comm { node_lo, node_hi, ranks_per_node: self.ranks_per_node }
    }

    pub fn nodes(&self) -> u32 {
        self.node_hi - self.node_lo + 1
    }

    pub fn size(&self) -> u64 {
        self.nodes() as u64 * self.ranks_per_node as u64
    }

    /// Node hosting `rank` (block rank placement, like BG/Q).
    pub fn node_of(&self, rank: u64) -> u32 {
        assert!(rank < self.size());
        self.node_lo + (rank / self.ranks_per_node as u64) as u32
    }

    pub fn node_range(&self) -> (u32, u32) {
        (self.node_lo, self.node_hi)
    }
}

/// Number of binomial-tree rounds to reach `n` participants.
pub fn tree_rounds(n: u64) -> u32 {
    if n <= 1 {
        0
    } else {
        64 - (n - 1).leading_zeros()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::bgq;

    #[test]
    fn world_and_leader_sizes() {
        let spec = bgq(512);
        let w = Comm::world(&spec);
        let l = Comm::leader(&spec);
        assert_eq!(w.size(), 512 * 16);
        assert_eq!(l.size(), 512);
        assert_eq!(w.nodes(), l.nodes());
    }

    #[test]
    fn rank_to_node_block_placement() {
        let spec = bgq(4);
        let w = Comm::world(&spec);
        assert_eq!(w.node_of(0), 0);
        assert_eq!(w.node_of(15), 0);
        assert_eq!(w.node_of(16), 1);
        assert_eq!(w.node_of(63), 3);
    }

    #[test]
    #[should_panic]
    fn rank_out_of_range_panics() {
        let spec = bgq(2);
        Comm::world(&spec).node_of(32);
    }

    #[test]
    fn sub_communicator() {
        let spec = bgq(16);
        let w = Comm::world(&spec);
        let s = w.sub(4, 7);
        assert_eq!(s.nodes(), 4);
        assert_eq!(s.node_of(0), 4);
    }

    #[test]
    fn tree_round_counts() {
        assert_eq!(tree_rounds(1), 0);
        assert_eq!(tree_rounds(2), 1);
        assert_eq!(tree_rounds(3), 2);
        assert_eq!(tree_rounds(8), 3);
        assert_eq!(tree_rounds(9), 4);
        assert_eq!(tree_rounds(8192), 13);
    }
}
