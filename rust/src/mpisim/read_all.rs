//! Two-phase collective `MPI_File_read_all` as a plan fragment.
//!
//! The ROMIO-style algorithm behind the staging hook's bulk transfer:
//!
//! 1. **Aggregation phase.** A subset of ranks (the I/O aggregators —
//!    on BG/Q, a fixed number per I/O node) each read one large,
//!    stripe-aligned, *disjoint* portion of the file from GPFS. This
//!    is the access pattern the filesystem loves: few streams, big
//!    sequential requests, no degradation (`path_coordinated_read`).
//! 2. **Redistribution phase.** The stripes are exchanged over the
//!    torus (ring allgather) so every participating node assembles the
//!    full replica: each node receives `bytes * (naggr-1)/naggr ~=
//!    bytes` from its neighbours, pipelined at injection bandwidth.
//!
//! The result is then written to node-local storage by the staging
//! layer (that write is *not* part of the collective; on BG/Q it rides
//! the ION uplink and dominates — see `staging::hook`).

use crate::cluster::Topology;
use crate::mpisim::Comm;
use crate::simtime::plan::{Plan, StepId};
use crate::units::Duration;

/// I/O aggregators per I/O node (BG/Q ROMIO default class).
pub const AGGREGATORS_PER_ION: u32 = 16;

/// Aggregators for a direct-attached (cluster) machine.
pub const AGGREGATORS_DIRECT: u32 = 16;

/// Metadata service latency for the collective open (one RPC).
pub const OPEN_LATENCY: Duration = Duration(500_000); // 0.5 ms

/// Number of aggregator ranks used for a collective over `comm`.
pub fn n_aggregators(topo: &Topology, comm: &Comm) -> u64 {
    let by_machine = if topo.spec.nodes_per_ion > 0 {
        topo.spec.n_ions() as u64 * AGGREGATORS_PER_ION as u64
    } else {
        AGGREGATORS_DIRECT as u64
    };
    by_machine.min(comm.size())
}

/// Append a collective read of `bytes` (a file, or a batch of files
/// opened back-to-back: `opens` metadata operations) that leaves every
/// node of `comm` holding the data in memory. Returns the completion
/// step.
pub fn read_all_plan(
    plan: &mut Plan,
    topo: &Topology,
    comm: &Comm,
    bytes: u64,
    opens: u64,
    deps: Vec<StepId>,
    label: &'static str,
) -> StepId {
    let naggr = n_aggregators(topo, comm);
    // Collective open: rank 0 performs `opens` metadata ops, then the
    // handle is shared. (Contrast: naive mode pays opens x ranks.)
    let open = plan.flow(topo.path_meta(), 1, opens.max(1), deps, label);
    let open_lat = plan.delay(OPEN_LATENCY, vec![open], label);
    // Phase 1: disjoint stripe reads by aggregators.
    let stripe = bytes.div_ceil(naggr);
    let read = plan.flow(
        topo.path_coordinated_read(),
        naggr,
        stripe,
        vec![open_lat],
        label,
    );
    // Phase 2: ring allgather over the torus; every node receives the
    // remainder of the file from peers, pipelined at injection rate.
    let n = comm.nodes() as u64;
    if n <= 1 {
        return plan.delay(Duration::ZERO, vec![read], label);
    }
    let recv_bytes = bytes.saturating_sub(stripe.min(bytes));
    if recv_bytes == 0 {
        return plan.delay(Duration::ZERO, vec![read], label);
    }
    plan.flow_capped(
        topo.path_torus(),
        n,
        recv_bytes,
        topo.spec.torus_link_bw,
        vec![read],
        label,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{bgq, orthros, Topology};
    use crate::engine::SimCore;
    use crate::pfs::GpfsParams;
    use crate::units::{GB, MB};

    fn sim_read_all(nodes: u32, bytes: u64) -> f64 {
        let mut core = SimCore::new();
        let topo = Topology::build(bgq(nodes), GpfsParams::default(), &mut core.net);
        let comm = Comm::leader(&topo.spec);
        let mut p = Plan::new(0);
        read_all_plan(&mut p, &topo, &comm, bytes, 1, vec![], "ra");
        core.submit(p);
        core.run_to_completion();
        core.now.secs_f64()
    }

    #[test]
    fn aggregator_counts() {
        let mut net = crate::simtime::flownet::FlowNet::new();
        let t = Topology::build(bgq(8192), GpfsParams::default(), &mut net);
        assert_eq!(n_aggregators(&t, &Comm::leader(&t.spec)), 64 * 16);
        let mut net2 = crate::simtime::flownet::FlowNet::new();
        let t2 = Topology::build(orthros(), GpfsParams::default(), &mut net2);
        assert_eq!(n_aggregators(&t2, &Comm::leader(&t2.spec)), 5);
    }

    #[test]
    fn collective_read_is_fast_at_scale() {
        // 577 MB to 8,192 nodes: stripe read at backplane rate plus a
        // pipelined allgather at 1.8 GB/s -> well under a second.
        let t = sim_read_all(8192, 577 * MB);
        assert!(t < 1.0, "{t}");
    }

    #[test]
    fn read_time_scales_with_bytes() {
        let t1 = sim_read_all(64, 100 * MB);
        let t2 = sim_read_all(64, 800 * MB);
        assert!(t2 / t1 > 4.0, "{t1} {t2}");
    }

    #[test]
    fn single_node_skips_redistribution() {
        // One node: just the aggregator read, no allgather.
        let t = sim_read_all(1, GB);
        // 1 GB via [backplane(240GB/s), ion(2.1GB/s)] -> ION-limited.
        assert!((t - 1.0 / 2.1).abs() < 0.01, "{t}");
    }

    #[test]
    fn uses_coordinated_path_no_degradation() {
        // The collective path must not traverse the degrading disk
        // stage: time at 8K nodes is unaffected by the stream knee.
        let fast = sim_read_all(8192, 577 * MB);
        // An uncoordinated read of the same bytes by every rank for
        // comparison (what naive mode does) is orders slower; tested in
        // staging::naive. Here: sanity that the collective beats the
        // per-node lower bound of reading 577 MB x 8192 from GPFS peak.
        let independent_floor = 577.0 * MB as f64 * 8192.0 / (240.0 * GB as f64);
        assert!(fast < independent_floor, "{fast} {independent_floor}");
    }
}
