//! Binomial-tree `MPI_Bcast` as a plan fragment.
//!
//! Round `k` (0-based) has `2^k` senders, each pushing the full
//! payload to one new node over its torus injection port; after
//! `ceil(log2 n)` rounds all `n` nodes hold the data. Each round is a
//! flow bundle capped at the per-node injection bandwidth plus a fixed
//! per-round software/link latency. This is the standard short-vector
//! algorithm; for the staging hook's payloads (file lists, parameter
//! files — KBs to a few MBs) it is within a small factor of the
//! hardware-collective time and never the staging bottleneck.

use crate::cluster::Topology;
use crate::mpisim::{tree_rounds, Comm};
use crate::simtime::plan::{Plan, StepId};
use crate::units::Duration;

/// Per-round software + torus latency (BG/Q PAMI broadcast class).
pub const ROUND_LATENCY: Duration = Duration(5_000); // 5 us

/// Append a broadcast of `bytes` from rank 0 of `comm` to all its
/// nodes. Returns the final step (the broadcast completion barrier).
pub fn bcast_plan(
    plan: &mut Plan,
    topo: &Topology,
    comm: &Comm,
    bytes: u64,
    deps: Vec<StepId>,
    label: &'static str,
) -> StepId {
    let n = comm.nodes() as u64;
    let rounds = tree_rounds(n);
    if rounds == 0 {
        // Single node: nothing moves.
        return plan.delay(Duration::ZERO, deps, label);
    }
    let mut prev = deps;
    let mut covered: u64 = 1;
    for k in 0..rounds {
        // Senders this round: everyone already covered, but no more
        // than the nodes still uncovered.
        let senders = covered.min(n - covered);
        let lat = plan.delay(ROUND_LATENCY, prev.clone(), label);
        let xfer = plan.flow_capped(
            topo.path_torus(),
            senders,
            bytes,
            topo.spec.torus_link_bw,
            vec![lat],
            label,
        );
        prev = vec![xfer];
        covered += senders;
        debug_assert!(covered <= n || k == rounds - 1);
    }
    plan.delay(Duration::ZERO, prev, label)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{bgq, Topology};
    use crate::engine::SimCore;
    use crate::pfs::GpfsParams;
    use crate::units::GB;

    fn sim_bcast(nodes: u32, bytes: u64) -> f64 {
        let mut core = SimCore::new();
        let topo = Topology::build(bgq(nodes), GpfsParams::default(), &mut core.net);
        let comm = crate::mpisim::Comm::leader(&topo.spec);
        let mut p = Plan::new(0);
        bcast_plan(&mut p, &topo, &comm, bytes, vec![], "bcast");
        core.submit(p);
        core.run_to_completion();
        core.now.secs_f64()
    }

    #[test]
    fn single_node_is_free() {
        assert_eq!(sim_bcast(1, GB), 0.0);
    }

    #[test]
    fn two_nodes_one_round() {
        // 1 round: 1.8 GB at 1.8 GB/s = 1 s (+ 5 us latency).
        let t = sim_bcast(2, (1.8 * GB as f64) as u64);
        assert!((t - 1.0).abs() < 1e-3, "{t}");
    }

    #[test]
    fn round_count_is_logarithmic() {
        // Time grows with log2(nodes), not nodes.
        let t8 = sim_bcast(8, 100_000_000);
        let t64 = sim_bcast(64, 100_000_000);
        let t512 = sim_bcast(512, 100_000_000);
        // 3, 6, 9 rounds respectively.
        assert!((t64 / t8 - 2.0).abs() < 0.05, "{t8} {t64}");
        assert!((t512 / t8 - 3.0).abs() < 0.05, "{t8} {t512}");
    }

    #[test]
    fn latency_dominates_tiny_messages() {
        // A 100-byte list to 8192 nodes: 13 rounds of ~5 us.
        let t = sim_bcast(8192, 100);
        assert!(t < 0.001, "{t}");
        assert!(t > 5e-6 * 13.0 * 0.9, "{t}");
    }

    #[test]
    fn plan_shape_has_rounds() {
        let mut net = crate::simtime::flownet::FlowNet::new();
        let topo = Topology::build(bgq(8), GpfsParams::default(), &mut net);
        let comm = crate::mpisim::Comm::leader(&topo.spec);
        let mut p = Plan::new(0);
        bcast_plan(&mut p, &topo, &comm, 1000, vec![], "b");
        // 3 rounds x (latency + flow) + final barrier.
        assert_eq!(p.len(), 7);
    }
}
