//! The HEDM application suite (SII, SV, SVI): the science the staging
//! framework exists to serve.
//!
//! - [`geometry`] — the diffraction forward model (mirror of
//!   `python/compile/geometry.py`; cross-checked against the artifact
//!   manifest so Rust and JAX can never drift apart).
//! - [`detector`] — synthetic beamline: builds a polycrystal layer
//!   with known ground-truth grain orientations and renders its
//!   rotation-series diffraction frames (real pixels, Gaussian spots,
//!   noise, zingers) into the shared filesystem.
//! - [`ccl`] — connected-component labeling + centroid extraction
//!   (the stage-1 "characterise all peaks" step, and the flood-fill
//!   analog of SVI-A).
//! - [`reduce`] — stage-1 reduction drivers: dark median, per-frame
//!   median/LoG/threshold via the AOT `reduce_frame` artifact (or the
//!   pure-Rust fallback for artifact-less unit tests).
//! - [`fit`] — stage-2 orientation fitting: multi-resolution scan over
//!   SO(3) batched through the `fit_orientation` artifact; replaces
//!   the paper's per-grid-point NLopt solve (DESIGN.md
//!   SHardware-Adaptation).
//! - [`ff`] — far-field indexing: assign observed spots to grains,
//!   recover per-grain orientations/centers (Fig 3 analog).
//! - [`workloads`] — the paper's workload constants (736 frames, 720
//!   FF-1 jobs, 4,109 FF-2 tasks, runtimes) used by the benches.

pub mod ccl;
pub mod detector;
pub mod ff;
pub mod fit;
pub mod geometry;
pub mod reduce;
pub mod symmetry;
pub mod workloads;
