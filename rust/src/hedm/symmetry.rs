//! Cubic crystal symmetry: the 24 proper rotations and
//! symmetry-reduced misorientation.
//!
//! A cubic crystal's diffraction pattern is invariant under the 24
//! proper rotations of the cube, so orientation recovery can only be
//! judged *modulo* that group: the misorientation between two
//! orientations is the smallest rotation angle over all symmetric
//! equivalents. This is the quantitative form of "sample points of
//! the same color have the same crystallographic orientation" (Fig 2)
//! — grain maps and indexing results are compared with
//! [`misorientation_deg`], and grains are distinct when it exceeds a
//! threshold (conventionally 5-15 degrees for grain boundaries).

use crate::hedm::geometry::euler_to_matrix;

type Mat3 = [[f64; 3]; 3];

fn matmul(a: &Mat3, b: &Mat3) -> Mat3 {
    let mut c = [[0.0; 3]; 3];
    for i in 0..3 {
        for j in 0..3 {
            for (k, bk) in b.iter().enumerate() {
                c[i][j] += a[i][k] * bk[j];
            }
        }
    }
    c
}

fn transpose(a: &Mat3) -> Mat3 {
    let mut t = [[0.0; 3]; 3];
    for i in 0..3 {
        for j in 0..3 {
            t[i][j] = a[j][i];
        }
    }
    t
}

fn trace(a: &Mat3) -> f64 {
    a[0][0] + a[1][1] + a[2][2]
}

/// The 24 proper rotation matrices of the cubic point group (O, 432).
/// Generated as all signed permutation matrices with determinant +1.
pub fn cubic_rotations() -> Vec<Mat3> {
    let perms = [
        [0usize, 1, 2], [0, 2, 1], [1, 0, 2], [1, 2, 0], [2, 0, 1], [2, 1, 0],
    ];
    let mut out = Vec::with_capacity(24);
    for p in perms {
        for signs in 0..8u32 {
            let s = [
                if signs & 1 == 0 { 1.0 } else { -1.0 },
                if signs & 2 == 0 { 1.0 } else { -1.0 },
                if signs & 4 == 0 { 1.0 } else { -1.0 },
            ];
            let mut m: Mat3 = [[0.0; 3]; 3];
            for (row, (&col, &sign)) in p.iter().zip(&s).enumerate() {
                m[row][col] = sign;
            }
            // determinant of a signed permutation: perm parity * sign product
            let det = {
                let a = &m;
                a[0][0] * (a[1][1] * a[2][2] - a[1][2] * a[2][1])
                    - a[0][1] * (a[1][0] * a[2][2] - a[1][2] * a[2][0])
                    + a[0][2] * (a[1][0] * a[2][1] - a[1][1] * a[2][0])
            };
            if (det - 1.0).abs() < 1e-9 {
                out.push(m);
            }
        }
    }
    debug_assert_eq!(out.len(), 24);
    out
}

/// Rotation angle (radians) of a rotation matrix.
fn rotation_angle(m: &Mat3) -> f64 {
    ((trace(m) - 1.0) / 2.0).clamp(-1.0, 1.0).acos()
}

/// Symmetry-reduced misorientation angle between two orientations
/// (Bunge Euler triples), in degrees. Zero iff they are cubic-
/// symmetry equivalent.
pub fn misorientation_deg(a: [f64; 3], b: [f64; 3]) -> f64 {
    let ra = euler_to_matrix(a[0], a[1], a[2]);
    let rb = euler_to_matrix(b[0], b[1], b[2]);
    let delta = matmul(&rb, &transpose(&ra)); // rotation taking a -> b
    let mut best = f64::INFINITY;
    for s in cubic_rotations() {
        let m = matmul(&s, &delta);
        best = best.min(rotation_angle(&m));
    }
    best.to_degrees()
}

/// Group orientations into grains: two orientations belong to the
/// same grain when their misorientation is below `tol_deg`.
/// Returns a grain id per input (ids are first-seen order).
pub fn cluster_orientations(eulers: &[[f64; 3]], tol_deg: f64) -> Vec<usize> {
    let mut reps: Vec<[f64; 3]> = Vec::new();
    let mut ids = Vec::with_capacity(eulers.len());
    for &e in eulers {
        let found = reps
            .iter()
            .position(|&r| misorientation_deg(e, r) < tol_deg);
        match found {
            Some(i) => ids.push(i),
            None => {
                reps.push(e);
                ids.push(reps.len() - 1);
            }
        }
    }
    ids
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    #[test]
    fn exactly_24_proper_rotations() {
        let rots = cubic_rotations();
        assert_eq!(rots.len(), 24);
        // All orthonormal with det +1, and pairwise distinct.
        for (i, a) in rots.iter().enumerate() {
            let at = transpose(a);
            let id = matmul(a, &at);
            for r in 0..3 {
                for c in 0..3 {
                    let want = if r == c { 1.0 } else { 0.0 };
                    assert!((id[r][c] - want).abs() < 1e-12);
                }
            }
            for b in rots.iter().skip(i + 1) {
                assert_ne!(a, b);
            }
        }
    }

    #[test]
    fn self_misorientation_is_zero() {
        let e = [0.9, 1.3, 0.2];
        assert!(misorientation_deg(e, e) < 1e-6);
    }

    #[test]
    fn symmetry_equivalents_are_zero() {
        // Rotating by 90 degrees about z (phi1 += pi/2) is a cubic
        // symmetry operation: misorientation must vanish.
        let e = [0.4, 0.9, 1.7];
        let eq = [e[0] + std::f64::consts::FRAC_PI_2, e[1], e[2]];
        assert!(misorientation_deg(e, eq) < 1e-6, "{}", misorientation_deg(e, eq));
    }

    #[test]
    fn small_rotation_small_misorientation() {
        let e = [0.4, 0.9, 1.7];
        let perturbed = [e[0] + 0.01, e[1], e[2]];
        let m = misorientation_deg(e, perturbed);
        assert!(m > 0.01 && m < 1.5, "{m}");
    }

    #[test]
    fn misorientation_is_symmetric_and_bounded() {
        let mut rng = Pcg64::new(5);
        for _ in 0..20 {
            let a = [rng.range_f64(0.0, 6.28), rng.range_f64(0.0, 3.14), rng.range_f64(0.0, 6.28)];
            let b = [rng.range_f64(0.0, 6.28), rng.range_f64(0.0, 3.14), rng.range_f64(0.0, 6.28)];
            let ab = misorientation_deg(a, b);
            let ba = misorientation_deg(b, a);
            assert!((ab - ba).abs() < 1e-6);
            // Cubic fundamental zone maximum ~= 62.8 degrees.
            assert!(ab <= 62.9, "{ab}");
        }
    }

    #[test]
    fn clustering_recovers_grain_count() {
        let mut rng = Pcg64::new(8);
        let grains = [
            [0.3, 0.7, 1.1],
            [2.0, 1.2, 0.4],
            [4.4, 2.2, 5.0],
        ];
        // 30 noisy measurements of 3 grains.
        let mut eulers = Vec::new();
        let mut truth = Vec::new();
        for i in 0..30 {
            let g = grains[i % 3];
            eulers.push([
                g[0] + rng.normal() * 0.005,
                g[1] + rng.normal() * 0.005,
                g[2] + rng.normal() * 0.005,
            ]);
            truth.push(i % 3);
        }
        let ids = cluster_orientations(&eulers, 5.0);
        assert_eq!(ids.iter().max().unwrap() + 1, 3, "{ids:?}");
        // Consistent labeling with truth (up to renaming).
        for i in 0..30 {
            for j in 0..30 {
                assert_eq!(ids[i] == ids[j], truth[i] == truth[j], "{i},{j}");
            }
        }
    }

    #[test]
    fn fit_results_judged_by_misorientation_or_pseudo_symmetry() {
        // Tie the symmetry module to the fitter. A recovered
        // orientation is correct when its misorientation vanishes mod
        // the 24 proper cubic rotations — OR when it is a
        // *pseudo-symmetric* solution: with a truncated reflection set
        // (58 G-vectors), a finite match tolerance, and Friedel-paired
        // spots, distinct orientations can produce near-identical
        // patterns. Diffraction cannot distinguish those; the honest
        // acceptance criterion is pattern equivalence, with
        // misorientation as the stronger check when it holds.
        use crate::hedm::fit::{fit_orientation, NativeScorer, ScanCfg};
        use crate::hedm::geometry::{simulate_spots, spot_overlap, Geom};
        let g = Geom { frame: 256, det_dist: 1.25e5, ..Geom::default() };
        let truth = [0.9, 1.3, 0.2];
        let obs = simulate_spots(truth, &g);
        let mut scorer = NativeScorer::new(g, &obs);
        let fit = fit_orientation(&mut scorer, &ScanCfg::default()).unwrap();
        let m = misorientation_deg(fit.euler, truth);
        let overlap = spot_overlap(
            &simulate_spots(fit.euler, &g),
            &simulate_spots(truth, &g),
            &g,
        );
        assert!(
            m < 1.0 || overlap > 0.9,
            "misorientation {m} deg with pattern overlap {overlap}"
        );
        // And the diagnostic is meaningful: a deliberately wrong
        // orientation fails both.
        let wrong = [truth[0] + 0.8, truth[1] + 0.5, truth[2]];
        let m_wrong = misorientation_deg(wrong, truth);
        let o_wrong = spot_overlap(
            &simulate_spots(wrong, &g),
            &simulate_spots(truth, &g),
            &g,
        );
        assert!(m_wrong > 5.0 && o_wrong < 0.5, "{m_wrong} {o_wrong}");
    }
}
