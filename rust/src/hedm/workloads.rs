//! The paper's workload constants and task-graph builders (SVI).
//!
//! Each evaluation subsection defines a workload; the experiment
//! drivers and benches build the corresponding task graphs from here
//! so every figure regenerates from one source of truth.

use crate::dataflow::graph::{Task, TaskGraph};
use crate::units::{Duration, MB};
use crate::util::prng::Pcg64;

/// SVI-A: NF-HEDM data reduction — "736 images from two detector
/// distances" on 320 Orthros cores in 106 s.
pub const NF_REDUCE_IMAGES: usize = 736;
/// Per-image reduction cost on an Orthros core (calibrated so 736
/// images on 320 cores = 3 scheduling waves + the shared dark-median
/// prepass gives ~106 s, as measured in SVI-A).
pub const NF_REDUCE_SECS_PER_IMAGE: f64 = 30.0;
/// The shared "median calculation on each pixel ... using all images"
/// prepass.
pub const NF_REDUCE_DARK_PREPASS_SECS: f64 = 14.0;
/// Raw frame size ("2D TIFF images, each 8 MB in size").
pub const RAW_FRAME_BYTES: u64 = 8 * MB;
/// Reduced binary size ("each 8 MB raw file can be reduced to an
/// ~1 MB binary file").
pub const REDUCED_FRAME_BYTES: u64 = 1 * MB;

/// SVI-B: the staged dataset ("a 577 MB data set from GPFS").
pub const NF_STAGE2_DATASET_BYTES: u64 = 577 * MB;
/// NF stage 2 scale: "~10^5 points per layer".
pub const NF_STAGE2_GRID_POINTS: usize = 100_000;
/// "each task runs for about 10 minutes" (Fig 2 context) at cluster
/// scale; ~30 s at BG/Q grid-point granularity (SV-B: "about 30 s for
/// each grid point").
pub const NF_STAGE2_SECS_PER_POINT: f64 = 30.0;

/// SVI-C: FF stage 1 — "720 images, with each image being processed in
/// parallel ... 5 s to 160 s" depending on diffraction spot count.
pub const FF1_JOBS: usize = 720;
pub const FF1_MIN_SECS: f64 = 5.0;
pub const FF1_MAX_SECS: f64 = 160.0;
/// Each job loads one 8 MB diffraction image and writes ~50 KB.
pub const FF1_INPUT_BYTES: u64 = 8 * MB;
pub const FF1_OUTPUT_BYTES: u64 = 50_000;

/// SVI-D: FF stage 2 — "4,109 grains and thus tasks, with the run-time
/// per task varying between 5 and 25 s".
pub const FF2_TASKS: usize = 4_109;
pub const FF2_MIN_SECS: f64 = 5.0;
pub const FF2_MAX_SECS: f64 = 25.0;

/// Fig 2: the NF gold-wire cross-section — 601-point hex grid, 4
/// grains, ~10 min/task on the cluster.
pub const FIG2_GRID_POINTS: usize = 601;
pub const FIG2_GRAINS: usize = 4;

/// Fig 3: the FF experimental-material section — 572 grain centers.
pub const FIG3_GRAINS: usize = 572;

/// Build the FF stage-1 task farm (Fig 12): log-uniform runtimes in
/// [5, 160] s, one 8 MB input read + 50 KB output each.
pub fn ff1_graph(seed: u64) -> TaskGraph {
    let mut g = TaskGraph::new();
    let mut rng = Pcg64::new(seed);
    g.foreach(FF1_JOBS, |i| {
        Task::compute(
            format!("ff1/peaks{i:03}"),
            Duration::from_secs_f64(rng.log_uniform(FF1_MIN_SECS, FF1_MAX_SECS)),
        )
        .with_input(format!("/tmp/ff/frame_{i:04}.bin"), Some(FF1_INPUT_BYTES))
        .with_output(FF1_OUTPUT_BYTES)
    });
    g
}

/// Build the FF stage-2 task farm (Fig 13): uniform [5, 25] s tasks.
pub fn ff2_graph(seed: u64) -> TaskGraph {
    let mut g = TaskGraph::new();
    let mut rng = Pcg64::new(seed);
    g.foreach(FF2_TASKS, |i| {
        Task::compute(
            format!("ff2/grain{i:04}"),
            Duration::from_secs_f64(rng.range_f64(FF2_MIN_SECS, FF2_MAX_SECS)),
        )
    });
    g
}

/// Build the NF reduction workload (SVI-A): a dark-median prepass task
/// followed by 736 per-image reductions that depend on it.
pub fn nf_reduce_graph(seed: u64) -> TaskGraph {
    let mut g = TaskGraph::new();
    let mut rng = Pcg64::new(seed);
    let dark = g.add(Task::compute(
        "nf1/dark-median",
        Duration::from_secs_f64(NF_REDUCE_DARK_PREPASS_SECS),
    ));
    for i in 0..NF_REDUCE_IMAGES {
        let jitter = rng.normal_ms(NF_REDUCE_SECS_PER_IMAGE, 3.0).max(5.0);
        g.add(
            Task::compute(format!("nf1/reduce{i:03}"), Duration::from_secs_f64(jitter))
                .with_dep(dark)
                .with_output(REDUCED_FRAME_BYTES),
        );
    }
    g
}

/// Build the NF stage-2 grid fit (Fig 8 / SV-B): `points` independent
/// FitOrientation tasks reading the staged dataset.
pub fn nf_stage2_graph(points: usize, staged_path: &str, seed: u64) -> TaskGraph {
    let mut g = TaskGraph::new();
    let mut rng = Pcg64::new(seed);
    g.foreach(points, |i| {
        let secs = rng.normal_ms(NF_STAGE2_SECS_PER_POINT, 5.0).clamp(10.0, 60.0);
        Task::compute(format!("nf2/fit{i:06}"), Duration::from_secs_f64(secs))
            .with_input(staged_path.to_string(), None)
    });
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ff1_runtime_distribution() {
        let g = ff1_graph(1);
        assert_eq!(g.len(), 720);
        for t in &g.tasks {
            let s = t.runtime.secs_f64();
            assert!((FF1_MIN_SECS..=FF1_MAX_SECS).contains(&s), "{s}");
            assert_eq!(t.inputs.len(), 1);
            assert_eq!(t.output_bytes, FF1_OUTPUT_BYTES);
        }
        // Log-uniform: median well below the midpoint.
        let mut secs: Vec<f64> = g.tasks.iter().map(|t| t.runtime.secs_f64()).collect();
        secs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(secs[360] < 60.0, "median {}", secs[360]);
    }

    #[test]
    fn ff2_shape() {
        let g = ff2_graph(2);
        assert_eq!(g.len(), FF2_TASKS);
        for t in &g.tasks {
            let s = t.runtime.secs_f64();
            assert!((FF2_MIN_SECS..=FF2_MAX_SECS).contains(&s));
        }
    }

    #[test]
    fn nf_reduce_depends_on_dark() {
        let g = nf_reduce_graph(3);
        assert_eq!(g.len(), 1 + NF_REDUCE_IMAGES);
        assert_eq!(g.roots().len(), 1);
        for t in &g.tasks[1..] {
            assert_eq!(t.deps.len(), 1);
        }
    }

    #[test]
    fn nf_stage2_reads_staged_data() {
        let g = nf_stage2_graph(100, "/tmp/hedm/ps.txt", 4);
        assert_eq!(g.len(), 100);
        assert!(g.tasks.iter().all(|t| t.inputs[0].path == "/tmp/hedm/ps.txt"));
    }

    #[test]
    fn graphs_are_deterministic() {
        let a = ff1_graph(7);
        let b = ff1_graph(7);
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.runtime, y.runtime);
        }
    }
}
