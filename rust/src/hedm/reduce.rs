//! Stage-1 reduction: raw frames -> binary diffraction-signal masks.
//!
//! "The data reduction step involves, first of all, a median
//! calculation on each pixel of the detector, using all images. Then,
//! independently on each image ... a median filter, followed by a
//! Laplacian of Gaussian filter to determine the edges of the
//! diffraction spots; a connected components labeling step; and a
//! flood fill operation" (SVI-A). The 8 MB raw frame reduces to ~1 MB
//! of signal — the sparsity that makes staging the reduced set cheap.
//!
//! Two interchangeable backends:
//! - **Artifact**: the AOT-compiled JAX graph (`reduce_frame.hlo.txt`,
//!   whose hot loop is the Pallas median kernel) on the PJRT client —
//!   the production path.
//! - **Native**: a pure-Rust mirror used by artifact-less unit tests
//!   *and* as an independent cross-check: integration tests assert the
//!   two backends agree pixel-for-pixel.

use anyhow::Result;

use crate::runtime::{Runtime, TensorF32};

/// Reduction thresholds (mirror of python geometry constants).
#[derive(Clone, Copy, Debug)]
pub struct ReduceParams {
    pub intensity_threshold: f32,
    pub log_threshold: f32,
    pub log_sigma: f64,
    pub log_half: usize,
}

impl Default for ReduceParams {
    fn default() -> Self {
        ReduceParams {
            intensity_threshold: 80.0,
            log_threshold: 12.0,
            log_sigma: 1.2,
            log_half: 2,
        }
    }
}

/// Output of one frame reduction.
#[derive(Clone, Debug)]
pub struct Reduced {
    /// Dark-subtracted, median-filtered frame.
    pub sub: Vec<f32>,
    /// Binary signal mask.
    pub mask: Vec<f32>,
    /// Signal pixel count.
    pub count: u64,
}

/// Median over a stack of frames, per pixel (the dark frame).
pub fn dark_median_native(frames: &[Vec<f32>]) -> Vec<f32> {
    assert!(!frames.is_empty());
    let n = frames[0].len();
    let k = frames.len();
    let mut out = vec![0f32; n];
    let mut buf = vec![0f32; k];
    for i in 0..n {
        for (j, f) in frames.iter().enumerate() {
            buf[j] = f[i];
        }
        buf.sort_by(|a, b| a.partial_cmp(b).unwrap());
        out[i] = if k % 2 == 1 {
            buf[k / 2]
        } else {
            0.5 * (buf[k / 2 - 1] + buf[k / 2])
        };
    }
    out
}

/// Median-of-9 via the 19-exchange min/max network (Paeth) — the same
/// network the Pallas kernel uses; branch-free and ~5x faster than a
/// per-pixel sort (EXPERIMENTS.md SPerf iteration 1).
#[inline(always)]
fn median9_network(mut p: [f32; 9]) -> f32 {
    #[inline(always)]
    fn ex(p: &mut [f32; 9], i: usize, j: usize) {
        // f32::min/max compile to branchless minss/maxss.
        let lo = p[i].min(p[j]);
        let hi = p[i].max(p[j]);
        p[i] = lo;
        p[j] = hi;
    }
    const NET: [(usize, usize); 19] = [
        (1, 2), (4, 5), (7, 8), (0, 1), (3, 4), (6, 7), (1, 2), (4, 5),
        (7, 8), (0, 3), (5, 8), (4, 7), (3, 6), (1, 4), (2, 5), (4, 7),
        (4, 2), (6, 4), (4, 2),
    ];
    for (i, j) in NET {
        ex(&mut p, i, j);
    }
    p[4]
}

/// 3x3 median filter, edge-clamped (mirror of the Pallas kernel +
/// shift_stack semantics). Interior pixels take the fast unclamped
/// path; the 1-pixel border falls back to clamped gathers.
pub fn median3x3(img: &[f32], w: usize) -> Vec<f32> {
    let h = img.len() / w;
    let mut out = vec![0f32; img.len()];
    let clamped = |y: i64, x: i64| -> f32 {
        let yy = y.clamp(0, h as i64 - 1) as usize;
        let xx = x.clamp(0, w as i64 - 1) as usize;
        img[yy * w + xx]
    };
    // Interior rows: run the exchange network *elementwise over row
    // slices* — nine shifted-row buffers, 19 vectorised min/max passes
    // (the SIMD form of the Pallas kernel's plane layout).
    const NET: [(usize, usize); 19] = [
        (1, 2), (4, 5), (7, 8), (0, 1), (3, 4), (6, 7), (1, 2), (4, 5),
        (7, 8), (0, 3), (5, 8), (4, 7), (3, 6), (1, 4), (2, 5), (4, 7),
        (4, 2), (6, 4), (4, 2),
    ];
    if h > 2 && w > 2 {
        let iw = w - 2;
        let mut planes: Vec<Vec<f32>> = (0..9).map(|_| vec![0f32; iw]).collect();
        for y in 1..h - 1 {
            for (k, plane) in planes.iter_mut().enumerate() {
                let (dy, dx) = (k / 3, k % 3);
                let start = (y - 1 + dy) * w + dx;
                plane.copy_from_slice(&img[start..start + iw]);
            }
            for (i, j) in NET {
                let (a, b) = if i < j {
                    let (lo, hi) = planes.split_at_mut(j);
                    (&mut lo[i], &mut hi[0])
                } else {
                    let (lo, hi) = planes.split_at_mut(i);
                    (&mut hi[0], &mut lo[j])
                };
                for (x, y2) in a.iter_mut().zip(b.iter_mut()) {
                    let lo = x.min(*y2);
                    let hi = x.max(*y2);
                    *x = lo;
                    *y2 = hi;
                }
            }
            out[y * w + 1..y * w + 1 + iw].copy_from_slice(&planes[4]);
        }
    }
    // Border: clamped scalar gathers.
    let border = |y: usize, x: usize, out: &mut Vec<f32>| {
        let mut nb = [0f32; 9];
        let mut k = 0;
        for dy in -1i64..=1 {
            for dx in -1i64..=1 {
                nb[k] = clamped(y as i64 + dy, x as i64 + dx);
                k += 1;
            }
        }
        out[y * w + x] = median9_network(nb);
    };
    for x in 0..w {
        border(0, x, &mut out);
        if h > 1 {
            border(h - 1, x, &mut out);
        }
    }
    for y in 1..h.saturating_sub(1) {
        border(y, 0, &mut out);
        if w > 1 {
            border(y, w - 1, &mut out);
        }
    }
    out
}

/// Negated LoG kernel, zero-mean (mirror of python log_kernel_2d).
pub fn log_kernel(sigma: f64, half: usize) -> Vec<f32> {
    let n = 2 * half + 1;
    let s2 = sigma * sigma;
    let mut k = vec![0f64; n * n];
    for y in 0..n {
        for x in 0..n {
            let dy = y as f64 - half as f64;
            let dx = x as f64 - half as f64;
            let r2 = dx * dx + dy * dy;
            k[y * n + x] = (r2 - 2.0 * s2) / (s2 * s2) * (-r2 / (2.0 * s2)).exp();
        }
    }
    let mean = k.iter().sum::<f64>() / k.len() as f64;
    k.iter().map(|v| -(v - mean) as f32).collect()
}

/// SAME-padding 2D convolution with a small kernel. Interior pixels
/// take a bounds-check-free row-slice path the compiler vectorises;
/// the `half`-wide border falls back to checked gathers
/// (EXPERIMENTS.md SPerf iteration 2).
pub fn convolve_same(img: &[f32], w: usize, kernel: &[f32], half: usize) -> Vec<f32> {
    let h = img.len() / w;
    let n = 2 * half + 1;
    let mut out = vec![0f32; img.len()];
    // Interior: out[y][x] = sum_ky sum_kx k[ky][kx] * img[y+ky-half][x+kx-half].
    // Iterate kernel-outer so each inner pass is a contiguous
    // scaled-row addition (auto-vectorises to FMA loops).
    if h > 2 * half && w > 2 * half {
        for ky in 0..n {
            for kx in 0..n {
                let kv = kernel[ky * n + kx];
                if kv == 0.0 {
                    continue;
                }
                for y in half..h - half {
                    // x in [half, w-half): src col = x + kx - half
                    // starts at kx for the row (y + ky - half).
                    let src_row = (y + ky - half) * w;
                    let src = &img[src_row + kx..src_row + kx + (w - 2 * half)];
                    let dst = &mut out[y * w + half..y * w + w - half];
                    for (d, s) in dst.iter_mut().zip(src) {
                        *d += kv * s;
                    }
                }
            }
        }
    }
    // Border: checked gathers.
    let checked = |y: i64, x: i64| -> f32 {
        if y < 0 || y >= h as i64 || x < 0 || x >= w as i64 {
            0.0
        } else {
            img[y as usize * w + x as usize]
        }
    };
    let mut border = |y: usize, x: usize| {
        let mut acc = 0f32;
        for ky in 0..n {
            for kx in 0..n {
                acc += kernel[ky * n + kx]
                    * checked(y as i64 + ky as i64 - half as i64, x as i64 + kx as i64 - half as i64);
            }
        }
        out[y * w + x] = acc;
    };
    for y in 0..h {
        if y < half || y >= h - half {
            for x in 0..w {
                border(y, x);
            }
        } else {
            for x in 0..half {
                border(y, x);
            }
            for x in w - half..w {
                border(y, x);
            }
        }
    }
    out
}

/// Pure-Rust frame reduction (mirror of L2 `model.reduce_frame`).
pub fn reduce_frame_native(
    frame: &[f32],
    dark: &[f32],
    w: usize,
    p: &ReduceParams,
) -> Reduced {
    let med = median3x3(frame, w);
    let sub: Vec<f32> = med
        .iter()
        .zip(dark)
        .map(|(m, d)| (m - d).max(0.0))
        .collect();
    let k = log_kernel(p.log_sigma, p.log_half);
    let logresp = convolve_same(&sub, w, &k, p.log_half);
    let mask: Vec<f32> = sub
        .iter()
        .zip(&logresp)
        .map(|(s, l)| {
            if *s > p.intensity_threshold && *l > p.log_threshold {
                1.0
            } else {
                0.0
            }
        })
        .collect();
    let count = mask.iter().map(|&m| m as u64).sum();
    Reduced { sub, mask, count }
}

/// Frame reduction through the AOT artifact (frame size must match the
/// manifest's traced shape).
pub fn reduce_frame_artifact(rt: &mut Runtime, frame: &[f32], dark: &[f32]) -> Result<Reduced> {
    let n = rt.manifest.config.frame;
    let shape = vec![n, n];
    let outs = rt.call(
        "reduce_frame",
        &[
            TensorF32::new(shape.clone(), frame.to_vec()),
            TensorF32::new(shape, dark.to_vec()),
        ],
    )?;
    // Outputs: sub, mask, logresp, count (see model.reduce_frame).
    let count = outs[3].data[0] as u64;
    Ok(Reduced { sub: outs[0].data.clone(), mask: outs[1].data.clone(), count })
}

/// Dark median through the AOT artifact.
pub fn dark_median_artifact(rt: &mut Runtime, frames: &[Vec<f32>]) -> Result<Vec<f32>> {
    let n = rt.manifest.config.frame;
    let k = rt.manifest.config.dark_frames;
    assert_eq!(frames.len(), k, "artifact traced for {k} dark frames");
    let mut data = Vec::with_capacity(k * n * n);
    for f in frames {
        data.extend_from_slice(f);
    }
    let outs = rt.call("dark_median", &[TensorF32::new(vec![k, n, n], data)])?;
    Ok(outs[0].data.clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hedm::detector::splat;

    #[test]
    fn dark_median_robust_to_outlier() {
        let mut frames = vec![vec![50.0f32; 16]; 7];
        frames.push(vec![5000.0f32; 16]);
        let dark = dark_median_native(&frames);
        assert!(dark.iter().all(|&v| v == 50.0));
    }

    #[test]
    fn median_kills_zinger_keeps_blob() {
        let n = 32;
        let mut img = vec![0f32; n * n];
        img[5 * n + 5] = 1000.0;
        for y in 10..13 {
            for x in 10..13 {
                img[y * n + x] = 500.0;
            }
        }
        let med = median3x3(&img, n);
        assert_eq!(med[5 * n + 5], 0.0);
        assert_eq!(med[11 * n + 11], 500.0);
    }

    #[test]
    fn log_kernel_zero_mean_positive_center() {
        let k = log_kernel(1.2, 2);
        let sum: f32 = k.iter().sum();
        assert!(sum.abs() < 1e-4);
        assert!(k[2 * 5 + 2] > 0.0);
    }

    #[test]
    fn reduction_detects_spot_rejects_flat() {
        let n = 64;
        let mut frame = vec![40.0f32; n * n];
        splat(&mut frame, n, 30.0, 20.0, 400.0, 1.5);
        let dark = vec![40.0f32; n * n];
        let r = reduce_frame_native(&frame, &dark, n, &ReduceParams::default());
        assert!(r.mask[20 * n + 30] == 1.0);
        assert!(r.count > 0 && r.count < 40, "{}", r.count);
        // Flat frame: nothing.
        let flat = reduce_frame_native(&dark, &dark, n, &ReduceParams::default());
        assert_eq!(flat.count, 0);
    }

    #[test]
    fn sparsity_matches_paper_ratio() {
        // 8 MB raw -> ~1 MB binary: the signal mask must be sparse.
        let n = 128;
        let mut frame = vec![40.0f32; n * n];
        for i in 0..12 {
            splat(&mut frame, n, 10.0 + 9.0 * i as f64, 64.0, 400.0, 1.5);
        }
        let dark = vec![40.0f32; n * n];
        let r = reduce_frame_native(&frame, &dark, n, &ReduceParams::default());
        let fill = r.count as f64 / (n * n) as f64;
        assert!(fill < 0.02, "mask fill {fill}");
    }

    /// Cross-language check: Rust native vs JAX artifact, same pixels.
    #[test]
    fn native_matches_artifact() {
        if !Runtime::artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut rt = Runtime::load(Runtime::default_dir()).unwrap();
        let n = rt.manifest.config.frame;
        let mut rng = crate::util::prng::Pcg64::new(11);
        let mut frame = vec![0f32; n * n];
        for px in frame.iter_mut() {
            *px = 40.0 + rng.normal() as f32 * 3.0;
        }
        for i in 0..8 {
            splat(&mut frame, n, 50.0 + 40.0 * i as f64, 100.0 + 30.0 * i as f64, 400.0, 1.5);
        }
        let dark = vec![40.0f32; n * n];
        let params = ReduceParams {
            intensity_threshold: rt.manifest.config.intensity_threshold as f32,
            log_threshold: rt.manifest.config.log_threshold as f32,
            ..Default::default()
        };
        let native = reduce_frame_native(&frame, &dark, n, &params);
        let artifact = reduce_frame_artifact(&mut rt, &frame, &dark).unwrap();
        assert_eq!(native.count, artifact.count, "signal counts differ");
        let mask_diff = native
            .mask
            .iter()
            .zip(&artifact.mask)
            .filter(|(a, b)| a != b)
            .count();
        assert_eq!(mask_diff, 0, "masks differ at {mask_diff} pixels");
        let max_sub_err = native
            .sub
            .iter()
            .zip(&artifact.sub)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_sub_err < 1e-3, "max |sub| err {max_sub_err}");
    }

    #[test]
    fn dark_median_native_matches_artifact() {
        if !Runtime::artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut rt = Runtime::load(Runtime::default_dir()).unwrap();
        let n = rt.manifest.config.frame;
        let k = rt.manifest.config.dark_frames;
        let mut rng = crate::util::prng::Pcg64::new(12);
        let frames: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..n * n).map(|_| 40.0 + rng.normal() as f32 * 3.0).collect())
            .collect();
        let native = dark_median_native(&frames);
        let artifact = dark_median_artifact(&mut rt, &frames).unwrap();
        let max_err = native
            .iter()
            .zip(&artifact)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-4, "{max_err}");
    }
}
