//! Far-field HEDM stage 2: indexing — assigning diffraction spots to
//! grains (SII).
//!
//! "In the second step, the diffraction spots are assigned (called
//! 'indexing') as belonging to grains, and properties of the grains
//! are calculated." Classic greedy indexing: repeatedly fit the best
//! orientation against the *unassigned* spot set, claim its matched
//! spots, and continue until no orientation reaches the completeness
//! floor. Each accepted orientation is one grain (the Fig 3 dots).

use anyhow::Result;

use crate::hedm::fit::{fit_orientation, FitResult, NativeScorer, ScanCfg};
use crate::hedm::geometry::{simulate_spots, Geom, Spot};
use crate::runtime::Runtime;

/// One indexed grain.
#[derive(Clone, Debug)]
pub struct IndexedGrain {
    pub fit: FitResult,
    /// Spots claimed from the observation set.
    pub claimed: usize,
}

/// Indexing configuration.
#[derive(Clone, Copy, Debug)]
pub struct IndexCfg {
    /// Stop when the best remaining completeness drops below this.
    pub min_confidence: f64,
    /// Safety cap on grains.
    pub max_grains: usize,
    pub scan: ScanCfg,
}

impl Default for IndexCfg {
    fn default() -> Self {
        IndexCfg { min_confidence: 0.6, max_grains: 64, scan: ScanCfg::default() }
    }
}

/// Remove from `obs` every spot within tolerance of a predicted spot
/// of `euler`; returns how many were claimed.
pub fn claim_spots(obs: &mut Vec<Spot>, euler: [f64; 3], g: &Geom) -> usize {
    let predicted = simulate_spots(euler, g);
    let tol2 = g.match_tol * g.match_tol;
    let before = obs.len();
    obs.retain(|o| {
        let ow = o.weighted(g);
        !predicted.iter().any(|p| {
            let pw = p.weighted(g);
            let d = [
                (pw[0] - ow[0]) as f64,
                (pw[1] - ow[1]) as f64,
                (pw[2] - ow[2]) as f64,
            ];
            d[0] * d[0] + d[1] * d[1] + d[2] * d[2] <= tol2
        })
    });
    before - obs.len()
}

/// Greedy indexing with the native scorer.
pub fn index_grains_native(obs: &[Spot], geom: Geom, cfg: &IndexCfg) -> Vec<IndexedGrain> {
    let mut remaining: Vec<Spot> = obs.to_vec();
    let mut grains = Vec::new();
    let mut seed = cfg.scan.seed;
    while grains.len() < cfg.max_grains && remaining.len() >= 4 {
        let mut scorer = NativeScorer::new(geom, &remaining);
        let scan = ScanCfg { seed, ..cfg.scan };
        let fit = fit_orientation(&mut scorer, &scan).expect("native scan");
        if fit.confidence < cfg.min_confidence {
            break;
        }
        let claimed = claim_spots(&mut remaining, fit.euler, &geom);
        if claimed == 0 {
            break; // no progress: avoid livelock
        }
        grains.push(IndexedGrain { fit, claimed });
        seed = seed.wrapping_add(1);
    }
    grains
}

/// Greedy indexing through the AOT artifact scorer.
pub fn index_grains_artifact(
    rt: &mut Runtime,
    obs: &[Spot],
    cfg: &IndexCfg,
) -> Result<Vec<IndexedGrain>> {
    let geom = Geom::from_manifest(&rt.manifest.config);
    let mut remaining: Vec<Spot> = obs.to_vec();
    let mut grains = Vec::new();
    let mut seed = cfg.scan.seed;
    while grains.len() < cfg.max_grains && remaining.len() >= 4 {
        let fit = {
            let mut scorer = crate::hedm::fit::ArtifactScorer::new(rt, &remaining);
            let scan = ScanCfg { seed, ..cfg.scan };
            fit_orientation(&mut scorer, &scan)?
        };
        if fit.confidence < cfg.min_confidence {
            break;
        }
        let claimed = claim_spots(&mut remaining, fit.euler, &geom);
        if claimed == 0 {
            break;
        }
        grains.push(IndexedGrain { fit, claimed });
        seed = seed.wrapping_add(1);
    }
    Ok(grains)
}

/// Match indexed grains against ground truth by spot-pattern overlap
/// (orientation comparison must be symmetry-invariant). Returns the
/// number of truth grains recovered.
pub fn count_recovered(
    grains: &[IndexedGrain],
    truth: &[[f64; 3]],
    geom: &Geom,
) -> usize {
    truth
        .iter()
        .filter(|t| {
            let ts = simulate_spots(**t, geom);
            grains.iter().any(|g| {
                let gs = simulate_spots(g.fit.euler, geom);
                crate::hedm::geometry::spot_overlap(&ts, &gs, geom) > 0.85
            })
        })
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hedm::detector::Layer;

    fn small_geom() -> Geom {
        Geom { frame: 256, det_dist: 1.25e5, ..Geom::default() }
    }

    #[test]
    fn claim_removes_exactly_matching_spots() {
        let g = small_geom();
        let e1 = [0.9, 1.3, 0.2];
        let e2 = [2.0, 0.6, 1.1];
        let s1 = simulate_spots(e1, &g);
        let s2 = simulate_spots(e2, &g);
        let mut obs: Vec<Spot> = s1.iter().chain(&s2).copied().collect();
        let claimed = claim_spots(&mut obs, e1, &g);
        assert!(claimed >= s1.len() * 9 / 10, "claimed {claimed} of {}", s1.len());
        // Most of grain 2's spots survive (a few may collide).
        assert!(obs.len() >= s2.len() * 7 / 10, "{} left", obs.len());
    }

    #[test]
    fn indexes_three_grain_volume() {
        let g = small_geom();
        let layer = Layer::synthesize(3, g, 21);
        let obs = layer.all_spots();
        let cfg = IndexCfg::default();
        let grains = index_grains_native(&obs, g, &cfg);
        assert!(grains.len() >= 3, "found {} grains", grains.len());
        let truth: Vec<[f64; 3]> = layer.grains.iter().map(|gr| gr.euler).collect();
        let recovered = count_recovered(&grains, &truth, &g);
        assert_eq!(recovered, 3, "recovered {recovered}/3 grains");
    }

    #[test]
    fn empty_observations_index_nothing() {
        let g = small_geom();
        let grains = index_grains_native(&[], g, &IndexCfg::default());
        assert!(grains.is_empty());
    }

    #[test]
    fn noise_floor_terminates() {
        // Pure noise: indexing must stop at the confidence floor, not
        // fabricate grains.
        let g = small_geom();
        let mut rng = crate::util::prng::Pcg64::new(9);
        let obs: Vec<Spot> = (0..30)
            .map(|_| Spot {
                u: rng.range_f64(0.0, 256.0),
                v: rng.range_f64(0.0, 256.0),
                omega_deg: rng.range_f64(-180.0, 180.0),
            })
            .collect();
        let cfg = IndexCfg {
            min_confidence: 0.7,
            scan: ScanCfg { coarse: 256, rounds: 2, per_leader: 12, ..Default::default() },
            ..Default::default()
        };
        let grains = index_grains_native(&obs, g, &cfg);
        assert!(grains.len() <= 1, "{} phantom grains", grains.len());
    }
}
