//! Synthetic beamline: polycrystal layers and their diffraction scans.
//!
//! The paper's raw data — proprietary rotation-series TIFF scans of
//! gold wire / alloy samples — is unavailable, so we build the
//! detector (DESIGN.md SSubstitutions): a layer is a set of grains
//! with known ground-truth orientations arranged as a Voronoi map on
//! the 2D cross-section; a scan renders, for each rotation step, the
//! diffraction frame with Gaussian spots at the forward-modelled
//! (u, v, omega) positions plus detector background, dark current and
//! zingers (isolated hot pixels — what the median filter exists to
//! kill). Frames are real pixel arrays written to the shared
//! filesystem; the reduction and fitting pipeline runs on them
//! unchanged, and because truth is known, recovery is *verified*, not
//! eyeballed (stronger than the paper's qualitative Figs 2-3).

use crate::hedm::geometry::{simulate_spots, Geom, Spot};
use crate::pfs::{Blob, ParallelFs};
use crate::util::prng::Pcg64;

/// One grain: ground-truth orientation + seed position in the layer.
#[derive(Clone, Debug)]
pub struct Grain {
    pub id: usize,
    pub euler: [f64; 3],
    /// Seed position in the cross-section, micrometres.
    pub pos: (f64, f64),
    /// Pre-computed spot list for this orientation.
    pub spots: Vec<Spot>,
}

/// A 2D sample layer (one NF-HEDM cross-section / FF volume slice).
#[derive(Clone, Debug)]
pub struct Layer {
    pub geom: Geom,
    pub grains: Vec<Grain>,
    /// Cross-section side length, micrometres.
    pub extent: f64,
}

impl Layer {
    /// Random layer with `n_grains` grains (deterministic in `seed`).
    pub fn synthesize(n_grains: usize, geom: Geom, seed: u64) -> Layer {
        assert!(n_grains >= 1);
        let mut rng = Pcg64::new(seed);
        let extent = 1000.0; // 1 mm section
        let grains = (0..n_grains)
            .map(|id| {
                let euler = [
                    rng.range_f64(0.0, 2.0 * std::f64::consts::PI),
                    rng.range_f64(0.0, std::f64::consts::PI),
                    rng.range_f64(0.0, 2.0 * std::f64::consts::PI),
                ];
                Grain {
                    id,
                    euler,
                    pos: (rng.range_f64(0.0, extent), rng.range_f64(0.0, extent)),
                    spots: simulate_spots(euler, &geom),
                }
            })
            .collect();
        Layer { geom, grains, extent }
    }

    /// Which grain owns point (x, y) (Voronoi by seed distance).
    pub fn grain_at(&self, x: f64, y: f64) -> usize {
        self.grains
            .iter()
            .map(|g| {
                let d = (g.pos.0 - x).powi(2) + (g.pos.1 - y).powi(2);
                (d, g.id)
            })
            .min_by(|a, b| a.partial_cmp(b).unwrap())
            .unwrap()
            .1
    }

    /// All spots of all grains (the FF per-volume observation).
    pub fn all_spots(&self) -> Vec<Spot> {
        self.grains.iter().flat_map(|g| g.spots.iter().copied()).collect()
    }

    /// A hexagonal measurement grid over the cross-section with
    /// `pitch` micrometre spacing (the Fig 2 "grid" of NF-HEDM);
    /// returns (x, y, owning grain) per point.
    pub fn hex_grid(&self, pitch: f64) -> Vec<(f64, f64, usize)> {
        let mut pts = Vec::new();
        let dy = pitch * 3.0f64.sqrt() / 2.0;
        let mut row = 0usize;
        let mut y = pitch / 2.0;
        while y < self.extent {
            let x0 = if row % 2 == 0 { pitch / 2.0 } else { pitch };
            let mut x = x0;
            while x < self.extent {
                pts.push((x, y, self.grain_at(x, y)));
                x += pitch;
            }
            y += dy;
            row += 1;
        }
        pts
    }
}

/// Detector noise model.
#[derive(Clone, Copy, Debug)]
pub struct NoiseModel {
    /// Mean dark level, counts.
    pub dark_level: f32,
    /// Background sigma.
    pub bg_sigma: f32,
    /// Spot peak amplitude, counts.
    pub spot_amp: f32,
    /// Spot width, pixels.
    pub spot_sigma: f32,
    /// Probability of a zinger per frame.
    pub zingers_per_frame: f64,
}

impl Default for NoiseModel {
    fn default() -> Self {
        NoiseModel {
            dark_level: 40.0,
            bg_sigma: 3.0,
            spot_amp: 400.0,
            spot_sigma: 1.5,
            zingers_per_frame: 2.0,
        }
    }
}

/// Render the diffraction frame for rotation step `step` from `spots`.
/// Omega bin: step covers [-180 + step*w, -180 + (step+1)*w) degrees.
pub fn render_frame(
    spots: &[Spot],
    geom: &Geom,
    noise: &NoiseModel,
    step: usize,
    rng: &mut Pcg64,
) -> Vec<f32> {
    let n = geom.frame;
    let w = 360.0 / geom.omega_steps as f64;
    let lo = -180.0 + step as f64 * w;
    let hi = lo + w;
    let mut img = vec![0f32; n * n];
    // Background + dark current.
    for px in img.iter_mut() {
        *px = noise.dark_level + (rng.normal() as f32) * noise.bg_sigma;
        if *px < 0.0 {
            *px = 0.0;
        }
    }
    // Spots in this omega bin.
    for s in spots {
        if s.omega_deg < lo || s.omega_deg >= hi {
            continue;
        }
        splat(&mut img, n, s.u, s.v, noise.spot_amp, noise.spot_sigma);
    }
    // Zingers (isolated hot pixels).
    let nz = noise.zingers_per_frame.floor() as usize
        + usize::from(rng.f64() < noise.zingers_per_frame.fract());
    for _ in 0..nz {
        let idx = rng.below((n * n) as u64) as usize;
        img[idx] = 1000.0;
    }
    img
}

/// Add a Gaussian spot (mirror of python tests' splat_gaussian).
pub fn splat(img: &mut [f32], n: usize, u: f64, v: f64, amp: f32, sigma: f32) {
    let r = (3.0 * sigma).ceil() as i64 + 1;
    let cu = u.round() as i64;
    let cv = v.round() as i64;
    let s2 = (2.0 * sigma * sigma) as f64;
    for y in (cv - r).max(0)..((cv + r + 1).min(n as i64)) {
        for x in (cu - r).max(0)..((cu + r + 1).min(n as i64)) {
            let d2 = (y as f64 - v).powi(2) + (x as f64 - u).powi(2);
            img[y as usize * n + x as usize] += amp * (-d2 / s2).exp() as f32;
        }
    }
}

/// A rendered dark frame (no beam).
pub fn render_dark(geom: &Geom, noise: &NoiseModel, rng: &mut Pcg64) -> Vec<f32> {
    render_frame(&[], geom, noise, 0, rng)
}

/// f32 frame <-> little-endian bytes (the on-"disk" format).
pub fn frame_to_bytes(frame: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(frame.len() * 4);
    for v in frame {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

pub fn bytes_to_frame(bytes: &[u8]) -> Vec<f32> {
    assert_eq!(bytes.len() % 4, 0, "frame bytes not f32-aligned");
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// Render a full scan (`omega_steps` frames + `dark_count` darks) into
/// the shared filesystem under `prefix`. Returns total bytes written.
pub fn write_scan(
    pfs: &mut ParallelFs,
    layer: &Layer,
    noise: &NoiseModel,
    prefix: &str,
    dark_count: usize,
    seed: u64,
) -> u64 {
    let mut rng = Pcg64::new(seed);
    let spots = layer.all_spots();
    let mut total = 0u64;
    for d in 0..dark_count {
        let frame = render_dark(&layer.geom, noise, &mut rng);
        let bytes = frame_to_bytes(&frame);
        total += bytes.len() as u64;
        pfs.write(format!("{prefix}/dark_{d:03}.bin"), Blob::real(bytes));
    }
    for step in 0..layer.geom.omega_steps {
        let frame = render_frame(&spots, &layer.geom, noise, step, &mut rng);
        let bytes = frame_to_bytes(&frame);
        total += bytes.len() as u64;
        pfs.write(format!("{prefix}/frame_{step:04}.bin"), Blob::real(bytes));
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_geom() -> Geom {
        Geom { frame: 128, det_dist: 0.625e5, omega_steps: 36, ..Geom::default() }
    }

    #[test]
    fn layer_is_deterministic() {
        let g = small_geom();
        let a = Layer::synthesize(4, g, 7);
        let b = Layer::synthesize(4, g, 7);
        assert_eq!(a.grains[2].euler, b.grains[2].euler);
        let c = Layer::synthesize(4, g, 8);
        assert_ne!(a.grains[0].euler, c.grains[0].euler);
    }

    #[test]
    fn grains_have_spots() {
        let layer = Layer::synthesize(4, small_geom(), 1);
        for g in &layer.grains {
            assert!(!g.spots.is_empty(), "grain {} produced no spots", g.id);
        }
    }

    #[test]
    fn voronoi_owns_seeds() {
        let layer = Layer::synthesize(6, small_geom(), 2);
        for g in &layer.grains {
            assert_eq!(layer.grain_at(g.pos.0, g.pos.1), g.id);
        }
    }

    #[test]
    fn hex_grid_covers_section() {
        let layer = Layer::synthesize(4, small_geom(), 3);
        let grid = layer.hex_grid(50.0);
        // ~1000/50 x 1000/43 ~= 460 points.
        assert!(grid.len() > 300 && grid.len() < 700, "{}", grid.len());
        // Every grain should own at least one point at this pitch.
        for g in &layer.grains {
            assert!(grid.iter().any(|&(_, _, owner)| owner == g.id));
        }
    }

    #[test]
    fn frames_contain_their_bin_spots() {
        let g = small_geom();
        let layer = Layer::synthesize(3, g, 4);
        let noise = NoiseModel { bg_sigma: 0.0, zingers_per_frame: 0.0, ..Default::default() };
        let spots = layer.all_spots();
        let mut rng = Pcg64::new(0);
        let s = &spots[0];
        let step = ((s.omega_deg + 180.0) / 10.0).floor() as usize;
        let img = render_frame(&spots, &g, &noise, step, &mut rng);
        let px = img[(s.v.round() as usize) * g.frame + s.u.round() as usize];
        assert!(px > noise.dark_level + 0.5 * noise.spot_amp, "{px}");
        // A frame from an empty bin has only background.
        let empty = render_frame(&[], &g, &noise, 0, &mut rng);
        let max = empty.iter().cloned().fold(0.0f32, f32::max);
        assert!(max <= noise.dark_level + 1.0);
    }

    #[test]
    fn byte_roundtrip() {
        let frame = vec![0.5f32, -1.25, 40.0, 1e6];
        assert_eq!(bytes_to_frame(&frame_to_bytes(&frame)), frame);
    }

    #[test]
    fn write_scan_populates_pfs() {
        let g = small_geom();
        let layer = Layer::synthesize(2, g, 5);
        let mut pfs = ParallelFs::new();
        let total = write_scan(&mut pfs, &layer, &NoiseModel::default(), "/aps/run1", 4, 9);
        assert_eq!(pfs.glob("/aps/run1/frame_*.bin").len(), 36);
        assert_eq!(pfs.glob("/aps/run1/dark_*.bin").len(), 4);
        assert_eq!(total, (36 + 4) * (128 * 128 * 4) as u64);
        assert_eq!(pfs.total_bytes(), total);
    }
}
