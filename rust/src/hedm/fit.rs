//! Stage-2 orientation fitting: the paper's FitOrientation (Fig 8).
//!
//! The paper runs an NLopt optimisation per grid point, one CPU core
//! each, ~10^5 points per layer. The TPU-era adaptation (DESIGN.md
//! SHardware-Adaptation) keeps the many-task structure at L3 but
//! replaces the scalar optimiser with a **batched multi-resolution
//! scan**: a coarse quasi-random sweep of SO(3) scored `b_batch`
//! candidates at a time by the AOT `fit_orientation` kernel (MXU
//! matmuls over (B,3,3)x(3,S) rotations and (B*2S,3)x(3,O) distance
//! cross-terms), then shrinking local refinement around the leaders.
//! The score is *completeness*: matched / simulated spots — the
//! paper's confidence measure.
//!
//! Two scorer backends with identical semantics: [`ArtifactScorer`]
//! (PJRT, production) and [`NativeScorer`] (pure Rust oracle).

use anyhow::Result;

use crate::hedm::geometry::{simulate_spots, Geom, Spot};
use crate::runtime::{Runtime, TensorF32};
use crate::util::prng::Pcg64;

/// Result of one grid-point / grain fit.
#[derive(Clone, Copy, Debug)]
pub struct FitResult {
    pub euler: [f64; 3],
    /// matched / simulated, in [0, 1].
    pub confidence: f64,
    pub matched: f64,
    pub simulated: f64,
}

/// Scores batches of candidate orientations against fixed observations.
pub trait Scorer {
    /// (score, matched, simulated) per candidate.
    fn score(&mut self, eulers: &[[f64; 3]]) -> Result<Vec<(f64, f64, f64)>>;
    fn geom(&self) -> &Geom;
}

/// Pure-Rust scorer (oracle / fallback).
pub struct NativeScorer {
    pub geom: Geom,
    obs: Vec<[f32; 3]>,
}

impl NativeScorer {
    pub fn new(geom: Geom, obs: &[Spot]) -> NativeScorer {
        NativeScorer { obs: obs.iter().map(|s| s.weighted(&geom)).collect(), geom }
    }
}

impl Scorer for NativeScorer {
    fn score(&mut self, eulers: &[[f64; 3]]) -> Result<Vec<(f64, f64, f64)>> {
        let tol2 = (self.geom.match_tol * self.geom.match_tol) as f32;
        Ok(eulers
            .iter()
            .map(|&e| {
                let sim = simulate_spots(e, &self.geom);
                let mut matched = 0usize;
                for s in &sim {
                    let sw = s.weighted(&self.geom);
                    if self.obs.iter().any(|o| {
                        let d = [sw[0] - o[0], sw[1] - o[1], sw[2] - o[2]];
                        d[0] * d[0] + d[1] * d[1] + d[2] * d[2] <= tol2
                    }) {
                        matched += 1;
                    }
                }
                let simulated = sim.len();
                let score = if simulated == 0 {
                    0.0
                } else {
                    matched as f64 / simulated as f64
                };
                (score, matched as f64, simulated as f64)
            })
            .collect())
    }

    fn geom(&self) -> &Geom {
        &self.geom
    }
}

/// PJRT-backed scorer using the `fit_orientation` artifact.
pub struct ArtifactScorer<'a> {
    rt: &'a mut Runtime,
    geom: Geom,
    gvec: TensorF32,
    gmask: TensorF32,
    obs: TensorF32,
    obs_mask: TensorF32,
}

impl<'a> ArtifactScorer<'a> {
    /// Pack observations once; reused across every batch of the scan.
    pub fn new(rt: &'a mut Runtime, obs: &[Spot]) -> ArtifactScorer<'a> {
        let geom = Geom::from_manifest(&rt.manifest.config);
        let o_max = geom.o_max;
        let mut obs_data = vec![-1.0e6f32; o_max * 3];
        let mut mask = vec![0f32; o_max];
        for (i, s) in obs.iter().take(o_max).enumerate() {
            let w = s.weighted(&geom);
            obs_data[i * 3] = w[0];
            obs_data[i * 3 + 1] = w[1];
            obs_data[i * 3 + 2] = w[2];
            mask[i] = 1.0;
        }
        let gvec_data: Vec<f32> = rt.manifest.gvectors.iter().flatten().copied().collect();
        let s_max = geom.s_max;
        ArtifactScorer {
            geom,
            gvec: TensorF32::new(vec![s_max, 3], gvec_data),
            gmask: TensorF32::new(vec![s_max], rt.manifest.gvector_mask.clone()),
            obs: TensorF32::new(vec![o_max, 3], obs_data),
            obs_mask: TensorF32::new(vec![o_max], mask),
            rt,
        }
    }
}

impl Scorer for ArtifactScorer<'_> {
    fn score(&mut self, eulers: &[[f64; 3]]) -> Result<Vec<(f64, f64, f64)>> {
        let b = self.geom.b_batch;
        let mut out = Vec::with_capacity(eulers.len());
        for chunk in eulers.chunks(b) {
            // Pad the final chunk by repeating its first entry.
            let mut data = Vec::with_capacity(b * 3);
            for e in chunk {
                data.extend_from_slice(&[e[0] as f32, e[1] as f32, e[2] as f32]);
            }
            while data.len() < b * 3 {
                data.extend_from_slice(&[
                    chunk[0][0] as f32,
                    chunk[0][1] as f32,
                    chunk[0][2] as f32,
                ]);
            }
            let outs = self.rt.call(
                "fit_orientation",
                &[
                    TensorF32::new(vec![b, 3], data),
                    self.gvec.clone(),
                    self.gmask.clone(),
                    self.obs.clone(),
                    self.obs_mask.clone(),
                ],
            )?;
            for i in 0..chunk.len() {
                out.push((
                    outs[0].data[i] as f64,
                    outs[1].data[i] as f64,
                    outs[2].data[i] as f64,
                ));
            }
        }
        Ok(out)
    }

    fn geom(&self) -> &Geom {
        &self.geom
    }
}

/// Scan configuration.
#[derive(Clone, Copy, Debug)]
pub struct ScanCfg {
    /// Coarse SO(3) samples.
    pub coarse: usize,
    /// Leaders refined per round.
    pub top_k: usize,
    /// Refinement rounds (radius shrinks x0.35 each).
    pub rounds: usize,
    /// Perturbations per leader per round.
    pub per_leader: usize,
    /// Initial refinement radius, radians.
    pub radius: f64,
    pub seed: u64,
}

impl Default for ScanCfg {
    fn default() -> Self {
        // Coarse density vs refinement radius: 3072 quasi-random SO(3)
        // samples leave a typical nearest-sample misorientation of
        // ~0.3 rad, so refinement starts at 0.35 rad and shrinks.
        ScanCfg { coarse: 3072, top_k: 8, rounds: 6, per_leader: 48, radius: 0.35, seed: 17 }
    }
}

/// Multi-resolution orientation scan. Returns the best fit found.
pub fn fit_orientation(scorer: &mut dyn Scorer, cfg: &ScanCfg) -> Result<FitResult> {
    let mut rng = Pcg64::new(cfg.seed);
    // Coarse sweep: uniform-ish Euler sampling (phi1, cos(Phi), phi2).
    let mut cands: Vec<[f64; 3]> = (0..cfg.coarse)
        .map(|_| {
            [
                rng.range_f64(0.0, 2.0 * std::f64::consts::PI),
                rng.range_f64(-1.0, 1.0).acos(),
                rng.range_f64(0.0, 2.0 * std::f64::consts::PI),
            ]
        })
        .collect();
    let mut best: Vec<([f64; 3], (f64, f64, f64))> = Vec::new();
    let scores = scorer.score(&cands)?;
    let mut ranked: Vec<usize> = (0..cands.len()).collect();
    ranked.sort_by(|&a, &b| scores[b].0.partial_cmp(&scores[a].0).unwrap());
    for &i in ranked.iter().take(cfg.top_k) {
        best.push((cands[i], scores[i]));
    }

    // Shrinking local refinement.
    let mut radius = cfg.radius;
    for _ in 0..cfg.rounds {
        cands.clear();
        for (e, _) in &best {
            for _ in 0..cfg.per_leader {
                cands.push([
                    e[0] + rng.normal() * radius,
                    e[1] + rng.normal() * radius,
                    e[2] + rng.normal() * radius,
                ]);
            }
        }
        let scores = scorer.score(&cands)?;
        for (c, s) in cands.iter().zip(&scores) {
            // Keep the global top_k across rounds.
            best.push((*c, *s));
        }
        best.sort_by(|a, b| b.1 .0.partial_cmp(&a.1 .0).unwrap());
        best.truncate(cfg.top_k);
        radius *= 0.35;
    }

    let (euler, (score, matched, simulated)) = best[0];
    Ok(FitResult { euler, confidence: score, matched, simulated })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hedm::geometry::spot_overlap;

    fn small_geom() -> Geom {
        Geom { frame: 256, det_dist: 1.25e5, ..Geom::default() }
    }

    /// Orientation recovery must be checked modulo cubic symmetry: the
    /// spot pattern is invariant under the 24 proper rotations of the
    /// cube, so compare *patterns*, not Euler angles.
    fn patterns_match(a: [f64; 3], b: [f64; 3], g: &Geom) -> bool {
        let sa = simulate_spots(a, g);
        let sb = simulate_spots(b, g);
        spot_overlap(&sa, &sb, g) > 0.9
    }

    #[test]
    fn native_scan_recovers_truth() {
        let g = small_geom();
        let truth = [0.9, 1.3, 0.2];
        let obs = simulate_spots(truth, &g);
        let mut scorer = NativeScorer::new(g, &obs);
        let cfg = ScanCfg::default();
        let fit = fit_orientation(&mut scorer, &cfg).unwrap();
        assert!(fit.confidence > 0.9, "confidence {}", fit.confidence);
        assert!(patterns_match(fit.euler, truth, &g), "euler {:?}", fit.euler);
    }

    #[test]
    fn confidence_low_for_garbage_observations() {
        let g = small_geom();
        // Observations at positions no lattice orientation produces
        // coherently: random scatter.
        let mut rng = Pcg64::new(5);
        let obs: Vec<Spot> = (0..40)
            .map(|_| crate::hedm::geometry::Spot {
                u: rng.range_f64(0.0, 256.0),
                v: rng.range_f64(0.0, 256.0),
                omega_deg: rng.range_f64(-180.0, 180.0),
            })
            .collect();
        let mut scorer = NativeScorer::new(g, &obs);
        let cfg = ScanCfg { coarse: 256, rounds: 2, per_leader: 16, ..Default::default() };
        let fit = fit_orientation(&mut scorer, &cfg).unwrap();
        assert!(fit.confidence < 0.6, "confidence {}", fit.confidence);
    }

    #[test]
    fn artifact_scorer_matches_native() {
        if !Runtime::artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut rt = Runtime::load(Runtime::default_dir()).unwrap();
        let g = Geom::from_manifest(&rt.manifest.config);
        let truth = [2.1, 0.8, 1.7];
        let obs = simulate_spots(truth, &g);
        let mut rng = Pcg64::new(3);
        let eulers: Vec<[f64; 3]> = std::iter::once(truth)
            .chain((0..63).map(|_| {
                [
                    rng.range_f64(0.0, 6.28),
                    rng.range_f64(0.0, 3.14),
                    rng.range_f64(0.0, 6.28),
                ]
            }))
            .collect();
        let native = NativeScorer::new(g, &obs).score(&eulers).unwrap();
        let artifact = ArtifactScorer::new(&mut rt, &obs).score(&eulers).unwrap();
        for (i, (n, a)) in native.iter().zip(&artifact).enumerate() {
            assert!(
                (n.0 - a.0).abs() < 0.08,
                "cand {i}: native {} vs artifact {}",
                n.0,
                a.0
            );
        }
        // The true orientation is a perfect fit on both backends.
        assert!(native[0].0 > 0.95 && artifact[0].0 > 0.95);
    }

    #[test]
    fn artifact_scan_recovers_truth() {
        if !Runtime::artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut rt = Runtime::load(Runtime::default_dir()).unwrap();
        let g = Geom::from_manifest(&rt.manifest.config);
        let truth = [0.9, 1.3, 0.2];
        let obs = simulate_spots(truth, &g);
        let mut scorer = ArtifactScorer::new(&mut rt, &obs);
        let cfg = ScanCfg { coarse: 1024, rounds: 4, ..Default::default() };
        let fit = fit_orientation(&mut scorer, &cfg).unwrap();
        assert!(fit.confidence > 0.9, "confidence {}", fit.confidence);
        assert!(patterns_match(fit.euler, truth, &g));
    }
}
