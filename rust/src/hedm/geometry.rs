//! Diffraction geometry: the Rust mirror of `python/compile/geometry.py`.
//!
//! Both sides implement the same far-field rotating-crystal forward
//! model from the same constants; `manifest_matches` cross-checks this
//! module against the values baked into the AOT artifacts, so the
//! detector simulator (Rust) and the fitting kernel (JAX) share one
//! physics. See the Python module docstring for the derivation.

use crate::runtime::manifest::GeomConfig;

/// Geometry constants (defaults = python geometry.DEFAULT_CONFIG).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Geom {
    /// X-ray wavelength, Angstrom (71.68 keV).
    pub wavelength: f64,
    /// Cubic lattice parameter, Angstrom (FCC gold).
    pub lattice_a: f64,
    /// Sample-detector distance, micrometres.
    pub det_dist: f64,
    /// Pixel pitch, micrometres.
    pub pixel_size: f64,
    /// Square panel size, pixels.
    pub frame: usize,
    /// Rotation steps per 360 degree scan.
    pub omega_steps: usize,
    /// Padded reciprocal-vector count.
    pub s_max: usize,
    /// Padded observed-spot count for the fit kernel.
    pub o_max: usize,
    /// Fit-kernel batch size.
    pub b_batch: usize,
    /// Omega weight in the spot metric, px/deg.
    pub omega_weight: f64,
    /// Match tolerance, px.
    pub match_tol: f64,
}

impl Default for Geom {
    fn default() -> Self {
        Geom {
            wavelength: 0.172979,
            lattice_a: 4.0782,
            det_dist: 2.5e5,
            pixel_size: 200.0,
            frame: 512,
            omega_steps: 360,
            s_max: 58,
            o_max: 512,
            b_batch: 256,
            omega_weight: 4.0,
            match_tol: 6.0,
        }
    }
}

impl Geom {
    /// Incident wavevector magnitude, 1/Angstrom.
    pub fn k_in(&self) -> f64 {
        2.0 * std::f64::consts::PI / self.wavelength
    }

    /// Beam-centre pixel.
    pub fn center(&self) -> f64 {
        self.frame as f64 / 2.0
    }

    /// From the artifact manifest (the authoritative source once
    /// artifacts exist).
    pub fn from_manifest(c: &GeomConfig) -> Geom {
        Geom {
            wavelength: c.wavelength,
            lattice_a: c.lattice_a,
            det_dist: c.det_dist,
            pixel_size: c.pixel_size,
            frame: c.frame,
            omega_steps: c.omega_steps,
            s_max: c.s_max,
            o_max: c.o_max,
            b_batch: c.b_batch,
            omega_weight: c.omega_weight,
            match_tol: c.match_tol,
        }
    }
}

/// FCC selection rule: h, k, l all even or all odd.
pub fn fcc_allowed(h: i32, k: i32, l: i32) -> bool {
    let p = (h.rem_euclid(2), k.rem_euclid(2), l.rem_euclid(2));
    p == (0, 0, 0) || p == (1, 1, 1)
}

/// Reciprocal-lattice vectors, complete-|G|-shell truncated and padded
/// to `s_max` (mirror of python `gvectors`). Returns (vectors, mask).
pub fn gvectors(g: &Geom) -> (Vec<[f64; 3]>, Vec<bool>) {
    let hmax = 3i32;
    let mut all: Vec<(i32, i32, i32, i32)> = Vec::new(); // (norm2, h, k, l)
    for h in -hmax..=hmax {
        for k in -hmax..=hmax {
            for l in -hmax..=hmax {
                if (h, k, l) == (0, 0, 0) || !fcc_allowed(h, k, l) {
                    continue;
                }
                all.push((h * h + k * k + l * l, h, k, l));
            }
        }
    }
    all.sort();
    let mut kept = Vec::new();
    let mut i = 0;
    while i < all.len() {
        let mut j = i;
        while j < all.len() && all[j].0 == all[i].0 {
            j += 1;
        }
        if kept.len() + (j - i) > g.s_max {
            break;
        }
        kept.extend_from_slice(&all[i..j]);
        i = j;
    }
    let scale = 2.0 * std::f64::consts::PI / g.lattice_a;
    let mut vecs: Vec<[f64; 3]> = kept
        .iter()
        .map(|&(_, h, k, l)| [h as f64 * scale, k as f64 * scale, l as f64 * scale])
        .collect();
    let mut mask = vec![true; vecs.len()];
    while vecs.len() < g.s_max {
        vecs.push([0.0; 3]);
        mask.push(false);
    }
    (vecs, mask)
}

/// Bunge ZXZ Euler angles -> rotation matrix (row-major 3x3).
pub fn euler_to_matrix(phi1: f64, capphi: f64, phi2: f64) -> [[f64; 3]; 3] {
    let (c1, s1) = (phi1.cos(), phi1.sin());
    let (cp, sp) = (capphi.cos(), capphi.sin());
    let (c2, s2) = (phi2.cos(), phi2.sin());
    [
        [c1 * c2 - s1 * cp * s2, -c1 * s2 - s1 * cp * c2, s1 * sp],
        [s1 * c2 + c1 * cp * s2, -s1 * s2 + c1 * cp * c2, -c1 * sp],
        [sp * s2, sp * c2, cp],
    ]
}

fn matvec(m: &[[f64; 3]; 3], v: &[f64; 3]) -> [f64; 3] {
    [
        m[0][0] * v[0] + m[0][1] * v[1] + m[0][2] * v[2],
        m[1][0] * v[0] + m[1][1] * v[1] + m[1][2] * v[2],
        m[2][0] * v[0] + m[2][1] * v[1] + m[2][2] * v[2],
    ]
}

/// One diffraction spot: detector pixel coordinates + rotation angle.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Spot {
    pub u: f64,
    pub v: f64,
    pub omega_deg: f64,
}

impl Spot {
    /// Weighted coordinates for the fit-kernel metric.
    pub fn weighted(&self, g: &Geom) -> [f32; 3] {
        [self.u as f32, self.v as f32, (self.omega_deg * g.omega_weight) as f32]
    }
}

/// Forward-simulate all spots of one grain (mirror of python
/// `simulate_spots`). Friedel pairs included; off-panel spots culled.
pub fn simulate_spots(euler: [f64; 3], g: &Geom) -> Vec<Spot> {
    let rot = euler_to_matrix(euler[0], euler[1], euler[2]);
    let (gv, mask) = gvectors(g);
    let lam = g.wavelength;
    let k = g.k_in();
    let four_pi = 4.0 * std::f64::consts::PI;
    let mut out = Vec::new();
    for (v0, keep) in gv.iter().zip(mask) {
        if !keep {
            continue;
        }
        let gr = matvec(&rot, v0);
        let gsq = gr[0] * gr[0] + gr[1] * gr[1] + gr[2] * gr[2];
        let a = (gr[0] * gr[0] + gr[1] * gr[1]).sqrt();
        if a < 1e-12 {
            continue;
        }
        let t = -lam * gsq / four_pi / a;
        if t.abs() > 1.0 {
            continue;
        }
        let phi = gr[1].atan2(gr[0]);
        for sign in [1.0, -1.0] {
            let mut omega = sign * t.acos() - phi;
            // wrap to [-pi, pi)
            omega = (omega + std::f64::consts::PI)
                .rem_euclid(2.0 * std::f64::consts::PI)
                - std::f64::consts::PI;
            let (co, so) = (omega.cos(), omega.sin());
            let gxr = gr[0] * co - gr[1] * so;
            let gyr = gr[0] * so + gr[1] * co;
            let kfx = k + gxr;
            if kfx <= 0.0 {
                continue;
            }
            let u = g.det_dist * gyr / kfx / g.pixel_size + g.center();
            let v = g.det_dist * gr[2] / kfx / g.pixel_size + g.center();
            if u >= 0.0 && u < g.frame as f64 && v >= 0.0 && v < g.frame as f64 {
                out.push(Spot { u, v, omega_deg: omega.to_degrees() });
            }
        }
    }
    out
}

/// Misorientation-free distance between two spot sets: fraction of
/// `a`'s spots with a match in `b` within `tol` (weighted metric).
pub fn spot_overlap(a: &[Spot], b: &[Spot], g: &Geom) -> f64 {
    if a.is_empty() {
        return 0.0;
    }
    let tol2 = g.match_tol * g.match_tol;
    let mut hits = 0usize;
    for s in a {
        let sw = s.weighted(g);
        let found = b.iter().any(|o| {
            let ow = o.weighted(g);
            let d = [sw[0] - ow[0], sw[1] - ow[1], sw[2] - ow[2]];
            (d[0] * d[0] + d[1] * d[1] + d[2] * d[2]) as f64 <= tol2
        });
        if found {
            hits += 1;
        }
    }
    hits as f64 / a.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fcc_rule() {
        assert!(fcc_allowed(1, 1, 1));
        assert!(fcc_allowed(2, 0, 0));
        assert!(fcc_allowed(-1, 1, -1));
        assert!(!fcc_allowed(1, 0, 0));
        assert!(!fcc_allowed(2, 1, 0));
    }

    #[test]
    fn gvectors_complete_shells() {
        let g = Geom::default();
        let (gv, mask) = gvectors(&g);
        assert_eq!(gv.len(), g.s_max);
        let real: Vec<_> = gv.iter().zip(&mask).filter(|(_, m)| **m).collect();
        assert_eq!(real.len(), 58); // {111}+{200}+{220}+{311}+{222}
        // Inversion symmetry (Friedel).
        for (v, _) in &real {
            let neg = [-v[0], -v[1], -v[2]];
            assert!(
                real.iter().any(|(w, _)| w
                    .iter()
                    .zip(&neg)
                    .all(|(a, b)| (a - b).abs() < 1e-9)),
                "missing Friedel mate of {v:?}"
            );
        }
    }

    #[test]
    fn rotation_is_orthonormal() {
        let r = euler_to_matrix(0.3, 0.7, 1.1);
        for i in 0..3 {
            for j in 0..3 {
                let dot: f64 = (0..3).map(|k| r[i][k] * r[j][k]).sum();
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn spots_on_panel_and_bragg_consistent() {
        let g = Geom::default();
        let spots = simulate_spots([0.3, 0.7, 1.1], &g);
        assert!(spots.len() >= 8, "{}", spots.len());
        let (gv, mask) = gvectors(&g);
        let norms: Vec<f64> = gv
            .iter()
            .zip(&mask)
            .filter(|(_, m)| **m)
            .map(|(v, _)| (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]).sqrt())
            .collect();
        for s in &spots {
            assert!(s.u >= 0.0 && s.u < g.frame as f64);
            assert!(s.v >= 0.0 && s.v < g.frame as f64);
            // Reconstruct |g| from the detector position; must equal a
            // lattice-vector norm (elastic scattering consistency).
            let y = (s.u - g.center()) * g.pixel_size;
            let z = (s.v - g.center()) * g.pixel_size;
            let x = g.det_dist;
            let n = (x * x + y * y + z * z).sqrt();
            let k = g.k_in();
            let kout = [k * x / n, k * y / n, k * z / n];
            let gv = [kout[0] - k, kout[1], kout[2]];
            let gn = (gv[0] * gv[0] + gv[1] * gv[1] + gv[2] * gv[2]).sqrt();
            let best = norms
                .iter()
                .map(|m| (m - gn).abs())
                .fold(f64::INFINITY, f64::min);
            assert!(best < 1e-3, "spot {s:?}: |g|={gn}, nearest shell {best}");
        }
    }

    #[test]
    fn self_overlap_is_one() {
        let g = Geom::default();
        let spots = simulate_spots([1.9, 0.4, 0.8], &g);
        assert_eq!(spot_overlap(&spots, &spots, &g), 1.0);
    }

    #[test]
    fn different_orientations_do_not_overlap() {
        let g = Geom::default();
        let a = simulate_spots([0.3, 0.7, 1.1], &g);
        let b = simulate_spots([2.0, 1.2, 0.1], &g);
        assert!(spot_overlap(&a, &b, &g) < 0.3);
    }

    /// Cross-language consistency: Rust vs the Python-traced manifest.
    #[test]
    fn manifest_matches_rust_geometry() {
        let dir = crate::runtime::Runtime::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = crate::runtime::Manifest::load(&dir).unwrap();
        let g = Geom::from_manifest(&m.config);
        let (gv, mask) = gvectors(&g);
        assert_eq!(gv.len(), m.gvectors.len());
        for i in 0..gv.len() {
            let pm = m.gvector_mask[i] > 0.5;
            assert_eq!(mask[i], pm, "mask row {i}");
            for c in 0..3 {
                assert!(
                    (gv[i][c] - m.gvectors[i][c] as f64).abs() < 1e-4,
                    "gvector [{i}][{c}]: rust {} vs jax {}",
                    gv[i][c],
                    m.gvectors[i][c]
                );
            }
        }
    }
}
