//! Connected-component labeling and peak characterisation.
//!
//! Stage 1 of both HEDM variants ends with "a connected components
//! labeling step, and a flood fill operation to retrieve information
//! regarding all useful pixels" (SVI-A) / "properties of the
//! diffraction spots are calculated" (SII). This module implements
//! two-pass union-find CCL over the binary signal mask and extracts
//! per-component peak properties (area, intensity-weighted centroid,
//! integrated and peak intensity) — the contents of the "~50 KB text
//! file" FF stage 1 emits per frame.

/// Per-component peak properties.
#[derive(Clone, Debug, PartialEq)]
pub struct Peak {
    /// Intensity-weighted centroid, pixels (x = u, y = v).
    pub u: f64,
    pub v: f64,
    /// Pixel count.
    pub area: usize,
    /// Sum of member intensities.
    pub integrated: f64,
    /// Max member intensity.
    pub peak: f32,
}

/// Union-find with path halving.
struct Uf {
    parent: Vec<u32>,
}

impl Uf {
    fn new(n: usize) -> Uf {
        Uf { parent: (0..n as u32).collect() }
    }

    fn find(&mut self, mut x: u32) -> u32 {
        while self.parent[x as usize] != x {
            self.parent[x as usize] = self.parent[self.parent[x as usize] as usize];
            x = self.parent[x as usize];
        }
        x
    }

    fn union(&mut self, a: u32, b: u32) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra != rb {
            // Smaller root wins: keeps labels stable/deterministic.
            let (lo, hi) = if ra < rb { (ra, rb) } else { (rb, ra) };
            self.parent[hi as usize] = lo;
        }
    }
}

/// Label 4-connected components of `mask` (non-zero = signal) and
/// compute peak properties from `intensity`. Components smaller than
/// `min_area` are dropped (hot-pixel leftovers). Peaks are returned
/// sorted by integrated intensity, descending.
pub fn find_peaks(
    mask: &[f32],
    intensity: &[f32],
    width: usize,
    min_area: usize,
) -> Vec<Peak> {
    assert_eq!(mask.len() % width, 0, "ragged mask");
    assert_eq!(mask.len(), intensity.len());
    let height = mask.len() / width;
    let mut labels = vec![0u32; mask.len()]; // 0 = background, else id+1
    let mut uf = Uf::new(0);
    let mut next = 0u32;

    // Pass 1: provisional labels + equivalences.
    for y in 0..height {
        for x in 0..width {
            let i = y * width + x;
            if mask[i] == 0.0 {
                continue;
            }
            let left = if x > 0 { labels[i - 1] } else { 0 };
            let up = if y > 0 { labels[i - width] } else { 0 };
            labels[i] = match (left, up) {
                (0, 0) => {
                    next += 1;
                    uf.parent.push(next - 1);
                    next
                }
                (l, 0) => l,
                (0, u) => u,
                (l, u) => {
                    uf.union(l - 1, u - 1);
                    l.min(u)
                }
            };
        }
    }

    // Pass 2: resolve + accumulate.
    #[derive(Default, Clone)]
    struct Acc {
        area: usize,
        wsum: f64,
        usum: f64,
        vsum: f64,
        peak: f32,
    }
    let mut accs: Vec<Acc> = vec![Acc::default(); next as usize];
    for y in 0..height {
        for x in 0..width {
            let i = y * width + x;
            if labels[i] == 0 {
                continue;
            }
            let root = uf.find(labels[i] - 1) as usize;
            let a = &mut accs[root];
            let w = intensity[i].max(1e-6) as f64;
            a.area += 1;
            a.wsum += w;
            a.usum += w * x as f64;
            a.vsum += w * y as f64;
            a.peak = a.peak.max(intensity[i]);
        }
    }
    let mut peaks: Vec<Peak> = accs
        .into_iter()
        .filter(|a| a.area >= min_area)
        .map(|a| Peak {
            u: a.usum / a.wsum,
            v: a.vsum / a.wsum,
            area: a.area,
            integrated: a.wsum,
            peak: a.peak,
        })
        .collect();
    peaks.sort_by(|a, b| b.integrated.partial_cmp(&a.integrated).unwrap());
    peaks
}

/// Serialise peaks as the FF stage-1 text format (one line per peak).
pub fn peaks_to_text(peaks: &[Peak], omega_deg: f64) -> String {
    let mut out = String::from("# u_px v_px omega_deg area integrated peak\n");
    for p in peaks {
        out.push_str(&format!(
            "{:.3} {:.3} {:.3} {} {:.1} {:.1}\n",
            p.u, p.v, omega_deg, p.area, p.integrated, p.peak
        ));
    }
    out
}

/// Parse the stage-1 text back into (u, v, omega) rows.
pub fn parse_peaks_text(text: &str) -> Vec<(f64, f64, f64)> {
    text.lines()
        .filter(|l| !l.starts_with('#') && !l.trim().is_empty())
        .filter_map(|l| {
            let mut it = l.split_whitespace();
            let u = it.next()?.parse().ok()?;
            let v = it.next()?.parse().ok()?;
            let w = it.next()?.parse().ok()?;
            Some((u, v, w))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hedm::detector::splat;

    fn blob_mask(img: &[f32], thr: f32) -> Vec<f32> {
        img.iter().map(|&v| if v > thr { 1.0 } else { 0.0 }).collect()
    }

    #[test]
    fn single_blob_centroid() {
        let n = 64;
        let mut img = vec![0f32; n * n];
        splat(&mut img, n, 20.3, 31.7, 500.0, 1.5);
        let mask = blob_mask(&img, 50.0);
        let peaks = find_peaks(&mask, &img, n, 1);
        assert_eq!(peaks.len(), 1);
        assert!((peaks[0].u - 20.3).abs() < 0.25, "{}", peaks[0].u);
        assert!((peaks[0].v - 31.7).abs() < 0.25, "{}", peaks[0].v);
        assert!(peaks[0].area >= 5);
    }

    #[test]
    fn two_blobs_two_components() {
        let n = 64;
        let mut img = vec![0f32; n * n];
        splat(&mut img, n, 10.0, 10.0, 500.0, 1.5);
        splat(&mut img, n, 50.0, 50.0, 300.0, 1.5);
        let mask = blob_mask(&img, 50.0);
        let peaks = find_peaks(&mask, &img, n, 1);
        assert_eq!(peaks.len(), 2);
        // Sorted by integrated intensity: the brighter one first.
        assert!(peaks[0].integrated > peaks[1].integrated);
        assert!((peaks[0].u - 10.0).abs() < 0.3);
    }

    #[test]
    fn touching_blobs_merge() {
        let n = 64;
        let mut img = vec![0f32; n * n];
        splat(&mut img, n, 30.0, 30.0, 500.0, 1.5);
        splat(&mut img, n, 33.0, 30.0, 500.0, 1.5);
        let mask = blob_mask(&img, 50.0);
        let peaks = find_peaks(&mask, &img, n, 1);
        assert_eq!(peaks.len(), 1);
        assert!((peaks[0].u - 31.5).abs() < 0.5);
    }

    #[test]
    fn min_area_drops_specks() {
        let n = 32;
        let mut img = vec![0f32; n * n];
        img[5 * n + 5] = 1000.0; // single-pixel zinger
        splat(&mut img, n, 20.0, 20.0, 500.0, 1.5);
        let mask = blob_mask(&img, 50.0);
        let all = find_peaks(&mask, &img, n, 1);
        let filtered = find_peaks(&mask, &img, n, 3);
        assert_eq!(all.len(), 2);
        assert_eq!(filtered.len(), 1);
    }

    #[test]
    fn u_shape_is_one_component() {
        // Classic CCL equivalence-merging case.
        let n = 8;
        let mut mask = vec![0f32; n * n];
        for y in 1..6 {
            mask[y * n + 1] = 1.0;
            mask[y * n + 5] = 1.0;
        }
        for x in 1..6 {
            mask[5 * n + x] = 1.0;
        }
        let inten = mask.clone();
        let peaks = find_peaks(&mask, &inten, n, 1);
        assert_eq!(peaks.len(), 1);
    }

    #[test]
    fn empty_mask_no_peaks() {
        let mask = vec![0f32; 16];
        let inten = vec![1f32; 16];
        assert!(find_peaks(&mask, &inten, 4, 1).is_empty());
    }

    #[test]
    fn text_roundtrip() {
        let peaks = vec![
            Peak { u: 1.25, v: 2.5, area: 9, integrated: 100.0, peak: 50.0 },
            Peak { u: 10.0, v: 20.0, area: 4, integrated: 30.0, peak: 20.0 },
        ];
        let text = peaks_to_text(&peaks, -42.5);
        let rows = parse_peaks_text(&text);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0], (1.25, 2.5, -42.5));
    }
}
