//! Seeded node-failure injection ("chaos") for the staged serving
//! stack.
//!
//! The paper's turnaround numbers assume every staged replica and
//! every dispatched task survives the campaign; at fleet scale node
//! loss is the norm. This module generates the *when and who* of
//! failures — a reproducible kill schedule — while the mechanics live
//! where the state lives:
//!
//! - [`crate::engine::SimCore::fail_node`] drops the dead node's RAM
//!   and SSD replicas (pins are not honoured) and keeps the residency
//!   mirror true;
//! - [`crate::engine::SimCore::abort_plan`] cancels the in-flight
//!   flows and unfinished steps of plans that died with the node,
//!   emitting **no** completion so the owner can resubmit under the
//!   same tag;
//! - [`crate::dataflow::sched::SessionScheduler::on_node_failure`]
//!   requeues the lost tasks exactly once (optionally stealing:
//!   [`crate::dataflow::sched::SchedulerCfg::work_stealing`]);
//! - [`crate::staging::incremental_plan`] re-stages lost
//!   replica ranges from the cheapest surviving source (peer RAM copy
//!   → node SSD promote → shared-FS re-read);
//! - [`crate::staging::service::ServiceCfg::chaos`] arms all of the
//!   above inside the serving loop.
//!
//! The failure model is **crash-restart with a warm spare**: the
//! node's memory contents vanish at the kill instant, but a
//! replacement with the same node id joins immediately — the machine
//! shape, slot pool, and network are unchanged, so recovery is purely
//! a data-and-tasks concern. Kills are sampled from a seeded
//! exponential inter-arrival process (a Poisson fleet-failure model)
//! with uniformly random victims, so a (seed, failures, mean-gap)
//! triple always yields the same schedule and the whole chaotic run
//! stays bit-reproducible.
//!
//! ```
//! use xstage::chaos::{kill_schedule, ChaosCfg};
//!
//! let cfg = ChaosCfg { seed: 7, failures: 3, mean_gap_secs: 60.0 };
//! let kills = kill_schedule(&cfg, 8);
//! assert_eq!(kills.len(), 3);
//! assert!(kills.iter().all(|&(_, node)| node < 8));
//! // Seeded: the same config always produces the same schedule.
//! assert_eq!(kills, kill_schedule(&cfg, 8));
//! ```

use crate::units::{Duration, SimTime};
use crate::util::prng::Pcg64;

/// Tag namespace for chaos kill timers. Strictly a **timer** namespace
/// — no plan is ever submitted with a chaos tag — sitting below the
/// engine's demotion plans (`1 << 46`), the staging plans (`1 << 47`),
/// and the scheduler's task plans (`1 << 48`). Directors that treat
/// `Notice::Timer` as something else (e.g. the serving layer's session
/// arrivals) must check this namespace first.
pub const CHAOS_TAG_BASE: u64 = 1 << 45;

/// Parameters of the seeded failure process.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChaosCfg {
    /// PRNG seed; the entire kill schedule is a pure function of
    /// `(seed, failures, mean_gap_secs, nodes)`.
    pub seed: u64,
    /// Number of node kills to inject. Zero disarms chaos entirely —
    /// a run with `failures: 0` is bit-identical to one with no chaos
    /// config at all (tested).
    pub failures: usize,
    /// Mean of the exponential gap between consecutive kills, in
    /// simulated seconds. This is the *fleet* inter-failure time; see
    /// [`mean_gap_secs_for_mtbf`] to derive it from a per-node MTBF.
    pub mean_gap_secs: f64,
}

impl Default for ChaosCfg {
    fn default() -> Self {
        ChaosCfg { seed: 0xC8A05, failures: 0, mean_gap_secs: 600.0 }
    }
}

impl ChaosCfg {
    /// A config whose kill cadence matches a per-node MTBF on an
    /// `nodes`-node machine (see [`mean_gap_secs_for_mtbf`]).
    pub fn calibrated(seed: u64, failures: usize, node_mtbf_hours: f64, nodes: u32) -> ChaosCfg {
        ChaosCfg {
            seed,
            failures,
            mean_gap_secs: mean_gap_secs_for_mtbf(node_mtbf_hours, nodes),
        }
    }
}

/// Fleet mean time between failures, in seconds, for a machine of
/// `nodes` nodes whose individual nodes fail independently with the
/// given MTBF: `mtbf / nodes`. A 25,000-hour-MTBF node population at
/// BG/Q scale (8,192 nodes) fails somewhere every ~3 hours; the
/// 5-node Orthros partition goes months.
///
/// ```
/// use xstage::chaos::mean_gap_secs_for_mtbf;
/// let gap = mean_gap_secs_for_mtbf(25_000.0, 8_192);
/// assert!((gap / 3600.0 - 3.05).abs() < 0.01); // ~3 hours
/// ```
pub fn mean_gap_secs_for_mtbf(node_mtbf_hours: f64, nodes: u32) -> f64 {
    assert!(node_mtbf_hours > 0.0 && node_mtbf_hours.is_finite(), "bad MTBF");
    assert!(nodes > 0, "no nodes");
    node_mtbf_hours * 3600.0 / nodes as f64
}

/// Exponential sample with the given mean (inverse-CDF on the open
/// unit interval; `1 - u` keeps the log away from zero).
fn exp_secs(rng: &mut Pcg64, mean: f64) -> f64 {
    -mean * (1.0 - rng.f64()).ln()
}

/// Materialise the kill schedule: `failures` events of (kill time,
/// victim node), times strictly increasing by exponential gaps from
/// `SimTime::ZERO`, victims uniform over `0..nodes`. Deterministic in
/// the config; callers arm each entry as an engine timer under
/// [`CHAOS_TAG_BASE`].
pub fn kill_schedule(cfg: &ChaosCfg, nodes: u32) -> Vec<(SimTime, u32)> {
    assert!(nodes > 0, "cannot schedule kills on an empty machine");
    let mut rng = Pcg64::new(cfg.seed);
    let mut t = SimTime::ZERO;
    let mut out = Vec::with_capacity(cfg.failures);
    for _ in 0..cfg.failures {
        t += Duration::from_secs_f64(exp_secs(&mut rng, cfg.mean_gap_secs));
        out.push((t, rng.below(nodes as u64) as u32));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_is_deterministic_and_in_bounds() {
        let cfg = ChaosCfg { seed: 11, failures: 50, mean_gap_secs: 30.0 };
        let a = kill_schedule(&cfg, 16);
        let b = kill_schedule(&cfg, 16);
        assert_eq!(a, b);
        assert_eq!(a.len(), 50);
        assert!(a.iter().all(|&(_, n)| n < 16));
        for w in a.windows(2) {
            assert!(w[0].0 <= w[1].0, "kill times must be non-decreasing");
        }
        let c = kill_schedule(&ChaosCfg { seed: 12, ..cfg }, 16);
        assert_ne!(a, c, "different seed, different schedule");
    }

    #[test]
    fn zero_failures_is_empty() {
        let cfg = ChaosCfg { failures: 0, ..Default::default() };
        assert!(kill_schedule(&cfg, 8).is_empty());
    }

    #[test]
    fn gaps_average_to_the_mean() {
        let cfg = ChaosCfg { seed: 3, failures: 20_000, mean_gap_secs: 40.0 };
        let sched = kill_schedule(&cfg, 4);
        let total = sched.last().unwrap().0.secs_f64();
        let mean = total / sched.len() as f64;
        assert!((mean - 40.0).abs() < 1.0, "empirical mean gap {mean}");
    }

    #[test]
    fn victims_cover_the_machine() {
        let cfg = ChaosCfg { seed: 5, failures: 200, mean_gap_secs: 1.0 };
        let mut seen = [false; 8];
        for (_, n) in kill_schedule(&cfg, 8) {
            seen[n as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "uniform victims hit every node");
    }

    #[test]
    fn mtbf_calibration() {
        // 8,192 nodes at 25k-hour MTBF: a failure every ~3.05 hours.
        let gap = mean_gap_secs_for_mtbf(25_000.0, 8_192);
        assert!((gap - 10_986.3).abs() < 1.0, "{gap}");
        let cfg = ChaosCfg::calibrated(1, 10, 25_000.0, 8_192);
        assert_eq!(cfg.mean_gap_secs, gap);
        // One node: the fleet rate is the node rate.
        assert_eq!(mean_gap_secs_for_mtbf(1.0, 1), 3600.0);
    }

    #[test]
    fn tag_namespace_sits_below_the_others() {
        use crate::staging::policy::{ELASTIC_TAG_BASE, KEEPALIVE_TAG_BASE};
        assert!(ELASTIC_TAG_BASE < KEEPALIVE_TAG_BASE);
        assert!(KEEPALIVE_TAG_BASE < crate::staging::ingest::INGEST_TAG_BASE);
        assert!(crate::staging::ingest::INGEST_TAG_BASE < CHAOS_TAG_BASE);
        assert!(CHAOS_TAG_BASE < crate::engine::DEMOTE_TAG);
        assert!(CHAOS_TAG_BASE < crate::staging::service::STAGE_TAG_BASE);
        assert!(CHAOS_TAG_BASE < crate::dataflow::sched::TASK_TAG_BASE);
    }
}
